"""Tests for fleet lifetime management: per-tile scenario batches,
stuck-fault-aware remapping invariants (bit-exact round trip, padding
preserved, top-decile weights kept off stuck-off cells, compile-cache
stability), emulator hot-swap, and the drift-timeline scheduler."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AnalogConfig
from repro.configs.rram_ps32 import CASE_A
from repro.core import conv4xbar
from repro.core.deployment import DeploymentState
from repro.core.analog import AnalogExecutor
from repro.core.crossbar import fault_aware_group_perm
from repro.models.common import init_params
from repro.nonideal import (LifetimeScheduler, Scenario, ScenarioSweep,
                            collapse_tiles, make_field_retrainer,
                            perturb_plan, realized_fault_masks, remap_plan,
                            scenario_at_age, scenario_from_json,
                            scenario_to_json, tile_scenarios)

ACFG = AnalogConfig()


def _executor(backend="analytic", **kw):
    if backend == "emulator":
        kw.setdefault("emulator_params", init_params(
            jax.random.PRNGKey(7), conv4xbar.conv4xbar_schema(CASE_A,
                                                              n_periph=2)))
        kw.setdefault("use_pallas", False)
    return AnalogExecutor(acfg=AnalogConfig(backend=backend), geom=CASE_A,
                          **kw)


def _data(K=70, N=16, B=4, seed=0):
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (K, N)) * 0.3
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, K)) * 0.5
    return x, w


# --------------------------------------------------------------------------- #
# Per-tile scenario batches
# --------------------------------------------------------------------------- #
def test_tile_scenarios_shapes_json_and_collapse():
    s = tile_scenarios(2, 4, prog_sigma=jnp.linspace(0.0, 0.3, 4),
                       p_stuck_off=0.01, n_levels=16, name="tiled")
    assert s.tile_shape == (2, 4)
    for f in ("prog_sigma", "p_stuck_off", "drift_nu", "n_levels"):
        assert getattr(s, f).shape == (2, 4)
    assert s.n_levels.dtype == jnp.int32
    # JSON round-trips array leaves as nested lists
    s2 = scenario_from_json(scenario_to_json(s))
    assert s2.tile_shape == (2, 4)
    np.testing.assert_array_equal(np.asarray(s.prog_sigma),
                                  np.asarray(s2.prog_sigma))
    # mean-field collapse
    c = collapse_tiles(s)
    assert c.tile_shape is None
    assert c.prog_sigma == pytest.approx(0.15)
    assert c.n_levels == 16
    assert not s.is_ideal and not c.is_ideal
    assert tile_scenarios(2, 4).is_ideal          # all-zero batch is ideal


def test_per_tile_perturbation_isolated_to_its_tile():
    x, w = _data()
    ex = _executor()
    plan = ex._plan_for(w, "t")
    sig = np.zeros((plan.NB, plan.NO))
    sig[0, 3] = 0.2
    ts = tile_scenarios(plan.NB, plan.NO, prog_sigma=sig, name="one_tile")
    pp = perturb_plan(plan, ACFG, ts, jax.random.PRNGKey(5))
    changed = np.asarray(pp.g_feat != plan.g_feat).any(axis=(2, 3, 4))
    assert changed[0, 3]
    changed[0, 3] = False
    assert not changed.any()       # every other tile bit-identical


def test_per_tile_shape_mismatch_raises():
    x, w = _data()
    ex = _executor()
    plan = ex._plan_for(w, "t")
    bad = tile_scenarios(plan.NB + 1, plan.NO, prog_sigma=0.1, name="bad")
    with pytest.raises(ValueError, match="tile lattice"):
        perturb_plan(plan, ACFG, bad, jax.random.PRNGKey(0))


def test_per_tile_sweep_compiles_once_across_patterns():
    x, w = _data(K=64, N=8, B=4)
    ex = _executor()
    ex.calibrate(jax.random.PRNGKey(2), w, "t", n=32)
    plan = ex._plan_for(w, "t")
    sweep = ScenarioSweep(ex, w, "t", n_draws=2)
    key = jax.random.PRNGKey(11)
    outs = []
    for hi in (0.05, 0.2, 0.4):
        grad = np.broadcast_to(np.linspace(0.0, hi, plan.NO),
                               (plan.NB, plan.NO))
        s = tile_scenarios(plan.NB, plan.NO, prog_sigma=grad, name="sw")
        outs.append(np.asarray(sweep(x, s, key)))
    assert sweep.trace_count == 1          # heterogeneity pattern is traced
    assert sweep.cache_size() == 1
    assert not np.allclose(outs[0], outs[2])


# --------------------------------------------------------------------------- #
# Stuck-fault-aware remapping
# --------------------------------------------------------------------------- #
def test_remap_identity_without_stuck_off_faults():
    x, w = _data()
    ex = _executor()
    plan = ex._plan_for(w, "t")
    rp, operm = remap_plan(plan, ACFG, Scenario(name="clean", prog_sigma=0.2),
                           jax.random.PRNGKey(0))
    assert rp is plan
    np.testing.assert_array_equal(np.asarray(operm), np.arange(plan.N))


def test_remap_roundtrip_bit_identical_at_ideal_point():
    """A remapped (but unperturbed) plan must produce bit-identical outputs
    to the base plan: groups move wholesale and the assemble gather undoes
    the move exactly."""
    x, w = _data()
    sc = Scenario(name="f", p_stuck_off=0.05)
    for backend in ("analytic", "emulator"):
        ex = _executor(backend)
        plan = ex._plan_for(w, "t")
        rp, operm = remap_plan(plan, ACFG, sc, jax.random.PRNGKey(7))
        assert not np.array_equal(np.asarray(operm), np.arange(plan.N))
        # conductance round trip: physical layout gathered back == base
        np.testing.assert_array_equal(
            np.asarray(rp.g_feat)[:, np.asarray(operm) // plan.no],
            np.asarray(plan.g_feat))
        y_base, s_base = ex.raw_matmul(x, w, "t")
        y_remap, s_remap = ex.raw_matmul(x, w, "t", plan=rp)
        np.testing.assert_array_equal(np.asarray(y_base),
                                      np.asarray(y_remap))
        np.testing.assert_array_equal(np.asarray(s_base),
                                      np.asarray(s_remap))


def test_remap_preserves_padding_cells():
    x, w = _data(K=70, N=13)       # row padding AND a partial output group
    ex = _executor()
    plan = ex._plan_for(w, "t")
    assert (np.asarray(plan.g_feat) == 0.0).any()
    sc = Scenario(name="f", p_stuck_off=0.05, prog_sigma=0.1)
    rp, operm = remap_plan(plan, ACFG, sc, jax.random.PRNGKey(3))
    pp = perturb_plan(rp, ACFG, sc, jax.random.PRNGKey(3))
    # padded (no-cell) sites travel with their group and stay exactly zero
    assert np.asarray(pp.g_feat == 0.0).sum() == \
        np.asarray(plan.g_feat == 0.0).sum()
    np.testing.assert_array_equal(np.asarray(pp.g_feat == 0.0),
                                  np.asarray(rp.g_feat == 0.0))


def test_remap_keeps_top_decile_weights_off_stuck_cells():
    x, w = _data(K=70, N=16)
    ex = _executor()
    plan = ex._plan_for(w, "t")
    sc = Scenario(name="f", p_stuck_off=0.03)
    key = jax.random.PRNGKey(7)
    _, off = realized_fault_masks(plan, sc, key)
    off = np.asarray(off)
    span = ACFG.g_max - ACFG.g_min

    def top_hits(g_feat):
        g = np.asarray(g_feat)
        excess = np.where(g > 0, (g - ACFG.g_min) / span, 0.0)
        thr = np.quantile(excess[excess > 0], 0.9)
        return int((off & (excess >= thr)).sum())

    before = top_hits(plan.g_feat)
    rp, operm = remap_plan(plan, ACFG, sc, key, top_q=0.9)
    after = top_hits(rp.g_feat)
    assert before > 0, "test vacuous: no top-decile weight was at risk"
    assert after == 0, f"remap left {after} top-decile weights on " \
                       f"stuck-off cells (was {before})"


def test_remap_toggle_invalidates_state_cache():
    """Deploying a different remap policy must not serve the stale
    (un)remapped device state from the materialization cache."""
    x, w = _data()
    ex = _executor()
    ex.deploy(scenario=Scenario(name="f", p_stuck_off=0.05),
              key=jax.random.PRNGKey(1))
    y_off = np.asarray(ex.matmul(x, w, "t"))
    st_off = ex._state_cache["t"][2]
    ex.deploy(remap=True)
    y_on = np.asarray(ex.matmul(x, w, "t"))
    st_on = ex._state_cache["t"][2]
    assert st_on is not st_off
    assert not np.array_equal(np.asarray(st_on.out_perm),
                              np.asarray(st_off.out_perm))
    assert not np.allclose(y_on, y_off)
    ex.deploy(remap=False)
    np.testing.assert_array_equal(np.asarray(ex.matmul(x, w, "t")), y_off)


def test_tiled_negative_drift_nu_is_not_ideal():
    """A per-tile batch mixing nu == 0 and nu < 0 tiles must not be
    classified ideal (max-only check would drop the drift silently)."""
    nu = np.zeros((2, 3))
    nu[1, 2] = -0.05                   # conductance growth on one tile
    s = tile_scenarios(2, 3, drift_nu=nu, drift_t=1e4, name="neg_nu")
    assert not s.is_ideal


def test_executor_remap_compile_cache_stable():
    x, w = _data()
    ex = _executor("emulator", fault_remap=True)
    ex.deploy(scenario=Scenario(name="a", p_stuck_off=0.04, prog_sigma=0.05),
              key=jax.random.PRNGKey(1))
    ya = np.asarray(ex.matmul(x, w, "t"))
    fn = ex._fns["t"][2]
    # different fleet -> different fault mask -> different permutation
    ex.deploy(scenario=Scenario(name="a", p_stuck_off=0.04, prog_sigma=0.05),
              key=jax.random.PRNGKey(2))
    yb = np.asarray(ex.matmul(x, w, "t"))
    # heavier faults, per-tile batch
    plan = ex._plan_for(w, "t")
    ex.deploy(scenario=tile_scenarios(plan.NB, plan.NO, p_stuck_off=0.08,
                                      prog_sigma=0.05, name="tiled"),
              key=jax.random.PRNGKey(3))
    yc = np.asarray(ex.matmul(x, w, "t"))
    assert ex._fns["t"][2] is fn
    assert fn._cache_size() == 1           # permutations are state leaves
    assert not np.allclose(ya, yb) and not np.allclose(yb, yc)
    # determinism: same fleet key reproduces the same remap + outputs
    ex.deploy(scenario=Scenario(name="a", p_stuck_off=0.04, prog_sigma=0.05),
              key=jax.random.PRNGKey(1))
    np.testing.assert_array_equal(np.asarray(ex.matmul(x, w, "t")), ya)


def test_ideal_scenario_with_remap_enabled_bit_identical_to_plain():
    x, w = _data()
    ex0 = _executor("emulator")
    y0 = np.asarray(ex0.matmul(x, w, "t"))
    ex1 = _executor("emulator", emulator_params=ex0.emulator_params,
                    fault_remap=True)
    ex1.deploy(scenario=Scenario(name="ideal"), key=jax.random.PRNGKey(9))
    np.testing.assert_array_equal(np.asarray(ex1.matmul(x, w, "t")), y0)
    # and the unified forward itself, fed the ideal state, is bit-identical
    plan = ex1._plan_for(w, "t")
    x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    y_sc = ex1._unified_for("t", w)(
        x2, DeploymentState.ideal(plan, eparams=ex1.emulator_params))
    np.testing.assert_array_equal(np.asarray(y_sc), y0)


# --------------------------------------------------------------------------- #
# Emulator hot-swap
# --------------------------------------------------------------------------- #
def test_hot_swap_keeps_scenario_cache_and_rebinds_plain_path():
    x, w = _data()
    ex = _executor("emulator")
    ex.deploy(scenario=Scenario(name="s", prog_sigma=0.05),
              key=jax.random.PRNGKey(3))
    y1 = np.asarray(ex.matmul(x, w, "t"))
    fn = ex._fns["t"][2]
    new_p = init_params(jax.random.PRNGKey(8),
                        conv4xbar.conv4xbar_schema(CASE_A, n_periph=2))
    ex.deploy(params=new_p)
    y2 = np.asarray(ex.matmul(x, w, "t"))
    assert ex._fns["t"][2] is fn and fn._cache_size() == 1
    assert not np.allclose(y1, y2)         # the swap actually took effect
    # the ideal deployment must serve the swapped params too (params are
    # state leaves, never baked-in constants)
    ex.deploy(scenario=None)
    y3 = np.asarray(ex.matmul(x, w, "t"))
    fresh = _executor("emulator", emulator_params=new_p)
    np.testing.assert_array_equal(y3, np.asarray(fresh.matmul(x, w, "t")))


# --------------------------------------------------------------------------- #
# Drift-timeline scheduler
# --------------------------------------------------------------------------- #
def test_scenario_at_age_scalar_and_tiled():
    sc = Scenario(name="fleet", prog_sigma=0.05, drift_nu=0.05)
    assert scenario_at_age(sc, 86_400.0).drift_t == 86_400.0
    assert scenario_at_age(sc, 86_400.0).prog_sigma == 0.05
    ts = tile_scenarios(2, 3, prog_sigma=0.05, drift_nu=0.05)
    aged = scenario_at_age(ts, 3_600.0)
    assert aged.drift_t.shape == (2, 3)
    assert float(aged.drift_t[0, 0]) == 3_600.0


def test_scheduler_mitigation_dominates_unmitigated():
    x, w = _data(K=64, N=8, B=4)
    fleet = Scenario(name="aging", prog_sigma=0.05, p_stuck_off=0.04,
                     drift_nu=0.05)
    kf = jax.random.PRNGKey(11)
    exi = _executor()
    exi.calibrate(jax.random.PRNGKey(9), w, "t", n=32)
    ref = np.asarray(exi.matmul(x, w, "t"))    # young ideal, calibrated

    def acc(y):
        n = np.linalg.norm(np.asarray(y) - ref) / np.linalg.norm(ref)
        return 1.0 / (1.0 + n)

    un = LifetimeScheduler(_executor(), fleet, remap=False,
                           recalibrate=False, key=kf, calib_n=32)
    ru = un.run(w, "t", x)
    mi = LifetimeScheduler(_executor(), fleet, remap=True,
                           recalibrate=True, key=kf, calib_n=32)
    rm = mi.run(w, "t", x)
    assert [r["label"] for r in ru] == ["t0", "1h", "1d", "1mo"]
    accs_u = [acc(r["y"]) for r in ru]
    accs_m = [acc(r["y"]) for r in rm]
    # unmitigated decays monotonically; mitigation dominates at every age
    assert all(a >= b - 1e-9 for a, b in zip(accs_u, accs_u[1:]))
    assert all(m > u for u, m in zip(accs_u[1:], accs_m[1:]))
    # ONE unified forward per tag; executables count only distinct input
    # shapes (matmul batch / cold-calibration probes / warm half-budget
    # probes) -- ages, remaps and recalibrations are all state leaves
    assert un.ex._fns["t"][2]._cache_size() == 2   # matmul + cold calib
    assert mi.ex._fns["t"][2]._cache_size() == 3   # ... + warm calib
    # calibration transfer: checkpoints past deployment warm-start from
    # the previous affine on HALF the probe budget (ROADMAP item)
    assert [r["calib_n"] for r in mi.history] == [32, 16, 16, 16]
    assert [r["calib_n"] for r in un.history] == [32, 0, 0, 0]


def test_scheduler_field_retrain_hot_swaps_compile_once():
    x, w = _data(K=64, N=8, B=4)
    ex = _executor("emulator")
    p0 = ex.emulator_params
    fleet = Scenario(name="aging", prog_sigma=0.05, p_stuck_off=0.03,
                     drift_nu=0.05)
    sched = LifetimeScheduler(
        ex, fleet, timeline=(("1h", 3_600.0), ("1d", 86_400.0)),
        remap=True, recalibrate=True,
        retrain=make_field_retrainer(jax.random.PRNGKey(5), n=32, epochs=2),
        key=jax.random.PRNGKey(4), calib_n=16)
    recs = sched.run(w, "t", x)
    assert [r["retrained"] for r in recs] == [True, True, True]
    assert ex.emulator_params is not p0        # swapped
    # matmul + cold calib + warm calib shapes; retrains/remaps are leaves
    assert ex._fns["t"][2]._cache_size() == 3
    for r in recs:
        assert np.all(np.isfinite(np.asarray(r["y"])))


# --------------------------------------------------------------------------- #
# Remap-aware calibration transfer (warm start)
# --------------------------------------------------------------------------- #
def test_calibration_transfer_warm_start_halves_probe_budget():
    """After an age/remap swap the affine refit warm-starts from the
    previous checkpoint's affine (drift is mostly a scale shift) and must
    converge in <= half the probe budget of a cold refit."""
    x, w = _data(K=64, N=8, B=4)
    fleet = Scenario(name="aging", prog_sigma=0.05, p_stuck_off=0.04,
                     drift_nu=0.05)
    kf, kc = jax.random.PRNGKey(3), jax.random.PRNGKey(9)

    def aged_executor():
        ex = _executor()
        ex.deploy(scenario=scenario_at_age(fleet, 0.0), key=kf, remap=True)
        ex.calibrate(kc, w, "t", n=64)        # deployment-time cold fit
        ex.deploy(scenario=scenario_at_age(fleet, 2.592e6))  # same fleet, old
        return ex

    cold = aged_executor()                    # pre-transfer behavior
    a_cold, b_cold = cold.calibrate(jax.random.fold_in(kc, 1), w, "t", n=64)
    assert cold._last_calib_n == 64
    warm = aged_executor()
    a_warm, b_warm = warm.calibrate(jax.random.fold_in(kc, 1), w, "t", n=64,
                                    warm_start=True)
    assert warm._last_calib_n == 32           # <= half the probe budget
    yd = np.asarray(x @ w)
    e_cold = np.linalg.norm(np.asarray(cold.matmul(x, w, "t")) - yd)
    e_warm = np.linalg.norm(np.asarray(warm.matmul(x, w, "t")) - yd)
    assert e_warm <= 1.05 * e_cold + 1e-9     # converged at half budget
    assert abs(a_warm - a_cold) < 0.1 * max(1.0, abs(a_cold))
    # without a previous affine the warm request falls back to a cold fit
    fresh = _executor()
    fresh.deploy(scenario=scenario_at_age(fleet, 2.592e6), key=kf, remap=True)
    fresh.calibrate(kc, w, "t", n=64, warm_start=True)
    assert fresh._last_calib_n == 64
