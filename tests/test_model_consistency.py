"""Model-level invariants:
  * blockwise/grouped attention variants == naive masked softmax reference
  * prefill + decode == full forward (cache consistency), per layer family
  * chunked cross-entropy == unchunked
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config, reduced
from repro.configs.base import ParallelConfig
from repro.models import attention as A
from repro.models import model as M
from repro.runtime import steps as S

PCFG = ParallelConfig(attn_block_kv=32, xent_chunk=16, scan_chunk=16)


def naive_attention(q, k, v, *, causal, window=0, chunk=0):
    B, Sq, H, D = q.shape
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (D ** -0.5)
    qi = jnp.arange(Sq)[:, None]
    ki = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= ki <= qi
    if window:
        mask &= (qi - ki) < window
    if chunk:
        mask &= (qi // chunk) == (ki // chunk)
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bhqd", p.astype(v.dtype), v)
    return jnp.transpose(o, (0, 2, 1, 3))


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 50), s_len=st.sampled_from([64, 128]),
       h=st.sampled_from([1, 2, 4]))
def test_flash_matches_naive(seed, s_len, h):
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (2, s_len, h, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, s_len, h, 16))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, s_len, h, 16))
    out = A.flash_attention(q, k, v, causal=True, block_kv=32)
    ref = naive_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("window", [16, 32])
def test_local_matches_naive(window):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (2, 128, 3, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, 128, 3, 16))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, 128, 3, 16))
    out = A.local_attention(q, k, v, window)
    ref = naive_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("chunk", [32, 64])
def test_chunked_matches_naive(chunk):
    key = jax.random.PRNGKey(1)
    q = jax.random.normal(key, (2, 128, 2, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, 128, 2, 16))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, 128, 2, 16))
    out = A.chunked_attention(q, k, v, chunk)
    ref = naive_attention(q, k, v, causal=True, chunk=chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)


def test_triangular_matches_flash():
    key = jax.random.PRNGKey(2)
    q = jax.random.normal(key, (1, 256, 2, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 256, 2, 16))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 256, 2, 16))
    out = A.triangular_attention(q, k, v, block_q=64, block_kv=64)
    ref = A.flash_attention(q, k, v, causal=True, block_kv=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)


# --------------------------------------------------------------------------- #
# prefill + decode == full forward
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("arch", ["gemma3-1b", "falcon-mamba-7b",
                                  "recurrentgemma-2b", "llama4-scout-17b-a16e",
                                  "seamless-m4t-large-v2"])
def test_decode_consistency(arch):
    """logits(prefill S, decode S..S+2) == logits(full forward S+3)."""
    cfg = reduced(get_config(arch))
    if cfg.moe is not None:
        # drop-free capacity so prefill and decode route identically
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(
                cfg.moe, eval_capacity_factor=float(cfg.moe.num_experts)))
    B, P, G = 2, 32, 3
    total = P + G
    key = jax.random.PRNGKey(0)
    toks = jax.random.randint(key, (B, total), 0, cfg.vocab_size)
    params = S.init_train_state(key, cfg)["params"]

    extra = {}
    if cfg.frontend == "vision":
        extra["image_embeds"] = jax.random.normal(
            key, (B, cfg.frontend_tokens, cfg.d_model), jnp.float32)
    if cfg.encoder_layers:
        extra["enc_frames"] = jax.random.normal(
            key, (B, P, cfg.d_model), jnp.float32)

    # full forward on all tokens (eval mode: same MoE routing as decode)
    h_full, _, _ = M.forward(params, toks, cfg=cfg, pcfg=PCFG, mode="prefill",
                             compute_dtype=jnp.float32, **extra)
    logits_full = M.compute_logits(params, h_full, cfg)

    # prefill P tokens, then decode G tokens (teacher forcing)
    h_pre, cache, _ = M.forward(params, toks[:, :P], cfg=cfg, pcfg=PCFG,
                                mode="prefill", compute_dtype=jnp.float32,
                                **extra)
    logits_pre = M.compute_logits(params, h_pre, cfg)
    np.testing.assert_allclose(np.asarray(logits_pre[:, -1]),
                               np.asarray(logits_full[:, P - 1]),
                               rtol=3e-3, atol=3e-3)

    # pad attention caches from P to `total` positions where needed
    cs = M.model_cache_schema(cfg, B, total, dtype=jnp.float32,
                              cross_len=(P if cfg.encoder_layers else 0))
    zero = M.zeros_cache(cs)

    def splice(z, c):
        c = c.astype(z.dtype)
        if z.shape == c.shape:
            return c
        pads = [(0, zd - cd) for zd, cd in zip(z.shape, c.shape)]
        return jnp.pad(c, pads)

    cache = jax.tree.map(splice, zero, cache)
    for i in range(G):
        pos = jnp.asarray(P + i, jnp.int32)
        logits_dec, cache = M.decode_step(params, toks[:, P + i:P + i + 1],
                                          cache, pos, cfg=cfg, pcfg=PCFG,
                                          compute_dtype=jnp.float32)
        np.testing.assert_allclose(
            np.asarray(logits_dec), np.asarray(logits_full[:, P + i]),
            rtol=3e-3, atol=3e-3,
            err_msg=f"{arch} decode step {i}")


# --------------------------------------------------------------------------- #
# chunked xent == full xent
# --------------------------------------------------------------------------- #
def test_chunked_xent_matches_full():
    cfg = reduced(get_config("deepseek-coder-33b"))
    key = jax.random.PRNGKey(0)
    B, S_len = 2, 64
    params = S.init_train_state(key, cfg)["params"]
    h = jax.random.normal(key, (B, S_len, cfg.d_model)) * 0.3
    t = jax.random.randint(jax.random.fold_in(key, 1), (B, S_len), 0,
                           cfg.vocab_size)
    mask = (jax.random.uniform(jax.random.fold_in(key, 2), (B, S_len)) > 0.2
            ).astype(jnp.float32)
    chunked = M.chunked_xent(params, h, t, mask, cfg=cfg, chunk=16, z_coef=0.0)
    full = M.chunked_xent(params, h, t, mask, cfg=cfg, chunk=S_len, z_coef=0.0)
    np.testing.assert_allclose(float(chunked), float(full), rtol=1e-5)
