"""Property tests for the pure partition math of the tensor-parallel
analog serving plane (repro.parallel.sharding; docs/parallel.md).

No mesh and no devices here: these pin down the invariants the
``shard_map``-ed forward in ``core.analog`` rests on, on a single
device, with integer payloads so any violation is an exact mismatch
rather than float noise:

  * ``lattice_scheme`` / ``local_lattice`` factorize the tile lattice
    exactly (shard-local shapes multiply back to the global lattice,
    col preferred whenever it is available);
  * ``shard_output_slices`` tiles the flat output-column range exactly
    -- contiguous, disjoint, in order;
  * the col-scheme scatter-then-psum assembly and the row-scheme
    partial-sum-then-psum assembly are each a PARTITION of the
    unsharded ``fault_aware_group_perm`` assembly: random tile shapes,
    mesh factorizations and stuck-fault permutations never drop,
    duplicate, or reorder an output group.

Runs under real hypothesis or the deterministic stub in conftest.py.
"""
import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.base import AnalogConfig
from repro.configs.rram_ps32 import CASE_A
from repro.core.crossbar import build_conductance_plan, fault_aware_group_perm
from repro.core.deployment import _STATE_FIELDS
from repro.parallel.sharding import (lattice_scheme, local_lattice,
                                     shard_output_slices, state_pspecs)

ACFG = AnalogConfig()
# CASE_A: rows=64, D=4 tiles per block group -> 256 K-rows per block group
_K_PER_NB = CASE_A.tiles * ACFG.rows


def _plan(rng, nb, n):
    """A real conductance plan with exactly ``nb`` block groups and
    ``n`` output columns (CASE_A has one output per block, so NO=n)."""
    K = int(rng.integers((nb - 1) * _K_PER_NB + 1, nb * _K_PER_NB + 1))
    w = rng.normal(size=(K, n)).astype(np.float32) * 0.3
    plan = build_conductance_plan(jnp.asarray(w), ACFG, CASE_A)
    assert (plan.NB, plan.NO) == (nb, n), (plan.NB, plan.NO)
    return plan


@settings(max_examples=25, deadline=None)
@given(nb=st.integers(min_value=1, max_value=12),
       no=st.integers(min_value=1, max_value=12),
       tp=st.sampled_from([1, 2, 3, 4, 8]))
def test_lattice_scheme_factorizes_exactly(nb, no, tp):
    scheme = lattice_scheme(nb, no, tp)
    nb_l, no_l = local_lattice(nb, no, tp, scheme)
    if scheme == "col":
        assert no % tp == 0 and (nb_l, no_l * tp) == (nb, no)
    elif scheme == "row":
        assert nb % tp == 0 and (nb_l * tp, no_l) == (nb, no)
    else:
        assert (nb_l, no_l) == (nb, no)
        assert tp <= 1 or (no % tp != 0 and nb % tp != 0)
    if tp > 1 and no % tp == 0:
        # col is preferred whenever available: it keeps the serving
        # plane's bit-identity contract (module docstring)
        assert scheme == "col"


@settings(max_examples=25, deadline=None)
@given(groups=st.integers(min_value=1, max_value=6),
       cpg=st.integers(min_value=1, max_value=4),
       tp=st.sampled_from([1, 2, 4]))
def test_shard_output_slices_tile_the_columns_exactly(groups, cpg, tp):
    no = groups * tp
    slices = shard_output_slices(no, cpg, tp)
    cols = [c for a, b in slices for c in range(a, b)]
    assert cols == list(range(no * cpg))     # contiguous, disjoint, ordered


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10**9),
       nb=st.integers(min_value=1, max_value=3),
       groups=st.integers(min_value=1, max_value=3),
       tp=st.sampled_from([2, 4]))
def test_sharded_assembly_partitions_fault_aware_assembly(seed, nb, groups,
                                                          tp):
    rng = np.random.default_rng(seed)
    plan = _plan(rng, nb, groups * tp)
    stuck = rng.random(np.shape(plan.g_feat)) < 0.05
    out_perm, gperm, ginv = fault_aware_group_perm(
        np.asarray(plan.g_feat), stuck, plan, ACFG)
    # the remap itself is a bijection: no group dropped or duplicated
    assert sorted(gperm.tolist()) == list(range(plan.NO))
    assert sorted(ginv.tolist()) == list(range(plan.NO))
    assert sorted(out_perm.tolist()) == list(range(plan.N))

    # integer block outputs: any dropped/duplicated/reordered group is an
    # exact mismatch, never float noise
    M = 3
    flat = rng.integers(-8, 9, size=(M, plan.NB, plan.NO * plan.no))
    ref = flat.sum(axis=1)[:, out_perm]      # unsharded permuted assembly

    # col scheme: each shard sums the full bitline for its own column
    # slice and scatters it; the "psum" is the += over shards
    acc = np.zeros((M, plan.NO * plan.no), flat.dtype)
    for a, b in shard_output_slices(plan.NO, plan.no, tp):
        acc[:, a:b] += flat[:, :, a:b].sum(axis=1)
    np.testing.assert_array_equal(acc[:, out_perm], ref)

    # row scheme at its finest grain (one block group per shard): the
    # psum finishes the digital block-group accumulation
    row = sum(flat[:, s] for s in range(plan.NB))
    np.testing.assert_array_equal(row[:, out_perm], ref)


def test_state_pspecs_cover_every_deployment_state_field():
    """The leaf PartitionSpec table stays in sync with DeploymentState:
    adding a state field without deciding its placement is an error."""
    for scheme in (None, "row", "col"):
        assert set(state_pspecs(scheme)) == set(_STATE_FIELDS)
