"""Serving test suite for the continuous-batching loop
(repro.launch.batching; docs/serving.md):

  * batched decode is bit-identical to N sequential single-request
    ``ServeSession.generate()`` calls -- digital AND at the analog
    ideal corner (bulk prefill keeps per-row arithmetic identical);
  * mixed prefill+decode batches stay compile-once under a
    ``RecompileSentinel`` (packed mode runs prompt tokens through the
    SAME batched decode program: zero prefill compiles);
  * KV-page alloc/free invariants across admit/finish/cancel: no page
    leaked, none double-assigned, occupancy never exceeds the slots;
  * property-based scheduler checks (hypothesis, or the deterministic
    stub in conftest.py): random admit/step/cancel interleavings never
    drop, duplicate, or reorder a request's tokens.

The property tests compare against per-request EXPECTED tokens produced
by the same engine serving each prompt alone.  The engine's decode call
is shape-stable in ``max_slots``, and GEMM rows round independently, so
solo-vs-packed outputs are bitwise equal regardless of which other
requests share the batch -- any mismatch is a scheduler bug (dropped /
duplicated / reordered tokens), not float noise.
"""
import asyncio

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.launch.batching import (AsyncBatchServer, ContinuousBatchEngine,
                                   KVPagePool, QueueFull)
from repro.launch.serve import ServeSession
from repro.obs import RecompileSentinel

ARCH = "gemma3-1b"
P, G = 8, 8


def _prompts(n, length, vocab, seed=1):
    key = jax.random.PRNGKey(seed)
    return [np.asarray(jax.random.randint(jax.random.fold_in(key, i),
                                          (length,), 0, vocab), np.int32)
            for i in range(n)]


def _sequential_reference(sess: ServeSession, prompts):
    """N sequential single-request generates through one batch=1 session."""
    outs = []
    for p in prompts:
        sess.batch = {"tokens": p[None, :]}
        outs.append(sess.generate()["tokens"][0])
    return outs


@pytest.fixture(scope="module")
def digital():
    """Shared digital session + 4-slot engine + solo-expected tokens.
    Property tests take this fixture too: the conftest hypothesis stub's
    ``given`` wrapper advertises non-strategy params via ``__signature__``,
    so pytest injects fixtures the same way real hypothesis does."""
    sess = ServeSession(ARCH, reduced=True, batch=1, prompt_len=P,
                        gen=G, seed=0)
    eng = ContinuousBatchEngine(sess, max_slots=4, max_len=P + G)
    prompts = _prompts(6, P, sess.cfg.vocab_size)
    expected = [eng.run([p], max_new=G)[0] for p in prompts]
    return sess, eng, prompts, expected


# --------------------------------------------------------------------------- #
# Bit-identity vs sequential single-request sessions
# --------------------------------------------------------------------------- #
def test_batched_bit_identical_to_sequential_sessions(digital):
    sess, eng, prompts, _ = digital
    ref_sess = ServeSession(ARCH, reduced=True, batch=1, prompt_len=P,
                            gen=G, seed=0)
    refs = _sequential_reference(ref_sess, prompts[:4])
    outs = eng.run(prompts[:4], max_new=G)
    for r, o in zip(refs, outs):
        np.testing.assert_array_equal(r, o)
    eng.pool.check()


def test_staggered_admission_and_slot_reuse_bit_identical(digital):
    """More requests than slots: waves + slot reuse must not leak any
    previous occupant's cache into a new request."""
    sess, _, prompts, expected = digital
    eng2 = ContinuousBatchEngine(sess, max_slots=2, max_len=P + G)
    outs = eng2.run(prompts, max_new=G)
    solo = [eng2.run([p], max_new=G)[0] for p in prompts]
    for s, o in zip(solo, outs):
        np.testing.assert_array_equal(s, o)
    eng2.pool.check()


def test_batched_bit_identical_ideal_corner_analog():
    """At the analog ideal corner, batched serving with threaded
    DeploymentStates == sequential single-request ServeSession calls."""
    from repro.configs.base import AnalogConfig
    from repro.configs.rram_ps32 import CASE_A
    from repro.core.analog import AnalogExecutor

    def mk():
        return AnalogExecutor(
            acfg=AnalogConfig(backend="analytic", layers=("mlp",)),
            geom=CASE_A)

    Ga = 4
    ref_sess = ServeSession(ARCH, reduced=True, batch=1, prompt_len=P,
                            gen=Ga, seed=0, executor=mk())
    prompts = _prompts(2, P, ref_sess.cfg.vocab_size)
    refs = _sequential_reference(ref_sess, prompts)

    ex = mk()
    sess = ServeSession(ARCH, reduced=True, batch=1, prompt_len=P, gen=Ga,
                        seed=0, executor=ex)
    eng = ContinuousBatchEngine(sess, max_slots=2, max_len=P + Ga)
    with RecompileSentinel(session=eng, executor=ex, label="serve-loop"):
        outs = eng.run(prompts, max_new=Ga)
    for r, o in zip(refs, outs):
        np.testing.assert_array_equal(r, o)
    assert eng.decode_traces == 1 and eng.prefill_traces == 1


# --------------------------------------------------------------------------- #
# Mixed prefill+decode batches compile once (packed mode)
# --------------------------------------------------------------------------- #
def test_mixed_prefill_decode_compile_once_packed(digital):
    sess, _, prompts, _ = digital
    eng = ContinuousBatchEngine(sess, max_slots=4, max_len=P + G,
                                prefill_mode="packed")
    with RecompileSentinel(session=eng, label="packed") as sent:
        r0 = eng.submit(prompts[0], G)
        r1 = eng.submit(prompts[1], G)
        for _ in range(P // 2):          # r0/r1 mid-prefill...
            eng.step()
        r2 = eng.submit(prompts[2], G)   # ...r2/r3 admitted mid-flight:
        r3 = eng.submit(prompts[3], G)   # prefill+decode share every tick
        eng.drain()
    assert sent.ok
    assert eng.decode_traces == 1, "mixed batches must not retrace"
    assert eng.prefill_traces == 0, "packed mode never bulk-prefills"
    # solo through the SAME packed engine: batching must not change any
    # request's tokens (packed prefill is not bitwise vs bulk/flash
    # prefill, so the reference is packed-solo, not the bulk expected)
    solo = [eng.run([p], max_new=G)[0] for p in prompts[:4]]
    for rid, exp in zip((r0, r1, r2, r3), solo):
        np.testing.assert_array_equal(eng.result(rid), exp)
    assert eng.decode_traces == 1, "solo reruns reuse the same program"


# --------------------------------------------------------------------------- #
# KV page pool invariants
# --------------------------------------------------------------------------- #
def test_page_pool_unit():
    pool = KVPagePool(n_slots=3, max_seq=16, page_size=4)
    assert pool.total_pages == 12 and pool.pages_for(16) == 4
    assert pool.reserve(0, 16) and pool.reserve(1, 9)
    pool.check()
    assert pool.in_use() == 4 + 3
    assert not pool.reserve(0, 4), "slot already owns pages"
    assert not pool.reserve(2, 24), "over capacity refuses whole request"
    pool.check()
    freed = pool.release(0)
    assert len(freed) == 4 and pool.release(0) == []
    pool.check()
    # oversubscribed pool: admission-side backpressure
    small = KVPagePool(n_slots=4, max_seq=16, page_size=4, total_pages=6)
    assert small.reserve(0, 16)
    assert not small.can_admit(16) and not small.reserve(1, 16)
    small.check()


def test_kv_page_invariants_through_lifecycle(digital):
    """admit/finish/cancel never leak or double-assign a page; occupancy
    never exceeds the slot count."""
    sess, _, prompts, _ = digital
    eng = ContinuousBatchEngine(sess, max_slots=2, max_len=P + G)
    rids = [eng.submit(p, max_new=2 + i % 3) for i, p in enumerate(prompts)]
    cancelled = rids[3]
    n_busy = 0
    while eng.busy:
        eng.step()
        live = [r for r in eng.slots if r is not None]
        assert len(live) <= eng.max_slots
        assert len(set(live)) == len(live), "request in two slots"
        assert set(eng.pool.owned) == {eng.requests[r].slot for r in live}
        eng.pool.check()
        n_busy += 1
        if n_busy == 2 and not eng.requests[cancelled].done:
            eng.cancel(cancelled)
            eng.pool.check()
    assert eng.pool.in_use() == 0 and len(eng.pool.free) == \
        eng.pool.total_pages
    assert eng.requests[cancelled].status == "cancelled"
    for rid in rids:
        if rid != cancelled:
            assert len(eng.result(rid)) == eng.requests[rid].max_new


def test_submit_backpressure():
    pool = KVPagePool(2, 8, page_size=8)
    assert pool.reserve(0, 8) and not pool.can_admit(24)


def test_engine_queue_backpressure(digital):
    sess, _, prompts, _ = digital
    eng = ContinuousBatchEngine(sess, max_slots=1, max_len=P + G,
                                max_queue=2)
    eng.submit(prompts[0], 2)
    eng.submit(prompts[1], 2)
    with pytest.raises(QueueFull):
        eng.submit(prompts[2], 2)
    eng.drain()


# --------------------------------------------------------------------------- #
# Property-based scheduler tests
# --------------------------------------------------------------------------- #
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10**9))
def test_scheduler_never_drops_dups_or_reorders(digital, seed):
    """Random admit/step/cancel interleavings: every finished request's
    tokens equal its solo-served expectation exactly (no drop/dup/
    reorder); cancelled requests hold a strict prefix."""
    sess, eng, prompts, expected = digital
    assert not eng.busy                      # clean engine between examples
    rng = np.random.default_rng(seed)
    n_req = int(rng.integers(1, len(prompts) + 1))
    order = rng.permutation(len(prompts))[:n_req]
    rids = {}
    for j, pi in enumerate(order):
        rids[int(pi)] = eng.submit(prompts[pi], max_new=G)
        for _ in range(int(rng.integers(0, 4))):
            eng.step()
            eng.pool.check()
        if rng.random() < 0.25:              # cancel a random live request
            victim = int(rng.choice(order[:j + 1]))
            if not eng.requests[rids[victim]].done:
                eng.cancel(rids[victim])
    eng.drain()
    for pi, rid in rids.items():
        req = eng.requests[rid]
        got = eng.result(rid)
        exp = expected[pi]
        if req.status == "done":
            np.testing.assert_array_equal(got, exp)
        else:                                # cancelled: prefix, never junk
            np.testing.assert_array_equal(got, exp[:len(got)])
    assert eng.pool.in_use() == 0


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10**9))
def test_page_pool_random_ops_hold_invariants(seed):
    """Pure-bookkeeping fuzz: any reserve/release sequence keeps the
    pool partitioned (every page free xor owned by exactly one slot)."""
    rng = np.random.default_rng(seed)
    pool = KVPagePool(n_slots=4, max_seq=32, page_size=int(rng.integers(1, 9)),
                      total_pages=int(rng.integers(4, 20)))
    for _ in range(50):
        slot = int(rng.integers(0, 4))
        if rng.random() < 0.5:
            pool.reserve(slot, int(rng.integers(1, 40)))
        else:
            pool.release(slot)
        pool.check()
        assert pool.in_use() + len(pool.free) == pool.total_pages


# --------------------------------------------------------------------------- #
# Async facade
# --------------------------------------------------------------------------- #
def test_async_server_matches_solo(digital):
    sess, eng, prompts, expected = digital

    async def go():
        with AsyncBatchServer(eng) as srv:
            return await asyncio.gather(
                *[srv.generate(p, G) for p in prompts[:4]])

    outs = asyncio.run(go())
    for o, exp in zip(outs, expected[:4]):
        np.testing.assert_array_equal(o, exp)
    assert eng.pool.in_use() == 0
