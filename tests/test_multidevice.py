"""Multi-device behaviour, exercised in subprocesses with 8 forced host
devices (XLA's device count is locked at first jax init, so none of this
can run in the main pytest process; ``run_multidevice`` in conftest.py
owns the subprocess + env plumbing).

Training plane (pre-existing coverage, now on the shared helper):
  * sharded training on a (4, 2) mesh: loss decreases, state is sharded;
    elastic restart onto (2, 4) continues from the same checkpoints
  * int8-compressed psum matches fp32 psum within quantization error

Serving plane (the tensor-parallel analog deploy tier; docs/parallel.md):
  * sharded ideal-corner forward is bit-identical to the replicated path
    (col scheme), float-close (row scheme), exact again when the lattice
    divides neither axis (replicated fallback)
  * a corner -> age -> remap -> params swap sequence on a (2, 4) mesh
    compiles exactly once (RecompileSentinel + the unified jit cache)
  * a deployment npz saved under a (4, 2) mesh re-shards onto (2, 4) on
    load and serves bit-identical outputs
  * guard for the jax 0.4.37 GSPMD miscompilation that shaped the
    executor's shard_map bodies: a batch-axis concat OUTSIDE a shard_map
    (feeding its operand inside jit) returns wrong values on a dp>1
    mesh, so the generic path passes the positive/negative drive rails
    as SEPARATE operands and concatenates inside the body
"""
import pytest

from conftest import run_multidevice

TRAIN_SCRIPT = r"""
import jax, numpy as np
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
assert len(jax.devices()) == 8

from repro.configs import get_config, reduced
from repro.configs.base import ParallelConfig, TrainConfig
from repro.data import SyntheticLMData
from repro.runtime.trainer import Trainer

cfg = reduced(get_config("qwen1.5-110b"))
pcfg = ParallelConfig(attn_block_kv=32, xent_chunk=32, scan_chunk=16)
tcfg = TrainConfig(lr=1e-3, warmup_steps=2, total_steps=20,
                   checkpoint_every=5, keep_checkpoints=2)
data = SyntheticLMData(cfg, seq_len=32, global_batch=8)

from repro.launch.mesh import _make_mesh

mesh1 = _make_mesh((4, 2), ("data", "model"))
import shutil; shutil.rmtree("/tmp/repro_md_ckpt", ignore_errors=True)
tr = Trainer(cfg=cfg, pcfg=pcfg, tcfg=tcfg, mesh=mesh1, data=data,
             ckpt_dir="/tmp/repro_md_ckpt")
s1 = tr.run(10)
assert s1["final_step"] == 10, s1
l1 = [m["loss"] for m in tr.metrics_log]

# ELASTIC: restart on a different mesh from the same checkpoints
mesh2 = _make_mesh((2, 4), ("data", "model"))
tr2 = tr.remesh(mesh2)
s2 = tr2.run(15)
assert s2["final_step"] == 15, s2
assert tr2.metrics_log[0]["step"] == 10
# loss continues from where it was (same data stream, same params)
assert abs(tr2.metrics_log[0]["loss"] - l1[-1]) < 0.8, \
    (tr2.metrics_log[0]["loss"], l1[-1])
print("TRAIN_ELASTIC_OK")
"""

PSUM_SCRIPT = r"""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
assert len(jax.devices()) == 8

from repro.launch.mesh import _make_mesh
from repro.parallel.collectives import compressed_psum, shard_map_compat

mesh = _make_mesh((8,), ("pod",))
x = jax.random.normal(jax.random.PRNGKey(0), (8, 256))
def f(xl):
    return compressed_psum(xl, "pod")
y = shard_map_compat(f, mesh, P("pod"), P("pod"))(x)
exact = jnp.broadcast_to(x.sum(0, keepdims=True), x.shape)
err = float(jnp.max(jnp.abs(y - exact)))
scale = float(jnp.max(jnp.abs(x))) / 127.0
assert err <= 8 * scale + 1e-6, (err, scale)
print("PSUM_OK")
"""

# shared prelude for the sharded analog serving scripts: a replicated
# and a mesh-carrying executor over the same emulator params
_ANALOG_PRELUDE = r"""
import numpy as np, jax
import jax.numpy as jnp
assert len(jax.devices()) == 8
from repro.configs.base import AnalogConfig
from repro.configs.rram_ps32 import CASE_A
from repro.core import conv4xbar
from repro.core.analog import AnalogExecutor
from repro.models.common import init_params
from repro.parallel.sharding import serve_mesh

PARAMS = init_params(jax.random.PRNGKey(7),
                     conv4xbar.conv4xbar_schema(CASE_A, n_periph=2))

def mk(backend="emulator", **kw):
    if backend == "emulator":
        kw.setdefault("emulator_params", PARAMS)
        kw.setdefault("use_pallas", False)
    return AnalogExecutor(acfg=AnalogConfig(backend=backend), geom=CASE_A,
                          **kw)

def data(K, N, B=6, seed=0):
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (K, N)) * 0.3
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, K)) * 0.5
    return x, w
"""

BIT_IDENTITY_SCRIPT = _ANALOG_PRELUDE + r"""
mesh = serve_mesh(2, 4)

# col scheme (NO=8, tp=4): BIT-identical for the emulator fast path AND
# the generic (analytic) path -- each shard contributes its own columns
# plus exact zeros, so the single psum adds nothing inexact
x, w = data(70, 8)
for backend in ("emulator", "analytic"):
    y_rep = np.asarray(mk(backend).matmul(x, w, "t"))
    exs = mk(backend, mesh=mesh)
    assert exs._scheme_for(1, 8) == "col"
    y_sh = np.asarray(exs.matmul(x, w, "t"))
    np.testing.assert_array_equal(y_sh, y_rep)

# row scheme (NB=4, tp=4), forced: the psum re-brackets the f32 bitline
# accumulation, so identity holds to float tolerance, not bitwise
x, w = data(1024, 5)
y_rep = np.asarray(mk().matmul(x, w, "t"))
y_sh = np.asarray(mk(mesh=mesh, shard_scheme="row").matmul(x, w, "t"))
np.testing.assert_allclose(y_sh, y_rep, rtol=1e-5, atol=2e-6)

# neither axis divides tp (NB=3, NO=5): lattice replicates over model,
# no psum, still exact
x, w = data(768, 5)
y_rep = np.asarray(mk().matmul(x, w, "t"))
exs = mk(mesh=mesh)
assert exs._scheme_for(3, 5) is None
y_sh = np.asarray(exs.matmul(x, w, "t"))
np.testing.assert_array_equal(y_sh, y_rep)
print("SHARD_IDENTITY_OK")
"""

COMPILE_ONCE_SCRIPT = _ANALOG_PRELUDE + r"""
from repro.nonideal import get_scenario
from repro.obs import RecompileSentinel

ex = mk(mesh=serve_mesh(2, 4))
x, w = data(70, 8, B=4)

outs = [np.asarray(ex.matmul(x, w, "t"))]                     # ideal
with RecompileSentinel(executor=ex, label="sharded-swaps") as sent:
    ex.deploy(scenario=get_scenario("stressed"), key=jax.random.PRNGKey(1))
    outs.append(np.asarray(ex.matmul(x, w, "t")))             # corner
    ex.deploy(age=2.592e6)
    outs.append(np.asarray(ex.matmul(x, w, "t")))             # age
    ex.deploy(remap=True)
    outs.append(np.asarray(ex.matmul(x, w, "t")))             # remap
    new_p = init_params(jax.random.PRNGKey(8),
                        conv4xbar.conv4xbar_schema(CASE_A, n_periph=2))
    ex.deploy(params=new_p)
    outs.append(np.asarray(ex.matmul(x, w, "t")))             # hot-swap
assert ex._fns["t"][2]._cache_size() == 1, ex._fns["t"][2]._cache_size()
for a, b in zip(outs, outs[1:]):
    assert not np.array_equal(a, b)          # each swap actually changed y
print("COMPILE_ONCE_OK", sent.new_counts)
"""

RESHARD_SCRIPT = _ANALOG_PRELUDE + r"""
import os, tempfile
from jax.sharding import PartitionSpec as P
from repro.core.deployment import load_deployment, save_deployment
from repro.nonideal import get_scenario

x, w = data(70, 8, B=4)

# serve a stressed + remapped deployment on a (4, 2) mesh, pin its state
ex1 = mk(mesh=serve_mesh(4, 2))
ex1.deploy(scenario=get_scenario("stressed"), remap=True,
           key=jax.random.PRNGKey(1))
st = ex1.state_for("t", w)
ex1.deploy(states={"t": st})                  # pin the read-cycle key
y1 = np.asarray(ex1.matmul(x, w, "t"))
path = os.path.join(tempfile.mkdtemp(), "dep.npz")
save_deployment(path, {"t": st}, ex1.deployment)

# load under a DIFFERENT mesh shape: values re-shard onto (2, 4)
ex2 = mk(mesh=serve_mesh(2, 4))
states, dep = load_deployment(path, executor=ex2)
ex2.deploy(scenario=dep.scenario, key=dep.key, remap=dep.remap,
           states=dep.states)
st2 = ex2.state_for("t", w)
sh = st2.gf.sharding
assert tuple(sh.mesh.devices.shape) == (2, 4), sh
assert sh.spec == P(None, "model"), sh.spec   # col scheme shards NO
y2 = np.asarray(ex2.matmul(x, w, "t"))
np.testing.assert_array_equal(y2, y1)         # same fleet, new mesh
print("RESHARD_OK")
"""

CONCAT_GUARD_SCRIPT = r"""
import jax, numpy as np
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
assert len(jax.devices()) == 8
from repro.parallel.collectives import shard_map_compat
from repro.parallel.sharding import DATA_AXIS, serve_mesh

mesh = serve_mesh(2, 4)
h = jnp.arange(8.0).reshape(4, 2)

# the shape the executor's generic path USES: rails as separate
# shard_map operands, concatenated INSIDE the body and reduced back to
# the per-device batch before leaving it (so the doubled batch never
# crosses the shard boundary and the output keeps global row order)
def body(a, b):
    c = jnp.concatenate([a, b], axis=0)
    n = a.shape[0]
    return c[:n] * 2.0 + c[n:]
f = shard_map_compat(body, mesh, (P(DATA_AXIS), P(DATA_AXIS)),
                     P(DATA_AXIS))
y = np.asarray(jax.jit(lambda t: f(t, t + 6.0))(h))
np.testing.assert_array_equal(y, np.asarray(h) * 2.0 + np.asarray(h) + 6.0)

expect = np.concatenate([np.asarray(h), np.asarray(h) + 6.0], axis=0)

# the shape it must NOT use: on jax 0.4.37, a batch-axis concat under
# jit feeding a shard_map operand on a dp>1 mesh returns values scaled
# by the model-axis size (GSPMD miscompilation; even for an identity
# body with no psum).  Report either way -- if a future jax fixes it,
# the note below flags that the workaround could be retired.
g = shard_map_compat(lambda a: a, mesh, P(DATA_AXIS), P(DATA_AXIS))
z = np.asarray(jax.jit(
    lambda t: g(jnp.concatenate([t, t + 6.0], axis=0)))(h))
if np.array_equal(z, expect):
    print("NOTE: upstream concat-into-shard_map bug no longer reproduces")
else:
    print("upstream bug still present (max abs err "
          f"{float(np.max(np.abs(z - expect))):.3g})")
print("CONCAT_GUARD_OK")
"""


@pytest.mark.slow
def test_multidevice_training_and_elastic_restart():
    out = run_multidevice(TRAIN_SCRIPT)
    assert "TRAIN_ELASTIC_OK" in out, out[-2000:]


@pytest.mark.slow
def test_multidevice_int8_compressed_psum():
    out = run_multidevice(PSUM_SCRIPT)
    assert "PSUM_OK" in out, out[-2000:]


@pytest.mark.slow
def test_sharded_serve_bit_identical_to_replicated():
    out = run_multidevice(BIT_IDENTITY_SCRIPT)
    assert "SHARD_IDENTITY_OK" in out, out[-2000:]


@pytest.mark.slow
def test_sharded_swap_sequence_compiles_once():
    out = run_multidevice(COMPILE_ONCE_SCRIPT)
    assert "COMPILE_ONCE_OK" in out, out[-2000:]


@pytest.mark.slow
def test_deployment_reshards_across_mesh_shapes():
    out = run_multidevice(RESHARD_SCRIPT)
    assert "RESHARD_OK" in out, out[-2000:]


@pytest.mark.slow
def test_concat_into_shard_map_guard():
    out = run_multidevice(CONCAT_GUARD_SCRIPT)
    assert "CONCAT_GUARD_OK" in out, out[-2000:]
