"""Multi-device behaviour, exercised in a subprocess with 8 forced host
devices (XLA device count is locked at first jax init, so these cannot run
in the main pytest process):
  * sharded training on a (4, 2) mesh: loss decreases, state is sharded
  * elastic restart: checkpoint from (4, 2) restored onto (2, 4)
  * int8-compressed psum matches fp32 psum within quantization error
"""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
assert len(jax.devices()) == 8

from repro.configs import get_config, reduced
from repro.configs.base import ParallelConfig, TrainConfig
from repro.data import SyntheticLMData
from repro.runtime.trainer import Trainer

cfg = reduced(get_config("qwen1.5-110b"))
pcfg = ParallelConfig(attn_block_kv=32, xent_chunk=32, scan_chunk=16)
tcfg = TrainConfig(lr=1e-3, warmup_steps=2, total_steps=20,
                   checkpoint_every=5, keep_checkpoints=2)
data = SyntheticLMData(cfg, seq_len=32, global_batch=8)

from repro.launch.mesh import _make_mesh

mesh1 = _make_mesh((4, 2), ("data", "model"))
tr = Trainer(cfg=cfg, pcfg=pcfg, tcfg=tcfg, mesh=mesh1, data=data,
             ckpt_dir="/tmp/repro_md_ckpt")
import shutil; shutil.rmtree("/tmp/repro_md_ckpt", ignore_errors=True)
tr = Trainer(cfg=cfg, pcfg=pcfg, tcfg=tcfg, mesh=mesh1, data=data,
             ckpt_dir="/tmp/repro_md_ckpt")
s1 = tr.run(10)
assert s1["final_step"] == 10, s1
l1 = [m["loss"] for m in tr.metrics_log]

# ELASTIC: restart on a different mesh from the same checkpoints
mesh2 = _make_mesh((2, 4), ("data", "model"))
tr2 = tr.remesh(mesh2)
s2 = tr2.run(15)
assert s2["final_step"] == 15, s2
assert tr2.metrics_log[0]["step"] == 10
# loss continues from where it was (same data stream, same params)
assert abs(tr2.metrics_log[0]["loss"] - l1[-1]) < 0.8, \
    (tr2.metrics_log[0]["loss"], l1[-1])

# int8 compressed psum vs exact
from repro.parallel.collectives import compressed_psum, shard_map_compat
mesh3 = _make_mesh((8,), ("pod",))
x = jax.random.normal(jax.random.PRNGKey(0), (8, 256))
def f(xl):
    return compressed_psum(xl, "pod")
y = shard_map_compat(f, mesh3, P("pod"), P("pod"))(x)
exact = jnp.broadcast_to(x.sum(0, keepdims=True), x.shape)
err = float(jnp.max(jnp.abs(y - exact)))
scale = float(jnp.max(jnp.abs(x))) / 127.0
assert err <= 8 * scale + 1e-6, (err, scale)
print("MULTIDEVICE_OK")
"""


@pytest.mark.slow
def test_multidevice_training_elastic_and_compression():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src:" + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "MULTIDEVICE_OK" in r.stdout, (r.stdout[-2000:], r.stderr[-3000:])
