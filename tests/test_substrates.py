"""Substrate tests: checkpoint manager, fault-tolerant trainer (failure
injection -> restart), straggler monitor, data determinism, HLO cost model."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, reduced
from repro.configs.base import ParallelConfig, TrainConfig
from repro.data import SyntheticLMData
from repro.runtime import steps as S
from repro.runtime.trainer import SimulatedFailure, StragglerMonitor, Trainer

PCFG = ParallelConfig(attn_block_kv=32, xent_chunk=32, scan_chunk=16)


def small_trainer(tmp_path, fault_hook=None, steps_total=30):
    cfg = reduced(get_config("deepseek-coder-33b"))
    tcfg = TrainConfig(lr=1e-3, warmup_steps=2, total_steps=steps_total,
                       checkpoint_every=5, keep_checkpoints=2)
    data = SyntheticLMData(cfg, seq_len=32, global_batch=4)
    return Trainer(cfg=cfg, pcfg=PCFG, tcfg=tcfg, mesh=None, data=data,
                   ckpt_dir=str(tmp_path / "ckpt"), fault_hook=fault_hook)


# --------------------------------------------------------------------------- #
# Checkpoint manager
# --------------------------------------------------------------------------- #
def test_checkpoint_roundtrip(tmp_path):
    cfg = reduced(get_config("gemma3-1b"))
    state = S.init_train_state(jax.random.PRNGKey(0), cfg)
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    mgr.save(state, 7)
    abstract = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    restored, step = mgr.restore(abstract)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keep_last_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    state = {"w": jnp.ones((4,))}
    for s in (1, 2, 3, 4):
        mgr.save(state, s)
    assert mgr.all_steps() == [3, 4]


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    state = {"w": jnp.arange(8.0)}
    mgr.save(state, 1)
    mgr.wait()
    assert mgr.latest_step() == 1


# --------------------------------------------------------------------------- #
# Trainer: loss goes down; failure injection recovers from checkpoint
# --------------------------------------------------------------------------- #
def test_trainer_loss_decreases(tmp_path):
    tr = small_trainer(tmp_path)
    summary = tr.run(30)
    assert summary["final_step"] == 30
    losses = [m["loss"] for m in tr.metrics_log]
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_trainer_failure_recovery(tmp_path):
    fails = {"armed": True}

    def hook(step):
        if step == 12 and fails["armed"]:
            fails["armed"] = False
            raise SimulatedFailure("node died")

    tr = small_trainer(tmp_path, fault_hook=hook)
    summary = tr.run(20)
    assert summary["final_step"] == 20
    assert summary["restarts"] == 1
    # recovery resumed from the last checkpoint (step 10), so step 10 and 11
    # were re-executed -> metrics log contains duplicates of step >= 10
    steps = [m["step"] for m in tr.metrics_log]
    assert steps.count(11) == 2


def test_trainer_resume_across_instances(tmp_path):
    tr = small_trainer(tmp_path)
    tr.run(10)
    tr2 = small_trainer(tmp_path)           # fresh process, same ckpt dir
    summary = tr2.run(15)
    assert summary["final_step"] == 15
    assert tr2.metrics_log[0]["step"] == 10  # resumed, not restarted


# --------------------------------------------------------------------------- #
# Straggler monitor
# --------------------------------------------------------------------------- #
def test_straggler_monitor_flags_outlier():
    mon = StragglerMonitor()
    for i in range(20):
        assert not mon.observe(i, 0.1 + 0.001 * (i % 3))
    assert mon.observe(20, 0.5)
    assert len(mon.events) == 1


def test_straggler_monitor_adapts():
    mon = StragglerMonitor()
    for i in range(10):
        mon.observe(i, 0.1)
    # a persistent slowdown stops being an outlier once the EMA adapts
    flags = [mon.observe(10 + i, 0.3) for i in range(20)]
    assert flags[0] is True
    assert not any(flags[-5:])


# --------------------------------------------------------------------------- #
# Data pipeline determinism
# --------------------------------------------------------------------------- #
def test_data_is_pure_function_of_step():
    cfg = reduced(get_config("gemma3-1b"))
    d1 = SyntheticLMData(cfg, 16, 4, seed=3)
    d2 = SyntheticLMData(cfg, 16, 4, seed=3)
    b1, b2 = d1.batch(17), d2.batch(17)
    for k in b1:
        np.testing.assert_array_equal(b1[k], b2[k])
    assert not np.array_equal(d1.batch(18)["tokens"], b1["tokens"])


# --------------------------------------------------------------------------- #
# HLO cost model
# --------------------------------------------------------------------------- #
def test_hlo_analysis_scan_trip_multiplication():
    from benchmarks.hlo_analysis import analyze_hlo
    L, D = 8, 64

    def body(x, w):
        return jnp.tanh(x @ w), None

    def f(x, ws):
        y, _ = jax.lax.scan(body, x, ws)
        return y.sum()

    x = jnp.ones((32, D))
    ws = jnp.ones((L, D, D))
    hlo = jax.jit(f).lower(x, ws).compile().as_text()
    c = analyze_hlo(hlo)
    expected = 2 * 32 * D * D * L
    assert abs(c["flops"] - expected) / expected < 0.2, c["flops"]


def test_hlo_analysis_collectives():
    from benchmarks.hlo_analysis import analyze_hlo
    if len(jax.devices()) < 2:
        pytest.skip("needs >1 device (run via test_multidevice subprocess)")
