"""Tests for the device non-ideality subsystem (repro.nonideal): scenario
registry round-trip, perturbation semantics, fault-mask determinism,
fast-path compile-cache non-invalidation across scenario changes, ideal
bit-identity, and the compile-once multi-draw sweep."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AnalogConfig
from repro.configs.rram_ps32 import CASE_A
from repro.core import conv4xbar
from repro.core.analog import AnalogExecutor
from repro.models.common import init_params
from repro.nonideal import (Scenario, ScenarioSweep, apply_read_noise,
                            get_scenario, list_scenarios, perturb_conductance,
                            register_scenario, sample_fault_masks,
                            scenario_circuit_params, scenario_from_json,
                            scenario_to_json)

ACFG = AnalogConfig()


def _executor(backend="analytic", **kw):
    if backend == "emulator":
        kw.setdefault("emulator_params", init_params(
            jax.random.PRNGKey(7), conv4xbar.conv4xbar_schema(CASE_A,
                                                              n_periph=2)))
        kw.setdefault("use_pallas", False)
    return AnalogExecutor(acfg=AnalogConfig(backend=backend), geom=CASE_A,
                          **kw)


def _data(K=70, N=3, B=4, seed=0):
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (K, N)) * 0.3
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, K)) * 0.5
    return x, w


def _plan_g(ex, w, tag="t"):
    return ex._plan_for(w, tag).g_feat


# --------------------------------------------------------------------------- #
# Registry + JSON
# --------------------------------------------------------------------------- #
def test_registry_roundtrip_identical_pytree():
    s = Scenario(name="rt_test", prog_sigma=0.07, read_sigma=0.01,
                 p_stuck_on=0.002, p_stuck_off=0.004, drift_nu=0.04,
                 drift_t=1234.5, r_line_scale=2.0, n_levels=16)
    register_scenario(s)
    assert get_scenario("rt_test") is s
    assert "rt_test" in list_scenarios()
    s2 = scenario_from_json(scenario_to_json(s))
    assert s2 == s                                     # dataclass equality
    l1, t1 = jax.tree_util.tree_flatten(s)
    l2, t2 = jax.tree_util.tree_flatten(s2)
    assert t1 == t2 and l1 == l2                       # identical pytree


def test_registry_rejects_silent_overwrite_and_unknown():
    s = Scenario(name="dup_test")
    register_scenario(s)
    with pytest.raises(ValueError):
        register_scenario(Scenario(name="dup_test", prog_sigma=0.1))
    register_scenario(Scenario(name="dup_test", prog_sigma=0.1),
                      overwrite=True)
    assert get_scenario("dup_test").prog_sigma == 0.1
    with pytest.raises(KeyError):
        get_scenario("no_such_scenario")
    with pytest.raises(ValueError):
        scenario_from_json(json.dumps({"name": "x", "bogus_field": 1.0}))


def test_scenario_leaf_dtype_pinning():
    """Scenario(prog_sigma=0) and Scenario(prog_sigma=0.0) must flatten to
    identical leaves, or sweeps would retrace per level."""
    a = jax.tree_util.tree_flatten(Scenario(name="x", prog_sigma=0))
    b = jax.tree_util.tree_flatten(Scenario(name="x", prog_sigma=0.0))
    assert a == b
    assert isinstance(Scenario(name="x", drift_t=100).drift_t, float)


# --------------------------------------------------------------------------- #
# Perturbation semantics
# --------------------------------------------------------------------------- #
def test_fault_masks_deterministic_disjoint_and_nested():
    key = jax.random.PRNGKey(3)
    on1, off1 = sample_fault_masks(key, (64, 64), 0.05, 0.1)
    on2, off2 = sample_fault_masks(key, (64, 64), 0.05, 0.1)
    assert np.array_equal(np.asarray(on1), np.asarray(on2))
    assert np.array_equal(np.asarray(off1), np.asarray(off2))
    assert not bool(jnp.any(on1 & off1))               # disjoint
    # nested fault populations across rate sweeps (same key)
    on_hi, _ = sample_fault_masks(key, (64, 64), 0.2, 0.1)
    assert bool(jnp.all(on_hi | ~on1))                 # on1 subset of on_hi
    assert abs(float(jnp.mean(on_hi)) - 0.2) < 0.02


def test_perturb_ideal_is_bitwise_identity():
    x, w = _data()
    ex = _executor()
    g = _plan_g(ex, w)
    gp = perturb_conductance(g, ACFG, get_scenario("ideal"),
                             jax.random.PRNGKey(0))
    assert np.array_equal(np.asarray(gp), np.asarray(g))
    gr = apply_read_noise(g, ACFG, 0.0, jax.random.PRNGKey(1))
    assert np.array_equal(np.asarray(gr), np.asarray(g))


def test_perturb_preserves_padding_and_range():
    x, w = _data(K=70, N=3)                            # padT: zero-padded cells
    ex = _executor()
    g = np.asarray(_plan_g(ex, w))
    assert (g == 0.0).any(), "test needs a padded plan"
    sc = Scenario(name="hard", prog_sigma=0.3, p_stuck_on=0.05,
                  p_stuck_off=0.05, read_sigma=0.2, n_levels=4,
                  drift_nu=0.1, drift_t=1e5)
    gp = perturb_conductance(jnp.asarray(g), ACFG, sc, jax.random.PRNGKey(2))
    gp = np.asarray(apply_read_noise(gp, ACFG, sc.read_sigma,
                                     jax.random.PRNGKey(3)))
    assert np.array_equal(gp == 0.0, g == 0.0)         # no phantom cells
    live = g > 0
    assert gp[live].min() >= ACFG.g_min - 1e-12
    assert gp[live].max() <= ACFG.g_max + 1e-12


def test_quantize_levels_snap_count():
    x, w = _data()
    ex = _executor()
    g = _plan_g(ex, w)
    gq = perturb_conductance(g, ACFG, Scenario(name="q", n_levels=4),
                             jax.random.PRNGKey(0))
    lv = np.unique(np.round(np.asarray(gq[gq > 0]), 12))
    assert len(lv) <= 4


def test_drift_shrinks_differential_weight():
    x, w = _data(K=64, N=4)
    ex = _executor()
    norms = []
    for t in (0.0, 1e2, 1e4, 1e6):
        ex.deploy(scenario=Scenario(name="d", drift_nu=0.1, drift_t=t),
                  key=jax.random.PRNGKey(0))
        y, _ = ex.raw_matmul(x, w, "t")
        norms.append(float(jnp.linalg.norm(y)))
    assert all(norms[i + 1] <= norms[i] + 1e-9 for i in range(len(norms) - 1))
    assert norms[-1] < 0.9 * norms[0]


def test_r_line_scale_degrades_circuit_output():
    x, w = _data(K=64, N=2, B=2)
    ex = _executor("circuit")
    y0, _ = ex.raw_matmul(x, w, "t")
    ex.deploy(scenario=get_scenario("ir_degraded"),
              key=jax.random.PRNGKey(0))
    y1, _ = ex.raw_matmul(x, w, "t")
    assert scenario_circuit_params(ex.cp, ex.scenario).r_bl == ex.cp.r_bl * 4.0
    assert not np.allclose(np.asarray(y0), np.asarray(y1))


# --------------------------------------------------------------------------- #
# Executor integration: bit-identity, cache stability, read cycles
# --------------------------------------------------------------------------- #
def test_ideal_scenario_bit_identical_to_fast_path():
    x, w = _data()
    ex0 = _executor("emulator")
    y0 = ex0.matmul(x, w, "t")
    ex1 = _executor("emulator", emulator_params=ex0.emulator_params)
    ex1.deploy(scenario=get_scenario("ideal"), key=jax.random.PRNGKey(9))
    y1 = ex1.matmul(x, w, "t")
    assert np.array_equal(np.asarray(y0), np.asarray(y1))


def test_scenario_changes_do_not_invalidate_compile_caches():
    x, w = _data()
    ex = _executor("emulator")
    y_plain = ex.matmul(x, w, "t")
    fn = ex._fns["t"][2]
    assert fn._cache_size() == 1
    ex.deploy(scenario=Scenario(name="a", prog_sigma=0.05),
              key=jax.random.PRNGKey(3))
    ya = ex.matmul(x, w, "t")
    ex.deploy(scenario=Scenario(name="b", prog_sigma=0.15, p_stuck_off=0.02,
                                read_sigma=0.05, n_levels=8,
                                drift_nu=0.02, drift_t=1e3),
              key=jax.random.PRNGKey(4))
    yb = ex.matmul(x, w, "t")
    # ONE unified forward, exactly one executable across ideal AND every
    # corner: the whole deployment is a single traced DeploymentState
    assert ex._fns["t"][2] is fn
    assert fn._cache_size() == 1
    ex.deploy(scenario=None)
    y_back = ex.matmul(x, w, "t")
    assert ex._fns["t"][2] is fn and fn._cache_size() == 1
    np.testing.assert_array_equal(np.asarray(y_back), np.asarray(y_plain))
    assert not np.allclose(np.asarray(ya), np.asarray(yb))


def test_device_draw_deterministic_and_keyed():
    x, w = _data()
    sc = Scenario(name="det", prog_sigma=0.1)

    def draw(key):
        ex = _executor()
        ex.deploy(scenario=sc, key=key)
        return ex.matmul(x, w, "t")

    ya = draw(jax.random.PRNGKey(5))
    yb = draw(jax.random.PRNGKey(5))
    yc = draw(jax.random.PRNGKey(6))
    np.testing.assert_array_equal(np.asarray(ya), np.asarray(yb))
    assert not np.allclose(np.asarray(ya), np.asarray(yc))


def test_read_noise_cycle_to_cycle_and_reproducible():
    x, w = _data()
    ex = _executor()
    sc = Scenario(name="rn", read_sigma=0.1)
    ex.deploy(scenario=sc, key=jax.random.PRNGKey(5))
    y1 = np.asarray(ex.matmul(x, w, "t"))
    y2 = np.asarray(ex.matmul(x, w, "t"))
    assert not np.array_equal(y1, y2)                  # fresh draw per read
    ex.deploy(scenario=sc, key=jax.random.PRNGKey(5))  # restart the sequence
    np.testing.assert_array_equal(np.asarray(ex.matmul(x, w, "t")), y1)
    np.testing.assert_array_equal(np.asarray(ex.matmul(x, w, "t")), y2)


def test_noise_aware_calibration_runs_against_scenario():
    x, w = _data(K=64, N=4, B=8)
    ex = _executor()
    ex.deploy(scenario=Scenario(name="cal", prog_sigma=0.1,
                               read_sigma=0.05),
              key=jax.random.PRNGKey(8))
    a, b = ex.calibrate(jax.random.PRNGKey(1), w, "t")
    assert np.isfinite(a) and np.isfinite(b)
    y = ex.matmul(x, w, "t")
    assert np.all(np.isfinite(np.asarray(y)))
    corr = np.corrcoef(np.asarray(y).ravel(),
                       np.asarray(x @ w).ravel())[0, 1]
    assert corr > 0.5                                  # still tracks digital


# --------------------------------------------------------------------------- #
# Sweeps
# --------------------------------------------------------------------------- #
def test_sweep_compiles_once_and_is_monotone():
    x, w = _data(K=64, N=4, B=8)
    ex = _executor()
    ex.calibrate(jax.random.PRNGKey(2), w, "t")
    sweep = ScenarioSweep(ex, w, "t", n_draws=4)
    key = jax.random.PRNGKey(11)
    outs = [np.asarray(sweep(x, Scenario(name="sw", prog_sigma=s),
                             key)).mean(axis=0)
            for s in (0.0, 0.05, 0.1, 0.2)]
    assert sweep.trace_count == 1                      # one executable
    assert sweep.cache_size() == 1
    ref = outs[0]
    errs = [float(np.linalg.norm(o - ref)) for o in outs]
    assert errs[0] == 0.0
    assert all(errs[i] <= errs[i + 1] + 1e-9 for i in range(len(errs) - 1))
    assert errs[-1] > 0.0


def test_sweep_rejects_static_r_line_scale():
    x, w = _data(K=64, N=4, B=8)
    sweep = ScenarioSweep(_executor(), w, "t", n_draws=2)
    with pytest.raises(ValueError, match="r_line_scale"):
        sweep(x, get_scenario("ir_degraded"), jax.random.PRNGKey(0))


def test_sweep_draws_are_independent_devices():
    x, w = _data(K=64, N=4, B=8)
    ex = _executor()
    sweep = ScenarioSweep(ex, w, "t", n_draws=3)
    ys = np.asarray(sweep(x, Scenario(name="sw", prog_sigma=0.2),
                          jax.random.PRNGKey(0)))
    assert ys.shape[0] == 3
    assert not np.allclose(ys[0], ys[1])
    assert not np.allclose(ys[1], ys[2])
