"""End-to-end behaviour tests for the paper's system: train a tiny LM with
the analog-emulated backend (SEMULATOR's target use-case) and check the
emulator acceptance machinery wiring."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.configs.base import AnalogConfig, ParallelConfig, TrainConfig
from repro.configs.rram_ps32 import CASE_A, EmulatorTrainConfig
from repro.core import theory
from repro.core.analog import AnalogExecutor
from repro.core.circuit import CircuitParams
from repro.core.emulator import train_emulator
from repro.data import SyntheticLMData
from repro.models.common import use_dense_hook
from repro.runtime import steps as S

PCFG = ParallelConfig(attn_block_kv=16, xent_chunk=16, scan_chunk=8)


@pytest.fixture(scope="module")
def tiny_emulator():
    # prefer the benchmark-cached QUICK emulator (10k samples / 200 epochs,
    # created by `python -m benchmarks.run`); fall back to a 25-epoch one
    import os
    import numpy as _np
    cache = os.path.join(os.path.dirname(__file__), "..", "results",
                         "emulator_cache", "rram_ps32_a_n10000_e200_s0.npz")
    if os.path.exists(cache):
        from repro.core.emulator import EmulatorResult
        data = _np.load(cache, allow_pickle=True)
        params = {k: jnp.asarray(v) for k, v in data.items()
                  if not k.startswith("__")}
        meta = data["__meta"].item() if "__meta" in data else {}
        return EmulatorResult(params=params, history={},
                              train_mse=meta.get("train_mse", 1.0),
                              test_mse=meta.get("test_mse", 1.0),
                              test_mae=meta.get("test_mae", 1.0),
                              bound=theory.mse_bound(3, 0.3),
                              accepted=bool(meta.get("accepted", False)),
                              sig_prob=meta.get("sig_prob", 0.0))
    tcfg = EmulatorTrainConfig(n_train=1500, n_test=300, epochs=25,
                               lr=2e-3, lr_halve_at=(15, 20), batch_size=256)
    return train_emulator(jax.random.PRNGKey(0), CASE_A, AnalogConfig(),
                          CircuitParams(), tcfg)


def test_emulator_training_reports_theorem_acceptance(tiny_emulator):
    res = tiny_emulator
    assert res.test_mse > 0
    assert res.bound == pytest.approx(theory.mse_bound(3, 0.3))
    # an under-trained emulator must NOT be silently accepted
    assert res.accepted == (res.test_mse < res.bound and res.sig_prob > 0.3)


def test_analog_emulated_train_step_runs(tiny_emulator):
    """One full train step with MLP matmuls routed through the emulator."""
    cfg = reduced(get_config("gemma3-1b"), layers=2)
    acfg = AnalogConfig(enabled=True, backend="emulator", layers=("mlp",))
    ex = AnalogExecutor(acfg=acfg, geom=CASE_A, cp=CircuitParams(),
                        emulator_params=tiny_emulator.params)
    data = SyntheticLMData(cfg, 16, 2)
    state = S.init_train_state(jax.random.PRNGKey(1), cfg)
    step = S.make_train_step(cfg, PCFG, TrainConfig(warmup_steps=1))
    batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    with use_dense_hook(ex.hook):
        new_state, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # gradients flowed (straight-through) -> params changed
    w0 = jax.tree.leaves(state["params"])[1]
    w1 = jax.tree.leaves(new_state["params"])[1]
    assert not np.allclose(np.asarray(w0), np.asarray(w1))


def test_backend_spectrum_consistency(tiny_emulator):
    """digital / analytic / circuit / emulator backends produce correlated
    outputs for the same projection (the whole point of emulation)."""
    key = jax.random.PRNGKey(2)
    w = jax.random.normal(key, (64, 8)) * 0.25
    x = jax.random.normal(jax.random.fold_in(key, 1), (16, 64)) * 0.5
    outs = {"digital": np.asarray(x @ w)}
    for backend in ("analytic", "circuit", "emulator"):
        ex = AnalogExecutor(
            acfg=AnalogConfig(backend=backend), geom=CASE_A,
            cp=CircuitParams(), emulator_params=tiny_emulator.params)
        ex.calibrate(jax.random.fold_in(key, 3), w, "t")
        outs[backend] = np.asarray(ex.matmul(x, w, "t"))
    # nonlinear hardware (threshold + saturation): correlated with the
    # digital ideal, not equal to it -- that deviation is the paper's point
    for backend in ("analytic", "circuit"):
        corr = np.corrcoef(outs["digital"].ravel(),
                           outs[backend].ravel())[0, 1]
        assert corr > 0.3, (backend, corr)
    # The emulator's contract is over the *training distribution* (random
    # block inputs), not arbitrary matmul drive patterns: compare circuit vs
    # emulator there. (Quality gating at matmul level is Theorem 4.1's job
    # after full training -- see benchmarks table1.)
    from repro.core.emulator import sample_block_inputs, normalize_features
    from repro.core import conv4xbar
    from repro.core.circuit import block_response
    acfg = AnalogConfig()
    if tiny_emulator.test_mse > 1.5e-3:
        pytest.skip("no cached emulator; the 25-epoch fallback is too weak "
                    "for structural checks (run `python -m benchmarks.run` "
                    "first)")
    xb, periph = sample_block_inputs(jax.random.PRNGKey(5), 256, CASE_A, acfg)
    y_circ = np.asarray(block_response(xb, CircuitParams(), periph))
    y_emu = np.asarray(conv4xbar.apply_fused(
        tiny_emulator.params, normalize_features(xb, acfg), periph))
    corr_ce = np.corrcoef(y_circ.ravel(), y_emu.ravel())[0, 1]
    assert corr_ce > 0.8, corr_ce
