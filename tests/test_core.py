"""Unit + property tests for the SEMULATOR core (theorem, crossbar mapping,
circuit solver physics, conv4xbar equivalence, analog executor)."""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import AnalogConfig
from repro.configs.rram_ps32 import CASE_A, CASE_B
from repro.core import conv4xbar, theory
from repro.core.analog import AnalogExecutor
from repro.core.circuit import (CircuitParams, block_response, cell_current,
                                solve_tile_currents)
from repro.core.crossbar import (conductance_to_weights, tile_matrix,
                                 weights_to_conductance)
from repro.models.common import init_params


# --------------------------------------------------------------------------- #
# Theorem 4.1
# --------------------------------------------------------------------------- #
def test_theorem_paper_example():
    # paper: s=3, p=0.3 -> upper bound ~= 6.7e-6
    assert abs(theory.mse_bound(3, 0.3) - 6.7e-6) < 2e-7


@settings(max_examples=50, deadline=None)
@given(s=st.integers(1, 6), p=st.floats(0.05, 0.95))
def test_theorem_monotonicity(s, p):
    b = theory.mse_bound(s, p)
    assert b > 0
    assert theory.mse_bound(s + 1, p) < b          # more digits -> tighter
    assert theory.mse_bound(s, min(p + 0.04, 0.99)) < b  # higher prob -> tighter


@settings(max_examples=20, deadline=None)
@given(s=st.integers(1, 3), p=st.floats(0.1, 0.9), seed=st.integers(0, 100))
def test_theorem_gaussian_consistency(s, p, seed):
    """If errors are N(0, sigma^2) with sigma^2 at the bound, the empirical
    P(|err| < 0.5*10^-s) should be near p (Lemma 4.2 + Thm 4.1 with the
    paper's numeric convention using 10^-s inside erf -> 0.5*10^-s covers
    p' = erf(0.5 * sqrt2 * erfinv(p)) <= p; we check the 10^-s variant)."""
    sigma = math.sqrt(theory.mse_bound(s, p))
    rng = np.random.default_rng(seed)
    err = rng.normal(0, sigma, 200_000)
    emp = np.mean(np.abs(err) < 10.0 ** (-s))
    assert abs(emp - p) < 0.02


# --------------------------------------------------------------------------- #
# Crossbar mapping
# --------------------------------------------------------------------------- #
@settings(max_examples=30, deadline=None)
@given(k=st.integers(1, 200), n=st.integers(1, 9), seed=st.integers(0, 99))
def test_conductance_roundtrip(k, n, seed):
    acfg = AnalogConfig()
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(0, 0.5, (k, n)), jnp.float32)
    scale = jnp.max(jnp.abs(w)) + 1e-12
    gp, gn = weights_to_conductance(w, acfg, scale)
    assert float(gp.min()) >= acfg.g_min - 1e-12
    assert float(gp.max()) <= acfg.g_max + 1e-12
    w2 = conductance_to_weights(gp, gn, acfg, scale)
    np.testing.assert_allclose(np.asarray(w2), np.asarray(w),
                               rtol=1e-4, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(k=st.integers(1, 300), n=st.integers(1, 5))
def test_tile_shapes(k, n):
    acfg = AnalogConfig()
    w = jnp.ones((k, n))
    gp, gn = tile_matrix(w, acfg)
    t = -(-k // acfg.rows)
    assert gp.shape == (t, acfg.rows, n) == gn.shape
    # padding rows are differentially neutral (both rails g_min)
    if k % acfg.rows:
        pad = np.asarray(gp)[-1, k % acfg.rows:, :]
        pad_n = np.asarray(gn)[-1, k % acfg.rows:, :]
        np.testing.assert_allclose(pad, pad_n)


# --------------------------------------------------------------------------- #
# Circuit solver physics (the Fig.5 structure)
# --------------------------------------------------------------------------- #
def test_cell_threshold_and_monotonicity():
    cp = CircuitParams()
    g = jnp.full((1,), 5e-5)
    v_below = cell_current(jnp.asarray([cp.v_th * 0.5]), g, 0.0, cp)
    v_above = cell_current(jnp.asarray([0.15]), g, 0.0, cp)
    v_high = cell_current(jnp.asarray([0.2]), g, 0.0, cp)
    assert float(v_below[0]) < 1e-9                  # cut off below threshold
    assert float(v_above[0]) > 1e-7
    assert float(v_high[0]) > float(v_above[0])      # monotone in V
    # monotone in g
    i1 = cell_current(jnp.asarray([0.2]), jnp.asarray([1e-5]), 0.0, cp)
    i2 = cell_current(jnp.asarray([0.2]), jnp.asarray([9e-5]), 0.0, cp)
    assert float(i2[0]) > float(i1[0])


def test_ir_drop_reduces_current():
    cp = CircuitParams()
    v = jnp.full((8,), 0.2)
    g = jnp.full((8, 2), 9e-5)
    i_with = solve_tile_currents(v, g, cp)
    i_wo = solve_tile_currents(v, g, dataclasses.replace(cp, r_bl=0.0))
    assert float(i_with.sum()) < float(i_wo.sum())


def test_differential_symmetry():
    """Swapping G+ and G- flips the block output sign (offset-free)."""
    cp = CircuitParams()
    key = jax.random.PRNGKey(0)
    acfg = AnalogConfig()
    from repro.core.emulator import sample_block_inputs
    x, _ = sample_block_inputs(key, 4, CASE_A, acfg, with_periph=False)
    y = block_response(x, cp)
    xs = x.at[:, 1].set(x[:, 1, :, :, ::-1])         # swap diff pairs
    ys = block_response(xs, cp)
    np.testing.assert_allclose(np.asarray(y), -np.asarray(ys),
                               rtol=1e-4, atol=1e-7)


# --------------------------------------------------------------------------- #
# Conv4Xbar
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("geom", [CASE_A, CASE_B], ids=lambda g: g.name)
def test_conv4xbar_matches_table2(geom):
    # Table 2: Linear(128, 32) for case A, Linear(256, 32) for case B
    expected = {"rram_ps32_a": 128, "rram_ps32_b": 256}[geom.name]
    assert conv4xbar.flat_features(geom) == expected


@pytest.mark.parametrize("geom", [CASE_A, CASE_B], ids=lambda g: g.name)
def test_conv4xbar_fused_equals_conv(geom):
    key = jax.random.PRNGKey(3)
    schema = conv4xbar.conv4xbar_schema(geom, n_periph=2)
    params = init_params(key, schema)
    x = jax.random.uniform(key, (16, geom.features, geom.tiles, geom.rows,
                                 geom.cols))
    p = jax.random.uniform(jax.random.fold_in(key, 1), (16, 2))
    np.testing.assert_allclose(
        np.asarray(conv4xbar.apply(params, x, p)),
        np.asarray(conv4xbar.apply_fused(params, x, p)),
        rtol=2e-5, atol=2e-6)


# --------------------------------------------------------------------------- #
# Analog executor
# --------------------------------------------------------------------------- #
def test_analog_straight_through_gradient():
    """custom_vjp: forward is analog, backward is the digital matmul grad."""
    acfg = AnalogConfig(backend="analytic")
    ex = AnalogExecutor(acfg=acfg, geom=CASE_A)
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (70, 3)) * 0.3
    x = jax.random.normal(jax.random.fold_in(key, 1), (4, 70)) * 0.5

    g_analog = jax.grad(lambda xx: ex.matmul(xx, w, "t").sum())(x)
    g_digital = jax.grad(lambda xx: (xx @ w).sum())(x)
    np.testing.assert_allclose(np.asarray(g_analog), np.asarray(g_digital),
                               rtol=1e-5, atol=1e-6)


def test_analog_calibrated_circuit_tracks_digital():
    acfg = AnalogConfig(backend="circuit")
    ex = AnalogExecutor(acfg=acfg, geom=CASE_A)
    key = jax.random.PRNGKey(1)
    w = jax.random.normal(key, (64, 4)) * 0.2
    ex.calibrate(jax.random.fold_in(key, 2), w, "t")
    x = jax.random.normal(jax.random.fold_in(key, 3), (8, 64)) * 0.4
    y_a = ex.matmul(x, w, "t")
    y_d = x @ w
    corr = np.corrcoef(np.asarray(y_a).ravel(), np.asarray(y_d).ravel())[0, 1]
    assert corr > 0.55, corr          # nonlinear hardware, but correlated


def test_dense_hook_routing():
    from repro.models.common import dense, use_dense_hook
    acfg = AnalogConfig(backend="analytic", layers=("mlp",))
    ex = AnalogExecutor(acfg=acfg, geom=CASE_A)
    x = jnp.ones((2, 64))
    w = jnp.full((64, 3), 0.1)
    with use_dense_hook(ex.hook):
        y_mlp = dense(x, w, "mlp.up")        # routed to analog
        y_attn = dense(x, w, "attn.q")       # stays digital
    np.testing.assert_allclose(np.asarray(y_attn), np.asarray(x @ w),
                               rtol=1e-6)
    assert not np.allclose(np.asarray(y_mlp), np.asarray(x @ w))
