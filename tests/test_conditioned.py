"""Tests for the scenario-conditioned emulator: the scenario_features
encoding (fixed length, pinned ordering, per-tile reduction determinism,
JSON stability, all-zero ideal), conditioned training data / schema
plumbing, fast-path/slow-path agreement of the conditioned forward, ideal
bit-identity, compile-cache invariance across corner/age swaps, and the
lifetime scheduler's conditioned-first policy."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AnalogConfig
from repro.configs.rram_ps32 import CASE_A, EmulatorTrainConfig
from repro.core import conv4xbar
from repro.core.analog import AnalogExecutor
from repro.core.circuit import CircuitParams
from repro.core.deployment import DeploymentState
from repro.models.common import init_params
from repro.nonideal import (BUILTIN_SCENARIOS, N_SCENARIO_FEATURES,
                            SCENARIO_FEATURE_NAMES, LifetimeScheduler,
                            Scenario, ScenarioSweep, get_scenario,
                            sample_scenarios, scenario_at_age,
                            scenario_features, tile_scenarios)
from repro.nonideal.data import generate_dataset_conditioned

ACFG = AnalogConfig()
NF = N_SCENARIO_FEATURES


def _cond_params(seed=7):
    return init_params(jax.random.PRNGKey(seed),
                       conv4xbar.conv4xbar_schema(CASE_A, n_periph=2 + NF))


def _executor(params=None, **kw):
    kw.setdefault("use_pallas", False)
    return AnalogExecutor(
        acfg=AnalogConfig(backend="emulator"), geom=CASE_A,
        emulator_params=params if params is not None else _cond_params(),
        **kw)


def _data(K=70, N=8, B=4, seed=0):
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (K, N)) * 0.3
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, K)) * 0.5
    return x, w


# --------------------------------------------------------------------------- #
# Feature encoding
# --------------------------------------------------------------------------- #
def test_feature_layout_is_pinned():
    """The ordering is part of the trained-params contract (fc0 rows bind
    to positions): any reorder/rename must be caught, append-only."""
    assert SCENARIO_FEATURE_NAMES == (
        "prog_sigma_mean", "prog_sigma_max",
        "read_sigma_mean", "read_sigma_max",
        "p_stuck_on_mean", "p_stuck_on_max",
        "p_stuck_off_mean", "p_stuck_off_max",
        "drift_nu_mean", "drift_nu_max",
        "drift_age", "r_line_scale_m1", "quant_inv")
    assert N_SCENARIO_FEATURES == len(SCENARIO_FEATURE_NAMES)


def test_features_fixed_length_and_finite_across_registry():
    for s in BUILTIN_SCENARIOS:
        v = np.asarray(scenario_features(s))
        assert v.shape == (NF,) and v.dtype == np.float32
        assert np.all(np.isfinite(v))


def test_ideal_scenario_encodes_to_zero():
    assert np.array_equal(np.asarray(scenario_features(Scenario())),
                          np.zeros(NF, np.float32))
    # and a uniformly-ideal tile batch too
    assert np.array_equal(np.asarray(scenario_features(tile_scenarios(2, 4))),
                          np.zeros(NF, np.float32))


def test_per_tile_reduction_deterministic_and_correct():
    grad = np.linspace(0.0, 0.3, 4)
    s = tile_scenarios(2, 4, prog_sigma=np.broadcast_to(grad, (2, 4)),
                       p_stuck_off=0.01, name="grad")
    v1 = np.asarray(scenario_features(s))
    v2 = np.asarray(scenario_features(s))
    np.testing.assert_array_equal(v1, v2)              # deterministic
    i = SCENARIO_FEATURE_NAMES.index
    assert v1[i("prog_sigma_mean")] == pytest.approx(grad.mean())
    assert v1[i("prog_sigma_max")] == pytest.approx(grad.max())
    assert v1[i("p_stuck_off_mean")] == pytest.approx(0.01)
    # a uniform tile batch encodes identically to its scalar corner
    u = tile_scenarios(2, 4, prog_sigma=0.05, name="uni")
    np.testing.assert_allclose(
        np.asarray(scenario_features(u)),
        np.asarray(scenario_features(Scenario(name="sc", prog_sigma=0.05))),
        rtol=1e-6)


def test_features_json_roundtrip_stable():
    """The encoding survives a JSON round trip bit-for-bit (feature vectors
    are logged next to BENCH artifacts and must be reproducible)."""
    for s in (get_scenario("stressed"),
              tile_scenarios(2, 3, prog_sigma=0.07, drift_nu=0.05,
                             drift_t=3.6e3, name="rt")):
        v = np.asarray(scenario_features(s), np.float32)
        back = np.asarray(json.loads(json.dumps(v.tolist())), np.float32)
        np.testing.assert_array_equal(v, back)


def test_drift_age_monotone_in_t():
    ages = [float(np.asarray(scenario_features(
        scenario_at_age(Scenario(name="d", drift_nu=0.05), t)))[
            SCENARIO_FEATURE_NAMES.index("drift_age")])
        for t in (0.0, 3.6e3, 8.64e4, 2.592e6)]
    assert ages[0] == 0.0
    assert all(a < b for a, b in zip(ages, ages[1:]))


# --------------------------------------------------------------------------- #
# Conditioned training data
# --------------------------------------------------------------------------- #
def test_sampled_scenarios_and_dataset_shapes():
    s = sample_scenarios(jax.random.PRNGKey(0), 16)
    assert s.prog_sigma.shape == (16,) and s.drift_t0.shape == (16,)
    assert s.n_levels.dtype == jnp.int32
    # some undrifted samples, some aged (the t=0 point mass)
    t = np.asarray(s.drift_t)
    assert (t == 0.0).any() and (t > 0.0).any()
    X, Pf, Y = generate_dataset_conditioned(
        jax.random.PRNGKey(1), 40, CASE_A, ACFG, CircuitParams(), batch=32)
    assert X.shape[0] == Pf.shape[0] == Y.shape[0] == 40
    assert Pf.shape[-1] == 2 + NF                      # gain, offset, sfeat
    assert np.all(np.isfinite(np.asarray(Y)))


def test_n_periph_detection():
    assert conv4xbar.n_periph_of(_cond_params(), CASE_A) == 2 + NF
    plain = init_params(jax.random.PRNGKey(1),
                        conv4xbar.conv4xbar_schema(CASE_A, n_periph=2))
    assert conv4xbar.n_periph_of(plain, CASE_A) == 2
    assert _executor().emulator_conditioned
    assert not _executor(plain).emulator_conditioned


# --------------------------------------------------------------------------- #
# Conditioned forward: correctness + bit-identity + cache invariance
# --------------------------------------------------------------------------- #
def test_conditioned_fastpath_matches_periph_concat():
    """The blocklast fc0-shift formulation must agree with the reference
    path that concatenates the features into the peripheral vector."""
    x, w = _data()
    sf = scenario_features(get_scenario("stressed"))
    fast = _executor()
    slow = _executor(fast.emulator_params, fast_path=False)
    yf, sfx = fast.raw_matmul(x, w, "t", sfeat=sf)
    ys, ssx = slow.raw_matmul(x, w, "t", sfeat=sf)
    np.testing.assert_allclose(np.asarray(yf), np.asarray(ys),
                               rtol=2e-4, atol=2e-6)
    np.testing.assert_array_equal(np.asarray(sfx), np.asarray(ssx))
    # and the features visibly steer the conditioned net
    y0, _ = fast.raw_matmul(x, w, "t")
    assert not np.allclose(np.asarray(yf), np.asarray(y0))


def test_conditioned_ideal_bit_identical_to_plain():
    x, w = _data()
    ex0 = _executor()
    y0 = np.asarray(ex0.matmul(x, w, "t"))
    ex1 = _executor(ex0.emulator_params)
    ex1.deploy(scenario=Scenario(name="ideal"), key=jax.random.PRNGKey(9))
    np.testing.assert_array_equal(np.asarray(ex1.matmul(x, w, "t")), y0)
    # unified forward fed the ideal state (all-zero feature block) explicitly
    plan = ex1._plan_for(w, "t")
    x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    y_sc = ex1._unified_for("t", w)(
        x2, DeploymentState.ideal(plan, eparams=ex1.emulator_params))
    np.testing.assert_array_equal(np.asarray(y_sc), y0)


def test_corner_and_age_swaps_zero_recompiles():
    """The tentpole cache invariant: sweeping corners AND ages through the
    conditioned forward (features, conductances, params all traced) must
    reuse exactly one executable per tag."""
    x, w = _data()
    ex = _executor(fault_remap=True)
    outs = []
    for sc in (get_scenario("stressed"),
               scenario_at_age(get_scenario("stressed"), 3.6e3),
               scenario_at_age(get_scenario("stressed"), 2.592e6),
               get_scenario("prog_heavy"),
               get_scenario("drift_1day")):
        ex.deploy(scenario=sc, key=jax.random.PRNGKey(1))
        outs.append(np.asarray(ex.matmul(x, w, "t")))
    fn = ex._fns["t"][2]
    assert fn._cache_size() == 1
    # ages actually change the served numbers (the net sees drift_age)
    assert not np.allclose(outs[1], outs[2])
    # a per-tile batch switches the sfeat operand to its (NB, NO, F)
    # per-tile encoding -- ONE extra executable for the tiled aval...
    plan = ex._plan_for(w, "t")
    ex.deploy(scenario=tile_scenarios(plan.NB, plan.NO, prog_sigma=0.06,
                                      drift_nu=0.05, drift_t=8.64e4,
                                      name="tiled"),
              key=jax.random.PRNGKey(2))
    ex.matmul(x, w, "t")
    assert ex._fns["t"][2] is fn and fn._cache_size() == 2
    # ...and every further tiled corner / age swap reuses it
    ex.deploy(scenario=tile_scenarios(plan.NB, plan.NO, prog_sigma=0.02,
                                      drift_nu=0.08, drift_t=2.592e6,
                                      name="tiled2"),
              key=jax.random.PRNGKey(3))
    ex.matmul(x, w, "t")
    assert ex._fns["t"][2] is fn and fn._cache_size() == 2


def test_conditioned_sweep_compiles_once():
    x, w = _data(K=64, N=8)
    ex = _executor()
    ex.calibrate(jax.random.PRNGKey(2), w, "t", n=16)
    sweep = ScenarioSweep(ex, w, "t", n_draws=2)
    key = jax.random.PRNGKey(11)
    outs = [np.asarray(sweep(x, Scenario(name="sw", prog_sigma=s,
                                         drift_nu=0.05, drift_t=t), key))
            for s, t in ((0.0, 0.0), (0.05, 3.6e3), (0.1, 2.592e6))]
    assert sweep.trace_count == 1 and sweep.cache_size() == 1
    assert not np.allclose(outs[0], outs[2])


# --------------------------------------------------------------------------- #
# Scheduler policy
# --------------------------------------------------------------------------- #
def test_scheduler_conditioned_retrains_at_deploy_only():
    """Conditioned-first policy: the retrain callback is a one-time
    deployment field calibration -- never invoked between checkpoints."""
    x, w = _data(K=64, N=8)
    calls = []

    def fake_retrain(sc, t, ex, w_, tag):
        calls.append(t)
        return None

    ex = _executor()
    sched = LifetimeScheduler(ex, Scenario(name="aging", prog_sigma=0.05,
                                           drift_nu=0.05),
                              timeline=(("1h", 3.6e3),),
                              retrain=fake_retrain, key=jax.random.PRNGKey(3),
                              calib_n=16)
    recs = sched.run(w, "t", x)
    assert sched.conditioned
    assert calls == [0.0]                  # deploy-time calibration only
    assert all(r["conditioned"] and not r["retrained"]
               for r in sched.history)
    assert all(np.all(np.isfinite(np.asarray(r["y"]))) for r in recs)
    # fallback: forcing the fine-tune path re-enables per-checkpoint calls
    calls.clear()
    ex2 = _executor()
    sched2 = LifetimeScheduler(ex2, Scenario(name="aging", prog_sigma=0.05,
                                             drift_nu=0.05),
                               timeline=(("1h", 3.6e3),),
                               retrain=fake_retrain, prefer_conditioned=False,
                               key=jax.random.PRNGKey(3), calib_n=16)
    sched2.run(w, "t", x)
    assert calls == [0.0, 3.6e3]


def test_conditioned_field_calibrator_deploy_only_and_hot_swaps():
    """make_conditioned_field_calibrator fine-tunes once at t = 0 on the
    realized device across sampled ages and returns None afterwards."""
    from repro.nonideal import make_conditioned_field_calibrator
    x, w = _data(K=64, N=8)
    ex = _executor(fault_remap=True)
    p0 = ex.emulator_params
    cal = make_conditioned_field_calibrator(
        jax.random.PRNGKey(5), ages=(0.0, 3.6e3), n=8, epochs=2)
    sched = LifetimeScheduler(ex, Scenario(name="aging", prog_sigma=0.05,
                                           p_stuck_off=0.03, drift_nu=0.05),
                              timeline=(("1h", 3.6e3),), retrain=cal,
                              key=jax.random.PRNGKey(4), calib_n=16)
    recs = sched.run(w, "t", x)
    assert [r["retrained"] for r in sched.history] == [True, False]
    assert ex.emulator_params is not p0            # deploy swap happened
    # matmul + cold-calib + warm-calib shapes on the ONE unified forward
    assert ex._fns["t"][2]._cache_size() == 3
    assert all(np.all(np.isfinite(np.asarray(r["y"]))) for r in recs)
