"""Tests for the unified DeploymentState redesign (docs/api.md):

  * ``DeploymentState.ideal()`` through the unified forward is
    bit-identical to the plain serving path (``raw_matmul``);
  * a corner -> age -> remap -> params swap sequence reuses exactly ONE
    compiled executable per (tag, shape) -- every deployed quantity is a
    leaf of the one traced state;
  * the state round-trips through pytree flatten/unflatten and npz, and
    the deployment spec through JSON;
  * the legacy mutable setters are thin ``DeprecationWarning`` shims that
    delegate exactly to the fluent ``deploy`` builder.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AnalogConfig
from repro.configs.rram_ps32 import CASE_A
from repro.core import conv4xbar
from repro.core.analog import AnalogExecutor
from repro.core.deployment import (Deployment, DeploymentState,
                                   load_deployment, save_deployment)
from repro.models.common import init_params
from repro.nonideal import (N_SCENARIO_FEATURES, Scenario, get_scenario,
                            scenario_at_age)

ACFG = AnalogConfig()


def _executor(backend="analytic", **kw):
    if backend == "emulator":
        kw.setdefault("emulator_params", init_params(
            jax.random.PRNGKey(7), conv4xbar.conv4xbar_schema(CASE_A,
                                                              n_periph=2)))
        kw.setdefault("use_pallas", False)
    return AnalogExecutor(acfg=AnalogConfig(backend=backend), geom=CASE_A,
                          **kw)


def _data(K=70, N=8, B=4, seed=0):
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (K, N)) * 0.3
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, K)) * 0.5
    return x, w


# --------------------------------------------------------------------------- #
# ideal() bit-identity with the plain path
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", ["analytic", "emulator"])
def test_ideal_state_bit_identical_to_plain_path(backend):
    """The unified forward fed DeploymentState.ideal() must reproduce the
    plain (pre-deployment-era) forward bit-for-bit: every non-ideal leaf
    sits at its exact-identity value."""
    import functools

    x, w = _data()
    ex = _executor(backend)
    x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    wf = w.astype(jnp.float32)

    # the pre-refactor plain forward, verbatim: per-tag jit closing over
    # w, affine as traced scalars, raw_matmul behind the same
    # custom_vjp boundary the old _st_matmul had (the boundary shapes
    # XLA's fusion, so it is part of "bit-identical")
    @functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
    def _st_plain(ex_, tag, q, ww, a, b):
        yv, xs = ex_.raw_matmul(q, ww, tag)
        return (a * yv + b) * xs

    _st_plain.defvjp(
        lambda ex_, tag, q, ww, a, b: (_st_plain(ex_, tag, q, ww, a, b),
                                       None),
        lambda ex_, tag, res, ct: (ct, ct, ct, ct))

    fn_plain = jax.jit(lambda q, a, b: _st_plain(ex, "t", q, wf, a, b))
    y_plain = np.asarray(fn_plain(x2, jnp.float32(2.0), jnp.float32(0.1)))
    plan = ex._plan_for(w, "t")
    ep = ex.emulator_params if backend == "emulator" else {}
    st = DeploymentState.ideal(plan, eparams=ep, calibration=(2.0, 0.1))
    y_state = ex._unified_for("t", w)(x2, st)
    np.testing.assert_array_equal(np.asarray(y_state), y_plain)
    # and matmul's default (ideal deployment + calibration dict) agrees
    ex.calibration["t"] = (2.0, 0.1)
    np.testing.assert_array_equal(
        np.asarray(ex.matmul(x, w, "t")).reshape(-1, w.shape[1]), y_plain)


# --------------------------------------------------------------------------- #
# zero-recompile swaps under ONE cache
# --------------------------------------------------------------------------- #
def test_corner_age_remap_params_swaps_compile_once():
    """The acceptance sequence: corner -> age -> remap -> params, one
    executable."""
    x, w = _data()
    ex = _executor("emulator")
    outs = [np.asarray(ex.matmul(x, w, "t"))]             # ideal
    fn = ex._fns["t"][2]
    ex.deploy(scenario=get_scenario("stressed"), key=jax.random.PRNGKey(1))
    outs.append(np.asarray(ex.matmul(x, w, "t")))         # corner
    ex.deploy(age=2.592e6)
    outs.append(np.asarray(ex.matmul(x, w, "t")))         # age
    ex.deploy(remap=True)
    outs.append(np.asarray(ex.matmul(x, w, "t")))         # remap
    new_p = init_params(jax.random.PRNGKey(8),
                        conv4xbar.conv4xbar_schema(CASE_A, n_periph=2))
    ex.deploy(params=new_p)
    outs.append(np.asarray(ex.matmul(x, w, "t")))         # hot-swap
    assert ex._fns["t"][2] is fn
    assert fn._cache_size() == 1                          # compiled ONCE
    for a, b in zip(outs, outs[1:]):
        assert not np.array_equal(a, b)                   # swaps took effect


def test_deploy_builder_is_fluent_and_partial():
    ex = _executor()
    sc = Scenario(name="fl", prog_sigma=0.05, drift_nu=0.05,
                  p_stuck_off=0.03)
    k = jax.random.PRNGKey(4)
    dep = ex.deploy(scenario=sc, key=k, remap=True)
    assert isinstance(dep, Deployment) and ex.deployment is dep
    assert ex.scenario is sc and ex.fault_remap
    # partial update: aging keeps the key and the remap policy
    dep2 = ex.deploy(age=3.6e3)
    assert dep2.remap and dep2.key is k
    assert float(np.asarray(ex.scenario.drift_t)) == 3.6e3
    assert ex.scenario.prog_sigma == 0.05
    # deployments are immutable specs
    with pytest.raises(dataclasses.FrozenInstanceError):
        dep2.remap = False
    with pytest.raises(ValueError):
        _executor().deploy(age=3.6e3)        # no scenario to age
    # clearing the corner is explicit
    assert ex.deploy(scenario=None).scenario is None


# --------------------------------------------------------------------------- #
# pytree / JSON / npz round trips
# --------------------------------------------------------------------------- #
def test_state_pytree_roundtrip():
    x, w = _data()
    ex = _executor("emulator")
    ex.deploy(scenario=get_scenario("stressed"), key=jax.random.PRNGKey(2),
              remap=True)
    st = ex.state_for("t", w)
    leaves, treedef = jax.tree_util.tree_flatten(st)
    st2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(st2, DeploymentState)
    for a, b in zip(jax.tree_util.tree_leaves(st),
                    jax.tree_util.tree_leaves(st2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # every deployed quantity is a LEAF (traced), nothing static
    assert len(leaves) == 7 + len(st.eparams)
    # fluent immutable updates
    st3 = st.with_calibration(2.0, -0.5).with_read_key(jax.random.PRNGKey(9))
    assert float(st3.cal_a) == 2.0 and st.cal_a is not st3.cal_a
    assert np.array_equal(np.asarray(st.gf), np.asarray(st3.gf))


def test_deployment_spec_json_roundtrip():
    sc = get_scenario("stressed")
    dep = Deployment(scenario=sc, key=jax.random.PRNGKey(11), remap=True)
    back = Deployment.from_spec_json(dep.spec_json())
    assert back.remap
    np.testing.assert_array_equal(np.asarray(back.key), np.asarray(dep.key))
    l1, t1 = jax.tree_util.tree_flatten(back.scenario)
    l2, t2 = jax.tree_util.tree_flatten(sc)
    assert t1 == t2 and l1 == l2
    # ideal spec round-trips too
    empty = Deployment.from_spec_json(Deployment().spec_json())
    assert empty.scenario is None and not empty.remap


def test_deployment_npz_roundtrip(tmp_path):
    """An aged + remapped + calibrated deployment serialized to npz and
    restored in a fresh executor serves bit-identical outputs."""
    x, w = _data()
    ex = _executor("emulator")
    ex.deploy(scenario=scenario_at_age(get_scenario("stressed"), 8.64e4),
              key=jax.random.PRNGKey(5), remap=True)
    ex.calibrate(jax.random.PRNGKey(6), w, "t", n=16)
    states = {"t": ex.state_for("t", w)}
    y_ref = np.asarray(ex._unified_for("t", w)(
        x.reshape(-1, x.shape[-1]).astype(jnp.float32), states["t"]))
    path = str(tmp_path / "dep.npz")
    save_deployment(path, states, ex.deployment)
    loaded, dep = load_deployment(path)
    assert set(loaded) == {"t"}
    for f in ("gf", "read_sigma", "read_key", "out_perm", "sfeat",
              "cal_a", "cal_b"):
        np.testing.assert_array_equal(np.asarray(getattr(loaded["t"], f)),
                                      np.asarray(getattr(states["t"], f)))
    assert set(loaded["t"].eparams) == set(ex.emulator_params)
    assert dep.remap and dep.states is loaded
    # a FRESH executor serving the loaded states reproduces the outputs
    ex2 = _executor("emulator", emulator_params=ex.emulator_params)
    ex2.deploy(scenario=dep.scenario, key=dep.key, remap=dep.remap,
               states=loaded)
    np.testing.assert_array_equal(
        np.asarray(ex2.matmul(x, w, "t")).reshape(-1, w.shape[1]), y_ref)


# --------------------------------------------------------------------------- #
# Scan-threaded serving (per-period states as lax.scan xs)
# --------------------------------------------------------------------------- #
def _scanned_session(ex, batch=2, gen=4, seed=0):
    """A reduced gemma3-1b at 12 layers: two scan periods, so the
    per-period DeploymentStates ride the layer scan as stacked xs."""
    from repro.launch.serve import ServeSession
    sess = ServeSession("gemma3-1b", reduced=True, reduced_layers=12,
                        batch=batch, prompt_len=8, gen=gen, seed=seed,
                        executor=ex)
    assert any(k.startswith("dec.") for k in sess.sites()), \
        "arch must actually be scanned (per-period 'dec.{p}:' site keys)"
    return sess


def test_scanned_session_swaps_compile_once_logits_shift():
    """Corner -> age -> remap swaps on a SCANNED model keep one compiled
    step pair (the states are scan xs, not trace constants) and take
    effect at the logits level from the very next generate()."""
    ex = _executor()
    sess = _scanned_session(ex)
    outs = [sess.generate()["logits"]]                        # ideal
    ex.deploy(scenario=get_scenario("stressed"), key=jax.random.PRNGKey(1))
    outs.append(sess.generate()["logits"])                    # corner
    ex.deploy(age=2.592e6)
    outs.append(sess.generate()["logits"])                    # age
    ex.deploy(remap=True)
    sess.generate()                                           # remap swap
    assert sess.prefill_traces == 1 and sess.decode_traces == 1
    assert not np.array_equal(outs[0], outs[1])               # corner bit
    assert not np.array_equal(outs[1], outs[2])               # aging bit


def test_scanned_threaded_ideal_matches_in_trace_hook_path():
    """Threading per-period ideal states through the scan xs reproduces
    the plain in-trace dense-hook path bit-for-bit -- threading is a
    pure re-plumbing of WHERE the state enters, never of the math."""
    from repro.models.common import use_dense_hook

    sess = _scanned_session(_executor())
    out = sess.generate()

    ex_ref = _executor()
    ref = _scanned_session(ex_ref)
    ref._bound = lambda states: use_dense_hook(ex_ref.hook)   # no threading
    out_ref = ref.generate()
    np.testing.assert_array_equal(out["tokens"], out_ref["tokens"])
    np.testing.assert_array_equal(out["logits"], out_ref["logits"])


def test_scanned_deployment_npz_roundtrip_through_session(tmp_path):
    """--state-save / --state-load for a scanned arch: per-period states
    (stacked scan leaves) survive npz and serve bit-identically from a
    fresh executor + session."""
    from repro.core.deployment import load_deployment

    ex = _executor()
    ex.deploy(scenario=scenario_at_age(get_scenario("stressed"), 8.64e4),
              key=jax.random.PRNGKey(5), remap=True)
    sess = _scanned_session(ex)
    out = sess.generate()
    path = str(tmp_path / "scan_dep.npz")
    sess.save_deployment(path)

    loaded, dep = load_deployment(path)
    assert set(loaded) == set(sess.sites())
    ex2 = _executor()
    ex2.deploy(scenario=dep.scenario, key=dep.key, remap=dep.remap,
               states=loaded)
    sess2 = _scanned_session(ex2)
    out2 = sess2.generate(states=loaded)
    np.testing.assert_array_equal(out2["tokens"], out["tokens"])
    np.testing.assert_array_equal(out2["logits"], out["logits"])


# --------------------------------------------------------------------------- #
# Deprecation shims
# --------------------------------------------------------------------------- #
def test_setter_shims_warn_and_delegate_exactly():
    x, w = _data()
    sc = Scenario(name="shim", prog_sigma=0.08, p_stuck_off=0.03)
    k = jax.random.PRNGKey(3)
    new_api = _executor()
    new_api.deploy(scenario=sc, key=k, remap=True)
    y_new = np.asarray(new_api.matmul(x, w, "t"))

    old_api = _executor()
    with pytest.warns(DeprecationWarning, match="set_scenario is deprecated"):
        ret = old_api.set_scenario(sc, key=k)
    assert ret is old_api                      # old chaining still works
    with pytest.warns(DeprecationWarning, match="fault_remap is deprecated"):
        old_api.fault_remap = True
    np.testing.assert_array_equal(np.asarray(old_api.matmul(x, w, "t")),
                                  y_new)

    em = _executor("emulator")
    new_p = init_params(jax.random.PRNGKey(8),
                        conv4xbar.conv4xbar_schema(CASE_A, n_periph=2))
    with pytest.warns(DeprecationWarning,
                      match="set_emulator_params is deprecated"):
        em.set_emulator_params(new_p)
    assert em.emulator_params is new_p
