"""Per-architecture smoke tests: reduced config, one forward + one train
step + one prefill/decode step on CPU; asserts shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config, reduced
from repro.configs.base import ParallelConfig, TrainConfig
from repro.models import model as M
from repro.runtime import steps as S

PCFG = ParallelConfig(attn_block_kv=16, xent_chunk=16, scan_chunk=8)
TCFG = TrainConfig(warmup_steps=2, total_steps=10)


def make_batch(cfg, B=2, S_len=32):
    key = jax.random.PRNGKey(0)
    batch = {
        "tokens": jax.random.randint(key, (B, S_len), 0, cfg.vocab_size),
        "targets": jax.random.randint(key, (B, S_len), 0, cfg.vocab_size),
        "mask": jnp.ones((B, S_len), jnp.float32),
    }
    if cfg.frontend == "vision":
        batch["image_embeds"] = jax.random.normal(
            key, (B, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.encoder_layers:
        batch["enc_frames"] = jax.random.normal(
            key, (B, S_len, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_and_train_step(arch):
    cfg = reduced(get_config(arch))
    batch = make_batch(cfg)
    state = S.init_train_state(jax.random.PRNGKey(1), cfg)
    step = S.make_train_step(cfg, PCFG, TCFG)
    new_state, metrics = jax.jit(step)(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), (arch, loss)
    assert loss > 0
    assert int(new_state["step"]) == 1
    # params actually changed
    before = jax.tree.leaves(state["params"])[0]
    after = jax.tree.leaves(new_state["params"])[0]
    assert not np.allclose(np.asarray(before), np.asarray(after))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_prefill_then_decode(arch):
    cfg = reduced(get_config(arch))
    B, S_len = 2, 32
    batch = make_batch(cfg, B, S_len)
    params = S.init_train_state(jax.random.PRNGKey(1), cfg)["params"]
    params = jax.tree.map(lambda x: x.astype(jnp.bfloat16), params)

    prefill = S.make_prefill_step(cfg, PCFG)
    logits, cache = jax.jit(prefill)(params, {k: v for k, v in batch.items()
                                              if k != "targets" and k != "mask"})
    assert logits.shape == (B, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    decode = S.make_decode_step(cfg, PCFG)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    # prefill cache covers S_len positions; continue decoding at pos=S_len
    # (global caches from prefill are sized S_len -> extend by padding)
    def pad_cache(c):
        def f(leaf):
            return leaf
        return jax.tree.map(f, c)
    # decode against a fresh zero cache written at pos = 0..2 for shape checks
    cs = M.model_cache_schema(cfg, B, S_len,
                              cross_len=(S_len if cfg.encoder_layers else 0))
    cache0 = M.zeros_cache(cs)
    if cfg.encoder_layers:
        # reuse prefill's cross cache (real encoder output)
        cache0 = jax.tree.map(lambda z, c: c.astype(z.dtype) if c.shape == z.shape else z,
                              cache0, cache)
    lg, cache1 = jax.jit(decode)(params, tok, cache0, jnp.zeros((), jnp.int32))
    assert lg.shape == (B, cfg.padded_vocab)
    assert np.isfinite(np.asarray(lg, np.float32)).all()
    lg2, _ = jax.jit(decode)(params, tok, cache1, jnp.ones((), jnp.int32))
    assert np.isfinite(np.asarray(lg2, np.float32)).all()
