"""Parity + cache tests for the cached/fused analog serving fast path.

The fast path (conductance-plan cache, single-pass dual-rail delta
factorization, channels-last conv rewrite, Pallas grid kernel) must be
numerically equivalent to the reference blockified path (`fast_path=False`,
which reproduces the original two-pass implementation) within fp32
tolerance, across backends and odd shapes that exercise padT / padN.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AnalogConfig
from repro.configs.rram_ps32 import CASE_A, CASE_B
from repro.core import conv4xbar
from repro.core.analog import AnalogExecutor
from repro.core.crossbar import build_conductance_plan
from repro.models.common import init_params

SHAPES = [
    (CASE_A, 64, 4, 8),      # exact tiling
    (CASE_A, 70, 3, 4),      # padT (70 -> 2 tiles) + padN irrelevant (no=1)
    (CASE_A, 512, 32, 16),   # the benchmark shape
    (CASE_B, 64, 8, 8),      # case B: no=4 divides N
    (CASE_B, 130, 7, 5),     # padT + padN (7 % 4 != 0)
    (CASE_A, 64, 1, 1),      # single output, single batch row
]


def _params(geom):
    schema = conv4xbar.conv4xbar_schema(geom, n_periph=2)
    return init_params(jax.random.PRNGKey(7), schema)


def _data(geom, K, N, B, seed=0):
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (K, N)) * 0.3
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, K)) * 0.5
    return x, w


@pytest.mark.parametrize("geom,K,N,B", SHAPES,
                         ids=[f"{g.name}-{k}x{n}x{b}" for g, k, n, b in SHAPES])
def test_fastpath_matches_reference_emulator(geom, K, N, B):
    x, w = _data(geom, K, N, B)
    params = _params(geom)
    kw = dict(acfg=AnalogConfig(backend="emulator"), geom=geom,
              emulator_params=params)
    y_ref, xs_ref = AnalogExecutor(fast_path=False, **kw).raw_matmul(x, w, "t")
    y_fast, xs_fast = AnalogExecutor(use_pallas=False, **kw).raw_matmul(x, w, "t")
    assert float(xs_ref) == float(xs_fast)
    np.testing.assert_allclose(np.asarray(y_fast), np.asarray(y_ref),
                               rtol=2e-4, atol=1e-5)


@pytest.mark.parametrize("geom,K,N,B", SHAPES[:4],
                         ids=[f"{g.name}-{k}x{n}x{b}" for g, k, n, b in SHAPES[:4]])
def test_fastpath_matches_reference_analytic(geom, K, N, B):
    """Single-pass dual-rail against the cached plan is bit-compatible with
    the reference path for the analytic backend (identical block tensors)."""
    x, w = _data(geom, K, N, B)
    kw = dict(acfg=AnalogConfig(backend="analytic"), geom=geom)
    y_ref, _ = AnalogExecutor(fast_path=False, **kw).raw_matmul(x, w, "t")
    y_fast, _ = AnalogExecutor(**kw).raw_matmul(x, w, "t")
    np.testing.assert_allclose(np.asarray(y_fast), np.asarray(y_ref),
                               rtol=1e-6, atol=1e-8)


@pytest.mark.parametrize("geom", [CASE_A, CASE_B], ids=lambda g: g.name)
def test_fastpath_pallas_grid_matches_reference(geom):
    """``use_pallas=True`` routes the fast path through the unified fused
    kernel (interpret mode on CPU); it agrees with the reference path."""
    x, w = _data(geom, 70, 4 if geom is CASE_B else 3, 4)
    params = _params(geom)
    kw = dict(acfg=AnalogConfig(backend="emulator"), geom=geom,
              emulator_params=params)
    y_ref, _ = AnalogExecutor(fast_path=False, **kw).raw_matmul(x, w, "t")
    y_pl, _ = AnalogExecutor(use_pallas=True, **kw).raw_matmul(x, w, "t")
    np.testing.assert_allclose(np.asarray(y_pl), np.asarray(y_ref),
                               rtol=2e-4, atol=1e-5)


def test_fastpath_under_jit_and_grad():
    """matmul through the fast path is jittable and keeps the
    straight-through digital gradient."""
    x, w = _data(CASE_A, 70, 3, 4)
    ex = AnalogExecutor(acfg=AnalogConfig(backend="emulator"), geom=CASE_A,
                        emulator_params=_params(CASE_A), use_pallas=False)
    y_eager = ex.matmul(x, w, "t")
    y_jit = jax.jit(lambda a: ex.matmul(a, w, "t"))(x)
    np.testing.assert_allclose(np.asarray(y_jit), np.asarray(y_eager),
                               rtol=1e-5, atol=1e-6)
    g_analog = jax.grad(lambda xx: ex.matmul(xx, w, "t").sum())(x)
    g_digital = jax.grad(lambda xx: (xx @ w).sum())(x)
    np.testing.assert_allclose(np.asarray(g_analog), np.asarray(g_digital),
                               rtol=1e-5, atol=1e-6)


def test_plan_cache_hit_and_invalidation():
    """The conductance plan is computed once per bound weight and rebuilt
    when a tag is rebound to a different matrix."""
    x, w = _data(CASE_A, 70, 3, 4)
    ex = AnalogExecutor(acfg=AnalogConfig(backend="analytic"), geom=CASE_A)
    y1, _ = ex.raw_matmul(x, w, "t")
    assert "t" in ex._plans
    plan1 = ex._plans["t"][1]
    y1b, _ = ex.raw_matmul(x, w, "t")
    assert ex._plans["t"][1] is plan1          # cache hit: same object
    np.testing.assert_allclose(np.asarray(y1b), np.asarray(y1))

    w2 = w * 2.0 + 0.1                         # rebind tag to a new matrix
    y2, _ = ex.raw_matmul(x, w2, "t")
    plan2 = ex._plans["t"][1]
    assert plan2 is not plan1                  # invalidated + rebuilt
    y2_fresh, _ = AnalogExecutor(
        acfg=AnalogConfig(backend="analytic"), geom=CASE_A).raw_matmul(
            x, w2, "other")
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y2_fresh),
                               rtol=1e-6, atol=1e-8)
    assert not np.allclose(np.asarray(y2), np.asarray(y1))


def test_pre_cache_invalidation_on_rebind():
    """The fast-path precompute (zero-voltage response) follows the plan."""
    x, w = _data(CASE_A, 70, 3, 4)
    ex = AnalogExecutor(acfg=AnalogConfig(backend="emulator"), geom=CASE_A,
                        emulator_params=_params(CASE_A), use_pallas=False)
    ex.raw_matmul(x, w, "t")
    pre1 = ex._g0_cache["t"][1]
    ex.raw_matmul(x, w, "t")
    assert ex._g0_cache["t"][1] is pre1
    w2 = w + 0.05
    y2, _ = ex.raw_matmul(x, w2, "t")
    assert ex._g0_cache["t"][1] is not pre1
    y_ref, _ = AnalogExecutor(
        acfg=AnalogConfig(backend="emulator"), geom=CASE_A,
        emulator_params=ex.emulator_params, fast_path=False).raw_matmul(
            x, w2, "x")
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y_ref),
                               rtol=2e-4, atol=1e-5)


def test_matmul_compile_cache_reused_across_calibration():
    """Recalibration must not retrigger compilation (affine enters as traced
    scalars); rebinding weights must."""
    x, w = _data(CASE_A, 64, 4, 8)
    ex = AnalogExecutor(acfg=AnalogConfig(backend="analytic"), geom=CASE_A)
    ex.matmul(x, w, "t")
    assert ex._fns["t"][0] is w
    fn1 = ex._fns["t"][2]
    ex.calibration["t"] = (2.0, 0.1)           # recalibrate
    y = ex.matmul(x, w, "t")
    assert ex._fns["t"][2] is fn1              # same compiled fn
    assert fn1._cache_size() == 1              # affine is a state leaf
    assert np.all(np.isfinite(np.asarray(y)))


def test_calibrated_fastpath_consistent_with_reference():
    """End-to-end matmul (calibration + affine + scale) agrees across paths."""
    x, w = _data(CASE_A, 96, 5, 6, seed=3)
    params = _params(CASE_A)
    kw = dict(acfg=AnalogConfig(backend="emulator"), geom=CASE_A,
              emulator_params=params)
    ex_ref = AnalogExecutor(fast_path=False, **kw)
    ex_fast = AnalogExecutor(use_pallas=False, **kw)
    key = jax.random.PRNGKey(9)
    ex_ref.calibrate(key, w, "t")
    ex_fast.calibrate(key, w, "t")
    a_r, b_r = ex_ref.calibration["t"]
    a_f, b_f = ex_fast.calibration["t"]
    assert abs(a_r - a_f) < 1e-3 * max(1.0, abs(a_r))
    y_ref = ex_ref.matmul(x, w, "t")
    y_fast = ex_fast.matmul(x, w, "t")
    np.testing.assert_allclose(np.asarray(y_fast), np.asarray(y_ref),
                               rtol=1e-3, atol=1e-4)
