"""Docs health as part of tier-1: every internal link in README / ROADMAP /
docs/*.md resolves (file and #anchor), and every ``>>>`` example in those
pages passes under doctest — the docs stay executable truth."""
import doctest
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "tools"))

import check_docs  # noqa: E402


def test_doc_files_found():
    files = check_docs.doc_files()
    assert "README.md" in files
    assert os.path.join("docs", "architecture.md") in files
    assert os.path.join("docs", "nonideal.md") in files
    assert os.path.join("docs", "lifetime.md") in files
    assert os.path.join("docs", "performance.md") in files


def test_internal_links_resolve():
    errors = []
    for rel in check_docs.doc_files():
        errors += check_docs.check_links(rel)
    assert not errors, "\n".join(errors)


def test_doc_doctests_pass():
    failures = []
    for rel in check_docs.doc_files():
        failures += check_docs.run_doctests(rel)
    assert not failures, "\n".join(failures)


def test_checker_catches_broken_link(tmp_path):
    bad = tmp_path / "bad.md"
    bad.write_text("see [missing](no/such/file.md) and "
                   "[anchor](bad.md#nope)\n\n# Real Heading\n")
    errs = check_docs.check_links(os.path.relpath(bad, check_docs.REPO))
    assert len(errs) == 2


def test_slugify_matches_github_style():
    assert check_docs.slugify("Per-tile heterogeneity") == \
        "per-tile-heterogeneity"
    assert check_docs.slugify("## The `Scenario` schema!") == \
        "-the-scenario-schema"
