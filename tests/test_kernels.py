"""Per-kernel validation: shape/dtype sweeps, assert_allclose against the
pure-jnp ref.py oracles (kernels run in interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AnalogConfig
from repro.configs.rram_ps32 import CASE_A, CASE_B


# --------------------------------------------------------------------------- #
# xbar_mac
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("B,K,N", [(128, 128, 128), (256, 384, 128),
                                   (128, 512, 256), (64, 64, 64),
                                   # non-divisible shapes: pad-and-slice path
                                   (100, 70, 130), (65, 64, 63)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_xbar_mac(B, K, N, dtype):
    from repro.kernels.xbar_mac import xbar_mac
    from repro.kernels.xbar_mac.ref import xbar_mac_ref
    key = jax.random.PRNGKey(B + K + N)
    v = jax.random.uniform(key, (B, K), dtype, maxval=0.2)
    g = jax.random.uniform(jax.random.fold_in(key, 1), (K, N), dtype,
                           minval=1e-6, maxval=1e-4)
    out = xbar_mac(v, g, block_b=64, block_n=64, block_k=64)
    ref = xbar_mac_ref(v, g)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


# --------------------------------------------------------------------------- #
# flash_attention
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("B,H,S,D", [(2, 2, 256, 64), (1, 4, 128, 128),
                                     (2, 1, 512, 32)])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 128), (False, 0)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, H, S, D, causal, window, dtype):
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    key = jax.random.PRNGKey(S + D)
    q = jax.random.normal(key, (B, H, S, D), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, H, S, D), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, H, S, D), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=128, block_kv=128)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


# --------------------------------------------------------------------------- #
# linear_scan
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("B,S,D", [(2, 256, 512), (1, 128, 1024), (4, 512, 64)])
@pytest.mark.parametrize("with_h0", [False, True])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_linear_scan(B, S, D, with_h0, dtype):
    from repro.kernels.linear_scan import linear_scan
    from repro.kernels.linear_scan.ref import linear_scan_ref
    key = jax.random.PRNGKey(S)
    a = jax.random.uniform(key, (B, S, D), dtype, minval=0.5, maxval=0.999)
    b = jax.random.normal(jax.random.fold_in(key, 1), (B, S, D), dtype) * 0.1
    h0 = (jax.random.normal(jax.random.fold_in(key, 2), (B, D), dtype)
          if with_h0 else None)
    h, h_last = linear_scan(a, b, h0, block_d=64, block_s=64)
    hr, hr_last = linear_scan_ref(a.astype(jnp.float32),
                                  b.astype(jnp.float32),
                                  None if h0 is None else h0.astype(jnp.float32))
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(h, np.float32),
                               np.asarray(hr, np.float32), rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(h_last, np.float32),
                               np.asarray(hr_last, np.float32),
                               rtol=tol, atol=tol)


# --------------------------------------------------------------------------- #
# emulator_block (fused Conv4Xbar)
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("geom", [CASE_A, CASE_B], ids=lambda g: g.name)
@pytest.mark.parametrize("n", [8, 32])
def test_emulator_block(geom, n):
    from repro.core import conv4xbar
    from repro.kernels.emulator_block import emulator_block
    from repro.models.common import init_params
    key = jax.random.PRNGKey(0)
    schema = conv4xbar.conv4xbar_schema(geom, n_periph=2)
    params = init_params(key, schema)
    x = jax.random.uniform(key, (n,) + (geom.features, geom.tiles,
                                        geom.rows, geom.cols))
    periph = jax.random.uniform(jax.random.fold_in(key, 1), (n, 2))
    out = emulator_block(params, x, periph, geom, block_n=8)
    ref = conv4xbar.apply(params, x, periph)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-6)


@pytest.mark.parametrize("geom", [CASE_A, CASE_B], ids=lambda g: g.name)
@pytest.mark.parametrize("M,NB,NO", [(4, 2, 3), (3, 1, 2)])
def test_emulator_block_grid(geom, M, NB, NO):
    """2-D grid serving kernel: per-block shared conductance features,
    constant (gain=1, off=0) peripherals; matches the paper-faithful apply
    over the equivalent broadcast batch (incl. batch padding M % bm != 0)."""
    from repro.core import conv4xbar
    from repro.kernels.emulator_block import emulator_block_grid
    from repro.models.common import init_params
    key = jax.random.PRNGKey(1)
    schema = conv4xbar.conv4xbar_schema(geom, n_periph=2)
    params = init_params(key, schema)
    D, H, W = geom.tiles, geom.rows, geom.cols
    v = jax.random.uniform(key, (M, NB, D, H))
    g = jax.random.uniform(jax.random.fold_in(key, 1), (NB * NO, D, H, W))
    out = emulator_block_grid(params, v, g, geom, block_m=2)
    assert out.shape == (M, NB * NO, geom.outputs)
    # reference: materialize the batch-broadcast (V, G) channel stack
    vch = jnp.broadcast_to(
        v[:, :, None, :, :, None], (M, NB, NO, D, H, W))
    gch = jnp.broadcast_to(
        g.reshape(NB, NO, D, H, W)[None], (M, NB, NO, D, H, W))
    x = jnp.stack([vch, gch], axis=3).reshape(M * NB * NO, 2, D, H, W)
    periph = jnp.concatenate([jnp.ones((x.shape[0], 1)),
                              jnp.zeros((x.shape[0], 1))], axis=-1)
    ref = conv4xbar.apply(params, x, periph).reshape(M, NB * NO, -1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-6)


# --------------------------------------------------------------------------- #
# emulator_block_unified (ONE kernel, every device corner)
# --------------------------------------------------------------------------- #
def _unified_fixture(geom, n_periph=2, NB=2, NO=3, M=6, seed=5):
    """aux/pre + drive tensors for the unified serving kernel."""
    from repro.core import conv4xbar
    from repro.models.common import init_params
    key = jax.random.PRNGKey(seed)
    schema = conv4xbar.conv4xbar_schema(geom, n_periph=n_periph)
    params = init_params(key, schema)
    aux = conv4xbar.blocklast_weights(params, geom)
    D, H, W = geom.tiles, geom.rows, geom.cols
    g = jax.random.uniform(jax.random.fold_in(key, 1), (NB, NO, D, H, W))
    pre = conv4xbar.blocklast_precompute(aux, g)
    u = jax.random.uniform(jax.random.fold_in(key, 2), (M, NB, D, H))
    pos = (jax.random.uniform(jax.random.fold_in(key, 3),
                              (M, NB, D, H)) > 0.5).astype(jnp.float32)
    return aux, pre, u, pos


@pytest.mark.parametrize("geom", [CASE_A, CASE_B], ids=lambda g: g.name)
@pytest.mark.parametrize("block_m", [4, 8])  # 6 % 4 != 0: pad-and-slice
def test_emulator_block_unified_ideal_bitwise(geom, block_m):
    """Ideal corner: the fused kernel (interpret mode) is BIT-IDENTICAL to
    the chunked XLA fast path -- same dual_rail_stage1/_tail_stages code,
    different schedule."""
    from repro.core import conv4xbar
    from repro.kernels.emulator_block.emulator_block import (
        emulator_block_unified_pallas)
    aux, pre, u, pos = _unified_fixture(geom)
    ref = conv4xbar.apply_blocklast(aux, pre, u, pos, chunk=3)
    out = emulator_block_unified_pallas(aux, pre, u, pos, block_m=block_m,
                                        interpret=True)
    assert out.shape == ref.shape
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_emulator_block_unified_conditioned():
    """Conditioned corner: the scenario epilogue (fc0 shift) matches the
    XLA path, and the all-zero feature encoding reproduces the ideal
    corner of the same net exactly -- one compiled kernel per shape serves
    every corner."""
    from repro.core import conv4xbar
    from repro.kernels.emulator_block.emulator_block import (
        emulator_block_unified_pallas)
    from repro.nonideal import N_SCENARIO_FEATURES
    aux, pre, u, pos = _unified_fixture(
        CASE_A, n_periph=2 + N_SCENARIO_FEATURES)
    sfeat = jnp.linspace(-0.5, 0.5, N_SCENARIO_FEATURES)
    shift = sfeat @ aux["f0_scen"]
    ref = conv4xbar.apply_blocklast(aux, pre, u, pos, chunk=2,
                                    fc0_shift=shift)
    out = emulator_block_unified_pallas(aux, pre, u, pos, shift=shift,
                                        block_m=4, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-7)
    # zero features == no epilogue == the plain ideal evaluation, bitwise
    z = jnp.zeros((N_SCENARIO_FEATURES,)) @ aux["f0_scen"]
    out_z = emulator_block_unified_pallas(aux, pre, u, pos, shift=z,
                                          block_m=4, interpret=True)
    out_n = emulator_block_unified_pallas(aux, pre, u, pos, shift=None,
                                          block_m=4, interpret=True)
    np.testing.assert_array_equal(np.asarray(out_z), np.asarray(out_n))


def test_emulator_block_unified_nonideal_vs_block_tensor():
    """Non-ideal corner, end to end: the unified-kernel fast path under a
    stressed scenario (perturbed conductances + conditioning features)
    agrees with the block-tensor reference path within fp32 tolerance."""
    from repro.configs.base import AnalogConfig
    from repro.core.analog import AnalogExecutor
    from repro.core import conv4xbar
    from repro.models.common import init_params
    from repro.nonideal import N_SCENARIO_FEATURES, get_scenario
    key = jax.random.PRNGKey(9)
    params = init_params(key, conv4xbar.conv4xbar_schema(
        CASE_A, n_periph=2 + N_SCENARIO_FEATURES))
    w = jax.random.normal(key, (70, 3)) * 0.3
    x = jax.random.normal(jax.random.fold_in(key, 1), (4, 70)) * 0.5
    kw = dict(acfg=AnalogConfig(backend="emulator"), geom=CASE_A,
              emulator_params=params)
    outs = []
    for exkw in (dict(fast_path=False), dict(use_pallas=True)):
        ex = AnalogExecutor(**kw, **exkw)
        ex.deploy(scenario=get_scenario("stressed"),
                  key=jax.random.PRNGKey(2))
        outs.append(np.asarray(ex.matmul(x, w, "t")))
    np.testing.assert_allclose(outs[1], outs[0], rtol=2e-4, atol=1e-5)


def test_emulator_block_unified_bf16():
    """bf16 accumulation mode: GEMMs run with bf16 operands / f32
    accumulators; parity is loose by construction."""
    from repro.core import conv4xbar
    from repro.kernels.emulator_block.emulator_block import (
        emulator_block_unified_pallas)
    aux, pre, u, pos = _unified_fixture(CASE_A)
    ref = conv4xbar.apply_blocklast(aux, pre, u, pos, chunk=2)
    out = emulator_block_unified_pallas(aux, pre, u, pos, block_m=8,
                                        interpret=True,
                                        compute_dtype=jnp.bfloat16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-2, atol=3e-2)


def test_emulator_block_unified_dispatcher_fallback_bitwise():
    """The dispatcher's two routes (pallas kernel / chunked XLA) are
    bit-identical in f32, so ``use_pallas`` is a pure scheduling choice."""
    from repro.kernels.emulator_block import emulator_block_unified
    aux, pre, u, pos = _unified_fixture(CASE_A)
    y_xla = emulator_block_unified(aux, pre, u, pos, use_pallas=False,
                                   chunk=2)
    y_pl = emulator_block_unified(aux, pre, u, pos, use_pallas=True,
                                  interpret=True, block_m=4)
    np.testing.assert_array_equal(np.asarray(y_pl), np.asarray(y_xla))


def test_unified_kernel_compile_once_across_corners():
    """Corner swaps through the deployed forward recompile NOTHING with the
    fused kernel on the fast path: scenario features ride the precomputed
    shift operand, perturbed conductances ride pre[...] -- all traced
    leaves of one executable."""
    from repro.configs.base import AnalogConfig
    from repro.core.analog import AnalogExecutor
    from repro.core import conv4xbar
    from repro.models.common import init_params
    from repro.nonideal import N_SCENARIO_FEATURES, get_scenario
    key = jax.random.PRNGKey(11)
    params = init_params(key, conv4xbar.conv4xbar_schema(
        CASE_A, n_periph=2 + N_SCENARIO_FEATURES))
    w = jax.random.normal(key, (70, 3)) * 0.3
    x = jax.random.normal(jax.random.fold_in(key, 1), (4, 70)) * 0.5
    ex = AnalogExecutor(acfg=AnalogConfig(backend="emulator"), geom=CASE_A,
                        emulator_params=params, use_pallas=True)
    outs = [np.asarray(ex.matmul(x, w, "t"))]                  # ideal
    fn = ex._fns["t"][2]
    ex.deploy(scenario=get_scenario("stressed"), key=jax.random.PRNGKey(3))
    outs.append(np.asarray(ex.matmul(x, w, "t")))              # corner
    ex.deploy(age=2.592e6)
    outs.append(np.asarray(ex.matmul(x, w, "t")))              # age
    assert ex._fns["t"][2] is fn
    assert fn._cache_size() == 1                               # ONE compile
    for a, b in zip(outs, outs[1:]):
        assert not np.array_equal(a, b)


def test_emulator_block_pad_batch():
    """Flat-batch kernel with N % block_n != 0: pad-and-slice instead of
    the old hard assert."""
    from repro.core import conv4xbar
    from repro.kernels.emulator_block import emulator_block
    from repro.models.common import init_params
    geom = CASE_A
    key = jax.random.PRNGKey(2)
    params = init_params(key, conv4xbar.conv4xbar_schema(geom, n_periph=2))
    n = 10                                    # 10 % 8 != 0
    x = jax.random.uniform(key, (n,) + (geom.features, geom.tiles,
                                        geom.rows, geom.cols))
    periph = jax.random.uniform(jax.random.fold_in(key, 1), (n, 2))
    out = emulator_block(params, x, periph, geom, block_n=8)
    ref = conv4xbar.apply(params, x, periph)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-6)


def test_autotune_cache_and_report(tmp_path, monkeypatch):
    """best_config: sweep once, then memory hit, then (fresh process
    simulated by clearing memory) disk hit; report records the source."""
    from repro.kernels import autotune
    monkeypatch.setenv("REPRO_AUTOTUNE", "1")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "at.json"))
    autotune.clear()
    calls = []

    def measure(cfg):
        calls.append(cfg["b"])
        if cfg["b"] == 8:
            raise ValueError("does not compile")  # losing candidate

    cands = [{"b": b} for b in (4, 8)]
    cfg = autotune.best_config("k", (1, 2), cands, measure, {"b": 16})
    assert cfg["b"] == 4 and 8 in calls
    assert autotune.report()["k"]["source"] == "swept"
    calls.clear()
    assert autotune.best_config("k", (1, 2), cands, measure, {"b": 16}) == cfg
    assert not calls                              # memory hit, no re-sweep
    assert autotune.report()["k"]["source"] == "memory"
    autotune.clear()                              # "new process"
    assert autotune.best_config("k", (1, 2), cands, measure, {"b": 16}) == cfg
    assert not calls and autotune.report()["k"]["source"] == "disk"
    # disabled -> caller's default, untimed
    monkeypatch.setenv("REPRO_AUTOTUNE", "0")
    autotune.clear(disk=True)
    assert autotune.best_config("k", (1, 2), cands, measure,
                                {"b": 16}) == {"b": 16}
    assert not calls and autotune.report()["k"]["source"] == "default"
