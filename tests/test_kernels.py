"""Per-kernel validation: shape/dtype sweeps, assert_allclose against the
pure-jnp ref.py oracles (kernels run in interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AnalogConfig
from repro.configs.rram_ps32 import CASE_A, CASE_B


# --------------------------------------------------------------------------- #
# xbar_mac
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("B,K,N", [(128, 128, 128), (256, 384, 128),
                                   (128, 512, 256), (64, 64, 64),
                                   # non-divisible shapes: pad-and-slice path
                                   (100, 70, 130), (65, 64, 63)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_xbar_mac(B, K, N, dtype):
    from repro.kernels.xbar_mac import xbar_mac
    from repro.kernels.xbar_mac.ref import xbar_mac_ref
    key = jax.random.PRNGKey(B + K + N)
    v = jax.random.uniform(key, (B, K), dtype, maxval=0.2)
    g = jax.random.uniform(jax.random.fold_in(key, 1), (K, N), dtype,
                           minval=1e-6, maxval=1e-4)
    out = xbar_mac(v, g, block_b=64, block_n=64, block_k=64)
    ref = xbar_mac_ref(v, g)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


# --------------------------------------------------------------------------- #
# flash_attention
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("B,H,S,D", [(2, 2, 256, 64), (1, 4, 128, 128),
                                     (2, 1, 512, 32)])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 128), (False, 0)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, H, S, D, causal, window, dtype):
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    key = jax.random.PRNGKey(S + D)
    q = jax.random.normal(key, (B, H, S, D), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, H, S, D), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, H, S, D), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=128, block_kv=128)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


# --------------------------------------------------------------------------- #
# linear_scan
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("B,S,D", [(2, 256, 512), (1, 128, 1024), (4, 512, 64)])
@pytest.mark.parametrize("with_h0", [False, True])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_linear_scan(B, S, D, with_h0, dtype):
    from repro.kernels.linear_scan import linear_scan
    from repro.kernels.linear_scan.ref import linear_scan_ref
    key = jax.random.PRNGKey(S)
    a = jax.random.uniform(key, (B, S, D), dtype, minval=0.5, maxval=0.999)
    b = jax.random.normal(jax.random.fold_in(key, 1), (B, S, D), dtype) * 0.1
    h0 = (jax.random.normal(jax.random.fold_in(key, 2), (B, D), dtype)
          if with_h0 else None)
    h, h_last = linear_scan(a, b, h0, block_d=64, block_s=64)
    hr, hr_last = linear_scan_ref(a.astype(jnp.float32),
                                  b.astype(jnp.float32),
                                  None if h0 is None else h0.astype(jnp.float32))
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(h, np.float32),
                               np.asarray(hr, np.float32), rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(h_last, np.float32),
                               np.asarray(hr_last, np.float32),
                               rtol=tol, atol=tol)


# --------------------------------------------------------------------------- #
# emulator_block (fused Conv4Xbar)
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("geom", [CASE_A, CASE_B], ids=lambda g: g.name)
@pytest.mark.parametrize("n", [8, 32])
def test_emulator_block(geom, n):
    from repro.core import conv4xbar
    from repro.kernels.emulator_block import emulator_block
    from repro.models.common import init_params
    key = jax.random.PRNGKey(0)
    schema = conv4xbar.conv4xbar_schema(geom, n_periph=2)
    params = init_params(key, schema)
    x = jax.random.uniform(key, (n,) + (geom.features, geom.tiles,
                                        geom.rows, geom.cols))
    periph = jax.random.uniform(jax.random.fold_in(key, 1), (n, 2))
    out = emulator_block(params, x, periph, geom, block_n=8)
    ref = conv4xbar.apply(params, x, periph)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-6)


@pytest.mark.parametrize("geom", [CASE_A, CASE_B], ids=lambda g: g.name)
@pytest.mark.parametrize("M,NB,NO", [(4, 2, 3), (3, 1, 2)])
def test_emulator_block_grid(geom, M, NB, NO):
    """2-D grid serving kernel: per-block shared conductance features,
    constant (gain=1, off=0) peripherals; matches the paper-faithful apply
    over the equivalent broadcast batch (incl. batch padding M % bm != 0)."""
    from repro.core import conv4xbar
    from repro.kernels.emulator_block import emulator_block_grid
    from repro.models.common import init_params
    key = jax.random.PRNGKey(1)
    schema = conv4xbar.conv4xbar_schema(geom, n_periph=2)
    params = init_params(key, schema)
    D, H, W = geom.tiles, geom.rows, geom.cols
    v = jax.random.uniform(key, (M, NB, D, H))
    g = jax.random.uniform(jax.random.fold_in(key, 1), (NB * NO, D, H, W))
    out = emulator_block_grid(params, v, g, geom, block_m=2)
    assert out.shape == (M, NB * NO, geom.outputs)
    # reference: materialize the batch-broadcast (V, G) channel stack
    vch = jnp.broadcast_to(
        v[:, :, None, :, :, None], (M, NB, NO, D, H, W))
    gch = jnp.broadcast_to(
        g.reshape(NB, NO, D, H, W)[None], (M, NB, NO, D, H, W))
    x = jnp.stack([vch, gch], axis=3).reshape(M * NB * NO, 2, D, H, W)
    periph = jnp.concatenate([jnp.ones((x.shape[0], 1)),
                              jnp.zeros((x.shape[0], 1))], axis=-1)
    ref = conv4xbar.apply(params, x, periph).reshape(M, NB * NO, -1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-6)
