"""Tests for the fleet digital twin: bitwise determinism across chunk
sizes and processes, padded-last-chunk correctness, the compile-once
chunk-executable contract, maintenance (reprogram + recalibrate)
semantics, planner cost-model units on synthetic forecast grids, and
the wear-aware remap policy plumbing."""
import os
import subprocess
import sys
import types
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AnalogConfig
from repro.configs.rram_ps32 import CASE_A
from repro.core import conv4xbar
from repro.core.analog import AnalogExecutor
from repro.fleet import (A_NONE, A_RECAL, A_RETIRE, A_RETRAIN, ActionCosts,
                         Fleet, FleetPlan, FleetSpec, MaintenancePlanner,
                         SurrogateRanker, always_recalibrate_policy,
                         never_policy, simulate_policy)
from repro.fleet.maintenance import _realized_cal_ages
from repro.models.common import init_params
from repro.nonideal import (N_SCENARIO_FEATURES, Scenario, remap_plan,
                            tile_scenarios)

ACFG = AnalogConfig()
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

BASE = Scenario(name="fleet-test", prog_sigma=0.04, read_sigma=0.01,
                p_stuck_off=0.05, drift_nu=0.03, drift_t=0.0)
AGES = (3_600.0, 86_400.0)


def _executor(backend="analytic", conditioned=False):
    kw = {}
    if backend == "emulator":
        n_periph = 2 + (N_SCENARIO_FEATURES if conditioned else 0)
        kw["emulator_params"] = init_params(
            jax.random.PRNGKey(7),
            conv4xbar.conv4xbar_schema(CASE_A, n_periph=n_periph))
        kw["use_pallas"] = False
    return AnalogExecutor(acfg=AnalogConfig(backend=backend), geom=CASE_A,
                          **kw)


def _fleet(n=24, chunk=8, backend="analytic", seed=0, n_probe=8,
           conditioned=False):
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (32, 8)) * 0.2
    ex = _executor(backend, conditioned=conditioned)
    spec = FleetSpec(n_devices=n, base=BASE, chunk=chunk)
    return Fleet(ex, w, "twin", spec, key=jax.random.fold_in(key, 2),
                 n_probe=n_probe)


def _x(seed=0, B=2, K=32):
    return jax.random.normal(jax.random.PRNGKey(100 + seed), (B, K)) * 0.5


def _crc(a: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(a).tobytes())


# --------------------------------------------------------------------- #
# determinism + chunking
# --------------------------------------------------------------------- #
def test_chunk_size_bitwise_determinism():
    """Chunking only regroups per-device computations: any chunk size
    (including non-divisors that force a padded last chunk) yields
    bit-identical per-device errors."""
    x = _x()
    ref = _fleet(n=24, chunk=24).evaluate(x, AGES[0])
    for chunk in (8, 5, 17):
        out = _fleet(n=24, chunk=chunk).evaluate(x, AGES[0])
        assert _crc(out) == _crc(ref), f"chunk={chunk} diverged"


def test_padded_last_chunk_matches_subset_eval():
    """Pad rows (repeats of the final device) must be dropped, never
    leak into results: a partial-id evaluation equals the same rows of
    the full one."""
    fleet = _fleet(n=10, chunk=8)
    x = _x()
    full = fleet.evaluate(x, AGES[1])
    ids = np.array([3, 8, 9], np.int32)
    sub = fleet.evaluate(x, AGES[1], ids=ids)
    np.testing.assert_array_equal(sub, full[ids])


def test_cross_process_bitwise_determinism():
    """A fresh interpreter reproduces the same population bit-for-bit
    (the determinism contract the module docstring promises)."""
    snippet = (
        "import zlib, numpy as np\n"
        "from tests.test_fleet import _fleet, _x, _crc\n"
        "out = _fleet(n=12, chunk=5).evaluate(_x(), 3600.0)\n"
        "print(_crc(out))\n"
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(REPO, "src"), REPO,
         env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    proc = subprocess.run([sys.executable, "-c", snippet], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr
    here = _crc(_fleet(n=12, chunk=5).evaluate(_x(), 3600.0))
    assert int(proc.stdout.strip().splitlines()[-1]) == here


def test_compile_once_across_ages_and_cal_cohorts():
    """Ages and maintenance epochs are traced operands: a whole campaign
    (every age x cohort combination) reuses ONE chunk executable."""
    fleet = _fleet(n=16, chunk=8)
    x = _x()
    rng = np.random.default_rng(0)
    for t in (0.0,) + AGES:
        fleet.evaluate(x, t)
        fleet.evaluate(x, t, cal_age=t)
        fleet.evaluate(x, t,
                       cal_age=rng.choice([0.0, t], size=16).astype(
                           np.float32))
    assert fleet.cache_size() == 1


def test_requires_unit_line_resistance():
    ex = _executor()
    sc = Scenario(name="ir", r_line_scale=3.0)
    spec = FleetSpec(n_devices=4, base=sc, chunk=4)
    with pytest.raises(ValueError, match="r_line"):
        Fleet(ex, jnp.ones((32, 8)) * 0.1, "bad", spec,
              key=jax.random.PRNGKey(0))


# --------------------------------------------------------------------- #
# maintenance (reprogram + recalibrate) semantics
# --------------------------------------------------------------------- #
def test_maintained_device_beats_stale_device():
    """cal_age = age means the array was rewritten and recalibrated at
    the serving checkpoint: the drift clock reset must pull the error
    back to the deployment floor, below the never-maintained device."""
    fleet = _fleet(n=32, chunk=16)
    x = _x()
    t = 2_592_000.0
    fresh = fleet.evaluate(x, t, cal_age=t)
    stale = fleet.evaluate(x, t, cal_age=0.0)
    floor = fleet.evaluate(x, 0.0)
    assert np.median(fresh) < np.median(stale)
    assert np.median(fresh) < 2.0 * np.median(floor)


def test_conditioned_fleet_runs_and_is_deterministic():
    """The conditioned-emulator path (per-tile feature operands) keeps
    the same determinism + compile-once contracts."""
    fa = _fleet(n=8, chunk=8, backend="emulator", conditioned=True)
    fb = _fleet(n=8, chunk=3, backend="emulator", conditioned=True)
    assert fa.ex.emulator_conditioned
    x = _x()
    a, b = fa.evaluate(x, AGES[0]), fb.evaluate(x, AGES[0])
    assert _crc(a) == _crc(b)
    assert fa.cache_size() == 1


# --------------------------------------------------------------------- #
# planner cost model (synthetic forecast grids -> exact DP units)
# --------------------------------------------------------------------- #
def _stub_planner(E, timeline=AGES, **kw):
    """Planner over a synthetic E[d, i, j] grid, no fleet evaluation."""
    n = E.shape[0]
    stub = types.SimpleNamespace(
        spec=types.SimpleNamespace(n_devices=n, base=BASE), tag="stub")
    planner = MaintenancePlanner(fleet=stub, timeline=list(timeline), **kw)
    planner._forecast_grid = lambda x: np.asarray(E, np.float32)
    return planner


def test_planner_healthy_device_does_nothing():
    E = np.full((3, 2, 3), 0.01, np.float32)
    plan = _stub_planner(E, slo=0.1).plan(None)
    assert (plan.actions == A_NONE).all()
    assert plan.expected_cost == 0.0


def test_planner_recalibrates_transient_drift():
    """Stale forecasts violate, freshly maintained ones don't: one
    recalibration (cost 1) beats eating the penalty (25) or retiring
    (40)."""
    E = np.full((2, 2, 3), 0.5, np.float32)
    E[:, 0, 1] = 0.02                       # maintained at t1, serve t1
    E[:, 1, 2] = 0.02                       # maintained at t2, serve t2
    E[:, 1, 1] = 0.02                       # t1 write still fresh at t2
    plan = _stub_planner(E, slo=0.1).plan(None)
    assert (plan.actions[:, 0] == A_RECAL).all()
    assert not (plan.actions == A_RETIRE).any()
    assert plan.expected_cost == pytest.approx(2 * 1.0)  # one recal each


def test_planner_retires_persistent_violation():
    """When even a fresh rewrite forecasts above SLO at every remaining
    checkpoint, the one-time retire cost undercuts the penalty stream
    (3 x 25 > 40)."""
    E = np.full((1, 3, 4), 0.9, np.float32)
    plan = _stub_planner(E, timeline=(1.0, 2.0, 3.0), slo=0.1).plan(None)
    assert plan.actions[0, 0] == A_RETIRE
    assert plan.expected_cost == pytest.approx(ActionCosts().retire)


def test_planner_never_retrains_under_conditioned_gain():
    rng = np.random.default_rng(3)
    E = rng.uniform(0.0, 0.6, size=(16, 2, 3)).astype(np.float32)
    plan = _stub_planner(E, slo=0.1, retrain_gain=1.0).plan(None)
    assert not (plan.actions == A_RETRAIN).any()


def test_planner_wear_horizon_decision():
    E = np.full((2, 2, 3), 0.01, np.float32)
    plan = _stub_planner(E, slo=0.1).plan(None)
    assert plan.remap_horizon == AGES       # stuck-off + drift corner
    quiet = types.SimpleNamespace(
        spec=types.SimpleNamespace(
            n_devices=2,
            base=Scenario(name="nodrift", p_stuck_off=0.05)), tag="s")
    planner = MaintenancePlanner(fleet=quiet, timeline=list(AGES))
    assert planner._choose_remap_horizon() is None


def test_realized_cal_ages_and_cohorts():
    acts = np.array([[A_NONE, A_RECAL, A_NONE],
                     [A_RECAL, A_NONE, A_RETRAIN],
                     [A_NONE, A_NONE, A_NONE]], np.int8)
    tl = (10.0, 20.0, 30.0)
    cal = _realized_cal_ages(acts, tl)
    np.testing.assert_array_equal(
        cal, np.array([[0, 20, 20], [10, 10, 30], [0, 0, 0]], np.float32))
    plan = FleetPlan(timeline=tl, actions=acts, expected_cost=0.0)
    c0 = plan.cohorts(0)
    np.testing.assert_array_equal(c0["none"], [0, 2])
    np.testing.assert_array_equal(c0["recalibrate"], [1])
    assert "retire" not in c0


def test_baseline_policies_shapes():
    nv = never_policy(5, AGES)
    al = always_recalibrate_policy(5, AGES)
    assert nv.shape == al.shape == (5, len(AGES))
    assert (nv == A_NONE).all() and (al == A_RECAL).all()


def test_simulate_policy_costs_and_retire_semantics():
    """Retired devices book one retire cost, then leave the error pool
    (accuracy 1.0) and act no further; recal costs accumulate per
    device-checkpoint; SLO violations price in."""
    fleet = _fleet(n=8, chunk=8)
    x = _x()
    costs = ActionCosts()
    acts = never_policy(8, AGES)
    acts[0, 0] = A_RETIRE
    acts[1, :] = A_RECAL
    out = simulate_policy(fleet, x, AGES, acts, costs, slo=1e9)
    assert len(out) == len(AGES)
    assert out[0]["retired"] == out[1]["retired"] == 1
    # slo=1e9 -> no penalties: cost is purely the action table
    assert out[0]["action_cost"] == pytest.approx(costs.retire
                                                 + costs.recalibrate)
    assert out[1]["action_cost"] == pytest.approx(costs.recalibrate)
    assert out[1]["cum_cost"] == pytest.approx(
        costs.retire + 2 * costs.recalibrate)
    viol = simulate_policy(fleet, x, AGES, never_policy(8, AGES), costs,
                           slo=-1.0)       # every live device violates
    assert viol[0]["violations"] == 8
    assert viol[0]["cum_cost"] == pytest.approx(8 * costs.slo_penalty)


# --------------------------------------------------------------------- #
# forecasting surrogate
# --------------------------------------------------------------------- #
def test_surrogate_ranker_fits_and_predicts():
    fleet = _fleet(n=16, chunk=8)
    x = _x()
    ranker = SurrogateRanker().fit(fleet, x, list(AGES), n_probe=8)
    assert np.isfinite(ranker.train_pinball)
    ids = np.arange(16, dtype=np.int32)
    pred = ranker.predict(fleet, ids, AGES[1], cal_age=0.0)
    assert pred.shape == (16,) and np.isfinite(pred).all()
    # reprogram semantics: a freshly maintained device must be forecast
    # strictly below the same device served stale from deployment
    fresh = ranker.predict(fleet, ids, AGES[1], cal_age=AGES[1])
    assert np.median(fresh) < np.median(pred)
    # one compiled executable even after the probe grid
    assert fleet.cache_size() == 1


# --------------------------------------------------------------------- #
# wear-aware remapping policy (fleet-level satellite)
# --------------------------------------------------------------------- #
def test_remap_horizon_none_bit_identical():
    """horizon=None must reproduce the instantaneous remapper exactly
    (the planner's 'not wear-aware' arm is the legacy behavior)."""
    ex = _executor()
    w = jax.random.normal(jax.random.PRNGKey(5), (32, 8)) * 0.3
    plan = ex._plan_for(w, "wear")
    sc = tile_scenarios(plan.NB, plan.NO, name="corner", p_stuck_off=0.2,
                        drift_nu=0.03)
    key = jax.random.PRNGKey(11)
    base, operm = remap_plan(plan, ACFG, sc, key)
    none, nperm = remap_plan(plan, ACFG, sc, key, horizon=None)
    np.testing.assert_array_equal(np.asarray(operm), np.asarray(nperm))
    np.testing.assert_array_equal(np.asarray(base.g_feat),
                                  np.asarray(none.g_feat))
    wear, wperm = remap_plan(plan, ACFG, sc, key, horizon=AGES)
    assert np.array_equal(np.sort(np.asarray(wperm)), np.arange(plan.N))
