"""Test-suite plumbing.

Two pieces live here:

  * ``run_multidevice`` -- the one way this suite runs anything on more
    than one device.  XLA's host-device count is locked at first jax
    init, so multi-device behaviour (sharded training, the
    tensor-parallel analog serving plane, collectives) is exercised in a
    subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_count``
    forced in its environment.  Tests import it with
    ``from conftest import run_multidevice``.

  * a deterministic ``hypothesis`` stand-in.  The container may lack
    ``hypothesis``; the property tests only use a small slice of its API
    (given / settings / integers / floats / sampled_from / booleans), so
    when the real package is missing we install a stub that runs each
    property test over a fixed number of seeded samples.  The stub's
    ``given`` wrapper advertises only the test's NON-strategy parameters
    via ``__signature__``, so pytest still injects fixtures into
    property tests exactly as real hypothesis does.  This keeps
    ``pytest -x`` collecting (and every test running) everywhere.
"""
import inspect
import os
import subprocess
import sys
import types

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_multidevice(script: str, n_devices: int = 8,
                    timeout: float = 900.0) -> str:
    """Run ``script`` under ``sys.executable`` with ``n_devices`` forced
    host devices; returns its stdout.

    The child gets ``src`` on PYTHONPATH and
    ``--xla_force_host_platform_device_count=<n_devices>`` prepended to
    XLA_FLAGS (set BEFORE jax ever imports -- the whole reason for the
    subprocess).  A non-zero exit raises ``AssertionError`` carrying the
    captured stdout/stderr tails, so a failing child script reads like a
    failing test."""
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(REPO_ROOT, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={int(n_devices)} "
        + env.get("XLA_FLAGS", ""))
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=timeout,
                       cwd=REPO_ROOT)
    if r.returncode != 0:
        raise AssertionError(
            f"multi-device subprocess failed (exit {r.returncode})\n"
            f"--- stdout (tail) ---\n{r.stdout[-4000:]}\n"
            f"--- stderr (tail) ---\n{r.stderr[-6000:]}")
    return r.stdout


try:  # pragma: no cover - exercised only when hypothesis is installed
    import hypothesis  # noqa: F401
except ImportError:
    import numpy as _np

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def sample(self, rng):
            return self._sample(rng)

    def integers(min_value, max_value):
        return _Strategy(lambda rng: int(rng.integers(min_value,
                                                      max_value + 1)))

    def floats(min_value, max_value, **_kw):
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    def sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])

    def booleans():
        return _Strategy(lambda rng: bool(rng.integers(2)))

    def given(**strategies):
        def deco(fn):
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_hyp_max_examples", 10)
                rng = _np.random.default_rng(0)
                for _ in range(n):
                    drawn = {k: s.sample(rng) for k, s in strategies.items()}
                    fn(*args, **drawn, **kwargs)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            # advertise only the non-strategy parameters: pytest reads
            # __signature__ to decide which fixtures to inject, exactly
            # as it does for real hypothesis' wrapper
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items()
                if name not in strategies])
            wrapper._hyp_max_examples = 10
            return wrapper
        return deco

    def settings(max_examples=10, deadline=None, **_kw):
        def deco(fn):
            if hasattr(fn, "_hyp_max_examples"):
                fn._hyp_max_examples = min(max_examples, 25)
            return fn
        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = integers
    _st.floats = floats
    _st.sampled_from = sampled_from
    _st.booleans = booleans

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = given
    _hyp.settings = settings
    _hyp.strategies = _st
    _hyp.__is_repro_stub__ = True

    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
