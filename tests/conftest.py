"""Test-suite plumbing.

The container may lack ``hypothesis``; the property tests only use a small
slice of its API (given / settings / integers / floats / sampled_from), so
when the real package is missing we install a deterministic stand-in that
runs each property test over a fixed number of seeded samples.  This keeps
``pytest -x`` collecting (and the non-property tests running) everywhere.
"""
import sys
import types

try:  # pragma: no cover - exercised only when hypothesis is installed
    import hypothesis  # noqa: F401
except ImportError:
    import numpy as _np

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def sample(self, rng):
            return self._sample(rng)

    def integers(min_value, max_value):
        return _Strategy(lambda rng: int(rng.integers(min_value,
                                                      max_value + 1)))

    def floats(min_value, max_value, **_kw):
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    def sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])

    def booleans():
        return _Strategy(lambda rng: bool(rng.integers(2)))

    def given(**strategies):
        def deco(fn):
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_hyp_max_examples", 10)
                rng = _np.random.default_rng(0)
                for _ in range(n):
                    drawn = {k: s.sample(rng) for k, s in strategies.items()}
                    fn(*args, **drawn, **kwargs)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper._hyp_max_examples = 10
            return wrapper
        return deco

    def settings(max_examples=10, deadline=None, **_kw):
        def deco(fn):
            if hasattr(fn, "_hyp_max_examples"):
                fn._hyp_max_examples = min(max_examples, 25)
            return fn
        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = integers
    _st.floats = floats
    _st.sampled_from = sampled_from
    _st.booleans = booleans

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = given
    _hyp.settings = settings
    _hyp.strategies = _st
    _hyp.__is_repro_stub__ = True

    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
