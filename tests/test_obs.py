"""Telemetry subsystem (repro.obs, docs/observability.md):

  * registry semantics: label series, gauge set/add, histogram bucket
    edges, kind-conflict rejection, thread-safety under a
    ``ThreadPoolExecutor``;
  * disabled mode really is a no-op: ``NULL_SPAN``, nothing recorded,
    instrumented hot paths leave the registry empty;
  * the neutrality contract: with telemetry ON the executor's jit trace
    counts AND the f32 outputs are bit-identical to telemetry OFF;
  * exporters round-trip: JSON snapshot -> Prometheus text -> parsed
    values; ``diff_snapshots`` zeroes counters against themselves;
  * ``RecompileSentinel`` passes a compile-once block and raises
    ``RecompileError`` (strict) on a shape-churn recompile;
  * end-to-end: a ServeSession + lifetime walk under telemetry exports a
    snapshot that validates against tools/telemetry_schema.json.
"""
import json
import os
import sys
import threading
from concurrent.futures import ThreadPoolExecutor

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.obs import (DEFAULT_BUCKETS, NULL_SPAN, OBS, MetricsRegistry,
                       RecompileError, RecompileSentinel, Telemetry,
                       diff_snapshots, parse_prometheus, snapshot,
                       to_prometheus, write_snapshot)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "tools"))


@pytest.fixture
def obs_enabled():
    """Enable the process singleton for one test, then restore it to the
    pristine disabled state (other tests rely on disabled-by-default)."""
    OBS.reset()
    OBS.enable()
    yield OBS
    OBS.reset()
    OBS.disable()


# --------------------------------------------------------------------------- #
# registry semantics
# --------------------------------------------------------------------------- #
def test_counter_labels_and_aggregation():
    reg = MetricsRegistry()
    reg.counter("req_total", "requests", site="a").inc()
    reg.counter("req_total", site="a").inc(2)
    reg.counter("req_total", site="b").inc()
    series = reg.snapshot()["metrics"]["req_total"]["series"]
    by_site = {s["labels"]["site"]: s["value"] for s in series}
    assert by_site == {"a": 3.0, "b": 1.0}


def test_counter_rejects_negative():
    reg = MetricsRegistry()
    with pytest.raises(ValueError):
        reg.counter("n_total").inc(-1)


def test_gauge_set_and_add():
    reg = MetricsRegistry()
    g = reg.gauge("age_seconds", tag="t")
    g.set(5.0)
    g.add(2.0)
    g.set(3.5)
    (s,) = reg.snapshot()["metrics"]["age_seconds"]["series"]
    assert s["value"] == 3.5


def test_histogram_bucket_edges_inclusive():
    """Prometheus ``le`` semantics: a value equal to a bucket boundary
    counts into that bucket, not the next."""
    reg = MetricsRegistry()
    h = reg.histogram("lat_seconds", buckets=(0.1, 1.0))
    for v in (0.05, 0.1, 0.5, 1.0, 99.0):
        h.observe(v)
    (s,) = reg.snapshot()["metrics"]["lat_seconds"]["series"]
    assert s["bucket_counts"] == [2, 2, 1]       # le=0.1, le=1.0, +Inf
    assert s["count"] == 5
    assert s["min"] == 0.05 and s["max"] == 99.0
    assert s["sum"] == pytest.approx(100.65)


def test_kind_conflict_raises():
    reg = MetricsRegistry()
    reg.counter("x_total").inc()
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("x_total")


def test_thread_safety_under_pool():
    """N threads hammering one counter / one histogram series must lose
    no increments (one lock per metric)."""
    reg = MetricsRegistry()
    n_threads, per_thread = 8, 500

    def work(i):
        for _ in range(per_thread):
            reg.counter("hits_total", worker="shared").inc()
            reg.histogram("t_seconds", worker="shared").observe(1e-3)
        return i

    with ThreadPoolExecutor(n_threads) as pool:
        list(pool.map(work, range(n_threads)))
    met = reg.snapshot()["metrics"]
    (c,) = met["hits_total"]["series"]
    (h,) = met["t_seconds"]["series"]
    assert c["value"] == n_threads * per_thread
    assert h["count"] == n_threads * per_thread
    assert h["sum"] == pytest.approx(n_threads * per_thread * 1e-3)


# --------------------------------------------------------------------------- #
# disabled mode
# --------------------------------------------------------------------------- #
def test_disabled_span_is_shared_null():
    t = Telemetry(enabled=False)
    s = t.span("anything", site="x")
    assert s is NULL_SPAN
    with s:                                       # no-op context manager
        pass
    assert t.snapshot()["metrics"] == {}


def test_disabled_hot_path_records_nothing():
    """The instrumented executor path must leave the registry untouched
    while OBS is disabled (the hooks are one attribute check)."""
    assert not OBS.enabled                        # suite default
    OBS.reset()
    ex = _executor()
    x, w = _data()
    ex.calibrate(jax.random.PRNGKey(3), w, "quiet", n=4)
    np.asarray(ex.matmul(x, w, "quiet"))
    assert OBS.snapshot()["metrics"] == {}


# --------------------------------------------------------------------------- #
# exporters
# --------------------------------------------------------------------------- #
def _sample_registry():
    reg = MetricsRegistry()
    reg.counter("req_total", "requests served", site="a#0").inc(3)
    reg.gauge("age_seconds", "drift age", tag='t"x').set(42.5)
    h = reg.histogram("lat_seconds", "latency", buckets=(0.01, 0.1),
                      site="a#0")
    h.observe(0.005)
    h.observe(0.05)
    h.observe(5.0)
    return reg


def test_json_snapshot_roundtrip(tmp_path):
    reg = _sample_registry()
    path = tmp_path / "snap.json"
    write_snapshot(str(path), registry=reg)
    doc = json.loads(path.read_text())
    assert doc == reg.snapshot()
    assert doc["schema"] == 1


def test_prometheus_roundtrip():
    """JSON snapshot -> text exposition -> parsed samples, including a
    label value with an embedded quote and cumulative histogram series."""
    snap = _sample_registry().snapshot()
    text = to_prometheus(snap)
    vals = parse_prometheus(text)
    assert vals[("req_total", frozenset({("site", "a#0")}))] == 3.0
    assert vals[("age_seconds", frozenset({("tag", 't"x')}))] == 42.5
    buckets = {k: v for k, v in vals.items() if k[0] == "lat_seconds_bucket"}
    by_le = {dict(k[1])["le"]: v for k, v in buckets.items()}
    assert by_le == {"0.01": 1.0, "0.1": 2.0, "+Inf": 3.0}   # cumulative
    assert vals[("lat_seconds_count", frozenset({("site", "a#0")}))] == 3.0
    assert vals[("lat_seconds_sum",
                 frozenset({("site", "a#0")}))] == pytest.approx(5.055)


def test_diff_snapshots_zeroes_counters():
    reg = _sample_registry()
    base = reg.snapshot()
    d = diff_snapshots(base, reg.snapshot())
    assert d["diff"] is True
    (c,) = d["metrics"]["req_total"]["series"]
    assert c["value"] == 0.0
    (h,) = d["metrics"]["lat_seconds"]["series"]
    assert h["count"] == 0 and h["bucket_counts"] == [0, 0, 0]
    # gauges pass through as the later value
    (g,) = d["metrics"]["age_seconds"]["series"]
    assert g["value"] == 42.5


# --------------------------------------------------------------------------- #
# neutrality: telemetry on/off changes neither traces nor bits
# --------------------------------------------------------------------------- #
def _executor(backend="analytic"):
    from repro.configs.base import AnalogConfig
    from repro.configs.rram_ps32 import CASE_A
    from repro.core.analog import AnalogExecutor
    return AnalogExecutor(acfg=AnalogConfig(backend=backend), geom=CASE_A,
                          use_pallas=False)


def _data(K=70, N=8, B=4, seed=0):
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (K, N)) * 0.3
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, K)) * 0.5
    return x, w


def _exercise(ex, x, w):
    """A deploy -> calibrate -> matmul -> age sequence touching every
    instrumented analog path; returns (outputs, per-tag trace counts)."""
    from repro.nonideal import Scenario, scenario_at_age
    ys = []
    ex.calibrate(jax.random.PRNGKey(3), w, "par", n=4)
    ys.append(np.asarray(ex.matmul(x, w, "par")))
    sc = Scenario(name="par", prog_sigma=0.05)
    ex.deploy(scenario=sc, key=jax.random.PRNGKey(5))
    ys.append(np.asarray(ex.matmul(x, w, "par")))
    ex.deploy(scenario=scenario_at_age(sc, 3600.0))
    ys.append(np.asarray(ex.matmul(x, w, "par")))
    traces = {tag: ent[2]._cache_size() for tag, ent in ex._fns.items()}
    return ys, traces


def test_telemetry_is_trace_and_bit_neutral(obs_enabled):
    """The gate on the whole design: identical jit trace counts and
    bit-identical f32 outputs with telemetry on vs off."""
    x, w = _data()
    OBS.disable()
    ys_off, traces_off = _exercise(_executor(), x, w)
    assert OBS.snapshot()["metrics"] == {}        # really was off
    OBS.enable()
    ys_on, traces_on = _exercise(_executor(), x, w)
    assert traces_on == traces_off
    for a, b in zip(ys_off, ys_on):
        assert np.array_equal(a, b)
    # and the enabled run did record the instrumented path
    met = OBS.snapshot()["metrics"]
    assert "analog_plan_cache_total" in met
    assert "analog_matmul_calls_total" in met
    assert "analog_traces_total" in met
    assert "analog_calibration_residual" in met


def test_enabled_counters_match_ground_truth(obs_enabled):
    """analog_traces_total must agree with jit's own executable count."""
    x, w = _data()
    ex = _executor()
    for _ in range(3):                            # same shape: one trace
        np.asarray(ex.matmul(x, w, "ct"))
    met = OBS.snapshot()["metrics"]
    traced = sum(s["value"]
                 for s in met["analog_traces_total"]["series"]
                 if s["labels"]["tag"] == "ct")
    assert traced == ex._fns["ct"][2]._cache_size() == 1
    calls = sum(s["value"]
                for s in met["analog_matmul_calls_total"]["series"]
                if s["labels"]["tag"] == "ct")
    assert calls == 3


# --------------------------------------------------------------------------- #
# RecompileSentinel
# --------------------------------------------------------------------------- #
def test_sentinel_passes_compile_once_block():
    fn = jax.jit(lambda a: a * 2.0)
    x = jnp.ones((4, 4))
    with RecompileSentinel(fns=[fn], label="ok") as sent:
        for _ in range(5):
            fn(x).block_until_ready()
    assert sent.ok
    assert sent.new_counts == {"fn[0]": 1}


def test_sentinel_strict_raises_on_recompile():
    fn = jax.jit(lambda a: a * 2.0)
    with pytest.raises(RecompileError, match="fn\\[0\\]"):
        with RecompileSentinel(fns=[fn], label="churn"):
            fn(jnp.ones((2, 2)))
            fn(jnp.ones((3, 3)))                  # second shape: recompile
    # non-strict records the verdict instead of raising
    fn2 = jax.jit(lambda a: a + 1.0)
    with RecompileSentinel(fns=[fn2], strict=False) as sent:
        fn2(jnp.ones((2, 2)))
        fn2(jnp.ones((3, 3)))
    assert sent.ok is False
    assert sent.violations == {"fn[0]": 2}


def test_sentinel_watches_executor_tags_created_inside():
    x, w = _data()
    ex = _executor()
    with RecompileSentinel(executor=ex, label="exec") as sent:
        np.asarray(ex.matmul(x, w, "new_tag"))    # tag born in the block
    assert sent.ok
    assert sent.new_counts == {"executor.unified[new_tag]": 1}


def test_sentinel_records_outcome_metric(obs_enabled):
    fn = jax.jit(lambda a: a - 1.0)
    with RecompileSentinel(fns=[fn], strict=False, label="ci"):
        fn(jnp.ones((2,)))
        fn(jnp.ones((3,)))
    met = OBS.snapshot()["metrics"]
    (s,) = [r for r in met["obs_sentinel_checks_total"]["series"]
            if r["labels"]["label"] == "ci"]
    assert s["labels"]["outcome"] == "violation" and s["value"] == 1.0


# --------------------------------------------------------------------------- #
# end-to-end: serve + lifetime under telemetry, validated against schema
# --------------------------------------------------------------------------- #
def test_serve_snapshot_validates_against_schema(obs_enabled, tmp_path):
    """A short ServeSession + lifetime walk + autotune resolution under
    telemetry must export a snapshot that passes the checked-in CI schema
    (tools/telemetry_schema.json) and carries the fleet health gauges."""
    import check_telemetry
    from repro.kernels import autotune
    from repro.launch.serve import ServeSession
    from repro.nonideal import LifetimeScheduler, Scenario

    ex = _executor()
    sess = ServeSession("gemma3-1b", reduced=True, reduced_layers=2,
                        batch=2, prompt_len=8, gen=4, seed=0, executor=ex)
    with RecompileSentinel(session=sess, executor=ex, strict=False,
                           label="test-serve"):
        sess.calibrate(n=4)
        sess.generate()

    sched = LifetimeScheduler(ex, Scenario(name="fleet", prog_sigma=0.03,
                                           drift_nu=0.05),
                              timeline=(("1h", 3600.0),), calib_n=8)
    _, w = _data()
    sched.run(w, "fleet", _data()[0])

    autotune.best_config("obs_test", (1,), [], None, {"block_m": 8})

    path = tmp_path / "snap.json"
    write_snapshot(str(path))
    snap = json.loads(path.read_text())
    with open(os.path.join(REPO, "tools", "telemetry_schema.json")) as f:
        schema = json.load(f)
    errs = check_telemetry.check(snap, schema)
    assert not errs, "\n".join(errs)

    met = snap["metrics"]
    # per-site latency histograms with observations
    for name in ("serve_prefill_seconds", "serve_decode_seconds"):
        (s,) = met[name]["series"]
        assert s["count"] >= 1 and "#" in s["labels"]["site"]
    # cache hit/miss counters
    events = {s["labels"]["event"]
              for s in met["analog_plan_cache_total"]["series"]}
    assert "miss" in events and "hit" in events
    sources = {s["labels"]["source"]
               for s in met["autotune_resolutions_total"]["series"]}
    assert sources & {"default", "memory", "disk", "swept"}
    # fleet health gauges from the lifetime walk
    ages = {s["labels"]["tag"]: s["value"]
            for s in met["lifetime_drift_age_seconds"]["series"]}
    assert ages["fleet"] == 3600.0
    ev = {s["labels"]["event"]: s["value"]
          for s in met["lifetime_events_total"]["series"]}
    assert ev["deploy"] == 1 and ev["checkpoint"] == 1
    assert ev["recalibrate"] == 2                 # cold + 1h refit
    assert met["analog_calibration_residual"]["series"]
