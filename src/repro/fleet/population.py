"""Fleet population: N fabricated devices from one key, evaluated in chunks.

A fleet is defined by a ``FleetSpec`` (population size, the base device
corner, fab-spread magnitudes) and one fabrication key.  Device ``d`` is
materialized lazily from ``fold_in(fleet_key, d)``:

  fab draw        -- per-tile lognormal multipliers on the base corner's
                     programming sigma, read sigma, stuck-off rate and
                     drift exponent (the (NB, NO) scenario lattice:
                     die-position heterogeneity, different per device);
  deterministic drift -- the device at age ``t`` is the SAME draw with
                     ``drift_t`` rewritten, so trajectories are exact
                     replays, not stochastic walks.

``Fleet.evaluate`` pushes any slice of the population through the
serving executor's unified forward as vmapped chunks of a FIXED size
(the last chunk is padded and the pad rows dropped), with per-device
maintenance epoch (``cal_age``) as a traced operand -- so a whole
maintenance campaign (every age x maintenance-cohort combination, for a
million devices) reuses exactly ONE compiled chunk executable.
``cal_age = tc`` means the device was last MAINTAINED at ``tc``
seconds: its array was reprogrammed (a fresh programming draw for that
epoch, drift clock reset -- stuck cells persist, they are fab defects)
and its affine recalibrated against the probe batch right after the
write.  Serving at age ``t`` then sees ``t - tc`` seconds of retention
drift on that epoch's write -- the dominant lifetime failure mode
(docs/lifetime.md), modeled exactly.  Each device is scored by the
relative error of its calibrated output against the IDEAL device
through the same backend (the day-zero ground truth,
``bench_lifetime``'s convention -- scoring against the backend's own
ideal output cancels the shared model floor).

Determinism contract (tests/test_fleet.py): results are bitwise
reproducible across chunk sizes and across processes -- chunking only
regroups the same per-device computations, and every random quantity
derives from ``fold_in(fleet_key, device_id)``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.deployment import DeploymentState
from repro.nonideal.perturb import (_broadcast_scenario, perturb_plan,
                                    realized_fault_masks)
from repro.nonideal.scenario import (N_SCENARIO_FEATURES, Scenario,
                                     scenario_features_tiled)
from repro.obs import OBS


@dataclass(frozen=True)
class FleetSpec:
    """Shape of a device population.

    Attributes:
      n_devices:    population size N.
      base:         the nominal device corner every instance is drawn
                    around (scalar or per-tile ``tile_scenarios``).
      sigma_spread: lognormal spread of per-tile programming/read sigma
                    multipliers (0 = every device identical in sigma).
      nu_spread:    lognormal spread of per-tile drift exponents -- fab
                    lots that age at different rates.
      fault_spread: lognormal spread of per-tile stuck-off rates.
      chunk:        devices per compiled chunk (the ONE executable's
                    batch size; memory high-water mark scales with it,
                    never with ``n_devices``).
    """
    n_devices: int
    base: Scenario
    sigma_spread: float = 0.25
    nu_spread: float = 0.25
    fault_spread: float = 0.25
    chunk: int = 256


class Fleet:
    """Chunk-compiled population evaluation of ``ex.matmul``-equivalent
    serving error for every device in a ``FleetSpec``.

    Like ``nonideal.ScenarioSweep``, the executor's own deployment state
    is bypassed: each device's corner, conductance draw, read key,
    scenario features and in-trace-fitted calibration affine are built
    per vmap lane from the device key.  The executor contributes the
    cached conductance plan, the (possibly conditioned) emulator params
    and the backend forward.  Static circuit parameters cannot vary per
    device, so the base corner must keep ``r_line_scale == 1.0``.
    """

    def __init__(self, ex, w: jax.Array, tag: str, spec: FleetSpec,
                 key: Optional[jax.Array] = None, n_probe: int = 16):
        if spec.base.r_line_scale != 1.0:
            raise ValueError(
                "Fleet populations vary traced scenario fields only; "
                "r_line_scale is a static of the circuit backend "
                "(see ScenarioSweep)")
        self.ex = ex
        self.w = w.astype(jnp.float32)
        self.tag = tag
        self.spec = spec
        self.key = jax.random.PRNGKey(0) if key is None else key
        self.n_probe = int(n_probe)
        self.trace_count = 0
        self._fn = None
        self._feat_fn = None
        # the in-trace calibration probe batch is part of the fleet
        # identity: fixed at construction, same for every device
        self._xp = jax.random.normal(
            jax.random.fold_in(self.key, 0xF1EE7), (self.n_probe, w.shape[0]),
        ) * 0.5
        if OBS.enabled:
            OBS.gauge("fleet_devices_total",
                      "population size of the active fleet",
                      tag=tag).set(float(spec.n_devices))

    # ------------------------------------------------------------------ #
    # per-device materialization (traced)
    # ------------------------------------------------------------------ #
    def _device_scenario(self, k: jax.Array, nb: int, no: int) -> Scenario:
        """The fab draw: device ``k``'s per-tile scenario lattice.

        Lognormal multipliers keep every leaf positive and the base
        corner the population median; a zero spread collapses the
        population to N identical devices (useful for isolating the
        conductance-draw variance)."""
        sp = self.spec
        base = _broadcast_scenario(sp.base, (nb, no))
        ks, kr, kn, kf = jax.random.split(k, 4)
        logn = lambda kk, s: jnp.exp(
            s * jax.random.normal(kk, (nb, no), jnp.float32))
        return dataclasses.replace(
            base,
            prog_sigma=base.prog_sigma * logn(ks, sp.sigma_spread),
            read_sigma=base.read_sigma * logn(kr, sp.sigma_spread),
            drift_nu=base.drift_nu * logn(kn, sp.nu_spread),
            p_stuck_off=jnp.clip(
                base.p_stuck_off * logn(kf, sp.fault_spread), 0.0, 0.5))

    def _build(self):
        from repro.core.analog import _st_matmul_u
        ex, w, tag = self.ex, self.w, self.tag
        fleet_key = self.key

        def fwd(x2, xp, ids, age, cal_age):
            self.trace_count += 1          # trace-time side effect, by design
            plan = ex._plan_for(w, tag)    # concrete w -> cached, baked
            nb, no = plan.NB, plan.NO
            ep = (ex.emulator_params
                  if ex.acfg.backend == "emulator"
                  and ex.emulator_params is not None else {})
            conditioned = getattr(ex, "emulator_conditioned", False)
            operm = jnp.arange(plan.N, dtype=jnp.int32)

            # ground truth: the IDEAL device through the same backend --
            # the day-zero computation lifetime management tries to
            # preserve (benchmarks/bench_lifetime.py's convention).
            # Scoring against the backend's own ideal output cancels the
            # shared model floor, which would otherwise swamp the aging
            # signal (the circuit -- and the emulator trained on it --
            # deviates from the digital product by design: IR drop,
            # nonlinearity).
            st0 = DeploymentState.ideal(plan, eparams=ep)
            yp_ref = _st_matmul_u(ex, tag, xp, w, st0)   # probe labels
            y_ref = _st_matmul_u(ex, tag, x2, w, st0)

            def fit_affine(yc):
                # recalibration restores the day-zero mapping: fit the
                # device's probe volts to the ideal reference labels in
                # x_scale-normalized units, ex.calibrate's mechanism
                # (the affine is applied pre-scale by the unified
                # forward).  Device ~= perturbed ideal, so the fit is
                # well-conditioned in every backend regime -- unlike a
                # fit against the digital product, which degenerates to
                # noise once the backend's model floor dominates.
                xsp = jnp.maximum(jnp.max(jnp.abs(xp)), 1e-9)
                yv, yd = (yc / xsp).ravel(), (yp_ref / xsp).ravel()
                vm, dm = yv.mean(), yd.mean()
                var = jnp.maximum(((yv - vm) ** 2).mean(), 1e-12)
                a = ((yv - vm) * (yd - dm)).mean() / var
                return a, dm - a * vm

            live = plan.g_feat > 0.0       # padded lattice sites stay 0

            def state_at(scen: Scenario, age, kp, kf, kr) -> DeploymentState:
                # ``age`` is seconds SINCE PROGRAMMING (the drift clock
                # resets when the array is rewritten); ``kp`` keys the
                # programming draw of that epoch, ``kf`` the fab draw --
                # stuck cells are permanent defects, so they come from
                # the device key no matter how often we reprogram
                aged = dataclasses.replace(
                    scen, drift_t=jnp.full((nb, no), age, jnp.float32))
                nofault = dataclasses.replace(
                    aged, p_stuck_on=jnp.zeros((nb, no), jnp.float32),
                    p_stuck_off=jnp.zeros((nb, no), jnp.float32))
                p = perturb_plan(plan, ex.acfg, nofault, kp)
                on, off = realized_fault_masks(plan, aged, kf)
                gf = jnp.where(live & on, ex.acfg.g_max,
                               jnp.where(live & off, ex.acfg.g_min,
                                         p.g_feat))
                sf = (scenario_features_tiled(aged) if conditioned
                      else jnp.zeros((N_SCENARIO_FEATURES,), jnp.float32))
                return DeploymentState(
                    gf=gf, read_sigma=aged.read_sigma, read_key=kr,
                    out_perm=operm, eparams=ep, sfeat=sf,
                    cal_a=jnp.asarray(1.0, jnp.float32),
                    cal_b=jnp.asarray(0.0, jnp.float32))

            def one(i, t, tc):
                k = jax.random.fold_in(fleet_key, i)
                kd, kc, kr = jax.random.split(jax.random.fold_in(k, 7), 3)
                scen = self._device_scenario(k, nb, no)
                # ``tc`` is the last MAINTENANCE epoch: the array was
                # reprogrammed (fresh conductance draw, drift clock
                # reset) and its affine re-fitted then.  kp keys that
                # epoch's programming draw; tc = 0 is the deployment
                # write
                kp = jax.random.fold_in(kd, tc.astype(jnp.int32))
                a, b = fit_affine(_st_matmul_u(
                    ex, tag, xp, w, state_at(scen, 0.0, kp, kd, kc)))
                # serve: the same written state drifted for (t - tc)
                # seconds, under the epoch's affine (kr: a fresh read)
                st = state_at(scen, t - tc, kp, kd, kr) \
                    .with_calibration(a, b)
                y = _st_matmul_u(ex, tag, x2, w, st)
                return jnp.linalg.norm(y - y_ref) \
                    / jnp.maximum(jnp.linalg.norm(y_ref), 1e-12)

            return jax.vmap(one)(ids, age, cal_age)

        self._fn = jax.jit(fwd)

    def cache_size(self) -> int:
        """Compiled chunk executables (tests/bench assert this stays 1
        across the whole campaign)."""
        return self._fn._cache_size() if self._fn is not None else 0

    # ------------------------------------------------------------------ #
    # chunked evaluation (bounded memory, padded last chunk)
    # ------------------------------------------------------------------ #
    def evaluate(self, x: jax.Array, age,
                 ids: Optional[np.ndarray] = None,
                 cal_age=None) -> np.ndarray:
        """Per-device serving relative error at ``age`` seconds.

        ``ids`` selects a device subset (default: the whole population);
        ``age`` and ``cal_age`` are scalars or per-device arrays.
        ``cal_age`` is the device's last maintenance epoch -- array
        reprogrammed and affine recalibrated then, so the serve sees
        ``age - cal_age`` seconds of drift (default 0.0: written at
        deployment, never maintained).  Work proceeds in fixed-size
        chunks -- the last chunk is
        padded by repeating its final device and the pad rows dropped --
        so memory is bounded by ``spec.chunk`` and every call reuses the
        one compiled executable."""
        if self._fn is None:
            self._build()
        ids = (np.arange(self.spec.n_devices, dtype=np.int32)
               if ids is None else np.asarray(ids, np.int32))
        n = ids.shape[0]
        age = np.broadcast_to(np.asarray(age, np.float32), (n,))
        cal = np.broadcast_to(
            np.asarray(0.0 if cal_age is None else cal_age, np.float32), (n,))
        x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
        c = self.spec.chunk
        out = np.empty((n,), np.float32)
        for lo in range(0, n, c):
            hi = min(lo + c, n)
            pad = c - (hi - lo)
            sl = lambda a: np.pad(a[lo:hi], (0, pad), mode="edge")
            res = self._fn(x2, self._xp, jnp.asarray(sl(ids)),
                           jnp.asarray(sl(age)), jnp.asarray(sl(cal)))
            out[lo:hi] = np.asarray(res)[:hi - lo]
            if OBS.enabled:
                OBS.counter("fleet_chunk_evals_total",
                            "compiled fleet chunk executions",
                            tag=self.tag).inc()
        if OBS.enabled:
            OBS.counter("fleet_eval_devices_total",
                        "devices evaluated across fleet campaigns",
                        tag=self.tag).inc(float(n))
            OBS.gauge("fleet_eval_rel_err",
                      "serving relative error of the last fleet "
                      "evaluation", tag=self.tag, stat="mean"
                      ).set(float(out.mean()))
            OBS.gauge("fleet_eval_rel_err",
                      "serving relative error of the last fleet "
                      "evaluation", tag=self.tag, stat="p95"
                      ).set(float(np.quantile(out, 0.95)))
        return out

    # ------------------------------------------------------------------ #
    # cheap per-device features (for the forecast surrogate)
    # ------------------------------------------------------------------ #
    def device_features(self, ids: np.ndarray, age) -> np.ndarray:
        """(n, 2 * N_SCENARIO_FEATURES + 4) per-device summary features
        at ``age`` (seconds since the array was written -- the DRIFT
        age, see ``evaluate``): mean and max over the tile lattice of
        each device's per-tile scenario feature encoding, plus the
        device's REALIZED stuck-cell fractions (mean/max over tiles of
        the fraction of live cells the fab draw actually stuck on/off).
        The realized fractions -- not just the fab-drawn rates already
        in the scenario encoding -- are what separate a device's
        freshly-maintained error floor from its neighbors': stuck cells
        are permanent, so an unlucky draw caps accuracy no matter how
        often the array is rewritten.  No emulator execution -- this is
        the surrogate ranker's input, cheap enough for the whole
        population."""
        if self._feat_fn is None:
            fleet_key = self.key
            plan = self.ex._plan_for(self.w, self.tag)
            nb, no = plan.NB, plan.NO
            live = plan.g_feat > 0.0
            cell_axes = tuple(range(2, plan.g_feat.ndim))
            n_live = jnp.maximum(live.sum(axis=cell_axes)
                                 .astype(jnp.float32), 1.0)

            def feats(i, t):
                k = jax.random.fold_in(fleet_key, i)
                # same key discipline as the chunk forward's ``one``:
                # kd is the device's permanent fab/fault key
                kd, _, _ = jax.random.split(jax.random.fold_in(k, 7), 3)
                scen = self._device_scenario(k, nb, no)
                aged = dataclasses.replace(
                    scen, drift_t=jnp.full((nb, no), t, jnp.float32))
                f = scenario_features_tiled(aged).reshape(
                    -1, N_SCENARIO_FEATURES)
                on, off = realized_fault_masks(plan, aged, kd)
                fr_on = (live & on).sum(axis=cell_axes) / n_live
                fr_off = (live & off).sum(axis=cell_axes) / n_live
                return jnp.concatenate([
                    f.mean(axis=0), f.max(axis=0),
                    jnp.stack([fr_on.mean(), fr_on.max(),
                               fr_off.mean(), fr_off.max()])])

            self._feat_fn = jax.jit(jax.vmap(feats))
        ids = np.asarray(ids, np.int32)
        n = ids.shape[0]
        age = np.broadcast_to(np.asarray(age, np.float32), (n,))
        c = self.spec.chunk
        out = np.empty((n, 2 * N_SCENARIO_FEATURES + 4), np.float32)
        for lo in range(0, n, c):
            hi = min(lo + c, n)
            pad = c - (hi - lo)
            sl = lambda a: np.pad(a[lo:hi], (0, pad), mode="edge")
            out[lo:hi] = np.asarray(
                self._feat_fn(jnp.asarray(sl(ids)),
                              jnp.asarray(sl(age))))[:hi - lo]
        return out
