"""repro.fleet -- million-device digital twin & predictive maintenance.

A deployed crossbar product is not one device: it is a *fleet* of
fabricated instances of the same weights, each with its own programming
-variation draw, stuck-cell population, read-noise level and retention
-drift rate.  This package simulates that fleet at scale and schedules
its maintenance:

  * ``population`` -- ``FleetSpec`` / ``Fleet``: N devices materialized
    lazily from per-device PRNG keys (fab draw -> per-tile scenario
    lattice -> deterministic drift), evaluated as chunked vmapped
    populations through the serving executor's unified forward.  A
    million devices fit in bounded memory and the whole campaign runs
    through exactly ONE compiled chunk executable
    (``obs.RecompileSentinel``-gated).
  * ``forecast`` -- per-device accuracy trajectories across the drift
    timeline via the scenario-conditioned emulator (zero retraining:
    the net reads each device's aged corner off its per-tile feature
    operands), plus a cheap quantile-regression surrogate fitted on a
    probed subsample that ranks all N devices without simulating them.
  * ``maintenance`` -- ``MaintenancePlanner``: per-device action
    timelines (recalibrate / field-retrain / retire, plus a fleet-level
    wear-aware remap decision) minimizing a cost model of action costs
    and accuracy-SLO violation penalties, with per-cohort batched
    recalibration.  ``simulate_policy`` replays any action table through
    the same chunk executable, which is how
    ``benchmarks/bench_fleet.py`` shows the planner dominating both
    "never maintain" and "recalibrate everything every checkpoint".

See docs/fleet.md for the narrative and tests/test_fleet.py for the
determinism / compile-once contracts.
"""
from repro.fleet.forecast import SurrogateRanker, forecast_fleet
from repro.fleet.maintenance import (A_NONE, A_RECAL, A_RETIRE, A_RETRAIN,
                                     ACTION_NAMES, ActionCosts, FleetPlan,
                                     MaintenancePlanner,
                                     always_recalibrate_policy, never_policy,
                                     simulate_policy)
from repro.fleet.population import Fleet, FleetSpec

__all__ = [
    "ACTION_NAMES", "A_NONE", "A_RECAL", "A_RETIRE", "A_RETRAIN",
    "ActionCosts", "Fleet", "FleetPlan", "FleetSpec",
    "MaintenancePlanner", "SurrogateRanker", "always_recalibrate_policy",
    "forecast_fleet", "never_policy", "simulate_policy",
]
