"""Predictive maintenance: per-device action timelines from a cost model.

``MaintenancePlanner`` turns forecasts into schedules.  The action
vocabulary per device per checkpoint:

  none          -- serve on, risking the accuracy SLO;
  recalibrate   -- rewrite the array and refit the volts->logical
                   affine: a fresh programming draw for the epoch, the
                   retention-drift clock reset to zero (stuck cells
                   persist -- fab defects survive a rewrite).  Cohorts
                   are batched: every device maintained at a checkpoint
                   rides the same chunk pass;
  field_retrain -- recalibrate + field fine-tune of the emulator on the
                   device's own serving distribution.  Under a
                   scenario-conditioned emulator the fine-tune buys
                   nothing the feature operands don't already provide
                   (``retrain_gain = 1.0``), so the cost model discovers
                   what docs/emulator.md argues: the action is dominated
                   and never scheduled.  Unconditioned fleets can set
                   ``retrain_gain < 1`` from a measured probe cohort.
  retire        -- swap in a spare: one-time cost, no further SLO
                   exposure (the device leaves the error pool).

plus one fleet-level decision: whether deployment-time remapping should
be *wear-aware* (``remap_horizon``: score permutations against the whole
maintenance timeline's drift trajectory instead of the young device --
``nonideal.remap_plan(horizon=...)``).

Planning is per-device dynamic programming over (last-calibration
checkpoint, retrained?, retired?) states -- exact for the cost model,
vectorized over the population with numpy -- on error forecasts from
either the ``SurrogateRanker`` (default: cheap enough for a million
devices) or the exact chunk-replayed grid (``exact=True``).  The cost
model is additive per device:

  total = sum_checkpoints action_cost + slo_penalty * 1{err > slo}

``simulate_policy`` replays ANY action table (the planner's or a
baseline's) through the fleet's one compiled chunk executable with the
realized per-device calibration ages, returning per-checkpoint realized
error, violations, cost, and the cost-adjusted accuracy
``mean(1 / (1 + err)) - acc_per_cost * cum_cost / n`` that
``benchmarks/bench_fleet.py`` gates on.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.fleet.forecast import SurrogateRanker, forecast_fleet
from repro.fleet.population import Fleet
from repro.obs import OBS

# action codes in the (n_devices, n_checkpoints) timeline tables
A_NONE, A_RECAL, A_RETRAIN, A_RETIRE = 0, 1, 2, 3
ACTION_NAMES: Tuple[str, ...] = ("none", "recalibrate", "field_retrain",
                                 "retire")


@dataclass(frozen=True)
class ActionCosts:
    """Unit costs of the maintenance cost model (arbitrary but common
    units; only ratios matter to the planner).

    ``slo_penalty`` prices one checkpoint of one device serving above
    the error SLO; ``acc_per_cost`` converts accumulated cost into
    accuracy points for the cost-adjusted accuracy report."""
    recalibrate: float = 1.0
    field_retrain: float = 8.0
    retire: float = 40.0
    slo_penalty: float = 25.0
    acc_per_cost: float = 0.002


@dataclass
class FleetPlan:
    """A materialized maintenance schedule.

    ``actions[d, i]`` is the action code for device ``d`` at checkpoint
    ``i`` of ``timeline``; ``expected_cost`` is the DP objective (per
    the forecasts); ``remap_horizon`` is the fleet-level wear-aware
    remap decision (None = instantaneous remapping)."""
    timeline: Tuple[float, ...]
    actions: np.ndarray
    expected_cost: float
    remap_horizon: Optional[Tuple[float, ...]] = None

    def cohorts(self, i: int) -> Dict[str, np.ndarray]:
        """Device-id cohorts per action at checkpoint ``i`` -- the
        batched-recalibration view: every id in one cohort shares the
        same traced calibration age, so the whole cohort is served by
        the same chunk executable in one pass."""
        return {ACTION_NAMES[a]: np.where(self.actions[:, i] == a)[0]
                for a in (A_NONE, A_RECAL, A_RETRAIN, A_RETIRE)
                if np.any(self.actions[:, i] == a)}


def never_policy(n_devices: int, timeline: Sequence[float]) -> np.ndarray:
    """Baseline: deploy, calibrate once, never touch again."""
    return np.full((n_devices, len(timeline)), A_NONE, np.int8)


def always_recalibrate_policy(n_devices: int,
                              timeline: Sequence[float]) -> np.ndarray:
    """Baseline: recalibrate every device at every checkpoint."""
    return np.full((n_devices, len(timeline)), A_RECAL, np.int8)


def _realized_cal_ages(actions: np.ndarray,
                       timeline: Sequence[float]) -> np.ndarray:
    """(n, T) age of the last calibration in effect AT each checkpoint
    (recalibration at checkpoint i serves checkpoint i already)."""
    n, T = actions.shape
    cal = np.zeros((n, T), np.float32)
    last = np.zeros((n,), np.float32)
    for i, t in enumerate(timeline):
        did = (actions[:, i] == A_RECAL) | (actions[:, i] == A_RETRAIN)
        last = np.where(did, np.float32(t), last)
        cal[:, i] = last
    return cal


def simulate_policy(fleet: Fleet, x, timeline: Sequence[float],
                    actions: np.ndarray, costs: ActionCosts,
                    slo: float, retrain_gain: float = 1.0,
                    policy: str = "plan") -> List[dict]:
    """Replay an action table against the real (simulated) fleet.

    Retired devices leave the error pool from their retirement checkpoint
    on (a spare serves at ideal accuracy) but their one-time cost stays
    on the books.  Returns one record per checkpoint with the realized
    mean/p95 error, SLO violations, cumulative cost and the
    cost-adjusted accuracy the benchmark gates compare."""
    acts = np.asarray(actions, np.int8)
    n, T = acts.shape
    cal = _realized_cal_ages(acts, timeline)
    retired = np.zeros((n,), bool)
    gain = np.ones((n,), np.float32)
    cum_cost = 0.0
    out: List[dict] = []
    unit = np.array([0.0, costs.recalibrate, costs.field_retrain,
                     costs.retire], np.float64)
    for i, t in enumerate(timeline):
        newly_retired = (acts[:, i] == A_RETIRE) & ~retired
        retired |= newly_retired
        gain = np.where(acts[:, i] == A_RETRAIN,
                        np.float32(retrain_gain), gain)
        live = ~retired
        err = np.zeros((n,), np.float32)
        if live.any():
            ids = np.where(live)[0].astype(np.int32)
            err[ids] = fleet.evaluate(x, t, ids=ids,
                                      cal_age=cal[ids, i]) * gain[ids]
        viol = int(((err > slo) & live).sum())
        # devices retired at an earlier checkpoint act (and cost) nothing;
        # the retiring checkpoint itself books the one-time retire cost
        act_cost = float(unit[acts[live | newly_retired, i]].sum())
        cum_cost += act_cost + costs.slo_penalty * viol
        acc = np.where(live, 1.0 / (1.0 + err), 1.0)
        rec = {
            "t": float(t),
            "mean_err": float(err[live].mean()) if live.any() else 0.0,
            "p95_err": (float(np.quantile(err[live], 0.95))
                        if live.any() else 0.0),
            "violations": viol,
            "retired": int(retired.sum()),
            "action_cost": act_cost,
            "cum_cost": float(cum_cost),
            "mean_acc": float(acc.mean()),
            "cost_adjusted_acc": float(
                acc.mean() - costs.acc_per_cost * cum_cost / n),
        }
        out.append(rec)
        if OBS.enabled:
            OBS.counter("fleet_slo_violations_total",
                        "SLO-violating device-checkpoints per policy",
                        tag=fleet.tag, policy=policy).inc(float(viol))
            OBS.gauge("fleet_policy_cost_adjusted_acc",
                      "cost-adjusted accuracy at the latest checkpoint",
                      tag=fleet.tag, policy=policy
                      ).set(rec["cost_adjusted_acc"])
    return out


@dataclass
class MaintenancePlanner:
    """Cost-optimal per-device maintenance schedules.

    Builds on the ``LifetimeScheduler`` model of a fleet walk (deploy
    -> age -> mitigate at checkpoints; same mitigations, same drift
    timeline) but plans each DEVICE independently against forecasts
    instead of applying one policy fleet-wide.

    Attributes:
      fleet:        the population to plan for.
      timeline:     checkpoint ages in seconds (``t0 = 0`` deployment
                    calibration is implicit and free).
      costs:        the cost model.
      slo:          relative-error SLO a serving device must stay under.
      margin:       forecast safety margin: a device is treated as
                    at-risk when its predicted error exceeds
                    ``slo * (1 - margin)``.
      retrain_gain: multiplicative residual-error factor a field
                    retrain buys (1.0 under a conditioned emulator).
      exact:        plan on exact chunk-replayed forecasts instead of
                    the surrogate (small fleets / ground truth).
      n_probe:      surrogate probe-subsample size.
    """
    fleet: Fleet
    timeline: Sequence[float]
    costs: ActionCosts = field(default_factory=ActionCosts)
    slo: float = 0.1
    margin: float = 0.1
    retrain_gain: float = 1.0
    exact: bool = False
    n_probe: int = 128
    ranker: Optional[SurrogateRanker] = None

    def _forecast_grid(self, x) -> np.ndarray:
        """E[d, i, j]: predicted error at checkpoint i when last
        calibrated at checkpoint j (j <= i; j indexes ``[0] + timeline``
        so j = 0 is the deployment calibration).

        The surrogate grid is clamped from below by each device's
        MEASURED commissioning floor: the freshly-maintained residual at
        the first checkpoint, replayed exactly for the whole population
        (one chunk pass -- operationally free, it is the calibration
        probe's own residual).  The floor is a realization quantity
        (this device's programming draw and stuck cells), invisible to
        any feature-based surrogate; without the clamp the planner
        schedules futile recalibrations for devices whose floor already
        violates the SLO instead of retiring them."""
        ages = list(self.timeline)
        cals = [0.0] + ages
        n = self.fleet.spec.n_devices
        ids = np.arange(n, dtype=np.int32)
        E = np.full((n, len(ages), len(cals)), np.inf, np.float32)
        if self.exact:
            for j, c in enumerate(cals):
                for i, t in enumerate(ages):
                    if c <= t:
                        E[:, i, j] = self.fleet.evaluate(x, t, cal_age=c)
            return E
        if self.ranker is None:
            self.ranker = SurrogateRanker().fit(
                self.fleet, x, ages, n_probe=self.n_probe)
        for j, c in enumerate(cals):
            for i, t in enumerate(ages):
                if c <= t:
                    E[:, i, j] = self.ranker.predict(self.fleet, ids, t,
                                                     cal_age=c)
        floor = self.fleet.evaluate(x, ages[0], cal_age=ages[0])
        return np.maximum(E, floor[:, None, None])

    def _choose_remap_horizon(self) -> Optional[Tuple[float, ...]]:
        """Fleet-level wear-aware remap decision: when the base corner
        carries stuck-off faults AND drift, score deployment-time
        remapping against the whole maintenance timeline
        (``remap_plan(horizon=...)``); otherwise instantaneous remapping
        (or none) is already optimal."""
        base = self.fleet.spec.base
        if base.has_stuck_off and bool(np.any(np.asarray(base.drift_nu))):
            return tuple(float(t) for t in self.timeline)
        return None

    def plan(self, x) -> FleetPlan:
        """Exact DP over the cost model, vectorized across devices.

        Device state at checkpoint i: (last-calibration index j,
        retrained?) or retired.  ``slo * (1 - margin)`` thresholds the
        forecasts; the realized dominance is asserted downstream by
        ``simulate_policy`` (benchmarks/bench_fleet.py)."""
        E = self._forecast_grid(x)
        n, T, _ = E.shape
        thr = self.slo * (1.0 - self.margin)
        pen = self.costs.slo_penalty
        c_re, c_ft = self.costs.recalibrate, self.costs.field_retrain
        c_rt = self.costs.retire
        # value[d, s]: cost-to-go from checkpoint i with state s; states
        # 0..T = last-cal index (plain), T+1..2T+1 = last-cal index
        # (retrained), 2T+2 = retired
        S = 2 * (T + 1) + 1
        RET = S - 1
        val = np.zeros((n, S), np.float64)
        act = np.empty((T, n, S), np.int8)
        nxt = np.empty((T, n, S), np.int16)
        for i in range(T - 1, -1, -1):
            new = np.empty((n, S), np.float64)
            for s in range(S):
                if s == RET:
                    new[:, s] = val[:, RET]
                    act[i, :, s] = A_NONE
                    nxt[i, :, s] = RET
                    continue
                j = s if s <= T else s - (T + 1)
                g = 1.0 if s <= T else self.retrain_gain
                e_stay = E[:, i, j] * g
                e_recal = E[:, i, i + 1] * g
                e_ftr = E[:, i, i + 1] * self.retrain_gain
                s_recal = (i + 1) if s <= T else (T + 1) + (i + 1)
                s_ftr = (T + 1) + (i + 1)
                cand = np.stack([
                    pen * (e_stay > thr) + val[:, s],
                    c_re + pen * (e_recal > thr) + val[:, s_recal],
                    c_ft + pen * (e_ftr > thr) + val[:, s_ftr],
                    c_rt + val[:, RET],
                ], axis=1)
                best = cand.argmin(axis=1)
                new[:, s] = cand[np.arange(n), best]
                act[i, :, s] = best.astype(np.int8)
                nxt[i, :, s] = np.where(
                    best == A_NONE, s,
                    np.where(best == A_RECAL, s_recal,
                             np.where(best == A_RETRAIN, s_ftr, RET)))
            val = new
        # forward pass: extract each device's argmin timeline from s = 0
        actions = np.empty((n, T), np.int8)
        state = np.zeros((n,), np.int16)
        rows = np.arange(n)
        for i in range(T):
            actions[:, i] = act[i, rows, state]
            state = nxt[i, rows, state]
        expected = float(val[:, 0].sum())
        plan = FleetPlan(timeline=tuple(float(t) for t in self.timeline),
                         actions=actions, expected_cost=expected,
                         remap_horizon=self._choose_remap_horizon())
        if OBS.enabled:
            for a, name in enumerate(ACTION_NAMES):
                OBS.counter("fleet_plan_actions_total",
                            "actions scheduled by the maintenance "
                            "planner", tag=self.fleet.tag, action=name
                            ).inc(float((actions == a).sum()))
            OBS.gauge("fleet_plan_expected_cost",
                      "DP objective of the latest maintenance plan",
                      tag=self.fleet.tag).set(expected)
        return plan
