"""Per-device accuracy forecasting across the drift timeline.

Two tiers, matching the two budgets a fleet operator has:

  * ``forecast_fleet`` -- EXACT trajectories: replay every requested
    device through the fleet's one compiled chunk executable at each
    (age, calibration-age) pair.  Drift is deterministic given the fab
    draw, so this is a forecast, not a guess -- the device at 1 month is
    computable today.  Cost: one chunk pass per grid point.
  * ``SurrogateRanker`` -- CHEAP scores for the whole population: a
    quantile-shifted linear regression from per-device scenario summary
    features (``Fleet.device_features``) + a drift-age encoding to the
    exact error, fitted on a small probed subsample.  At the default
    ``tau = 0.8`` the surrogate over-covers: it predicts a conservative
    upper quantile of the error, which is what a maintenance planner
    should rank by.  Fitting is a closed-form ridge solve plus a
    tau-quantile intercept shift -- fully deterministic, no iteration.

Maintenance REPROGRAMS the array (population.py): a device maintained
at ``cal_age = tc`` and served at ``t`` carries ``t - tc`` seconds of
drift on a fresh write, so its error depends on the DRIFT AGE alone,
never on absolute age.  The surrogate encodes exactly that -- device
features and the age encoding are both evaluated at ``t - tc``.
Feeding absolute age as a feature lets the (collinear: stale probe rows
have ``cal = 0``) fit leak the drift slope into it, inflating
fresh-maintenance forecasts at late checkpoints until the planner
wrongly retires repairable devices.

The scenario-conditioned emulator makes both tiers retraining-free: the
net reads each device's aged per-tile corner off its feature operands
(docs/emulator.md), so forecasting N devices x T ages never touches
training infrastructure.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np

from repro.fleet.population import Fleet
from repro.obs import OBS

_AGE_SCALE = 16.0          # matches scenario._DRIFT_AGE_SCALE


def forecast_fleet(fleet: Fleet, x, ages: Sequence[float],
                   ids: Optional[np.ndarray] = None,
                   cal_age=0.0) -> np.ndarray:
    """Exact (n_devices, n_ages) relative-error trajectories.

    ``cal_age`` is the age the affine was (or will be) fitted at --
    scalar, or per-device.  Every grid point reuses the fleet's one
    compiled chunk executable (ages and calibration ages are traced
    operands)."""
    cols = [fleet.evaluate(x, t, ids=ids, cal_age=cal_age) for t in ages]
    return np.stack(cols, axis=1)


def _ranker_features(feats: np.ndarray, drift_age: np.ndarray) -> np.ndarray:
    """Design matrix: device summary features (evaluated AT the drift
    age) + a drift-age encoding + intercept."""
    d = np.broadcast_to(np.asarray(drift_age, np.float32),
                        (feats.shape[0],))
    enc = np.log1p(np.maximum(d, 0.0)) / _AGE_SCALE
    ones = np.ones((feats.shape[0], 1), np.float32)
    return np.concatenate([feats, enc[:, None], ones],
                          axis=1).astype(np.float64)


@dataclass
class SurrogateRanker:
    """Quantile-regression surrogate for per-device serving error.

    ``fit`` probes ``n_probe`` devices exactly over the (age, cal_age)
    grid, ridge-fits the conditional mean and shifts the intercept by
    the tau-quantile of the training residuals (so the prediction is a
    calibrated tau-quantile on the probe set by construction --
    closed-form, deterministic, immune to the near-constant feature
    columns that destabilize iterative pinball descent); ``predict``
    then scores any device at any (age, cal_age) from its cheap
    drift-age feature encoding alone -- the whole-population ranking
    pass behind ``MaintenancePlanner``.
    """
    tau: float = 0.8
    coef: Optional[np.ndarray] = None
    train_pinball: float = field(default=float("nan"), init=False)

    def fit(self, fleet: Fleet, x, ages: Sequence[float],
            n_probe: int = 128, key: int = 0) -> "SurrogateRanker":
        """Probe an evenly-strided ``n_probe``-device subsample over every
        valid (age, cal_age <= age) pair and fit the quantile surface."""
        n = fleet.spec.n_devices
        stride = max(1, n // max(1, int(n_probe)))
        ids = np.arange(0, n, stride, dtype=np.int32)[:int(n_probe)]
        grid = [(t, c) for t in ages for c in [0.0] + list(ages) if c <= t]
        # dedupe while keeping deterministic order
        grid = list(dict.fromkeys(grid))
        Xs, ys = [], []
        for t, c in grid:
            err = fleet.evaluate(x, t, ids=ids, cal_age=c)
            drift = np.full(ids.shape, t - c, np.float32)
            Xs.append(_ranker_features(
                fleet.device_features(ids, drift), drift))
            ys.append(err.astype(np.float64))
        X = np.concatenate(Xs, axis=0)
        y = np.concatenate(ys, axis=0)
        # column scaling for a well-conditioned ridge solve
        scale = np.maximum(np.abs(X).max(axis=0), 1e-9)
        Xs_ = X / scale
        wvec = np.linalg.solve(Xs_.T @ Xs_ + 1e-6 * np.eye(X.shape[1]),
                               Xs_.T @ y)
        # tau-quantile intercept shift: the mean fit becomes a calibrated
        # upper-quantile forecast (the intercept column is last, unit
        # scale, so the shift moves every prediction by the same amount)
        wvec[-1] += np.quantile(y - Xs_ @ wvec, self.tau)
        self.coef = wvec / scale
        r = y - X @ self.coef
        self.train_pinball = float(
            np.mean(np.where(r > 0, self.tau * r, (self.tau - 1.0) * r)))
        if OBS.enabled:
            OBS.gauge("fleet_surrogate_pinball",
                      "training pinball loss of the fitted forecast "
                      "surrogate", tag=fleet.tag).set(self.train_pinball)
        return self

    def predict(self, fleet: Fleet, ids: np.ndarray, age,
                cal_age=0.0) -> np.ndarray:
        """Predicted tau-quantile relative error for each device."""
        if self.coef is None:
            raise ValueError("SurrogateRanker.predict before fit")
        ids = np.asarray(ids, np.int32)
        n = ids.shape[0]
        age_a = np.broadcast_to(np.asarray(age, np.float32), (n,))
        cal_a = np.broadcast_to(np.asarray(cal_age, np.float32), (n,))
        drift = np.maximum(age_a - cal_a, 0.0)
        X = _ranker_features(fleet.device_features(ids, drift), drift)
        return (X @ self.coef).astype(np.float32)
