"""Block-size autotuner for the serving kernels.

Every kernel wrapper used to hardcode its tiling (``block_m=128`` /
``block_n=256`` -- and the flash-attention exemplar this repo started
from still carries a literal ``# TODO: tune BLOCK_SIZE``).  This module
replaces the constants with a measured choice: on first use of a
(kernel, backend, dtype, shape) combination the candidate configs are
timed on dummy operands and the winner is cached

  * in-process (``_MEM``), so one sweep serves the whole run, and
  * on disk (``cache_dir()/autotune.json`` -- ``$REPRO_CACHE_DIR``,
    else ``$XDG_CACHE_HOME/repro``, else ``~/.cache/repro``; the file
    itself overridable with ``$REPRO_AUTOTUNE_CACHE``), so repeat runs
    skip the sweep entirely.

Every resolution is also counted into the telemetry registry when
enabled (``autotune_resolutions_total{kernel, source}``, plus a sweep
duration histogram and a cache-path info gauge -- docs/observability.md).

Sweeping is explicit opt-in off-TPU (``REPRO_AUTOTUNE=1``): candidates
are timed through real compiles, which is exactly right for a serving
deployment or a benchmark run and exactly wrong for a unit-test sweep.
With tuning disabled every call resolves to the caller's default, so
the kernels behave like the old hardcoded constants.

``best_config`` may be consulted from inside a ``jit`` trace: the key is
shape-derived (static under tracing) and the measure closure runs on
concrete dummy operands, so a cache miss sweeps eagerly at trace time
and the chosen config is baked into the executable being built.

Every resolution is recorded (``report()``) so benchmark runs can write
the chosen block sizes and the cache-hit status into their artifact
(BENCH_speed.json schema 2, docs/performance.md).
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List, Optional, Sequence

import jax

from repro.obs import OBS

_MEM: Dict[str, dict] = {}
_REPORT: Dict[str, dict] = {}
_DISK_VERSION = 1


def cache_dir() -> str:
    """Root of the repro disk caches.  Resolution order:

      1. ``REPRO_CACHE_DIR``    -- explicit override (CI runners and
         multi-user hosts point this at a job-local scratch dir so
         concurrent runs never collide on one shared cache file);
      2. ``XDG_CACHE_HOME``/repro -- the XDG base-directory convention;
      3. ``~/.cache/repro``     -- the historical default.
    """
    env = os.environ.get("REPRO_CACHE_DIR")
    if env:
        return env
    xdg = os.environ.get("XDG_CACHE_HOME")
    base = xdg if xdg else os.path.join(os.path.expanduser("~"), ".cache")
    return os.path.join(base, "repro")


def cache_path() -> str:
    """Autotune disk-cache file (``REPRO_AUTOTUNE_CACHE`` overrides the
    whole path; otherwise it lives under ``cache_dir()``)."""
    env = os.environ.get("REPRO_AUTOTUNE_CACHE")
    if env:
        return env
    return os.path.join(cache_dir(), "autotune.json")


def enabled() -> bool:
    """Whether cache misses sweep (else the caller's default is used).

    ``REPRO_AUTOTUNE=1``/``0`` forces it; unset, sweeping is on only
    where the kernels actually compile (TPU) -- interpret-mode timings
    would tune for the wrong executor.
    """
    env = os.environ.get("REPRO_AUTOTUNE")
    if env is not None:
        return env not in ("0", "false", "")
    return jax.default_backend() == "tpu"


def _load_disk() -> dict:
    try:
        with open(cache_path()) as f:
            doc = json.load(f)
        if isinstance(doc, dict) and doc.get("version") == _DISK_VERSION:
            return doc.get("entries", {})
    except (OSError, json.JSONDecodeError, ValueError):
        pass
    return {}


def _store_disk(key: str, cfg: dict) -> None:
    path = cache_path()
    try:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        entries = _load_disk()
        entries[key] = cfg
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"version": _DISK_VERSION, "entries": entries}, f,
                      indent=1, sort_keys=True)
        os.replace(tmp, path)
    except OSError:
        pass                      # cache is best-effort; in-process holds


def _key(kernel: str, key_parts: Sequence) -> str:
    return "|".join([kernel, jax.default_backend()]
                    + [str(p) for p in key_parts])


def _measure_median(measure: Callable[[dict], float], cfg: dict,
                    reps: int = 5) -> float:
    measure(cfg)                  # warmup: compile outside the timing
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        measure(cfg)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def best_config(kernel: str, key_parts: Sequence, candidates: List[dict],
                measure: Optional[Callable[[dict], float]], default: dict,
                ) -> dict:
    """Resolve the config for one (kernel, backend, shape) combination.

    ``measure(cfg)`` runs the kernel once under ``cfg`` (it is invoked
    repeatedly and timed here); candidates that raise are skipped, so an
    over-sized block that fails to compile just loses the sweep.  With
    tuning disabled or no ``measure``, ``default`` is returned
    unconditionally (and recorded as such).
    """
    key = _key(kernel, key_parts)
    if key in _MEM:
        _record(kernel, key, _MEM[key], "memory")
        return _MEM[key]
    disk = _load_disk()
    if key in disk:
        _MEM[key] = disk[key]
        _record(kernel, key, disk[key], "disk")
        return disk[key]
    if not enabled() or measure is None:
        _record(kernel, key, default, "default")
        return default
    t_sweep = time.perf_counter()
    best, best_t = default, float("inf")
    for cfg in candidates:
        try:
            t = _measure_median(measure, cfg)
        except Exception:         # noqa: BLE001 -- losing candidates is fine
            continue
        if t < best_t:
            best, best_t = cfg, t
    if OBS.enabled:
        OBS.histogram("autotune_sweep_seconds",
                      "wall-clock of one candidate sweep (compiles "
                      "included)", kernel=kernel).observe(
                          time.perf_counter() - t_sweep)
    _MEM[key] = best
    _store_disk(key, best)
    _record(kernel, key, best, "swept")
    return best


def _record(kernel: str, key: str, cfg: dict, source: str) -> None:
    _REPORT[kernel] = {"key": key, "config": dict(cfg), "source": source}
    if OBS.enabled:
        OBS.counter("autotune_resolutions_total",
                    "block-size resolutions per kernel and source "
                    "(memory/disk cache hit, fresh sweep, or the "
                    "caller's default)", kernel=kernel, source=source).inc()
        OBS.gauge("autotune_cache_path_info",
                  "constant 1; the label carries the active autotune "
                  "disk-cache path", path=cache_path()).set(1)


def report() -> Dict[str, dict]:
    """Last resolution per kernel this process: the chosen config and
    where it came from (``memory`` / ``disk`` / ``swept`` / ``default``).
    Benchmark runs persist this next to their timings (schema 2)."""
    return {k: dict(v) for k, v in _REPORT.items()}


def clear(memory: bool = True, disk: bool = False) -> None:
    """Test/bench hook: drop the in-process (and optionally disk) cache."""
    if memory:
        _MEM.clear()
        _REPORT.clear()
    if disk:
        try:
            os.remove(cache_path())
        except OSError:
            pass
