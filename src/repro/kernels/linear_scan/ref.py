"""Pure-jnp oracle: diagonal gated linear recurrence h_t = a_t*h_{t-1} + b_t
along axis 1 (the shared primitive behind Mamba-1 and RG-LRU)."""
import jax
import jax.numpy as jnp


def linear_scan_ref(a, b, h0=None):
    """a, b: (B, S, D); h0: (B, D) or None -> (h (B,S,D), h_last (B,D))."""
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)

    def comb(x, y):
        a1, b1 = x
        a2, b2 = y
        return a2 * a1, a2 * b1 + b2

    aa, bb = jax.lax.associative_scan(comb, (a, b), axis=1)
    return bb, bb[:, -1]
