"""Pallas TPU kernel: diagonal gated linear recurrence (Mamba-1 / RG-LRU).

    h_t = a_t * h_{t-1} + b_t        (elementwise over D)

Tiling: grid (B, D/bd, S/bs) with the SEQUENCE axis innermost; the carry h
(bd,) lives in VMEM scratch across sequence blocks, so HBM traffic is
exactly one read of (a, b) and one write of h -- the op is purely
memory-bound and the kernel streams it at line rate. Inside a block the
recurrence runs as an unrolled VPU loop over bs steps (bs is small, e.g.
128-256; the D lanes vectorize).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, o_ref, h_ref, *, bs):
    sb = pl.program_id(2)

    @pl.when(sb == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0].astype(jnp.float32)                  # (bs, bd)
    b = b_ref[0].astype(jnp.float32)
    h = h_ref[...]

    def step(i, carry):
        h, out = carry
        h = a[i] * h + b[i]
        out = jax.lax.dynamic_update_index_in_dim(out, h, i, 0)
        return h, out

    out0 = jnp.zeros_like(a)
    h, out = jax.lax.fori_loop(0, bs, step, (h, out0))
    h_ref[...] = h
    o_ref[0] = out.astype(o_ref.dtype)


def linear_scan_pallas(a, b, *, block_d=512, block_s=128, interpret=False):
    """a, b: (B, S, D) -> h: (B, S, D), h0 = 0 (fold h0 into b[:,0])."""
    B, S, D = a.shape
    bd, bs = min(block_d, D), min(block_s, S)
    assert D % bd == 0 and S % bs == 0
    grid = (B, D // bd, S // bs)

    return pl.pallas_call(
        functools.partial(_kernel, bs=bs),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bs, bd), lambda i, j, k: (i, k, j)),
            pl.BlockSpec((1, bs, bd), lambda i, j, k: (i, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bs, bd), lambda i, j, k: (i, k, j)),
        out_shape=jax.ShapeDtypeStruct((B, S, D), a.dtype),
        scratch_shapes=[pltpu.VMEM((bd,), jnp.float32)],
        interpret=interpret,
    )(a, b)
