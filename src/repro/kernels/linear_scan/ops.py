"""Public wrapper: handles h0 by exactly folding it into b[:, 0]."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.linear_scan.linear_scan import linear_scan_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("block_d", "block_s"))
def linear_scan(a, b, h0=None, *, block_d=512, block_s=128):
    """a, b: (B, S, D); h0: (B, D) or None. Returns (h, h_last)."""
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0.astype(b.dtype))
    h = linear_scan_pallas(a, b, block_d=block_d, block_s=block_s,
                           interpret=not _on_tpu())
    return h, h[:, -1]
