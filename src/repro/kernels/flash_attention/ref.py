"""Pure-jnp oracle: exact (softmax-once) attention with causal and/or
sliding-window masking. q/k/v: (B, H, S, D)."""
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal=True, window=0):
    B, H, S, D = q.shape
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (D ** -0.5)
    qi = jnp.arange(S)[:, None]
    ki = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= ki <= qi
    if window:
        mask &= (qi - ki) < window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)
