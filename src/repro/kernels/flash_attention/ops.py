"""Public wrapper: accepts (B, H, S, D), pads D to the 128-lane MXU width,
flattens (B, H) into the grid's batch axis."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "block_q", "block_kv"))
def flash_attention(q, k, v, *, causal=True, window=0,
                    block_q=128, block_kv=128):
    B, H, S, D = q.shape
    pad = (-D) % 128 if _on_tpu() else 0
    if pad:
        zq = jnp.zeros((B, H, S, pad), q.dtype)
        q = jnp.concatenate([q, zq], -1)
        k = jnp.concatenate([k, zq.astype(k.dtype)], -1)
        v = jnp.concatenate([v, zq.astype(v.dtype)], -1)
    out = flash_attention_pallas(
        q.reshape(B * H, S, -1), k.reshape(B * H, S, -1),
        v.reshape(B * H, S, -1), causal=causal, window=window,
        scale=D ** -0.5,
        block_q=block_q, block_kv=block_kv, interpret=not _on_tpu())
    out = out.reshape(B, H, S, -1)
    return out[..., :D] if pad else out
