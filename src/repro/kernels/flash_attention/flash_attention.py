"""Pallas TPU kernel: blockwise-softmax (flash) attention, causal and/or
sliding window.

Tiling: grid (B*H, S/bq, S/bk) with the KV axis innermost; the online
softmax state (m, l) and the output accumulator live in VMEM scratch across
KV steps. Out-of-range blocks (beyond the causal diagonal or the window)
still execute but are fully masked -- on TPU the index_map keeps their data
local, and the §Perf triangular variant skips them at the jnp level.
q/k/v layout: (B*H, S, D) with D MXU-aligned (pad to 128 in ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, scale, causal, window, bq, bk, nk):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (bq, D)
    k = k_ref[0].astype(jnp.float32)                  # (bk, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)

    q_pos = pl.program_id(1) * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq, bk), 0)
    k_pos = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    m_ref[...] = m_new
    pv = jax.lax.dot_general(p.astype(v_ref.dtype), v_ref[0],
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + pv

    @pl.when(kb == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal=True, window=0, scale=None,
                           block_q=128, block_kv=128, interpret=False):
    """q/k/v: (BH, S, D) -> (BH, S, D). `scale` defaults to D**-0.5 of the
    (unpadded) head dim -- callers that pad D must pass it explicitly."""
    BH, S, D = q.shape
    bq, bk = min(block_q, S), min(block_kv, S)
    assert S % bq == 0 and S % bk == 0
    nk = S // bk
    grid = (BH, S // bq, nk)
    scale = D ** -0.5 if scale is None else scale

    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, window=window,
                          bq=bq, bk=bk, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, D), v.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
