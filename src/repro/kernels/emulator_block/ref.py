"""Oracle: the paper-faithful Conv4Xbar apply (lax.conv_general_dilated)."""
from repro.core.conv4xbar import apply as conv4xbar_apply_ref  # noqa: F401
