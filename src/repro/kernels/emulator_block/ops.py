"""Public wrapper: builds the stage plan from the block geometry."""
from __future__ import annotations

import functools

import jax

from repro.configs.rram_ps32 import BlockGeometry
from repro.core.conv4xbar import build_stages
from repro.kernels.emulator_block.emulator_block import (
    emulator_block_grid_pallas, emulator_block_pallas)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def emulator_block(params: dict, x: jax.Array, periph: jax.Array,
                   geom: BlockGeometry, *, block_n: int = 256):
    """Fused Conv4Xbar forward. x: (N, C, D, H, W) normalized; -> (N, O)."""
    stages = build_stages(geom)
    return emulator_block_pallas(params, x, periph, stages,
                                 block_n=block_n, interpret=not _on_tpu())


def emulator_block_grid(params: dict, v01: jax.Array, g_norm: jax.Array,
                        geom: BlockGeometry, *, block_m: int = 128,
                        interpret: bool = None):
    """Batched serving variant: 2-D grid (batch tiles, NB*NO block index).

    v01: (M, NB, D, H) normalized voltages; g_norm: (NB*NO, D, H, W) shared
    normalized conductance features; -> (M, NB*NO, O)."""
    stages = build_stages(geom)
    if interpret is None:
        interpret = not _on_tpu()
    return emulator_block_grid_pallas(params, v01, g_norm, stages,
                                      block_m=block_m, interpret=interpret)
