"""Public wrapper: builds the stage plan from the block geometry."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.rram_ps32 import BlockGeometry
from repro.core.conv4xbar import apply_blocklast, build_stages
from repro.kernels import autotune
from repro.kernels.emulator_block.emulator_block import (
    emulator_block_grid_pallas, emulator_block_pallas,
    emulator_block_unified_pallas)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def emulator_block(params: dict, x: jax.Array, periph: jax.Array,
                   geom: BlockGeometry, *, block_n: int = 256):
    """Fused Conv4Xbar forward. x: (N, C, D, H, W) normalized; -> (N, O)."""
    stages = build_stages(geom)
    return emulator_block_pallas(params, x, periph, stages,
                                 block_n=block_n, interpret=not _on_tpu())


def emulator_block_grid(params: dict, v01: jax.Array, g_norm: jax.Array,
                        geom: BlockGeometry, *, block_m: int = 128,
                        interpret: bool = None):
    """Batched serving variant: 2-D grid (batch tiles, NB*NO block index).

    v01: (M, NB, D, H) normalized voltages; g_norm: (NB*NO, D, H, W) shared
    normalized conductance features; -> (M, NB*NO, O)."""
    stages = build_stages(geom)
    if interpret is None:
        interpret = not _on_tpu()
    return emulator_block_grid_pallas(params, v01, g_norm, stages,
                                      block_m=block_m, interpret=interpret)


def _dummy_like(tree):
    """Concrete stand-ins with the tree's shapes/dtypes (leaves may be
    tracers when the caller is under ``jit``; shapes are static).
    Non-array leaves (the static kernel widths in aux) pass through."""
    return jax.tree_util.tree_map(
        lambda a: jnp.full(a.shape, 0.1, a.dtype)
        if hasattr(a, "shape") else a, tree)


def emulator_block_unified(aux: dict, pre: dict, u01: jax.Array,
                           pos01: jax.Array, *,
                           shift: jax.Array | None = None,
                           use_pallas: bool | None = None,
                           chunk: int | None = None,
                           block_m: int | None = None,
                           interpret: bool | None = None,
                           tune: bool = True,
                           compute_dtype=jnp.float32) -> jax.Array:
    """Single entry point for the emulator serving math, every corner.

    Dispatches ONE dual-rail evaluation -- ``shift`` is the precomputed
    scenario epilogue (``sfeat @ aux["f0_scen"]``, None at the ideal
    corner) -- to either the fused pallas kernel
    (``emulator_block_unified_pallas``, default on TPU) or the identical
    chunked XLA evaluation (``conv4xbar.apply_blocklast``, default
    elsewhere).  Both run the same ``dual_rail_stage1``/``_tail_stages``
    code, so the choice is a pure scheduling decision: outputs are
    bit-identical in f32.

    ``block_m``/``chunk`` left as None are resolved by the autotuner
    (``kernels.autotune``) when sweeping is enabled, else fall back to
    heuristic defaults (min(128, M) / 2).  ``tune=False`` skips the
    autotuner entirely and takes the heuristic defaults directly -- the
    executor's ``shard_map`` bodies run per-shard lattice slices whose
    shapes the tuner never measured, and a sweep (timed compiles) must
    not fire inside a collective trace.  Block-size choice is a pure
    scheduling decision either way: outputs are bit-identical in f32.
    Returns (2, M*NB*NO, O).
    """
    M = u01.shape[0]
    g0k = pre["g0k"]
    k1, NB, NO, D, W, G, C0 = g0k.shape
    n_out = aux["fcs"][-1][0].shape[1]
    if use_pallas is None:
        use_pallas = _on_tpu()

    if use_pallas:
        if interpret is None:
            interpret = not _on_tpu()
        if block_m is None and not tune:
            block_m = min(128, M)
        if block_m is None:
            key_parts = (M, NB, NO, D, W, G, k1, C0, n_out,
                         jnp.dtype(compute_dtype).name, interpret)
            # dummies/jitted fns built lazily INSIDE measure -- it only
            # runs on a sweep; cache hits must stay a dict lookup
            state = {}

            def measure(cfg):
                bm = cfg["block_m"]
                if "dummies" not in state:
                    state["dummies"] = _dummy_like((aux, pre, u01, pos01,
                                                    shift))
                da, dp, du, dpos, dsh = state["dummies"]
                if bm not in state:
                    # aux/pre closed over (weights are trace constants in
                    # serving too); drive tensors traced so nothing folds
                    state[bm] = jax.jit(
                        lambda uu, qq, ss, bm=bm:
                        emulator_block_unified_pallas(
                            da, dp, uu, qq, shift=ss, block_m=bm,
                            interpret=interpret,
                            compute_dtype=compute_dtype))
                jax.block_until_ready(state[bm](du, dpos, dsh))

            cfg = autotune.best_config(
                "emulator_unified", key_parts,
                [{"block_m": b} for b in (16, 32, 64, 128, 256)],
                measure, {"block_m": min(128, M)})
            block_m = cfg["block_m"]
        return emulator_block_unified_pallas(
            aux, pre, u01, pos01, shift=shift, block_m=block_m,
            interpret=interpret, compute_dtype=compute_dtype)

    if chunk is None and not tune:
        chunk = 2
    if chunk is None:
        key_parts = (M, NB, NO, D, W, G, k1, C0, n_out)
        state = {}             # lazy dummies + per-config compiled fns

        def measure(cfg):
            ch = cfg["chunk"]
            if "dummies" not in state:
                state["dummies"] = _dummy_like((aux, pre, u01, pos01,
                                                shift))
            da, dp, du, dpos, dsh = state["dummies"]
            if ch not in state:
                state[ch] = jax.jit(
                    lambda uu, qq, ss, ch=ch: apply_blocklast(
                        da, dp, uu, qq, chunk=ch, fc0_shift=ss))
            jax.block_until_ready(state[ch](du, dpos, dsh))

        cfg = autotune.best_config(
            "blocklast_chunk", key_parts,
            [{"chunk": c} for c in (1, 2, 4, 8)],
            measure, {"chunk": 2})
        chunk = cfg["chunk"]
    return apply_blocklast(aux, pre, u01, pos01, chunk=chunk,
                           fc0_shift=shift)
