"""Pallas TPU kernel: the whole Conv4Xbar emulator evaluated per crossbar
block, fused in VMEM.

At system level the emulator runs over THOUSANDS of blocks per layer
(every weight tile of every projection); the hot loop is thousands of tiny
convs + FC stacks. This kernel keeps one batch-tile of blocks resident in
VMEM and evaluates the full network (conv stages as blocked matmuls over
row groups, then the FC head) without touching HBM in between -- the
emulator's weights (a few KB) stay resident across the whole grid.

Tiling: grid (N / bn); every stage is a dot over (C_in x k) contractions.
"""
from __future__ import annotations

import functools
from typing import List, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.conv4xbar import (ConvStage, _tail_stages, conv_out_sizes,
                                  dual_rail_stage1)


def _stage_apply(h, w, b, st: ConvStage):
    """h: (n, C, D, H, W) fp32; w: (O, I, kd, kh, kw); matches apply_fused."""
    n, C, D, H, W = h.shape
    O = w.shape[0]
    kd, kh, kw = st.kernel
    if (kh, kw) == (1, 1):
        y = jnp.einsum("ncdhw,oc->nodhw", h, w[:, :, 0, 0, 0])
    elif kw == 1:
        hg = h.reshape(n, C, D, H // kh, kh, W)
        y = jnp.einsum("ncdgkw,ock->nodgw", hg, w[:, :, 0, :, 0])
    else:
        wk = w[:, :, 0, 0, :]
        if st.stride[2] == kw:
            hg = h.reshape(n, C, D, H, W // kw, kw)
            y = jnp.einsum("ncdhgk,ock->nodhg", hg, wk)
        else:
            y = (jnp.einsum("ncdhw,oc->nodhw", h[..., :-1], wk[:, :, 0])
                 + jnp.einsum("ncdhw,oc->nodhw", h[..., 1:], wk[:, :, 1]))
    return jax.nn.celu(y + b[None, :, None, None, None])


def _kernel(*refs, stages: List[ConvStage], n_fc: int, out_dtype):
    # refs: x, periph, conv_w..., conv_b..., fc_w..., fc_b..., out
    x_ref, periph_ref = refs[0], refs[1]
    idx = 2
    conv = []
    for _ in stages:
        conv.append((refs[idx], refs[idx + 1]))
        idx += 2
    fcs = []
    for _ in range(n_fc):
        fcs.append((refs[idx], refs[idx + 1]))
        idx += 2
    o_ref = refs[idx]

    h = x_ref[...].astype(jnp.float32)
    for (w_ref, b_ref), st in zip(conv, stages):
        h = _stage_apply(h, w_ref[...].astype(jnp.float32),
                         b_ref[...].astype(jnp.float32), st)
    h = h.reshape(h.shape[0], -1)
    p = periph_ref[...].astype(jnp.float32)
    h = jnp.concatenate([h, p], axis=-1)
    for i, (w_ref, b_ref) in enumerate(fcs):
        h = jnp.dot(h, w_ref[...].astype(jnp.float32),
                    preferred_element_type=jnp.float32) \
            + b_ref[...].astype(jnp.float32)
        if i < n_fc - 1:
            h = jax.nn.celu(h)
    o_ref[...] = h.astype(out_dtype)


def _weight_operands(params: dict, stages: List[ConvStage], n_fc: int):
    """Emulator weights as pallas operands with grid-constant BlockSpecs."""
    operands, in_specs = [], []
    names = [f"conv{j}" for j in range(len(stages))] + \
            [f"fc{j}" for j in range(n_fc)]
    for name in names:
        for suf in ("_w", "_b"):
            wgt = params[f"{name}{suf}"]
            operands.append(wgt)
            in_specs.append(pl.BlockSpec(
                wgt.shape, lambda *_, nd=wgt.ndim: (0,) * nd))
    return operands, in_specs


def _grid_kernel(*refs, stages: List[ConvStage], n_fc: int, n_periph: int,
                 out_dtype):
    """2-D grid step: one batch tile of one crossbar block.

    The conductance features are batch-constant, so they arrive as a
    block-indexed operand (g_ref) shared across the whole batch axis of the
    grid instead of a batch-broadcast tensor in HBM; the (V, G) channel
    stack is materialized only in VMEM."""
    v_ref, g_ref = refs[0], refs[1]
    idx = 2
    conv = []
    for _ in stages:
        conv.append((refs[idx], refs[idx + 1]))
        idx += 2
    fcs = []
    for _ in range(n_fc):
        fcs.append((refs[idx], refs[idx + 1]))
        idx += 2
    o_ref = refs[idx]

    v = v_ref[...].astype(jnp.float32)                # (bm, 1, D, H)
    g = g_ref[...].astype(jnp.float32)                # (1, D, H, W)
    bm = v.shape[0]
    D, H, W = g.shape[1], g.shape[2], g.shape[3]
    vch = jnp.broadcast_to(v.reshape(bm, D, H, 1), (bm, D, H, W))
    gch = jnp.broadcast_to(g, (bm, D, H, W))
    h = jnp.stack([vch, gch], axis=1)                 # (bm, 2, D, H, W)
    for (w_ref, b_ref), st in zip(conv, stages):
        h = _stage_apply(h, w_ref[...].astype(jnp.float32),
                         b_ref[...].astype(jnp.float32), st)
    h = h.reshape(bm, -1)
    if n_periph:
        # serving-path peripheral features are the constant (gain=1, off=0)
        p = jnp.concatenate([jnp.ones((bm, 1), jnp.float32),
                             jnp.zeros((bm, n_periph - 1), jnp.float32)],
                            axis=-1)
        h = jnp.concatenate([h, p], axis=-1)
    for i, (w_ref, b_ref) in enumerate(fcs):
        h = jnp.dot(h, w_ref[...].astype(jnp.float32),
                    preferred_element_type=jnp.float32) \
            + b_ref[...].astype(jnp.float32)
        if i < n_fc - 1:
            h = jax.nn.celu(h)
    o_ref[...] = h.reshape(bm, 1, -1).astype(out_dtype)


def emulator_block_grid_pallas(params: dict, v01: jax.Array,
                               g_norm: jax.Array, stages: List[ConvStage],
                               *, block_m: int = 128,
                               interpret: bool = False) -> jax.Array:
    """Batched serving variant over a 2-D grid (batch tiles, NB*NO blocks).

    v01: (M, NB, D, H) normalized wordline voltages; g_norm: (NB*NO, D, H, W)
    normalized conductance features shared by every batch row.
    Returns (M, NB*NO, O)."""
    M, NB, D, H = v01.shape
    NBLK = g_norm.shape[0]
    NO = NBLK // NB
    assert NO * NB == NBLK, (NB, NBLK)
    n_fc = len([k for k in params if k.startswith("fc") and k.endswith("_w")])
    n_out = params[f"fc{n_fc-1}_w"].shape[1]
    d, h, w = conv_out_sizes(stages, D, H, g_norm.shape[-1])
    flat = stages[-1].c_out * d * h * w
    n_periph = params["fc0_w"].shape[0] - flat

    bm = min(block_m, M)
    padM = (-M) % bm
    vp = jnp.pad(v01, ((0, padM), (0, 0), (0, 0), (0, 0))) if padM else v01
    Mp = M + padM

    operands = [vp, g_norm]
    in_specs = [
        pl.BlockSpec((bm, 1, D, H), lambda i, j: (i, j // NO, 0, 0)),
        pl.BlockSpec((1,) + g_norm.shape[1:], lambda i, j: (j, 0, 0, 0)),
    ]
    w_ops, w_specs = _weight_operands(params, stages, n_fc)
    operands += w_ops
    in_specs += w_specs

    out = pl.pallas_call(
        functools.partial(_grid_kernel, stages=stages, n_fc=n_fc,
                          n_periph=n_periph, out_dtype=v01.dtype),
        grid=(Mp // bm, NBLK),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, 1, n_out), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((Mp, NBLK, n_out), v01.dtype),
        interpret=interpret,
    )(*operands)
    return out[:M] if padM else out


def emulator_block_pallas(params: dict, x: jax.Array, periph: jax.Array,
                          stages: List[ConvStage], *, block_n: int = 256,
                          interpret: bool = False) -> jax.Array:
    """x: (N, C, D, H, W) normalized features; periph: (N, P) -> (N, O).

    Non-divisible batches are padded to the block size and sliced back
    (zero rows are valid block inputs), like the grid variant pads M."""
    N = x.shape[0]
    bn = min(block_n, N)
    padN = (-N) % bn
    if padN:
        x = jnp.pad(x, ((0, padN),) + ((0, 0),) * (x.ndim - 1))
        periph = jnp.pad(periph, ((0, padN), (0, 0)))
    Np = N + padN
    n_fc = len([k for k in params if k.startswith("fc") and k.endswith("_w")])
    n_out = params[f"fc{n_fc-1}_w"].shape[1]

    operands = [x, periph]
    in_specs = [
        pl.BlockSpec((bn,) + x.shape[1:],
                     lambda i: (i,) + (0,) * (x.ndim - 1)),
        pl.BlockSpec((bn, periph.shape[1]), lambda i: (i, 0)),
    ]
    w_ops, w_specs = _weight_operands(params, stages, n_fc)
    operands += w_ops
    in_specs += w_specs

    out = pl.pallas_call(
        functools.partial(_kernel, stages=stages, n_fc=n_fc,
                          out_dtype=x.dtype),
        grid=(Np // bn,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bn, n_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Np, n_out), x.dtype),
        interpret=interpret,
    )(*operands)
    return out[:N] if padN else out


# --------------------------------------------------------------------------- #
# THE unified serving kernel: one pallas_call for every device corner
# --------------------------------------------------------------------------- #
def _unified_kernel(*refs, tail_ks: Tuple[int, ...], kw: int, n_fc: int,
                    out_dtype, compute_dtype):
    """Grid step (batch tile i, crossbar block j): BOTH rails of the
    dual-rail delta factorization and BOTH GEMM stages (stage-1 window
    contraction + tail conv/FC stack), evaluated in VMEM.

    The kernel body calls the same ``dual_rail_stage1``/``_tail_stages``
    code the CPU fast path (``conv4xbar.apply_blocklast``) runs, so the
    two paths are bit-identical by construction.  The scenario epilogue
    is the precomputed fc0 shift ``sfeat @ f0_scen`` -- grid-constant
    for a whole-plan corner, block-indexed ``(1, fc0_out)`` for per-tile
    feature operands, exactly zero at the ideal corner's all-zero
    encoding -- so
    ONE compiled kernel serves ideal, conditioned and non-ideal corners
    (perturbed conductances arrive through the block-indexed g0/celu0/y0
    precompute operands).  ``compute_dtype=bfloat16`` runs every GEMM
    with bf16 operands and f32 accumulation (MXU-native); f32 keeps the
    parity-exact contraction."""
    (u_ref, pos_ref, g0_ref, c0_ref, y0_ref, sh_ref, w0v_ref,
     w1k_ref) = refs[:8]
    idx = 8
    tail = []
    for k in tail_ks:
        tail.append((refs[idx][...].astype(jnp.float32),
                     refs[idx + 1][...].astype(jnp.float32), k))
        idx += 2
    wstage = (refs[idx][...].astype(jnp.float32),
              refs[idx + 1][...].astype(jnp.float32), kw)
    idx += 2
    fcs = []
    for _ in range(n_fc):
        fcs.append((refs[idx][...].astype(jnp.float32),
                    refs[idx + 1][...].astype(jnp.float32)))
        idx += 2
    o_ref = refs[idx]

    u = u_ref[...].astype(jnp.float32)                # (bm, 1, D, G, k1)
    pos = pos_ref[...].astype(jnp.float32)
    bm, _, D, G, k1 = u.shape
    g0k = g0_ref[...].astype(jnp.float32)[0]          # (k1, D, W, G, C0)
    celu0k = c0_ref[...].astype(jnp.float32)[0]
    W = g0k.shape[2]
    y0 = y0_ref[...].astype(jnp.float32)[0]           # (D*W*G, O1)
    shift = sh_ref[...].astype(jnp.float32)
    w0v = w0v_ref[...].astype(jnp.float32)
    w1k = w1k_ref[...].astype(jnp.float32)

    if compute_dtype == jnp.float32:
        dot = None                # jnp.matmul -- identical to the CPU path
    else:
        def dot(a, b):
            return jnp.dot(a.astype(compute_dtype), b.astype(compute_dtype),
                           preferred_element_type=jnp.float32)

    # singleton W axis so the per-kk drive broadcasts against g0k[kk]
    ub = u.reshape(bm, D, 1, G, k1)
    pb = pos.reshape(bm, D, 1, G, k1)
    h = jax.nn.celu(dual_rail_stage1(g0k, celu0k, y0, w0v, w1k, ub, pb,
                                     dot=dot))        # (2, bm, D*W*G, O1)
    n2 = 2 * bm
    aux_k = {"hstages": ((None, None, k1),) + tuple(tail),
             "wstage": wstage, "fcs": tuple(fcs)}
    h = _tail_stages(aux_k, h.reshape(n2, -1), n2, (n2, D, W, G),
                     fc0_shift=shift, dot=dot)
    o_ref[...] = h.reshape(2, bm, 1, -1).astype(out_dtype)


def _const_spec(arr):
    return pl.BlockSpec(arr.shape, lambda *_, nd=arr.ndim: (0,) * nd)


def emulator_block_unified_pallas(aux: dict, pre: dict, u01: jax.Array,
                                  pos01: jax.Array, *,
                                  shift: jax.Array | None = None,
                                  block_m: int = 128,
                                  interpret: bool = False,
                                  compute_dtype=jnp.float32) -> jax.Array:
    """One kernel launch per matmul, every corner on the TPU path.

    aux/pre: ``conv4xbar.blocklast_weights`` / ``blocklast_precompute``
    tensors (the precompute carries the deployed -- possibly perturbed --
    conductance state); u01/pos01: (M, NB, D, H) magnitude drive and
    positive-rail mask; shift: optional scenario epilogue
    ``sfeat @ aux["f0_scen"]`` -- ``(fc0_out,)`` grid-constant for a
    whole-plan corner, or ``(NB*NO, fc0_out)`` block-indexed for
    per-tile feature operands (each grid cell then reads its own tile's
    shift) -- None = ideal, folds to an exact zero add.
    Returns (2, M*NB*NO, O) rail block outputs, row-compatible with
    ``apply_blocklast``."""
    M, NB, D, H = u01.shape
    g0k = pre["g0k"]                                  # (k1,NB,NO,D,W,G,C0)
    k1, _, NO, _, W, G, C0 = g0k.shape
    NBLK = NB * NO
    w1k = aux["w1k"]
    O1 = w1k.shape[2]
    fcs = aux["fcs"]
    n_fc = len(fcs)
    n_out = fcs[-1][0].shape[1]
    if shift is None:
        shift = jnp.zeros((fcs[0][0].shape[1],), jnp.float32)

    bm = min(block_m, M)
    padM = (-M) % bm
    ug = u01.reshape(M, NB, D, G, k1)
    pg = pos01.reshape(M, NB, D, G, k1)
    if padM:
        ug = jnp.pad(ug, ((0, padM),) + ((0, 0),) * 4)
        pg = jnp.pad(pg, ((0, padM),) + ((0, 0),) * 4)
    Mp = M + padM
    g0b = g0k.transpose(1, 2, 0, 3, 4, 5, 6).reshape(NBLK, k1, D, W, G, C0)
    c0b = pre["celu0k"].transpose(1, 2, 0, 3, 4, 5, 6).reshape(
        NBLK, k1, D, W, G, C0)
    y0b = pre["y0"].reshape(NBLK, D * W * G, O1)

    tail = aux["hstages"][1:]
    wst_w, wst_b, kw = aux["wstage"]
    operands = [ug, pg, g0b, c0b, y0b, shift, aux["w0v"], w1k]
    in_specs = [
        pl.BlockSpec((bm, 1, D, G, k1), lambda i, j: (i, j // NO, 0, 0, 0)),
        pl.BlockSpec((bm, 1, D, G, k1), lambda i, j: (i, j // NO, 0, 0, 0)),
        pl.BlockSpec((1, k1, D, W, G, C0),
                     lambda i, j: (j, 0, 0, 0, 0, 0)),
        pl.BlockSpec((1, k1, D, W, G, C0),
                     lambda i, j: (j, 0, 0, 0, 0, 0)),
        pl.BlockSpec((1, D * W * G, O1), lambda i, j: (j, 0, 0)),
        # per-tile (NBLK, fc0_out) shift: each grid cell j reads row j;
        # whole-plan (fc0_out,) shift: grid-constant
        (pl.BlockSpec((1, shift.shape[1]), lambda i, j: (j, 0))
         if shift.ndim == 2 else _const_spec(shift)),
        _const_spec(aux["w0v"]), _const_spec(w1k),
    ]
    for wk, b, _ in tail:
        operands += [wk, b]
        in_specs += [_const_spec(wk), _const_spec(b)]
    operands += [wst_w, wst_b]
    in_specs += [_const_spec(wst_w), _const_spec(wst_b)]
    for fw, fb in fcs:
        operands += [fw, fb]
        in_specs += [_const_spec(fw), _const_spec(fb)]

    out = pl.pallas_call(
        functools.partial(_unified_kernel,
                          tail_ks=tuple(k for _, _, k in tail), kw=kw,
                          n_fc=n_fc, out_dtype=jnp.float32,
                          compute_dtype=compute_dtype),
        grid=(Mp // bm, NBLK),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((2, bm, 1, n_out), lambda i, j: (0, i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((2, Mp, NBLK, n_out), jnp.float32),
        interpret=interpret,
    )(*operands)
    return out[:, :M].reshape(2, M * NBLK, n_out)
