"""Pallas TPU kernel: the whole Conv4Xbar emulator evaluated per crossbar
block, fused in VMEM.

At system level the emulator runs over THOUSANDS of blocks per layer
(every weight tile of every projection); the hot loop is thousands of tiny
convs + FC stacks. This kernel keeps one batch-tile of blocks resident in
VMEM and evaluates the full network (conv stages as blocked matmuls over
row groups, then the FC head) without touching HBM in between -- the
emulator's weights (a few KB) stay resident across the whole grid.

Tiling: grid (N / bn); every stage is a dot over (C_in x k) contractions.
"""
from __future__ import annotations

import functools
from typing import List, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.conv4xbar import ConvStage, conv_out_sizes


def _stage_apply(h, w, b, st: ConvStage):
    """h: (n, C, D, H, W) fp32; w: (O, I, kd, kh, kw); matches apply_fused."""
    n, C, D, H, W = h.shape
    O = w.shape[0]
    kd, kh, kw = st.kernel
    if (kh, kw) == (1, 1):
        y = jnp.einsum("ncdhw,oc->nodhw", h, w[:, :, 0, 0, 0])
    elif kw == 1:
        hg = h.reshape(n, C, D, H // kh, kh, W)
        y = jnp.einsum("ncdgkw,ock->nodgw", hg, w[:, :, 0, :, 0])
    else:
        wk = w[:, :, 0, 0, :]
        if st.stride[2] == kw:
            hg = h.reshape(n, C, D, H, W // kw, kw)
            y = jnp.einsum("ncdhgk,ock->nodhg", hg, wk)
        else:
            y = (jnp.einsum("ncdhw,oc->nodhw", h[..., :-1], wk[:, :, 0])
                 + jnp.einsum("ncdhw,oc->nodhw", h[..., 1:], wk[:, :, 1]))
    return jax.nn.celu(y + b[None, :, None, None, None])


def _kernel(*refs, stages: List[ConvStage], n_fc: int, out_dtype):
    # refs: x, periph, conv_w..., conv_b..., fc_w..., fc_b..., out
    x_ref, periph_ref = refs[0], refs[1]
    idx = 2
    conv = []
    for _ in stages:
        conv.append((refs[idx], refs[idx + 1]))
        idx += 2
    fcs = []
    for _ in range(n_fc):
        fcs.append((refs[idx], refs[idx + 1]))
        idx += 2
    o_ref = refs[idx]

    h = x_ref[...].astype(jnp.float32)
    for (w_ref, b_ref), st in zip(conv, stages):
        h = _stage_apply(h, w_ref[...].astype(jnp.float32),
                         b_ref[...].astype(jnp.float32), st)
    h = h.reshape(h.shape[0], -1)
    p = periph_ref[...].astype(jnp.float32)
    h = jnp.concatenate([h, p], axis=-1)
    for i, (w_ref, b_ref) in enumerate(fcs):
        h = jnp.dot(h, w_ref[...].astype(jnp.float32),
                    preferred_element_type=jnp.float32) \
            + b_ref[...].astype(jnp.float32)
        if i < n_fc - 1:
            h = jax.nn.celu(h)
    o_ref[...] = h.astype(out_dtype)


def _weight_operands(params: dict, stages: List[ConvStage], n_fc: int):
    """Emulator weights as pallas operands with grid-constant BlockSpecs."""
    operands, in_specs = [], []
    names = [f"conv{j}" for j in range(len(stages))] + \
            [f"fc{j}" for j in range(n_fc)]
    for name in names:
        for suf in ("_w", "_b"):
            wgt = params[f"{name}{suf}"]
            operands.append(wgt)
            in_specs.append(pl.BlockSpec(
                wgt.shape, lambda *_, nd=wgt.ndim: (0,) * nd))
    return operands, in_specs


def _grid_kernel(*refs, stages: List[ConvStage], n_fc: int, n_periph: int,
                 out_dtype):
    """2-D grid step: one batch tile of one crossbar block.

    The conductance features are batch-constant, so they arrive as a
    block-indexed operand (g_ref) shared across the whole batch axis of the
    grid instead of a batch-broadcast tensor in HBM; the (V, G) channel
    stack is materialized only in VMEM."""
    v_ref, g_ref = refs[0], refs[1]
    idx = 2
    conv = []
    for _ in stages:
        conv.append((refs[idx], refs[idx + 1]))
        idx += 2
    fcs = []
    for _ in range(n_fc):
        fcs.append((refs[idx], refs[idx + 1]))
        idx += 2
    o_ref = refs[idx]

    v = v_ref[...].astype(jnp.float32)                # (bm, 1, D, H)
    g = g_ref[...].astype(jnp.float32)                # (1, D, H, W)
    bm = v.shape[0]
    D, H, W = g.shape[1], g.shape[2], g.shape[3]
    vch = jnp.broadcast_to(v.reshape(bm, D, H, 1), (bm, D, H, W))
    gch = jnp.broadcast_to(g, (bm, D, H, W))
    h = jnp.stack([vch, gch], axis=1)                 # (bm, 2, D, H, W)
    for (w_ref, b_ref), st in zip(conv, stages):
        h = _stage_apply(h, w_ref[...].astype(jnp.float32),
                         b_ref[...].astype(jnp.float32), st)
    h = h.reshape(bm, -1)
    if n_periph:
        # serving-path peripheral features are the constant (gain=1, off=0)
        p = jnp.concatenate([jnp.ones((bm, 1), jnp.float32),
                             jnp.zeros((bm, n_periph - 1), jnp.float32)],
                            axis=-1)
        h = jnp.concatenate([h, p], axis=-1)
    for i, (w_ref, b_ref) in enumerate(fcs):
        h = jnp.dot(h, w_ref[...].astype(jnp.float32),
                    preferred_element_type=jnp.float32) \
            + b_ref[...].astype(jnp.float32)
        if i < n_fc - 1:
            h = jax.nn.celu(h)
    o_ref[...] = h.reshape(bm, 1, -1).astype(out_dtype)


def emulator_block_grid_pallas(params: dict, v01: jax.Array,
                               g_norm: jax.Array, stages: List[ConvStage],
                               *, block_m: int = 128,
                               interpret: bool = False) -> jax.Array:
    """Batched serving variant over a 2-D grid (batch tiles, NB*NO blocks).

    v01: (M, NB, D, H) normalized wordline voltages; g_norm: (NB*NO, D, H, W)
    normalized conductance features shared by every batch row.
    Returns (M, NB*NO, O)."""
    M, NB, D, H = v01.shape
    NBLK = g_norm.shape[0]
    NO = NBLK // NB
    assert NO * NB == NBLK, (NB, NBLK)
    n_fc = len([k for k in params if k.startswith("fc") and k.endswith("_w")])
    n_out = params[f"fc{n_fc-1}_w"].shape[1]
    d, h, w = conv_out_sizes(stages, D, H, g_norm.shape[-1])
    flat = stages[-1].c_out * d * h * w
    n_periph = params["fc0_w"].shape[0] - flat

    bm = min(block_m, M)
    padM = (-M) % bm
    vp = jnp.pad(v01, ((0, padM), (0, 0), (0, 0), (0, 0))) if padM else v01
    Mp = M + padM

    operands = [vp, g_norm]
    in_specs = [
        pl.BlockSpec((bm, 1, D, H), lambda i, j: (i, j // NO, 0, 0)),
        pl.BlockSpec((1,) + g_norm.shape[1:], lambda i, j: (j, 0, 0, 0)),
    ]
    w_ops, w_specs = _weight_operands(params, stages, n_fc)
    operands += w_ops
    in_specs += w_specs

    out = pl.pallas_call(
        functools.partial(_grid_kernel, stages=stages, n_fc=n_fc,
                          n_periph=n_periph, out_dtype=v01.dtype),
        grid=(Mp // bm, NBLK),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, 1, n_out), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((Mp, NBLK, n_out), v01.dtype),
        interpret=interpret,
    )(*operands)
    return out[:M] if padM else out


def emulator_block_pallas(params: dict, x: jax.Array, periph: jax.Array,
                          stages: List[ConvStage], *, block_n: int = 256,
                          interpret: bool = False) -> jax.Array:
    """x: (N, C, D, H, W) normalized features; periph: (N, P) -> (N, O)."""
    N = x.shape[0]
    bn = min(block_n, N)
    assert N % bn == 0
    n_fc = len([k for k in params if k.startswith("fc") and k.endswith("_w")])
    n_out = params[f"fc{n_fc-1}_w"].shape[1]

    operands = [x, periph]
    in_specs = [
        pl.BlockSpec((bn,) + x.shape[1:],
                     lambda i: (i,) + (0,) * (x.ndim - 1)),
        pl.BlockSpec((bn, periph.shape[1]), lambda i: (i, 0)),
    ]
    w_ops, w_specs = _weight_operands(params, stages, n_fc)
    operands += w_ops
    in_specs += w_specs

    return pl.pallas_call(
        functools.partial(_kernel, stages=stages, n_fc=n_fc,
                          out_dtype=x.dtype),
        grid=(N // bn,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bn, n_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, n_out), x.dtype),
        interpret=interpret,
    )(*operands)
