from repro.kernels.emulator_block.ops import (  # noqa: F401
    emulator_block, emulator_block_grid, emulator_block_unified)
