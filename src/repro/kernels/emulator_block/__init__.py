from repro.kernels.emulator_block.ops import emulator_block  # noqa: F401
