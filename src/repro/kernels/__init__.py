"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel directory has:
  <name>.py -- pl.pallas_call with explicit BlockSpec VMEM tiling (TPU target)
  ops.py    -- jit'd public wrapper (interpret=True on CPU for validation)
  ref.py    -- pure-jnp oracle used by the allclose test sweeps
"""
