"""Pallas TPU kernel: nonlinear crossbar MAC.

Tiling: grid (B/bb, N/bn, K/bk); K is the innermost (sequential) axis so the
fp32 accumulator scratch lives in VMEM across K steps; the cell nonlinearity
is fused into the MXU feed and the integrator tanh is applied on the last K
step. Block shapes default to MXU-aligned (128, 128) tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(v_ref, g_ref, o_ref, acc_ref, *, v_th, beta, gain, v_sat, nk):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    v = v_ref[...].astype(jnp.float32)                 # (bb, bk)
    g = g_ref[...].astype(jnp.float32)                 # (bk, bn)
    drive = jnp.maximum(v - v_th, 0.0) * (1.0 + beta * v)
    acc_ref[...] += jnp.dot(drive, g, preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _finish():
        o_ref[...] = (v_sat * jnp.tanh(gain * acc_ref[...] / v_sat)
                      ).astype(o_ref.dtype)


def xbar_mac_pallas(v, g, *, v_th=0.08, beta=0.6, gain=3200.0, v_sat=1.0,
                    block_b=128, block_n=128, block_k=128, interpret=False):
    B, K = v.shape
    K2, N = g.shape
    assert K == K2
    bb, bn, bk = min(block_b, B), min(block_n, N), min(block_k, K)
    assert B % bb == 0 and N % bn == 0 and K % bk == 0, (B, N, K, bb, bn, bk)
    nk = K // bk
    grid = (B // bb, N // bn, nk)

    return pl.pallas_call(
        functools.partial(_kernel, v_th=v_th, beta=beta, gain=gain,
                          v_sat=v_sat, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bb, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, N), v.dtype),
        scratch_shapes=[pltpu.VMEM((bb, bn), jnp.float32)],
        interpret=interpret,
    )(v, g)
