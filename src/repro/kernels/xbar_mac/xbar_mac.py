"""Pallas TPU kernel: nonlinear crossbar MAC.

Tiling: grid (B/bb, N/bn, K/bk); K is the innermost (sequential) axis so the
fp32 accumulator scratch lives in VMEM across K steps; the cell nonlinearity
is fused into the MXU feed and the integrator tanh is applied on the last K
step. Block shapes default to MXU-aligned (128, 128) tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(v_ref, g_ref, o_ref, acc_ref, *, v_th, beta, gain, v_sat, nk):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    v = v_ref[...].astype(jnp.float32)                 # (bb, bk)
    g = g_ref[...].astype(jnp.float32)                 # (bk, bn)
    drive = jnp.maximum(v - v_th, 0.0) * (1.0 + beta * v)
    acc_ref[...] += jnp.dot(drive, g, preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _finish():
        o_ref[...] = (v_sat * jnp.tanh(gain * acc_ref[...] / v_sat)
                      ).astype(o_ref.dtype)


def xbar_mac_pallas(v, g, *, v_th=0.08, beta=0.6, gain=3200.0, v_sat=1.0,
                    block_b=128, block_n=128, block_k=128, interpret=False):
    B, K = v.shape
    K2, N = g.shape
    if K != K2:
        raise ValueError(f"contraction mismatch: v is (.., {K}), g is ({K2}, ..)")
    bb, bn, bk = min(block_b, B), min(block_n, N), min(block_k, K)
    # pad-and-slice for non-divisible shapes: zero drive rows are cut off by
    # the cell threshold (relu(v - v_th) == 0) and zero-conductance columns
    # integrate to tanh(0) == 0, so zero padding is exact
    pb, pn, pk = (-B) % bb, (-N) % bn, (-K) % bk
    if pb or pk:
        v = jnp.pad(v, ((0, pb), (0, pk)))
    if pk or pn:
        g = jnp.pad(g, ((0, pk), (0, pn)))
    out = _xbar_mac_padded(v, g, v_th=v_th, beta=beta, gain=gain, v_sat=v_sat,
                           bb=bb, bn=bn, bk=bk, interpret=interpret)
    return out[:B, :N] if (pb or pn) else out


def _xbar_mac_padded(v, g, *, v_th, beta, gain, v_sat, bb, bn, bk, interpret):
    B, K = v.shape
    N = g.shape[1]
    nk = K // bk
    grid = (B // bb, N // bn, nk)

    return pl.pallas_call(
        functools.partial(_kernel, v_th=v_th, beta=beta, gain=gain,
                          v_sat=v_sat, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bb, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, N), v.dtype),
        scratch_shapes=[pltpu.VMEM((bb, bn), jnp.float32)],
        interpret=interpret,
    )(v, g)
