"""Pure-jnp oracle for the nonlinear crossbar MAC.

Analog MAC with the analytic 1T1R cell model (threshold + curvature) and a
saturating integrator -- the per-tile compute the SEMULATOR framework's
`analytic` backend evaluates for every crossbar tile:

    i_cell = g * max(v - v_th, 0) * (1 + beta * v)
    out    = v_sat * tanh(gain * sum_k i_cell / v_sat)
"""
import jax.numpy as jnp


def xbar_mac_ref(v, g, *, v_th=0.08, beta=0.6, gain=3200.0, v_sat=1.0):
    """v: (B, K) wordline voltages; g: (K, N) conductances -> (B, N)."""
    drive = jnp.maximum(v - v_th, 0.0) * (1.0 + beta * v)      # (B, K)
    i = drive.astype(jnp.float32) @ g.astype(jnp.float32)      # (B, N)
    return v_sat * jnp.tanh(gain * i / v_sat)
