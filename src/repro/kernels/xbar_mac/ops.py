"""Public wrapper for the crossbar-MAC kernel: jit'd, interpret=True on CPU
(the TPU path is selected automatically on TPU backends)."""
from __future__ import annotations

import functools

import jax

from repro.kernels.xbar_mac.xbar_mac import xbar_mac_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("v_th", "beta", "gain", "v_sat",
                                             "block_b", "block_n", "block_k"))
def xbar_mac(v, g, *, v_th=0.08, beta=0.6, gain=3200.0, v_sat=1.0,
             block_b=128, block_n=128, block_k=128):
    return xbar_mac_pallas(v, g, v_th=v_th, beta=beta, gain=gain, v_sat=v_sat,
                           block_b=block_b, block_n=block_n, block_k=block_k,
                           interpret=not _on_tpu())
