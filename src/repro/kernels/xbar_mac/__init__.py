from repro.kernels.xbar_mac.ops import xbar_mac  # noqa: F401
