from repro.optim.adamw import (adamw_update, global_norm, init_opt_schema,
                               lr_schedule)  # noqa: F401
