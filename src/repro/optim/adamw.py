"""Hand-rolled AdamW on pytrees with ZeRO-style sharded states (optimizer
state inherits the parameter PartitionSpecs -> fully sharded over
(data, model), replicated over pods) + warmup-cosine schedule + global-norm
clipping.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.models.common import ParamSchema, is_schema_leaf, _tree_map


def init_opt_schema(param_schema):
    """m/v schemas mirroring the params (zeros, same specs, fp32)."""
    def z(p: ParamSchema) -> ParamSchema:
        return ParamSchema(p.shape, p.spec, "zeros", 0.0, jnp.float32)
    return {"m": _tree_map(z, param_schema), "v": _tree_map(z, param_schema)}


def lr_schedule(step, tcfg: TrainConfig):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum((step + 1.0) / max(tcfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - tcfg.warmup_steps)
                    / max(tcfg.total_steps - tcfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return tcfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(params, grads, opt, step, tcfg: TrainConfig
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """One AdamW step. Returns (new_params, new_opt, metrics)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, tcfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = lr_schedule(step, tcfg)
    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - tcfg.b1 ** t
    bc2 = 1.0 - tcfg.b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m = tcfg.b1 * m + (1 - tcfg.b1) * g
        v = tcfg.b2 * v + (1 - tcfg.b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        step_ = mh / (jnp.sqrt(vh) + tcfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            step_ = step_ + tcfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * step_).astype(p.dtype)
        return newp, m, v

    out = jax.tree.map(upd, params, grads, opt["m"], opt["v"])
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    metrics = {"gnorm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v}, metrics
