"""Production mesh definitions.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state. The dry-run entry point sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 *before* importing jax.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh


def _make_mesh(shape, axes) -> Mesh:
    """jax.make_mesh with explicit Auto axis types where the installed jax
    supports them (jax.sharding.AxisType landed after 0.4.x)."""
    kwargs = {}
    if hasattr(jax.sharding, "AxisType"):
        kwargs["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, **kwargs)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_mesh_for(devices: Optional[int] = None, *, model_axis: int = 1) -> Mesh:
    """Elastic mesh over the first `devices` available devices (defaults to
    all): shape (devices // model_axis, model_axis) as (data, model)."""
    n = devices if devices is not None else len(jax.devices())
    assert n % model_axis == 0, (n, model_axis)
    return _make_mesh((n // model_axis, model_axis), ("data", "model"))


def make_serve_mesh(dp: int = 1, tp: int = 1) -> Mesh:
    """(data, model) serving mesh for the tensor-parallel analog plane
    (``repro.parallel.sharding``; the ``serve --mesh DP,TP`` flag)."""
    from repro.parallel.sharding import serve_mesh
    return serve_mesh(dp, tp)
