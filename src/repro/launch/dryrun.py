"""Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh) cell
with ShapeDtypeStruct stand-ins (nothing is ever allocated), then record
memory_analysis / cost_analysis / collective traffic for the roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only|--single-pod-only]
"""
# The dry-run (and ONLY the dry-run) needs 512 placeholder devices so
# jax.make_mesh can build the production mesh. Must precede ANY jax import.
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse
import json
import sys
import time
import traceback
from dataclasses import replace

import jax

from repro.configs import ARCH_NAMES, SHAPES_BY_NAME, get_config
from repro.configs.base import ParallelConfig, TrainConfig
from repro.launch.mesh import make_production_mesh
from repro.models.common import use_mesh
from repro.runtime import steps as S

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def default_pcfg(cfg, shape, mesh_name, overrides=None):
    kw = dict(overrides or {})
    dp = 32 if mesh_name == "multi" else 16
    # Big global-attention KV caches don't fit per-device batch shards:
    # shard the cache sequence dim over the model axis (flash-decode).
    if shape.mode == "decode" and any(k == "G" for k in cfg.pattern):
        kv_bytes = (cfg.num_layers * 2 * cfg.num_kv_heads * cfg.head_dim
                    * shape.seq_len * 2 * shape.global_batch)
        if kv_bytes > 64e9:
            kw.setdefault("decode_seq_shard", True)
    # Sequence-parallel residual stream (Megatron-SP): the scan-remat stash
    # shrinks to num_periods x (B_loc, S/tp, D) -> usually no microbatching.
    if shape.mode in ("train", "prefill"):
        kw.setdefault("residual_seq_shard", True)
    # Auto grad-accum: microbatch until one microbatch's stash fits ~5 GB.
    if shape.mode == "train":
        b_loc = shape.global_batch // dp
        tp = 16 if kw.get("residual_seq_shard") else 1
        stash = cfg.num_periods * b_loc * shape.seq_len * cfg.d_model * 2 / tp
        m = 1
        while stash / m > 5e9 and m < b_loc:
            m *= 2
        if cfg.moe is not None:
            m = max(m, 4)       # MoE dispatch buffers scale with microbatch
        if m > 1:
            kw.setdefault("grad_accum", m)
    return ParallelConfig(**kw)


def build_lowerable(cfg, shape, mesh, pcfg):
    """Returns (jitted_fn, example_args) for the cell."""
    with use_mesh(mesh):
        if shape.mode == "train":
            fn = S.make_train_step(cfg, pcfg, TrainConfig())
            state = S.abstract_train_state(cfg, mesh)
            batch = S.train_batch_abstract(cfg, shape, mesh)
            jf = jax.jit(fn, donate_argnums=(0,))
            return jf, (state, batch)
        if shape.mode == "prefill":
            fn = S.make_prefill_step(cfg, pcfg)
            params = S.abstract_params_bf16(cfg, mesh)
            batch = S.prefill_batch_abstract(cfg, shape, mesh)
            jf = jax.jit(fn)
            return jf, (params, batch)
        fn = S.make_decode_step(cfg, pcfg)
        params, token, cache, pos = S.decode_inputs_abstract(
            cfg, shape, mesh, pcfg)
        jf = jax.jit(fn, donate_argnums=(2,))
        return jf, (params, token, cache, pos)


def run_cell(arch, shape_name, multi_pod, pcfg_overrides=None,
             save=True, tag=""):
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    mesh_name = "multi" if multi_pod else "single"
    if not cfg.supports_shape(shape):
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "skipped", "tag": tag,
               "reason": "full-attention arch: long-context decode has no "
                         "sub-quadratic structure (see DESIGN.md)"}
        if save:
            os.makedirs(RESULTS_DIR, exist_ok=True)
            suffix = f"_{tag}" if tag else ""
            with open(os.path.join(
                    RESULTS_DIR,
                    f"{arch}_{shape_name}_{mesh_name}{suffix}.json"), "w") as f:
                json.dump(rec, f, indent=1)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    pcfg = default_pcfg(cfg, shape, mesh_name, pcfg_overrides)
    t0 = time.time()
    with use_mesh(mesh):
        jf, args = build_lowerable(cfg, shape, mesh, pcfg)
        lowered = jf.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()

    from benchmarks.hlo_analysis import analyze_hlo
    ana = analyze_hlo(hlo)

    n_chips = mesh.devices.size
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "tag": tag,
        "status": "ok",
        "n_chips": int(n_chips),
        "pcfg": {k: getattr(pcfg, k) for k in
                 ("remat", "decode_seq_shard", "attn_block_kv", "xent_chunk",
                  "scan_chunk", "grad_compression")},
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
        },
        "xla_cost": {"flops": float(cost.get("flops", -1)),
                     "bytes": float(cost.get("bytes accessed", -1))},
        "hlo_analysis": ana,
        "params": int(cfg.param_count()),
        "active_params": int(cfg.active_param_count()),
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "mode": shape.mode,
    }
    if save:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        suffix = f"_{tag}" if tag else ""
        path = os.path.join(RESULTS_DIR,
                            f"{arch}_{shape_name}_{mesh_name}{suffix}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in ARCH_NAMES:
            for s in SHAPES_BY_NAME:
                cells.append((a, s))
    else:
        assert args.arch and args.shape
        cells.append((args.arch, args.shape))

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            label = f"{arch} x {shape} x {'multi' if mp else 'single'}"
            try:
                rec = run_cell(arch, shape, mp, tag=args.tag)
                if rec["status"] == "skipped":
                    print(f"[SKIP] {label}: {rec['reason']}", flush=True)
                else:
                    ana = rec["hlo_analysis"]
                    print(f"[OK]   {label}: compile={rec['compile_s']}s "
                          f"flops/dev={ana['flops']:.3e} "
                          f"hbm/dev={ana['hbm_bytes']:.3e} "
                          f"coll/dev={ana['collective_bytes']:.3e} "
                          f"temp={rec['memory']['temp_bytes']/1e9:.2f}GB",
                          flush=True)
            except Exception as e:
                failures += 1
                print(f"[FAIL] {label}: {type(e).__name__}: {e}", flush=True)
                traceback.print_exc()
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
