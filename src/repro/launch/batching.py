"""Continuous batching: many concurrent requests through ONE compiled
decode call (docs/serving.md).

``ServeSession`` (repro.launch.serve) serves one batched request at a
time.  This module adds the serving plane above it, per the ROADMAP's
"continuous batching" item:

  * ``ContinuousBatchEngine`` -- a fixed-slot batch scheduler over a
    session's compiled model.  Each of ``max_slots`` request slots owns
    one row of a shared KV cache; every scheduler tick packs all live
    slots (each at its OWN sequence position) into a single batched
    decode call, so admitting / finishing requests never retraces.
    With an analog executor, per-site ``DeploymentState``s thread
    through the batched calls exactly as in ``ServeSession`` --
    corner/age/remap swaps stay zero-recompile under a
    ``RecompileSentinel`` (the engine exposes ``prefill_traces`` /
    ``decode_traces`` like a session).

  * ``KVPagePool`` -- page-granular bookkeeping of the KV budget.
    Admission reserves every page a request can touch
    (``prompt + max_new``); a full pool makes ``submit()`` queue and
    ``try_admit`` refuse -- that is the backpressure signal.  The
    physical cache stays a dense per-slot row (the compiled call is
    shape-stable); the pool is the allocator surface the invariant
    tests drive (no page leaked, none double-assigned).

  * ``AsyncBatchServer`` -- an async facade: ``await server.generate()``
    from many tasks; a background thread runs the engine loop and
    resolves futures as requests finish.

Prefill runs in one of two modes:

  * ``"bulk"`` (default): an admitted request prefills its whole prompt
    in one (1, P) compiled call and the resulting cache row is spliced
    into the slot.  Per-row arithmetic is IDENTICAL to a batch=1
    ``ServeSession`` -- batched serving is bit-identical to sequential
    serving (tests/test_serve_loop.py).  One compile per distinct
    prompt length (a sentinel watching ``prefill_traces`` budgets the
    bucket count).

  * ``"packed"``: prompt tokens are fed one per tick through the SAME
    batched decode call as everyone else's decode steps -- mixed
    prefill+decode batches with exactly ONE compiled program and zero
    prefill compiles.  Token-level attention is mathematically equal
    but not bitwise equal to flash prefill, so bulk mode is the one
    used for bit-identity checks.

Sampling is greedy (argmax), matching ``ServeSession`` at
``temperature=0`` -- determinism is what the bit-identity and
scheduler-invariant tests rest on.
"""
from __future__ import annotations

import collections
import itertools
import queue as _queue
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.obs import OBS

_ENGINE_IDS = itertools.count()

QUEUED, PREFILL, RUNNING, DONE, CANCELLED = (
    "queued", "prefill", "running", "done", "cancelled")


class QueueFull(RuntimeError):
    """Backpressure: the engine's admission queue is at capacity."""


# --------------------------------------------------------------------------- #
# KV page pool
# --------------------------------------------------------------------------- #
class KVPagePool:
    """Page-granular allocator over the per-slot KV budget.

    ``total_pages`` pages of ``page_size`` cache positions each.  A
    request slot reserves ``ceil(max_seq / page_size)`` pages at
    admission and returns them all on finish/cancel/evict -- reserving
    up front (rather than faulting pages in mid-decode) means a decode
    step can never fail on allocation, so backpressure acts only at the
    admission edge.  Invariants (``check()``; property-tested):

      * every page is either free or owned by exactly one slot;
      * ``len(free) + sum(owned) == total_pages`` (nothing leaks);
      * no page id appears twice anywhere.
    """

    def __init__(self, n_slots: int, max_seq: int, page_size: int = 16,
                 total_pages: Optional[int] = None):
        self.page_size = max(1, int(page_size))
        self.pages_per_slot = -(-int(max_seq) // self.page_size)
        self.total_pages = (int(total_pages) if total_pages is not None
                            else n_slots * self.pages_per_slot)
        self.free: set = set(range(self.total_pages))
        self.owned: Dict[int, List[int]] = {}

    def pages_for(self, seq_len: int) -> int:
        return -(-max(0, int(seq_len)) // self.page_size)

    def can_admit(self, seq_len: int) -> bool:
        return len(self.free) >= self.pages_for(seq_len)

    def reserve(self, slot: int, seq_len: int) -> bool:
        """All-or-nothing reservation for a request of ``seq_len``."""
        n = self.pages_for(seq_len)
        if slot in self.owned or len(self.free) < n:
            return False
        pages = [self.free.pop() for _ in range(n)]
        self.owned[slot] = pages
        return True

    def release(self, slot: int) -> List[int]:
        pages = self.owned.pop(slot, [])
        self.free.update(pages)
        return pages

    def in_use(self) -> int:
        return sum(len(p) for p in self.owned.values())

    def check(self) -> None:
        seen: List[int] = sorted(self.free)
        for pages in self.owned.values():
            seen.extend(pages)
        assert len(seen) == len(set(seen)), "page double-assigned"
        assert sorted(seen) == list(range(self.total_pages)), "page leaked"


# --------------------------------------------------------------------------- #
# Requests
# --------------------------------------------------------------------------- #
@dataclass
class Request:
    rid: int
    prompt: np.ndarray                      # (P,) int32
    max_new: int
    status: str = QUEUED
    slot: int = -1
    next_pos: int = 0                       # next cache position to write
    out: List[int] = field(default_factory=list)
    t_submit: float = 0.0
    t_first: Optional[float] = None         # time-to-first-token edge
    t_done: Optional[float] = None

    @property
    def done(self) -> bool:
        return self.status in (DONE, CANCELLED)

    def tokens(self) -> np.ndarray:
        return np.asarray(self.out, np.int32)


# --------------------------------------------------------------------------- #
# Engine
# --------------------------------------------------------------------------- #
class ContinuousBatchEngine:
    """Fixed-slot continuous-batching scheduler over a ``ServeSession``.

    The session supplies the model (params, compiled step fns, analog
    executor + state threading); the engine owns the multi-request
    cache, the slot scheduler and the page pool.  Typical use::

        sess = ServeSession("gemma3-1b", executor=ex, ...)
        eng = ContinuousBatchEngine(sess, max_slots=8)
        rids = [eng.submit(p, max_new=16) for p in prompts]
        eng.drain()
        tokens = [eng.result(r) for r in rids]

    ``step()`` is one scheduler tick: admit from the queue while pages
    and slots allow, then run ONE batched decode over every live slot.
    All compiled calls are shape-stable in ``max_slots``, so the tick
    never retraces as requests come and go (``decode_traces`` stays 1;
    the engine plugs into ``RecompileSentinel(session=engine)``).
    """

    def __init__(self, session, *, max_slots: int = 8,
                 max_len: Optional[int] = None, page_size: int = 16,
                 total_pages: Optional[int] = None,
                 prefill_mode: str = "bulk", max_queue: int = 256):
        import jax
        import jax.numpy as jnp
        self._jax, self._jnp = jax, jnp
        cfg = session.cfg
        assert cfg.frontend != "vision" and not cfg.encoder_layers, \
            "continuous batching serves token-only decoder models"
        assert prefill_mode in ("bulk", "packed"), prefill_mode
        self.session = session
        self.cfg = cfg
        self.max_slots = int(max_slots)
        self.max_len = int(max_len if max_len is not None
                           else session.P + session.G)
        self.prefill_mode = prefill_mode
        self.max_queue = int(max_queue)
        self.pool = KVPagePool(self.max_slots, self.max_len,
                               page_size=page_size, total_pages=total_pages)
        self.site = f"batch:{cfg.name}#{next(_ENGINE_IDS)}"

        self._rid = itertools.count()
        self.requests: Dict[int, Request] = {}
        self.queue: collections.deque = collections.deque()
        self.slots: List[Optional[int]] = [None] * self.max_slots   # rid
        self.prefill_traces = 0
        self.decode_traces = 0
        self._states: Optional[dict] = None
        self._cache = None
        self._build()

    # ------------------------------------------------------------------ #
    # Compiled calls (shape-stable in max_slots)
    # ------------------------------------------------------------------ #
    def _build(self):
        jax = self._jax
        from repro.models import model as M
        cs = M.model_cache_schema(self.cfg, self.max_slots, self.max_len)
        self._cache_schema = cs

        def run_decode(tok, cache, pos, states):
            self.decode_traces += 1             # trace-time side effect
            if OBS.enabled:
                OBS.counter("serve_traces_total",
                            "jit traces of the serving steps (a healthy "
                            "sweep holds this at 1 per step)",
                            site=self.site, step="batch_decode").inc()
            with self.session._bound(states):
                return self.session._decode_step(
                    self.session.params, tok, cache, pos)

        def run_prefill(b, states):
            self.prefill_traces += 1
            if OBS.enabled:
                OBS.counter("serve_traces_total",
                            "jit traces of the serving steps (a healthy "
                            "sweep holds this at 1 per step)",
                            site=self.site, step="bulk_prefill").inc()
            with self.session._bound(states):
                return self.session._prefill_step(self.session.params, b)

        def splice(cache, pc, slot):
            """Write a (1, ...) prefill cache into a slot's row.  Scan
            leaves are (n_periods, B, ...); tail leaves are (B, ...)."""
            def row(z, c, axis):
                c = c.astype(z.dtype)
                start = [0] * c.ndim
                start[axis] = slot
                return jax.lax.dynamic_update_slice(z, c, tuple(start))
            return {"scan": jax.tree.map(lambda z, c: row(z, c, 1),
                                         cache["scan"], pc["scan"]),
                    "tail": jax.tree.map(lambda z, c: row(z, c, 0),
                                         cache["tail"], pc["tail"])}

        def reset_slot(cache, slot):
            """Zero a slot's row (packed admission: the row may hold the
            previous occupant's recurrent/SSM state)."""
            return {"scan": jax.tree.map(lambda z: z.at[:, slot].set(0),
                                         cache["scan"]),
                    "tail": jax.tree.map(lambda z: z.at[slot].set(0),
                                         cache["tail"])}

        self._decode = jax.jit(run_decode, donate_argnums=(1,))
        self._prefill = jax.jit(run_prefill)
        self._splice = jax.jit(splice, donate_argnums=(0,))
        self._reset = jax.jit(reset_slot, donate_argnums=(0,))
        self.jit_fns = (self._decode, self._prefill, self._splice,
                        self._reset)
        self._fresh_cache()

    def _fresh_cache(self):
        from repro.models import model as M
        self._cache = M.zeros_cache(self._cache_schema)

    # ------------------------------------------------------------------ #
    # States (analog device-state threading, as in ServeSession)
    # ------------------------------------------------------------------ #
    def refresh_states(self, states: Optional[dict] = None) -> None:
        """Re-materialize per-site ``DeploymentState``s from the
        session's executor (call after ``ex.deploy(...)`` mid-run: the
        swap applies from the next tick, with zero recompiles).

        Explicitly passed ``states`` (e.g. host arrays from
        ``load_deployment``) are placed onto the executor's serving mesh
        first, so a mid-run hot-swap keeps the compiled tick's input
        shardings stable (docs/parallel.md)."""
        if states is not None:
            if self.session.threading:
                states = self.session.ex.shard_states(states)
            self._states = states
        else:
            self._states = (self.session.states()
                            if self.session.threading else {})

    def _st(self) -> dict:
        if self._states is None:
            self.refresh_states()
        return self._states

    # ------------------------------------------------------------------ #
    # Request lifecycle
    # ------------------------------------------------------------------ #
    def submit(self, prompt, max_new: int) -> int:
        """Enqueue a request; returns its rid.  Raises ``QueueFull``
        past ``max_queue`` waiting requests (backpressure)."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        assert prompt.size >= 1, "empty prompt"
        assert prompt.size + max_new <= self.max_len, \
            f"prompt+max_new {prompt.size + max_new} > max_len {self.max_len}"
        if len(self.queue) >= self.max_queue:
            raise QueueFull(f"admission queue at capacity {self.max_queue}")
        rid = next(self._rid)
        self.requests[rid] = Request(rid=rid, prompt=prompt,
                                     max_new=int(max_new),
                                     t_submit=time.monotonic())
        self.queue.append(rid)
        if OBS.enabled:
            OBS.gauge("serve_queue_depth",
                      "requests waiting for a slot (admission backlog)",
                      site=self.site).set(len(self.queue))
        return rid

    def cancel(self, rid: int) -> None:
        """Drop a request.  Queued: removed; live: its slot and pages
        free immediately (tokens produced so far are kept)."""
        req = self.requests[rid]
        if req.done:
            return
        if req.status == QUEUED:
            self.queue.remove(rid)
        else:
            self.slots[req.slot] = None
            self.pool.release(req.slot)
        req.status = CANCELLED
        req.t_done = time.monotonic()
        self._account_finish(req, outcome="cancelled")

    def result(self, rid: int) -> np.ndarray:
        req = self.requests[rid]
        assert req.done, f"request {rid} still {req.status}"
        return req.tokens()

    def _account_finish(self, req: Request, outcome: str) -> None:
        if not OBS.enabled:
            return
        OBS.counter("serve_requests_total",
                    "requests leaving the engine, by outcome",
                    site=self.site, outcome=outcome).inc()
        OBS.histogram("serve_request_latency_seconds",
                      "submit -> last token, per request",
                      site=self.site, arch=self.cfg.name).observe(
                          (req.t_done or 0.0) - req.t_submit)
        if req.t_first is not None:
            OBS.histogram("serve_request_ttft_seconds",
                          "submit -> first generated token, per request",
                          site=self.site, arch=self.cfg.name).observe(
                              req.t_first - req.t_submit)
        OBS.gauge("serve_kv_pages_in_use",
                  "KV pages currently reserved by live request slots",
                  site=self.site).set(self.pool.in_use())

    # ------------------------------------------------------------------ #
    # Scheduler tick
    # ------------------------------------------------------------------ #
    def _free_slot(self) -> int:
        for i, rid in enumerate(self.slots):
            if rid is None:
                return i
        return -1

    def try_admit(self) -> int:
        """Admit queued requests while a slot AND pages are available.
        Returns the number admitted this tick."""
        n = 0
        while self.queue:
            slot = self._free_slot()
            if slot < 0:
                break
            req = self.requests[self.queue[0]]
            need = req.prompt.size + req.max_new
            if not self.pool.reserve(slot, need):
                break                      # backpressure: pool exhausted
            self.queue.popleft()
            self.slots[slot] = req.rid
            req.slot, req.next_pos = slot, 0
            if self.prefill_mode == "bulk":
                self._bulk_prefill(req)
            else:
                self._cache = self._reset(self._cache, self._jnp.asarray(
                    slot, self._jnp.int32))
                req.status = PREFILL
            n += 1
        if OBS.enabled and n:
            OBS.gauge("serve_queue_depth",
                      "requests waiting for a slot (admission backlog)",
                      site=self.site).set(len(self.queue))
        return n

    def _bulk_prefill(self, req: Request) -> None:
        jnp = self._jnp
        P = req.prompt.size
        logits, pcache = self._prefill(
            {"tokens": jnp.asarray(req.prompt[None, :])}, self._st())
        self._cache = self._splice(self._cache, pcache,
                                   jnp.asarray(req.slot, jnp.int32))
        tok = int(np.argmax(np.asarray(logits[0], np.float32)))
        req.out.append(tok)
        req.next_pos = P
        req.t_first = time.monotonic()
        req.status = RUNNING
        if OBS.enabled:
            OBS.counter("serve_engine_tokens_total",
                        "tokens through the engine (prompt + generated)",
                        site=self.site, kind="prefill").inc(P)
        if len(req.out) >= req.max_new:
            self._finish(req)

    def _finish(self, req: Request) -> None:
        self.slots[req.slot] = None
        self.pool.release(req.slot)
        req.status = DONE
        req.t_done = time.monotonic()
        self._account_finish(req, outcome="done")

    def step(self) -> List[Request]:
        """One scheduler tick: admit, then one batched decode over all
        live slots.  Returns the requests that finished this tick."""
        jnp = self._jnp
        self.try_admit()
        live = [(i, self.requests[rid]) for i, rid in enumerate(self.slots)
                if rid is not None]
        if not live:
            return []
        tok = np.zeros((self.max_slots, 1), np.int32)
        pos = np.zeros((self.max_slots,), np.int32)
        for i, req in live:
            if req.status == PREFILL:
                tok[i, 0] = req.prompt[req.next_pos]
            else:
                tok[i, 0] = req.out[-1]
            pos[i] = req.next_pos
        if OBS.enabled:
            OBS.gauge("serve_slots_active",
                      "live request slots this tick", site=self.site) \
                .set(len(live))
            OBS.histogram("serve_batch_occupancy",
                          "live slots per batched decode tick "
                          "(out of max_slots)", site=self.site,
                          slots=str(self.max_slots)).observe(len(live))
        logits, self._cache = self._decode(
            jnp.asarray(tok), self._cache, jnp.asarray(pos), self._st())
        largs = np.argmax(np.asarray(logits, np.float32), axis=-1)

        finished: List[Request] = []
        n_dec = 0
        for i, req in live:
            req.next_pos += 1
            if req.status == PREFILL:
                if req.next_pos >= req.prompt.size:   # prompt consumed:
                    req.out.append(int(largs[i]))     # first generated tok
                    req.t_first = time.monotonic()
                    req.status = RUNNING
                    n_dec += 1
            else:
                req.out.append(int(largs[i]))
                n_dec += 1
            if req.status == RUNNING and len(req.out) >= req.max_new:
                self._finish(req)
                finished.append(req)
        if OBS.enabled and n_dec:
            OBS.counter("serve_engine_tokens_total",
                        "tokens through the engine (prompt + generated)",
                        site=self.site, kind="decode").inc(n_dec)
        return finished

    @property
    def busy(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.slots)

    def drain(self) -> None:
        while self.busy:
            self.step()

    def run(self, prompts: Sequence, max_new: int) -> List[np.ndarray]:
        """Convenience: submit all, drain, collect in submit order."""
        rids = [self.submit(p, max_new) for p in prompts]
        self.drain()
        return [self.result(r) for r in rids]


# --------------------------------------------------------------------------- #
# Async facade
# --------------------------------------------------------------------------- #
class AsyncBatchServer:
    """Async request front-end over a ``ContinuousBatchEngine``.

    A single background thread owns the engine (jax dispatch stays
    single-threaded); callers hand prompts over a bounded thread-safe
    queue and get back futures::

        server = AsyncBatchServer(engine)
        server.start()
        toks = await server.generate(prompt, max_new=16)   # asyncio
        toks = server.submit(prompt, 16).result()          # threads
        server.stop()

    A full intake queue raises ``QueueFull`` -- backpressure propagates
    to the caller rather than growing unbounded buffers.
    """

    def __init__(self, engine: ContinuousBatchEngine,
                 intake: Optional[int] = None, idle_sleep: float = 0.002):
        import concurrent.futures as _f
        self._futures = _f
        self.engine = engine
        self._intake: _queue.Queue = _queue.Queue(
            maxsize=intake if intake is not None else engine.max_queue)
        self._pending: Dict[int, object] = {}       # rid -> Future
        self._idle_sleep = idle_sleep
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def start(self) -> "AsyncBatchServer":
        assert self._thread is None, "already started"
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop,
                                        name="serve-batch-loop", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def __enter__(self) -> "AsyncBatchServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def submit(self, prompt, max_new: int):
        """Thread-safe submit; returns a ``concurrent.futures.Future``
        resolving to the request's generated tokens (np.int32)."""
        fut = self._futures.Future()
        try:
            self._intake.put_nowait((np.asarray(prompt, np.int32), max_new,
                                     fut))
        except _queue.Full:
            raise QueueFull("server intake queue full") from None
        return fut

    async def generate(self, prompt, max_new: int):
        import asyncio
        return await asyncio.wrap_future(self.submit(prompt, max_new))

    def _loop(self) -> None:
        eng = self.engine
        while not self._stop.is_set():
            moved = False
            while True:                    # intake -> engine queue
                try:
                    prompt, max_new, fut = self._intake.get_nowait()
                except _queue.Empty:
                    break
                try:
                    rid = eng.submit(prompt, max_new)
                    self._pending[rid] = fut
                    moved = True
                except Exception as e:     # backpressure / bad request
                    fut.set_exception(e)
            if eng.busy:
                for req in eng.step():
                    fut = self._pending.pop(req.rid, None)
                    if fut is not None:
                        fut.set_result(req.tokens())
            elif not moved:
                time.sleep(self._idle_sleep)
        # resolve what we can on shutdown; cancel the rest
        for rid, fut in list(self._pending.items()):
            req = eng.requests.get(rid)
            if req is not None and req.done:
                fut.set_result(req.tokens())
            else:
                fut.cancel()
        self._pending.clear()
