"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b --steps 200 \
      --reduced --backend digital [--analog-layers mlp]

Real configs need a real fleet; on this CPU host use --reduced (same code
path, small model). --devices N simulates an N-device pod via host devices
(set before jax initializes).
"""
import argparse
import os
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--devices", type=int, default=0,
                    help="simulate N host devices (0 = real devices)")
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--backend", default="digital",
                    choices=["digital", "analytic", "circuit", "emulator"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--log", default="")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", ""))

    import dataclasses
    import jax
    from repro.configs import get_config, reduced
    from repro.configs.base import ParallelConfig, TrainConfig
    from repro.data import SyntheticLMData
    from repro.launch.mesh import make_mesh_for
    from repro.runtime.trainer import Trainer

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    cfg = dataclasses.replace(
        cfg, analog=dataclasses.replace(cfg.analog,
                                        enabled=args.backend != "digital",
                                        backend=args.backend))
    pcfg = ParallelConfig(attn_block_kv=min(1024, args.seq_len),
                          xent_chunk=min(2048, args.seq_len),
                          scan_chunk=min(256, args.seq_len))
    tcfg = TrainConfig(lr=args.lr, total_steps=args.steps,
                       warmup_steps=max(1, args.steps // 20),
                       checkpoint_every=max(10, args.steps // 5))
    mesh = make_mesh_for(model_axis=args.model_axis) \
        if len(jax.devices()) > 1 else None
    data = SyntheticLMData(cfg, args.seq_len, args.global_batch)

    hook = None
    if cfg.analog.enabled:
        from repro.core.analog import AnalogExecutor
        from repro.core.emulator import train_emulator
        from repro.configs.rram_ps32 import CASE_A, EmulatorTrainConfig
        from repro.core.circuit import CircuitParams
        ex = AnalogExecutor(acfg=cfg.analog, geom=CASE_A)
        if args.backend == "emulator":
            print("training emulator for the analog backend ...", flush=True)
            res = train_emulator(jax.random.PRNGKey(0), CASE_A, cfg.analog,
                                 CircuitParams(),
                                 EmulatorTrainConfig(n_train=4000, n_test=500,
                                                     epochs=40,
                                                     lr_halve_at=(20, 30)))
            ex.emulator_params = res.params
        hook = ex.hook

    trainer = Trainer(cfg=cfg, pcfg=pcfg, tcfg=tcfg, mesh=mesh, data=data,
                      ckpt_dir=args.ckpt_dir, log_path=args.log or None)
    from repro.models.common import use_dense_hook
    import contextlib
    ctx = use_dense_hook(hook) if hook else contextlib.nullcontext()
    with ctx:
        summary = trainer.run(args.steps)
    print("SUMMARY:", summary)
    losses = [m["loss"] for m in trainer.metrics_log]
    if len(losses) >= 10:
        print(f"loss first10 {sum(losses[:10])/10:.4f} "
              f"last10 {sum(losses[-10:])/10:.4f}")


if __name__ == "__main__":
    main()
