"""Serving launcher: batched prefill + decode loop with a KV cache.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""
import argparse
import os
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0,
                    help="PRNG seed; init/prompt/sampling/device-noise each "
                         "get their own derived key, so noisy-scenario "
                         "inference is reproducible")
    ap.add_argument("--analog-backend", default="digital",
                    choices=["digital", "analytic", "circuit", "emulator"],
                    help="route MLP projections through the analog fast path")
    ap.add_argument("--emulator-params", default=None,
                    help="npz with trained Conv4Xbar params (benchmarks cache "
                         "format); required for --analog-backend=emulator")
    ap.add_argument("--scenario", default=None,
                    help="device non-ideality scenario name from the "
                         "repro.nonideal registry (e.g. prog_mild, stressed); "
                         "requires a non-digital --analog-backend")
    ap.add_argument("--age", type=float, default=None,
                    help="seconds since the fleet was programmed: overrides "
                         "the scenario's drift_t (serve an aged fleet; see "
                         "docs/lifetime.md)")
    ap.add_argument("--fault-remap", action="store_true",
                    help="stuck-fault-aware column remapping: permute output "
                         "columns so large weights avoid the scenario's "
                         "stuck-off cells (requires --scenario)")
    ap.add_argument("--conditioned-emulator", action="store_true",
                    help="require --emulator-params to hold a scenario-"
                         "conditioned Conv4Xbar (peripheral width > 2): one "
                         "net serves every --scenario/--age corner with zero "
                         "retraining (docs/emulator.md)")
    args = ap.parse_args()
    if args.scenario and args.analog_backend == "digital":
        ap.error("--scenario requires a non-digital --analog-backend")
    if (args.fault_remap or args.age is not None) and not args.scenario:
        ap.error("--fault-remap / --age require --scenario")
    if args.conditioned_emulator and args.analog_backend != "emulator":
        ap.error("--conditioned-emulator requires --analog-backend=emulator")

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", ""))

    import jax
    import jax.numpy as jnp
    from repro.configs import get_config, reduced
    from repro.configs.base import ParallelConfig
    from repro.models import model as M
    from repro.runtime import steps as S

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    B, P, G = args.batch, args.prompt_len, args.gen
    total = P + G
    pcfg = ParallelConfig(attn_block_kv=min(1024, P), xent_chunk=128,
                          scan_chunk=min(256, P))

    # explicit key threading: every stochastic path (param init, prompt,
    # sampling temperature, scenario device draws) gets its own derived key
    root = jax.random.PRNGKey(args.seed)
    k_init, k_prompt, k_img, k_enc, key = jax.random.split(root, 5)
    params = S.init_train_state(k_init, cfg)["params"]
    params = jax.tree.map(lambda x: x.astype(jnp.bfloat16), params)
    prompt = jax.random.randint(k_prompt, (B, P), 0, cfg.vocab_size)

    batch = {"tokens": prompt}
    if cfg.frontend == "vision":
        batch["image_embeds"] = jax.random.normal(
            k_img, (B, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.encoder_layers:
        batch["enc_frames"] = jax.random.normal(
            k_enc, (B, P, cfg.d_model), jnp.bfloat16)

    # optional: serve the MLP projections on emulated analog hardware (the
    # SEMULATOR serving path; uses the cached-conductance-plan fast path)
    import contextlib
    hook_ctx = contextlib.nullcontext()
    if args.analog_backend != "digital":
        import numpy as np
        from repro.configs.base import AnalogConfig
        from repro.configs.rram_ps32 import CASE_A
        from repro.core.analog import AnalogExecutor
        from repro.models.common import use_dense_hook
        eparams = None
        if args.analog_backend == "emulator":
            assert args.emulator_params, \
                "--analog-backend=emulator needs --emulator-params <npz>"
            data = np.load(args.emulator_params, allow_pickle=True)
            eparams = {k: jnp.asarray(v) for k, v in data.items()
                       if not k.startswith("__")}
        ex = AnalogExecutor(
            acfg=AnalogConfig(enabled=True, backend=args.analog_backend,
                              layers=("mlp",), scenario=args.scenario),
            geom=CASE_A, emulator_params=eparams,
            fault_remap=args.fault_remap)
        if args.conditioned_emulator:
            from repro.nonideal import (N_SCENARIO_FEATURES,
                                        SCENARIO_FEATURE_NAMES)
            assert ex.emulator_conditioned, \
                "--conditioned-emulator: params are not scenario-" \
                "conditioned (peripheral width must be 2 + " \
                f"{N_SCENARIO_FEATURES}; train with " \
                "nonideal.data.train_conditioned_emulator)"
            print(f"conditioned emulator: {N_SCENARIO_FEATURES} scenario "
                  f"features ({', '.join(SCENARIO_FEATURE_NAMES[:4])}, ...)")
        if ex.scenario is not None:
            if args.age is not None:
                from repro.nonideal import scenario_at_age
                ex.scenario = scenario_at_age(ex.scenario, args.age)
            key, k_dev = jax.random.split(key)
            ex.set_scenario(ex.scenario, key=k_dev)
            print(f"analog scenario: {ex.scenario}")
        hook_ctx = use_dense_hook(ex.hook)

    # params are frozen for the whole serve loop, so close them over the
    # jitted steps instead of passing them as traced args: the analog fast
    # path then sees concrete weights at trace time and its conductance-plan
    # / precompute caches bake in as constants (instead of re-tiling inside
    # the compiled graph on every decode step)
    prefill_step = S.make_prefill_step(cfg, pcfg)
    decode_step = S.make_decode_step(cfg, pcfg)
    prefill = jax.jit(lambda b: prefill_step(params, b))
    decode = jax.jit(lambda tok, cache, pos: decode_step(params, tok, cache, pos),
                     donate_argnums=(1,))

    # keep the hook active for the whole serve loop (tracing happens at the
    # first prefill/decode call)
    stack = contextlib.ExitStack()
    stack.enter_context(hook_ctx)

    t0 = time.time()
    logits, pcache = prefill(batch)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    # build a generation cache sized for P+G and splice the prefill cache in
    cs = M.model_cache_schema(cfg, B, total,
                              cross_len=(P if cfg.encoder_layers else 0))
    cache = M.zeros_cache(cs)

    def splice(z, c):
        c = c.astype(z.dtype)
        if z.shape == c.shape:
            return c
        if z.ndim == c.ndim and z.shape[2:] == c.shape[2:] and \
                z.shape[0] == c.shape[0]:
            return jax.lax.dynamic_update_slice(
                z, c, (0,) * c.ndim)           # prompt occupies [0, P)
        if z.ndim == c.ndim and z.shape[3:] == c.shape[3:] and \
                z.shape[:2] == c.shape[:2]:
            return jax.lax.dynamic_update_slice(z, c, (0,) * c.ndim)
        return z
    cache = jax.tree.map(splice, cache, pcache)

    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.time()
    for i in range(G - 1):
        logits, cache = decode(tok, cache, jnp.asarray(P + i, jnp.int32))
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits / args.temperature, axis=-1)[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    toks = jnp.concatenate(out_tokens, axis=1)
    print(f"prefill {B}x{P}: {t_prefill*1e3:.1f} ms "
          f"({B*P/t_prefill:.0f} tok/s)")
    print(f"decode  {G-1} steps: {t_decode*1e3:.1f} ms "
          f"({B*(G-1)/max(t_decode,1e-9):.0f} tok/s)")
    print("sample tokens[0]:", toks[0, :12].tolist())


if __name__ == "__main__":
    main()
