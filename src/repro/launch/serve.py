"""Serving launcher: batched prefill + decode loop with a KV cache.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""
import argparse
import os
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", ""))

    import jax
    import jax.numpy as jnp
    from repro.configs import get_config, reduced
    from repro.configs.base import ParallelConfig
    from repro.models import model as M
    from repro.runtime import steps as S

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    B, P, G = args.batch, args.prompt_len, args.gen
    total = P + G
    pcfg = ParallelConfig(attn_block_kv=min(1024, P), xent_chunk=128,
                          scan_chunk=min(256, P))

    key = jax.random.PRNGKey(0)
    params = S.init_train_state(key, cfg)["params"]
    params = jax.tree.map(lambda x: x.astype(jnp.bfloat16), params)
    prompt = jax.random.randint(key, (B, P), 0, cfg.vocab_size)

    batch = {"tokens": prompt}
    if cfg.frontend == "vision":
        batch["image_embeds"] = jax.random.normal(
            key, (B, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.encoder_layers:
        batch["enc_frames"] = jax.random.normal(
            key, (B, P, cfg.d_model), jnp.bfloat16)

    prefill = jax.jit(S.make_prefill_step(cfg, pcfg))
    decode = jax.jit(S.make_decode_step(cfg, pcfg), donate_argnums=(2,))

    t0 = time.time()
    logits, pcache = prefill(params, batch)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    # build a generation cache sized for P+G and splice the prefill cache in
    cs = M.model_cache_schema(cfg, B, total,
                              cross_len=(P if cfg.encoder_layers else 0))
    cache = M.zeros_cache(cs)

    def splice(z, c):
        c = c.astype(z.dtype)
        if z.shape == c.shape:
            return c
        if z.ndim == c.ndim and z.shape[2:] == c.shape[2:] and \
                z.shape[0] == c.shape[0]:
            return jax.lax.dynamic_update_slice(
                z, c, (0,) * c.ndim)           # prompt occupies [0, P)
        if z.ndim == c.ndim and z.shape[3:] == c.shape[3:] and \
                z.shape[:2] == c.shape[:2]:
            return jax.lax.dynamic_update_slice(z, c, (0,) * c.ndim)
        return z
    cache = jax.tree.map(splice, cache, pcache)

    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.time()
    for i in range(G - 1):
        logits, cache = decode(params, tok, cache, jnp.asarray(P + i, jnp.int32))
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits / args.temperature, axis=-1)[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    toks = jnp.concatenate(out_tokens, axis=1)
    print(f"prefill {B}x{P}: {t_prefill*1e3:.1f} ms "
          f"({B*P/t_prefill:.0f} tok/s)")
    print(f"decode  {G-1} steps: {t_decode*1e3:.1f} ms "
          f"({B*(G-1)/max(t_decode,1e-9):.0f} tok/s)")
    print("sample tokens[0]:", toks[0, :12].tolist())


if __name__ == "__main__":
    main()
