"""Serving: batched prefill + decode with a KV cache, as a CLI and as a
programmatic ``ServeSession``.

CLI (the original launcher, now a thin wrapper over ``ServeSession``):

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --reduced \
      --batch 4 --prompt-len 32 --gen 16

``ServeSession`` is the task-level API the ROADMAP's "task-level
robustness" item asks for: it builds the model once, discovers every
analog dense() call site, and threads one ``DeploymentState`` per site
through its compiled prefill/decode steps as TRACED arguments.  A
``ScenarioSweep``-style loop can therefore swap the whole fleet's device
state between ``generate()`` calls -- corners, ages, remaps, retrained
params, recalibrations -- and the serving steps never recompile
(``decode_traces`` stays 1; asserted by ``benchmarks/bench_task.py``,
which turns this into accuracy-vs-sigma / accuracy-vs-age curves on
actual token prediction).

State threading covers scanned and unrolled layers alike.  Call sites
in Python-unrolled layers are keyed ``"<tag>#<ordinal>"`` (model tags
repeat across layers; trace order is deterministic); call sites inside
the model's ``lax.scan`` over layer periods are keyed
``"<group>.<period>:<tag>#<ordinal>"`` (``group`` is ``dec``/``enc``)
and their per-period states ride the scan as stacked xs -- a leading
layer axis on every state leaf -- so full-depth scanned models get the
same zero-recompile corner/age/remap sweeps as unrolled ones (the
legacy bake-in-at-trace-time fallback is gone).  A deployment is
serializable either way: ``--state-save`` writes the served per-site
states + spec to npz (``core.deployment.save_deployment``) and
``--state-load`` restores them verbatim in another process -- same
fleet, same age, same remap, same read-noise draw, bit-identical
tokens.

Batched multi-request serving (continuous batching, paged KV slots,
Poisson-load benchmarks) lives one level up in
``repro.launch.batching`` (docs/serving.md).
"""
import argparse
import contextlib
import itertools
import json
import os
import time
from typing import Dict, Optional

from repro.obs import OBS

# per-process serving call-site ordinal: telemetry series from two
# sessions of the same arch stay distinguishable
_SESSION_IDS = itertools.count()


class ServeSession:
    """A reusable serving session over one model + one analog executor.

    Builds params, prompt and compiled steps once; ``generate()`` runs
    prefill + greedy/temperature decode and returns tokens, per-step
    logits and timings.  With an ``executor``, the analog layers' device
    states enter the compiled steps as traced arguments (see module
    docstring); ``generate()`` re-materializes them from the executor's
    ACTIVE deployment each call, so the usage for a sweep is::

        sess = ServeSession("gemma3-1b", executor=ex, ...)
        for sigma in sigmas:
            ex.deploy(scenario=Scenario(name="s", prog_sigma=sigma), key=k)
            sess.calibrate(n=16)
            out = sess.generate()          # zero recompiles across sigmas

    With ``executor=None`` the session serves the plain digital model
    (the reference for task-level accuracy).
    """

    def __init__(self, arch: str, *, reduced: bool = True,
                 reduced_layers: Optional[int] = None, batch: int = 4,
                 prompt_len: int = 32, gen: int = 16,
                 temperature: float = 0.0, seed: int = 0, executor=None):
        import jax
        import jax.numpy as jnp
        from repro.configs import get_config, reduced as reduce_cfg
        from repro.configs.base import ParallelConfig
        from repro.runtime import steps as S
        self._jax, self._jnp = jax, jnp

        cfg = get_config(arch)
        if reduced:
            cfg = reduce_cfg(cfg, layers=reduced_layers)
        self.cfg = cfg
        self.B, self.P, self.G = batch, prompt_len, gen
        self.temperature = temperature
        self.seed = seed
        self.ex = executor
        pcfg = ParallelConfig(attn_block_kv=min(1024, prompt_len),
                              xent_chunk=128,
                              scan_chunk=min(256, prompt_len))

        # explicit key threading: every stochastic path (param init,
        # prompt, sampling) gets its own derived key
        root = jax.random.PRNGKey(seed)
        k_init, k_prompt, k_img, k_enc, self._key = jax.random.split(root, 5)
        params = S.init_train_state(k_init, cfg)["params"]
        self.params = jax.tree.map(lambda v: v.astype(jnp.bfloat16), params)
        prompt = jax.random.randint(k_prompt, (batch, prompt_len), 0,
                                    cfg.vocab_size)
        self.batch = {"tokens": prompt}
        if cfg.frontend == "vision":
            self.batch["image_embeds"] = jax.random.normal(
                k_img, (batch, cfg.frontend_tokens, cfg.d_model),
                jnp.bfloat16)
        if cfg.encoder_layers:
            self.batch["enc_frames"] = jax.random.normal(
                k_enc, (batch, prompt_len, cfg.d_model), jnp.bfloat16)

        # telemetry identity of this serving call site (docs/observability
        # .md): every session-level metric series carries site=<this>
        self.site = f"{arch}#{next(_SESSION_IDS)}"
        self._prefill_step = S.make_prefill_step(cfg, pcfg)
        self._decode_step = S.make_decode_step(cfg, pcfg)
        # per-site state threading: unrolled sites as plain traced args,
        # scanned sites as stacked lax.scan xs (see module docstring)
        self.threading = executor is not None
        self._sites: Optional[Dict[str, object]] = None
        self._steps_built = False
        self._last_states: Optional[dict] = None
        self.prefill_traces = 0
        self.decode_traces = 0

    # ------------------------------------------------------------------ #
    # Analog call-site discovery + device-state materialization
    # ------------------------------------------------------------------ #
    def sites(self) -> Dict[str, object]:
        """``site_key -> weight`` for every analog dense() call site,
        discovered once with a zero-FLOP ``jax.eval_shape`` pass (the
        model's weights are concrete; only activations are abstract)."""
        if self.ex is None:
            return {}
        if self._sites is None:
            from repro.core.analog import _StateBinding
            from repro.models.common import use_dense_hook, use_scan_states
            rec: Dict[str, object] = {}
            binding = _StateBinding(record=rec)
            with use_dense_hook(self.ex.hook), use_scan_states(binding), \
                    self.ex.bound_states(binding):
                self._jax.eval_shape(
                    lambda b: self._prefill_step(self.params, b), self.batch)
            self._sites = rec
        return self._sites

    def states(self) -> Dict[str, object]:
        """One ready-to-serve ``DeploymentState`` per call site,
        materialized from the executor's ACTIVE deployment."""
        sts = {sk: self.ex.state_for(sk, w)
               for sk, w in self.sites().items()}
        if OBS.enabled:
            for sk in sts:
                OBS.counter("serve_state_swaps_total",
                            "DeploymentStates materialized and threaded "
                            "into the compiled steps, per analog call site",
                            site=self.site, call_site=sk).inc()
        return sts

    def calibrate(self, key=None, n: int = 16,
                  warm_start: bool = False) -> None:
        """Fit every call site's volts->logical affine against digital
        under the executor's active deployment (noise-aware; reuses each
        site's ONE compiled forward across sweep points)."""
        jax = self._jax
        if key is None:
            key = jax.random.PRNGKey(self.seed + 1)
        for i, (sk, w) in enumerate(sorted(self.sites().items())):
            self.ex.calibrate(jax.random.fold_in(key, i), w, sk, n=n,
                              warm_start=warm_start)

    def save_deployment(self, path: str) -> str:
        """Serialize the last-served (or current) per-site states + the
        deployment spec to npz (``serve --state-save``)."""
        from repro.core.deployment import save_deployment
        states = self._last_states if self._last_states else self.states()
        return save_deployment(path, states, self.ex.deployment)

    # ------------------------------------------------------------------ #
    # Compiled serving steps (device states as traced arguments)
    # ------------------------------------------------------------------ #
    def _bound(self, states):
        if self.ex is None:
            return contextlib.nullcontext()
        from repro.core.analog import _StateBinding
        from repro.models.common import use_dense_hook, use_scan_states
        binding = _StateBinding(states=states)
        stack = contextlib.ExitStack()
        stack.enter_context(use_dense_hook(self.ex.hook))
        stack.enter_context(use_scan_states(binding))
        stack.enter_context(self.ex.bound_states(binding))
        return stack

    def _build_steps(self):
        jax = self._jax

        def run_prefill(b, states):
            self.prefill_traces += 1           # trace-time side effect
            if OBS.enabled:
                OBS.counter("serve_traces_total",
                            "jit traces of the serving steps (a healthy "
                            "sweep holds this at 1 per step)",
                            site=self.site, step="prefill").inc()
            with self._bound(states):
                return self._prefill_step(self.params, b)

        def run_decode(tok, cache, pos, states):
            self.decode_traces += 1
            if OBS.enabled:
                OBS.counter("serve_traces_total",
                            "jit traces of the serving steps (a healthy "
                            "sweep holds this at 1 per step)",
                            site=self.site, step="decode").inc()
            with self._bound(states):
                return self._decode_step(self.params, tok, cache, pos)

        self._prefill = jax.jit(run_prefill)
        self._decode = jax.jit(run_decode, donate_argnums=(1,))
        self._steps_built = True

    def generate(self, states: Optional[dict] = None) -> dict:
        """One prefill + greedy/temperature decode pass.

        ``states`` overrides the per-site device states (e.g. loaded from
        ``--state-load``); by default they re-materialize from the
        executor's active deployment.  Returns ``{"tokens": (B, G) int
        array, "logits": (G, B, V) float array, "prefill_s", "decode_s"}``.
        Repeated calls with swapped deployments reuse the same compiled
        steps (``prefill_traces`` / ``decode_traces`` stay 1)."""
        jax, jnp = self._jax, self._jnp
        import numpy as np
        from repro.models import model as M
        if not self._steps_built:
            self._build_steps()
        if states is None:
            states = self.states() if self.threading else {}
        self._last_states = states
        B, P, G = self.B, self.P, self.G
        total = P + G

        t0 = time.time()
        logits, pcache = self._prefill(self.batch, states)
        logits.block_until_ready()
        t_prefill = time.time() - t0
        if OBS.enabled:
            OBS.histogram("serve_prefill_seconds",
                          "full prefill wall clock (synchronized) per "
                          "serving call site", site=self.site,
                          arch=self.cfg.name).observe(t_prefill)

        # build a generation cache sized for P+G, splice the prefill cache
        cs = M.model_cache_schema(
            self.cfg, B, total,
            cross_len=(P if self.cfg.encoder_layers else 0))
        cache = M.zeros_cache(cs)

        def splice(z, c):
            c = c.astype(z.dtype)
            if z.shape == c.shape:
                return c
            if z.ndim == c.ndim and z.shape[2:] == c.shape[2:] and \
                    z.shape[0] == c.shape[0]:
                return jax.lax.dynamic_update_slice(
                    z, c, (0,) * c.ndim)       # prompt occupies [0, P)
            if z.ndim == c.ndim and z.shape[3:] == c.shape[3:] and \
                    z.shape[:2] == c.shape[:2]:
                return jax.lax.dynamic_update_slice(z, c, (0,) * c.ndim)
            return z
        cache = jax.tree.map(splice, cache, pcache)

        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        # keep logits on device inside the timed loop (a host transfer
        # per step would serialize the dispatch pipeline); convert once
        # at the end
        out_tokens, out_logits = [tok], [logits]
        t0 = time.time()
        for i in range(G - 1):
            ts = time.perf_counter() if OBS.enabled else 0.0
            logits, cache = self._decode(tok, cache,
                                         jnp.asarray(P + i, jnp.int32),
                                         states)
            if OBS.enabled:
                # per-step DISPATCH latency: deliberately no
                # block_until_ready inside the loop (a host sync per
                # step would serialize the dispatch pipeline -- see the
                # comment above); the synchronized total lands in
                # serve_decode_seconds below
                OBS.histogram("serve_decode_step_seconds",
                              "per-step decode dispatch latency (host "
                              "side, no device sync)", site=self.site,
                              arch=self.cfg.name).observe(
                                  time.perf_counter() - ts)
            if self.temperature > 0:
                self._key, sub = jax.random.split(self._key)
                tok = jax.random.categorical(
                    sub, logits / self.temperature,
                    axis=-1)[:, None].astype(jnp.int32)
            else:
                tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            out_tokens.append(tok)
            out_logits.append(logits)
        jax.block_until_ready(tok)
        t_decode = time.time() - t0
        if OBS.enabled:
            OBS.histogram("serve_decode_seconds",
                          "full decode-loop wall clock (synchronized) per "
                          "serving call site", site=self.site,
                          arch=self.cfg.name).observe(t_decode)
            OBS.counter("serve_tokens_total",
                        "tokens served (prompt + generated)",
                        site=self.site, arch=self.cfg.name).inc(
                            B * (P + G))
        return {"tokens": np.asarray(jnp.concatenate(out_tokens, axis=1)),
                "logits": np.stack([np.asarray(l, np.float32)
                                    for l in out_logits]),
                "prefill_s": t_prefill, "decode_s": t_decode}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--layers", type=int, default=None,
                    help="reduced layer count override (below the arch's "
                         "pattern length the layers unroll; state "
                         "threading and --state-save/--state-load work "
                         "for scanned and unrolled layers alike)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--mesh", default=None, metavar="DP,TP",
                    help="serve the analog plane tensor-parallel on a "
                         "(data, model) mesh of this shape: DeploymentState "
                         "leaves shard over the tile lattice and the bitline "
                         "reduction runs as one psum (docs/parallel.md); "
                         "requires a non-digital --analog-backend and "
                         "DP*TP available devices (combine with --devices "
                         "to force host devices)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0,
                    help="PRNG seed; init/prompt/sampling/device-noise each "
                         "get their own derived key, so noisy-scenario "
                         "inference is reproducible")
    ap.add_argument("--analog-backend", default="digital",
                    choices=["digital", "analytic", "circuit", "emulator"],
                    help="route MLP projections through the analog fast path")
    ap.add_argument("--emulator-params", default=None,
                    help="npz with trained Conv4Xbar params (benchmarks cache "
                         "format); required for --analog-backend=emulator")
    ap.add_argument("--scenario", default=None,
                    help="device non-ideality scenario name from the "
                         "repro.nonideal registry (e.g. prog_mild, stressed); "
                         "requires a non-digital --analog-backend")
    ap.add_argument("--age", type=float, default=None,
                    help="seconds since the fleet was programmed: ages the "
                         "scenario's drift_t (serve an aged fleet; see "
                         "docs/lifetime.md)")
    ap.add_argument("--fault-remap", action="store_true",
                    help="stuck-fault-aware column remapping: permute output "
                         "columns so large weights avoid the scenario's "
                         "stuck-off cells (requires --scenario)")
    ap.add_argument("--conditioned-emulator", action="store_true",
                    help="require --emulator-params to hold a scenario-"
                         "conditioned Conv4Xbar (peripheral width > 2): one "
                         "net serves every --scenario/--age corner with zero "
                         "retraining (docs/emulator.md)")
    ap.add_argument("--state-save", default=None, metavar="NPZ",
                    help="after serving, write the deployment (per-site "
                         "DeploymentStates + spec) to this npz so another "
                         "process can reproduce it with --state-load")
    ap.add_argument("--state-load", default=None, metavar="NPZ",
                    help="serve a deployment saved with --state-save: the "
                         "per-site device states (fleet draw, age, remap, "
                         "read keys, calibration) are restored verbatim")
    ap.add_argument("--telemetry", nargs="?", const="-", default=None,
                    metavar="PATH",
                    help="enable the metrics registry for this run and dump "
                         "the JSON snapshot on exit -- to PATH, or to stdout "
                         "when the flag is given bare (docs/observability.md)")
    args = ap.parse_args()
    if args.telemetry is not None:
        OBS.enable()
    if args.scenario and args.analog_backend == "digital":
        ap.error("--scenario requires a non-digital --analog-backend")
    if (args.fault_remap or args.age is not None) and not args.scenario:
        ap.error("--fault-remap / --age require --scenario")
    if args.conditioned_emulator and args.analog_backend != "emulator":
        ap.error("--conditioned-emulator requires --analog-backend=emulator")
    if (args.state_save or args.state_load) \
            and args.analog_backend == "digital":
        ap.error("--state-save/--state-load require a non-digital "
                 "--analog-backend")
    if args.mesh is not None and args.analog_backend == "digital":
        ap.error("--mesh shards the analog plane and requires a "
                 "non-digital --analog-backend")

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", ""))

    import jax
    import jax.numpy as jnp

    # optional: serve the MLP projections on emulated analog hardware (the
    # SEMULATOR serving path; uses the cached-conductance-plan fast path)
    ex = None
    loaded_states = None
    mesh = None
    if args.mesh is not None:
        from repro.launch.mesh import make_serve_mesh
        try:
            dp, tp = (int(v) for v in args.mesh.split(","))
        except ValueError:
            ap.error(f"--mesh expects DP,TP (got {args.mesh!r})")
        mesh = make_serve_mesh(dp, tp)
        print(f"serving mesh: (data, model) = ({dp}, {tp})")
    if args.analog_backend != "digital":
        import numpy as np
        from repro.configs.base import AnalogConfig
        from repro.configs.rram_ps32 import CASE_A
        from repro.core.analog import AnalogExecutor
        eparams = None
        if args.analog_backend == "emulator":
            assert args.emulator_params, \
                "--analog-backend=emulator needs --emulator-params <npz>"
            data = np.load(args.emulator_params, allow_pickle=True)
            eparams = {k: jnp.asarray(v) for k, v in data.items()
                       if not k.startswith("__")}
        ex = AnalogExecutor(
            acfg=AnalogConfig(enabled=True, backend=args.analog_backend,
                              layers=("mlp",)),
            geom=CASE_A, emulator_params=eparams, mesh=mesh)
        if args.conditioned_emulator:
            from repro.nonideal import (N_SCENARIO_FEATURES,
                                        SCENARIO_FEATURE_NAMES)
            assert ex.emulator_conditioned, \
                "--conditioned-emulator: params are not scenario-" \
                "conditioned (peripheral width must be 2 + " \
                f"{N_SCENARIO_FEATURES}; train with " \
                "nonideal.data.train_conditioned_emulator)"
            print(f"conditioned emulator: {N_SCENARIO_FEATURES} scenario "
                  f"features ({', '.join(SCENARIO_FEATURE_NAMES[:4])}, ...)")
        if args.state_load:
            from repro.core.deployment import load_deployment
            # executor=ex: loaded host arrays land straight on the serving
            # mesh (re-shard-on-load; the npz records values, not placements)
            loaded_states, dep = load_deployment(args.state_load, executor=ex)
            ex.deploy(scenario=dep.scenario, key=dep.key, remap=dep.remap,
                      states=dep.states)
            print(f"deployment restored: {len(loaded_states)} call sites "
                  f"from {args.state_load}")
        elif args.scenario:
            from repro.nonideal import get_scenario
            k_dev = jax.random.fold_in(jax.random.PRNGKey(args.seed), 0xDEF)
            ex.deploy(scenario=get_scenario(args.scenario), age=args.age,
                      remap=args.fault_remap, key=k_dev)
            print(f"analog scenario: {ex.scenario}")

    sess = ServeSession(args.arch, reduced=args.reduced,
                        reduced_layers=args.layers, batch=args.batch,
                        prompt_len=args.prompt_len, gen=args.gen,
                        temperature=args.temperature, seed=args.seed,
                        executor=ex)
    from repro.obs import RecompileSentinel
    with RecompileSentinel(session=sess, executor=ex, strict=False,
                           label="serve") as sent:
        out = sess.generate(states=loaded_states)

    B, P, G = args.batch, args.prompt_len, args.gen
    print(f"prefill {B}x{P}: {out['prefill_s']*1e3:.1f} ms "
          f"({B*P/out['prefill_s']:.0f} tok/s)")
    print(f"decode  {G-1} steps: {out['decode_s']*1e3:.1f} ms "
          f"({B*(G-1)/max(out['decode_s'],1e-9):.0f} tok/s)")
    print("sample tokens[0]:", out["tokens"][0, :12].tolist())
    if args.state_save:
        path = sess.save_deployment(args.state_save)
        print(f"deployment saved: {len(sess._last_states)} call sites "
              f"-> {path}")
    if args.telemetry is not None:
        if not sent.ok:
            print(f"WARNING recompile sentinel tripped: {sent.violations}")
        from repro.obs import snapshot as obs_snapshot
        if args.telemetry == "-":
            print(json.dumps(obs_snapshot(), indent=2, sort_keys=True))
        else:
            from repro.obs import write_snapshot
            write_snapshot(args.telemetry)
            print(f"telemetry snapshot -> {args.telemetry}")


if __name__ == "__main__":
    main()
