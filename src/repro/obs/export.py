"""Exporters for a ``MetricsRegistry`` snapshot.

Two wire formats (docs/observability.md):

  * **JSON snapshot** -- ``snapshot()``: the canonical machine-readable
    dump (``{"schema": 1, "enabled": ..., "metrics": {...}}``).  This is
    what ``serve --telemetry`` writes, what ``tools/obs_report.py``
    renders and what ``tools/check_telemetry.py`` validates in CI.
  * **Prometheus text format** -- ``to_prometheus(snap)``: the standard
    exposition format (``# HELP`` / ``# TYPE`` + samples; histograms as
    cumulative ``_bucket{le=...}`` + ``_sum`` + ``_count``), so a node
    exporter sidecar or a pushgateway can scrape a serving process
    without any new dependency.  ``parse_prometheus`` is the minimal
    inverse used by the round-trip test.

``diff_snapshots(a, b)`` subtracts counter values and histogram series
(b - a; gauges take b's value): two snapshots around a workload yield
exactly that workload's metrics, which is how ``obs_report.py --base``
renders per-run deltas.
"""
from __future__ import annotations

import json
from typing import Dict, Optional, Tuple

from repro.obs.registry import OBS, MetricsRegistry


def snapshot(registry: Optional[MetricsRegistry] = None) -> dict:
    """JSON-ready snapshot of ``registry`` (default: the process ``OBS``)."""
    return (registry if registry is not None else OBS).snapshot()


def write_snapshot(path: str,
                   registry: Optional[MetricsRegistry] = None) -> str:
    with open(path, "w") as f:
        json.dump(snapshot(registry), f, indent=2, sort_keys=True)
        f.write("\n")
    return path


# --------------------------------------------------------------------------- #
# Prometheus text exposition format
# --------------------------------------------------------------------------- #
def _fmt(v: float) -> str:
    """Integral floats render as integers (counters read naturally)."""
    f = float(v)
    return str(int(f)) if f.is_integer() else repr(f)


def _esc(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _labels_text(labels: Dict[str, str], extra: Tuple[str, str] = None) -> str:
    items = sorted(labels.items())
    if extra is not None:
        items = items + [extra]
    if not items:
        return ""
    return "{" + ",".join(f'{k}="{_esc(str(v))}"' for k, v in items) + "}"


def to_prometheus(snap: dict) -> str:
    """Render a snapshot in the Prometheus text exposition format."""
    lines = []
    for name, m in sorted(snap.get("metrics", {}).items()):
        if m.get("help"):
            lines.append(f"# HELP {name} {m['help']}")
        lines.append(f"# TYPE {name} {m['kind']}")
        for s in m.get("series", []):
            labels = s.get("labels", {})
            if m["kind"] == "histogram":
                cum = 0
                for le, c in zip(list(m["buckets"]) + ["+Inf"],
                                 s["bucket_counts"]):
                    cum += c
                    lines.append(
                        f"{name}_bucket"
                        f"{_labels_text(labels, ('le', _fmt(le) if le != '+Inf' else '+Inf'))}"
                        f" {cum}")
                lines.append(f"{name}_sum{_labels_text(labels)}"
                             f" {repr(float(s['sum']))}")
                lines.append(f"{name}_count{_labels_text(labels)}"
                             f" {s['count']}")
            else:
                lines.append(f"{name}{_labels_text(labels)}"
                             f" {_fmt(s['value'])}")
    return "\n".join(lines) + "\n"


def parse_prometheus(text: str) -> Dict[Tuple[str, frozenset], float]:
    """Minimal inverse of ``to_prometheus`` (round-trip testing): maps
    ``(sample_name, frozenset(label_items))`` to the sample value."""
    out: Dict[Tuple[str, frozenset], float] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        head, _, value = line.rpartition(" ")
        if "{" in head:
            name, _, rest = head.partition("{")
            body = rest.rstrip("}")
            labels = []
            for part in _split_labels(body):
                k, _, v = part.partition("=")
                labels.append((k, v.strip('"').replace('\\"', '"')
                               .replace("\\n", "\n").replace("\\\\", "\\")))
            key = (name, frozenset(labels))
        else:
            key = (head, frozenset())
        out[key] = float(value)
    return out


def _split_labels(body: str) -> list:
    """Split ``k1="v1",k2="v2"`` on commas outside quotes."""
    parts, cur, in_q, prev = [], [], False, ""
    for ch in body:
        if ch == '"' and prev != "\\":
            in_q = not in_q
        if ch == "," and not in_q:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
        prev = ch
    if cur:
        parts.append("".join(cur))
    return [p for p in parts if p]


# --------------------------------------------------------------------------- #
# Snapshot diffs
# --------------------------------------------------------------------------- #
def _series_map(m: dict) -> dict:
    return {tuple(sorted(s.get("labels", {}).items())): s
            for s in m.get("series", [])}


def diff_snapshots(base: dict, snap: dict) -> dict:
    """``snap - base``: counters and histograms subtract per series
    (series absent from ``base`` count from zero; series that only exist
    in ``base`` are dropped), gauges pass through ``snap``'s value."""
    out = {"schema": snap.get("schema", 1), "enabled": snap.get("enabled"),
           "diff": True, "metrics": {}}
    for name, m in snap.get("metrics", {}).items():
        b = _series_map(base.get("metrics", {}).get(name, {}))
        series = []
        for s in m.get("series", []):
            key = tuple(sorted(s.get("labels", {}).items()))
            prev = b.get(key)
            if m["kind"] == "counter" and prev is not None:
                d = dict(s)
                d["value"] = s["value"] - prev["value"]
                series.append(d)
            elif m["kind"] == "histogram" and prev is not None:
                d = dict(s)
                d["count"] = s["count"] - prev["count"]
                d["sum"] = s["sum"] - prev["sum"]
                d["bucket_counts"] = [x - y for x, y in
                                      zip(s["bucket_counts"],
                                          prev["bucket_counts"])]
                # min/max are not recoverable for the window; keep snap's
                series.append(d)
            else:
                series.append(dict(s))
        entry = {"kind": m["kind"], "help": m.get("help", ""),
                 "series": series}
        if "buckets" in m:
            entry["buckets"] = m["buckets"]
        out["metrics"][name] = entry
    return out
