"""Serving telemetry: metrics registry, trace spans, exporters, sentinels.

The observability layer the serving stack reports through
(docs/observability.md).  Everything hangs off the process-local
``OBS`` singleton:

    from repro.obs import OBS

    if OBS.enabled:                              # one attribute check
        OBS.counter("analog_plan_cache_total", tag=tag, event="hit").inc()

    with OBS.span("serve_prefill", site=site):   # NULL_SPAN when disabled
        ...

Disabled (the default) every hook costs one attribute check and records
nothing; enabled (``REPRO_TELEMETRY=1``, ``OBS.enable()``, or
``serve --telemetry``) it feeds the JSON / Prometheus exporters and the
``RecompileSentinel`` compile-once checks.  Instrumentation is
bit-neutral and compile-neutral by contract: no instrument touches a
traced value or emits a jax op (gated by tests/test_obs.py).
"""
from repro.obs.export import (diff_snapshots, parse_prometheus, snapshot,
                              to_prometheus, write_snapshot)
from repro.obs.registry import (DEFAULT_BUCKETS, OBS, MetricsRegistry,
                                Telemetry)
from repro.obs.sentinel import RecompileError, RecompileSentinel
from repro.obs.trace import NULL_SPAN, Span

__all__ = [
    "OBS", "Telemetry", "MetricsRegistry", "DEFAULT_BUCKETS",
    "Span", "NULL_SPAN",
    "snapshot", "write_snapshot", "to_prometheus", "parse_prometheus",
    "diff_snapshots",
    "RecompileSentinel", "RecompileError",
]
