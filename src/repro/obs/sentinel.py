"""RecompileSentinel: the compile-once invariant as a reusable check.

Every bench in this repo asserts some flavor of "the sweep compiled
exactly once" by hand-collecting trace counters
(``ServeSession.prefill_traces``, ``jit_fn._cache_size()``,
``ScenarioSweep.trace_count``).  The sentinel packages that into one
context manager: snapshot the counters on entry, re-read them on exit,
and flag any watched counter that grew past its budget.

    with RecompileSentinel(session=sess, executor=ex,
                           label="task:emulator") as sent:
        for corner in corners:
            ex.deploy(scenario=corner)
            sess.generate()
    assert sent.ok          # strict=True (default) raises instead

Watchable things (any combination):

  * ``session``  -- a ``ServeSession``: ``prefill_traces`` and
    ``decode_traces``;
  * ``executor`` -- an ``AnalogExecutor``: the executable count of every
    per-tag unified forward (``_fns``), including tags created inside
    the block (they count from zero);
  * ``sweep``    -- a ``ScenarioSweep``: ``trace_count``;
  * ``fns``      -- any jitted callables exposing ``_cache_size()``.

``max_traces`` is the per-counter budget for NEW traces/executables
inside the block (default 1: the block may pay its first compile, never
a recompile).  On exit the outcome lands in the metrics registry when
telemetry is enabled (``obs_sentinel_checks_total{label, outcome}``),
which is what lets CI fail a serve run on ``outcome="violation"``
straight from the exported snapshot (tools/check_telemetry.py).
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence

from repro.obs.registry import OBS


class RecompileError(AssertionError):
    """A watched jit cache grew past the sentinel's trace budget."""


def _cache_size(fn) -> int:
    try:
        return fn._cache_size()
    except Exception:                  # pragma: no cover - jax API drift
        return 0


class RecompileSentinel:
    """Context manager asserting nothing recompiled beyond budget
    (see module docstring)."""

    def __init__(self, *, session=None, executor=None, sweep=None,
                 fns: Sequence = (), max_traces: int = 1, label: str = "",
                 strict: bool = True):
        self.session = session
        self.executor = executor
        self.sweep = sweep
        self.fns = tuple(fns)
        self.max_traces = max_traces
        self.label = label
        self.strict = strict
        self.ok: Optional[bool] = None
        self.new_counts: Dict[str, int] = {}
        self.violations: Dict[str, int] = {}
        self._base: Dict[str, int] = {}

    def counts(self) -> Dict[str, int]:
        """Current absolute counts of every watched counter."""
        c: Dict[str, int] = {}
        if self.session is not None:
            c["session.prefill_traces"] = self.session.prefill_traces
            c["session.decode_traces"] = self.session.decode_traces
        if self.executor is not None:
            for tag, ent in self.executor._fns.items():
                c[f"executor.unified[{tag}]"] = _cache_size(ent[2])
        if self.sweep is not None:
            c["sweep.trace_count"] = self.sweep.trace_count
        for i, fn in enumerate(self.fns):
            c[f"fn[{i}]"] = _cache_size(fn)
        return c

    def __enter__(self) -> "RecompileSentinel":
        self._base = self.counts()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if exc_type is not None:
            return False               # don't mask the original error
        end = self.counts()
        self.new_counts = {k: v - self._base.get(k, 0)
                           for k, v in end.items()}
        self.violations = {k: v for k, v in self.new_counts.items()
                           if v > self.max_traces}
        self.ok = not self.violations
        if OBS.enabled:
            OBS.counter(
                "obs_sentinel_checks_total",
                "RecompileSentinel outcomes (violation = a watched jit "
                "cache grew past the trace budget)",
                label=self.label or "<unlabeled>",
                outcome="ok" if self.ok else "violation").inc()
            for k, v in self.new_counts.items():
                OBS.gauge(
                    "obs_sentinel_new_traces",
                    "new traces/executables per watched counter in the "
                    "last sentinel block",
                    label=self.label or "<unlabeled>", watch=k).set(v)
        if self.strict and not self.ok:
            raise RecompileError(
                f"recompile sentinel {self.label or ''!s} tripped: "
                f"{self.violations} new traces exceed the budget of "
                f"{self.max_traces} (all watched: {self.new_counts})")
        return False
