"""Trace spans: wall-clock timing of a code region into a histogram.

A span is the cheapest possible wrapper around ``time.perf_counter``:
on exit it records the elapsed seconds into the owning registry's
``<name>_seconds`` histogram (span names therefore use underscores, not
dots, so the derived metric name is Prometheus-legal as-is).  With the
registry's ``profiler`` flag set the span additionally opens a
``jax.profiler.TraceAnnotation`` of the same name, so serving spans show
up on the XLA trace viewer timeline next to the device ops they wrap.

Spans never touch traced values and never emit jax ops: a span around a
jitted call times the host-side dispatch (document the sync discipline
at the call site -- the span does not ``block_until_ready`` for you).

When telemetry is disabled, ``Telemetry.span`` returns the shared
``NULL_SPAN`` singleton -- entering and exiting it is two empty method
calls, no allocation, no clock read.
"""
from __future__ import annotations

import time


class NullSpan:
    """No-op context manager handed out while telemetry is disabled."""

    __slots__ = ()

    def __enter__(self) -> "NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


NULL_SPAN = NullSpan()


class Span:
    """Times a with-block into ``<name>_seconds`` on ``registry``."""

    __slots__ = ("_registry", "name", "help", "labels", "_t0", "_annotation")

    def __init__(self, registry, name: str, help: str = "",
                 labels: dict | None = None):
        self._registry = registry
        self.name = name
        self.help = help
        self.labels = labels or {}
        self._t0 = 0.0
        self._annotation = None

    def __enter__(self) -> "Span":
        if getattr(self._registry, "profiler", False):
            import jax.profiler  # lazy: obs must import without jax

            self._annotation = jax.profiler.TraceAnnotation(self.name)
            self._annotation.__enter__()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        dt = time.perf_counter() - self._t0
        if self._annotation is not None:
            self._annotation.__exit__(*exc)
            self._annotation = None
        self._registry.histogram(self.name + "_seconds", self.help,
                                 **self.labels).observe(dt)
        return False
