"""Process-local metrics registry: counters, gauges, histograms + labels.

The serving path is instrumented against ONE module-level ``Telemetry``
instance, ``OBS``.  The contract every instrumentation point follows
(docs/observability.md):

  * **zero overhead when disabled** -- every hook is gated as
    ``if OBS.enabled: ...``, i.e. one attribute check on the shared
    singleton; no handle lookup, no allocation, no clock read.  Spans
    come back as the shared ``NULL_SPAN`` when disabled.
  * **bit-neutral** -- instruments record host-side Python floats only;
    they never touch traced values, so enabling telemetry cannot change
    a served number.
  * **compile-neutral** -- no instrument emits a jax op; counters
    incremented inside a traced function are trace-time side effects
    (they *count* traces, they do not alter the jaxpr).  A gated test
    asserts jit trace counts are identical with telemetry on vs off.

Metric naming is Prometheus-legal as written (``[a-z0-9_]``, counters
end in ``_total``, histograms in ``_seconds`` for latencies); the
inventory lives in docs/observability.md.  Everything is thread-safe:
one lock per metric guards its label series (asserted under a
``ThreadPoolExecutor`` in tests/test_obs.py).
"""
from __future__ import annotations

import bisect
import math
import os
import threading
from typing import Dict, Optional, Sequence, Tuple

from repro.obs.trace import NULL_SPAN, Span

# Latency-oriented default buckets (seconds): 100 us .. 10 s, roughly
# log-spaced, wide enough for both a fused-kernel dispatch and a full
# prefill on a cold CPU host.
DEFAULT_BUCKETS: Tuple[float, ...] = (
    1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    1e-1, 2.5e-1, 5e-1, 1.0, 2.5, 5.0, 10.0)

_KINDS = ("counter", "gauge", "histogram")


class _HistSeries:
    """One labeled histogram series: bucket counts + running stats."""

    __slots__ = ("counts", "sum", "count", "min", "max")

    def __init__(self, n_buckets: int):
        self.counts = [0] * (n_buckets + 1)   # +1: the +Inf bucket
        self.sum = 0.0
        self.count = 0
        self.min = math.inf
        self.max = -math.inf


class Metric:
    """One named metric and all of its label series (thread-safe)."""

    __slots__ = ("name", "kind", "help", "buckets", "_series", "_lock")

    def __init__(self, name: str, kind: str, help: str = "",
                 buckets: Optional[Sequence[float]] = None):
        assert kind in _KINDS, kind
        self.name = name
        self.kind = kind
        self.help = help
        self.buckets = (tuple(buckets) if buckets is not None
                        else DEFAULT_BUCKETS) if kind == "histogram" else None
        self._series: Dict[tuple, object] = {}
        self._lock = threading.Lock()

    # -- series mutation (all under the metric lock) -------------------- #
    def _add(self, key: tuple, v: float) -> None:
        with self._lock:
            self._series[key] = self._series.get(key, 0.0) + v

    def _set(self, key: tuple, v: float) -> None:
        with self._lock:
            self._series[key] = v

    def _observe(self, key: tuple, v: float) -> None:
        with self._lock:
            h = self._series.get(key)
            if h is None:
                h = self._series[key] = _HistSeries(len(self.buckets))
            h.counts[bisect.bisect_left(self.buckets, v)] += 1
            h.sum += v
            h.count += 1
            h.min = v if v < h.min else h.min
            h.max = v if v > h.max else h.max

    def snapshot_series(self) -> list:
        """Label series as JSON-ready dicts, deterministically ordered."""
        with self._lock:
            items = sorted(self._series.items())
        out = []
        for key, val in items:
            row: dict = {"labels": dict(key)}
            if self.kind == "histogram":
                row.update(count=val.count, sum=val.sum,
                           min=(None if val.count == 0 else val.min),
                           max=(None if val.count == 0 else val.max),
                           bucket_counts=list(val.counts))
            else:
                row["value"] = val
            out.append(row)
        return out


class _Counter:
    __slots__ = ("_metric", "_key")

    def __init__(self, metric: Metric, key: tuple):
        self._metric, self._key = metric, key

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError("counters only go up; use a gauge")
        self._metric._add(self._key, n)


class _Gauge:
    __slots__ = ("_metric", "_key")

    def __init__(self, metric: Metric, key: tuple):
        self._metric, self._key = metric, key

    def set(self, v: float) -> None:
        self._metric._set(self._key, float(v))

    def add(self, n: float = 1.0) -> None:
        self._metric._add(self._key, n)


class _Histogram:
    __slots__ = ("_metric", "_key")

    def __init__(self, metric: Metric, key: tuple):
        self._metric, self._key = metric, key

    def observe(self, v: float) -> None:
        self._metric._observe(self._key, float(v))


def _label_key(labels: dict) -> tuple:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class MetricsRegistry:
    """A set of named metrics; the unit every exporter consumes.

    ``counter`` / ``gauge`` / ``histogram`` get-or-create the named
    metric and return a handle bound to one label set; re-using a name
    with a different kind raises.  ``snapshot()`` is the canonical
    JSON-ready export (repro.obs.export adds Prometheus text + diffs).
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, Metric] = {}

    def _metric(self, name: str, kind: str, help: str,
                buckets: Optional[Sequence[float]] = None) -> Metric:
        m = self._metrics.get(name)
        if m is None:
            with self._lock:
                m = self._metrics.get(name)
                if m is None:
                    m = self._metrics[name] = Metric(name, kind, help,
                                                     buckets)
        if m.kind != kind:
            raise ValueError(
                f"metric {name!r} already registered as {m.kind}, "
                f"requested {kind}")
        return m

    def counter(self, name: str, help: str = "", **labels) -> _Counter:
        m = self._metric(name, "counter", help)
        return _Counter(m, _label_key(labels))

    def gauge(self, name: str, help: str = "", **labels) -> _Gauge:
        m = self._metric(name, "gauge", help)
        return _Gauge(m, _label_key(labels))

    def histogram(self, name: str, help: str = "",
                  buckets: Optional[Sequence[float]] = None,
                  **labels) -> _Histogram:
        m = self._metric(name, "histogram", help, buckets)
        return _Histogram(m, _label_key(labels))

    def snapshot(self) -> dict:
        """JSON-ready view of every metric (schema in export.py)."""
        with self._lock:
            metrics = sorted(self._metrics.items())
        out = {}
        for name, m in metrics:
            entry: dict = {"kind": m.kind, "help": m.help,
                           "series": m.snapshot_series()}
            if m.kind == "histogram":
                entry["buckets"] = list(m.buckets)
            out[name] = entry
        return {"schema": 1, "enabled": getattr(self, "enabled", True),
                "metrics": out}

    def reset(self) -> None:
        """Drop every metric (tests / between benchmark phases)."""
        with self._lock:
            self._metrics.clear()


class Telemetry(MetricsRegistry):
    """The process-local registry plus the master enable switch.

    Instrumentation points gate on ``OBS.enabled`` (one attribute
    check); ``span(name, ...)`` returns the shared no-op ``NULL_SPAN``
    while disabled.  ``profiler=True`` additionally wraps every span in
    a ``jax.profiler.TraceAnnotation`` so spans land on XLA traces.
    """

    def __init__(self, enabled: bool = False, profiler: bool = False):
        super().__init__()
        self.enabled = enabled
        self.profiler = profiler

    def enable(self, profiler: Optional[bool] = None) -> "Telemetry":
        self.enabled = True
        if profiler is not None:
            self.profiler = profiler
        return self

    def disable(self) -> "Telemetry":
        self.enabled = False
        return self

    def span(self, name: str, help: str = "", **labels):
        if not self.enabled:
            return NULL_SPAN
        return Span(self, name, help, labels)


def _env_enabled() -> bool:
    return os.environ.get("REPRO_TELEMETRY", "") not in ("", "0", "false")


#: THE process-local telemetry instance every instrumentation point and
#: exporter defaults to.  Disabled unless ``REPRO_TELEMETRY=1`` (or a
#: caller -- ``serve --telemetry``, a benchmark, a test -- enables it).
OBS = Telemetry(enabled=_env_enabled())
