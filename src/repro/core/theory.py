"""Theorem 4.1 (SEMULATOR): training-acceptance bound for the emulator.

To guarantee  P(|Y - f(X)| < 0.5 * 10^-s) > p  for a regression network whose
error is ~ N(0, sigma^2) (Lemma 4.2), the MSE must satisfy

    E[|Y - f(X)|^2] = sigma^2  <  0.5 * (10^-s / erfinv(p))^2

Note: the paper's Theorem statement writes the probability condition with
0.5 * 10^-s but the proof (and the s=3, p=0.3 -> 6.7e-6 numeric example)
carries 10^-s through erf. We follow the numeric example for ``mse_bound``
and expose the strict variant separately.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.scipy.special import erfinv


def mse_bound(s: int, p: float) -> float:
    """Upper bound on MSE (paper's numeric convention; s=3, p=0.3 -> 6.73e-6)."""
    return float(0.5 * (10.0 ** (-s) / erfinv(jnp.asarray(p))) ** 2)


def mse_bound_strict(s: int, p: float) -> float:
    """Same bound with the Theorem statement's 0.5 * 10^-s inside erf."""
    return float(0.5 * (0.5 * 10.0 ** (-s) / erfinv(jnp.asarray(p))) ** 2)


def significance_probability(errors: jax.Array, s: int) -> jax.Array:
    """Empirical P(|err| < 0.5 * 10^-s)."""
    return jnp.mean((jnp.abs(errors) < 0.5 * 10.0 ** (-s)).astype(jnp.float32))


def check_significance(errors: jax.Array, s: int, p: float) -> bool:
    """Does the empirical error distribution satisfy the Thm 4.1 condition?"""
    return bool(significance_probability(errors, s) > p)


def predicted_probability(mse: float, s: int) -> float:
    """Given an achieved MSE (= sigma^2 under Lemma 4.2), the probability
    P(|err| < 10^-s) predicted by the Gaussian model: erf(10^-s / sqrt(2 mse))."""
    import math
    return math.erf(10.0 ** (-s) / math.sqrt(2.0 * max(mse, 1e-30)))
