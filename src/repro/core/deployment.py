"""DeploymentState: everything one analog matmul needs, as ONE pytree.

Four PRs of growth left the executor threading eleven positional slots
through its traced forwards (conductances, read sigma/key, remap
permutation, emulator params, scenario features, calibration affine) --
every new scenario axis cost a new positional argument and an edit to
three parallel jit-cache families.  This module collapses that sprawl
into a single registered pytree:

  * ``DeploymentState`` -- the per-tag bundle of *traced* leaves the
    unified forward consumes.  One dataclass, one traced argument, one
    jit cache per weight tag (``AnalogExecutor._unified_for``).  Adding a
    scenario axis is now a one-field change.
  * ``Deployment`` -- the immutable executor-level *spec* (scenario,
    fleet key, remap policy, hot-swapped params) that
    ``AnalogExecutor.deploy`` builds and from which per-tag states are
    materialized lazily.  Replaces the mutable ``set_scenario`` /
    ``set_emulator_params`` / ``fault_remap`` setter family (now thin
    deprecation shims).
  * ``save_deployment`` / ``load_deployment`` -- npz round trip, so an
    aged / remapped / recalibrated deployment is reproducible across
    processes (``serve --state-save/--state-load``).

Contract (tested in tests/test_deployment_state.py):
  * ``DeploymentState.ideal(plan)`` leaves reproduce the plain serving
    fast path bit-identically (identity read noise, identity gather,
    all-zero scenario features, unit affine);
  * every leaf is traced by the unified forward, so swapping corners,
    ages, remaps, read cycles, calibrations or retrained params reuses
    ONE compiled executable per (tag, shape);
  * the pytree round-trips through flatten/unflatten and npz untouched.

See docs/api.md for the one-traced-arg contract and the fluent builder.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# NOTE: no module-level repro.* imports -- this module sits below both
# repro.core.analog and repro.nonideal in the import graph; anything from
# those layers is imported lazily inside functions.

_STATE_FIELDS: Tuple[str, ...] = (
    "gf", "read_sigma", "read_key", "out_perm", "eparams", "sfeat",
    "cal_a", "cal_b",
)


@dataclass(frozen=True)
class DeploymentState:
    """Per-tag deployed-device state: the ONE traced argument of the
    executor's unified forward.

    Leaves (all jax arrays; ``eparams`` is a dict subtree, ``{}`` for
    non-emulator backends):

      gf         -- (NB, NO, D, H, W) perturbed raw conductances
                    (device draw + drift + faults applied; remapped
                    group layout when ``out_perm`` is non-identity)
      read_sigma -- (NB, NO) per-tile cycle-to-cycle read-noise sigma
                    (zeros = exact identity)
      read_key   -- PRNG key for this read cycle's noise draw
      out_perm   -- (N,) int32 logical->physical output gather
                    (identity = exact identity)
      eparams    -- emulator params (hot-swappable; traced)
      sfeat      -- scenario feature encoding a conditioned emulator
                    consumes: (N_SCENARIO_FEATURES,) for a scalar corner
                    or (NB, NO, N_SCENARIO_FEATURES) per-tile feature
                    operands for a tiled corner (all-zero at ideal)
      cal_a/cal_b -- the per-layer volts->logical calibration affine

    Instances are immutable; derive variants with ``replace`` /
    ``with_read_key`` / ``with_calibration``.  The ideal constructor is
    bit-identical to the plain path by construction: every non-ideal leaf
    sits at its exact-identity value.
    """
    gf: jax.Array
    read_sigma: jax.Array
    read_key: jax.Array
    out_perm: jax.Array
    eparams: Dict[str, jax.Array]
    sfeat: jax.Array
    cal_a: jax.Array
    cal_b: jax.Array

    @classmethod
    def ideal(cls, plan, eparams: Optional[dict] = None,
              calibration: Tuple[float, float] = (1.0, 0.0),
              n_features: Optional[int] = None) -> "DeploymentState":
        """The exact-identity state for a conductance plan: unperturbed
        conductances, zero read sigma, identity permutation, all-zero
        scenario features, the given affine.  Feeding this to the unified
        forward reproduces the plain serving fast path bit-for-bit."""
        if n_features is None:
            from repro.nonideal.scenario import N_SCENARIO_FEATURES
            n_features = N_SCENARIO_FEATURES
        # gf is pinned to float32 regardless of the weights' dtype (a
        # bf16-served model would otherwise flip the state's aval between
        # the ideal and any perturbed corner and retrace its consumers)
        return cls(
            gf=plan.g_feat.astype(jnp.float32),
            read_sigma=jnp.zeros((plan.NB, plan.NO), jnp.float32),
            read_key=jax.random.PRNGKey(0),
            out_perm=jnp.arange(plan.N, dtype=jnp.int32),
            eparams=dict(eparams) if eparams else {},
            sfeat=jnp.zeros((n_features,), jnp.float32),
            cal_a=jnp.asarray(calibration[0], jnp.float32),
            cal_b=jnp.asarray(calibration[1], jnp.float32))

    def replace(self, **kw) -> "DeploymentState":
        """Immutable field update (the fluent derivation primitive)."""
        return dataclasses.replace(self, **kw)

    def with_read_key(self, key: jax.Array) -> "DeploymentState":
        """Same device, next read cycle."""
        return dataclasses.replace(self, read_key=key)

    def with_calibration(self, a, b) -> "DeploymentState":
        """Same device, refitted volts->logical affine."""
        return dataclasses.replace(self, cal_a=jnp.asarray(a, jnp.float32),
                                   cal_b=jnp.asarray(b, jnp.float32))


jax.tree_util.register_pytree_node(
    DeploymentState,
    lambda s: (tuple(getattr(s, f) for f in _STATE_FIELDS), None),
    lambda aux, children: DeploymentState(*children))


@dataclass(frozen=True, eq=False)
class Deployment:
    """Immutable executor-level deployment spec (what ``ex.deploy`` builds).

    Per-tag ``DeploymentState``s are materialized lazily from this spec
    (``AnalogExecutor.state_for``) and cached against its identity, so a
    new deployment -- a new corner, age, remap policy or hot-swapped
    params -- invalidates exactly the derived device state and nothing
    compiled.

      scenario -- device non-ideality corner (None = ideal hardware)
      key      -- fleet fabrication key (same key = same devices)
      remap    -- stuck-fault-aware column remapping policy: False/True
                  (off / instantaneous) or a tuple of checkpoint ages in
                  seconds (wear-aware horizon scoring)
      params   -- emulator param override (hot-swap; None = executor's)
      states   -- preloaded per-tag states (``load_deployment``), served
                  verbatim instead of being re-derived
    """
    scenario: Optional[object] = None          # nonideal.Scenario
    key: Optional[jax.Array] = None
    remap: "bool | Tuple[float, ...]" = False
    params: Optional[dict] = None
    states: Optional[Dict[str, DeploymentState]] = None

    def replace(self, **kw) -> "Deployment":
        """Fluent derivation: a new spec differing in the given fields."""
        return dataclasses.replace(self, **kw)

    def spec_json(self) -> str:
        """Canonical JSON of the reproducible part of the spec (scenario,
        fleet key, remap policy).  ``params``/``states`` are binary
        payloads and travel through npz (``save_deployment``)."""
        from repro.nonideal.scenario import scenario_to_json
        return json.dumps({
            "scenario": (None if self.scenario is None
                         else json.loads(scenario_to_json(self.scenario))),
            "key": (None if self.key is None
                    else np.asarray(self.key).tolist()),
            "remap": (list(self.remap)
                      if isinstance(self.remap, (tuple, list))
                      else bool(self.remap)),
        }, sort_keys=True)

    @classmethod
    def from_spec_json(cls, doc: str) -> "Deployment":
        """Inverse of ``spec_json`` (scenario/key/remap only)."""
        from repro.nonideal.scenario import scenario_from_json
        d = json.loads(doc)
        sc = d.get("scenario")
        key = d.get("key")
        rm = d.get("remap", False)
        return cls(
            scenario=(None if sc is None
                      else scenario_from_json(json.dumps(sc))),
            key=(None if key is None
                 else jnp.asarray(np.asarray(key, np.uint32))),
            remap=tuple(rm) if isinstance(rm, list) else bool(rm))


# --------------------------------------------------------------------------- #
# npz (de)serialization: a deployment reproducible across processes
# --------------------------------------------------------------------------- #
_SPEC_KEY = "__deployment_spec"
_EP_PREFIX = "__eparams::"


def save_deployment(path: str, states: Dict[str, DeploymentState],
                    deployment: Optional[Deployment] = None) -> str:
    """Serialize per-tag states (+ the spec) to one npz.

    Emulator params are stored once (states materialized from one
    executor share them); every other leaf is stored per tag under
    ``<tag>::<field>``.  ``load_deployment`` restores bit-identical
    states, so an aged / remapped / recalibrated fleet can be served by
    another process without re-deriving the device draw."""
    arrs: Dict[str, np.ndarray] = {}
    eparams: Dict[str, jax.Array] = {}
    for tag, st in states.items():
        for f in _STATE_FIELDS:
            if f == "eparams":
                if st.eparams:
                    if eparams and st.eparams is not eparams:
                        # the format stores ONE shared param set; states
                        # materialized from one executor share it by
                        # construction -- refuse to silently collapse
                        # heterogeneous per-tag params
                        raise ValueError(
                            "save_deployment: per-tag states carry "
                            "different eparams dicts; the npz format "
                            "stores one shared emulator param set")
                    eparams = st.eparams
                continue
            arrs[f"{tag}::{f}"] = np.asarray(getattr(st, f))
    for k, v in eparams.items():
        arrs[_EP_PREFIX + k] = np.asarray(v)
    spec = (deployment or Deployment()).spec_json()
    np.savez(path, **{_SPEC_KEY: np.array(spec)}, **arrs)
    return path


def load_deployment(path: str, executor=None
                    ) -> Tuple[Dict[str, DeploymentState], Deployment]:
    """Inverse of ``save_deployment``: ``(states, deployment)`` with the
    loaded states attached to the returned spec (``deployment.states``).

    With ``executor`` given (an ``AnalogExecutor``), the loaded host
    arrays are placed straight onto the executor's serving mesh under
    the lattice partition specs (``executor.shard_states``).  The npz
    records VALUES, not placements, so a deployment saved under one mesh
    shape re-shards cleanly onto any other -- the elastic-restart
    semantics for serving fleets (docs/parallel.md).  Without
    ``executor`` (or without a mesh) this is a no-op and the executor
    re-shards lazily in ``state_for``."""
    data = np.load(path, allow_pickle=True)
    eparams = {k[len(_EP_PREFIX):]: jnp.asarray(data[k])
               for k in data.files if k.startswith(_EP_PREFIX)}
    tags = sorted({k.split("::", 1)[0] for k in data.files
                   if "::" in k and not k.startswith("__")})
    states: Dict[str, DeploymentState] = {}
    for tag in tags:
        kw = {}
        for f in _STATE_FIELDS:
            if f == "eparams":
                continue
            v = jnp.asarray(data[f"{tag}::{f}"])
            kw[f] = v
        states[tag] = DeploymentState(eparams=dict(eparams), **kw)
    if executor is not None:
        states = executor.shard_states(states)
    dep = Deployment.from_spec_json(str(data[_SPEC_KEY]))
    return states, dep.replace(states=states)
