"""Conv4Xbar: the paper's emulator architecture (Fig. 3, Table 2).

A 3D-CNN whose kernels have depth 1 (tiles axis) and grow along the row axis
(H: 1 -> 2 -> 4 -> 8 with matching strides), mirroring column-wise current
accumulation; then a (1,1,2) conv across the differential column pairs; then
an FCNN 'circuit equation solver' head (128/256 -> 32 -> 16 -> O), CELU
everywhere. Peripheral-circuit features are concatenated before the head.

Two apply paths:
  apply()       -- paper-faithful lax.conv_general_dilated stack
  apply_fused() -- TPU-native algebraic rewrite: each depth-1 strided conv is
                   a blocked matmul over reshaped row groups (MXU-friendly;
                   validated equal to apply() in tests). See DESIGN.md §3.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.rram_ps32 import BlockGeometry
from repro.models.common import ParamSchema


@dataclass(frozen=True)
class ConvStage:
    c_in: int
    c_out: int
    kernel: Tuple[int, int, int]     # (D, H, W)
    stride: Tuple[int, int, int]


def build_stages(geom: BlockGeometry) -> List[ConvStage]:
    """Table 2 stack, generalized to any (C, D, H, W) geometry."""
    stages = [ConvStage(geom.features, 16, (1, 1, 1), (1, 1, 1))]
    h = geom.rows
    plan = [(16, 8, 2), (8, 4, 4), (4, 32, 8)]
    for c_in, c_out, k in plan:
        k = min(k, h)
        stages.append(ConvStage(c_in, c_out, (1, k, 1), (1, k, 1)))
        h = h // k
    # across differential column pairs; stride 2 when W > 2 (case B: the
    # paper's Linear(256, 32) implies stride (1,1,2) -- Table 2 typo)
    w_stride = 1 if geom.cols <= 2 else 2
    stages.append(ConvStage(32, 32, (1, 1, 2), (1, 1, w_stride)))
    return stages


def _out_size(size, k, s):
    return (size - k) // s + 1


def conv_out_sizes(stages: Sequence[ConvStage], d: int, h: int, w: int):
    """Spatial output dims of the stage stack for a (d, h, w) input."""
    for st in stages:
        d = _out_size(d, st.kernel[0], st.stride[0])
        h = _out_size(h, st.kernel[1], st.stride[1])
        w = _out_size(w, st.kernel[2], st.stride[2])
    return d, h, w


def flat_features(geom: BlockGeometry) -> int:
    d, h, w = conv_out_sizes(build_stages(geom), geom.tiles, geom.rows,
                             geom.cols)
    return 32 * d * h * w


def conv4xbar_schema(geom: BlockGeometry, n_periph: int = 0,
                     head: Sequence[int] = (32, 16)):
    """Parameter schema (shapes + shardings + init) for one emulator."""
    s = {}
    for i, st in enumerate(build_stages(geom)):
        fan_in = st.c_in * int(np.prod(st.kernel))
        s[f"conv{i}_w"] = ParamSchema(
            (st.c_out, st.c_in) + st.kernel, P(None), "normal",
            math.sqrt(2.0 / fan_in))
        s[f"conv{i}_b"] = ParamSchema((st.c_out,), P(None), "zeros")
    d_in = flat_features(geom) + n_periph
    dims = [d_in, *head, geom.outputs]
    for i in range(len(dims) - 1):
        s[f"fc{i}_w"] = ParamSchema((dims[i], dims[i + 1]), P(None), "normal",
                                    math.sqrt(2.0 / dims[i]))
        s[f"fc{i}_b"] = ParamSchema((dims[i + 1],), P(None), "zeros")
    s["_meta"] = ParamSchema((3,), P(None), "zeros")   # (n_stages, n_fc, n_periph)
    return s


def n_periph_of(params, geom: BlockGeometry) -> int:
    """Peripheral-feature width a trained param set was bound to (the fc0
    rows past the conv flatten).  Static even for traced params -- shapes
    are aval data -- so callers may branch on it at trace time.  ``> 2``
    means the net is scenario-conditioned: rows ``2:`` of the peripheral
    block consume ``nonideal.scenario_features`` (docs/emulator.md)."""
    return int(params["fc0_w"].shape[0]) - flat_features(geom)


def _head(params, h, n_fc):
    for i in range(n_fc):
        h = h @ params[f"fc{i}_w"] + params[f"fc{i}_b"]
        if i < n_fc - 1:
            h = jax.nn.celu(h)
    return h


def _n_stages(params):
    return len([k for k in params if k.startswith("conv") and k.endswith("_w")])


def _n_fc(params):
    return len([k for k in params if k.startswith("fc") and k.endswith("_w")])


def apply(params, x: jax.Array, periph: jax.Array | None = None) -> jax.Array:
    """Paper-faithful path. x: (B, C, D, H, W) -> (B, O)."""
    h = x
    for i in range(_n_stages(params)):
        w = params[f"conv{i}_w"]
        stride = _stride_of(w, h)
        h = jax.lax.conv_general_dilated(
            h, w, window_strides=stride, padding="VALID",
            dimension_numbers=("NCDHW", "OIDHW", "NCDHW"))
        h = jax.nn.celu(h + params[f"conv{i}_b"][None, :, None, None, None])
    h = h.reshape(h.shape[0], -1)
    if periph is not None:
        h = jnp.concatenate([h, periph.astype(h.dtype)], axis=-1)
    return _head(params, h, _n_fc(params))


def _stride_of(w, h):
    """Recover the stage stride from kernel shape (stride == kernel except
    the final (1,1,2) stage where stride_w is 2 iff W_in > 2)."""
    kd, kh, kw = w.shape[2], w.shape[3], w.shape[4]
    if (kd, kh, kw) == (1, 1, 2):
        return (1, 1, 1 if h.shape[4] <= 2 else 2)
    return (kd, kh, kw)


# --------------------------------------------------------------------------- #
# Blockified serving fast path (channels-last, conductance precomputed)
#
# At system level (core/analog.py) the emulator evaluates B * NB * NO blocks
# per matmul for BOTH voltage rails, but the conductance features are
# batch-constant: only the voltage channel changes per call.  The fast path
#   * precomputes stage-0's conductance contribution once per weight plan
#     (g0 = w0_g * g_norm + b0), together with the zero-voltage block
#     response celu(g0) and its stage-1 projection y0 = celu(g0) @ W1 + b1;
#   * exploits dual-rail complementarity: at every wordline exactly one of
#     (v+ = relu(x), v- = relu(-x)) is nonzero, so the expensive stage-0
#     CELU is evaluated ONCE on |x| (half the rail-stacked batch) and both
#     rails are reconstructed from delta = celu(v0 + g0) - celu(g0) --
#     delta rows with v = 0 vanish exactly;
#   * moves the rail mask to the stage-1 GEMM *output* by splitting the
#     row-window contraction (the mask is constant across the channel dim),
#     so no masked 8M-element copies are materialized;
#   * keeps activations channels-LAST (n, D, W, H, C) so every conv stage is
#     a reshape + trailing-dim matmul -- no layout transposes on the hot
#     path -- and evaluates in cache-sized batch chunks (lax.map);
#   * folds the constant peripheral features (gain=1, offset=0) into the
#     first FC bias, skipping the per-sample concat.
# Numerically equivalent to apply()/apply_fused() within fp32 tolerance
# (same contractions, different association order); see tests/test_analog_fastpath.
# --------------------------------------------------------------------------- #
def blocklast_weights(params, geom: BlockGeometry,
                      periph_const=(1.0, 0.0)) -> dict:
    """Repack emulator params for the channels-last blockified fast path."""
    assert geom.features == 2, "expects (V, G) cell features"
    stages = build_stages(geom)
    aux = {}
    w0 = params["conv0_w"][:, :, 0, 0, 0]             # (C0, 2)
    aux["w0v"], aux["w0g"] = w0[:, 0], w0[:, 1]
    aux["b0"] = params["conv0_b"]
    hstages = []
    for i, st in enumerate(stages[1:-1], start=1):
        k = st.kernel[1]
        w = params[f"conv{i}_w"][:, :, 0, :, 0]       # (O, I, k)
        wk = w.transpose(2, 1, 0).reshape(k * st.c_in, st.c_out)
        hstages.append((wk, params[f"conv{i}_b"], k))
    aux["hstages"] = tuple(hstages)
    # stage 1 split by row-window position kk: (k1, C0, O1) so the dual-rail
    # mask (constant across channels) can be applied to each position's
    # GEMM output -- one (C0, O1) contraction per kk instead of a k1^2
    # cross-position GEMM whose off-diagonal blocks were discarded
    w1, _, k1 = hstages[0]
    c0 = stages[0].c_out
    o1 = w1.shape[1]
    aux["w1k"] = w1.reshape(k1, c0, o1)
    iw = len(stages) - 1
    st = stages[iw]
    kw = st.kernel[2]
    w = params[f"conv{iw}_w"][:, :, 0, 0, :]          # (O, I, kw)
    aux["wstage"] = (w.transpose(2, 1, 0).reshape(kw * st.c_in, st.c_out),
                     params[f"conv{iw}_b"], kw)
    # fc0: permute rows from (c, d, h, w) flatten order to (d, h, w, c), and
    # fold the constant peripheral drive into the bias.
    d, h, wd = conv_out_sizes(stages, geom.tiles, geom.rows, geom.cols)
    cf = stages[-1].c_out
    flat = cf * d * h * wd
    f0 = params["fc0_w"]
    perm = f0[:flat].reshape(cf, d, h, wd, -1).transpose(1, 2, 3, 0, 4)
    perm = perm.reshape(flat, -1)
    n_periph = f0.shape[0] - flat
    b0 = params["fc0_b"]
    if n_periph:
        # pad with zeros past the supplied constants: a conditioned net's
        # scenario-feature rows (2:) encode the IDEAL corner as exactly 0,
        # so the zero fold keeps the plain fast path bit-identical to the
        # unconditioned one; the scenario forward adds the corner's
        # contribution as a traced fc0 shift (apply_blocklast(fc0_shift=))
        pc = jnp.zeros((n_periph,), f0.dtype)
        pc = pc.at[:min(len(periph_const), n_periph)].set(
            jnp.asarray(periph_const[:n_periph], f0.dtype))
        b0 = b0 + pc @ f0[flat:]
    if n_periph > len(periph_const):
        # scenario-feature rows of fc0: the conditioned corner's fc0
        # contribution is sfeat @ f0_scen, a per-call bias shift
        aux["f0_scen"] = f0[flat + len(periph_const):]
    fcs = [(perm, b0)]
    for i in range(1, _n_fc(params)):
        fcs.append((params[f"fc{i}_w"], params[f"fc{i}_b"]))
    aux["fcs"] = tuple(fcs)
    return aux


def stage0_conductance(aux: dict, g_norm: jax.Array) -> jax.Array:
    """g_norm: (NB, NO, D, H, W) normalized conductance features ->
    (NB, NO, D, W, H, C0) precomputed stage-0 pre-activation contribution."""
    g = g_norm.transpose(0, 1, 2, 4, 3)               # (NB, NO, D, W, H)
    return g[..., None] * aux["w0g"] + aux["b0"]


def blocklast_precompute(aux: dict, g_norm: jax.Array) -> dict:
    """Batch-independent per-plan tensors for apply_blocklast.

    g0k:    stage-0 pre-activation conductance contribution, split by
            row-window position: (k1, NB, NO, D, W, G, C0) so the hot
            loop's per-kk slices are contiguous views
    celu0k: the zero-voltage stage-0 response celu(g0), same split
    y0:     its stage-1 projection celu(g0) @ W1 + b1 (pre-activation),
            (NB*NO*D*W*G, O1)
    """
    g0 = stage0_conductance(aux, g_norm)              # (NB, NO, D, W, H, C0)
    celu0 = jax.nn.celu(g0)
    w1, b1, k1 = aux["hstages"][0]
    y0 = celu0.reshape(-1, w1.shape[0]) @ w1 + b1     # (NB*NO*D*W*G, O1)
    nb, no, d, w, h, c0 = g0.shape
    shp = (nb, no, d, w, h // k1, k1, c0)             # H -> (G, kk)
    g0k = jnp.moveaxis(g0.reshape(shp), 5, 0)
    celu0k = jnp.moveaxis(celu0.reshape(shp), 5, 0)
    return {"g0k": g0k, "celu0k": celu0k, "y0": y0}


def _tail_stages(aux: dict, h: jax.Array, n: int, shp,
                 fc0_shift: jax.Array | None = None,
                 dot=None) -> jax.Array:
    """Conv stages 2.. + FC head on channels-last rows.  h: 2-D (rows, C)
    laid out as shp=(n, D, W, G) x channels; -> (n, O).  ``fc0_shift`` is
    an optional per-call bias shift on fc0's pre-activation (the
    conditioned emulator's scenario-feature contribution): either a flat
    ``(fc0_out,)`` vector (whole-plan corner) or a per-tile ``(nblk,
    fc0_out)`` lattice -- rows are laid out block-innermost (NB*NO cycles
    fastest), so a 2-D shift folds onto ``(n // nblk, nblk, fc0_out)``
    and each block gets its own scenario contribution.  ``dot``
    overrides the contraction (the unified Pallas kernel passes its
    MXU/bf16 dot so this exact code runs inside the kernel body)."""
    if dot is None:
        dot = jnp.matmul
    for wk, b, k in aux["hstages"][1:]:
        # one flat GEMM over (k*C) -- batched matmuls over small trailing
        # matrices are pathologically slow on CPU backends
        h = jax.nn.celu(dot(h.reshape(-1, wk.shape[0]), wk) + b)
        shp = shp[:3] + (shp[3] // k,)
    wk, b, kw = aux["wstage"]
    h = h.reshape(shp + (-1,)).transpose(0, 1, 3, 2, 4)   # (n, D, H, W, C)
    h = jax.nn.celu(dot(h.reshape(-1, wk.shape[0]), wk) + b)
    h = h.reshape(n, -1)                              # (d, h, w, c) flatten
    fcs = aux["fcs"]
    for i, (fw, fb) in enumerate(fcs):
        h = dot(h, fw) + fb
        if i == 0 and fc0_shift is not None:
            if fc0_shift.ndim == 2:
                nblk, f = fc0_shift.shape
                h = (h.reshape(-1, nblk, f) + fc0_shift).reshape(n, f)
            else:
                h = h + fc0_shift
        if i < len(fcs) - 1:
            h = jax.nn.celu(h)
    return h


def dual_rail_stage1(g0k, celu0k, y0, w0v, w1k, u, pos, dot=None):
    """Stage 0+1 of the single-pass dual-rail factorization.

    u, pos: (..., G, k1) magnitude drive / positive-rail mask, with the
    leading axes shaped to broadcast against ``g0k[kk]``/``celu0k[kk]``
    (callers insert singleton NO/W axes).  y0: (R, O1) zero-voltage
    stage-1 projection, tiled over the batch rows.  Returns the two
    rails' stage-1 pre-activations ``(y0 + t_pos, y0 + t_full - t_pos)``
    stacked: (2, batch, R, O1).

    Shared verbatim by ``apply_blocklast`` (CPU/XLA path) and the unified
    Pallas kernel body, so the two paths are bit-identical by
    construction: per window position kk, delta_kk = celu(v0 + g0) -
    celu(g0) is contracted over channels only (one (C0, O1) GEMM) and the
    rail mask lands on the GEMM *output* -- half the FLOPs of the old
    cross-position (C0, k1*O1) contraction, and no diagonal gather."""
    if dot is None:
        dot = jnp.matmul
    k1, C0, O1 = w1k.shape
    R = y0.shape[0]
    t_full = t_pos = None
    for kk in range(k1):
        v0 = u[..., kk, None] * w0v                   # broadcasts vs g0k[kk]
        delta = jax.nn.celu(v0 + g0k[kk]) - celu0k[kk]
        t = dot(delta.reshape(-1, C0), w1k[kk])
        t = t.reshape(-1, R, O1)                      # (batch, R, O1)
        m = jnp.broadcast_to(pos[..., kk, None], delta.shape[:-1] + (1,))
        m = m.reshape(-1, R, 1)
        t_full = t if t_full is None else t_full + t
        tp = t * m
        t_pos = tp if t_pos is None else t_pos + tp
    return jnp.stack([y0[None] + t_pos, y0[None] + t_full - t_pos])


def apply_blocklast(aux: dict, pre: dict, u01: jax.Array, pos01: jax.Array,
                    *, chunk: int = 4,
                    fc0_shift: jax.Array | None = None) -> jax.Array:
    """Single-pass dual-rail blockified forward.

    u01:   (M, NB, D, H) |x|-magnitude wordline drive in [0, 1]
    pos01: (M, NB, D, H) 1.0 where the positive rail is driven (x > 0)
    fc0_shift: optional pre-activation shift -- a conditioned emulator's
    scenario-feature contribution ``sfeat @ aux["f0_scen"]``: either
    ``(fc0_out,)`` (whole-plan corner) or ``(NB*NO, fc0_out)`` (per-tile
    feature operands, one shift per block in lattice order), traced so
    corner/age changes reuse the executable (exactly zero at the ideal
    corner, where the plain path omits it entirely).
    Returns (2, M*NB*NO, O): block outputs of the (v+, v-) rails.

    The stage-0 CELU runs once on the magnitude drive; each rail's stage-1
    pre-activation is reconstructed as y0 + mask-selected delta terms, which
    is exact because delta rows with v = 0 vanish identically."""
    M, NB, D, H = u01.shape
    g0k, celu0k, y0 = pre["g0k"], pre["celu0k"], pre["y0"]
    k1 = g0k.shape[0]
    NO, W, G = g0k.shape[2], g0k.shape[4], g0k.shape[5]

    mc = min(chunk, M)
    padM = (-M) % mc
    if padM:
        u01 = jnp.pad(u01, ((0, padM),) + ((0, 0),) * 3)
        pos01 = jnp.pad(pos01, ((0, padM),) + ((0, 0),) * 3)
    Mp = M + padM
    # wordline index split into (row group G, window position k1), with
    # singleton NO/W axes so the per-kk drive broadcasts against g0k
    ug = u01.reshape(Mp, NB, 1, D, 1, G, k1)
    pg = pos01.reshape(Mp, NB, 1, D, 1, G, k1)

    def one(args):
        uc, mk = args                                 # (mc,NB,1,D,1,G,k1) x2
        h = jax.nn.celu(dual_rail_stage1(g0k, celu0k, y0, aux["w0v"],
                                         aux["w1k"], uc, mk))
        n2 = 2 * mc * NB * NO                         # h: (2, mc, R, O1)
        h = _tail_stages(aux, h.reshape(n2, -1), n2, (n2, D, W, G),
                         fc0_shift=fc0_shift)
        return h.reshape(2, mc * NB * NO, -1)

    ub = ug.reshape((Mp // mc, mc) + ug.shape[1:])
    mb = pg.reshape((Mp // mc, mc) + pg.shape[1:])
    out = jax.lax.map(one, (ub, mb))                  # (nc, 2, mc*NBLK, O)
    out = out.transpose(1, 0, 2, 3).reshape(2, Mp * NB * NO, -1)
    return out[:, :M * NB * NO]


def apply_fused(params, x: jax.Array, periph: jax.Array | None = None) -> jax.Array:
    """TPU-native path: every depth-1 conv rewritten as a reshape + matmul.

    Stage with kernel (1,k,1)/stride (1,k,1):  (B,C,D,H,W) -> group H into
    (H/k, k) and contract (C,k) -> C'.  Final (1,1,2) stage groups W.
    Bit-exact vs apply() (same weights, same arithmetic order up to matmul
    association)."""
    h = x
    for i in range(_n_stages(params)):
        w = params[f"conv{i}_w"]                      # (O, I, kd, kh, kw)
        O, I, kd, kh, kw = w.shape
        B, C, D, H, W = h.shape
        if (kh, kw) == (1, 1):
            # pointwise: (B,C,DHW) x (C,O)
            hm = h.reshape(B, C, D * H * W)
            y = jnp.einsum("bcn,co->bon", hm, w[:, :, 0, 0, 0].T)
            h = y.reshape(B, O, D, H, W)
        elif kw == 1:
            hg = h.reshape(B, C, D, H // kh, kh, W)
            wk = w[:, :, 0, :, 0]                     # (O, I, kh)
            h = jnp.einsum("bcdgkw,ock->bodgw", hg, wk)
            h = h.reshape(B, O, D, H // kh, W)
        else:
            stride_w = _stride_of(w, h)[2]
            wk = w[:, :, 0, 0, :]                     # (O, I, kw)
            if stride_w == kw:
                hg = h.reshape(B, C, D, H, W // kw, kw)
                h = jnp.einsum("bcdhgk,ock->bodhg", hg, wk)
            else:                                      # stride 1, kernel 2
                h = (jnp.einsum("bcdhw,oc->bodhw", h[..., :-1], wk[:, :, 0])
                     + jnp.einsum("bcdhw,oc->bodhw", h[..., 1:], wk[:, :, 1]))
        h = jax.nn.celu(h + params[f"conv{i}_b"][None, :, None, None, None])
    h = h.reshape(h.shape[0], -1)
    if periph is not None:
        h = jnp.concatenate([h, periph.astype(h.dtype)], axis=-1)
    return _head(params, h, _n_fc(params))
