"""Conv4Xbar: the paper's emulator architecture (Fig. 3, Table 2).

A 3D-CNN whose kernels have depth 1 (tiles axis) and grow along the row axis
(H: 1 -> 2 -> 4 -> 8 with matching strides), mirroring column-wise current
accumulation; then a (1,1,2) conv across the differential column pairs; then
an FCNN 'circuit equation solver' head (128/256 -> 32 -> 16 -> O), CELU
everywhere. Peripheral-circuit features are concatenated before the head.

Two apply paths:
  apply()       -- paper-faithful lax.conv_general_dilated stack
  apply_fused() -- TPU-native algebraic rewrite: each depth-1 strided conv is
                   a blocked matmul over reshaped row groups (MXU-friendly;
                   validated equal to apply() in tests). See DESIGN.md §3.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.rram_ps32 import BlockGeometry
from repro.models.common import ParamSchema


@dataclass(frozen=True)
class ConvStage:
    c_in: int
    c_out: int
    kernel: Tuple[int, int, int]     # (D, H, W)
    stride: Tuple[int, int, int]


def build_stages(geom: BlockGeometry) -> List[ConvStage]:
    """Table 2 stack, generalized to any (C, D, H, W) geometry."""
    stages = [ConvStage(geom.features, 16, (1, 1, 1), (1, 1, 1))]
    h = geom.rows
    plan = [(16, 8, 2), (8, 4, 4), (4, 32, 8)]
    for c_in, c_out, k in plan:
        k = min(k, h)
        stages.append(ConvStage(c_in, c_out, (1, k, 1), (1, k, 1)))
        h = h // k
    # across differential column pairs; stride 2 when W > 2 (case B: the
    # paper's Linear(256, 32) implies stride (1,1,2) -- Table 2 typo)
    w_stride = 1 if geom.cols <= 2 else 2
    stages.append(ConvStage(32, 32, (1, 1, 2), (1, 1, w_stride)))
    return stages


def _out_size(size, k, s):
    return (size - k) // s + 1


def flat_features(geom: BlockGeometry) -> int:
    d, h, w = geom.tiles, geom.rows, geom.cols
    for st in build_stages(geom):
        d = _out_size(d, st.kernel[0], st.stride[0])
        h = _out_size(h, st.kernel[1], st.stride[1])
        w = _out_size(w, st.kernel[2], st.stride[2])
    return 32 * d * h * w


def conv4xbar_schema(geom: BlockGeometry, n_periph: int = 0,
                     head: Sequence[int] = (32, 16)):
    """Parameter schema (shapes + shardings + init) for one emulator."""
    s = {}
    for i, st in enumerate(build_stages(geom)):
        fan_in = st.c_in * int(np.prod(st.kernel))
        s[f"conv{i}_w"] = ParamSchema(
            (st.c_out, st.c_in) + st.kernel, P(None), "normal",
            math.sqrt(2.0 / fan_in))
        s[f"conv{i}_b"] = ParamSchema((st.c_out,), P(None), "zeros")
    d_in = flat_features(geom) + n_periph
    dims = [d_in, *head, geom.outputs]
    for i in range(len(dims) - 1):
        s[f"fc{i}_w"] = ParamSchema((dims[i], dims[i + 1]), P(None), "normal",
                                    math.sqrt(2.0 / dims[i]))
        s[f"fc{i}_b"] = ParamSchema((dims[i + 1],), P(None), "zeros")
    s["_meta"] = ParamSchema((3,), P(None), "zeros")   # (n_stages, n_fc, n_periph)
    return s


def _head(params, h, n_fc):
    for i in range(n_fc):
        h = h @ params[f"fc{i}_w"] + params[f"fc{i}_b"]
        if i < n_fc - 1:
            h = jax.nn.celu(h)
    return h


def _n_stages(params):
    return len([k for k in params if k.startswith("conv") and k.endswith("_w")])


def _n_fc(params):
    return len([k for k in params if k.startswith("fc") and k.endswith("_w")])


def apply(params, x: jax.Array, periph: jax.Array | None = None) -> jax.Array:
    """Paper-faithful path. x: (B, C, D, H, W) -> (B, O)."""
    h = x
    for i in range(_n_stages(params)):
        w = params[f"conv{i}_w"]
        stride = _stride_of(w, h)
        h = jax.lax.conv_general_dilated(
            h, w, window_strides=stride, padding="VALID",
            dimension_numbers=("NCDHW", "OIDHW", "NCDHW"))
        h = jax.nn.celu(h + params[f"conv{i}_b"][None, :, None, None, None])
    h = h.reshape(h.shape[0], -1)
    if periph is not None:
        h = jnp.concatenate([h, periph.astype(h.dtype)], axis=-1)
    return _head(params, h, _n_fc(params))


def _stride_of(w, h):
    """Recover the stage stride from kernel shape (stride == kernel except
    the final (1,1,2) stage where stride_w is 2 iff W_in > 2)."""
    kd, kh, kw = w.shape[2], w.shape[3], w.shape[4]
    if (kd, kh, kw) == (1, 1, 2):
        return (1, 1, 1 if h.shape[4] <= 2 else 2)
    return (kd, kh, kw)


def apply_fused(params, x: jax.Array, periph: jax.Array | None = None) -> jax.Array:
    """TPU-native path: every depth-1 conv rewritten as a reshape + matmul.

    Stage with kernel (1,k,1)/stride (1,k,1):  (B,C,D,H,W) -> group H into
    (H/k, k) and contract (C,k) -> C'.  Final (1,1,2) stage groups W.
    Bit-exact vs apply() (same weights, same arithmetic order up to matmul
    association)."""
    h = x
    for i in range(_n_stages(params)):
        w = params[f"conv{i}_w"]                      # (O, I, kd, kh, kw)
        O, I, kd, kh, kw = w.shape
        B, C, D, H, W = h.shape
        if (kh, kw) == (1, 1):
            # pointwise: (B,C,DHW) x (C,O)
            hm = h.reshape(B, C, D * H * W)
            y = jnp.einsum("bcn,co->bon", hm, w[:, :, 0, 0, 0].T)
            h = y.reshape(B, O, D, H, W)
        elif kw == 1:
            hg = h.reshape(B, C, D, H // kh, kh, W)
            wk = w[:, :, 0, :, 0]                     # (O, I, kh)
            h = jnp.einsum("bcdgkw,ock->bodgw", hg, wk)
            h = h.reshape(B, O, D, H // kh, W)
        else:
            stride_w = _stride_of(w, h)[2]
            wk = w[:, :, 0, 0, :]                     # (O, I, kw)
            if stride_w == kw:
                hg = h.reshape(B, C, D, H, W // kw, kw)
                h = jnp.einsum("bcdhgk,ock->bodhg", hg, wk)
            else:                                      # stride 1, kernel 2
                h = (jnp.einsum("bcdhw,oc->bodhw", h[..., :-1], wk[:, :, 0])
                     + jnp.einsum("bcdhw,oc->bodhw", h[..., 1:], wk[:, :, 1]))
        h = jax.nn.celu(h + params[f"conv{i}_b"][None, :, None, None, None])
    h = h.reshape(h.shape[0], -1)
    if periph is not None:
        h = jnp.concatenate([h, periph.astype(h.dtype)], axis=-1)
    return _head(params, h, _n_fc(params))
