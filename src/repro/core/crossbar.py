"""Crossbar geometry: mapping real-valued weight matrices onto tiled
differential 1T1R crossbar arrays, and building the (C, D, H, W) cell-feature
tensors the paper's emulator consumes.

Layout (matching paper Table 1 geometries):
  * a weight column j (output j) maps to a differential bitline pair
    (G+ holds w>0, G- holds -w<0), so W = 2 * outs_per_block columns/tile
  * the K input rows are split into tiles of `rows`; `tiles_per_block` tiles
    are accumulated *in analog* inside one computing block; remaining tiles
    go to further blocks summed digitally.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import AnalogConfig
from repro.configs.rram_ps32 import BlockGeometry


def weights_to_conductance(w: jax.Array, acfg: AnalogConfig,
                           w_scale: jax.Array):
    """w: (K, N) real -> (g_pos, g_neg): (K, N) conductances in [g_min,g_max].

    w_scale: per-output (N,) or scalar normalization (max |w|)."""
    span = acfg.g_max - acfg.g_min
    wn = w / jnp.maximum(w_scale, 1e-12)
    g_pos = acfg.g_min + span * jnp.clip(wn, 0.0, 1.0)
    g_neg = acfg.g_min + span * jnp.clip(-wn, 0.0, 1.0)
    return g_pos, g_neg


def conductance_to_weights(g_pos, g_neg, acfg: AnalogConfig, w_scale):
    """Inverse mapping (exact for |wn| <= 1)."""
    span = acfg.g_max - acfg.g_min
    return (g_pos - g_neg) / span * w_scale


def pad_rows(x: jax.Array, rows: int, axis: int = 0) -> jax.Array:
    k = x.shape[axis]
    pad = (-k) % rows
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def tile_matrix(w: jax.Array, acfg: AnalogConfig) -> Tuple[jax.Array, jax.Array]:
    """(K, N) -> (T, rows, N) tiles of G+/G- with zero padding.

    Returns (g_pos_tiles, g_neg_tiles), each (T, rows, N)."""
    K, N = w.shape
    w_scale = jnp.max(jnp.abs(w))
    g_pos, g_neg = weights_to_conductance(w, acfg, w_scale)
    # zero weight -> both rails g_min (cancels differentially)
    g_pos = pad_rows(g_pos, acfg.rows)
    g_neg = pad_rows(g_neg, acfg.rows)
    T = g_pos.shape[0] // acfg.rows
    return (g_pos.reshape(T, acfg.rows, N), g_neg.reshape(T, acfg.rows, N))


def tile_inputs(v: jax.Array, acfg: AnalogConfig) -> jax.Array:
    """(B, K) in [0,1] -> (B, T, rows) wordline drive voltages."""
    B, K = v.shape
    v = pad_rows(v, acfg.rows, axis=1)
    T = v.shape[1] // acfg.rows
    return v.reshape(B, T, acfg.rows) * acfg.v_read


def build_block_tensor(v_tiles: jax.Array, gp: jax.Array, gn: jax.Array,
                       geom: BlockGeometry, out_slice) -> jax.Array:
    """Assemble the emulator input tensor X (B, C=2, D, H, W) for one block.

    v_tiles: (B, D, H) voltages; gp/gn: (D, H, n_out) conductances for the
    outputs in `out_slice` (n_out = geom.outputs). W interleaves (G+, G-)
    per output: W = 2 * n_out.
    """
    B, D, H = v_tiles.shape
    n_out = gp.shape[-1]
    # conductance channel: (D, H, W)
    g = jnp.stack([gp, gn], axis=-1).reshape(D, H, 2 * n_out)
    gch = jnp.broadcast_to(g[None], (B, D, H, 2 * n_out))
    vch = jnp.broadcast_to(v_tiles[..., None], (B, D, H, 2 * n_out))
    x = jnp.stack([vch, gch], axis=1)                 # (B, 2, D, H, W)
    return x


@dataclass(frozen=True)
class MatmulPlan:
    """How a (K, N) matmul maps onto computing blocks."""
    K: int
    N: int
    rows: int
    tiles_per_block: int          # D: tiles accumulated in analog
    outs_per_block: int           # outputs sharing a block
    n_tiles: int                  # total row tiles (ceil(K / rows))
    n_block_groups: int           # ceil(n_tiles / D): digital partial sums


def plan_matmul(K: int, N: int, acfg: AnalogConfig,
                geom: BlockGeometry) -> MatmulPlan:
    n_tiles = -(-K // acfg.rows)
    d = geom.tiles
    return MatmulPlan(K=K, N=N, rows=acfg.rows, tiles_per_block=d,
                      outs_per_block=geom.outputs, n_tiles=n_tiles,
                      n_block_groups=-(-n_tiles // d))
