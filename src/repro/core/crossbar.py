"""Crossbar geometry: mapping real-valued weight matrices onto tiled
differential 1T1R crossbar arrays, and building the (C, D, H, W) cell-feature
tensors the paper's emulator consumes.

Layout (matching paper Table 1 geometries):
  * a weight column j (output j) maps to a differential bitline pair
    (G+ holds w>0, G- holds -w<0), so W = 2 * outs_per_block columns/tile
  * the K input rows are split into tiles of `rows`; `tiles_per_block` tiles
    are accumulated *in analog* inside one computing block; remaining tiles
    go to further blocks summed digitally.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AnalogConfig
from repro.configs.rram_ps32 import BlockGeometry


def weights_to_conductance(w: jax.Array, acfg: AnalogConfig,
                           w_scale: jax.Array):
    """w: (K, N) real -> (g_pos, g_neg): (K, N) conductances in [g_min,g_max].

    w_scale: per-output (N,) or scalar normalization (max |w|)."""
    span = acfg.g_max - acfg.g_min
    wn = w / jnp.maximum(w_scale, 1e-12)
    g_pos = acfg.g_min + span * jnp.clip(wn, 0.0, 1.0)
    g_neg = acfg.g_min + span * jnp.clip(-wn, 0.0, 1.0)
    return g_pos, g_neg


def conductance_to_weights(g_pos, g_neg, acfg: AnalogConfig, w_scale):
    """Inverse mapping (exact for |wn| <= 1)."""
    span = acfg.g_max - acfg.g_min
    return (g_pos - g_neg) / span * w_scale


def pad_rows(x: jax.Array, rows: int, axis: int = 0) -> jax.Array:
    k = x.shape[axis]
    pad = (-k) % rows
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def tile_matrix(w: jax.Array, acfg: AnalogConfig) -> Tuple[jax.Array, jax.Array]:
    """(K, N) -> (T, rows, N) tiles of G+/G- with zero padding.

    Returns (g_pos_tiles, g_neg_tiles), each (T, rows, N)."""
    K, N = w.shape
    w_scale = jnp.max(jnp.abs(w))
    g_pos, g_neg = weights_to_conductance(w, acfg, w_scale)
    # zero weight -> both rails g_min (cancels differentially)
    g_pos = pad_rows(g_pos, acfg.rows)
    g_neg = pad_rows(g_neg, acfg.rows)
    T = g_pos.shape[0] // acfg.rows
    return (g_pos.reshape(T, acfg.rows, N), g_neg.reshape(T, acfg.rows, N))


def tile_inputs(v: jax.Array, acfg: AnalogConfig) -> jax.Array:
    """(B, K) in [0,1] -> (B, T, rows) wordline drive voltages."""
    B, K = v.shape
    v = pad_rows(v, acfg.rows, axis=1)
    T = v.shape[1] // acfg.rows
    return v.reshape(B, T, acfg.rows) * acfg.v_read


def build_block_tensor(v_tiles: jax.Array, gp: jax.Array, gn: jax.Array,
                       geom: BlockGeometry, out_slice) -> jax.Array:
    """Assemble the emulator input tensor X (B, C=2, D, H, W) for one block.

    v_tiles: (B, D, H) voltages; gp/gn: (D, H, n_out) conductances for the
    outputs in `out_slice` (n_out = geom.outputs). W interleaves (G+, G-)
    per output: W = 2 * n_out.
    """
    B, D, H = v_tiles.shape
    n_out = gp.shape[-1]
    # conductance channel: (D, H, W)
    g = jnp.stack([gp, gn], axis=-1).reshape(D, H, 2 * n_out)
    gch = jnp.broadcast_to(g[None], (B, D, H, 2 * n_out))
    vch = jnp.broadcast_to(v_tiles[..., None], (B, D, H, 2 * n_out))
    x = jnp.stack([vch, gch], axis=1)                 # (B, 2, D, H, W)
    return x


@dataclass(frozen=True)
class MatmulPlan:
    """How a (K, N) matmul maps onto computing blocks."""
    K: int
    N: int
    rows: int
    tiles_per_block: int          # D: tiles accumulated in analog
    outs_per_block: int           # outputs sharing a block
    n_tiles: int                  # total row tiles (ceil(K / rows))
    n_block_groups: int           # ceil(n_tiles / D): digital partial sums


def plan_matmul(K: int, N: int, acfg: AnalogConfig,
                geom: BlockGeometry) -> MatmulPlan:
    n_tiles = -(-K // acfg.rows)
    d = geom.tiles
    return MatmulPlan(K=K, N=N, rows=acfg.rows, tiles_per_block=d,
                      outs_per_block=geom.outputs, n_tiles=n_tiles,
                      n_block_groups=-(-n_tiles // d))


@dataclass(frozen=True)
class ConductancePlan:
    """Precomputed block layout of one (K, N) weight matrix.

    Conductance features are batch-constant: tiling, padding and the
    per-block (G+, G-) interleave run ONCE when a weight tag is bound, not
    on every forward call.  `g_feat` is indexed by block (NB * NO blocks)
    and broadcast over the batch lazily by whichever backend consumes it.

    `out_perm` (optional) records a fault-aware remapping of logical
    output columns onto physical block positions: `g_feat`'s NO axis holds
    the *permuted* layout and `assemble` gathers outputs back into logical
    order with `y[:, out_perm]`.  Remapping acts at output-group
    granularity (whole blocks move; a block is the atomic unit every
    backend evaluates, so moving one is bit-exact at the ideal point --
    conv/FC feature mixing happens only *within* a block).  `out_perm` may
    be a traced argument: permutation swaps never recompile consumers.
    """
    K: int
    N: int
    rows: int                     # H: wordlines per tile
    D: int                        # tiles accumulated in analog per block
    NB: int                       # block groups over K (digital partial sums)
    NO: int                       # output groups over N
    no: int                       # outputs per block
    g_feat: jax.Array             # (NB, NO, D, H, W=2*no) raw conductances [S]
    g_norm: jax.Array             # same, normalized to [0, 1] for the emulator
    out_perm: Optional[jax.Array] = None   # (N,) logical col -> physical col

    @property
    def n_blocks(self) -> int:
        return self.NB * self.NO

    def with_perm(self, out_perm: Optional[jax.Array]) -> "ConductancePlan":
        """Same layout and conductances, different output gather.  The
        caller is responsible for `g_feat` already holding the matching
        permuted group layout (see `nonideal.perturb.remap_plan`)."""
        return dataclasses.replace(self, out_perm=out_perm)

    def with_lattice(self, g_feat: jax.Array, acfg: AnalogConfig, *,
                     NB: Optional[int] = None,
                     NO: Optional[int] = None) -> "ConductancePlan":
        """A LOCAL view of this plan over a slice of the tile lattice:
        same geometry (rows/D/no), a reduced block-group (NB) and/or
        output-group (NO) count, and the matching ``g_feat`` slice.
        ``repro.parallel.sharding`` builds one per shard inside the
        executor's ``shard_map`` body -- every backend evaluates blocks
        independently, so computing on a lattice slice is bit-identical
        to slicing the full computation.  The output permutation is
        dropped: the fault-remap gather runs on the full post-psum
        output, never on a shard-local slice."""
        g_norm = (g_feat - acfg.g_min) / (acfg.g_max - acfg.g_min)
        return dataclasses.replace(
            self, NB=self.NB if NB is None else NB,
            NO=self.NO if NO is None else NO,
            g_feat=g_feat, g_norm=g_norm, out_perm=None)

    def with_g(self, g_feat: jax.Array, acfg: AnalogConfig) -> "ConductancePlan":
        """Same block layout, different conductances (repro.nonideal injects
        perturbed devices here).  g_norm is rederived so every consumer --
        circuit, analytic, emulator fast path, Pallas kernel -- sees the
        perturbation.  Static fields are unchanged, so compiled functions
        built for this plan's shapes are reused when g_feat is a traced
        argument."""
        g_norm = (g_feat - acfg.g_min) / (acfg.g_max - acfg.g_min)
        return dataclasses.replace(self, g_feat=g_feat, g_norm=g_norm)

    def tile_v(self, v01: jax.Array, v_read: float) -> jax.Array:
        """(M, K) wordline drive in [0,1] -> (M, NB, D, H) tile voltages."""
        M = v01.shape[0]
        v = pad_rows(v01, self.rows, axis=1)
        T = v.shape[1] // self.rows
        vt = v.reshape(M, T, self.rows) * v_read
        padT = self.NB * self.D - T
        if padT:
            vt = jnp.pad(vt, ((0, 0), (0, padT), (0, 0)))
        return vt.reshape(M, self.NB, self.D, self.rows)

    def build_x(self, vb: jax.Array) -> jax.Array:
        """vb: (M, NB, D, H) volts -> (M*NB*NO, 2, D, H, W) raw block-feature
        tensors (the layout circuit/analytic backends consume)."""
        M = vb.shape[0]
        shp = (M, self.NB, self.NO, self.D, self.rows, 2 * self.no)
        v = jnp.broadcast_to(vb[:, :, None, :, :, None], shp)
        g = jnp.broadcast_to(self.g_feat[None], shp)
        x = jnp.stack([v, g], axis=3)         # (M, NB, NO, 2, D, H, W)
        return x.reshape(M * self.n_blocks, 2, self.D, self.rows, 2 * self.no)

    def assemble(self, outs: jax.Array) -> jax.Array:
        """(M*NB*NO, no) block outputs -> (M, N) digital block-group sum.
        With `out_perm` set, physical block outputs are gathered back into
        logical column order (the inverse of the fault-aware remap)."""
        M = outs.shape[0] // self.n_blocks
        if self.out_perm is None:
            y = outs.reshape(M, self.NB, self.NO * self.no)[:, :, :self.N]
            return y.sum(axis=1)
        y = outs.reshape(M, self.NB, self.NO * self.no).sum(axis=1)
        return jnp.take(y, self.out_perm, axis=1)


def build_conductance_plan(w: jax.Array, acfg: AnalogConfig,
                           geom: BlockGeometry) -> ConductancePlan:
    """Tile + pad + interleave a (K, N) weight matrix once."""
    K, N = w.shape
    gp, gn = tile_matrix(w, acfg)                     # (T, H, N)
    T = gp.shape[0]
    D = geom.tiles
    padT = (-T) % D
    if padT:
        gp = jnp.pad(gp, ((0, padT), (0, 0), (0, 0)))
        gn = jnp.pad(gn, ((0, padT), (0, 0), (0, 0)))
    NB = (T + padT) // D
    no = geom.outputs
    padN = (-N) % no
    if padN:
        gp = jnp.pad(gp, ((0, 0), (0, 0), (0, padN)))
        gn = jnp.pad(gn, ((0, 0), (0, 0), (0, padN)))
    NO = (N + padN) // no
    H = acfg.rows
    gpb = gp.reshape(NB, D, H, NO, no)
    gnb = gn.reshape(NB, D, H, NO, no)
    g = jnp.stack([gpb, gnb], axis=-1).reshape(NB, D, H, NO, 2 * no)
    g_feat = g.transpose(0, 3, 1, 2, 4)               # (NB, NO, D, H, W)
    g_norm = (g_feat - acfg.g_min) / (acfg.g_max - acfg.g_min)
    return ConductancePlan(K=K, N=N, rows=H, D=D, NB=NB, NO=NO, no=no,
                           g_feat=g_feat, g_norm=g_norm)


# --------------------------------------------------------------------------- #
# Stuck-fault-aware remapping (classic fault-tolerant mapping)
# --------------------------------------------------------------------------- #
def _horizon_damage(g: np.ndarray, live: np.ndarray, fault: np.ndarray,
                    by_group, plan: ConductancePlan, acfg: AnalogConfig,
                    horizon: Sequence[np.ndarray]) -> np.ndarray:
    """Anticipated end-of-horizon damage matrix ``dmg[q, p]`` averaged
    over a drift trajectory.  Two terms per checkpoint:

      * **drifted stuck-off excess** -- at age t a live cell of logical
        group q placed at physical position p holds
        ``clip(g * df_p(t), g_min, g_max)`` (the decay multiplier
        belongs to the *physical* die position), and a stuck cell there
        reads g_min instead: the clipped, drifted overhang is what the
        fault costs once periodic recalibration has re-centered the
        fleet on its drifted response.
      * **drift mismatch** -- the healthy cells of a group hosted at a
        decay-outlier position deviate from the fleet-mean decay a
        global affine refit absorbs: ``g * |df_p - mean_p df|`` per live
        unfaulted cell.  Without this term a fast-drifting position
        looks deceptively "clean" (its fault excess decays away) and the
        assignment would park heavy groups on the die positions that
        decay them hardest.

    An all-ones trajectory zeroes the mismatch term and reduces the
    fault term to the instantaneous matrix exactly (live plan
    conductances already sit inside [g_min, g_max])."""
    gg = by_group(g)                                   # (NO, C) logical
    lv = by_group(live)
    C = gg.shape[1]
    cells_per_nb = C // plan.NB
    dmg = np.zeros((plan.NO, plan.NO))
    horizon = list(horizon)
    for df in horizon:
        d = np.asarray(df, np.float64)
        if d.ndim == 0:
            dfc = np.broadcast_to(d, (plan.NO, C))
        elif d.shape == (plan.NB, plan.NO):
            # per-tile decay -> per (physical group, cell) with the cell
            # axis (NB, D, H, W)-flattened NB-outermost, matching by_group
            dfc = np.repeat(d.T, cells_per_nb, axis=1)
        else:
            raise ValueError(
                f"horizon drift factor shaped {d.shape}; expected a "
                f"scalar or (NB, NO) = {(plan.NB, plan.NO)}")
        dbar = dfc.mean(axis=0)                        # fleet-mean decay
        for p in range(plan.NO):
            gd = np.clip(gg * dfc[p], acfg.g_min, acfg.g_max)
            ex = np.where(lv, (gd - acfg.g_min), 0.0)
            dmg[:, p] += ex @ fault[p]
            mis = np.where(lv, gg * np.abs(dfc[p] - dbar), 0.0)
            dmg[:, p] += mis @ (1.0 - fault[p])
    span = float(acfg.g_max - acfg.g_min)
    return dmg / (span * max(len(horizon), 1))


def _assignment_horizon_score(g: np.ndarray, off: np.ndarray,
                              gperm: np.ndarray, plan: ConductancePlan,
                              acfg: AnalogConfig,
                              horizon: Sequence[np.ndarray]) -> float:
    """Exact end-of-horizon weight-space deviation of an assignment.

    For each horizon drift factor, realize the effective cell
    conductances a device would serve with under the candidate
    permutation -- stuck-off cells pinned at ``g_min`` (the fault mask
    lives at *physical* positions), live cells decayed by the physical
    host's retention factor and clipped back into range -- fold the
    interleaved pos/neg pairs into differential weights, and measure
    ``min_a ||W_young - a * W_eff||_F^2`` over the real (un-padded)
    columns.  The scalar ``a`` is the global affine refit periodic
    recalibration performs, solved in closed form.  Averaged over the
    horizon; lower is better.  This is the model the greedy candidates
    are judged under, so the returned winner can never model-worse than
    instant remapping."""
    gperm = np.asarray(gperm)
    off_at = off[:, gperm]                             # fault mask seen by q
    live = g > 0.0
    # mask padded logical columns (dropped by the assemble gather)
    no = plan.no
    col = (np.arange(plan.NO)[:, None] * no + np.arange(no)[None, :])
    valid = (col < plan.N).astype(np.float64)          # (NO, no)
    vmask = valid[None, :, None, None, :]
    w_young = (g[..., 0::2] - g[..., 1::2]) * vmask
    total = 0.0
    horizon = list(horizon)
    for df in horizon:
        d = np.asarray(df, np.float64)
        if d.ndim == 0:
            dfq = np.broadcast_to(d, (plan.NB, plan.NO))
        elif d.shape == (plan.NB, plan.NO):
            dfq = d[:, gperm]                          # decay of q's host
        else:
            raise ValueError(
                f"horizon drift factor shaped {d.shape}; expected a "
                f"scalar or (NB, NO) = {(plan.NB, plan.NO)}")
        dfe = dfq[:, :, None, None, None]
        aged = np.clip(g * dfe, acfg.g_min, acfg.g_max)
        eff = np.where(off_at, acfg.g_min, np.where(live, aged, 0.0))
        w_eff = (eff[..., 0::2] - eff[..., 1::2]) * vmask
        ee = float((w_eff * w_eff).sum())
        a = float((w_eff * w_young).sum()) / ee if ee > 0.0 else 1.0
        r = w_young - a * w_eff
        total += float((r * r).sum())
    return total / max(len(horizon), 1)


def fault_aware_group_perm(g_feat: np.ndarray, stuck_off: np.ndarray,
                           plan: ConductancePlan, acfg: AnalogConfig,
                           top_q: float = 0.9,
                           horizon: Optional[Sequence[np.ndarray]] = None
                           ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Permute logical output groups across physical block positions so
    large-magnitude weights avoid stuck-at-G_off cells.

    A cell stuck at G_off reads as weight zero: the damage it does equals
    the conductance excess `g - g_min` the plan wanted to program there.
    Remapping moves whole output groups (the blocks backends evaluate
    atomically, so the move is bit-exact at the ideal point; with
    `no == 1`, as in the paper's case-A geometry, that is per-column).
    The assignment is lexicographic: first minimize the number of
    top-`top_q`-quantile |w| cells landing on stuck-off sites, then the
    total excess landing there -- greedy over logical groups in descending
    order of top-weight mass, which pairs the most-vulnerable groups with
    the cleanest physical positions first (rearrangement-inequality
    heuristic).  Deterministic; the identity permutation falls out exactly
    when no stuck-off cell overlaps any programmed cell.

    Wear-aware mode (``horizon`` given): ``horizon`` is a sequence of
    retention-decay multipliers (``nonideal.perturb.drift_factor`` at the
    maintenance checkpoints), each a scalar or an (NB, NO) array indexed
    by *physical* tile.  A second candidate assignment is grown greedily
    under the anticipated-damage matrix (``_horizon_damage``: drifted
    stuck-off excess + drift-mismatch of healthy cells), and the instant
    and wear-aware candidates are then SCORED under the exact
    end-of-horizon weight-space deviation model
    (``_assignment_horizon_score``: realized differential weights under
    faults + per-position decay, with the global affine refit absorbed)
    -- the lower-scoring assignment wins, instant on ties.  Wear-aware
    remapping therefore never models-worse than instant remapping over
    the horizon, and genuinely wins when per-die drift heterogeneity
    makes slow-decaying positions the riskier hosts.  ``horizon=None``
    runs the instantaneous assignment, bit-identically to a call without
    the argument.

    Args:
      g_feat:    (NB, NO, D, H, W) base-plan conductances (logical layout).
      stuck_off: (NB, NO, D, H, W) boolean stuck-off mask at *physical*
                 positions (from `nonideal.perturb.realized_fault_masks`).
      plan:      the base plan (geometry only).
      acfg:      conductance range (g_min for the excess measure).
      top_q:     |w| quantile defining the protected cell set.
      horizon:   optional drift-factor trajectory for wear-aware scoring.

    Returns `(out_perm, gperm, ginv)` int arrays: `out_perm[j]` = physical
    column of logical column j (the `assemble` gather), `gperm[q]` =
    physical group of logical group q, `ginv[p]` = logical group at
    physical position p (the `g_feat` NO-axis gather).
    """
    g = np.asarray(g_feat, np.float64)
    off = np.asarray(stuck_off, bool)
    cands = _perm_candidates(g, off, plan, acfg, top_q, horizon)
    gperm = cands[0]
    if len(cands) > 1:
        s_inst = _assignment_horizon_score(g, off, cands[0], plan, acfg,
                                           horizon)
        s_wear = _assignment_horizon_score(g, off, cands[1], plan, acfg,
                                           horizon)
        if s_wear < s_inst:                            # instant wins ties
            gperm = cands[1]
    return finish_group_perm(gperm, plan)


def finish_group_perm(gperm: np.ndarray, plan: ConductancePlan
                      ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Expand a logical->physical group assignment into the
    `(out_perm, gperm, ginv)` triple `fault_aware_group_perm` returns."""
    ginv = np.empty_like(gperm)
    ginv[gperm] = np.arange(plan.NO, dtype=np.int32)
    cols = np.arange(plan.N, dtype=np.int32)
    out_perm = gperm[cols // plan.no] * plan.no + cols % plan.no
    return out_perm.astype(np.int32), gperm, ginv


def _perm_candidates(g: np.ndarray, off: np.ndarray, plan: ConductancePlan,
                     acfg: AnalogConfig, top_q: float,
                     horizon: Optional[Sequence[np.ndarray]]) -> list:
    """Candidate group assignments: the instantaneous greedy first,
    plus -- when a ``horizon`` is given and it disagrees -- the
    wear-aware greedy grown under the anticipated-damage matrix.  The
    caller selects between them (model score here, realized score in
    ``nonideal.perturb.remap_plan``)."""
    span = float(acfg.g_max - acfg.g_min)
    live = g > 0.0
    # damage a stuck-off cell does = programmed excess over g_min, in
    # weight units; padded sites (no physical cell) carry none
    excess = np.where(live, (g - acfg.g_min) / span, 0.0)
    pos_excess = excess[excess > 0.0]
    if pos_excess.size == 0:
        return [np.arange(plan.NO, dtype=np.int32)]
    thr = np.quantile(pos_excess, top_q)
    top = (excess >= thr) & live                       # top-decile |w| cells
    # per-group flattening: (NB, NO, D, H, W) -> (NO, NB*D*H*W)
    by_group = lambda a: a.transpose(1, 0, 2, 3, 4).reshape(plan.NO, -1)
    fault = by_group(off)                              # physical positions
    excess_g = by_group(excess)                        # logical groups
    top_g = by_group(top).astype(np.float64)
    hits = np.einsum("pc,qc->qp", fault, top_g)
    # greedy: most-vulnerable logical groups pick first -- ordered by
    # top-decile cell count FIRST (its own scale: a group's total excess
    # routinely exceeds dmg.max(), which is damped by the sparse mask)
    vbig = excess_g.sum(axis=1).max() + 1.0
    vuln = top_g.sum(axis=1) * vbig + excess_g.sum(axis=1)
    order = np.argsort(-vuln, kind="stable")

    def greedy(dmg: np.ndarray) -> np.ndarray:
        big = dmg.max() + 1.0
        cost = hits * big + dmg                        # lexicographic
        gp = np.full(plan.NO, -1, dtype=np.int32)
        free = np.ones(plan.NO, bool)
        for q in order:
            c = np.where(free, cost[q], np.inf)
            best = c.min()
            # prefer staying home on ties -> identity when fault-free
            p = int(q) if (free[q] and c[q] <= best) else int(np.argmin(c))
            gp[q] = p
            free[p] = False
        return gp

    cands = [greedy(np.einsum("pc,qc->qp", fault, excess_g))]
    if horizon is not None:
        cand = greedy(_horizon_damage(g, live, fault, by_group, plan, acfg,
                                      horizon))
        if not np.array_equal(cand, cands[0]):
            cands.append(cand)
    return cands
