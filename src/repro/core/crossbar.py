"""Crossbar geometry: mapping real-valued weight matrices onto tiled
differential 1T1R crossbar arrays, and building the (C, D, H, W) cell-feature
tensors the paper's emulator consumes.

Layout (matching paper Table 1 geometries):
  * a weight column j (output j) maps to a differential bitline pair
    (G+ holds w>0, G- holds -w<0), so W = 2 * outs_per_block columns/tile
  * the K input rows are split into tiles of `rows`; `tiles_per_block` tiles
    are accumulated *in analog* inside one computing block; remaining tiles
    go to further blocks summed digitally.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AnalogConfig
from repro.configs.rram_ps32 import BlockGeometry


def weights_to_conductance(w: jax.Array, acfg: AnalogConfig,
                           w_scale: jax.Array):
    """w: (K, N) real -> (g_pos, g_neg): (K, N) conductances in [g_min,g_max].

    w_scale: per-output (N,) or scalar normalization (max |w|)."""
    span = acfg.g_max - acfg.g_min
    wn = w / jnp.maximum(w_scale, 1e-12)
    g_pos = acfg.g_min + span * jnp.clip(wn, 0.0, 1.0)
    g_neg = acfg.g_min + span * jnp.clip(-wn, 0.0, 1.0)
    return g_pos, g_neg


def conductance_to_weights(g_pos, g_neg, acfg: AnalogConfig, w_scale):
    """Inverse mapping (exact for |wn| <= 1)."""
    span = acfg.g_max - acfg.g_min
    return (g_pos - g_neg) / span * w_scale


def pad_rows(x: jax.Array, rows: int, axis: int = 0) -> jax.Array:
    k = x.shape[axis]
    pad = (-k) % rows
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def tile_matrix(w: jax.Array, acfg: AnalogConfig) -> Tuple[jax.Array, jax.Array]:
    """(K, N) -> (T, rows, N) tiles of G+/G- with zero padding.

    Returns (g_pos_tiles, g_neg_tiles), each (T, rows, N)."""
    K, N = w.shape
    w_scale = jnp.max(jnp.abs(w))
    g_pos, g_neg = weights_to_conductance(w, acfg, w_scale)
    # zero weight -> both rails g_min (cancels differentially)
    g_pos = pad_rows(g_pos, acfg.rows)
    g_neg = pad_rows(g_neg, acfg.rows)
    T = g_pos.shape[0] // acfg.rows
    return (g_pos.reshape(T, acfg.rows, N), g_neg.reshape(T, acfg.rows, N))


def tile_inputs(v: jax.Array, acfg: AnalogConfig) -> jax.Array:
    """(B, K) in [0,1] -> (B, T, rows) wordline drive voltages."""
    B, K = v.shape
    v = pad_rows(v, acfg.rows, axis=1)
    T = v.shape[1] // acfg.rows
    return v.reshape(B, T, acfg.rows) * acfg.v_read


def build_block_tensor(v_tiles: jax.Array, gp: jax.Array, gn: jax.Array,
                       geom: BlockGeometry, out_slice) -> jax.Array:
    """Assemble the emulator input tensor X (B, C=2, D, H, W) for one block.

    v_tiles: (B, D, H) voltages; gp/gn: (D, H, n_out) conductances for the
    outputs in `out_slice` (n_out = geom.outputs). W interleaves (G+, G-)
    per output: W = 2 * n_out.
    """
    B, D, H = v_tiles.shape
    n_out = gp.shape[-1]
    # conductance channel: (D, H, W)
    g = jnp.stack([gp, gn], axis=-1).reshape(D, H, 2 * n_out)
    gch = jnp.broadcast_to(g[None], (B, D, H, 2 * n_out))
    vch = jnp.broadcast_to(v_tiles[..., None], (B, D, H, 2 * n_out))
    x = jnp.stack([vch, gch], axis=1)                 # (B, 2, D, H, W)
    return x


@dataclass(frozen=True)
class MatmulPlan:
    """How a (K, N) matmul maps onto computing blocks."""
    K: int
    N: int
    rows: int
    tiles_per_block: int          # D: tiles accumulated in analog
    outs_per_block: int           # outputs sharing a block
    n_tiles: int                  # total row tiles (ceil(K / rows))
    n_block_groups: int           # ceil(n_tiles / D): digital partial sums


def plan_matmul(K: int, N: int, acfg: AnalogConfig,
                geom: BlockGeometry) -> MatmulPlan:
    n_tiles = -(-K // acfg.rows)
    d = geom.tiles
    return MatmulPlan(K=K, N=N, rows=acfg.rows, tiles_per_block=d,
                      outs_per_block=geom.outputs, n_tiles=n_tiles,
                      n_block_groups=-(-n_tiles // d))


@dataclass(frozen=True)
class ConductancePlan:
    """Precomputed block layout of one (K, N) weight matrix.

    Conductance features are batch-constant: tiling, padding and the
    per-block (G+, G-) interleave run ONCE when a weight tag is bound, not
    on every forward call.  `g_feat` is indexed by block (NB * NO blocks)
    and broadcast over the batch lazily by whichever backend consumes it.

    `out_perm` (optional) records a fault-aware remapping of logical
    output columns onto physical block positions: `g_feat`'s NO axis holds
    the *permuted* layout and `assemble` gathers outputs back into logical
    order with `y[:, out_perm]`.  Remapping acts at output-group
    granularity (whole blocks move; a block is the atomic unit every
    backend evaluates, so moving one is bit-exact at the ideal point --
    conv/FC feature mixing happens only *within* a block).  `out_perm` may
    be a traced argument: permutation swaps never recompile consumers.
    """
    K: int
    N: int
    rows: int                     # H: wordlines per tile
    D: int                        # tiles accumulated in analog per block
    NB: int                       # block groups over K (digital partial sums)
    NO: int                       # output groups over N
    no: int                       # outputs per block
    g_feat: jax.Array             # (NB, NO, D, H, W=2*no) raw conductances [S]
    g_norm: jax.Array             # same, normalized to [0, 1] for the emulator
    out_perm: Optional[jax.Array] = None   # (N,) logical col -> physical col

    @property
    def n_blocks(self) -> int:
        return self.NB * self.NO

    def with_perm(self, out_perm: Optional[jax.Array]) -> "ConductancePlan":
        """Same layout and conductances, different output gather.  The
        caller is responsible for `g_feat` already holding the matching
        permuted group layout (see `nonideal.perturb.remap_plan`)."""
        return dataclasses.replace(self, out_perm=out_perm)

    def with_lattice(self, g_feat: jax.Array, acfg: AnalogConfig, *,
                     NB: Optional[int] = None,
                     NO: Optional[int] = None) -> "ConductancePlan":
        """A LOCAL view of this plan over a slice of the tile lattice:
        same geometry (rows/D/no), a reduced block-group (NB) and/or
        output-group (NO) count, and the matching ``g_feat`` slice.
        ``repro.parallel.sharding`` builds one per shard inside the
        executor's ``shard_map`` body -- every backend evaluates blocks
        independently, so computing on a lattice slice is bit-identical
        to slicing the full computation.  The output permutation is
        dropped: the fault-remap gather runs on the full post-psum
        output, never on a shard-local slice."""
        g_norm = (g_feat - acfg.g_min) / (acfg.g_max - acfg.g_min)
        return dataclasses.replace(
            self, NB=self.NB if NB is None else NB,
            NO=self.NO if NO is None else NO,
            g_feat=g_feat, g_norm=g_norm, out_perm=None)

    def with_g(self, g_feat: jax.Array, acfg: AnalogConfig) -> "ConductancePlan":
        """Same block layout, different conductances (repro.nonideal injects
        perturbed devices here).  g_norm is rederived so every consumer --
        circuit, analytic, emulator fast path, Pallas kernel -- sees the
        perturbation.  Static fields are unchanged, so compiled functions
        built for this plan's shapes are reused when g_feat is a traced
        argument."""
        g_norm = (g_feat - acfg.g_min) / (acfg.g_max - acfg.g_min)
        return dataclasses.replace(self, g_feat=g_feat, g_norm=g_norm)

    def tile_v(self, v01: jax.Array, v_read: float) -> jax.Array:
        """(M, K) wordline drive in [0,1] -> (M, NB, D, H) tile voltages."""
        M = v01.shape[0]
        v = pad_rows(v01, self.rows, axis=1)
        T = v.shape[1] // self.rows
        vt = v.reshape(M, T, self.rows) * v_read
        padT = self.NB * self.D - T
        if padT:
            vt = jnp.pad(vt, ((0, 0), (0, padT), (0, 0)))
        return vt.reshape(M, self.NB, self.D, self.rows)

    def build_x(self, vb: jax.Array) -> jax.Array:
        """vb: (M, NB, D, H) volts -> (M*NB*NO, 2, D, H, W) raw block-feature
        tensors (the layout circuit/analytic backends consume)."""
        M = vb.shape[0]
        shp = (M, self.NB, self.NO, self.D, self.rows, 2 * self.no)
        v = jnp.broadcast_to(vb[:, :, None, :, :, None], shp)
        g = jnp.broadcast_to(self.g_feat[None], shp)
        x = jnp.stack([v, g], axis=3)         # (M, NB, NO, 2, D, H, W)
        return x.reshape(M * self.n_blocks, 2, self.D, self.rows, 2 * self.no)

    def assemble(self, outs: jax.Array) -> jax.Array:
        """(M*NB*NO, no) block outputs -> (M, N) digital block-group sum.
        With `out_perm` set, physical block outputs are gathered back into
        logical column order (the inverse of the fault-aware remap)."""
        M = outs.shape[0] // self.n_blocks
        if self.out_perm is None:
            y = outs.reshape(M, self.NB, self.NO * self.no)[:, :, :self.N]
            return y.sum(axis=1)
        y = outs.reshape(M, self.NB, self.NO * self.no).sum(axis=1)
        return jnp.take(y, self.out_perm, axis=1)


def build_conductance_plan(w: jax.Array, acfg: AnalogConfig,
                           geom: BlockGeometry) -> ConductancePlan:
    """Tile + pad + interleave a (K, N) weight matrix once."""
    K, N = w.shape
    gp, gn = tile_matrix(w, acfg)                     # (T, H, N)
    T = gp.shape[0]
    D = geom.tiles
    padT = (-T) % D
    if padT:
        gp = jnp.pad(gp, ((0, padT), (0, 0), (0, 0)))
        gn = jnp.pad(gn, ((0, padT), (0, 0), (0, 0)))
    NB = (T + padT) // D
    no = geom.outputs
    padN = (-N) % no
    if padN:
        gp = jnp.pad(gp, ((0, 0), (0, 0), (0, padN)))
        gn = jnp.pad(gn, ((0, 0), (0, 0), (0, padN)))
    NO = (N + padN) // no
    H = acfg.rows
    gpb = gp.reshape(NB, D, H, NO, no)
    gnb = gn.reshape(NB, D, H, NO, no)
    g = jnp.stack([gpb, gnb], axis=-1).reshape(NB, D, H, NO, 2 * no)
    g_feat = g.transpose(0, 3, 1, 2, 4)               # (NB, NO, D, H, W)
    g_norm = (g_feat - acfg.g_min) / (acfg.g_max - acfg.g_min)
    return ConductancePlan(K=K, N=N, rows=H, D=D, NB=NB, NO=NO, no=no,
                           g_feat=g_feat, g_norm=g_norm)


# --------------------------------------------------------------------------- #
# Stuck-fault-aware remapping (classic fault-tolerant mapping)
# --------------------------------------------------------------------------- #
def fault_aware_group_perm(g_feat: np.ndarray, stuck_off: np.ndarray,
                           plan: ConductancePlan, acfg: AnalogConfig,
                           top_q: float = 0.9
                           ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Permute logical output groups across physical block positions so
    large-magnitude weights avoid stuck-at-G_off cells.

    A cell stuck at G_off reads as weight zero: the damage it does equals
    the conductance excess `g - g_min` the plan wanted to program there.
    Remapping moves whole output groups (the blocks backends evaluate
    atomically, so the move is bit-exact at the ideal point; with
    `no == 1`, as in the paper's case-A geometry, that is per-column).
    The assignment is lexicographic: first minimize the number of
    top-`top_q`-quantile |w| cells landing on stuck-off sites, then the
    total excess landing there -- greedy over logical groups in descending
    order of top-weight mass, which pairs the most-vulnerable groups with
    the cleanest physical positions first (rearrangement-inequality
    heuristic).  Deterministic; the identity permutation falls out exactly
    when no stuck-off cell overlaps any programmed cell.

    Args:
      g_feat:    (NB, NO, D, H, W) base-plan conductances (logical layout).
      stuck_off: (NB, NO, D, H, W) boolean stuck-off mask at *physical*
                 positions (from `nonideal.perturb.realized_fault_masks`).
      plan:      the base plan (geometry only).
      acfg:      conductance range (g_min for the excess measure).

    Returns `(out_perm, gperm, ginv)` int arrays: `out_perm[j]` = physical
    column of logical column j (the `assemble` gather), `gperm[q]` =
    physical group of logical group q, `ginv[p]` = logical group at
    physical position p (the `g_feat` NO-axis gather).
    """
    g = np.asarray(g_feat, np.float64)
    off = np.asarray(stuck_off, bool)
    span = float(acfg.g_max - acfg.g_min)
    live = g > 0.0
    # damage a stuck-off cell does = programmed excess over g_min, in
    # weight units; padded sites (no physical cell) carry none
    excess = np.where(live, (g - acfg.g_min) / span, 0.0)
    pos_excess = excess[excess > 0.0]
    if pos_excess.size == 0:
        ident = np.arange(plan.NO, dtype=np.int32)
        return np.arange(plan.N, dtype=np.int32), ident, ident.copy()
    thr = np.quantile(pos_excess, top_q)
    top = (excess >= thr) & live                       # top-decile |w| cells
    # per-group flattening: (NB, NO, D, H, W) -> (NO, NB*D*H*W)
    by_group = lambda a: a.transpose(1, 0, 2, 3, 4).reshape(plan.NO, -1)
    fault = by_group(off)                              # physical positions
    excess_g = by_group(excess)                        # logical groups
    top_g = by_group(top).astype(np.float64)
    dmg = np.einsum("pc,qc->qp", fault, excess_g)
    hits = np.einsum("pc,qc->qp", fault, top_g)
    big = dmg.max() + 1.0
    cost = hits * big + dmg                            # lexicographic
    # greedy: most-vulnerable logical groups pick first -- ordered by
    # top-decile cell count FIRST (its own scale: a group's total excess
    # routinely exceeds dmg.max(), which is damped by the sparse mask)
    vbig = excess_g.sum(axis=1).max() + 1.0
    vuln = top_g.sum(axis=1) * vbig + excess_g.sum(axis=1)
    order = np.argsort(-vuln, kind="stable")
    gperm = np.full(plan.NO, -1, dtype=np.int32)
    free = np.ones(plan.NO, bool)
    for q in order:
        c = np.where(free, cost[q], np.inf)
        best = c.min()
        # prefer staying home on ties -> identity when fault-free
        p = int(q) if (free[q] and c[q] <= best) else int(np.argmin(c))
        gperm[q] = p
        free[p] = False
    ginv = np.empty_like(gperm)
    ginv[gperm] = np.arange(plan.NO, dtype=np.int32)
    cols = np.arange(plan.N, dtype=np.int32)
    out_perm = gperm[cols // plan.no] * plan.no + cols % plan.no
    return out_perm.astype(np.int32), gperm, ginv
