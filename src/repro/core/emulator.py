"""Emulator lifecycle: generate circuit data -> train Conv4Xbar by MSE
regression -> accept via Theorem 4.1 -> deploy as an analog-matmul backend.

Reproduces the paper's training protocol: Adam, lr halved at fixed epochs
(Fig. 4), 50k samples (Table 1), train/test split, MAE reporting.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple


def _n_fc_keys(p) -> int:
    return len([k for k in p if k.startswith("fc") and k.endswith("_w")])

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AnalogConfig
from repro.configs.rram_ps32 import BlockGeometry, EmulatorTrainConfig
from repro.core import conv4xbar, theory
from repro.core.circuit import CircuitParams, block_response
from repro.models.common import init_params


def sample_block_inputs(key, n: int, geom: BlockGeometry, acfg: AnalogConfig,
                        with_periph: bool = True):
    """Random (V, G) cell features + peripheral features, shaped for the
    emulator: X (n, 2, D, H, W), periph (n, 2)."""
    k1, k2, k3 = jax.random.split(key, 3)
    v = jax.random.uniform(k1, (n, geom.tiles, geom.rows)) * acfg.v_read
    g = jax.random.uniform(k2, (n, geom.tiles, geom.rows, geom.cols),
                           minval=acfg.g_min, maxval=acfg.g_max)
    vch = jnp.broadcast_to(v[..., None], g.shape)
    x = jnp.stack([vch, g], axis=1)                   # (n, 2, D, H, W)
    if with_periph:
        gain = jax.random.uniform(k3, (n, 1), minval=0.9, maxval=1.1)
        off = jax.random.uniform(jax.random.fold_in(k3, 1), (n, 1),
                                 minval=-0.01, maxval=0.01)
        periph = jnp.concatenate([gain, off], axis=-1)
    else:
        periph = None
    return x, periph


def normalize_features(x: jax.Array, acfg: AnalogConfig) -> jax.Array:
    """Paper normalizes V and G channels to [0, 1]."""
    v = x[:, 0] / acfg.v_read
    g = (x[:, 1] - acfg.g_min) / (acfg.g_max - acfg.g_min)
    return jnp.stack([v, g], axis=1)


def generate_dataset(key, n: int, geom: BlockGeometry, acfg: AnalogConfig,
                     cp: CircuitParams, batch: int = 2048,
                     with_periph: bool = True):
    """Run the circuit solver to label n random block inputs."""
    solve = jax.jit(lambda x, p: block_response(x, cp, p))
    xs, ps, ys = [], [], []
    done = 0
    while done < n:
        b = min(batch, n - done)
        key, sub = jax.random.split(key)
        # always sample the fixed batch size and slice the tail, so `solve`
        # compiles exactly once instead of once more for the final partial
        # batch
        x, periph = sample_block_inputs(sub, batch, geom, acfg, with_periph)
        y = solve(x, periph)
        xs.append(normalize_features(x[:b], acfg))
        ps.append(periph[:b] if periph is not None else None)
        ys.append(y[:b])
        done += b
    X = jnp.concatenate(xs)
    Pf = jnp.concatenate(ps) if with_periph else None
    Y = jnp.concatenate(ys)
    return X, Pf, Y


@dataclass
class EmulatorResult:
    params: dict
    history: Dict[str, List[float]]
    train_mse: float
    test_mse: float
    test_mae: float
    bound: float
    accepted: bool
    sig_prob: float


def train_emulator(key, geom: BlockGeometry, acfg: AnalogConfig,
                   cp: CircuitParams, tcfg: EmulatorTrainConfig,
                   fused: bool = True, log_every: int = 0,
                   data=None) -> EmulatorResult:
    """Full paper protocol. `data` lets callers reuse a pregenerated set.

    Targets are standardized during optimization and the affine is folded
    exactly into the last FC layer afterwards, so the returned params
    predict raw volts. fused=True uses the MXU-native algebraic rewrite of
    the conv stack (bit-equal to the paper's conv path; see tests)."""
    kd, ki, ks = jax.random.split(key, 3)
    if data is None:
        X, Pf, Y = generate_dataset(kd, tcfg.n_train + tcfg.n_test, geom, acfg, cp)
    else:
        X, Pf, Y = data
    n_periph = 0 if Pf is None else Pf.shape[-1]
    Xtr, Xte = X[:tcfg.n_train], X[tcfg.n_train:]
    Ytr, Yte = Y[:tcfg.n_train], Y[tcfg.n_train:]
    Ptr = Pf[:tcfg.n_train] if Pf is not None else None
    Pte = Pf[tcfg.n_train:] if Pf is not None else None

    y_mean = jnp.mean(Ytr, axis=0)
    y_std = jnp.maximum(jnp.std(Ytr, axis=0), 1e-6)
    Ytr_n = (Ytr - y_mean) / y_std

    schema = conv4xbar.conv4xbar_schema(geom, n_periph=n_periph)
    params = init_params(ki, schema)
    apply_fn = conv4xbar.apply_fused if fused else conv4xbar.apply

    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)

    def loss_fn(p, xb, pb, yb):
        pred = apply_fn(p, xb, pb)
        return jnp.mean(jnp.square(pred - yb))

    n = Xtr.shape[0]
    bs = min(tcfg.batch_size, n)
    steps_per_epoch = max(1, n // bs)

    @functools.partial(jax.jit, donate_argnums=(0, 1, 2))
    def epoch_fn(p, m, v, t0, lr, perm):
        xb = Xtr[perm[:steps_per_epoch * bs]].reshape(
            (steps_per_epoch, bs) + Xtr.shape[1:])
        yb = Ytr_n[perm[:steps_per_epoch * bs]].reshape(
            (steps_per_epoch, bs) + Ytr_n.shape[1:])
        if Ptr is not None:
            pb = Ptr[perm[:steps_per_epoch * bs]].reshape(
                (steps_per_epoch, bs) + Ptr.shape[1:])
        else:
            pb = jnp.zeros((steps_per_epoch, bs, 0))

        def step(carry, xs):
            p, m, v, t = carry
            xi, pi, yi = xs
            l, g = jax.value_and_grad(loss_fn)(
                p, xi, pi if Ptr is not None else None, yi)
            m = jax.tree.map(lambda a, b: 0.9 * a + 0.1 * b, m, g)
            v = jax.tree.map(lambda a, b: 0.999 * a + 0.001 * jnp.square(b), v, g)
            t = t + 1
            bc1 = 1 - 0.9 ** t
            bc2 = 1 - 0.999 ** t
            p = jax.tree.map(
                lambda pp, mm, vv: pp - lr * (mm / bc1) / (jnp.sqrt(vv / bc2) + 1e-8),
                p, m, v)
            return (p, m, v, t), l

        (p, m, v, t), ls = jax.lax.scan(step, (p, m, v, t0), (xb, pb, yb))
        return p, m, v, t, ls.mean()

    def unfold(p):
        """Fold target standardization into the last FC layer (exact)."""
        nf = _n_fc_keys(p)
        q = dict(p)
        q[f"fc{nf-1}_w"] = p[f"fc{nf-1}_w"] * y_std[None, :]
        q[f"fc{nf-1}_b"] = p[f"fc{nf-1}_b"] * y_std + y_mean
        return q

    eval_mse = jax.jit(
        lambda p: jnp.mean(jnp.square(apply_fn(p, Xte, Pte) - Yte)))
    hist = {"epoch": [], "train": [], "test": [], "lr": []}
    lr = tcfg.lr
    t = jnp.zeros((), jnp.float32)
    rng = np.random.default_rng(tcfg.seed)
    tr_loss = float("nan")
    for epoch in range(tcfg.epochs):
        if epoch in tcfg.lr_halve_at:
            lr *= 0.5
        perm = jnp.asarray(rng.permutation(n))
        # lr enters as a device scalar, not a Python float: lr-halving epochs
        # must not retrigger a compile of epoch_fn
        params, m, v, t, l = epoch_fn(params, m, v, t,
                                      jnp.float32(lr), perm)
        tr_loss = float(l) * float(jnp.mean(y_std) ** 2)
        if log_every and (epoch % log_every == 0 or epoch == tcfg.epochs - 1):
            te = float(eval_mse(unfold(params)))
            hist["epoch"].append(epoch)
            hist["train"].append(tr_loss)
            hist["test"].append(te)
            hist["lr"].append(lr)
            print(f"  epoch {epoch:5d} lr {lr:.2e} train {tr_loss:.3e} test {te:.3e}",
                  flush=True)

    params = unfold(params)
    test_pred = apply_fn(params, Xte, Pte)
    err = test_pred - Yte
    test_mse = float(jnp.mean(jnp.square(err)))
    test_mae = float(jnp.mean(jnp.abs(err)))
    bound = theory.mse_bound(tcfg.sig_bit, tcfg.prob)
    sig = float(theory.significance_probability(err, tcfg.sig_bit))
    return EmulatorResult(
        params=params, history=hist, train_mse=tr_loss, test_mse=test_mse,
        test_mae=test_mae, bound=bound,
        accepted=(test_mse < bound) and (sig > tcfg.prob), sig_prob=sig)
