"""SEMULATOR core: the paper's contribution as a composable JAX module.

  theory     -- Theorem 4.1 acceptance bounds
  crossbar   -- weight->conductance mapping, tiling, block tensors
  circuit    -- Newton-Raphson 1T1R + PS32 solver (SPICE stand-in)
  analytic   -- expert analytical baseline
  conv4xbar  -- the emulator network (Table 2), conv + fused paths
  emulator   -- dataset generation + regression training + acceptance
  analog     -- AnalogMatmul executor wired into repro.models via dense()
  deployment -- DeploymentState pytree + immutable Deployment spec: the
                one traced argument of the executor's unified forward
                (docs/api.md)
"""
