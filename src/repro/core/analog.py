"""AnalogMatmul: execute dense projections on emulated crossbar hardware.

Backends (config ``analog.backend``):
  digital   -- plain matmul (technique off; baseline)
  analytic  -- expert analytical model (paper's strawman)
  circuit   -- Newton-Raphson circuit solver (exact, slow; SPICE stand-in)
  emulator  -- trained Conv4Xbar regression net (the paper's contribution)

Execution model (see core/crossbar.py): weights are tiled onto differential
1T1R crossbars; activations drive wordlines dual-rail (v+ = relu(x),
v- = relu(-x)); blocks of D tiles accumulate in analog, block groups sum
digitally; a per-layer affine calibration maps block output voltages back to
logical units. The backward pass is the straight-through digital gradient
(hardware-aware training), via custom_vjp.

Serving fast path (docs/performance.md): the conductance plan for a weight
tag (tiling, padding, block interleave) is cached and reused across calls;
both voltage rails are evaluated in ONE blockified pass — the emulator
backend reconstructs them from a single magnitude-drive CELU against the
precomputed zero-voltage block response (``apply_blocklast``), other
backends stack the rails on the batch axis — and the per-block conductance
features are consumed directly (block-indexed Pallas operand on TPU)
instead of a batch-broadcast feature tensor.  The straight-through
``custom_vjp`` and per-tag ``jit`` are constructed once, so ``matmul``
compiles once per shape.

Non-idealities (docs/nonideal.md): ``set_scenario`` activates a
``repro.nonideal.Scenario`` (programming variation, read noise, stuck
cells, drift, quantized levels, line resistance; scalar or
(NB, NO)-per-tile).  Perturbations apply at the conductance-plan level;
on the serving fast path the perturbed conductances, read sigma, read
key, fault-remap permutation and emulator params are traced arguments of
a separate per-tag scenario forward, so switching scenarios never
invalidates the compile caches, and the ideal scenario is bit-identical
to the plain path.  ``calibrate`` is noise-aware (fits against the
active scenario).

Lifetime (docs/lifetime.md): ``fault_remap`` permutes output groups away
from stuck-off cells (inverse gather folded into the plan's assemble),
and ``set_emulator_params`` hot-swaps retrained emulator params -- both
ride the scenario forward's traced arguments, so an entire
drift-timeline walk (``repro.nonideal.lifetime``) compiles once per
(tag, shape).

Conditioning (docs/emulator.md): a *scenario-conditioned* emulator
(peripheral width > 2, ``nonideal.data.train_conditioned_emulator``)
consumes ``scenario_features(scenario)`` alongside the cell features, so
ONE net covers the whole corner manifold with zero per-corner
retraining.  The feature vector is a traced argument of the scenario
forward (corner/age changes never recompile), enters the blocklast fast
path as an fc0 bias shift that is exactly zero at the ideal corner, and
the plain path folds the ideal (all-zero) encoding into the cached
weights -- so an unconditioned and a conditioned net share every code
path and the ideal conditioned forward is bit-identical to the plain
one.

Install into a model with ``use_dense_hook(executor.hook)`` -- every
``dense()`` in repro.models routes through here.
"""
from __future__ import annotations

import functools
import zlib
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AnalogConfig
from repro.configs.rram_ps32 import BlockGeometry, CASE_A
from repro.core import conv4xbar
from repro.core.analytic import analytic_block_response
from repro.core.circuit import CircuitParams, block_response
from repro.core.crossbar import ConductancePlan, build_conductance_plan
from repro.core.emulator import normalize_features
from repro.nonideal.perturb import (apply_read_noise, perturb_plan,
                                    remap_plan, scenario_circuit_params)
from repro.nonideal.scenario import (N_SCENARIO_FEATURES, Scenario,
                                     scenario_features)


def _is_tracer(x) -> bool:
    return isinstance(x, jax.core.Tracer)


# --------------------------------------------------------------------------- #
# Straight-through analog matmul, hoisted to module level so the custom_vjp
# (and the per-tag jit wrapping it) is built once, not per forward call.
# --------------------------------------------------------------------------- #
@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _st_matmul(ex: "AnalogExecutor", tag: str, x2, w, a, b):
    yv, xs = ex.raw_matmul(x2, w, tag)
    return (a * yv + b) * xs


def _st_fwd(ex, tag, x2, w, a, b):
    return _st_matmul(ex, tag, x2, w, a, b), (x2, w)


def _st_bwd(ex, tag, res, ct):
    x2, w = res                        # straight-through digital grads
    return ct @ w.T, x2.T @ ct, jnp.zeros((), ct.dtype), jnp.zeros((), ct.dtype)


_st_matmul.defvjp(_st_fwd, _st_bwd)


# --------------------------------------------------------------------------- #
# Scenario-path straight-through matmul.  The device-state perturbed
# conductances (gf), read-noise sigma, read key, fault-remap output gather
# (operm) and emulator params (eparams; {} for non-emulator backends) enter
# as TRACED arguments, so sweeping scenario parameters, redrawing devices /
# read cycles, swapping remap permutations, or hot-swapping retrained
# emulator params all reuse one compiled executable per (tag, shape) -- the
# non-ideality twin of the calibration-affine-as-traced-scalars trick above.
# --------------------------------------------------------------------------- #
@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _st_matmul_sc(ex: "AnalogExecutor", tag: str, x2, w, a, b, gf, rsig, rkey,
                  operm, eparams, sfeat):
    plan = ex._plan_for(w, tag).with_g(gf, ex.acfg).with_perm(operm)
    yv, xs = ex.raw_matmul(x2, w, tag, plan=plan, read_key=rkey,
                           read_sigma=rsig,
                           eparams=eparams if eparams else None,
                           sfeat=sfeat)
    return (a * yv + b) * xs


def _st_sc_fwd(ex, tag, x2, w, a, b, gf, rsig, rkey, operm, eparams, sfeat):
    return (_st_matmul_sc(ex, tag, x2, w, a, b, gf, rsig, rkey, operm,
                          eparams, sfeat),
            (x2, w, gf, rsig, rkey, operm, eparams, sfeat))


def _st_sc_bwd(ex, tag, res, ct):
    x2, w, gf, rsig, rkey, operm, eparams, sfeat = res
    # straight-through digital grads; the device draw, permutation and
    # (frozen, serving-time) emulator params are not trained quantities
    z = jnp.zeros((), ct.dtype)
    return (ct @ w.T, x2.T @ ct, z, z, jnp.zeros_like(gf),
            jnp.zeros_like(rsig),
            np.zeros(rkey.shape, jax.dtypes.float0),
            np.zeros(operm.shape, jax.dtypes.float0),
            jax.tree.map(jnp.zeros_like, eparams),
            jnp.zeros_like(sfeat))


_st_matmul_sc.defvjp(_st_sc_fwd, _st_sc_bwd)


@dataclass(eq=False)
class AnalogExecutor:
    """Stateful serving executor for analog matmuls (see module docstring).

    Owns, per weight ``tag``: the cached conductance plan (``_plan_for``),
    the compiled plain forward (``_jit_for``), the compiled scenario
    forward (``_jit_sc_for``), the device-state perturbation cache
    (``_scenario_plan``) and the per-layer calibration affine.  Scenario
    state is set with ``set_scenario``; retrained emulator params are
    hot-swapped with ``set_emulator_params``; ``fault_remap`` turns on
    stuck-fault-aware column remapping for scenarios with stuck-off cells
    (docs/lifetime.md).
    """
    acfg: AnalogConfig
    geom: BlockGeometry = CASE_A
    cp: CircuitParams = field(default_factory=CircuitParams)
    emulator_params: Optional[dict] = None
    calibration: Dict[str, tuple] = field(default_factory=dict)
    fused_emulator: bool = True        # apply_fused vs apply on the slow path
    fast_path: bool = True             # cached-plan blockified serving path
    fast_chunk: int = 4                # batch rows per cache-sized chunk
    use_pallas: Optional[bool] = None  # None = auto (TPU only)
    scenario: Optional[Scenario] = None          # device non-ideality corner
    scenario_key: Optional[jax.Array] = None     # device-draw base key
    fault_remap: bool = False          # stuck-fault-aware column remapping

    def __post_init__(self):
        self._plans: Dict[str, Tuple[jax.Array, ConductancePlan]] = {}
        self._jit_fns: Dict[str, Tuple[jax.Array, Callable]] = {}
        self._g0_cache: Dict[str, Tuple[ConductancePlan, dict]] = {}
        self._aux = None
        self._aux_src = None
        # scenario state: perturbed-conductance cache + per-tag scenario
        # forwards (kept separate from _jit_fns so toggling a scenario on
        # and off never invalidates either compile cache)
        self._pert_cache: Dict[str, tuple] = {}
        self._sc_fns: Dict[str, tuple] = {}
        self._cal_fns: Dict[str, tuple] = {}
        self._read_calls = 0
        # scenario-feature cache (one encode per Scenario object) and the
        # zero vector fed to the scenario forward when conditioning is
        # inactive -- one stable (N_SCENARIO_FEATURES,) aval either way
        self._sfeat_ent: Optional[tuple] = None
        self._zero_sfeat = jnp.zeros((N_SCENARIO_FEATURES,), jnp.float32)
        if self.scenario_key is None:
            self.scenario_key = jax.random.PRNGKey(0)
        if self.scenario is None and self.acfg.scenario:
            from repro.nonideal import get_scenario
            self.scenario = get_scenario(self.acfg.scenario)

    # ------------------------------------------------------------------ #
    # Non-ideality scenario state (repro.nonideal)
    # ------------------------------------------------------------------ #
    def set_scenario(self, scenario: Optional[Scenario],
                     key: Optional[jax.Array] = None) -> "AnalogExecutor":
        """Activate (or clear, with None) a device non-ideality scenario.

        Clears the perturbed-conductance cache and resets the read-cycle
        counter, but does NOT touch any compiled forward: scenario
        parameters, fault draws, read keys and remap permutations are
        traced arguments of the scenario path, so switching scenarios
        reuses the executable.  Keeping ``key`` fixed across calls models
        the SAME fabricated fleet under different conditions (aging a
        fleet = same key, growing ``drift_t``); a new ``key`` fabricates a
        new fleet.  Per-tile scenario batches (``tile_scenarios``) and
        scalar scenarios are both accepted."""
        self.scenario = scenario
        if key is not None:
            self.scenario_key = key
        self._pert_cache.clear()
        self._sfeat_ent = None
        self._read_calls = 0
        return self

    @property
    def emulator_conditioned(self) -> bool:
        """True when the bound emulator params are scenario-conditioned
        (peripheral width > 2: fc0 has rows for ``scenario_features``).
        Static -- derived from param shapes -- so callers may branch on it
        at trace time (docs/emulator.md)."""
        return (self.emulator_params is not None
                and conv4xbar.n_periph_of(self.emulator_params,
                                          self.geom) > 2)

    def _scenario_features(self) -> jax.Array:
        """Feature encoding of the active scenario, cached per Scenario
        object (the encode is a handful of scalar reductions, but matmul
        is the serving hot path).  Forced eager: the executor's scenario
        leaves are concrete state, and under an ENCLOSING jit (serve loop)
        the encode must come out concrete so the cache never holds a
        leaked tracer."""
        sc = self.scenario
        ent = self._sfeat_ent
        if ent is not None and ent[0] is sc:
            return ent[1]
        with jax.ensure_compile_time_eval():
            v = scenario_features(sc)
        self._sfeat_ent = (sc, v)
        return v

    def set_emulator_params(self, params: dict) -> "AnalogExecutor":
        """Hot-swap trained emulator params (drift-scheduled retraining).

        The scenario forward takes the params as TRACED arguments, so the
        swap reuses its compiled executable -- recalibrate + retrain
        across a drift timeline compiles exactly once per (tag, shape).
        The plain (no-scenario) forward bakes params in as constants for
        speed, so it is dropped here and lazily rebuilt on next use."""
        self.emulator_params = params
        self._jit_fns.clear()
        return self

    def _tag_key(self, tag: str) -> jax.Array:
        """Per-tag device-draw key; crc32 keeps it stable across processes
        (hash() is salted per interpreter run)."""
        return jax.random.fold_in(self.scenario_key,
                                  zlib.crc32(tag.encode()) & 0x7FFFFFFF)

    def _next_read_key(self) -> jax.Array:
        """Fresh key per read cycle; the sequence restarts at set_scenario
        so a serve run with a fixed --seed is reproducible end to end."""
        k = jax.random.fold_in(
            jax.random.fold_in(self.scenario_key, 0x5245AD), self._read_calls)
        self._read_calls += 1
        return k

    def _scenario_plan(self, tag: str, w: jax.Array) -> ConductancePlan:
        """Device-state perturbed (and, with ``fault_remap``, stuck-fault
        remapped) plan, computed once per (tag, plan, scenario) and reused
        -- as a stable object, so downstream identity-keyed caches
        (_pre_for) hit across eager calls, and as the source of the traced
        conductance / permutation buffers for the compiled scenario
        forward.  ``out_perm`` is always set on the result (identity when
        remapping is off or the scenario has no stuck-off faults) so the
        scenario forward sees one stable argument signature."""
        plan = self._plan_for(w, tag)
        ent = self._pert_cache.get(tag)
        if ent is not None and ent[0] is plan and ent[1] is self.scenario \
                and ent[2] == self.fault_remap:
            return ent[3]
        with jax.ensure_compile_time_eval():
            key = self._tag_key(tag)
            base, operm = plan, jnp.arange(plan.N, dtype=jnp.int32)
            if self.fault_remap and self.scenario.has_stuck_off:
                base, operm = remap_plan(plan, self.acfg, self.scenario, key)
            pplan = perturb_plan(base, self.acfg, self.scenario,
                                 key).with_perm(operm)
        self._pert_cache[tag] = (plan, self.scenario, self.fault_remap, pplan)
        return pplan

    def _cp_effective(self) -> CircuitParams:
        """CircuitParams with the scenario's line-resistance scaling (static:
        only the circuit backend reads it, and changing it recompiles)."""
        if self.scenario is not None:
            return scenario_circuit_params(self.cp, self.scenario)
        return self.cp

    # ------------------------------------------------------------------ #
    # Conductance-plan cache
    # ------------------------------------------------------------------ #
    def _plan_for(self, w: jax.Array, tag: str) -> ConductancePlan:
        """Tile/pad/interleave once per bound weight; rebuilt only when the
        tag is rebound to a different array (or under tracing)."""
        if _is_tracer(w):
            return build_conductance_plan(w, self.acfg, self.geom)
        ent = self._plans.get(tag) if tag else None
        if ent is not None and ent[0] is w:
            return ent[1]
        # force eager evaluation even under an enclosing jit trace: the plan
        # must come out concrete so it is computed once and cached, not
        # re-tiled inside the compiled graph on every call
        with jax.ensure_compile_time_eval():
            plan = build_conductance_plan(w, self.acfg, self.geom)
        if tag:
            self._plans[tag] = (w, plan)
            self._g0_cache.pop(tag, None)
        return plan

    def _blocklast_aux(self, eparams: Optional[dict] = None) -> dict:
        """Stage-collapsed emulator weights (conv4xbar.blocklast_weights),
        cached per params binding.  ``eparams`` overrides the executor's
        own params (the scenario forward passes hot-swappable traced
        params through here)."""
        params = self.emulator_params if eparams is None else eparams
        assert params is not None, \
            "emulator backend needs trained params (core.emulator)"
        if any(_is_tracer(v) for v in params.values()):
            return conv4xbar.blocklast_weights(params, self.geom)
        if self._aux is None or self._aux_src is not params:
            with jax.ensure_compile_time_eval():
                self._aux = conv4xbar.blocklast_weights(params, self.geom)
            self._aux_src = params
            self._g0_cache.clear()
        return self._aux

    def _pre_for(self, plan: ConductancePlan, tag: str, aux: dict) -> dict:
        """Batch-independent fast-path tensors (zero-voltage block response
        and its stage-1 projection), cached per (tag, plan)."""
        if _is_tracer(plan.g_norm) or any(_is_tracer(v) for v in aux.values()
                                          if isinstance(v, jax.Array)):
            return conv4xbar.blocklast_precompute(aux, plan.g_norm)
        ent = self._g0_cache.get(tag) if tag else None
        if ent is not None and ent[0] is plan:
            return ent[1]
        with jax.ensure_compile_time_eval():
            pre = conv4xbar.blocklast_precompute(aux, plan.g_norm)
        if tag:
            self._g0_cache[tag] = (plan, pre)
        return pre

    # ------------------------------------------------------------------ #
    # Backends
    # ------------------------------------------------------------------ #
    def _backend_fn(self, eparams: Optional[dict] = None):
        """Block-response function of the configured backend; ``eparams``
        overrides the executor's emulator params (hot-swap path)."""
        b = self.acfg.backend
        cp = self._cp_effective()
        if b == "circuit":
            return lambda x, p: block_response(x, cp, p)
        if b == "analytic":
            return lambda x, p: analytic_block_response(x, cp, p)
        if b == "emulator":
            params = self.emulator_params if eparams is None else eparams
            assert params is not None, \
                "emulator backend needs trained params (core.emulator)"
            ap = (conv4xbar.apply_fused if self.fused_emulator
                  else conv4xbar.apply)
            return lambda x, p: ap(params,
                                   normalize_features(x, self.acfg), p)
        raise ValueError(b)

    def block_outputs(self, x: jax.Array,
                      eparams: Optional[dict] = None,
                      sfeat: Optional[jax.Array] = None) -> jax.Array:
        """x: (NBLK, 2, D, H, W) raw-feature block tensors -> (NBLK, O).

        For a scenario-conditioned emulator the peripheral vector is
        widened to ``(gain, offset, *scenario_features)``; ``sfeat=None``
        feeds the ideal corner's all-zero feature block."""
        n = x.shape[0]
        periph = jnp.concatenate(
            [jnp.ones((n, 1), x.dtype), jnp.zeros((n, 1), x.dtype)], axis=-1)
        if self.acfg.backend == "emulator":
            params = self.emulator_params if eparams is None else eparams
            npf = (conv4xbar.n_periph_of(params, self.geom)
                   if params is not None else 2)
            if npf > 2:
                tail = (jnp.zeros((npf - 2,), x.dtype) if sfeat is None
                        else sfeat.astype(x.dtype))
                periph = jnp.concatenate(
                    [periph, jnp.broadcast_to(tail[None], (n, npf - 2))],
                    axis=-1)
        return self._backend_fn(eparams)(x, periph)

    def _pallas_enabled(self) -> bool:
        if self.use_pallas is not None:
            return self.use_pallas
        return jax.default_backend() == "tpu"

    def _eval_blocks(self, plan: ConductancePlan, vb01: jax.Array,
                     eparams: Optional[dict] = None,
                     sfeat: Optional[jax.Array] = None) -> jax.Array:
        """vb01: (M, NB, D, H) wordline drive in [0, 1] -> (M*NB*NO, no)."""
        if self.acfg.backend == "emulator" and self.fast_path \
                and self._pallas_enabled():
            params = self.emulator_params if eparams is None else eparams
            # the grid kernel bakes the constant peripheral block (which is
            # the ideal all-zero scenario encoding for a conditioned net);
            # explicit non-ideal features fall through to the block-tensor
            # path, which threads them through the peripheral vector
            if sfeat is None or conv4xbar.n_periph_of(params,
                                                      self.geom) <= 2:
                from repro.kernels.emulator_block import emulator_block_grid
                M = vb01.shape[0]
                g = plan.g_norm.reshape((plan.n_blocks,)
                                        + plan.g_norm.shape[2:])
                y = emulator_block_grid(params, vb01, g, self.geom)
                return y.reshape(M * plan.n_blocks, -1)
        x = plan.build_x(vb01 * self.acfg.v_read)
        return self.block_outputs(x.astype(jnp.float32), eparams, sfeat)

    def _drive01(self, u01: jax.Array) -> jax.Array:
        """Gate-overdrive wordline biasing (AnalogConfig.wl_overdrive): map
        nonzero normalized drives into [v_th/v_read, 1] so they clear the
        access transistor's cut-off instead of sitting in its deadband.
        Zero stays exactly zero -- the dual-rail delta factorization and
        padded tiles depend on it."""
        if not self.acfg.wl_overdrive:
            return u01
        t = self.cp.v_th / self.acfg.v_read
        return jnp.where(u01 > 0.0, t + u01 * (1.0 - t), 0.0)

    # ------------------------------------------------------------------ #
    def raw_matmul(self, x2d: jax.Array, w: jax.Array, tag: str = "",
                   plan: Optional[ConductancePlan] = None,
                   read_key: Optional[jax.Array] = None,
                   read_sigma=None,
                   eparams: Optional[dict] = None,
                   sfeat: Optional[jax.Array] = None
                   ) -> Tuple[jax.Array, jax.Array]:
        """Analog forward for (B,K) @ (K,N): dual-rail inputs, tiled blocks,
        digital block-group accumulation. Output in volts (uncalibrated).

        Both rails run as ONE blockified batch against the cached
        conductance plan for `tag`: the emulator fast path evaluates them
        via the shared-magnitude delta factorization (apply_blocklast), all
        other backends stack the rails on the batch axis.

        `plan` overrides the cached conductance plan (repro.nonideal passes
        device-perturbed, possibly fault-remapped plans); with `plan=None`
        and an active scenario the device-state perturbation (and, with
        `fault_remap`, the remap) is applied here, inside the trace.
        `read_key`/`read_sigma` add one cycle-to-cycle read-noise draw on
        top of whatever plan is in effect (`read_sigma` may be per-tile).
        `eparams` overrides the executor's emulator params -- the scenario
        forward passes hot-swapped retrained params through here as traced
        arguments.  `sfeat` is the scenario-feature vector a conditioned
        emulator consumes (traced in the scenario forward); with
        `sfeat=None` and an active scenario it is derived here, so the
        in-trace path conditions too, and with no scenario the net sees
        the ideal (all-zero) corner encoding."""
        if plan is None:
            plan = self._plan_for(w, tag)
            sc = self.scenario
            if sc is not None and not sc.is_ideal:
                if tag and not _is_tracer(plan.g_feat):
                    plan = self._scenario_plan(tag, w)   # cached device draw
                else:
                    plan = perturb_plan(plan, self.acfg, sc,
                                        self._tag_key(tag))
                if read_key is None and sc.has_read_noise:
                    read_key, read_sigma = self._next_read_key(), sc.read_sigma
                if sfeat is None and self.acfg.backend == "emulator" \
                        and eparams is None and self.emulator_conditioned:
                    sfeat = self._scenario_features()
        if read_key is not None:
            rs = 0.0 if read_sigma is None else read_sigma
            plan = plan.with_g(
                apply_read_noise(plan.g_feat, self.acfg, rs, read_key),
                self.acfg)
        B = x2d.shape[0]
        x2d = x2d.astype(jnp.float32)
        x_scale = jnp.maximum(jnp.max(jnp.abs(x2d)), 1e-9)
        if self.acfg.backend == "emulator" and self.fast_path \
                and not self._pallas_enabled():
            aux = self._blocklast_aux(eparams)
            pre = self._pre_for(plan, tag, aux)
            shift = None
            if sfeat is not None and "f0_scen" in aux:
                # conditioned corner contribution: one (fc0_out,) bias
                # shift, exactly zero at the ideal (all-zero) encoding
                shift = sfeat @ aux["f0_scen"]
            u = plan.tile_v(self._drive01(jnp.abs(x2d) / x_scale), 1.0)
            pos = plan.tile_v((x2d > 0).astype(jnp.float32), 1.0)
            y2 = conv4xbar.apply_blocklast(aux, pre, u, pos,
                                           chunk=self.fast_chunk,
                                           fc0_shift=shift)
            return plan.assemble(y2[0]) - plan.assemble(y2[1]), x_scale
        rails = jnp.concatenate([jnp.clip(x2d, 0.0, None),
                                 jnp.clip(-x2d, 0.0, None)], axis=0)
        vb01 = plan.tile_v(self._drive01(rails / x_scale), 1.0)  # (2B,NB,D,H)
        outs = self._eval_blocks(plan, vb01.astype(jnp.float32), eparams,
                                 sfeat)
        y = plan.assemble(outs)                       # (2B, N)
        return y[:B] - y[B:], x_scale

    def calibrate(self, key, w: jax.Array, tag: str, n: int = 256,
                  noise_draws: int = 4):
        """Fit the per-layer affine volts->logical map against digital.

        Noise-aware: with an active scenario the fit runs against the same
        perturbed device the serving path sees, and the block response is
        averaged over `noise_draws` cycle-to-cycle read draws so the affine
        targets the expected (not one-shot) transfer."""
        xc = jax.random.normal(key, (n, w.shape[0])) * 0.5
        sc = self.scenario
        if sc is not None and not sc.is_ideal:
            draws = max(1, noise_draws) if sc.has_read_noise else 1
            keys = jax.random.split(
                jax.random.fold_in(self.scenario_key, 0xCA11B), draws)
            pplan = self._scenario_plan(tag, w)
            ep = (self.emulator_params
                  if self.acfg.backend == "emulator" else {})
            rsig = jnp.broadcast_to(
                jnp.asarray(sc.read_sigma, jnp.float32),
                (pplan.NB, pplan.NO))
            sf = (self._scenario_features() if self.acfg.backend == "emulator"
                  and self.emulator_conditioned else self._zero_sfeat)
            yvs, xss = self._jit_cal_for(tag, w)(
                xc, pplan.g_feat, rsig, keys, pplan.out_perm, ep, sf)
            yv, xs = yvs.mean(axis=0), xss[0]
        else:
            yv, xs = jax.jit(lambda xx: self.raw_matmul(xx, w, tag))(xc)
        yd = (xc @ w) / xs
        yv_flat = yv.reshape(-1)
        A = jnp.stack([yv_flat, jnp.ones_like(yv_flat)], axis=1)
        sol, *_ = jnp.linalg.lstsq(A, yd.reshape(-1))
        self.calibration[tag] = (float(sol[0]), float(sol[1]))
        return self.calibration[tag]

    def _jit_for(self, tag: str, w: jax.Array) -> Callable:
        """Per-(tag, weight-binding) jitted forward.  `w` is closed over as a
        concrete constant, so the cached conductance plan is computed at
        trace time (once) and baked into the executable."""
        ent = self._jit_fns.get(tag)
        if ent is not None and ent[0] is w:
            return ent[1]
        wf = w.astype(jnp.float32)
        fn = jax.jit(lambda x2, a, b: _st_matmul(self, tag, x2, wf, a, b))
        self._jit_fns[tag] = (w, fn)
        return fn

    def _jit_cal_for(self, tag: str, w: jax.Array) -> Callable:
        """Per-(tag, weight-binding) calibration forward: the noise-draw
        vmapped raw matmul against a scenario device, with conductances,
        read sigma / keys, remap permutation and emulator params as
        traced arguments.  Drift-timeline recalibration
        (``nonideal.lifetime``) therefore compiles the fit's forward
        exactly once per (tag, sample-count) instead of once per
        checkpoint."""
        ent = self._cal_fns.get(tag)
        rls = self.scenario.r_line_scale if self.scenario else 1.0
        if ent is not None and ent[0] is w and ent[1] == rls:
            return ent[2]
        wf = w.astype(jnp.float32)

        def one(xc, gf, rsig, kk, operm, ep, sf):
            plan = self._plan_for(wf, tag).with_g(gf, self.acfg) \
                .with_perm(operm)
            return self.raw_matmul(xc, wf, tag, plan=plan, read_key=kk,
                                   read_sigma=rsig,
                                   eparams=ep if ep else None, sfeat=sf)

        fn = jax.jit(lambda xc, gf, rsig, keys, operm, ep, sf: jax.vmap(
            lambda kk: one(xc, gf, rsig, kk, operm, ep, sf))(keys))
        self._cal_fns[tag] = (w, rls, fn)
        return fn

    def _jit_sc_for(self, tag: str, w: jax.Array) -> Callable:
        """Per-(tag, weight-binding) scenario forward.  Perturbed
        conductances, read sigma, read key, remap permutation and emulator
        params are traced arguments, so changing scenarios, read cycles,
        remappings, or hot-swapped retrained params reuses the executable;
        only a line-resistance change rebuilds it (CircuitParams is
        static).

        The read-noise draw and the output gather run even for read_sigma
        == 0 / identity permutations (exact identities there): a
        g_feat-sized threefry sample and an (N,)-gather are tens of
        microseconds against a millisecond-scale matmul, and keeping them
        unconditional preserves exactly ONE executable per tag."""
        ent = self._sc_fns.get(tag)
        rls = self.scenario.r_line_scale if self.scenario else 1.0
        if ent is not None and ent[0] is w and ent[1] == rls:
            return ent[2]
        wf = w.astype(jnp.float32)
        fn = jax.jit(lambda x2, a, b, gf, rsig, rkey, operm, ep, sf:
                     _st_matmul_sc(self, tag, x2, wf, a, b, gf, rsig, rkey,
                                   operm, ep, sf))
        self._sc_fns[tag] = (w, rls, fn)
        return fn

    def matmul(self, x: jax.Array, w: jax.Array, tag: str = "") -> jax.Array:
        """Calibrated analog matmul with straight-through digital gradient.

        Compiles once per (tag, shape): the custom_vjp is module-level and
        the calibration affine enters as traced scalars, so recalibration
        does not retrigger compilation.  An active non-ideality scenario
        dispatches to the scenario forward (same compile-once property,
        see _jit_sc_for); the ideal scenario is routed to the plain fast
        path and is bit-identical to it."""
        a, b = self.calibration.get(tag, (1.0, 0.0))
        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
        af = jnp.asarray(a, jnp.float32)
        bf = jnp.asarray(b, jnp.float32)
        sc = self.scenario
        if _is_tracer(x2) or _is_tracer(w) or not tag:
            y = _st_matmul(self, tag, x2, w.astype(jnp.float32), af, bf)
        elif sc is not None and not sc.is_ideal:
            pplan = self._scenario_plan(tag, w)
            ep = (self.emulator_params
                  if self.acfg.backend == "emulator" else {})
            # read sigma always enters tile-shaped so scalar and per-tile
            # scenarios share ONE compiled forward per tag; the scenario
            # features likewise always enter as one (N_SCENARIO_FEATURES,)
            # traced vector (zeros when conditioning is inactive)
            rsig = jnp.broadcast_to(
                jnp.asarray(sc.read_sigma, jnp.float32),
                (pplan.NB, pplan.NO))
            sf = (self._scenario_features()
                  if self.acfg.backend == "emulator"
                  and self.emulator_conditioned else self._zero_sfeat)
            y = self._jit_sc_for(tag, w)(
                x2, af, bf, pplan.g_feat, rsig,
                self._next_read_key(), pplan.out_perm, ep, sf)
        else:
            y = self._jit_for(tag, w)(x2, af, bf)
        return y.reshape(*lead, w.shape[1]).astype(x.dtype)

    # ------------------------------------------------------------------ #
    def hook(self, x: jax.Array, w: jax.Array, tag: str):
        """dense()-hook: route configured projections to the analog path."""
        if self.acfg.backend == "digital":
            return None
        if not any(tag.startswith(l) for l in self.acfg.layers):
            return None
        return self.matmul(x, w, tag)
