"""AnalogMatmul: execute dense projections on emulated crossbar hardware.

Backends (config ``analog.backend``):
  digital   -- plain matmul (technique off; baseline)
  analytic  -- expert analytical model (paper's strawman)
  circuit   -- Newton-Raphson circuit solver (exact, slow; SPICE stand-in)
  emulator  -- trained Conv4Xbar regression net (the paper's contribution)

Execution model (see core/crossbar.py): weights are tiled onto differential
1T1R crossbars; activations drive wordlines dual-rail (v+ = relu(x),
v- = relu(-x)); blocks of D tiles accumulate in analog, block groups sum
digitally; a per-layer affine calibration maps block output voltages back to
logical units. The backward pass is the straight-through digital gradient
(hardware-aware training), via custom_vjp.

Install into a model with ``use_dense_hook(executor.hook)`` -- every
``dense()`` in repro.models routes through here.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import AnalogConfig
from repro.configs.rram_ps32 import BlockGeometry, CASE_A
from repro.core import conv4xbar
from repro.core.analytic import analytic_block_response
from repro.core.circuit import CircuitParams, block_response
from repro.core.crossbar import (build_block_tensor, pad_rows, tile_inputs,
                                 tile_matrix)
from repro.core.emulator import normalize_features


def _blockify(v01: jax.Array, w: jax.Array, acfg: AnalogConfig,
              geom: BlockGeometry):
    """v01: (B, K) wordline drive in [0,1]; w: (K, N).
    Returns X (B*NB*NO, 2, D, H, W), shapes for reassembly, and w_scale.
    NB = block groups over K; NO = output groups over N."""
    B, K = v01.shape
    N = w.shape[1]
    gp, gn = tile_matrix(w, acfg)                     # (T, H, N)
    vt = tile_inputs(v01, acfg)                       # (B, T, H)
    T = gp.shape[0]
    D = geom.tiles
    padT = (-T) % D
    if padT:
        gp = jnp.pad(gp, ((0, padT), (0, 0), (0, 0)))
        gn = jnp.pad(gn, ((0, padT), (0, 0), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, padT), (0, 0)))
    NB = (T + padT) // D
    no = geom.outputs
    padN = (-N) % no
    if padN:
        gp = jnp.pad(gp, ((0, 0), (0, 0), (0, padN)))
        gn = jnp.pad(gn, ((0, 0), (0, 0), (0, padN)))
    NO = (N + padN) // no

    # (B, NB, D, H) voltages; (NB, D, H, NO, no) conductances
    vb = vt.reshape(B, NB, D, -1)
    gpb = gp.reshape(NB, D, gp.shape[1], NO, no)
    gnb = gn.reshape(NB, D, gn.shape[1], NO, no)
    # X: (B, NB, NO, 2, D, H, 2*no)
    g = jnp.stack([gpb, gnb], axis=-1).reshape(NB, D, gp.shape[1], NO, 2 * no)
    g = jnp.broadcast_to(g[None, :, :, :, :, :].transpose(0, 1, 4, 2, 3, 5),
                         (B, NB, NO, D, gp.shape[1], 2 * no))
    v = jnp.broadcast_to(vb[:, :, None, :, :, None],
                         (B, NB, NO, D, vb.shape[-1], 2 * no))
    x = jnp.stack([v, g], axis=3)                     # (B, NB, NO, 2, D, H, W)
    x = x.reshape(B * NB * NO, 2, D, vb.shape[-1], 2 * no)
    return x, (B, NB, NO, no, N)


def _assemble(outs: jax.Array, shapes) -> jax.Array:
    B, NB, NO, no, N = shapes
    y = outs.reshape(B, NB, NO * no)[:, :, :N]        # (B, NB, N)
    return y.sum(axis=1)                              # digital block-group sum


@dataclass
class AnalogExecutor:
    acfg: AnalogConfig
    geom: BlockGeometry = CASE_A
    cp: CircuitParams = field(default_factory=CircuitParams)
    emulator_params: Optional[dict] = None
    calibration: Dict[str, tuple] = field(default_factory=dict)
    fused_emulator: bool = True

    # ------------------------------------------------------------------ #
    def _backend_fn(self):
        b = self.acfg.backend
        if b == "circuit":
            return lambda x, p: block_response(x, self.cp, p)
        if b == "analytic":
            return lambda x, p: analytic_block_response(x, self.cp, p)
        if b == "emulator":
            assert self.emulator_params is not None, \
                "emulator backend needs trained params (core.emulator)"
            ap = (conv4xbar.apply_fused if self.fused_emulator
                  else conv4xbar.apply)
            return lambda x, p: ap(self.emulator_params,
                                   normalize_features(x, self.acfg), p)
        raise ValueError(b)

    def block_outputs(self, x: jax.Array) -> jax.Array:
        """x: (NBLK, 2, D, H, W) raw-feature block tensors -> (NBLK, O)."""
        periph = jnp.concatenate(
            [jnp.ones((x.shape[0], 1), x.dtype),
             jnp.zeros((x.shape[0], 1), x.dtype)], axis=-1)
        return self._backend_fn()(x, periph)

    def raw_matmul(self, x2d: jax.Array, w: jax.Array) -> jax.Array:
        """Analog forward for (B,K) @ (K,N): dual-rail inputs, tiled blocks,
        digital block-group accumulation. Output in volts (uncalibrated)."""
        xp = jnp.clip(x2d, 0.0, None)
        xn = jnp.clip(-x2d, 0.0, None)
        x_scale = jnp.maximum(jnp.max(jnp.abs(x2d)), 1e-9)
        out = None
        for rail, sign in ((xp, 1.0), (xn, -1.0)):
            xb, shapes = _blockify(rail / x_scale, w, self.acfg, self.geom)
            y = self.block_outputs(xb.astype(jnp.float32))
            y = _assemble(y, shapes) * sign
            out = y if out is None else out + y
        return out, x_scale

    def calibrate(self, key, w: jax.Array, tag: str, n: int = 256):
        """Fit the per-layer affine volts->logical map against digital."""
        xc = jax.random.normal(key, (n, w.shape[0])) * 0.5
        yv, xs = self.raw_matmul(xc, w)
        yd = (xc @ w) / xs
        yv_flat = yv.reshape(-1)
        A = jnp.stack([yv_flat, jnp.ones_like(yv_flat)], axis=1)
        sol, *_ = jnp.linalg.lstsq(A, yd.reshape(-1))
        self.calibration[tag] = (float(sol[0]), float(sol[1]))
        return self.calibration[tag]

    def matmul(self, x: jax.Array, w: jax.Array, tag: str = "") -> jax.Array:
        """Calibrated analog matmul with straight-through digital gradient."""
        a, b = self.calibration.get(tag, (1.0, 0.0))
        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
        w = w.astype(jnp.float32)

        @jax.custom_vjp
        def f(x2, w):
            yv, xs = self.raw_matmul(x2, w)
            return (a * yv + b) * xs

        def fwd(x2, w):
            return f(x2, w), (x2, w)

        def bwd(res, ct):
            x2, w = res
            return ct @ w.T, x2.T @ ct     # straight-through digital grads

        f.defvjp(fwd, bwd)
        y = f(x2, w)
        return y.reshape(*lead, w.shape[1]).astype(x.dtype)

    # ------------------------------------------------------------------ #
    def hook(self, x: jax.Array, w: jax.Array, tag: str):
        """dense()-hook: route configured projections to the analog path."""
        if self.acfg.backend == "digital":
            return None
        if not any(tag.startswith(l) for l in self.acfg.layers):
            return None
        return self.matmul(x, w, tag)
