"""AnalogMatmul: execute dense projections on emulated crossbar hardware.

Backends (config ``analog.backend``):
  digital   -- plain matmul (technique off; baseline)
  analytic  -- expert analytical model (paper's strawman)
  circuit   -- Newton-Raphson circuit solver (exact, slow; SPICE stand-in)
  emulator  -- trained Conv4Xbar regression net (the paper's contribution)

Execution model (see core/crossbar.py): weights are tiled onto differential
1T1R crossbars; activations drive wordlines dual-rail (v+ = relu(x),
v- = relu(-x)); blocks of D tiles accumulate in analog, block groups sum
digitally; a per-layer affine calibration maps block output voltages back to
logical units. The backward pass is the straight-through digital gradient
(hardware-aware training), via custom_vjp.

Serving fast path (docs/performance.md): the conductance plan for a weight
tag (tiling, padding, block interleave) is cached and reused across calls;
both voltage rails are evaluated in ONE blockified pass -- the emulator
backend reconstructs them from a single magnitude-drive CELU against the
precomputed zero-voltage block response, other backends stack the rails on
the batch axis.  The emulator evaluation goes through ONE dispatcher
(``kernels.emulator_block.emulator_block_unified``): a single fused Pallas
kernel on TPU (both rails, both GEMM stages, scenario epilogue -- one
compiled launch for every device corner) or the identical chunked XLA
schedule (``apply_blocklast``) elsewhere, with block sizes resolved by
``kernels.autotune``.

Deployment model (docs/api.md): everything that distinguishes a deployed
device from the ideal hardware -- perturbed conductances, read sigma and
key, the fault-remap output permutation, hot-swappable emulator params,
the scenario feature encoding a conditioned net consumes, and the
volts->logical calibration affine -- is bundled into ONE registered
pytree, ``core.deployment.DeploymentState``, threaded as ONE traced
argument through ONE jit cache per weight tag (``_unified_for``).
Swapping corners, ages, remap permutations, read cycles, calibrations or
retrained params therefore reuses a single compiled executable per
(tag, shape), and ``DeploymentState.ideal()`` reproduces the plain path
bit-identically (every non-ideal leaf sits at its exact-identity value).

Deployments are built with the immutable, fluent builder
``AnalogExecutor.deploy(scenario=..., age=..., remap=..., params=...,
key=...)`` -- the former mutable setter family (``set_scenario``,
``set_emulator_params``, assigning ``fault_remap``) survives as thin
deprecation shims for one release.  Non-ideality semantics
(docs/nonideal.md), fault-aware remapping and lifetime scheduling
(docs/lifetime.md) and the scenario-conditioned emulator
(docs/emulator.md) are unchanged; they now ride the unified forward.

Install into a model with ``use_dense_hook(executor.hook)`` -- every
``dense()`` in repro.models routes through here.  A ``ServeSession``
(``repro.launch.serve``) threads per-call-site ``DeploymentState``s
through its compiled serving steps, so task-level sweeps (accuracy vs
sigma / age on actual token prediction) swap device state with zero
recompiles.
"""
from __future__ import annotations

import contextlib
import functools
import time
import warnings
import zlib
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AnalogConfig
from repro.configs.rram_ps32 import BlockGeometry, CASE_A
from repro.core import conv4xbar
from repro.core.analytic import analytic_block_response
from repro.core.circuit import CircuitParams, block_response
from repro.core.crossbar import ConductancePlan, build_conductance_plan
from repro.core.deployment import Deployment, DeploymentState
from repro.core.emulator import normalize_features
from repro.nonideal.perturb import (apply_read_noise, perturb_plan,
                                    remap_plan, scenario_circuit_params)
from repro.nonideal.scenario import (N_SCENARIO_FEATURES, Scenario,
                                     scenario_features,
                                     scenario_features_tiled)
from repro.obs import OBS
from repro.parallel.sharding import (DATA_AXIS, MODEL_AXIS, lattice_scheme,
                                     local_lattice, mesh_shape,
                                     shard_deployment_state, state_pspecs)

_UNSET = object()


def _is_tracer(x) -> bool:
    return isinstance(x, jax.core.Tracer)


# --------------------------------------------------------------------------- #
# THE unified straight-through analog matmul.  One traced DeploymentState
# carries every deployed-device quantity (conductances, read sigma/key,
# remap permutation, emulator params, scenario features, calibration
# affine), so one executable per (tag, shape) serves the entire corner x
# age x remap x params manifold.  Hoisted to module level so the
# custom_vjp (and the per-tag jit wrapping it) is built once.
# --------------------------------------------------------------------------- #
@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _st_matmul_u(ex: "AnalogExecutor", tag: str, x2, w, st: DeploymentState):
    plan = ex._plan_for(w, tag).with_g(st.gf, ex.acfg).with_perm(st.out_perm)
    yv, xs = ex.raw_matmul(x2, w, tag, plan=plan, read_key=st.read_key,
                           read_sigma=st.read_sigma,
                           eparams=st.eparams if st.eparams else None,
                           sfeat=st.sfeat)
    return (st.cal_a * yv + st.cal_b) * xs


def _st_u_fwd(ex, tag, x2, w, st):
    return _st_matmul_u(ex, tag, x2, w, st), (x2, w, st)


def _zero_tangent(v):
    """Symbolic-zero cotangent for a state leaf (float0 for int leaves:
    the read key and the remap permutation are not differentiable)."""
    if jnp.issubdtype(jnp.result_type(v), jnp.floating):
        return jnp.zeros_like(v)
    return np.zeros(jnp.shape(v), jax.dtypes.float0)


def _st_u_bwd(ex, tag, res, ct):
    x2, w, st = res                    # straight-through digital grads;
    # nothing in the deployment state is a trained quantity (cotangent
    # dtypes must match the primals: w may be served in bf16)
    return ((ct @ w.T).astype(x2.dtype), (x2.T @ ct).astype(w.dtype),
            jax.tree.map(_zero_tangent, st))


_st_matmul_u.defvjp(_st_u_fwd, _st_u_bwd)


class _StateBinding:
    """Per-forward-pass resolution of dense() call sites to
    ``DeploymentState``s.

    Model tags repeat across layers (every block calls ``dense(...,
    "mlp.up")``), so a *site key* disambiguates by trace-order ordinal:
    the i-th call with tag T gets ``"T#i"``.  Trace order is
    deterministic, so site keys are stable across prefill / decode /
    processes.  In record mode the binding collects ``site_key ->
    weight`` (under ``jax.eval_shape``: zero FLOPs) for a ``ServeSession``
    to materialize states against; in serve mode it routes each site
    through the unified forward with that site's (typically traced)
    state.

    Scanned models (``lax.scan`` over layer periods) thread their states
    as scan xs: the binding doubles as the model's scan-states provider
    (``models.common.use_scan_states``).  Sites inside scan group ``g``,
    period ``p`` are keyed ``"{g}.{p}:{tag}#{j}"`` with the ordinal ``j``
    counted within the period (``scan_record``); at serve time
    ``scan_xs`` stacks the per-period states onto a leading layer axis so
    the scan body receives each period's states as TRACED xs slices
    (``scan_slice``), and ``intercept`` resolves sites from the slice --
    the traced weight slice takes the executor's eager in-trace path, so
    the whole scan stays inside ONE compiled serving step."""

    def __init__(self, states: Optional[Dict[str, DeploymentState]] = None,
                 record: Optional[Dict[str, jax.Array]] = None):
        self.states = states
        self.record = record
        self._ordinals: Dict[str, int] = {}
        self._prefix = ""
        self._slice: Optional[Dict[str, DeploymentState]] = None

    @property
    def recording(self) -> bool:
        return self.record is not None

    def site_key(self, tag: str) -> str:
        i = self._ordinals.get(tag, 0)
        self._ordinals[tag] = i + 1
        return f"{self._prefix}{tag}#{i}"

    @contextlib.contextmanager
    def _scoped(self, prefix: str, slice_states):
        """Fresh within-period ordinals + key prefix / slice lookup for
        the duration (scan bodies re-enter per period / per trace, so the
        reset also makes remat's double-trace idempotent)."""
        saved = (self._ordinals, self._prefix, self._slice)
        self._ordinals, self._prefix, self._slice = {}, prefix, slice_states
        try:
            yield
        finally:
            self._ordinals, self._prefix, self._slice = saved

    def scan_record(self, group: str, period: int):
        """Record mode: key the sites of one Python-unrolled period."""
        return self._scoped(f"{group}.{period}:", None)

    def scan_slice(self, group: str, ls):
        """Serve mode: resolve the scan body's sites from the traced
        per-period state slice ``ls`` (keyed by within-period site key)."""
        return self._scoped(f"{group}.?:", ls)

    def scan_xs(self, group: str, n: int):
        """Stack the bound states of scan group ``group`` onto a leading
        layer axis: ``{inner_site_key: DeploymentState}`` with every leaf
        ``(n, ...)`` -- ready to ride ``lax.scan`` as xs.  Returns None
        when the group has no bound states (digital scan layers)."""
        if self.states is None:
            return None
        pre = f"{group}."
        per: list = [dict() for _ in range(n)]
        for sk, st in self.states.items():
            if not sk.startswith(pre) or ":" not in sk:
                continue
            p_str, inner = sk[len(pre):].split(":", 1)
            per[int(p_str)][inner] = st
        if not per[0]:
            return None
        keys = sorted(per[0])
        if any(sorted(d) != keys for d in per):
            raise KeyError(
                f"scan group {group!r}: per-period site keys differ "
                f"across the {n} periods (bound: {sorted(self.states)}); "
                "a saved deployment must be served with the model / "
                "layer configuration it was saved from")
        return {k: jax.tree.map(lambda *ls: jnp.stack(ls),
                                *[d[k] for d in per]) for k in keys}

    def intercept(self, ex: "AnalogExecutor", x, w, tag: str):
        sk = self.site_key(tag)
        if self.record is not None:
            self.record[sk] = w
            return None                # digital fallback while recording
        if self._slice is not None:
            # inside a scan body: the key's period field is positional
            # (the xs slice IS period p); look up by within-period key
            st = self._slice.get(sk.split(":", 1)[1])
        else:
            st = self.states.get(sk) if self.states is not None else None
        if st is None:
            # a silent digital fallback here would break the round-trip
            # contract without a trace -- fail loudly instead
            bound = sorted(self._slice) if self._slice is not None \
                else sorted(self.states or ())
            raise KeyError(
                f"no DeploymentState bound for call site {sk!r} (bound: "
                f"{bound}); a saved deployment must be served with the "
                "model / layer configuration it was saved from")
        return ex.matmul(x, w, sk, state=st)


class AnalogExecutor:
    """Stateful serving executor for analog matmuls (see module docstring).

    Owns, per weight ``tag``: the cached conductance plan (``_plan_for``),
    ONE compiled unified forward (``_unified_for``) taking a single traced
    ``DeploymentState``, and the materialized-device-state cache
    (``_state_cache``).  The active ``Deployment`` (an immutable spec:
    scenario, fleet key, remap policy, hot-swapped params) is built with
    the fluent ``deploy(...)`` builder; per-tag states derive from it
    lazily via ``state_for``.  The legacy mutable setters delegate to
    ``deploy`` and emit ``DeprecationWarning``.
    """

    def __init__(self, acfg: AnalogConfig, geom: BlockGeometry = CASE_A,
                 cp: Optional[CircuitParams] = None,
                 emulator_params: Optional[dict] = None,
                 calibration: Optional[Dict[str, tuple]] = None,
                 fused_emulator: bool = True, fast_path: bool = True,
                 fast_chunk: Optional[int] = None,
                 use_pallas: Optional[bool] = None,
                 scenario: Optional[Scenario] = None,
                 scenario_key: Optional[jax.Array] = None,
                 fault_remap: bool = False,
                 mesh=None, shard_scheme: str = "auto"):
        self.acfg = acfg
        self.geom = geom
        self.cp = cp if cp is not None else CircuitParams()
        self._base_params = emulator_params
        self.calibration: Dict[str, tuple] = (
            calibration if calibration is not None else {})
        self.fused_emulator = fused_emulator  # apply_fused vs apply (slow path)
        self.fast_path = fast_path            # cached-plan blockified path
        self.fast_chunk = fast_chunk          # None = autotuned/heuristic
        self.use_pallas = use_pallas          # None = auto (TPU only)
        # tensor-parallel serving (repro.parallel.sharding; docs/parallel.md):
        # a (data, model) mesh shards batch rows and the tile lattice; the
        # scheme ('auto' -> lattice_scheme, or forced 'row'/'col'/'none')
        # picks which lattice axis the model axis partitions
        self.mesh = mesh
        self.shard_scheme = shard_scheme

        self._plans: Dict[str, Tuple[jax.Array, ConductancePlan]] = {}
        # ONE jit-cache family: tag -> (w, r_line_scale, fn(x2, state))
        self._fns: Dict[str, Tuple[jax.Array, float, Callable]] = {}
        self._g0_cache: Dict[str, Tuple[ConductancePlan, dict]] = {}
        self._aux = None
        self._aux_src = None
        # tag -> (plan, deployment, base_state, perturbed_plan)
        self._state_cache: Dict[str, tuple] = {}
        self._binding: Optional[_StateBinding] = None
        self._read_calls = 0
        self._last_calib_n = 0
        # scenario-feature cache (one encode per Scenario object) and the
        # zero vector the ideal state carries -- one stable
        # (N_SCENARIO_FEATURES,) aval either way
        self._sfeat_ent: Optional[tuple] = None
        self._zero_sfeat = jnp.zeros((N_SCENARIO_FEATURES,), jnp.float32)

        if scenario is None and self.acfg.scenario:
            from repro.nonideal import get_scenario
            scenario = get_scenario(self.acfg.scenario)
        self._deployment = Deployment(
            scenario=scenario,
            key=(scenario_key if scenario_key is not None
                 else jax.random.PRNGKey(0)),
            remap=fault_remap)

    # ------------------------------------------------------------------ #
    # The immutable deployment (repro.core.deployment)
    # ------------------------------------------------------------------ #
    @property
    def deployment(self) -> Deployment:
        """The active immutable deployment spec."""
        return self._deployment

    @property
    def scenario(self) -> Optional[Scenario]:
        """The active deployment's device corner (None = ideal)."""
        return self._deployment.scenario

    @property
    def scenario_key(self) -> jax.Array:
        """The active deployment's fleet fabrication key."""
        return self._deployment.key

    @property
    def fault_remap(self) -> bool:
        """Stuck-fault-aware remapping policy of the active deployment."""
        return self._deployment.remap

    @fault_remap.setter
    def fault_remap(self, value: bool):
        warnings.warn(
            "assigning AnalogExecutor.fault_remap is deprecated; use "
            "AnalogExecutor.deploy(remap=...)", DeprecationWarning,
            stacklevel=2)
        self.deploy(remap=bool(value))

    @property
    def emulator_params(self) -> Optional[dict]:
        """The serving emulator params: the deployment's hot-swapped
        override when set, else the params bound at construction."""
        return (self._deployment.params if self._deployment.params is not None
                else self._base_params)

    def deploy(self, *, scenario=_UNSET, age: Optional[float] = None,
               remap=_UNSET, params=_UNSET, key: Optional[jax.Array] = None,
               states=_UNSET) -> Deployment:
        """Activate (and return) a new immutable deployment.

        Fluent partial update: only the given fields change, everything
        else carries over from the active deployment.  ``scenario=None``
        clears the corner (ideal hardware); ``age`` rewrites the
        scenario's ``drift_t`` (seconds since programming; the fleet ages,
        it is not refabricated); ``remap`` sets the stuck-fault-aware
        remapping policy (``True`` = instantaneous; a sequence of
        checkpoint ages in seconds = wear-aware horizon scoring,
        ``nonideal.remap_plan``); ``params`` hot-swaps retrained emulator
        params;
        ``key`` refabricates the fleet (a fixed key across deploys models
        the SAME devices under different conditions); ``states`` installs
        preloaded per-tag states (``core.deployment.load_deployment``).

        Invalidates only the materialized device-state cache and the
        read-cycle counter.  Nothing compiled is touched: every leaf of a
        ``DeploymentState`` is a traced argument of the unified forward,
        so a corner -> age -> remap -> params swap sequence reuses one
        executable per (tag, shape).
        """
        dep = self._deployment
        sc = dep.scenario if scenario is _UNSET else scenario
        if age is not None:
            if sc is None:
                raise ValueError("deploy(age=...) needs a scenario to age")
            from repro.nonideal.lifetime import scenario_at_age
            sc = scenario_at_age(sc, age)
        if remap is not _UNSET and isinstance(remap, (tuple, list)):
            # wear-aware remapping: a horizon of checkpoint ages (seconds)
            remap = tuple(float(t) for t in remap)
        new = Deployment(
            scenario=sc,
            key=dep.key if key is None else key,
            remap=(dep.remap if remap is _UNSET
                   else remap if isinstance(remap, tuple) else bool(remap)),
            params=dep.params if params is _UNSET else params,
            states=dep.states if states is _UNSET else states)
        self._deployment = new
        self._state_cache.clear()
        self._sfeat_ent = None
        self._read_calls = 0
        return new

    # ------------------------------------------------------------------ #
    # Deprecated mutable-setter shims (one release; docs/api.md)
    # ------------------------------------------------------------------ #
    def set_scenario(self, scenario: Optional[Scenario],
                     key: Optional[jax.Array] = None) -> "AnalogExecutor":
        """Deprecated: use ``deploy(scenario=..., key=...)``."""
        warnings.warn(
            "AnalogExecutor.set_scenario is deprecated; use "
            "AnalogExecutor.deploy(scenario=..., key=...)",
            DeprecationWarning, stacklevel=2)
        self.deploy(scenario=scenario, key=key)
        return self

    def set_emulator_params(self, params: dict) -> "AnalogExecutor":
        """Deprecated: use ``deploy(params=...)``."""
        warnings.warn(
            "AnalogExecutor.set_emulator_params is deprecated; use "
            "AnalogExecutor.deploy(params=...)",
            DeprecationWarning, stacklevel=2)
        self.deploy(params=params)
        return self

    # ------------------------------------------------------------------ #
    # Device-state materialization
    # ------------------------------------------------------------------ #
    @property
    def emulator_conditioned(self) -> bool:
        """True when the bound emulator params are scenario-conditioned
        (peripheral width > 2: fc0 has rows for ``scenario_features``).
        Static -- derived from param shapes -- so callers may branch on it
        at trace time (docs/emulator.md)."""
        return (self.emulator_params is not None
                and conv4xbar.n_periph_of(self.emulator_params,
                                          self.geom) > 2)

    def _scenario_features(self) -> jax.Array:
        """Feature encoding of the active scenario, cached per Scenario
        object (the encode is a handful of scalar reductions, but matmul
        is the serving hot path).  A tile-indexed scenario encodes as the
        per-tile ``(NB, NO, F)`` feature lattice
        (``scenario_features_tiled``), so a conditioned net sees each
        tile's own corner rather than fleet mean/max summaries; scalar
        corners keep the flat ``(F,)`` vector (one extra executable per
        tag when a deployment switches between the two shapes).  Forced
        eager: the deployment's scenario leaves are concrete state, and
        under an ENCLOSING jit (serve loop) the encode must come out
        concrete so the cache never holds a leaked tracer."""
        sc = self.scenario
        ent = self._sfeat_ent
        if ent is not None and ent[0] is sc:
            return ent[1]
        with jax.ensure_compile_time_eval():
            v = (scenario_features_tiled(sc)
                 if sc.tile_shape is not None else scenario_features(sc))
        self._sfeat_ent = (sc, v)
        return v

    def _tag_key(self, tag: str) -> jax.Array:
        """Per-tag device-draw key; crc32 keeps it stable across processes
        (hash() is salted per interpreter run)."""
        return jax.random.fold_in(self.scenario_key,
                                  zlib.crc32(tag.encode()) & 0x7FFFFFFF)

    def _next_read_key(self) -> jax.Array:
        """Fresh key per read cycle; the sequence restarts at deploy()
        so a serve run with a fixed --seed is reproducible end to end."""
        k = jax.random.fold_in(
            jax.random.fold_in(self.scenario_key, 0x5245AD), self._read_calls)
        self._read_calls += 1
        return k

    def _base_state(self, tag: str, w: jax.Array) -> DeploymentState:
        """The deployment's device state for ``(tag, w)``: the scenario's
        perturbation (and, under ``remap``, the stuck-fault-aware
        permutation) materialized once per (tag, plan, deployment) and
        cached -- with unit affine and a placeholder read key
        (``state_for`` stamps the serving-time ones)."""
        dep = self._deployment
        plan = self._plan_for(w, tag)
        ent = self._state_cache.get(tag) if tag else None
        if ent is not None and ent[0] is plan and ent[1] is dep:
            if OBS.enabled:
                OBS.counter("analog_state_cache_total",
                            "materialized device-state cache lookups",
                            tag=tag, event="hit").inc()
            return ent[2]
        if OBS.enabled:
            OBS.counter("analog_state_cache_total",
                        "materialized device-state cache lookups",
                        tag=tag or "<anon>", event="miss").inc()
        sc = dep.scenario
        with jax.ensure_compile_time_eval():
            ep = (self.emulator_params
                  if self.acfg.backend == "emulator"
                  and self.emulator_params is not None else {})
            if sc is None or sc.is_ideal:
                pplan = plan.with_perm(jnp.arange(plan.N, dtype=jnp.int32))
                rsig = jnp.zeros((plan.NB, plan.NO), jnp.float32)
                sfeat = self._zero_sfeat
            else:
                key = self._tag_key(tag)
                base, operm = plan, jnp.arange(plan.N, dtype=jnp.int32)
                if dep.remap and sc.has_stuck_off:
                    # a tuple remap policy is a wear-aware horizon of
                    # checkpoint ages; True = instantaneous remapping
                    hz = dep.remap if isinstance(dep.remap, tuple) else None
                    base, operm = remap_plan(plan, self.acfg, sc, key,
                                             horizon=hz)
                pplan = perturb_plan(base, self.acfg, sc,
                                     key).with_perm(operm)
                # read sigma always enters tile-shaped so scalar and
                # per-tile scenarios share ONE compiled forward per tag
                rsig = jnp.broadcast_to(
                    jnp.asarray(sc.read_sigma, jnp.float32),
                    (plan.NB, plan.NO))
                sfeat = (self._scenario_features()
                         if self.acfg.backend == "emulator"
                         and self.emulator_conditioned else self._zero_sfeat)
            st = DeploymentState(
                # f32 regardless of the weights' dtype: one stable aval
                # for the ideal AND every perturbed corner
                gf=pplan.g_feat.astype(jnp.float32), read_sigma=rsig,
                read_key=jax.random.PRNGKey(0), out_perm=pplan.out_perm,
                eparams=ep, sfeat=sfeat,
                cal_a=jnp.asarray(1.0, jnp.float32),
                cal_b=jnp.asarray(0.0, jnp.float32))
        if tag:
            self._state_cache[tag] = (plan, dep, st, pplan)
        return st

    def state_for(self, tag: str, w: jax.Array) -> DeploymentState:
        """The ready-to-serve ``DeploymentState`` for ``(tag, w)``: the
        cached device state stamped with the current calibration affine
        and, when the corner draws read noise, a fresh read-cycle key.
        Preloaded states (``deploy(states=...)``) are served verbatim --
        they carry their saved affine and read key."""
        dep = self._deployment
        if dep.states is not None and tag in dep.states:
            # preloaded states still get mesh placement: this is the
            # re-shard-on-load path for deployments saved under a
            # different (or no) mesh shape (docs/parallel.md)
            return self.shard_state(dep.states[tag])
        st = self._base_state(tag, w)
        a, b = self.calibration.get(tag, (1.0, 0.0))
        st = st.with_calibration(a, b)
        sc = dep.scenario
        if sc is not None and sc.has_read_noise:
            st = st.with_read_key(self._next_read_key())
        return self.shard_state(st)

    def _inline_state(self, tag: str, w: jax.Array, a, b) -> DeploymentState:
        """State for the in-trace path (enclosing jit / grad / anonymous
        tag).  With a bound weight the cached state is reused (its
        concrete leaves bake into the enclosing executable, exactly as
        the pre-unification trace-time path did); under traced weights
        (hardware-aware training) the state derives in-trace."""
        dep = self._deployment
        if dep.states is not None and tag in dep.states:
            return dep.states[tag]
        if tag and not _is_tracer(w):
            return self.state_for(tag, w)
        plan = self._plan_for(w, tag)
        ep = (self.emulator_params
              if self.acfg.backend == "emulator"
              and self.emulator_params is not None else {})
        st = DeploymentState.ideal(plan, eparams=ep, calibration=(a, b))
        sc = dep.scenario
        if sc is not None and not sc.is_ideal:
            pplan = perturb_plan(plan, self.acfg, sc, self._tag_key(tag))
            kw = dict(gf=pplan.g_feat,
                      read_sigma=jnp.broadcast_to(
                          jnp.asarray(sc.read_sigma, jnp.float32),
                          (plan.NB, plan.NO)))
            if sc.has_read_noise:
                kw["read_key"] = self._next_read_key()
            if self.acfg.backend == "emulator" and self.emulator_conditioned:
                kw["sfeat"] = self._scenario_features()
            st = st.replace(**kw)
        return st

    def _scenario_plan(self, tag: str, w: jax.Array) -> ConductancePlan:
        """Device-state perturbed (and, with ``remap``, stuck-fault
        remapped) conductance plan -- the plan-shaped view of
        ``_base_state``, stable per (tag, plan, deployment) so
        identity-keyed caches (``_pre_for``) hit across eager calls."""
        self._base_state(tag, w)
        return self._state_cache[tag][3]

    def _cp_effective(self) -> CircuitParams:
        """CircuitParams with the scenario's line-resistance scaling (static:
        only the circuit backend reads it, and changing it recompiles)."""
        if self.scenario is not None:
            return scenario_circuit_params(self.cp, self.scenario)
        return self.cp

    # ------------------------------------------------------------------ #
    # Conductance-plan cache
    # ------------------------------------------------------------------ #
    def _plan_for(self, w: jax.Array, tag: str) -> ConductancePlan:
        """Tile/pad/interleave once per bound weight; rebuilt only when the
        tag is rebound to a different array (or under tracing)."""
        if _is_tracer(w):
            return build_conductance_plan(w, self.acfg, self.geom)
        ent = self._plans.get(tag) if tag else None
        if ent is not None and ent[0] is w:
            if OBS.enabled:
                OBS.counter("analog_plan_cache_total",
                            "conductance-plan cache lookups per weight tag",
                            tag=tag, event="hit").inc()
            return ent[1]
        if OBS.enabled:
            OBS.counter("analog_plan_cache_total",
                        "conductance-plan cache lookups per weight tag",
                        tag=tag or "<anon>", event="miss").inc()
        # force eager evaluation even under an enclosing jit trace: the plan
        # must come out concrete so it is computed once and cached, not
        # re-tiled inside the compiled graph on every call
        with jax.ensure_compile_time_eval():
            plan = build_conductance_plan(w, self.acfg, self.geom)
        if tag:
            self._plans[tag] = (w, plan)
            self._g0_cache.pop(tag, None)
        return plan

    def _blocklast_aux(self, eparams: Optional[dict] = None) -> dict:
        """Stage-collapsed emulator weights (conv4xbar.blocklast_weights),
        cached per params binding.  ``eparams`` overrides the executor's
        own params (the unified forward passes the deployment state's
        traced params through here)."""
        params = self.emulator_params if eparams is None else eparams
        assert params is not None, \
            "emulator backend needs trained params (core.emulator)"
        if any(_is_tracer(v) for v in params.values()):
            return conv4xbar.blocklast_weights(params, self.geom)
        if self._aux is None or self._aux_src is not params:
            with jax.ensure_compile_time_eval():
                self._aux = conv4xbar.blocklast_weights(params, self.geom)
            self._aux_src = params
            self._g0_cache.clear()
        return self._aux

    def _pre_for(self, plan: ConductancePlan, tag: str, aux: dict) -> dict:
        """Batch-independent fast-path tensors (zero-voltage block response
        and its stage-1 projection), cached per (tag, plan)."""
        if _is_tracer(plan.g_norm) or any(_is_tracer(v) for v in aux.values()
                                          if isinstance(v, jax.Array)):
            return conv4xbar.blocklast_precompute(aux, plan.g_norm)
        ent = self._g0_cache.get(tag) if tag else None
        if ent is not None and ent[0] is plan:
            return ent[1]
        with jax.ensure_compile_time_eval():
            pre = conv4xbar.blocklast_precompute(aux, plan.g_norm)
        if tag:
            self._g0_cache[tag] = (plan, pre)
        return pre

    # ------------------------------------------------------------------ #
    # Backends
    # ------------------------------------------------------------------ #
    def _backend_fn(self, eparams: Optional[dict] = None):
        """Block-response function of the configured backend; ``eparams``
        overrides the executor's emulator params (hot-swap path)."""
        b = self.acfg.backend
        cp = self._cp_effective()
        if b == "circuit":
            return lambda x, p: block_response(x, cp, p)
        if b == "analytic":
            return lambda x, p: analytic_block_response(x, cp, p)
        if b == "emulator":
            params = self.emulator_params if eparams is None else eparams
            assert params is not None, \
                "emulator backend needs trained params (core.emulator)"
            ap = (conv4xbar.apply_fused if self.fused_emulator
                  else conv4xbar.apply)
            return lambda x, p: ap(params,
                                   normalize_features(x, self.acfg), p)
        raise ValueError(b)

    def block_outputs(self, x: jax.Array,
                      eparams: Optional[dict] = None,
                      sfeat: Optional[jax.Array] = None) -> jax.Array:
        """x: (NBLK, 2, D, H, W) raw-feature block tensors -> (NBLK, O).

        For a scenario-conditioned emulator the peripheral vector is
        widened to ``(gain, offset, *scenario_features)``; ``sfeat=None``
        feeds the ideal corner's all-zero feature block.  A per-tile
        ``(NB, NO, F)`` sfeat is tiled across the batch rows -- the block
        rows are lattice-innermost (``ConductancePlan.build_x``), so each
        block gets its own tile's features."""
        n = x.shape[0]
        periph = jnp.concatenate(
            [jnp.ones((n, 1), x.dtype), jnp.zeros((n, 1), x.dtype)], axis=-1)
        if self.acfg.backend == "emulator":
            params = self.emulator_params if eparams is None else eparams
            npf = (conv4xbar.n_periph_of(params, self.geom)
                   if params is not None else 2)
            if npf > 2:
                if sfeat is None:
                    tail = jnp.zeros((n, npf - 2), x.dtype)
                elif sfeat.ndim >= 2:
                    t2 = sfeat.reshape(-1, sfeat.shape[-1]).astype(x.dtype)
                    tail = jnp.tile(t2, (n // t2.shape[0], 1))
                else:
                    tail = jnp.broadcast_to(sfeat.astype(x.dtype)[None],
                                            (n, npf - 2))
                periph = jnp.concatenate([periph, tail], axis=-1)
        return self._backend_fn(eparams)(x, periph)

    def _eval_blocks(self, plan: ConductancePlan, vb01: jax.Array,
                     eparams: Optional[dict] = None,
                     sfeat: Optional[jax.Array] = None) -> jax.Array:
        """vb01: (M, NB, D, H) wordline drive in [0, 1] -> (M*NB*NO, no).

        Only the slow paths route here (``fast_path=False`` or non-emulator
        backends); with the fast path on, the emulator backend goes through
        ``emulator_block_unified`` in ``raw_matmul`` -- on every device,
        Pallas or not."""
        x = plan.build_x(vb01 * self.acfg.v_read)
        return self.block_outputs(x.astype(jnp.float32), eparams, sfeat)

    def _drive01(self, u01: jax.Array) -> jax.Array:
        """Gate-overdrive wordline biasing (AnalogConfig.wl_overdrive): map
        nonzero normalized drives into [v_th/v_read, 1] so they clear the
        access transistor's cut-off instead of sitting in its deadband.
        Zero stays exactly zero -- the dual-rail delta factorization and
        padded tiles depend on it."""
        if not self.acfg.wl_overdrive:
            return u01
        t = self.cp.v_th / self.acfg.v_read
        return jnp.where(u01 > 0.0, t + u01 * (1.0 - t), 0.0)

    # ------------------------------------------------------------------ #
    # Tensor-parallel serving (docs/parallel.md)
    # ------------------------------------------------------------------ #
    def _scheme_for(self, nb: int, no: int) -> Optional[str]:
        """Lattice-sharding scheme for a (NB, NO) plan on this executor's
        mesh: 'auto' defers to ``lattice_scheme`` (col preferred -- it is
        bit-identical to the replicated path); a forced scheme is
        validated against the model-axis divisibility it requires."""
        _, tp = mesh_shape(self.mesh)
        if tp <= 1:
            return None
        if self.shard_scheme == "auto":
            return lattice_scheme(nb, no, tp)
        s = None if self.shard_scheme == "none" else self.shard_scheme
        if s not in (None, "row", "col"):
            raise ValueError(f"shard_scheme={self.shard_scheme!r} "
                             "(expected 'auto', 'row', 'col' or 'none')")
        if s == "col" and no % tp:
            raise ValueError(
                f"shard_scheme='col' needs NO % tp == 0 (NO={no}, tp={tp})")
        if s == "row" and nb % tp:
            raise ValueError(
                f"shard_scheme='row' needs NB % tp == 0 (NB={nb}, tp={tp})")
        return s

    def shard_state(self, st: DeploymentState) -> DeploymentState:
        """Place a ``DeploymentState``'s leaves on the serving mesh under
        the lattice partition specs (no-op without a mesh).  Idempotent,
        and re-shards states materialized elsewhere -- including host
        arrays npz-loaded from a deployment saved under a DIFFERENT mesh
        shape (``load_deployment(..., executor=...)``)."""
        if self.mesh is None:
            return st
        nb, no = int(st.gf.shape[0]), int(st.gf.shape[1])
        return shard_deployment_state(st, self.mesh,
                                      self._scheme_for(nb, no))

    def shard_states(self, states: Dict[str, DeploymentState]
                     ) -> Dict[str, DeploymentState]:
        """``shard_state`` over a per-site state dict (serve sessions,
        loaded deployments)."""
        return {k: self.shard_state(v) for k, v in states.items()}

    def _sharded_matmul(self, x2d: jax.Array, x_scale: jax.Array,
                        plan: ConductancePlan, tag: str,
                        eparams: Optional[dict],
                        sfeat: Optional[jax.Array]) -> jax.Array:
        """The dp x tp ``shard_map`` evaluation of one analog matmul.

        Everything order-sensitive stays OUTSIDE the shard_map exactly as
        the replicated path computes it -- the global drive scale, the
        wordline tiling, the read-noise draw on the FULL conductance field
        (so noise values are mesh-invariant), the scenario shift, and the
        fault-remap output gather (post-psum, on full columns).  Inside,
        each shard evaluates its lattice slice as a local
        ``ConductancePlan`` view (``with_lattice``) -- blocks are
        independent across the lattice, so the per-shard math is the
        replicated math restricted to a slice -- and ONE ``psum`` over
        the model axis completes the digital bitline accumulation:

          col: full per-column NB reduction locally, scatter into the
               owned column range, psum against exact zeros elsewhere
               (bit-identical to the replicated path);
          row: per-shard partial bitline sums, psum finishes the
               reduction (float-tolerance: the psum re-brackets the f32
               accumulation).

        Returns the calibrand voltages (B, N) with the output permutation
        (or padded-column slice) already applied."""
        from repro.parallel.collectives import shard_map_compat
        from jax.sharding import PartitionSpec as P

        mesh = self.mesh
        dp, tp = mesh_shape(mesh)
        scheme = self._scheme_for(plan.NB, plan.NO)
        nb_l, no_l = local_lattice(plan.NB, plan.NO, tp, scheme)
        gf_spec = state_pspecs(scheme)["gf"]
        no, NOno = plan.no, plan.NO * plan.no
        B = x2d.shape[0]

        fast = self.acfg.backend == "emulator" and self.fast_path
        if fast:
            aux = self._blocklast_aux(eparams)
            ep = self.emulator_params if eparams is None else eparams
            shift = (sfeat @ aux["f0_scen"]
                     if sfeat is not None and "f0_scen" in aux else None)
            u = plan.tile_v(self._drive01(jnp.abs(x2d) / x_scale), 1.0)
            pos = plan.tile_v((x2d > 0).astype(jnp.float32), 1.0)
            drives, R = (u, pos), B
        else:
            # the rails ride as SEPARATE operands, concatenated per-shard
            # inside the body: a batch-axis concat feeding a shard_map
            # operand is miscompiled by GSPMD on this jax version (each
            # row comes back multiplied by the model-axis size -- see
            # tests/test_multidevice.py), while ops inside the manual
            # region are plain local computations
            vp = plan.tile_v(self._drive01(jnp.clip(x2d, 0.0, None)
                                           / x_scale), 1.0)
            vn = plan.tile_v(self._drive01(jnp.clip(-x2d, 0.0, None)
                                           / x_scale), 1.0)
            ep, shift = eparams, None
            drives, R = (vp, vn), B

        # pad batch rows to a dp multiple with zero rows -- bit-neutral:
        # rows are independent and the drive scale is already fixed
        Rp = -(-R // dp) * dp
        if Rp != R:
            drives = tuple(
                jnp.pad(v, ((0, Rp - R),) + ((0, 0),) * (v.ndim - 1))
                for v in drives)
        # row scheme shards the drives' NB axis alongside gf; col/None
        # replicate them over model (columns share the wordline drive)
        d_spec = P(DATA_AXIS, MODEL_AXIS) if scheme == "row" \
            else P(DATA_AXIS)

        def _combine(y_cols, Ml):
            # y_cols: (Ml, no_l * no) -- this shard's full-NB column slice
            # (col) or all-column bitline partial (row / replicated)
            if scheme == "col":
                i = jax.lax.axis_index(MODEL_AXIS)
                y_cols = jax.lax.dynamic_update_slice(
                    jnp.zeros((Ml, NOno), y_cols.dtype), y_cols,
                    (0, i * no_l * no))
            if scheme is not None:
                y_cols = jax.lax.psum(y_cols, MODEL_AXIS)  # THE collective
            return y_cols

        # bodies take every traced quantity as an explicit arg (shard_map
        # rejects closed-over tracers) and rebuild the stage-collapsed
        # weights from the raw param arrays inside (aux carries static
        # kernel widths that cannot ride a PartitionSpec'd pytree)
        if fast:
            from repro.kernels.emulator_block import emulator_block_unified

            def body(u, pos, gf, ep, *sh):
                lp = plan.with_lattice(gf, self.acfg, NB=nb_l, NO=no_l)
                laux = conv4xbar.blocklast_weights(ep, self.geom)
                lpre = conv4xbar.blocklast_precompute(laux, lp.g_norm)
                s = sh[0] if sh else None
                if s is not None and s.ndim == 3:
                    # per-tile shift: the spec sliced this shard's own
                    # (nb_l, no_l) lattice window; flatten to block order
                    s = s.reshape(-1, s.shape[-1])
                y2 = emulator_block_unified(
                    laux, lpre, u, pos, shift=s,
                    use_pallas=self.use_pallas, chunk=self.fast_chunk,
                    tune=False)
                Ml = u.shape[0]
                asm = lambda o: o.reshape(Ml, nb_l, no_l * no).sum(axis=1)
                return _combine(asm(y2[0]) - asm(y2[1]), Ml)

            args = drives + (plan.g_feat, ep)
            in_specs = (d_spec, d_spec, gf_spec, P())
            if shift is not None:
                args += (shift,)
                # per-tile (NB, NO, fc0_out) shift rides the SAME lattice
                # axis as gf so each shard sees its own tiles' epilogue;
                # flat (fc0_out,) shifts replicate
                in_specs += ((gf_spec if shift.ndim == 3 else P()),)
        else:
            v_read = self.acfg.v_read

            def body(vp, vn, gf, ep, sf):
                lp = plan.with_lattice(gf, self.acfg, NB=nb_l, NO=no_l)
                # both rails in ONE blockified batch, as the replicated
                # path stacks them (local concat: safe inside the region)
                vb = jnp.concatenate([vp, vn], axis=0)
                x = lp.build_x(vb * v_read)
                outs = self.block_outputs(x.astype(jnp.float32), ep, sf)
                Ml = vp.shape[0]
                y = outs.reshape(2 * Ml, nb_l, no_l * no).sum(axis=1)
                return _combine(y[:Ml] - y[Ml:], Ml)

            args = drives + (plan.g_feat, ep, sfeat)
            # per-tile (NB, NO, F) features shard with the lattice (each
            # shard's block_outputs tiles its own window); flat vectors
            # and None replicate
            sf_spec = (gf_spec if sfeat is not None and sfeat.ndim == 3
                       else P())
            in_specs = (d_spec, d_spec, gf_spec, P(), sf_spec)

        y = shard_map_compat(body, mesh, in_specs, P(DATA_AXIS))(*args)
        if Rp != R:
            y = y[:R]
        # logical column order: the remap gather runs post-psum on the
        # full output, exactly as plan.assemble orders it
        return (jnp.take(y, plan.out_perm, axis=1)
                if plan.out_perm is not None else y[:, :plan.N])

    # ------------------------------------------------------------------ #
    def raw_matmul(self, x2d: jax.Array, w: jax.Array, tag: str = "",
                   plan: Optional[ConductancePlan] = None,
                   read_key: Optional[jax.Array] = None,
                   read_sigma=None,
                   eparams: Optional[dict] = None,
                   sfeat: Optional[jax.Array] = None
                   ) -> Tuple[jax.Array, jax.Array]:
        """Analog forward for (B,K) @ (K,N): dual-rail inputs, tiled blocks,
        digital block-group accumulation. Output in volts (uncalibrated).

        Both rails run as ONE blockified batch against the cached
        conductance plan for `tag`: the emulator fast path evaluates them
        via the shared-magnitude delta factorization (the unified
        kernel/dispatcher ``emulator_block_unified``), all other backends
        stack the rails on the batch axis.

        `plan` overrides the cached conductance plan (the unified forward
        passes the deployment state's device-perturbed, possibly
        fault-remapped plan); with `plan=None` and an active scenario the
        device-state perturbation is applied here, inside the trace.
        `read_key`/`read_sigma` add one cycle-to-cycle read-noise draw on
        top of whatever plan is in effect (`read_sigma` may be per-tile;
        sigma 0 is an exact bitwise identity).  `eparams` overrides the
        executor's emulator params (the deployment state's hot-swapped
        params arrive here as traced arguments).  `sfeat` is the
        scenario-feature vector a conditioned emulator consumes (all-zero
        = the ideal corner's encoding); with `sfeat=None` and an active
        scenario it is derived here, so the in-trace path conditions too."""
        if plan is None:
            plan = self._plan_for(w, tag)
            sc = self.scenario
            if sc is not None and not sc.is_ideal:
                if tag and not _is_tracer(plan.g_feat):
                    plan = self._scenario_plan(tag, w)   # cached device draw
                else:
                    plan = perturb_plan(plan, self.acfg, sc,
                                        self._tag_key(tag))
                if read_key is None and sc.has_read_noise:
                    read_key, read_sigma = self._next_read_key(), sc.read_sigma
                if sfeat is None and self.acfg.backend == "emulator" \
                        and eparams is None and self.emulator_conditioned:
                    sfeat = self._scenario_features()
        if read_key is not None:
            rs = 0.0 if read_sigma is None else read_sigma
            if self.mesh is not None and mesh_shape(self.mesh) != (1, 1):
                # The read-noise draw must be MESH-INVARIANT: jax's
                # default (non-partitionable) threefry changes values
                # when GSPMD partitions the counter computation, and
                # even with a pinned draw a partitioned elementwise
                # application leaves ulp-level fusion differences.  So
                # the whole noise block -- inputs, draw, output -- runs
                # replicated (P()) and the shard_map operand re-slices
                # the result; a deployment then serves the same noisy
                # conductances on every mesh shape, including none
                # (docs/parallel.md).
                from jax.sharding import NamedSharding
                from jax.sharding import PartitionSpec as P
                rep = NamedSharding(self.mesh, P())
                wsc = jax.lax.with_sharding_constraint
                gn = wsc(apply_read_noise(
                    wsc(plan.g_feat, rep), self.acfg,
                    wsc(jnp.asarray(rs, jnp.float32), rep), read_key), rep)
            else:
                gn = apply_read_noise(plan.g_feat, self.acfg, rs, read_key)
            plan = plan.with_g(gn, self.acfg)
        B = x2d.shape[0]
        x2d = x2d.astype(jnp.float32)
        x_scale = jnp.maximum(jnp.max(jnp.abs(x2d)), 1e-9)
        if self.mesh is not None and mesh_shape(self.mesh) != (1, 1):
            return self._sharded_matmul(x2d, x_scale, plan, tag,
                                        eparams, sfeat), x_scale
        if self.acfg.backend == "emulator" and self.fast_path:
            from repro.kernels.emulator_block import emulator_block_unified
            aux = self._blocklast_aux(eparams)
            pre = self._pre_for(plan, tag, aux)
            shift = None
            if sfeat is not None and "f0_scen" in aux:
                # conditioned corner contribution: a (fc0_out,) bias
                # shift, exactly zero at the ideal (all-zero) encoding;
                # per-tile (NB, NO, F) operands flatten to one
                # (NB*NO, fc0_out) shift per block in lattice order
                shift = sfeat @ aux["f0_scen"]
                if shift.ndim == 3:
                    shift = shift.reshape(-1, shift.shape[-1])
            u = plan.tile_v(self._drive01(jnp.abs(x2d) / x_scale), 1.0)
            pos = plan.tile_v((x2d > 0).astype(jnp.float32), 1.0)
            y2 = emulator_block_unified(aux, pre, u, pos, shift=shift,
                                        use_pallas=self.use_pallas,
                                        chunk=self.fast_chunk)
            return plan.assemble(y2[0]) - plan.assemble(y2[1]), x_scale
        rails = jnp.concatenate([jnp.clip(x2d, 0.0, None),
                                 jnp.clip(-x2d, 0.0, None)], axis=0)
        vb01 = plan.tile_v(self._drive01(rails / x_scale), 1.0)  # (2B,NB,D,H)
        outs = self._eval_blocks(plan, vb01.astype(jnp.float32), eparams,
                                 sfeat)
        y = plan.assemble(outs)                       # (2B, N)
        return y[:B] - y[B:], x_scale

    def calibrate(self, key, w: jax.Array, tag: str, n: int = 256,
                  noise_draws: int = 4, warm_start: bool = False):
        """Fit the per-layer affine volts->logical map against digital.

        Noise-aware: with an active scenario the fit runs against the same
        device state the serving path sees (the unified forward at unit
        affine), and the response is averaged over `noise_draws`
        cycle-to-cycle read draws so the affine targets the expected (not
        one-shot) transfer.  The fit reuses the tag's ONE compiled
        forward -- each read draw is just a new ``read_key`` leaf.

        ``warm_start=True`` transfers the previous affine instead of
        refitting from scratch (docs/lifetime.md "calibration transfer"):
        drift between checkpoints is mostly a scale shift, so the refit
        runs on HALF the probe budget with the previous ``(a, b)`` as a
        ridge prior.  Falls back to a cold full-budget fit when no
        previous affine exists.  The probe count actually used is
        recorded in ``_last_calib_n`` (asserted in tests)."""
        prev = self.calibration.get(tag) if warm_start else None
        n_eff = max(8, n // 2) if prev is not None else n
        xc = jax.random.normal(key, (n_eff, w.shape[0])) * 0.5
        sc = self.scenario
        st = self._base_state(tag, w)        # unit affine by construction
        draws = (max(1, noise_draws)
                 if sc is not None and sc.has_read_noise else 1)
        keys = jax.random.split(
            jax.random.fold_in(self.scenario_key, 0xCA11B), draws)
        fn = self._unified_for(tag, w)
        ys = jnp.stack([fn(xc, st.with_read_key(k))
                        for k in keys]).mean(axis=0)
        xs = jnp.maximum(jnp.max(jnp.abs(xc.astype(jnp.float32))), 1e-9)
        yv_flat = (ys / xs).reshape(-1)
        yd_flat = ((xc @ w) / xs).reshape(-1)
        A = jnp.stack([yv_flat, jnp.ones_like(yv_flat)], axis=1)
        rhs = yd_flat
        if prev is not None:
            # ridge prior toward the previous checkpoint's affine: one
            # synthetic row per parameter, each weighted at ~5% of the
            # data's leverage on THAT parameter (sum yv^2 for the scale,
            # the row count for the offset) so the probes still dominate
            la = jnp.sqrt(0.05 * jnp.sum(yv_flat * yv_flat) + 1e-12)
            lb = jnp.sqrt(0.05 * yv_flat.shape[0])
            A = jnp.concatenate(
                [A, jnp.asarray([[1.0, 0.0], [0.0, 1.0]], A.dtype)
                 * jnp.asarray([[la], [lb]], A.dtype)], axis=0)
            rhs = jnp.concatenate(
                [rhs, jnp.asarray([la * prev[0], lb * prev[1]], rhs.dtype)],
                axis=0)
        sol, *_ = jnp.linalg.lstsq(A, rhs)
        self.calibration[tag] = (float(sol[0]), float(sol[1]))
        self._last_calib_n = n_eff
        if OBS.enabled:
            # fleet health: RMS residual of the affine fit over the DATA
            # rows (prior rows excluded) -- a drifting device that the
            # affine can no longer linearize shows up here first.  All
            # arrays are concrete (this is an eager fit): recording them
            # cannot perturb anything served.
            res = yv_flat * sol[0] + sol[1] - yd_flat
            OBS.gauge("analog_calibration_residual",
                      "RMS residual of the volts->logical affine fit",
                      tag=tag).set(float(jnp.sqrt(jnp.mean(res * res))))
            OBS.gauge("analog_calibration_probes",
                      "probe budget used by the last calibration fit",
                      tag=tag).set(n_eff)
            OBS.counter("analog_calibrations_total",
                        "calibration fits per tag and start mode",
                        tag=tag,
                        mode="warm" if prev is not None else "cold").inc()
        return self.calibration[tag]

    # ------------------------------------------------------------------ #
    # THE per-tag compiled forward (the single surviving jit-cache family)
    # ------------------------------------------------------------------ #
    def _unified_for(self, tag: str, w: jax.Array) -> Callable:
        """Per-(tag, weight-binding) unified forward ``fn(x2, state)``.

        `w` is closed over as a concrete constant, so the cached
        conductance plan is computed at trace time; EVERYTHING deployed --
        conductances, read sigma/key, remap permutation, emulator params,
        scenario features, calibration affine -- arrives inside the one
        traced ``DeploymentState``, so corner / age / remap / read-cycle /
        recalibration / retrained-params swaps all reuse one executable
        per (tag, shape).  Only a line-resistance change rebuilds it
        (CircuitParams is a hashed static of the circuit backend).

        The read-noise draw and the output gather run even at sigma == 0 /
        identity permutations (exact identities there): a g_feat-sized
        threefry sample and an (N,)-gather are tens of microseconds
        against a millisecond-scale matmul, and keeping them unconditional
        preserves exactly ONE executable per tag."""
        ent = self._fns.get(tag)
        rls = self.scenario.r_line_scale if self.scenario else 1.0
        if ent is not None and ent[0] is w and ent[1] == rls:
            return ent[2]
        # close over the ORIGINAL weight binding: the plan's conductances
        # are replaced by the state's gf leaf anyway, and an f32 alias
        # would make the per-tag plan cache ping-pong between identities
        # for bf16-served weights
        if OBS.enabled:
            OBS.counter("analog_unified_builds_total",
                        "per-tag unified forwards (re)built -- each build "
                        "implies at least one fresh compile",
                        tag=tag).inc()

        def _fwd(x2, st):
            # trace-time side effect: counts compiles of THIS tag's
            # forward (pure Python -- the jaxpr is unchanged, so the
            # counter is compile- and bit-neutral by construction)
            if OBS.enabled:
                OBS.counter("analog_traces_total",
                            "jit traces of the per-tag unified forward",
                            tag=tag).inc()
            return _st_matmul_u(self, tag, x2, w, st)

        fn = jax.jit(_fwd)
        self._fns[tag] = (w, rls, fn)
        return fn

    def matmul(self, x: jax.Array, w: jax.Array, tag: str = "",
               state: Optional[DeploymentState] = None) -> jax.Array:
        """Calibrated analog matmul with straight-through digital gradient.

        Compiles once per (tag, shape): the custom_vjp is module-level and
        the whole deployment -- device perturbation, remap, read cycle,
        emulator params, scenario features AND the calibration affine --
        enters as ONE traced ``DeploymentState``, so recalibration,
        scenario swaps, aging, remapping and retraining never retrigger
        compilation.  ``state`` overrides the active deployment's
        materialized state (``ServeSession`` threads per-call-site states
        through its compiled serving steps this way); by default the state
        derives from ``deploy(...)``'s spec, and the ideal deployment is
        bit-identical to the plain serving fast path."""
        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
        t0 = time.perf_counter() if OBS.enabled else 0.0
        if _is_tracer(x2) or _is_tracer(w) or not tag:
            mode = "eager"
            if state is None:
                a, b = self.calibration.get(tag, (1.0, 0.0))
                state = self._inline_state(tag, w, a, b)
            y = _st_matmul_u(self, tag, x2, w, state)
        else:
            mode = "jit"
            st = state if state is not None else self.state_for(tag, w)
            y = self._unified_for(tag, w)(x2, st)
        if OBS.enabled:
            # dispatch latency, NOT synchronized compute time: no
            # block_until_ready is added here (that would serialize the
            # dispatch pipeline the serving loop depends on).  "jit" is
            # the per-tag compiled forward; "eager" is the in-trace /
            # anonymous-tag path (under an enclosing jit this records
            # once, at trace time).
            dt = time.perf_counter() - t0
            OBS.histogram("analog_matmul_seconds",
                          "unified-forward dispatch latency, split "
                          "eager-vs-jit (host-side, no device sync)",
                          mode=mode).observe(dt)
            OBS.counter("analog_matmul_calls_total",
                        "analog matmul calls per tag and dispatch mode",
                        tag=tag or "<anon>", mode=mode).inc()
        return y.reshape(*lead, w.shape[1]).astype(x.dtype)

    # ------------------------------------------------------------------ #
    @contextlib.contextmanager
    def bound_states(self, binding: _StateBinding):
        """Route dense() call sites through ``binding`` for the duration
        (``ServeSession``'s per-step state threading)."""
        prev = self._binding
        self._binding = binding
        try:
            yield binding
        finally:
            self._binding = prev

    def hook(self, x: jax.Array, w: jax.Array, tag: str):
        """dense()-hook: route configured projections to the analog path."""
        if self.acfg.backend == "digital":
            return None
        if not any(tag.startswith(l) for l in self.acfg.layers):
            return None
        if self._binding is not None:
            return self._binding.intercept(self, x, w, tag)
        return self.matmul(x, w, tag)
