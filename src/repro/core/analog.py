"""AnalogMatmul: execute dense projections on emulated crossbar hardware.

Backends (config ``analog.backend``):
  digital   -- plain matmul (technique off; baseline)
  analytic  -- expert analytical model (paper's strawman)
  circuit   -- Newton-Raphson circuit solver (exact, slow; SPICE stand-in)
  emulator  -- trained Conv4Xbar regression net (the paper's contribution)

Execution model (see core/crossbar.py): weights are tiled onto differential
1T1R crossbars; activations drive wordlines dual-rail (v+ = relu(x),
v- = relu(-x)); blocks of D tiles accumulate in analog, block groups sum
digitally; a per-layer affine calibration maps block output voltages back to
logical units. The backward pass is the straight-through digital gradient
(hardware-aware training), via custom_vjp.

Serving fast path (docs/performance.md): the conductance plan for a weight
tag (tiling, padding, block interleave) is cached and reused across calls;
both voltage rails are evaluated in ONE blockified pass — the emulator
backend reconstructs them from a single magnitude-drive CELU against the
precomputed zero-voltage block response (``apply_blocklast``), other
backends stack the rails on the batch axis — and the per-block conductance
features are consumed directly (block-indexed Pallas operand on TPU)
instead of a batch-broadcast feature tensor.  The straight-through
``custom_vjp`` and per-tag ``jit`` are constructed once, so ``matmul``
compiles once per shape.

Install into a model with ``use_dense_hook(executor.hook)`` -- every
``dense()`` in repro.models routes through here.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import AnalogConfig
from repro.configs.rram_ps32 import BlockGeometry, CASE_A
from repro.core import conv4xbar
from repro.core.analytic import analytic_block_response
from repro.core.circuit import CircuitParams, block_response
from repro.core.crossbar import ConductancePlan, build_conductance_plan
from repro.core.emulator import normalize_features


def _is_tracer(x) -> bool:
    return isinstance(x, jax.core.Tracer)


# --------------------------------------------------------------------------- #
# Straight-through analog matmul, hoisted to module level so the custom_vjp
# (and the per-tag jit wrapping it) is built once, not per forward call.
# --------------------------------------------------------------------------- #
@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _st_matmul(ex: "AnalogExecutor", tag: str, x2, w, a, b):
    yv, xs = ex.raw_matmul(x2, w, tag)
    return (a * yv + b) * xs


def _st_fwd(ex, tag, x2, w, a, b):
    return _st_matmul(ex, tag, x2, w, a, b), (x2, w)


def _st_bwd(ex, tag, res, ct):
    x2, w = res                        # straight-through digital grads
    return ct @ w.T, x2.T @ ct, jnp.zeros((), ct.dtype), jnp.zeros((), ct.dtype)


_st_matmul.defvjp(_st_fwd, _st_bwd)


@dataclass(eq=False)
class AnalogExecutor:
    acfg: AnalogConfig
    geom: BlockGeometry = CASE_A
    cp: CircuitParams = field(default_factory=CircuitParams)
    emulator_params: Optional[dict] = None
    calibration: Dict[str, tuple] = field(default_factory=dict)
    fused_emulator: bool = True        # apply_fused vs apply on the slow path
    fast_path: bool = True             # cached-plan blockified serving path
    fast_chunk: int = 4                # batch rows per cache-sized chunk
    use_pallas: Optional[bool] = None  # None = auto (TPU only)

    def __post_init__(self):
        self._plans: Dict[str, Tuple[jax.Array, ConductancePlan]] = {}
        self._jit_fns: Dict[str, Tuple[jax.Array, Callable]] = {}
        self._g0_cache: Dict[str, Tuple[ConductancePlan, dict]] = {}
        self._aux = None
        self._aux_src = None

    # ------------------------------------------------------------------ #
    # Conductance-plan cache
    # ------------------------------------------------------------------ #
    def _plan_for(self, w: jax.Array, tag: str) -> ConductancePlan:
        """Tile/pad/interleave once per bound weight; rebuilt only when the
        tag is rebound to a different array (or under tracing)."""
        if _is_tracer(w):
            return build_conductance_plan(w, self.acfg, self.geom)
        ent = self._plans.get(tag) if tag else None
        if ent is not None and ent[0] is w:
            return ent[1]
        # force eager evaluation even under an enclosing jit trace: the plan
        # must come out concrete so it is computed once and cached, not
        # re-tiled inside the compiled graph on every call
        with jax.ensure_compile_time_eval():
            plan = build_conductance_plan(w, self.acfg, self.geom)
        if tag:
            self._plans[tag] = (w, plan)
            self._g0_cache.pop(tag, None)
        return plan

    def _blocklast_aux(self) -> dict:
        assert self.emulator_params is not None, \
            "emulator backend needs trained params (core.emulator)"
        if any(_is_tracer(v) for v in self.emulator_params.values()):
            return conv4xbar.blocklast_weights(self.emulator_params, self.geom)
        if self._aux is None or self._aux_src is not self.emulator_params:
            with jax.ensure_compile_time_eval():
                self._aux = conv4xbar.blocklast_weights(self.emulator_params,
                                                        self.geom)
            self._aux_src = self.emulator_params
            self._g0_cache.clear()
        return self._aux

    def _pre_for(self, plan: ConductancePlan, tag: str, aux: dict) -> dict:
        """Batch-independent fast-path tensors (zero-voltage block response
        and its stage-1 projection), cached per (tag, plan)."""
        if _is_tracer(plan.g_norm) or any(_is_tracer(v) for v in aux.values()
                                          if isinstance(v, jax.Array)):
            return conv4xbar.blocklast_precompute(aux, plan.g_norm)
        ent = self._g0_cache.get(tag) if tag else None
        if ent is not None and ent[0] is plan:
            return ent[1]
        with jax.ensure_compile_time_eval():
            pre = conv4xbar.blocklast_precompute(aux, plan.g_norm)
        if tag:
            self._g0_cache[tag] = (plan, pre)
        return pre

    # ------------------------------------------------------------------ #
    # Backends
    # ------------------------------------------------------------------ #
    def _backend_fn(self):
        b = self.acfg.backend
        if b == "circuit":
            return lambda x, p: block_response(x, self.cp, p)
        if b == "analytic":
            return lambda x, p: analytic_block_response(x, self.cp, p)
        if b == "emulator":
            assert self.emulator_params is not None, \
                "emulator backend needs trained params (core.emulator)"
            ap = (conv4xbar.apply_fused if self.fused_emulator
                  else conv4xbar.apply)
            return lambda x, p: ap(self.emulator_params,
                                   normalize_features(x, self.acfg), p)
        raise ValueError(b)

    def block_outputs(self, x: jax.Array) -> jax.Array:
        """x: (NBLK, 2, D, H, W) raw-feature block tensors -> (NBLK, O)."""
        periph = jnp.concatenate(
            [jnp.ones((x.shape[0], 1), x.dtype),
             jnp.zeros((x.shape[0], 1), x.dtype)], axis=-1)
        return self._backend_fn()(x, periph)

    def _pallas_enabled(self) -> bool:
        if self.use_pallas is not None:
            return self.use_pallas
        return jax.default_backend() == "tpu"

    def _eval_blocks(self, plan: ConductancePlan,
                     vb01: jax.Array) -> jax.Array:
        """vb01: (M, NB, D, H) wordline drive in [0, 1] -> (M*NB*NO, no)."""
        if self.acfg.backend == "emulator" and self.fast_path \
                and self._pallas_enabled():
            from repro.kernels.emulator_block import emulator_block_grid
            M = vb01.shape[0]
            g = plan.g_norm.reshape((plan.n_blocks,) + plan.g_norm.shape[2:])
            y = emulator_block_grid(self.emulator_params, vb01, g, self.geom)
            return y.reshape(M * plan.n_blocks, -1)
        x = plan.build_x(vb01 * self.acfg.v_read)
        return self.block_outputs(x.astype(jnp.float32))

    # ------------------------------------------------------------------ #
    def raw_matmul(self, x2d: jax.Array, w: jax.Array,
                   tag: str = "") -> Tuple[jax.Array, jax.Array]:
        """Analog forward for (B,K) @ (K,N): dual-rail inputs, tiled blocks,
        digital block-group accumulation. Output in volts (uncalibrated).

        Both rails run as ONE blockified batch against the cached
        conductance plan for `tag`: the emulator fast path evaluates them
        via the shared-magnitude delta factorization (apply_blocklast), all
        other backends stack the rails on the batch axis."""
        plan = self._plan_for(w, tag)
        B = x2d.shape[0]
        x2d = x2d.astype(jnp.float32)
        x_scale = jnp.maximum(jnp.max(jnp.abs(x2d)), 1e-9)
        if self.acfg.backend == "emulator" and self.fast_path \
                and not self._pallas_enabled():
            aux = self._blocklast_aux()
            pre = self._pre_for(plan, tag, aux)
            u = plan.tile_v(jnp.abs(x2d) / x_scale, 1.0)
            pos = plan.tile_v((x2d > 0).astype(jnp.float32), 1.0)
            y2 = conv4xbar.apply_blocklast(aux, pre, u, pos,
                                           chunk=self.fast_chunk)
            return plan.assemble(y2[0]) - plan.assemble(y2[1]), x_scale
        rails = jnp.concatenate([jnp.clip(x2d, 0.0, None),
                                 jnp.clip(-x2d, 0.0, None)], axis=0)
        vb01 = plan.tile_v(rails / x_scale, 1.0)      # (2B, NB, D, H)
        outs = self._eval_blocks(plan, vb01.astype(jnp.float32))
        y = plan.assemble(outs)                       # (2B, N)
        return y[:B] - y[B:], x_scale

    def calibrate(self, key, w: jax.Array, tag: str, n: int = 256):
        """Fit the per-layer affine volts->logical map against digital."""
        xc = jax.random.normal(key, (n, w.shape[0])) * 0.5
        yv, xs = jax.jit(lambda xx: self.raw_matmul(xx, w, tag))(xc)
        yd = (xc @ w) / xs
        yv_flat = yv.reshape(-1)
        A = jnp.stack([yv_flat, jnp.ones_like(yv_flat)], axis=1)
        sol, *_ = jnp.linalg.lstsq(A, yd.reshape(-1))
        self.calibration[tag] = (float(sol[0]), float(sol[1]))
        return self.calibration[tag]

    def _jit_for(self, tag: str, w: jax.Array) -> Callable:
        """Per-(tag, weight-binding) jitted forward.  `w` is closed over as a
        concrete constant, so the cached conductance plan is computed at
        trace time (once) and baked into the executable."""
        ent = self._jit_fns.get(tag)
        if ent is not None and ent[0] is w:
            return ent[1]
        wf = w.astype(jnp.float32)
        fn = jax.jit(lambda x2, a, b: _st_matmul(self, tag, x2, wf, a, b))
        self._jit_fns[tag] = (w, fn)
        return fn

    def matmul(self, x: jax.Array, w: jax.Array, tag: str = "") -> jax.Array:
        """Calibrated analog matmul with straight-through digital gradient.

        Compiles once per (tag, shape): the custom_vjp is module-level and
        the calibration affine enters as traced scalars, so recalibration
        does not retrigger compilation."""
        a, b = self.calibration.get(tag, (1.0, 0.0))
        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
        af = jnp.asarray(a, jnp.float32)
        bf = jnp.asarray(b, jnp.float32)
        if _is_tracer(x2) or _is_tracer(w) or not tag:
            y = _st_matmul(self, tag, x2, w.astype(jnp.float32), af, bf)
        else:
            y = self._jit_for(tag, w)(x2, af, bf)
        return y.reshape(*lead, w.shape[1]).astype(x.dtype)

    # ------------------------------------------------------------------ #
    def hook(self, x: jax.Array, w: jax.Array, tag: str):
        """dense()-hook: route configured projections to the analog path."""
        if self.acfg.backend == "digital":
            return None
        if not any(tag.startswith(l) for l in self.acfg.layers):
            return None
        return self.matmul(x, w, tag)
