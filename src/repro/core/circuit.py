"""SPICE stand-in: a batched Newton-Raphson nonlinear circuit solver for
1T1R crossbar tiles with a PS32-style saturating integrator peripheral.

This is the *data generator* for the emulator (the paper uses SPYCE/SPICE;
offline here we solve the same class of nonlinear circuit equations
numerically -- a non-analytic function obtained by iteration, which is the
qualitative object the emulator must learn).

Cell model (series 1T1R):
  access transistor, gate driven by the wordline voltage V (the activation):
    square-law NMOS with threshold V_th, transconductance k_t, channel-length
    modulation lambda; cut off for V <= V_th  (=> the Fig.5 threshold)
  memristor programmed to conductance g with a mild quadratic nonlinearity:
    i_m = g * v_m * (1 + beta * v_m)
  solved for the internal node v_x with vectorized NR (all cells at once).

Bitline: integrator virtual ground with finite input resistance r_bl =>
IR-drop feedback (fixed-point, 3 iterations).

Peripheral (PS32): differential current integrated over t_int onto c_int
with a tanh() op-amp saturation at v_sat, gain/offset being *peripheral
features* exposed to the emulator.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import AnalogConfig


@dataclass(frozen=True)
class CircuitParams:
    v_th: float = 0.08            # transistor threshold (V) -- Fig.5 V_const
    k_t: float = 2.2e-3           # transconductance (A/V^2)
    lam: float = 0.05             # channel-length modulation (1/V)
    beta: float = 0.6             # memristor quadratic nonlinearity (1/V)
    r_bl: float = 400.0           # bitline/integrator input resistance (ohm)
    t_int: float = 3.2e-6         # integration time (s)  (32 pulses x 100ns)
    c_int: float = 1.0e-9         # integration cap (F)
    v_sat: float = 1.0            # op-amp saturation (V)
    nr_iters: int = 12
    ir_iters: int = 3


def transistor_current(v_gs: jax.Array, v_ds: jax.Array,
                       cp: CircuitParams) -> jax.Array:
    """Square-law NMOS, smooth blend triode/saturation, cut off below V_th."""
    vov = jnp.maximum(v_gs - cp.v_th, 0.0)
    v_ds = jnp.maximum(v_ds, 0.0)
    vd_eff = jnp.minimum(v_ds, vov)
    i = cp.k_t * (vov * vd_eff - 0.5 * vd_eff * vd_eff) * (1.0 + cp.lam * v_ds)
    return i


def _transistor_gds(v_gs, v_ds, cp: CircuitParams):
    """d i_t / d v_ds (for NR)."""
    vov = jnp.maximum(v_gs - cp.v_th, 0.0)
    v_ds = jnp.maximum(v_ds, 0.0)
    triode = v_ds < vov
    g_tri = cp.k_t * (vov - v_ds) * (1.0 + cp.lam * v_ds) \
        + cp.k_t * (vov * v_ds - 0.5 * v_ds ** 2) * cp.lam
    g_sat = cp.k_t * 0.5 * vov ** 2 * cp.lam
    return jnp.where(triode, g_tri, g_sat) + 1e-9


def memristor_current(g: jax.Array, v_m: jax.Array, cp: CircuitParams):
    return g * v_m * (1.0 + cp.beta * v_m)


def _memristor_gm(g, v_m, cp: CircuitParams):
    return g * (1.0 + 2.0 * cp.beta * v_m) + 1e-12


def cell_current(v_wl: jax.Array, g: jax.Array, v_bl: jax.Array,
                 cp: CircuitParams) -> jax.Array:
    """Series 1T1R cell current via NR on the internal node v_x.

    v_wl: gate voltage (= activation-scaled v_read); g: memristor
    conductance; v_bl: bitline voltage (IR drop). All broadcastable.
    Cell stack: drain at v_dd_read = v_wl ... we drive the memristor top
    electrode at a fixed read rail v_r = 0.2 V, transistor source at the
    bitline. Memristor from rail to v_x; transistor from v_x to bitline.
    """
    v_rail = 0.2
    v_lo = v_bl
    v_x = jnp.broadcast_to(0.5 * (v_rail + v_lo),
                           jnp.broadcast_shapes(v_wl.shape, g.shape,
                                                jnp.shape(v_bl))).astype(jnp.float32)

    def body(i, v_x):
        i_m = memristor_current(g, v_rail - v_x, cp)
        i_t = transistor_current(v_wl - v_lo, v_x - v_lo, cp)
        f = i_m - i_t                                  # KCL at v_x
        df = -_memristor_gm(g, v_rail - v_x, cp) - _transistor_gds(
            v_wl - v_lo, v_x - v_lo, cp)
        step = f / df
        v_new = v_x - jnp.clip(step, -0.1, 0.1)
        return jnp.clip(v_new, v_lo, v_rail)

    v_x = jax.lax.fori_loop(0, cp.nr_iters, body, v_x)
    return transistor_current(v_wl - v_lo, v_x - v_lo, cp)


def solve_tile_currents(v: jax.Array, g: jax.Array,
                        cp: CircuitParams) -> jax.Array:
    """Column currents with bitline IR-drop fixed point.

    v: (..., H) wordline voltages; g: (..., H, W) conductances.
    Returns (..., W) column currents."""
    vv = v[..., :, None]

    def ir_step(_, i_col):
        v_bl = cp.r_bl * i_col[..., None, :]          # (..., 1, W)
        i_cell = cell_current(vv, g, v_bl, cp)
        return i_cell.sum(axis=-2)

    i0 = cell_current(vv, g, jnp.zeros_like(g[..., :1, :]), cp).sum(axis=-2)
    return jax.lax.fori_loop(0, cp.ir_iters, ir_step, i0)


def ps32_output(i_pos: jax.Array, i_neg: jax.Array, cp: CircuitParams,
                gain: jax.Array = 1.0, offset: jax.Array = 0.0) -> jax.Array:
    """Differential integrate + saturate: the computing block's output voltage.

    gain/offset are the *peripheral features* (vary per fabricated block)."""
    q = (i_pos - i_neg) * cp.t_int / cp.c_int
    return cp.v_sat * jnp.tanh(gain * q / cp.v_sat) + offset


def block_response(x: jax.Array, cp: CircuitParams,
                   periph: jax.Array | None = None) -> jax.Array:
    """Full computing-block response for emulator input tensors.

    x: (B, 2, D, H, W) with channel 0 = wordline voltage, channel 1 =
    conductance, W = 2*n_out interleaved (G+, G-).
    periph: (B, 2) [gain, offset] or None.
    Returns (B, n_out) output voltages.
    """
    v = x[:, 0, :, :, 0]                              # (B, D, H) -- same V per col
    g = x[:, 1]                                       # (B, D, H, W)
    i_cols = solve_tile_currents(v, g, cp)            # (B, D, W)
    i_cols = i_cols.sum(axis=1)                       # analog tile accumulation
    i_pos = i_cols[..., 0::2]
    i_neg = i_cols[..., 1::2]
    if periph is None:
        return ps32_output(i_pos, i_neg, cp)
    return ps32_output(i_pos, i_neg, cp, periph[:, 0:1], periph[:, 1:2])
