"""The 'human-expert analytical model' baseline the paper criticises
(Section 3.1): crossbar as an ideal linear MAC plus a hand-written clipping
nonlinearity for the peripheral. Cheap, differentiable, and -- as the paper
argues -- systematically wrong about the cell's threshold/power-law response
(it assumes i = g*v with no access-transistor physics, no IR drop).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.circuit import CircuitParams


def analytic_block_response(x: jax.Array, cp: CircuitParams,
                            periph: jax.Array | None = None) -> jax.Array:
    """x: (B, 2, D, H, W) as in circuit.block_response. Linear model:
    i = g * v_eff with a fitted effective transconductance, then the same
    integrator transfer (the expert knows the peripheral's gain but models
    the cell linearly)."""
    v = x[:, 0, :, :, 0]                              # (B, D, H)
    g = x[:, 1]                                       # (B, D, H, W)
    # linear cell: the expert calibrates a single slope around the bias point
    v_eff = jnp.maximum(v - cp.v_th, 0.0)             # knows the threshold...
    i = g * (0.55 * v_eff)[..., None]                 # ...but not the curvature
    i_cols = i.sum(axis=(1, 2)).reshape(x.shape[0], -1)
    i_pos = i_cols[..., 0::2]
    i_neg = i_cols[..., 1::2]
    q = (i_pos - i_neg) * cp.t_int / cp.c_int
    gain = 1.0 if periph is None else periph[:, 0:1]
    offset = 0.0 if periph is None else periph[:, 1:2]
    return jnp.clip(gain * q, -cp.v_sat, cp.v_sat) + offset
