"""Tensor-parallel partitioning of the analog serving plane.

Every ``DeploymentState`` used to be replicated per host: the conductance
field ``gf`` -- by far the largest leaf, ``(NB, NO, D, H, W)`` over the
whole tile lattice -- lived in full on every device, capping both layer
width and fleet size.  This module gives the deployment-state leaves
``PartitionSpec``s aligned with the tile lattice of the weights they
mirror, and supplies the mesh / placement helpers the executor's
``shard_map``-ed forward (``core.analog``) is built on.

Mesh axes (``serve_mesh(dp, tp)``):

  data   -- batch rows (requests / probe rows).  Bit-exact: rows are
            independent, and the drive normalization is a global max
            (computed outside the shard_map, so every shard sees the
            same scale).
  model  -- the tile lattice.  Two schemes (``lattice_scheme``):

    col -- shard the NO axis (output groups / bitline columns).  Each
           shard runs the FULL bitline (NB) reduction for its own
           columns in the exact flat order of the replicated path, then
           ONE ``psum`` over ``model`` completes the digital
           block-group accumulation (each shard contributes its columns
           plus exact zeros elsewhere).  Adding zeros is bit-preserving,
           so the col scheme is BIT-IDENTICAL to the replicated path.
    row -- shard the NB axis (row tiles / block groups).  Each shard
           sums its own block groups and ONE ``psum`` over ``model``
           finishes the bitline reduction -- the classic Megatron-style
           row-parallel linear.  The psum re-brackets the f32
           accumulation (local sums first, shard sum second), so row
           outputs agree with the replicated path to float tolerance,
           not bitwise (documented in docs/parallel.md).

  ``lattice_scheme`` prefers ``col`` exactly because it preserves the
  serving plane's standing bit-identity contract; ``row`` is chosen when
  only NB divides the model axis, and either can be forced via
  ``AnalogExecutor(shard_scheme=...)``.

Doctest (pure partition math; no devices needed):

    >>> lattice_scheme(nb=2, no=8, tp=4)
    'col'
    >>> lattice_scheme(nb=8, no=6, tp=4)
    'row'
    >>> lattice_scheme(nb=3, no=5, tp=4) is None
    True
    >>> local_lattice(nb=8, no=6, tp=4, scheme='row')
    (2, 6)
    >>> shard_output_slices(no=8, cols_per_group=1, tp=4)
    [(0, 2), (2, 4), (4, 6), (6, 8)]

See docs/parallel.md for the leaf PartitionSpec table, psum placement
and the re-shard-on-load semantics.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

DATA_AXIS = "data"
MODEL_AXIS = "model"


# --------------------------------------------------------------------------- #
# Pure partition math (property-tested in tests/test_sharding.py)
# --------------------------------------------------------------------------- #
def lattice_scheme(nb: int, no: int, tp: int) -> Optional[str]:
    """Which lattice axis the ``model`` mesh axis shards for a plan with
    ``nb`` block groups (rows) x ``no`` output groups (columns).

    Prefers ``'col'`` (bit-identical to the replicated path) whenever NO
    divides ``tp``; falls back to ``'row'`` (single psum on the bitline
    reduction, float-tolerance identity) when only NB divides; returns
    ``None`` -- replicate the lattice over ``model`` -- when neither
    does.  ``tp == 1`` always replicates."""
    if tp <= 1:
        return None
    if no % tp == 0:
        return "col"
    if nb % tp == 0:
        return "row"
    return None


def local_lattice(nb: int, no: int, tp: int,
                  scheme: Optional[str]) -> Tuple[int, int]:
    """Per-shard (NB_local, NO_local) under ``scheme``."""
    if scheme == "row":
        return nb // tp, no
    if scheme == "col":
        return nb, no // tp
    return nb, no


def shard_output_slices(no: int, cols_per_group: int,
                        tp: int) -> List[Tuple[int, int]]:
    """The [start, stop) output-column range each ``col``-scheme shard
    owns.  These ranges tile [0, no * cols_per_group) exactly -- no
    column dropped, duplicated, or reordered (the partition property the
    sharded assembly relies on; fuzzed in tests/test_sharding.py
    against ``fault_aware_group_perm`` assemblies)."""
    assert no % tp == 0, (no, tp)
    w = (no // tp) * cols_per_group
    return [(s * w, (s + 1) * w) for s in range(tp)]


def state_pspecs(scheme: Optional[str]) -> Dict[str, P]:
    """field name -> PartitionSpec for every ``DeploymentState`` leaf.

    The conductance field and the per-tile read sigma are partitioned
    along the same lattice axis as the weights they mirror; everything
    else (read key, output permutation, emulator params, scenario
    features, calibration affine) is replicated -- those leaves are
    either consumed post-psum on the full output or are O(1)-sized.

      gf         (NB, NO, D, H, W) -> row: P('model', ...) on NB
                                      col: P(None, 'model', ...) on NO
      read_sigma (NB, NO)          -> same lattice axis
      read_key / out_perm / eparams / sfeat / cal_a / cal_b -> P()
    """
    if scheme == "row":
        gf, rs = P(MODEL_AXIS), P(MODEL_AXIS)
    elif scheme == "col":
        gf, rs = P(None, MODEL_AXIS), P(None, MODEL_AXIS)
    else:
        gf, rs = P(), P()
    return {"gf": gf, "read_sigma": rs, "read_key": P(), "out_perm": P(),
            "eparams": P(), "sfeat": P(), "cal_a": P(), "cal_b": P()}


# --------------------------------------------------------------------------- #
# Mesh + placement
# --------------------------------------------------------------------------- #
def serve_mesh(dp: int = 1, tp: int = 1,
               devices: Optional[int] = None) -> Mesh:
    """A (data, model) serving mesh over ``dp * tp`` devices (defaults
    to requiring exactly that many; ``devices`` forces a host-device
    count check upstream).  Thin wrapper over ``launch.mesh._make_mesh``
    so Auto axis types follow the installed jax version."""
    from repro.launch.mesh import _make_mesh
    n = dp * tp
    avail = len(jax.devices()) if devices is None else devices
    if n > avail:
        raise ValueError(
            f"serve_mesh({dp}, {tp}) needs {n} devices, have {avail} "
            "(force host devices with "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    return _make_mesh((dp, tp), (DATA_AXIS, MODEL_AXIS))


def mesh_shape(mesh: Optional[Mesh]) -> Tuple[int, int]:
    """(dp, tp) of a serving mesh (1, 1 when mesh is None).  Accepts any
    mesh carrying the data/model axes; absent axes count as size 1."""
    if mesh is None:
        return 1, 1
    shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    return shape.get(DATA_AXIS, 1), shape.get(MODEL_AXIS, 1)


def shard_deployment_state(st, mesh: Mesh, scheme: Optional[str]):
    """Place one ``DeploymentState``'s leaves on ``mesh`` under the
    lattice partition specs.  Works on freshly materialized, npz-loaded
    (host) and previously-sharded states alike: ``device_put`` re-shards
    onto the target mesh, which is exactly the elastic-restart semantics
    deployments need when an npz saved under one mesh shape is served
    under another (docs/parallel.md)."""
    import dataclasses

    specs = state_pspecs(scheme)

    def put(field, v):
        sh = NamedSharding(mesh, specs[field])
        return jax.tree.map(lambda a: jax.device_put(a, sh), v)

    return dataclasses.replace(
        st, **{f: put(f, getattr(st, f)) for f in specs})
