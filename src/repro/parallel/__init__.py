from repro.parallel.collectives import (int8_compress, int8_decompress,
                                        compressed_psum)  # noqa: F401
