from repro.parallel.collectives import (int8_compress, int8_decompress,
                                        compressed_psum)  # noqa: F401
from repro.parallel.sharding import (DATA_AXIS, MODEL_AXIS,  # noqa: F401
                                     lattice_scheme, local_lattice,
                                     mesh_shape, serve_mesh,
                                     shard_deployment_state,
                                     shard_output_slices, state_pspecs)
