"""Gradient-compression collectives: int8-quantized all-reduce.

Used for the cross-pod (data-parallel replica) gradient sync: quantize each
tensor with a per-tensor scale, psum the int32 accumulators, dequantize --
4x fewer bytes on the slow inter-pod links than fp32 (2x vs bf16), with
stochastic-rounding-free deterministic quantization and optional error
feedback handled by the caller.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def shard_map_compat(f, mesh, in_specs, out_specs, axis_names=None):
    """jax.shard_map across jax versions: new API (jax.shard_map, check_vma,
    axis_names) when present, else jax.experimental.shard_map (check_rep,
    auto = complement of the manual axes)."""
    if hasattr(jax, "shard_map"):
        kw = {"check_vma": False}
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _sm
    kw = {"check_rep": False}
    if axis_names is not None:
        kw["auto"] = frozenset(mesh.axis_names) - set(axis_names)
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


def int8_compress(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def int8_decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """Inside shard_map: int8-quantized psum over `axis_name`.

    The wire format is int8 (the int32 upcast happens at the reduction);
    scales are psum-maxed first so all participants dequantize alike.
    """
    q, scale = int8_compress(x)
    scale = jax.lax.pmax(scale, axis_name)
    # requantize against the common scale so the sum is consistent
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale


def compressed_grad_sync(grads, mesh, axis: str = "pod"):
    """All-reduce a gradient pytree over `axis` with int8 compression.

    Grads must be replicated over `axis` -- i.e. per-pod partial means --
    and sharded however they like over the remaining axes (those specs are
    preserved via shard_map auto axes)."""
    if mesh is None or axis not in mesh.axis_names:
        return grads
    other = tuple(a for a in mesh.axis_names if a != axis)

    def sync(g):
        def f(gl):
            return compressed_psum(gl, axis) / mesh.shape[axis]
        return shard_map_compat(f, mesh, P(*[None] * g.ndim),
                                P(*[None] * g.ndim), axis_names={axis})(g)

    return jax.tree.map(sync, grads)
