"""Step-function builders: train_step / prefill_step / decode_step, plus the
abstract state/batch trees (ShapeDtypeStruct + NamedSharding) used both by
the dry-run (AOT lowering, zero allocation) and the real trainer.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import (ArchConfig, ParallelConfig, ShapeConfig,
                                TrainConfig)
from repro.models import model as M
from repro.models.common import (abstract_params, abstract_array, init_params,
                                 use_mesh, dp_axes)
from repro.optim.adamw import adamw_update, init_opt_schema, global_norm


def compute_dtype_of(pcfg: ParallelConfig):
    return jnp.bfloat16 if pcfg.compute_dtype == "bfloat16" else jnp.float32


# --------------------------------------------------------------------------- #
# State schemas
# --------------------------------------------------------------------------- #
def train_state_schema(cfg: ArchConfig):
    ps = M.model_schema(cfg)
    return {"params": ps, "opt": init_opt_schema(ps)}


def abstract_train_state(cfg: ArchConfig, mesh: Optional[Mesh]):
    sch = train_state_schema(cfg)
    state = {
        "params": abstract_params(sch["params"], mesh),
        "opt": abstract_params(sch["opt"], mesh),
        "step": abstract_array((), jnp.int32, P(), mesh),
    }
    return state


def init_train_state(key, cfg: ArchConfig):
    sch = train_state_schema(cfg)
    return {
        "params": init_params(key, sch["params"]),
        "opt": init_params(key, sch["opt"]),
        "step": jnp.zeros((), jnp.int32),
    }


# --------------------------------------------------------------------------- #
# Batch specs
# --------------------------------------------------------------------------- #
def abstract_params_bf16(cfg: ArchConfig, mesh: Optional[Mesh]):
    """Serving-time parameter tree (bf16)."""
    return abstract_params(M.model_schema(cfg), mesh, dtype=jnp.bfloat16)


def train_batch_abstract(cfg: ArchConfig, shape: ShapeConfig,
                         mesh: Optional[Mesh]):
    B, S = shape.global_batch, shape.seq_len
    dp = ("pod", "data")
    b: Dict[str, Any] = {
        "tokens": abstract_array((B, S), jnp.int32, P(dp, None), mesh),
        "targets": abstract_array((B, S), jnp.int32, P(dp, None), mesh),
        "mask": abstract_array((B, S), jnp.float32, P(dp, None), mesh),
    }
    if cfg.frontend == "vision":
        b["image_embeds"] = abstract_array(
            (B, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16,
            P(dp, None, None), mesh)
    if cfg.encoder_layers:
        b["enc_frames"] = abstract_array(
            (B, S, cfg.d_model), jnp.bfloat16, P(dp, None, None), mesh)
    return b


# --------------------------------------------------------------------------- #
# Train step
# --------------------------------------------------------------------------- #
def make_train_step(cfg: ArchConfig, pcfg: ParallelConfig, tcfg: TrainConfig):
    cdt = compute_dtype_of(pcfg)

    def loss_of(params, batch):
        # cast matrices to the compute dtype ONCE per step, before any use:
        # FSDP weight all-gathers then move bf16 (2x fewer bytes) instead of
        # f32 master weights; grads still flow to the f32 masters
        params = jax.tree.map(
            lambda p: p.astype(cdt)
            if (p.ndim >= 2 and p.dtype == jnp.float32) else p, params)
        return M.lm_loss(params, batch, cfg=cfg, pcfg=pcfg,
                         compute_dtype=cdt, z_coef=tcfg.z_loss)

    def train_step(state, batch):
        m = max(1, pcfg.grad_accum)
        if m == 1:
            (loss, parts), grads = jax.value_and_grad(loss_of, has_aux=True)(
                state["params"], batch)
        else:
            # microbatched gradient accumulation: only one microbatch's remat
            # stash is live at a time; grads accumulate in (sharded) fp32
            mb = jax.tree.map(
                lambda x: x.reshape((m, x.shape[0] // m) + x.shape[1:]), batch)

            def one(carry, b):
                gacc, lacc, xacc, aacc = carry
                (l, p), g = jax.value_and_grad(loss_of, has_aux=True)(
                    state["params"], b)
                gacc = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32), gacc, g)
                return (gacc, lacc + l, xacc + p["xent"], aacc + p["aux"]), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state["params"])
            (gsum, lsum, xsum, asum), _ = jax.lax.scan(
                one, (zeros, jnp.zeros((), jnp.float32),
                      jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
                mb)
            grads = jax.tree.map(lambda g: g / m, gsum)
            loss = lsum / m
            parts = {"xent": xsum / m, "aux": asum / m}

        new_params, new_opt, om = adamw_update(
            state["params"], grads, state["opt"], state["step"], tcfg)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        metrics = {"loss": loss, **parts, **om}
        return new_state, metrics

    return train_step


# --------------------------------------------------------------------------- #
# Serving steps
# --------------------------------------------------------------------------- #
def make_prefill_step(cfg: ArchConfig, pcfg: ParallelConfig):
    cdt = compute_dtype_of(pcfg)

    def prefill_step(params, batch):
        return M.prefill(params, batch["tokens"], cfg=cfg, pcfg=pcfg,
                         image_embeds=batch.get("image_embeds"),
                         enc_frames=batch.get("enc_frames"),
                         compute_dtype=cdt)

    return prefill_step


def make_decode_step(cfg: ArchConfig, pcfg: ParallelConfig):
    cdt = compute_dtype_of(pcfg)

    def decode_step(params, token, cache, pos):
        return M.decode_step(params, token, cache, pos, cfg=cfg, pcfg=pcfg,
                             compute_dtype=cdt)

    return decode_step


def prefill_batch_abstract(cfg: ArchConfig, shape: ShapeConfig,
                           mesh: Optional[Mesh]):
    B, S = shape.global_batch, shape.seq_len
    dp = ("pod", "data")
    b: Dict[str, Any] = {
        "tokens": abstract_array((B, S), jnp.int32, P(dp, None), mesh),
    }
    if cfg.frontend == "vision":
        b["image_embeds"] = abstract_array(
            (B, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16,
            P(dp, None, None), mesh)
    if cfg.encoder_layers:
        b["enc_frames"] = abstract_array(
            (B, S, cfg.d_model), jnp.bfloat16, P(dp, None, None), mesh)
        b["tokens"] = abstract_array((B, max(S // 32, 8)), jnp.int32,
                                     P(dp, None), mesh)
    return b


def decode_inputs_abstract(cfg: ArchConfig, shape: ShapeConfig,
                           mesh: Optional[Mesh], pcfg: ParallelConfig):
    """(params_bf16, token, cache, pos) abstract trees for one decode step."""
    B, S = shape.global_batch, shape.seq_len
    dp = ("pod", "data")
    params = abstract_params(M.model_schema(cfg), mesh, dtype=jnp.bfloat16)
    token = abstract_array((B, 1), jnp.int32, P(dp, None), mesh)
    pos = abstract_array((), jnp.int32, P(), mesh)
    cs = M.model_cache_schema(
        cfg, B, S, seq_shard=pcfg.decode_seq_shard,
        cross_len=(S if cfg.encoder_layers else 0))
    cache = M.abstract_cache(cs, mesh)
    return params, token, cache, pos
