"""Fault-tolerant training supervisor.

Designed for 1000+ node behaviour, simulated faithfully on CPU:
  * checkpoint/restart: atomic checkpoints every k steps; on ANY step
    failure the supervisor restores the latest checkpoint and resumes
    (data pipeline is stateless-resumable, so no loader state is needed)
  * failure injection: deterministic or callable fault hooks for tests
  * straggler mitigation: per-step wall-time EMA + z-score detector; slow
    steps are logged and counted (on a real cluster this feeds the
    scheduler's hot-spare replacement; here it drives metrics + tests)
  * elastic re-scale: checkpoints are mesh-agnostic -- `Trainer.remesh()`
    rebuilds state on a new (smaller/larger) mesh between runs
"""
from __future__ import annotations

import json
import math
import os
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import ArchConfig, ParallelConfig, TrainConfig
from repro.data import SyntheticLMData
from repro.models.common import use_mesh
from repro.runtime import steps as S


class SimulatedFailure(RuntimeError):
    pass


@dataclass
class StragglerMonitor:
    alpha: float = 0.2
    z_thresh: float = 3.0
    ema: float = 0.0
    var: float = 0.0
    n: int = 0
    events: List[dict] = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        slow = False
        if self.n >= 5:
            sd = math.sqrt(max(self.var, 1e-12))
            if dt > self.ema + self.z_thresh * sd and dt > 1.2 * self.ema:
                slow = True
                self.events.append({"step": step, "dt": dt, "ema": self.ema})
        d = dt - self.ema
        self.ema += self.alpha * d
        self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        self.n += 1
        return slow


@dataclass
class Trainer:
    cfg: ArchConfig
    pcfg: ParallelConfig
    tcfg: TrainConfig
    mesh: Optional[jax.sharding.Mesh]
    data: SyntheticLMData
    ckpt_dir: str
    fault_hook: Optional[Callable[[int], None]] = None
    log_path: Optional[str] = None

    def __post_init__(self):
        self.ckpt = CheckpointManager(self.ckpt_dir,
                                      keep=self.tcfg.keep_checkpoints)
        self.monitor = StragglerMonitor()
        self.restarts = 0
        self._jit_step = None
        self.metrics_log: List[dict] = []

    # ------------------------------------------------------------------ #
    def _build(self):
        with use_mesh(self.mesh):
            step_fn = S.make_train_step(self.cfg, self.pcfg, self.tcfg)
            self._jit_step = jax.jit(step_fn, donate_argnums=(0,))

    def _init_or_restore(self):
        with use_mesh(self.mesh):
            abstract = S.abstract_train_state(self.cfg, self.mesh)
            if self.ckpt.latest_step() is not None:
                state, at = self.ckpt.restore(abstract)
                return state, int(at)
            state = S.init_train_state(
                jax.random.PRNGKey(self.tcfg.seed), self.cfg)
            if self.mesh is not None:
                shardings = jax.tree.map(lambda a: a.sharding, abstract)
                state = jax.tree.map(jax.device_put, state, shardings)
            return state, 0

    def _put_batch(self, batch):
        if self.mesh is None:
            return {k: jax.numpy.asarray(v) for k, v in batch.items()}
        from jax.sharding import NamedSharding, PartitionSpec as P
        dp = tuple(a for a in ("pod", "data") if a in self.mesh.axis_names)
        out = {}
        for k, v in batch.items():
            spec = P(dp, *([None] * (v.ndim - 1)))
            out[k] = jax.device_put(v, NamedSharding(self.mesh, spec))
        return out

    # ------------------------------------------------------------------ #
    def run(self, steps: int) -> Dict[str, float]:
        """Run up to `steps` optimizer steps with automatic restart."""
        if self._jit_step is None:
            self._build()
        state, start = self._init_or_restore()
        step = start
        while step < steps:
            try:
                t0 = time.time()
                if self.fault_hook is not None:
                    self.fault_hook(step)
                batch = self._put_batch(self.data.batch(step))
                with use_mesh(self.mesh):
                    state, metrics = self._jit_step(state, batch)
                loss = float(metrics["loss"])
                if not np.isfinite(loss):
                    raise RuntimeError(f"non-finite loss at step {step}")
                dt = time.time() - t0
                slow = self.monitor.observe(step, dt)
                rec = {"step": step, "loss": loss, "dt": round(dt, 4),
                       "gnorm": float(metrics["gnorm"]),
                       "lr": float(metrics["lr"]), "straggler": slow}
                self.metrics_log.append(rec)
                if self.log_path:
                    with open(self.log_path, "a") as f:
                        f.write(json.dumps(rec) + "\n")
                step += 1
                if step % self.tcfg.checkpoint_every == 0 or step == steps:
                    self.ckpt.save(state, step)
            except SimulatedFailure:
                self.restarts += 1
                state, step = self._recover()
            except KeyboardInterrupt:
                self.ckpt.save(state, step)
                raise
        self.ckpt.wait()
        return {"final_step": step, "restarts": self.restarts,
                "final_loss": self.metrics_log[-1]["loss"]
                if self.metrics_log else float("nan"),
                "straggler_events": len(self.monitor.events)}

    def _recover(self):
        """Restore from the latest checkpoint (or re-init at step 0)."""
        with use_mesh(self.mesh):
            abstract = S.abstract_train_state(self.cfg, self.mesh)
            if self.ckpt.latest_step() is not None:
                state, at = self.ckpt.restore(abstract)
                return state, int(at)
        return self._init_or_restore()

    # ------------------------------------------------------------------ #
    def remesh(self, new_mesh) -> "Trainer":
        """Elastic re-scale: same checkpoints, new mesh (e.g. lost a pod)."""
        return Trainer(cfg=self.cfg, pcfg=self.pcfg, tcfg=self.tcfg,
                       mesh=new_mesh, data=self.data, ckpt_dir=self.ckpt_dir,
                       fault_hook=None, log_path=self.log_path)
