"""Full model: embeddings -> (encoder) -> period-scanned decoder stack ->
final norm -> LM head, with train / prefill / decode entry points and a
chunked cross-entropy loss (no B x S x V materialization).

Layers are grouped into the arch's repeating ``pattern`` period; the period
body is Python-unrolled (heterogeneous sub-layers), ``lax.scan`` runs over
periods with stacked params, remainder layers are unrolled at the tail.
"""
from __future__ import annotations

import contextlib
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (ArchConfig, ParallelConfig, BIDIR_ATTN)
from repro.models.blocks import (apply_layer, layer_schema, layer_cache_schema)
from repro.models.common import (ParamSchema, abstract_array, apply_norm,
                                 current_mesh, dense, norm_schema,
                                 scan_states_provider, shard, stack_schema,
                                 _sanitize_spec)

NEG_INF = -1e30


# --------------------------------------------------------------------------- #
# Schema
# --------------------------------------------------------------------------- #
def model_schema(cfg: ArchConfig) -> Dict[str, Any]:
    d, vp = cfg.d_model, cfg.padded_vocab
    cross = cfg.encoder_layers > 0
    s: Dict[str, Any] = {
        "embed": ParamSchema((vp, d), P("model", "data"), "embed", d ** -0.5),
        "final_norm": norm_schema(d, cfg.norm),
    }
    if not cfg.tie_embeddings:
        s["head"] = ParamSchema((d, vp), P("data", "model"), "normal", d ** -0.5)
    if cfg.frontend == "vision":
        s["proj"] = ParamSchema((d, d), P("data", "model"), "normal", d ** -0.5)

    scan: Dict[str, Any] = {}
    if cfg.num_periods > 0:
        for i, kind in enumerate(cfg.pattern):
            scan[f"p{i}"] = stack_schema(layer_schema(cfg, kind, cross=cross),
                                         cfg.num_periods)
    tail = {f"t{i}": layer_schema(cfg, kind, cross=cross)
            for i, kind in enumerate(cfg.tail_kinds)}
    s["decoder"] = {"scan": scan, "tail": tail}

    if cross:
        enc_scan = {"p0": stack_schema(layer_schema(cfg, BIDIR_ATTN),
                                       cfg.encoder_layers)}
        s["encoder"] = {"scan": enc_scan, "tail": {},
                        "final_norm": norm_schema(d, cfg.norm)}
    return s


def model_cache_schema(cfg: ArchConfig, batch: int, s_max: int, *,
                       seq_shard: bool = False, cross_len: int = 0,
                       dtype=None):
    """{scan: {p_i: stacked-layer cache schema}, tail: {...}} of
    (shape, dtype, PartitionSpec) leaves."""
    def stack_leaf(leaf, n):
        shape, dtype, spec = leaf
        return ((n,) + tuple(shape), dtype, P(None, *spec))

    def is_leaf(x):
        return isinstance(x, tuple) and len(x) == 3 and isinstance(x[0], tuple)

    scan = {}
    if cfg.num_periods > 0:
        for i, kind in enumerate(cfg.pattern):
            ls = layer_cache_schema(cfg, kind, batch, s_max,
                                    cross_len=cross_len, seq_shard=seq_shard,
                                    dtype=dtype)
            scan[f"p{i}"] = jax.tree.map(
                lambda l: stack_leaf(l, cfg.num_periods), ls, is_leaf=is_leaf)
    tail = {f"t{i}": layer_cache_schema(cfg, kind, batch, s_max,
                                        cross_len=cross_len,
                                        seq_shard=seq_shard, dtype=dtype)
            for i, kind in enumerate(cfg.tail_kinds)}
    return {"scan": scan, "tail": tail}


def _cache_is_leaf(x):
    return isinstance(x, tuple) and len(x) == 3 and isinstance(x[0], tuple)


def abstract_cache(cache_schema, mesh=None):
    return jax.tree.map(
        lambda l: abstract_array(l[0], l[1], l[2], mesh),
        cache_schema, is_leaf=_cache_is_leaf)


def zeros_cache(cache_schema):
    return jax.tree.map(lambda l: jnp.zeros(l[0], l[1]),
                        cache_schema, is_leaf=_cache_is_leaf)


# --------------------------------------------------------------------------- #
# Stack runner
# --------------------------------------------------------------------------- #
def _remat_wrap(fn, pcfg: ParallelConfig):
    if pcfg.remat == "none":
        return fn
    if pcfg.remat == "dots":
        pol = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)


def _run_stack(stack_params, x, *, cfg: ArchConfig, pcfg: ParallelConfig,
               pattern, tail_kinds, mode, caches, pos, positions, enc_out,
               scan_group: str = "dec"):
    """Runs scan-over-periods + unrolled tail. Returns (x, aux, new_caches).

    When a scan-states provider is installed (``models.common.
    use_scan_states``; a serving session threading per-site analog
    ``DeploymentState``s), the scanned periods cooperate with it: in
    record mode the period loop is Python-unrolled so every ``dense()``
    call site sees its CONCRETE per-period weight slice (call sites keyed
    ``"{scan_group}.{period}:{tag}#{ordinal}"``); in serve mode the
    provider's stacked per-period states ride the scan as xs, so each
    period's sites resolve against traced state slices and the whole
    stack stays ONE compiled step -- scanned models get the same
    zero-recompile state swaps as unrolled ones."""
    provider = scan_states_provider()

    def period_fn(x, aux, lp, lc, ls=None):
        # The scan carry is saved per period by remat: keep it SEQ-SHARDED
        # over the model axis so the stash is L/period x (B,S/tp,D) per
        # device (Megatron-SP-style); gather once per period for compute.
        ctx = (provider.scan_slice(scan_group, ls)
               if provider is not None and ls is not None
               else contextlib.nullcontext())
        with ctx:
            if not pcfg.residual_seq_shard:
                x = shard(x, "dp", None, None)
            ncs = {}
            for i, kind in enumerate(pattern):
                x, nc, a = apply_layer(
                    lp[f"p{i}"], x, cfg=cfg, pcfg=pcfg, kind=kind, mode=mode,
                    cache=None if lc is None else lc.get(f"p{i}"),
                    pos=pos, positions=positions, enc_out=enc_out)
                if nc is not None:
                    ncs[f"p{i}"] = nc
                aux = aux + a
            x = shard(x, "dp", "model", None)
        return x, aux, (ncs if ncs else None)

    period = _remat_wrap(period_fn, pcfg)
    aux = jnp.zeros((), jnp.float32)
    new_caches: Dict[str, Any] = {"scan": {}, "tail": {}}

    scan_params = stack_params["scan"]
    if scan_params:
        n = jax.tree.leaves(scan_params)[0].shape[0]
        if provider is not None and provider.recording:
            # call-site discovery: unroll the periods so dense() records
            # concrete weight slices under stable per-period site keys
            # (runs under eval_shape -- activations are abstract, the
            # closed-over params and their slices are concrete)
            ncs = []
            for p in range(n):
                # the params are concrete (closed over); slice them OUT of
                # the ambient trace so dense() records real arrays, not
                # tracers that would leak out of the eval_shape scope
                with jax.ensure_compile_time_eval():
                    lp = jax.tree.map(lambda v: v[p], scan_params)
                lc = (jax.tree.map(lambda v: v[p], caches["scan"])
                      if mode == "decode" else None)
                with provider.scan_record(scan_group, p):
                    x, aux, nc = period_fn(x, aux, lp, lc)
                ncs.append(nc)
            if mode in ("prefill", "decode") and ncs[0] is not None:
                new_caches["scan"] = jax.tree.map(
                    lambda *vs: jnp.stack(vs), *ncs)
        else:
            xs_states = (provider.scan_xs(scan_group, n)
                         if provider is not None else None)
            if mode == "decode":
                def body(carry, xs):
                    lp, lc, ls = xs
                    x, aux = carry
                    x, aux, nc = period(x, aux, lp, lc, ls)
                    return (x, aux), nc
                (x, aux), ys = jax.lax.scan(
                    body, (x, aux), (scan_params, caches["scan"], xs_states))
                new_caches["scan"] = ys
            elif mode == "prefill":
                def body(carry, xs):
                    lp, ls = xs
                    x, aux = carry
                    x, aux, nc = period(x, aux, lp, None, ls)
                    return (x, aux), nc
                (x, aux), ys = jax.lax.scan(body, (x, aux),
                                            (scan_params, xs_states))
                new_caches["scan"] = ys
            else:
                def body(carry, xs):
                    lp, ls = xs
                    x, aux = carry
                    x, aux, _ = period(x, aux, lp, None, ls)
                    return (x, aux), None
                (x, aux), _ = jax.lax.scan(body, (x, aux),
                                           (scan_params, xs_states))

    for i, kind in enumerate(tail_kinds):
        lc = None
        if mode == "decode":
            lc = caches["tail"].get(f"t{i}")
        x, nc, a = apply_layer(
            stack_params["tail"][f"t{i}"], x, cfg=cfg, pcfg=pcfg, kind=kind,
            mode=mode, cache=lc, pos=pos, positions=positions, enc_out=enc_out)
        aux = aux + a
        if nc is not None:
            new_caches["tail"][f"t{i}"] = nc

    return x, aux, new_caches


# --------------------------------------------------------------------------- #
# Forward passes
# --------------------------------------------------------------------------- #
def embed_tokens(params, tokens, cfg: ArchConfig, compute_dtype):
    x = jnp.take(params["embed"], tokens, axis=0).astype(compute_dtype)
    if cfg.emb_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, compute_dtype)
    return x


def encode(params, enc_frames, *, cfg: ArchConfig, pcfg: ParallelConfig):
    """Encoder over precomputed frontend frames (B, S_enc, D)."""
    x = shard(enc_frames, "dp", None, None)
    x, aux, _ = _run_stack(
        {"scan": params["encoder"]["scan"], "tail": {}}, x, cfg=cfg, pcfg=pcfg,
        pattern=(BIDIR_ATTN,), tail_kinds=(), mode="train", caches=None,
        pos=None, positions=None, enc_out=None, scan_group="enc")
    return apply_norm(params["encoder"]["final_norm"], x, cfg.norm), aux


def forward(params, tokens, *, cfg: ArchConfig, pcfg: ParallelConfig,
            mode: str = "train", cache=None, pos=None, image_embeds=None,
            enc_frames=None, compute_dtype=jnp.bfloat16):
    """Returns (hidden (B,S,D), new_cache_or_None, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    enc_out = None
    if cfg.encoder_layers:
        if mode == "decode":
            enc_out = None                      # decoder reads cross cache
        else:
            assert enc_frames is not None
            enc_out, aux_e = encode(params, enc_frames.astype(compute_dtype),
                                    cfg=cfg, pcfg=pcfg)
            aux = aux + aux_e

    x = embed_tokens(params, tokens, cfg, compute_dtype)
    if cfg.frontend == "vision" and image_embeds is not None:
        img = dense(image_embeds.astype(compute_dtype), params["proj"], "frontend.proj")
        n = img.shape[1]
        x = jnp.concatenate([img, x[:, n:]], axis=1)
    rs = "model" if (pcfg.residual_seq_shard and mode != "decode") else None
    x = shard(x, "dp", rs, None)

    if mode == "decode":
        positions = None
    else:
        positions = jnp.arange(tokens.shape[1])[None, :]

    x, aux_d, new_caches = _run_stack(
        params["decoder"], x, cfg=cfg, pcfg=pcfg, pattern=cfg.pattern,
        tail_kinds=cfg.tail_kinds, mode=mode, caches=cache, pos=pos,
        positions=positions, enc_out=enc_out)
    aux = aux + aux_d
    x = apply_norm(params["final_norm"], x, cfg.norm)
    return x, (new_caches if mode in ("prefill", "decode") else None), aux


# --------------------------------------------------------------------------- #
# Logits & loss
# --------------------------------------------------------------------------- #
def compute_logits(params, h, cfg: ArchConfig):
    """h: (B,S,D) -> logits (B,S,Vp) fp32, padded vocab masked."""
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", h,
                            params["embed"].astype(h.dtype))
    else:
        logits = dense(h, params["head"], "lm_head")
    logits = logits.astype(jnp.float32)
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = jnp.tanh(logits / c) * c
    if cfg.padded_vocab != cfg.vocab_size:
        mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(mask[None, None, :], NEG_INF, logits)
    return logits


def chunked_xent(params, h, targets, mask, *, cfg: ArchConfig,
                 chunk: int, z_coef: float = 0.0):
    """Mean xent over masked positions; logits live one seq-chunk at a time."""
    B, S, D = h.shape
    ck = min(chunk, S)
    if S % ck != 0:
        ck = S
    n = S // ck

    def chunk_fn(hc, tc, mc):
        # vocab-sharded logits: lse reduces over the sharded vocab dim (small
        # all-reduce) and the target gather lowers to mask+reduce -- both tiny
        hc = shard(hc, "dp", None, None)
        logits = compute_logits(params, hc, cfg)
        logits = shard(logits, "dp", None, "model")
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0] - lse
        zl = z_coef * jnp.square(lse) if z_coef else 0.0
        m = mc.astype(jnp.float32)
        return ((-ll + zl) * m).sum(), m.sum()

    chunk_fn = jax.checkpoint(chunk_fn)

    def body(carry, xs):
        ls, ms = carry
        l, m = chunk_fn(*xs)
        return (ls + l, ms + m), None

    hr = h.reshape(B, n, ck, D).swapaxes(0, 1)
    tr = targets.reshape(B, n, ck).swapaxes(0, 1)
    mr = mask.reshape(B, n, ck).swapaxes(0, 1)
    (loss_sum, denom), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hr, tr, mr))
    return loss_sum / jnp.maximum(denom, 1.0)


def lm_loss(params, batch, *, cfg: ArchConfig, pcfg: ParallelConfig,
            compute_dtype=jnp.bfloat16, z_coef: float = 1e-4):
    """batch: {tokens, targets, mask, [image_embeds], [enc_frames]}."""
    h, _, aux = forward(
        params, batch["tokens"], cfg=cfg, pcfg=pcfg, mode="train",
        image_embeds=batch.get("image_embeds"),
        enc_frames=batch.get("enc_frames"), compute_dtype=compute_dtype)
    loss = chunked_xent(params, h, batch["targets"], batch["mask"],
                        cfg=cfg, chunk=pcfg.xent_chunk, z_coef=z_coef)
    return loss + aux, {"xent": loss, "aux": aux}


# --------------------------------------------------------------------------- #
# Serving entry points
# --------------------------------------------------------------------------- #
def prefill(params, tokens, *, cfg: ArchConfig, pcfg: ParallelConfig,
            image_embeds=None, enc_frames=None, compute_dtype=jnp.bfloat16):
    """Returns (last-position logits (B,Vp), cache)."""
    h, cache, _ = forward(params, tokens, cfg=cfg, pcfg=pcfg, mode="prefill",
                          image_embeds=image_embeds, enc_frames=enc_frames,
                          compute_dtype=compute_dtype)
    logits = compute_logits(params, h[:, -1:], cfg)[:, 0]
    return logits, cache


def decode_step(params, token, cache, pos, *, cfg: ArchConfig,
                pcfg: ParallelConfig, compute_dtype=jnp.bfloat16):
    """token: (B,1) int32; pos: () int32 -- position being written -- or
    (B,) int32 for per-row positions (continuous batching: each request
    slot decodes at its own offset).  Returns (logits (B,Vp), new_cache)."""
    h, new_cache, _ = forward(params, token, cfg=cfg, pcfg=pcfg, mode="decode",
                              cache=cache, pos=pos, compute_dtype=compute_dtype)
    logits = compute_logits(params, h, cfg)[:, 0]
    return logits, new_cache
