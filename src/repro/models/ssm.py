"""State-space mixers: Mamba-1 selective scan and the RG-LRU (griffin)
recurrent block. Both reduce to the same *diagonal gated linear recurrence*

    h_t = a_t * h_{t-1} + b_t

evaluated by ``chunked_recurrence`` (sequential scan over chunks; parallel
associative scan within each chunk) so peak memory is O(B * chunk * D * N)
instead of O(B * S * D * N). A Pallas TPU kernel for the same recurrence
lives in repro.kernels.linear_scan.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ParallelConfig
from repro.models.common import ParamSchema, dense_schema, shard


# --------------------------------------------------------------------------- #
# Shared recurrence
# --------------------------------------------------------------------------- #
def _assoc_combine(x, y):
    a1, b1 = x
    a2, b2 = y
    return a2 * a1, a2 * b1 + b2


def chunked_recurrence(a, b, h0, chunk: int):
    """h_t = a_t * h_{t-1} + b_t along axis 1.

    a, b: (B, S, ...); h0: (B, ...). Returns (h (B,S,...), h_last (B,...)).
    """
    B, S = a.shape[:2]
    ck = min(chunk, S)
    if S % ck != 0:
        ck = S
    n = S // ck
    ar = a.reshape((B, n, ck) + a.shape[2:])
    br = b.reshape((B, n, ck) + b.shape[2:])

    def step(h, xs):
        ai, bi = xs                                   # (B, ck, ...)
        aa, bb = jax.lax.associative_scan(_assoc_combine, (ai, bi), axis=1)
        h_all = aa * h[:, None] + bb                  # (B, ck, ...)
        return h_all[:, -1], h_all

    h_last, hs = jax.lax.scan(
        step, h0, (ar.swapaxes(0, 1), br.swapaxes(0, 1)))
    hs = hs.swapaxes(0, 1).reshape((B, S) + a.shape[2:])
    return hs, h_last


# --------------------------------------------------------------------------- #
# Mamba-1 mixer
# --------------------------------------------------------------------------- #
def mamba_dims(cfg: ArchConfig) -> Tuple[int, int, int, int]:
    di = cfg.d_model * cfg.ssm.expand
    return di, cfg.ssm.d_state, cfg.ssm.d_conv, cfg.ssm.resolved_dt_rank(cfg.d_model)


def mamba_schema(cfg: ArchConfig):
    d = cfg.d_model
    di, n, k, dtr = mamba_dims(cfg)
    return {
        "in_proj": dense_schema(d, 2 * di),
        "conv_w": ParamSchema((k, di), P(None, "model"), "normal", k ** -0.5),
        "conv_b": ParamSchema((di,), P("model"), "zeros"),
        "x_proj": ParamSchema((di, dtr + 2 * n), P("model", None), "normal", di ** -0.5),
        "dt_proj": ParamSchema((dtr, di), P(None, "model"), "normal", dtr ** -0.5),
        "dt_bias": ParamSchema((di,), P("model"), "ones"),
        "A_log": ParamSchema((di, n), P("model", None), "ones"),
        "D": ParamSchema((di,), P("model"), "ones"),
        "out_proj": dense_schema(di, d, fsdp="model", tp="data"),
    }


def _causal_conv(x, w, b, state: Optional[jax.Array]):
    """Depthwise causal conv along seq. x: (B,S,Di); w: (K,Di).
    state: (B, K-1, Di) trailing inputs from the previous segment (or None).
    Returns (y (B,S,Di), new_state (B,K-1,Di))."""
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], K - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)     # (B, S+K-1, Di)
    y = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype) for i in range(K))
    new_state = xp[:, -(K - 1):]
    return y + b.astype(x.dtype), new_state


def mamba_mixer(params, x, *, cfg: ArchConfig, pcfg: ParallelConfig,
                cache=None, mode: str = "train"):
    """x: (B,S,D). cache: {"conv": (B,K-1,Di), "h": (B,Di,N)} for decode.
    Returns (y (B,S,D), new_cache_or_None)."""
    di, N, K, dtr = mamba_dims(cfg)
    B, S, D = x.shape
    if pcfg.residual_seq_shard and mode != "decode":
        x = shard(x, "dp", None, None)
    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(x.dtype))
    xz = shard(xz, "dp", None, "model")
    xin, z = jnp.split(xz, 2, axis=-1)

    conv_state = cache["conv"] if cache is not None else None
    xc, new_conv = _causal_conv(xin, params["conv_w"], params["conv_b"], conv_state)
    xc = jax.nn.silu(xc)

    dbc = jnp.einsum("bse,ef->bsf", xc, params["x_proj"].astype(xc.dtype))
    dt, Bm, Cm = jnp.split(dbc, [dtr, dtr + N], axis=-1)
    dt = jnp.einsum("bsr,re->bse", dt, params["dt_proj"].astype(dt.dtype))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))            # (Di, N)

    a = jnp.exp(dt[..., None] * A)                               # (B,S,Di,N) fp32
    b = (dt * xc.astype(jnp.float32))[..., None] * Bm.astype(jnp.float32)[:, :, None, :]

    h0 = cache["h"].astype(jnp.float32) if cache is not None else \
        jnp.zeros((B, di, N), jnp.float32)
    if mode == "decode" and S == 1:
        h = a[:, 0] * h0 + b[:, 0]                               # (B,Di,N)
        y = (h * Cm.astype(jnp.float32)[:, 0, None, :]).sum(-1)[:, None]
        h_last = h
    else:
        hs, h_last = chunked_recurrence(a, b, h0, pcfg.scan_chunk)
        y = (hs * Cm.astype(jnp.float32)[:, :, None, :]).sum(-1)  # (B,S,Di)
    y = y + params["D"].astype(jnp.float32) * xc.astype(jnp.float32)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(y.dtype))

    new_cache = None
    if mode in ("prefill", "decode"):
        conv_dt = cache["conv"].dtype if cache is not None else new_conv.dtype
        new_cache = {"conv": new_conv.astype(conv_dt),
                     "h": h_last.astype(jnp.float32)}
    return out, new_cache


def mamba_cache_schema(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16):
    di, N, K, _ = mamba_dims(cfg)
    return {
        "conv": ((batch, K - 1, di), dtype, P(("pod", "data"), None, "model")),
        "h": ((batch, di, N), jnp.float32, P(("pod", "data"), "model", None)),
    }


# --------------------------------------------------------------------------- #
# RG-LRU (griffin) recurrent block
# --------------------------------------------------------------------------- #
_RGLRU_C = 8.0


def rglru_schema(cfg: ArchConfig):
    d = cfg.d_model
    w = cfg.rglru.lru_width or d
    k = cfg.rglru.d_conv
    return {
        "in_x": dense_schema(d, w),
        "in_gate": dense_schema(d, w),
        "conv_w": ParamSchema((k, w), P(None, "model"), "normal", k ** -0.5),
        "conv_b": ParamSchema((w,), P("model"), "zeros"),
        "w_i": dense_schema(w, w),
        "b_i": ParamSchema((w,), P("model"), "zeros"),
        "w_r": dense_schema(w, w),
        "b_r": ParamSchema((w,), P("model"), "zeros"),
        "lam": ParamSchema((w,), P("model"), "ones"),
        "out": dense_schema(w, d, fsdp="model", tp="data"),
    }


def rglru_mixer(params, x, *, cfg: ArchConfig, pcfg: ParallelConfig,
                cache=None, mode: str = "train"):
    """Griffin recurrent block. cache: {"conv": (B,K-1,W), "h": (B,W)}."""
    B, S, D = x.shape
    if pcfg.residual_seq_shard and mode != "decode":
        x = shard(x, "dp", None, None)
    xb = jnp.einsum("bsd,dw->bsw", x, params["in_x"].astype(x.dtype))
    gate = jnp.einsum("bsd,dw->bsw", x, params["in_gate"].astype(x.dtype))
    xb = shard(xb, "dp", None, "model")

    conv_state = cache["conv"] if cache is not None else None
    xc, new_conv = _causal_conv(xb, params["conv_w"], params["conv_b"], conv_state)

    i_t = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", xc, params["w_i"].astype(xc.dtype))
                         + params["b_i"].astype(xc.dtype))
    r_t = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", xc, params["w_r"].astype(xc.dtype))
                         + params["b_r"].astype(xc.dtype))
    log_a = -_RGLRU_C * jax.nn.softplus(params["lam"].astype(jnp.float32)) \
        * r_t.astype(jnp.float32)
    a = jnp.exp(log_a)                                           # (B,S,W) fp32
    gated_x = (i_t * xc).astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated_x

    h0 = cache["h"].astype(jnp.float32) if cache is not None else \
        jnp.zeros((B, a.shape[-1]), jnp.float32)
    if mode == "decode" and S == 1:
        h_last = a[:, 0] * h0 + b[:, 0]
        hs = h_last[:, None]
    else:
        hs, h_last = chunked_recurrence(a, b, h0, pcfg.scan_chunk)

    y = hs.astype(x.dtype) * jax.nn.gelu(gate)
    out = jnp.einsum("bsw,wd->bsd", y, params["out"].astype(y.dtype))

    new_cache = None
    if mode in ("prefill", "decode"):
        conv_dt = cache["conv"].dtype if cache is not None else new_conv.dtype
        new_cache = {"conv": new_conv.astype(conv_dt),
                     "h": h_last.astype(jnp.float32)}
    return out, new_cache


def rglru_cache_schema(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16):
    w = cfg.rglru.lru_width or cfg.d_model
    k = cfg.rglru.d_conv
    return {
        "conv": ((batch, k - 1, w), dtype, P(("pod", "data"), None, "model")),
        "h": ((batch, w), jnp.float32, P(("pod", "data"), "model")),
    }
