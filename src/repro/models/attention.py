"""Attention: GQA projections + blockwise-softmax ("flash" in pure JAX)
variants. No S x S materialization anywhere.

Train/prefill use a FLAT-HEAD layout (B, S, Hq, D) with KV repeated to Hq
heads at compute time, so tensor parallelism can shard the head dim whenever
Hq divides the model axis (qwen 64H, command-r 96H, internvl 64H, phi 32H,
seamless 16H). When it doesn't (gemma3 4H, recurrentgemma 10H, llama4 40H,
deepseek 56H), attention falls back to *sequence* sharding of the query dim
over the model axis (context parallelism) with the (small, GQA) KV gathered.
The choice is automatic via divisibility; both are expressed as sharding
constraints, never shard_map, so XLA owns the collective schedule.

Decode keeps the grouped (B, S, Hkv, D) cache layout (no KV repeat in
memory) and can shard the cache *sequence* dim over the model axis with an
explicit shard_map flash-decode (partial-softmax combine).

Variants
  global  : causal, blockwise scan over KV blocks
  local   : exact sliding window via the 2-chunk trick
  chunked : llama4-style intra-chunk causal attention (1-chunk trick)
  bidir   : encoder self attention (no mask)
  cross   : encoder-decoder cross attention (no mask)
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import (ArchConfig, ParallelConfig, GLOBAL_ATTN,
                                LOCAL_ATTN, CHUNKED_ATTN, BIDIR_ATTN)
from repro.models.common import (ParamSchema, apply_norm, apply_rope,
                                 axis_size, current_mesh, dense, dense_schema,
                                 dp_axes, norm_schema, shard)

NEG_INF = -1e30


# --------------------------------------------------------------------------- #
# Schema
# --------------------------------------------------------------------------- #
def attention_schema(cfg: ArchConfig, *, cross: bool = False):
    d, qf = cfg.d_model, cfg.num_heads * cfg.head_dim
    kvf = cfg.num_kv_heads * cfg.head_dim
    s = {
        "wq": dense_schema(d, qf),
        "wk": dense_schema(d, kvf),
        "wv": dense_schema(d, kvf),
        "wo": dense_schema(qf, d, fsdp="model", tp="data"),
    }
    if cfg.qkv_bias and not cross:
        s["bq"] = ParamSchema((qf,), P("model"), "zeros")
        s["bk"] = ParamSchema((kvf,), P(None), "zeros")
        s["bv"] = ParamSchema((kvf,), P(None), "zeros")
    if cfg.qk_norm:
        s["qnorm"] = norm_schema(cfg.head_dim, "rmsnorm")
        s["knorm"] = norm_schema(cfg.head_dim, "rmsnorm")
    return s


def _head_tp(cfg: ArchConfig) -> bool:
    tp = axis_size("model")
    return cfg.num_heads % tp == 0


def _shard_flat(x, cfg, *trailing):
    """Shard (B, S, H, ...) on heads if divisible else on S."""
    if _head_tp(cfg):
        return shard(x, "dp", None, "model", *trailing)
    return shard(x, "dp", "model", None, *trailing)


# --------------------------------------------------------------------------- #
# Projections
# --------------------------------------------------------------------------- #
def _project_q(params, x, cfg: ArchConfig):
    """-> (B, S, Hq, D) flat heads."""
    B, S, _ = x.shape
    q = dense(x, params["wq"], "attn.q")
    if "bq" in params:
        q = q + params["bq"].astype(x.dtype)
    q = q.reshape(B, S, cfg.num_heads, cfg.head_dim)
    if "qnorm" in params:
        q = apply_norm(params["qnorm"], q, "rmsnorm")
    return q


def _project_kv(params, x, cfg: ArchConfig):
    """-> (B, S, Hkv, D) grouped."""
    B, S, _ = x.shape
    k = dense(x, params["wk"], "attn.k")
    v = dense(x, params["wv"], "attn.v")
    if "bk" in params:
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    k = k.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    if "knorm" in params:
        k = apply_norm(params["knorm"], k, "rmsnorm")
    return k, v


def _repeat_kv(k, cfg: ArchConfig):
    """(B,S,Hkv,D) -> (B,S,Hq,D). Under head sharding each device only
    materializes its own head slice of the broadcast."""
    g = cfg.num_heads // cfg.num_kv_heads
    if g == 1:
        return k
    B, S, Hkv, D = k.shape
    k = jnp.broadcast_to(k[:, :, :, None], (B, S, Hkv, g, D))
    return k.reshape(B, S, Hkv * g, D)


def _out_proj(params, o, cfg: ArchConfig):
    B, S = o.shape[:2]
    o = o.reshape(B, S, cfg.num_heads * cfg.head_dim)
    if not _head_tp(cfg) and S > 1:
        # seq-TP case: gather the (bf16) attention output over the model axis
        # once, so the out-projection contracts an unsharded dim (XLA would
        # otherwise emit a fp32 all-reduce of the residual stream).
        o = shard(o, "dp", None, None)
    return dense(o, params["wo"], "attn.o")


def _mixer_gather(x, pcfg, mode):
    if pcfg.residual_seq_shard and mode != "decode":
        return shard(x, "dp", None, None)
    return x


# --------------------------------------------------------------------------- #
# Blockwise softmax core (flat heads)
# --------------------------------------------------------------------------- #
def flash_attention(q, k, v, *, causal: bool, q_offset=0, k_offset=0,
                    block_kv: int = 1024, shard_hint=None,
                    window: int = 0, chunk: int = 0):
    """q: (B,Sq,H,D); k,v: (B,Sk,H,D) (already head-repeated).
    shard_hint: None | "heads" | "seq" -- where the model axis lives.
    window/chunk add sliding-window / same-chunk masking (dense fallback for
    shapes the exact windowed paths can't tile). Returns (B,Sq,H,D)."""
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    bk = min(block_kv, Sk)
    if Sk % bk != 0:                   # pad KV; padded keys are masked out
        pad = bk - Sk % bk
        k = jnp.concatenate([k, jnp.zeros((B, pad, H, D), k.dtype)], axis=1)
        v = jnp.concatenate([v, jnp.zeros((B, pad, H, D), v.dtype)], axis=1)
    kv_len = Sk
    Sk = k.shape[1]
    nb = Sk // bk
    q = q * (D ** -0.5)
    q_pos = q_offset + jnp.arange(Sq)

    def c_spec(*tail):  # carry spec for (B, H, Sq, *tail)
        if shard_hint == "heads":
            return ("dp", "model", None) + tail
        if shard_hint == "seq":
            return ("dp", None, "model") + tail
        return ("dp", None, None) + tail

    kr = k.reshape(B, nb, bk, H, D).transpose(1, 0, 2, 3, 4)
    vr = v.reshape(B, nb, bk, H, D).transpose(1, 0, 2, 3, 4)
    blk_start = k_offset + jnp.arange(nb) * bk

    init = (shard(jnp.full((B, H, Sq), NEG_INF, jnp.float32), *c_spec()),
            shard(jnp.zeros((B, H, Sq), jnp.float32), *c_spec()),
            shard(jnp.zeros((B, H, Sq, D), jnp.float32), *c_spec(None)))

    def body(carry, xs):
        kb, vb, start = xs
        m, l, o = carry
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kb).astype(jnp.float32)
        k_pos = start + jnp.arange(bk)
        if causal:
            mask = k_pos[None, :] <= q_pos[:, None]
            if window:
                mask &= (q_pos[:, None] - k_pos[None, :]) < window
            if chunk:
                mask &= (q_pos[:, None] // chunk) == (k_pos[None, :] // chunk)
            s = jnp.where(mask[None, None], s, NEG_INF)
        elif kv_len != Sk:             # mask padded keys in the bidir case
            mask = (k_pos < k_offset + kv_len)[None, None, None]
            s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bhqk,bkhd->bhqd", p.astype(vb.dtype), vb)
        o_new = o * alpha[..., None] + pv.astype(jnp.float32)
        return (m_new, l_new, o_new), None

    # remat the per-block body: backward recomputes one score block at a
    # time instead of stashing the full (B,H,Sq,Sk) score tensor
    body = jax.checkpoint(body)
    (m, l, o), _ = jax.lax.scan(body, init, (kr, vr, blk_start))
    o = o / jnp.maximum(l, 1e-20)[..., None]
    return jnp.transpose(o, (0, 2, 1, 3)).astype(v.dtype)   # (B,Sq,H,D)


def _grouped_windowed(q, k, v, w: int, *, sliding: bool):
    """Shared core for local (sliding=True) and llama4-chunked (False)
    attention over (B,S,H,D) inputs, reshaped to window chunks.

    Model-axis sharding, by divisibility:
      H % tp == 0        -> 5D (B,n,H,w,D) sharded on heads
      (n*H) % tp == 0    -> 4D (B,G=n*H,w,D) sharded on the merged group dim
      else               -> replicated over the model axis
    """
    B, S, H, D = q.shape
    n = S // w
    G = n * H
    tp = axis_size("model")

    def to5(x):  # (B,S,H,D) -> (B,n,H,w,D)
        return x.reshape(B, n, w, H, D).transpose(0, 1, 3, 2, 4)

    q5, k5, v5 = to5(q), to5(k), to5(v)
    if sliding:
        kp = jnp.concatenate([jnp.zeros_like(k5[:, :1]), k5[:, :-1]], axis=1)
        vp = jnp.concatenate([jnp.zeros_like(v5[:, :1]), v5[:, :-1]], axis=1)
        k5 = jnp.concatenate([kp, k5], axis=3)        # (B,n,H,2w,D)
        v5 = jnp.concatenate([vp, v5], axis=3)
    wk = k5.shape[3]

    k_pos = jnp.arange(wk)[None, :]
    if sliding:
        q_pos = jnp.arange(w)[:, None] + w            # within the 2w frame
        valid = (k_pos <= q_pos) & (q_pos - k_pos < w)       # (w, 2w)
        nz = jnp.arange(n) > 0                               # chunk 0: no prev
        mask_n = valid[None] & (nz[:, None, None] | (k_pos >= w)[None])  # (n,w,wk)
    else:
        q_pos = jnp.arange(w)[:, None]
        mask_n = jnp.broadcast_to((k_pos <= q_pos)[None], (n, w, wk))

    if H % tp == 0:
        spec = ("dp", None, "model", None, None)
        q5 = shard(q5, *spec)
        k5 = shard(k5, *spec)
        v5 = shard(v5, *spec)
        s = jnp.einsum("bnhqd,bnhkd->bnhqk", q5 * (D ** -0.5), k5).astype(jnp.float32)
        s = jnp.where(mask_n[None, :, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o5 = jnp.einsum("bnhqk,bnhkd->bnhqd", p.astype(v5.dtype), v5)
        o5 = shard(o5, *spec)
    elif G % tp == 0:
        gspec = ("dp", "model", None, None)
        qg = shard(q5.reshape(B, G, w, D), *gspec)
        kg = shard(k5.reshape(B, G, wk, D), *gspec)
        vg = shard(v5.reshape(B, G, wk, D), *gspec)
        mask_g = jnp.repeat(mask_n, H, axis=0)        # (G,w,wk) n-major like G
        s = jnp.einsum("bgqd,bgkd->bgqk", qg * (D ** -0.5), kg).astype(jnp.float32)
        s = jnp.where(mask_g[None], s, NEG_INF)
        s = shard(s, *gspec)
        p = jax.nn.softmax(s, axis=-1)
        og = jnp.einsum("bgqk,bgkd->bgqd", p.astype(vg.dtype), vg)
        o5 = shard(og, *gspec).reshape(B, n, H, w, D)
    else:
        s = jnp.einsum("bnhqd,bnhkd->bnhqk", q5 * (D ** -0.5), k5).astype(jnp.float32)
        s = jnp.where(mask_n[None, :, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o5 = jnp.einsum("bnhqk,bnhkd->bnhqd", p.astype(v5.dtype), v5)

    return o5.transpose(0, 1, 3, 2, 4).reshape(B, S, H, D)


def triangular_attention(q, k, v, *, block_q: int = 1024,
                         block_kv: int = 1024, shard_hint=None):
    """Exact causal attention with a Python-unrolled q-block loop so each q
    block only scans its KV prefix -- no masked-out FLOPs beyond the
    diagonal block (the compute-optimal global-attention path; §Perf)."""
    B, Sq, H, D = q.shape
    bq = min(block_q, Sq)
    assert Sq % bq == 0 and q.shape[1] == k.shape[1]
    outs = []
    for i in range(Sq // bq):
        qi = q[:, i * bq:(i + 1) * bq]
        hi = (i + 1) * bq
        outs.append(flash_attention(
            qi, k[:, :hi], v[:, :hi], causal=True, q_offset=i * bq,
            block_kv=min(block_kv, hi), shard_hint=shard_hint))
    return jnp.concatenate(outs, axis=1)


def local_attention(q, k, v, window: int):
    """Exact sliding-window causal attention via the 2-chunk trick.
    q/k/v: (B,S,H,D) flat heads; requires S % window == 0 (else fallback)."""
    S = q.shape[1]
    if window >= S or S % window != 0:
        return flash_attention(q, k, v, causal=True, block_kv=min(1024, S),
                               window=window if window < S else 0)
    return _grouped_windowed(q, k, v, window, sliding=True)


def chunked_attention(q, k, v, chunk: int):
    """llama4-style: causal attention restricted to the query's own chunk."""
    S = q.shape[1]
    if chunk >= S or S % chunk != 0:
        return flash_attention(q, k, v, causal=True, block_kv=min(1024, S),
                               chunk=chunk if chunk < S else 0)
    return _grouped_windowed(q, k, v, chunk, sliding=False)


# --------------------------------------------------------------------------- #
# Decode (single step against a grouped cache)
# --------------------------------------------------------------------------- #
def decode_attention(q, ck, cv, valid_mask, cfg: ArchConfig):
    """q: (B,1,Hq,D) flat; ck/cv: (B,S,Hkv,D); valid_mask: (B,S) or (S,)."""
    B = q.shape[0]
    D = q.shape[-1]
    g = cfg.num_heads // cfg.num_kv_heads
    qg = q.reshape(B, 1, cfg.num_kv_heads, g, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg * (D ** -0.5), ck).astype(jnp.float32)
    if valid_mask.ndim == 1:
        valid_mask = valid_mask[None]
    s = jnp.where(valid_mask[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(cv.dtype), cv)
    return jnp.transpose(o, (0, 3, 1, 2, 4)).reshape(B, 1, cfg.num_heads, D)


def sharded_flash_decode(q, ck, cv, pos, cfg: ArchConfig, *, tp_axis="model"):
    """Flash-decode with the cache sequence dim sharded over the TP axis.

    Each shard computes a partial softmax over its sequence slice; partials
    are merged with the (max, sum) trick via pmax/psum. q is replicated over
    the TP axis; ck/cv are P(dp, tp) on (batch, seq)."""
    mesh = current_mesh()
    if mesh is None or tp_axis not in mesh.axis_names:
        S = ck.shape[1]
        return decode_attention(q, ck, cv, jnp.arange(S) <= pos, cfg)
    B, _, Hq, D = q.shape
    # batch too small to shard over the data axes -> replicate batch
    dp = dp_axes()
    if B % max(1, axis_size(dp)) != 0:
        dp = ()
    n_shards = dict(zip(mesh.axis_names, mesh.devices.shape))[tp_axis]
    S_local = ck.shape[1] // n_shards
    g = cfg.num_heads // cfg.num_kv_heads

    def f(q, ck, cv, pos):
        off = jax.lax.axis_index(tp_axis) * S_local
        qg = q.reshape(q.shape[0], 1, cfg.num_kv_heads, g, D)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg * (D ** -0.5), ck).astype(jnp.float32)
        valid = (jnp.arange(S_local) + off) <= pos
        s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
        m = s.max(axis=-1)
        p = jnp.exp(s - m[..., None])
        l = p.sum(axis=-1)
        o = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(cv.dtype), cv).astype(jnp.float32)
        M = jax.lax.pmax(m, tp_axis)
        corr = jnp.exp(m - M)
        L = jax.lax.psum(l * corr, tp_axis)
        O = jax.lax.psum(o * corr[..., None], tp_axis)
        out = O / jnp.maximum(L, 1e-20)[..., None]
        out = jnp.transpose(out, (0, 3, 1, 2, 4))
        return out.reshape(out.shape[0], 1, cfg.num_heads, D).astype(cv.dtype)

    from repro.parallel.collectives import shard_map_compat
    return shard_map_compat(
        f, mesh,
        (P(dp), P(dp, tp_axis), P(dp, tp_axis), P()),
        P(dp),
    )(q, ck, cv, pos)


# --------------------------------------------------------------------------- #
# Full mixer (pre-normed input -> attn output), train/prefill/decode
# --------------------------------------------------------------------------- #
def rope_base_for(cfg: ArchConfig, kind: str) -> float:
    if kind == GLOBAL_ATTN and cfg.rope_base_global:
        return cfg.rope_base_global
    return cfg.rope_base


def attn_mixer(params, x, *, cfg: ArchConfig, pcfg: ParallelConfig, kind: str,
               positions=None, cache=None, pos=None, enc_kv=None,
               mode: str = "train"):
    """Returns (out (B,S,D), new_cache_or_None). Cache layout:
      global : {"k","v"}: (B, S_max, Hkv, Dh), abs position p at slot p
      local/chunked : ring buffer (B, W, Hkv, Dh), slot = p mod W
      cross  : read-only {"k","v"} precomputed from encoder output
    """
    B, S, _ = x.shape
    base = rope_base_for(cfg, kind)
    if pcfg.residual_seq_shard and mode != "decode":
        x = shard(x, "dp", None, None)        # gather SP residual for QKV
    q = _project_q(params, x, cfg)

    if kind == "cross":
        k, v = enc_kv
        q = _shard_flat(q, cfg, None)
        o = flash_attention(q, _repeat_kv(k.astype(q.dtype), cfg),
                            _repeat_kv(v.astype(q.dtype), cfg), causal=False,
                            block_kv=min(pcfg.attn_block_kv, k.shape[1]),
                            shard_hint="heads" if _head_tp(cfg) else "seq")
        return _out_proj(params, o, cfg), None

    if mode == "decode":
        # pos is either a scalar (all rows at the same position -- single
        # session) or a (B,) vector of per-slot positions (continuous
        # batching: each request slot decodes at its own offset).
        vec = getattr(pos, "ndim", 0) == 1
        p2 = pos[:, None] if vec else pos + jnp.zeros((B, 1), jnp.int32)
        q = apply_rope(q, p2, base)
        k, v = _project_kv(params, x, cfg)
        k = apply_rope(k, p2, base)
        if kind == GLOBAL_ATTN:
            S_max = cache["k"].shape[1]
            if vec:
                rows = jnp.arange(B)
                ck = cache["k"].at[rows, pos % S_max].set(
                    k[:, 0].astype(cache["k"].dtype))
                cv = cache["v"].at[rows, pos % S_max].set(
                    v[:, 0].astype(cache["v"].dtype))
                o = decode_attention(
                    q, ck, cv, jnp.arange(S_max)[None, :] <= pos[:, None], cfg)
            else:
                ck = jax.lax.dynamic_update_slice(
                    cache["k"], k.astype(cache["k"].dtype), (0, pos % S_max, 0, 0))
                cv = jax.lax.dynamic_update_slice(
                    cache["v"], v.astype(cache["v"].dtype), (0, pos % S_max, 0, 0))
                if pcfg.decode_seq_shard:
                    o = sharded_flash_decode(q, ck, cv, pos, cfg,
                                             tp_axis=pcfg.tp_axis)
                else:
                    o = decode_attention(q, ck, cv, jnp.arange(S_max) <= pos, cfg)
        else:  # local / chunked ring buffer
            W = cache["k"].shape[1]
            slot = pos % W
            if vec:
                rows = jnp.arange(B)
                ck = cache["k"].at[rows, slot].set(k[:, 0].astype(cache["k"].dtype))
                cv = cache["v"].at[rows, slot].set(v[:, 0].astype(cache["v"].dtype))
                idx = jnp.arange(W)[None, :]
                slot_b, pos_b = slot[:, None], pos[:, None]
            else:
                ck = jax.lax.dynamic_update_slice(
                    cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
                cv = jax.lax.dynamic_update_slice(
                    cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
                idx = jnp.arange(W)
                slot_b, pos_b = slot, pos
            abs_pos = pos_b - ((slot_b - idx) % W)    # position held in slot i
            if kind == LOCAL_ATTN:
                valid = (abs_pos >= 0) & (abs_pos > pos_b - W) & (abs_pos <= pos_b)
            else:  # chunked: same chunk as pos
                valid = (abs_pos >= 0) & (abs_pos // W == pos_b // W) \
                    & (abs_pos <= pos_b)
            o = decode_attention(q, ck, cv, valid, cfg)
        return _out_proj(params, o, cfg), {"k": ck, "v": cv}

    # train / prefill
    if positions is None:
        positions = jnp.arange(S)[None, :]
    head_tp = _head_tp(cfg)
    windowed = kind in (LOCAL_ATTN, CHUNKED_ATTN) and cfg.window < S \
        and S % cfg.window == 0

    # Pin shardings BEFORE rope so its fp32 internals never cross shards.
    if not windowed:
        q = _shard_flat(q, cfg, None)
    elif head_tp:
        q = shard(q, "dp", None, "model", None)
    q = apply_rope(q, positions, base)
    k, v = _project_kv(params, x, cfg)
    if not windowed or head_tp:
        if head_tp and cfg.num_kv_heads % axis_size("model") == 0:
            k = shard(k, "dp", None, "model", None)
            v = shard(v, "dp", None, "model", None)
        else:
            # KV is small under GQA: gather it (replicate over model) so
            # scores never contract a sharded head_dim.
            k = shard(k, "dp", None, None, None)
            v = shard(v, "dp", None, None, None)
    k = apply_rope(k, positions, base)
    kf, vf = _repeat_kv(k, cfg), _repeat_kv(v, cfg)
    if head_tp:
        kf = shard(kf, "dp", None, "model", None)
        vf = shard(vf, "dp", None, "model", None)
    hint = "heads" if head_tp else "seq"

    if kind == LOCAL_ATTN:
        o = local_attention(q, kf, vf, cfg.window)
    elif kind == CHUNKED_ATTN:
        o = chunked_attention(q, kf, vf, cfg.window)
    elif kind == BIDIR_ATTN:
        o = flash_attention(q, kf, vf, causal=False,
                            block_kv=min(pcfg.attn_block_kv, S), shard_hint=hint)
    else:
        o = flash_attention(q, kf, vf, causal=True,
                            block_kv=min(pcfg.attn_block_kv, S), shard_hint=hint)

    new_cache = None
    if mode == "prefill":
        # caches keep the compute dtype; serving casts to the serving cache
        # dtype (bf16) when splicing into the generation cache
        if kind in (GLOBAL_ATTN, BIDIR_ATTN):
            new_cache = {"k": k, "v": v}
        else:
            W = min(cfg.window, S)
            new_cache = {"k": k[:, -W:], "v": v[:, -W:]}
    return _out_proj(params, o, cfg), new_cache


def attn_cache_schema(cfg: ArchConfig, kind: str, batch: int, s_max: int,
                      dtype=jnp.bfloat16, *, seq_shard: bool = False):
    """Abstract cache spec for one attention layer (used by launch/serve)."""
    if kind == GLOBAL_ATTN:
        size = s_max
        seq_axis = "model" if seq_shard else None
    else:
        size = min(cfg.window, s_max)
        seq_axis = None
    shape = (batch, size, cfg.num_kv_heads, cfg.head_dim)
    spec = P(("pod", "data"), seq_axis, None, None)
    return {"k": (shape, dtype, spec), "v": (shape, dtype, spec)}
