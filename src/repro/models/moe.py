"""Mixture-of-Experts FFN with capacity-based scatter dispatch and expert
parallelism (experts sharded over the `model` axis; token buffers routed by
GSPMD-inserted all-to-alls).

Dispatch is the GShard/Switch capacity scheme implemented with scatter/gather
instead of the O(T*E*C) one-hot einsum (which would not fit memory at
T = 1M tokens):
  pos_in_expert = cumsum(onehot(assign)) - 1
  keep          = pos < capacity
  buffer[e, pos] += x_t          (scatter-add over unique slots)
  y_t            = sum_k gate_k * buffer[e_k, pos_k]
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, MoEConfig, ParallelConfig
from repro.models.common import ParamSchema, activation, dense_schema, shard


def moe_schema(cfg: ArchConfig):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe.num_experts
    s = {
        "router": ParamSchema((d, e), P(None, None), "normal", d ** -0.5),
        "w_up": ParamSchema((e, d, f), P("model", "data", None), "normal", d ** -0.5),
        "w_down": ParamSchema((e, f, d), P("model", None, "data"), "normal", f ** -0.5),
    }
    if cfg.mlp_gated:
        s["w_gate"] = ParamSchema((e, d, f), P("model", "data", None), "normal", d ** -0.5)
    if cfg.moe.shared_expert:
        s["shared_up"] = dense_schema(d, f)
        s["shared_down"] = dense_schema(f, d, fsdp="model", tp="data")
        if cfg.mlp_gated:
            s["shared_gate"] = dense_schema(d, f)
    return s


def _capacity(n_tokens: int, mcfg: MoEConfig, train: bool) -> int:
    cf = mcfg.capacity_factor if train else mcfg.eval_capacity_factor
    c = int(n_tokens * mcfg.top_k * cf / mcfg.num_experts)
    return max(4, -(-c // 4) * 4)


def moe_mixer(params, x, *, cfg: ArchConfig, pcfg: ParallelConfig,
              train: bool = True) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D). Returns (y (B,S,D), aux_loss scalar fp32)."""
    mcfg = cfg.moe
    if pcfg.residual_seq_shard:
        x = shard(x, "dp", None, None)
    B, S, D = x.shape
    T = B * S
    E, K = mcfg.num_experts, mcfg.top_k
    C = _capacity(T, mcfg, train)
    act = activation(cfg.mlp_act)

    xt = x.reshape(T, D)
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                       # (T, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)               # (T, K)
    if K > 1:
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9)

    # position of each assignment within its expert (global order); an
    # explicit log-depth associative scan -- jnp.cumsum lowers to an O(n^2)
    # reduce-window on some backends (confirmed via the HLO cost model)
    assign_oh = jax.nn.one_hot(expert_idx, E, dtype=jnp.int32)    # (T, K, E)
    flat_oh = assign_oh.reshape(T * K, E)
    csum = jax.lax.associative_scan(jnp.add, flat_oh, axis=0)     # inclusive
    pos = csum - flat_oh                                          # (T*K, E)
    pos = (pos.reshape(T, K, E) * assign_oh).sum(-1)              # (T, K)
    keep = pos < C

    # dropped assignments write (masked-to-zero) into the last slot, so the
    # buffer stays exactly (E*C, D) and shards cleanly over the expert axis
    slot = jnp.where(keep, expert_idx * C + pos, E * C - 1)
    slot = shard(slot.reshape(T * K), "dp")
    xk = jnp.broadcast_to(xt[:, None], (T, K, D)).reshape(T * K, D)
    xk = shard(xk * keep.reshape(-1, 1).astype(xt.dtype), "dp", None)
    buf = jnp.zeros((E * C, D), xt.dtype).at[slot].add(xk)
    buf = shard(buf.reshape(E, C, D), "model", None, None)

    up = jnp.einsum("ecd,edf->ecf", buf, params["w_up"].astype(buf.dtype))
    up = shard(up, "model", None, None)
    if cfg.mlp_gated:
        g = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"].astype(buf.dtype))
        h = act(shard(g, "model", None, None)) * up
    else:
        h = act(up)
    yb = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(h.dtype))
    yb = shard(yb, "model", None, None)

    yk = yb.reshape(E * C, D)[slot].reshape(T, K, D)
    y = (yk * (gate_vals * keep).astype(yk.dtype)[..., None]).sum(axis=1)
    y = shard(y, "dp", None)

    if mcfg.shared_expert:
        up_s = jnp.einsum("td,df->tf", xt, params["shared_up"].astype(xt.dtype))
        if cfg.mlp_gated:
            g_s = jnp.einsum("td,df->tf", xt, params["shared_gate"].astype(xt.dtype))
            h_s = act(g_s) * up_s
        else:
            h_s = act(up_s)
        y = y + jnp.einsum("tf,fd->td", h_s, params["shared_down"].astype(h_s.dtype))

    # Switch-style load-balance aux loss
    top1 = jax.nn.one_hot(expert_idx[:, 0], E, dtype=jnp.float32)
    frac_tokens = top1.mean(axis=0)
    frac_probs = probs.mean(axis=0)
    aux = E * jnp.sum(frac_tokens * frac_probs) * mcfg.router_aux_coef

    return y.reshape(B, S, D), aux
