"""Shared model infrastructure: parameter schemas (one source of truth for
shapes / shardings / init), mesh context, norms, activations, RoPE.

No flax: a module is (schema builder, pure apply fn). From a schema we derive
  * real params        (tests, small-scale training)
  * ShapeDtypeStructs  (dry-run lowering -- nothing allocated)
  * PartitionSpec tree (in_shardings / sharding constraints)
"""
from __future__ import annotations

import contextlib
import threading
import zlib
from typing import Any, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# --------------------------------------------------------------------------- #
# Mesh context
# --------------------------------------------------------------------------- #
class _MeshState(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None


_STATE = _MeshState()


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    prev = _STATE.mesh
    _STATE.mesh = mesh
    try:
        yield mesh
    finally:
        _STATE.mesh = prev


def current_mesh() -> Optional[Mesh]:
    return _STATE.mesh


def dp_axes() -> Tuple[str, ...]:
    """Axes the global batch is sharded over."""
    mesh = current_mesh()
    if mesh is None:
        return ()
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def axis_size(name) -> int:
    mesh = current_mesh()
    if mesh is None:
        return 1
    if isinstance(name, (tuple, list)):
        n = 1
        for a in name:
            n *= axis_size(a)
        return n
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def _sanitize_spec(shape: Tuple[int, ...], spec: P) -> P:
    """Drop spec axes that are absent from the mesh or don't divide the dim."""
    mesh = current_mesh()
    present = set(mesh.axis_names) if mesh is not None else set()

    def keep_axes(ax):
        if ax is None:
            return None
        axes = ax if isinstance(ax, (tuple, list)) else (ax,)
        axes = tuple(a for a in axes if a in present)
        if not axes:
            return None
        return axes if len(axes) > 1 else axes[0]

    entries = [keep_axes(a) for a in spec] + [None] * (len(shape) - len(spec))
    out = []
    for dim, ax in zip(shape, entries):
        if ax is None or axis_size(ax) <= 1 or dim % axis_size(ax) != 0:
            out.append(None)
        else:
            out.append(ax)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def shard(x: jax.Array, *spec_entries) -> jax.Array:
    """with_sharding_constraint against the context mesh (no-op without one).

    Entries may be None, an axis name, or a tuple of axis names. The special
    string "dp" expands to the batch axes of the current mesh.
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    entries = tuple(dp_axes() if e == "dp" else e for e in spec_entries)
    spec = _sanitize_spec(x.shape, P(*entries))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# --------------------------------------------------------------------------- #
# Parameter schemas
# --------------------------------------------------------------------------- #
class ParamSchema(NamedTuple):
    shape: Tuple[int, ...]
    spec: P
    init: str = "normal"        # normal | zeros | ones | embed
    scale: float = 1.0          # stddev for "normal"
    dtype: Any = jnp.float32


def dense_schema(d_in: int, d_out: int, *, fsdp="data", tp="model",
                 scale: Optional[float] = None) -> ParamSchema:
    """2-D (FSDP x TP) sharded projection weight."""
    s = scale if scale is not None else d_in ** -0.5
    return ParamSchema((d_in, d_out), P(fsdp, tp), "normal", s)


def is_schema_leaf(x) -> bool:
    return isinstance(x, ParamSchema)


def _tree_map(fn, schema):
    return jax.tree.map(fn, schema, is_leaf=is_schema_leaf)


def stack_schema(schema, n: int):
    """Add a leading stacked-layers dim of size n to every leaf."""
    def f(p: ParamSchema) -> ParamSchema:
        return ParamSchema((n,) + p.shape, P(None, *p.spec), p.init, p.scale, p.dtype)
    return _tree_map(f, schema)


def init_params(key: jax.Array, schema, dtype=jnp.float32):
    """Materialize real parameters (path-deterministic key folding)."""
    leaves, treedef = jax.tree_util.tree_flatten_with_path(
        schema, is_leaf=is_schema_leaf)

    def init_one(path, p: ParamSchema):
        # crc32, NOT hash(): str hashing is salted per interpreter run,
        # which made every process draw DIFFERENT params for the same
        # seed and broke cross-process round trips (--state-save/-load)
        k = jax.random.fold_in(key, zlib.crc32(
            jax.tree_util.keystr(path).encode()) & 0x7FFFFFFF)
        dt = p.dtype if p.dtype != jnp.float32 else dtype
        if p.init == "zeros":
            return jnp.zeros(p.shape, dt)
        if p.init == "ones":
            return jnp.ones(p.shape, dt)
        if p.init == "embed":
            return (jax.random.normal(k, p.shape, jnp.float32) * p.scale).astype(dt)
        return (jax.random.normal(k, p.shape, jnp.float32) * p.scale).astype(dt)

    vals = [init_one(path, p) for path, p in leaves]
    return jax.tree.unflatten(treedef, vals)


def spec_tree(schema):
    return _tree_map(lambda p: p.spec, schema)


def abstract_params(schema, mesh: Optional[Mesh] = None, dtype=jnp.float32):
    """ShapeDtypeStructs (+ NamedShardings) -- for AOT lowering."""
    def f(p: ParamSchema):
        dt = p.dtype if p.dtype != jnp.float32 else dtype
        if mesh is None:
            return jax.ShapeDtypeStruct(p.shape, dt)
        spec = _sanitize_spec(p.shape, p.spec)
        return jax.ShapeDtypeStruct(p.shape, dt, sharding=NamedSharding(mesh, spec))
    return _tree_map(f, schema)


def sharding_tree(schema, mesh: Mesh):
    def f(p: ParamSchema):
        return NamedSharding(mesh, _sanitize_spec(p.shape, p.spec))
    return _tree_map(f, schema)


def param_count(schema) -> int:
    leaves = jax.tree.leaves(schema, is_leaf=is_schema_leaf)
    return int(sum(int(np.prod(p.shape)) for p in leaves))


# --------------------------------------------------------------------------- #
# Abstract arrays helper (activations / caches)
# --------------------------------------------------------------------------- #
def abstract_array(shape, dtype, spec: P, mesh: Optional[Mesh]):
    if mesh is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jax.ShapeDtypeStruct(
        shape, dtype, sharding=NamedSharding(mesh, _sanitize_spec(tuple(shape), spec)))


# --------------------------------------------------------------------------- #
# Dense hook: routes matmuls through an alternative executor (the SEMULATOR
# analog backend installs itself here; default is a plain einsum).
# --------------------------------------------------------------------------- #
class _HookState(threading.local):
    def __init__(self):
        self.fn = None


_HOOK = _HookState()


@contextlib.contextmanager
def use_dense_hook(fn):
    prev = _HOOK.fn
    _HOOK.fn = fn
    try:
        yield
    finally:
        _HOOK.fn = prev


def dense(x: jax.Array, w: jax.Array, tag: str = "") -> jax.Array:
    """y = x @ w over the last dim of x; interceptable by the analog backend."""
    if _HOOK.fn is not None:
        out = _HOOK.fn(x, w, tag)
        if out is not None:
            return out
    return jnp.einsum("...k,kf->...f", x, w.astype(x.dtype))


# --------------------------------------------------------------------------- #
# Scan-states channel: lets the model's lax.scan over layer periods thread
# per-period DeploymentStates as scan xs.  The provider (an analog
# _StateBinding) exposes:
#   recording          -- True while discovering call sites (period loop is
#                         Python-unrolled so dense() sees concrete weights)
#   scan_record(g, p)  -- context: record period p of scan group g
#   scan_xs(g, n)      -- stacked per-period state pytree (leading axis n)
#                         to feed lax.scan as xs, or None when group g has
#                         no bound states
#   scan_slice(g, ls)  -- context: serve the scan body's current period
#                         from the traced per-period slice ls
# The model never imports the analog layer; it only calls this protocol.
# --------------------------------------------------------------------------- #
class _ScanStatesState(threading.local):
    def __init__(self):
        self.provider = None


_SCAN_STATES = _ScanStatesState()


@contextlib.contextmanager
def use_scan_states(provider):
    prev = _SCAN_STATES.provider
    _SCAN_STATES.provider = provider
    try:
        yield provider
    finally:
        _SCAN_STATES.provider = prev


def scan_states_provider():
    return _SCAN_STATES.provider


# --------------------------------------------------------------------------- #
# Numerics
# --------------------------------------------------------------------------- #
def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * w.astype(jnp.float32)).astype(dt)


def layernorm(x: jax.Array, w: jax.Array, b: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def norm_schema(d: int, kind: str):
    if kind == "layernorm":
        return {"w": ParamSchema((d,), P(None), "ones"),
                "b": ParamSchema((d,), P(None), "zeros")}
    return {"w": ParamSchema((d,), P(None), "ones")}


def apply_norm(params, x, kind: str):
    if kind == "layernorm":
        return layernorm(x, params["w"], params["b"])
    return rmsnorm(x, params["w"])


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu,
            "celu": jax.nn.celu}[name]


# --------------------------------------------------------------------------- #
# RoPE
# --------------------------------------------------------------------------- #
def rope_freqs(head_dim: int, base: float) -> jax.Array:
    return base ** (-jnp.arange(0, head_dim // 2, dtype=jnp.float32) / (head_dim // 2))


def apply_rope(x: jax.Array, positions: jax.Array, base: float) -> jax.Array:
    """x: (..., S, H, D) or (..., S, D); positions: broadcastable to (..., S).

    Angles/sin/cos are computed in fp32 (position precision), but the
    rotation products stay in x's dtype so sharded activations never float
    through the collective layer as fp32 (2x bytes)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, base)                       # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., S, D/2)
    if x.ndim == ang.ndim + 1:                        # head axis present
        ang = ang[..., None, :]
    cos = jnp.cos(ang).astype(x.dtype)
    sin = jnp.sin(ang).astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
