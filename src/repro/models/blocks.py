"""Per-layer wiring: norms + residuals + mixer + FFN, for every layer kind.

A layer = (norm -> mixer -> residual) [+ (norm -> FFN/MoE -> residual)].
Mamba layers are mixer-only (the mixer subsumes the FFN); cohere-style
``parallel_block`` computes attention and FFN from the same normed input.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import (ArchConfig, ParallelConfig, ATTN_KINDS,
                                GLOBAL_ATTN, LOCAL_ATTN, CHUNKED_ATTN,
                                BIDIR_ATTN, RECURRENT, MAMBA)
from repro.models.attention import (attention_schema, attn_mixer,
                                    attn_cache_schema, _project_kv)
from repro.models.common import (activation, apply_norm, dense, dense_schema,
                                 norm_schema, shard)
from repro.models.moe import moe_schema, moe_mixer
from repro.models.ssm import (mamba_schema, mamba_mixer, mamba_cache_schema,
                              rglru_schema, rglru_mixer, rglru_cache_schema)


# --------------------------------------------------------------------------- #
# FFN
# --------------------------------------------------------------------------- #
def mlp_schema(cfg: ArchConfig):
    d, f = cfg.d_model, cfg.d_ff
    s = {"w_up": dense_schema(d, f),
         "w_down": dense_schema(f, d, fsdp="model", tp="data")}
    if cfg.mlp_gated:
        s["w_gate"] = dense_schema(d, f)
    return s


def mlp_apply(params, x, cfg: ArchConfig, pcfg: ParallelConfig = None):
    if pcfg is not None and pcfg.residual_seq_shard:
        x = shard(x, "dp", None, None)        # gather seq -> TP inside
    act = activation(cfg.mlp_act)
    up = dense(x, params["w_up"], "mlp.up")
    if cfg.mlp_gated:
        g = dense(x, params["w_gate"], "mlp.gate")
        h = act(g) * up
    else:
        h = act(up)
    h = shard(h, "dp", None, "model")
    out = dense(h, params["w_down"], "mlp.down")
    if pcfg is not None and pcfg.residual_seq_shard:
        out = shard(out, "dp", "model", None)  # reduce-scatter back to SP
    return out


# --------------------------------------------------------------------------- #
# Layer schema / cache schema
# --------------------------------------------------------------------------- #
def layer_schema(cfg: ArchConfig, kind: str, *, cross: bool = False):
    d = cfg.d_model
    s: Dict[str, Any] = {"norm1": norm_schema(d, cfg.norm)}
    if kind in ATTN_KINDS:
        s["attn"] = attention_schema(cfg)
    elif kind == RECURRENT:
        s["mixer"] = rglru_schema(cfg)
    elif kind == MAMBA:
        s["mixer"] = mamba_schema(cfg)
    else:
        raise ValueError(kind)

    if cross:
        s["norm_cross"] = norm_schema(d, cfg.norm)
        s["cross"] = attention_schema(cfg, cross=True)

    if kind != MAMBA and not cfg.parallel_block:
        s["norm2"] = norm_schema(d, cfg.norm)
    if kind != MAMBA:
        s["ff"] = moe_schema(cfg) if cfg.moe is not None else mlp_schema(cfg)
    if cfg.post_norms:
        s["post_norm1"] = norm_schema(d, cfg.norm)
        if kind != MAMBA:
            s["post_norm2"] = norm_schema(d, cfg.norm)
    return s


def layer_cache_schema(cfg: ArchConfig, kind: str, batch: int, s_max: int,
                       *, cross_len: int = 0, seq_shard: bool = False,
                       dtype=None):
    """Returns {name: (shape, dtype, PartitionSpec)} for one layer's cache."""
    dt = dtype or jnp.bfloat16
    out: Dict[str, Any] = {}
    if kind in ATTN_KINDS:
        out["attn"] = attn_cache_schema(cfg, kind, batch, s_max, dtype=dt,
                                        seq_shard=seq_shard)
    elif kind == RECURRENT:
        out["mixer"] = rglru_cache_schema(cfg, batch, dtype=dt)
    elif kind == MAMBA:
        out["mixer"] = mamba_cache_schema(cfg, batch, dtype=dt)
    if cross_len:
        shape = (batch, cross_len, cfg.num_kv_heads, cfg.head_dim)
        spec = P(("pod", "data"), None, None, None)
        out["cross"] = {"k": (shape, dt, spec),
                        "v": (shape, dt, spec)}
    return out


# --------------------------------------------------------------------------- #
# Layer application
# --------------------------------------------------------------------------- #
def apply_layer(params, x, *, cfg: ArchConfig, pcfg: ParallelConfig, kind: str,
                mode: str = "train", cache=None, pos=None, positions=None,
                enc_out=None) -> Tuple[jax.Array, Optional[dict], jax.Array]:
    """Returns (x, new_cache_or_None, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, Any] = {}
    c = cache or {}
    rs = "model" if (pcfg.residual_seq_shard and mode != "decode") else None

    h = apply_norm(params["norm1"], x, cfg.norm)

    if kind in ATTN_KINDS:
        mix, mc = attn_mixer(params["attn"], h, cfg=cfg, pcfg=pcfg, kind=kind,
                             positions=positions, cache=c.get("attn"),
                             pos=pos, mode=mode)
    elif kind == RECURRENT:
        mix, mc = rglru_mixer(params["mixer"], h, cfg=cfg, pcfg=pcfg,
                              cache=c.get("mixer"), mode=mode)
    else:  # MAMBA
        mix, mc = mamba_mixer(params["mixer"], h, cfg=cfg, pcfg=pcfg,
                              cache=c.get("mixer"), mode=mode)
    if mc is not None:
        key = "attn" if kind in ATTN_KINDS else "mixer"
        new_cache[key] = mc

    if cfg.post_norms:
        mix = apply_norm(params["post_norm1"], mix, cfg.norm)

    if cfg.parallel_block and kind in ATTN_KINDS:
        # x + attn(n(x)) + ff(n(x))  (cohere)
        if cfg.moe is not None:
            ff, aux_ff = moe_mixer(params["ff"], h, cfg=cfg, pcfg=pcfg,
                                   train=(mode == "train"))
            aux = aux + aux_ff
        else:
            ff = mlp_apply(params["ff"], h, cfg, pcfg)
        x = x + mix + ff
        x = shard(x, "dp", rs, None)
        return x, (new_cache or None), aux

    x = x + mix
    x = shard(x, "dp", rs, None)

    if "cross" in params:
        hc = apply_norm(params["norm_cross"], x, cfg.norm)
        if mode == "decode":
            enc_kv = (c["cross"]["k"], c["cross"]["v"])
        else:
            enc_kv = _project_kv(params["cross"], enc_out, cfg)
            if mode == "prefill":
                new_cache["cross"] = {"k": enc_kv[0], "v": enc_kv[1]}
        mix_c, _ = attn_mixer(params["cross"], hc, cfg=cfg, pcfg=pcfg,
                              kind="cross", enc_kv=enc_kv, mode=mode)
        x = x + mix_c
        if mode == "decode":
            new_cache["cross"] = c["cross"]     # pass through unchanged

    if kind != MAMBA:
        h2 = apply_norm(params["norm2"], x, cfg.norm)
        if cfg.moe is not None:
            ff, aux_ff = moe_mixer(params["ff"], h2, cfg=cfg, pcfg=pcfg,
                                   train=(mode == "train"))
            aux = aux + aux_ff
        else:
            ff = mlp_apply(params["ff"], h2, cfg, pcfg)
        if cfg.post_norms:
            ff = apply_norm(params["post_norm2"], ff, cfg.norm)
        x = x + ff
        x = shard(x, "dp", rs, None)

    return x, (new_cache or None), aux
