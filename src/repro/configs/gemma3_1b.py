"""gemma3-1b — dense, 5:1 local:global hybrid attention, 128k context.

[hf:google/gemma-3-1b-pt; unverified]
"""
from repro.configs.base import ArchConfig, GLOBAL_ATTN, LOCAL_ATTN

CONFIG = ArchConfig(
    name="gemma3-1b",
    family="dense",
    num_layers=26,
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    pattern=(LOCAL_ATTN,) * 5 + (GLOBAL_ATTN,),
    window=512,
    rope_base=10_000.0,
    rope_base_global=1_000_000.0,
    qk_norm=True,
    mlp_gated=True,
    mlp_act="gelu",
    post_norms=True,
    tie_embeddings=True,
    emb_scale=True,
    source="hf:google/gemma-3-1b-pt",
)
