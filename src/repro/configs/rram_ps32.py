"""The paper's own two computing-block geometries (Table 1 / Table 2).

RRAM (1T1R cells) + PS32 peripheral:
  case A: input (C,D,H,W) = (2, 4, 64, 2) -> 1 output voltage
  case B: input (C,D,H,W) = (2, 2, 64, 8) -> 4 output voltages
50k samples each, MAE ~= 1 mV against the circuit solver.
"""
from dataclasses import dataclass, field
from typing import Tuple


@dataclass(frozen=True)
class BlockGeometry:
    """Geometry of one analog computing block (the emulator's input tensor)."""
    name: str
    features: int          # C: per-cell features (V applied, G programmed)
    tiles: int             # D: crossbar tiles accumulated into this block
    rows: int              # H: wordlines per tile
    cols: int              # W: bitlines per tile (2 per output: diff pair)
    outputs: int           # O: MAC output voltages

    @property
    def chw(self) -> Tuple[int, int, int, int]:
        return (self.features, self.tiles, self.rows, self.cols)


@dataclass(frozen=True)
class EmulatorTrainConfig:
    n_train: int = 50_000
    n_test: int = 5_000
    batch_size: int = 256
    epochs: int = 2000
    lr: float = 1e-3
    lr_halve_at: Tuple[int, ...] = (1000, 1500, 1800)   # paper Fig. 4
    sig_bit: int = 3                                    # Thm 4.1 "s"
    prob: float = 0.3                                   # Thm 4.1 "p"
    seed: int = 0


CASE_A = BlockGeometry("rram_ps32_a", features=2, tiles=4, rows=64, cols=2, outputs=1)
CASE_B = BlockGeometry("rram_ps32_b", features=2, tiles=2, rows=64, cols=8, outputs=4)

BLOCKS = {b.name: b for b in (CASE_A, CASE_B)}
