"""seamless-m4t-large-v2 — encoder-decoder multimodal backbone (24L enc +
24L dec, MHA kv=16). Audio frontend is a STUB providing precomputed frame
embeddings.

[arXiv:2308.11596; hf]
"""
from repro.configs.base import ArchConfig, GLOBAL_ATTN

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    pattern=(GLOBAL_ATTN,),
    rope_base=10_000.0,
    norm="layernorm",
    mlp_gated=False,
    mlp_act="gelu",
    encoder_layers=24,
    frontend="audio",
    source="arXiv:2308.11596",
)
