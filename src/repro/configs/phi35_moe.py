"""phi3.5-moe-42b-a6.6b — 32L MoE, 16 experts top-2, GQA kv=8.

[hf:microsoft/Phi-3.5-MoE-instruct; hf]
"""
from repro.configs.base import ArchConfig, GLOBAL_ATTN, MoEConfig

CONFIG = ArchConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=6400,
    vocab_size=32064,
    pattern=(GLOBAL_ATTN,),
    rope_base=10_000.0,
    mlp_gated=True,
    mlp_act="silu",
    norm="layernorm",
    moe=MoEConfig(num_experts=16, top_k=2),
    source="hf:microsoft/Phi-3.5-MoE-instruct",
)
