"""recurrentgemma-2b — griffin hybrid: (RG-LRU, RG-LRU, local-attn) pattern,
MQA head_dim 256, window 2048.

[arXiv:2402.19427; hf]
"""
from repro.configs.base import ArchConfig, LOCAL_ATTN, RECURRENT, RGLRUConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    pattern=(RECURRENT, RECURRENT, LOCAL_ATTN),
    window=2048,
    rope_base=10_000.0,
    mlp_gated=True,
    mlp_act="gelu",
    tie_embeddings=True,
    emb_scale=True,
    rglru=RGLRUConfig(lru_width=2560, d_conv=4),
    source="arXiv:2402.19427",
)
