"""deepseek-coder-33b — dense 62L llama-arch, GQA kv=8.

[arXiv:2401.14196; hf]
"""
from repro.configs.base import ArchConfig, GLOBAL_ATTN

CONFIG = ArchConfig(
    name="deepseek-coder-33b",
    family="dense",
    num_layers=62,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=19200,
    vocab_size=32256,
    pattern=(GLOBAL_ATTN,),
    rope_base=100_000.0,
    mlp_gated=True,
    mlp_act="silu",
    source="arXiv:2401.14196",
)
