"""Architecture config registry: ``get_config(name)`` / ``ARCH_NAMES``."""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    ArchConfig,
    AnalogConfig,
    MoEConfig,
    ParallelConfig,
    RGLRUConfig,
    SHAPES,
    SHAPES_BY_NAME,
    ShapeConfig,
    SSMConfig,
    TrainConfig,
    reduced,
)

_MODULES = {
    "gemma3-1b": "gemma3_1b",
    "qwen1.5-110b": "qwen15_110b",
    "command-r-plus-104b": "command_r_plus_104b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe",
    "llama4-scout-17b-a16e": "llama4_scout",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "internvl2-76b": "internvl2_76b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG
