"""qwen1.5-110b — dense 80L, GQA kv=8, QKV bias.

[hf:Qwen/Qwen1.5-110B (family config per assignment); hf]
"""
from repro.configs.base import ArchConfig, GLOBAL_ATTN

CONFIG = ArchConfig(
    name="qwen1.5-110b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=49152,
    vocab_size=152064,
    pattern=(GLOBAL_ATTN,),
    rope_base=1_000_000.0,
    qkv_bias=True,
    mlp_gated=True,
    mlp_act="silu",
    source="hf:Qwen/Qwen1.5-110B",
)
