"""Configuration dataclasses for the repro framework.

One ``ArchConfig`` fully describes a model; ``ShapeConfig`` describes one
(seq_len, global_batch, mode) workload cell; ``ParallelConfig`` the
distribution strategy; ``AnalogConfig`` the SEMULATOR analog-execution
backend (the paper's technique) applied to the model's matmuls.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple

# Layer kinds used in ``ArchConfig.pattern``.
GLOBAL_ATTN = "G"     # full causal self attention
LOCAL_ATTN = "L"      # sliding-window causal self attention
CHUNKED_ATTN = "C"    # block-chunked causal self attention (llama4 iRoPE)
RECURRENT = "R"       # RG-LRU recurrent block (griffin/recurrentgemma)
MAMBA = "M"           # mamba-1 selective-SSM mixer
BIDIR_ATTN = "B"      # bidirectional self attention (encoder)

ATTN_KINDS = (GLOBAL_ATTN, LOCAL_ATTN, CHUNKED_ATTN, BIDIR_ATTN)


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 16
    top_k: int = 2
    capacity_factor: float = 1.25
    eval_capacity_factor: float = 2.0
    shared_expert: bool = False        # llama4-style always-on shared expert
    router_aux_coef: float = 0.01
    router_jitter: float = 0.0


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-1 mixer configuration."""
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0                   # 0 -> ceil(d_model / 16)

    def resolved_dt_rank(self, d_model: int) -> int:
        return self.dt_rank or -(-d_model // 16)


@dataclass(frozen=True)
class RGLRUConfig:
    """RG-LRU recurrent block (griffin) configuration."""
    lru_width: int = 0                 # 0 -> d_model
    d_conv: int = 4


@dataclass(frozen=True)
class AnalogConfig:
    """SEMULATOR analog-crossbar execution of matmuls (the paper's feature).

    backend:
      digital   -- plain matmul (technique off)
      analytic  -- human-expert analytical model (paper's strawman baseline)
      circuit   -- Newton-Raphson circuit solver (SPICE stand-in; slow, exact)
      emulator  -- Conv4Xbar regression network (the paper's contribution)
    """
    enabled: bool = False
    backend: str = "emulator"
    rows: int = 64                     # crossbar wordlines per tile
    cols_per_out: int = 2              # differential pair (G+, G-)
    outs_per_block: int = 1            # MAC outputs per computing block
    g_min: float = 1e-6                # S
    g_max: float = 1e-4                # S
    v_read: float = 0.2                # V
    layers: Tuple[str, ...] = ("mlp", "attn")  # which projections run analog
    emulator_params_path: Optional[str] = None
    # gate-overdrive wordline biasing: map nonzero normalized drives into
    # [v_th/v_read, 1] so activations are not swallowed by the access
    # transistor's cut-off deadband (93% of a N(0,1) drive sits below v_th
    # with the naive linear map)
    wl_overdrive: bool = True
    # device non-ideality scenario name (repro.nonideal registry); None =
    # ideal device corner.  AnalogExecutor resolves it at construction.
    scenario: Optional[str] = None


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str                          # "train" | "prefill" | "decode"


# The four assigned workload shapes (identical for every LM arch).
SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)
SHAPES_BY_NAME = {s.name: s for s in SHAPES}


@dataclass(frozen=True)
class ParallelConfig:
    # Mesh axis names: batch is sharded over (pod, data); weights over
    # (data=fsdp, model=tp); experts and big KV-cache sequence dims over model.
    fsdp_axis: str = "data"
    tp_axis: str = "model"
    pod_axis: str = "pod"
    remat: str = "full"                # "none" | "full" | "dots"
    scan_layers: bool = True
    attn_block_kv: int = 1024          # blockwise-softmax KV block
    attn_block_q: int = 1024
    xent_chunk: int = 2048             # chunked cross-entropy seq chunk
    scan_chunk: int = 256              # mamba/rglru chunked-scan chunk
    decode_seq_shard: bool = False     # shard KV-cache seq dim over model
    residual_seq_shard: bool = False   # Megatron-SP residual stream: the
    #   carry/remat stash is (B, S/tp, D); gathers happen inside layers
    grad_accum: int = 1                # microbatches per step (memory knob)
    grad_compression: str = "none"     # "none" | "int8"
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"


@dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    z_loss: float = 1e-4
    seed: int = 0
    checkpoint_every: int = 100
    keep_checkpoints: int = 3


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                        # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # layer pattern, cycled over layers (periods scanned, remainder unrolled)
    pattern: Tuple[str, ...] = (GLOBAL_ATTN,)
    window: int = 4096                 # local-attn window / chunk size
    rope_base: float = 10_000.0
    rope_base_global: float = 0.0      # 0 -> same as rope_base
    qk_norm: bool = False
    qkv_bias: bool = False
    mlp_gated: bool = True
    mlp_act: str = "silu"              # silu | gelu | relu
    norm: str = "rmsnorm"              # rmsnorm | layernorm
    parallel_block: bool = False       # cohere-style parallel attn+mlp
    post_norms: bool = False           # gemma3 sandwich norms
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    emb_scale: bool = False            # gemma-style sqrt(d) embedding scale
    vocab_pad_to: int = 256
    # encoder-decoder
    encoder_layers: int = 0
    # sub-configs
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    analog: AnalogConfig = field(default_factory=AnalogConfig)
    # frontends ("none" | "vision" | "audio"); stubs provide embeddings
    frontend: str = "none"
    frontend_tokens: int = 256         # vision: #patch embeds prepended
    # provenance
    source: str = ""

    # ------------------------------------------------------------------ #
    @property
    def padded_vocab(self) -> int:
        p = self.vocab_pad_to
        return -(-self.vocab_size // p) * p

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        """Kind of every decoder layer, pattern cycled."""
        p = self.pattern
        return tuple(p[i % len(p)] for i in range(self.num_layers))

    @property
    def num_periods(self) -> int:
        return self.num_layers // len(self.pattern)

    @property
    def tail_kinds(self) -> Tuple[str, ...]:
        rem = self.num_layers % len(self.pattern)
        return tuple(self.pattern[:rem])

    @property
    def sub_quadratic(self) -> bool:
        """True if decode over very long context is O(1)/O(window) for most
        layers (SSM / hybrid / windowed) -> long_500k applies."""
        return all(k != GLOBAL_ATTN for k in self.pattern) or (
            sum(k == GLOBAL_ATTN for k in self.pattern) < len(self.pattern) // 2
        )

    def supports_shape(self, shape: ShapeConfig) -> bool:
        if shape.name == "long_500k":
            return self.sub_quadratic
        return True

    def param_count(self) -> int:
        """Approximate parameter count (embeddings included)."""
        d, f, L = self.d_model, self.d_ff, self.num_layers
        qf = self.num_heads * self.head_dim
        kvf = self.num_kv_heads * self.head_dim
        attn = d * qf + 2 * d * kvf + qf * d
        mlp = d * f * (3 if self.mlp_gated else 2)
        total = 0
        for kind in self.layer_kinds:
            if kind in ATTN_KINDS:
                total += attn
                if self.moe is not None:
                    e = self.moe.num_experts + (1 if self.moe.shared_expert else 0)
                    total += e * mlp + d * self.moe.num_experts
                else:
                    total += mlp
            elif kind == RECURRENT:
                w = (self.rglru.lru_width or d) if self.rglru else d
                total += 2 * d * w + w * d + 3 * w + mlp
            elif kind == MAMBA:
                di = d * self.ssm.expand
                dtr = self.ssm.resolved_dt_rank(d)
                total += (d * 2 * di + di * (dtr + 2 * self.ssm.d_state)
                          + dtr * di + di * d + di * self.ssm.d_conv
                          + di * self.ssm.d_state + di)
        total += self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        if self.encoder_layers:
            # encoder self-attn + ffn, decoder cross-attn
            total += self.encoder_layers * (attn + mlp)
            total += self.num_layers * attn      # cross attention
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed experts)."""
        if self.moe is None:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        mlp = d * f * (3 if self.mlp_gated else 2)
        e_total = self.moe.num_experts + (1 if self.moe.shared_expert else 0)
        e_active = self.moe.top_k + (1 if self.moe.shared_expert else 0)
        n_moe_layers = sum(1 for k in self.layer_kinds if k in ATTN_KINDS)
        return self.param_count() - n_moe_layers * (e_total - e_active) * mlp


def reduced(cfg: ArchConfig, *, layers: Optional[int] = None) -> ArchConfig:
    """A tiny same-family config for CPU smoke tests."""
    pat = cfg.pattern
    n_layers = layers if layers is not None else max(len(pat), 2)
    kw = dict(
        num_layers=n_layers,
        d_model=64,
        num_heads=4,
        num_kv_heads=max(1, min(cfg.num_kv_heads, 2)),
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        vocab_pad_to=32,
        window=max(8, min(cfg.window, 16)),
        frontend_tokens=4 if cfg.frontend != "none" else cfg.frontend_tokens,
        encoder_layers=2 if cfg.encoder_layers else 0,
    )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(cfg.moe, num_experts=4, top_k=min(cfg.moe.top_k, 2))
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(cfg.ssm, d_state=4, dt_rank=8)
    if cfg.rglru is not None:
        kw["rglru"] = dataclasses.replace(cfg.rglru, lru_width=64)
    return dataclasses.replace(cfg, name=cfg.name + "-reduced", **kw)
