"""llama4-scout-17b-a16e — 48L MoE (16 experts top-1 + shared expert),
iRoPE-style 3:1 chunked-local:global attention pattern.

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
"""
from repro.configs.base import ArchConfig, CHUNKED_ATTN, GLOBAL_ATTN, MoEConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    pattern=(CHUNKED_ATTN, CHUNKED_ATTN, CHUNKED_ATTN, GLOBAL_ATTN),
    window=8192,                 # attention chunk size
    rope_base=500_000.0,
    mlp_gated=True,
    mlp_act="silu",
    moe=MoEConfig(num_experts=16, top_k=1, shared_expert=True),
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
