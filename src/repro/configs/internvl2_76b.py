"""internvl2-76b — VLM: InternViT frontend (STUB: precomputed patch
embeddings) + 80L llama-3-70B-class language backbone.

[arXiv:2404.16821; unverified]
"""
from repro.configs.base import ArchConfig, GLOBAL_ATTN

CONFIG = ArchConfig(
    name="internvl2-76b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    pattern=(GLOBAL_ATTN,),
    rope_base=500_000.0,
    mlp_gated=True,
    mlp_act="silu",
    frontend="vision",
    frontend_tokens=256,
    source="arXiv:2404.16821",
)
