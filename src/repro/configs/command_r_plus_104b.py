"""command-r-plus-104b — dense 64L, GQA kv=8, parallel attn+FFN block,
LayerNorm, tied embeddings, no biases.

[hf:CohereForAI/c4ai-command-r-plus; unverified]
"""
from repro.configs.base import ArchConfig, GLOBAL_ATTN

CONFIG = ArchConfig(
    name="command-r-plus-104b",
    family="dense",
    num_layers=64,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    head_dim=128,
    d_ff=33792,
    vocab_size=256000,
    pattern=(GLOBAL_ATTN,),
    rope_base=75_000_000.0,
    norm="layernorm",
    parallel_block=True,
    tie_embeddings=True,
    mlp_gated=True,
    mlp_act="silu",
    source="hf:CohereForAI/c4ai-command-r-plus",
)
