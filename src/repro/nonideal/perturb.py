"""Conductance-level perturbation ops.

Everything applies at the *conductance-plan* level -- raw conductances in
[g_min, g_max], any shape -- so one implementation serves all three analog
backends: the circuit solver consumes perturbed ``g`` directly (noise-aware
training data), and the emulator / analytic fast paths consume a perturbed
``ConductancePlan`` (``plan.with_g``) whose arrays enter the per-tag jitted
forward as traced buffers, leaving PR 1's compile cache intact.

Composition order (device-state, one draw per device key):
  quantize -> programming variation -> retention drift -> stuck faults -> clip
then per read cycle:
  read noise -> clip

Each step is an exact bitwise identity at its ideal parameter value: the
non-ideal candidate is computed on the side and selected with
``jnp.where(active, candidate, g)``, multiplicative factors are exactly 1.0
at zero sigma, and the final clip is a no-op for in-range values.

Per-tile heterogeneity: a tile-indexed scenario batch (``tile_scenarios``,
leaves shaped ``(NB, NO)``) makes ``perturb_plan`` vmap the perturbation
over the plan's tile lattice, so each (block-group, output-group) tile
gets its own scenario level AND its own device key -- the same vmap
machinery ``ScenarioSweep`` uses for multi-draw sweeps, turned inward.

Fault-aware remapping: ``remap_plan`` predicts the exact stuck-off mask a
``(plan, scenario, key)`` triple will realize (``realized_fault_masks``),
asks the conductance planner for an output-group permutation that steers
large-|w| columns away from stuck-off cells
(``crossbar.fault_aware_group_perm``), and returns a permuted plan whose
``out_perm`` gather undoes the move at assemble time.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AnalogConfig
from repro.core.circuit import CircuitParams
from repro.core.crossbar import (ConductancePlan, _perm_candidates,
                                 finish_group_perm)
from repro.nonideal.scenario import _LEAF_FIELDS, _leaf_dtype, Scenario


def sample_fault_masks(key: jax.Array, shape, p_stuck_on, p_stuck_off):
    """(stuck_on, stuck_off) boolean masks from ONE uniform draw per cell.

    A single draw keeps the masks disjoint (for p_on + p_off <= 1), makes
    them deterministic under a fixed key, and makes fault populations nested
    across p sweeps (cells stuck at p=0.001 stay stuck at p=0.01), which is
    what makes fault-rate curves monotone."""
    u = jax.random.uniform(key, shape)
    return u < p_stuck_on, u > 1.0 - p_stuck_off


def drift_factor(scenario: Scenario) -> jax.Array:
    """Retention decay multiplier (t / t0)^-nu; exactly 1.0 when inactive."""
    t = jnp.asarray(scenario.drift_t, jnp.float32)
    nu = jnp.asarray(scenario.drift_nu, jnp.float32)
    active = (nu != 0.0) & (t > 0.0)
    tt = jnp.maximum(t, 1e-30) / jnp.asarray(scenario.drift_t0, jnp.float32)
    return jnp.where(active, jnp.power(tt, -nu), 1.0)


def quantize_levels(g: jax.Array, acfg: AnalogConfig, n_levels) -> jax.Array:
    """Snap to n_levels equispaced programming levels over [g_min, g_max]."""
    span = acfg.g_max - acfg.g_min
    lm1 = jnp.maximum(jnp.asarray(n_levels, jnp.float32) - 1.0, 1.0)
    gq = acfg.g_min + span * (jnp.round((g - acfg.g_min) / span * lm1) / lm1)
    return jnp.where(jnp.asarray(n_levels) >= 2, gq, g)


def perturb_conductance(g: jax.Array, acfg: AnalogConfig,
                        scenario: Scenario, key: jax.Array) -> jax.Array:
    """Device-state perturbation (programming + retention) of raw
    conductances.  One ``key`` = one fabricated device draw; the same key
    reproduces the same device.  Read noise is separate (per read cycle):
    see apply_read_noise."""
    kp, kf = jax.random.split(key)
    # conductance plans pad partial tiles/output groups with g == 0 exactly:
    # there is no physical cell at those lattice sites, so no perturbation
    # (and in particular no clip up to g_min) may touch them
    live = g > 0.0
    gp = quantize_levels(g, acfg, scenario.n_levels)
    eps = jax.random.normal(kp, g.shape, jnp.float32)
    gp = gp * jnp.exp(jnp.asarray(scenario.prog_sigma, jnp.float32) * eps)
    gp = gp * drift_factor(scenario)
    on, off = sample_fault_masks(kf, g.shape, scenario.p_stuck_on,
                                 scenario.p_stuck_off)
    gp = jnp.where(on, acfg.g_max, gp)
    gp = jnp.where(off, acfg.g_min, gp)
    return jnp.where(live, jnp.clip(gp, acfg.g_min, acfg.g_max), g)


def apply_read_noise(g: jax.Array, acfg: AnalogConfig, read_sigma,
                     key: jax.Array) -> jax.Array:
    """Cycle-to-cycle multiplicative read noise; one key per read cycle.
    ``read_sigma`` may be a scalar or an (NB, NO) per-tile array (aligned
    against leading axes of ``g``).  Padded lattice sites (g == 0, no
    cell) stay exactly zero."""
    eps = jax.random.normal(key, g.shape, jnp.float32)
    rs = jnp.asarray(read_sigma, jnp.float32)
    if rs.ndim and rs.ndim < g.ndim:
        rs = rs.reshape(rs.shape + (1,) * (g.ndim - rs.ndim))
    gn = g * (1.0 + rs * eps)
    return jnp.where(g > 0.0, jnp.clip(gn, acfg.g_min, acfg.g_max), g)


# --------------------------------------------------------------------------- #
# Plan-level perturbation (scalar scenario or (NB, NO) per-tile batch)
# --------------------------------------------------------------------------- #
def _broadcast_scenario(scenario: Scenario, shape) -> Scenario:
    """Every numeric leaf broadcast to ``shape`` (mixed scalar / per-tile
    batches become uniformly tiled, ready to vmap over)."""
    kw = {f: jnp.broadcast_to(
        jnp.asarray(getattr(scenario, f), _leaf_dtype(f)), shape)
        for f in _LEAF_FIELDS}
    return dataclasses.replace(scenario, **kw)


def _tile_keys(key: jax.Array, nb: int, no: int) -> jax.Array:
    """One independent device-draw key per (NB, NO) tile."""
    keys = jax.random.split(key, nb * no)
    return keys.reshape((nb, no) + keys.shape[1:])


def _check_tile_shape(plan: ConductancePlan, scenario: Scenario):
    ts = scenario.tile_shape
    if ts is not None and ts != (plan.NB, plan.NO):
        raise ValueError(
            f"per-tile scenario batch shaped {ts} does not match the "
            f"plan's (NB, NO) = {(plan.NB, plan.NO)} tile lattice")
    return ts


def perturb_plan(plan: ConductancePlan, acfg: AnalogConfig,
                 scenario: Scenario, key: jax.Array) -> ConductancePlan:
    """Device-state-perturbed copy of a conductance plan (static layout
    unchanged, so consumers compiled for the base plan's shapes are
    reused).

    With a scalar scenario, one device key perturbs the whole plan.  With
    a tile-indexed scenario batch (leaves shaped ``(NB, NO)``, see
    ``tile_scenarios``) the perturbation is vmapped over the tile lattice:
    tile (i, j) sees scenario level ``leaf[i, j]`` and its own key derived
    from ``key``, so fab heterogeneity and per-die fault rates compose
    with everything downstream unchanged."""
    ts = _check_tile_shape(plan, scenario)
    if ts is None:
        return plan.with_g(
            perturb_conductance(plan.g_feat, acfg, scenario, key), acfg)
    scb = _broadcast_scenario(scenario, ts)
    keys = _tile_keys(key, plan.NB, plan.NO)
    per_tile = jax.vmap(jax.vmap(perturb_conductance,
                                 in_axes=(0, None, 0, 0)),
                        in_axes=(0, None, 0, 0))
    return plan.with_g(per_tile(plan.g_feat, acfg, scb, keys), acfg)


def realized_fault_masks(plan: ConductancePlan, scenario: Scenario,
                         key: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """The exact (stuck_on, stuck_off) masks ``perturb_plan`` will realize
    for this (plan, scenario, key) -- same key-split discipline, scalar or
    per-tile.  The masks depend only on shapes and the key, never on the
    conductance values, which is what lets the remapper move weights
    around without moving the faults."""
    ts = _check_tile_shape(plan, scenario)
    shape = plan.g_feat.shape
    if ts is None:
        _, kf = jax.random.split(key)
        return sample_fault_masks(kf, shape, scenario.p_stuck_on,
                                  scenario.p_stuck_off)
    scb = _broadcast_scenario(scenario, ts)
    keys = _tile_keys(key, plan.NB, plan.NO)

    def one(st: Scenario, k):
        _, kf = jax.random.split(k)
        return sample_fault_masks(kf, shape[2:], st.p_stuck_on,
                                  st.p_stuck_off)

    return jax.vmap(jax.vmap(one))(scb, keys)


def drift_factor_at_age(scenario: Scenario, age: float) -> jax.Array:
    """Retention decay multiplier at ``age`` seconds since programming --
    ``drift_factor`` with the scenario's ``drift_t`` replaced by ``age``.
    Tile-aware: per-tile ``drift_nu`` / ``drift_t0`` leaves give an
    (NB, NO) factor; exactly 1.0 wherever drift is inactive."""
    t = jnp.asarray(age, jnp.float32)
    nu = jnp.asarray(scenario.drift_nu, jnp.float32)
    t0 = jnp.asarray(scenario.drift_t0, jnp.float32)
    active = (nu != 0.0) & (t > 0.0)
    return jnp.where(active, jnp.power(jnp.maximum(t, 1e-30) / t0, -nu), 1.0)


def remap_plan(plan: ConductancePlan, acfg: AnalogConfig, scenario: Scenario,
               key: jax.Array, top_q: float = 0.9,
               horizon: Optional[Sequence[float]] = None
               ) -> Tuple[ConductancePlan, jax.Array]:
    """Stuck-fault-aware remapped copy of a conductance plan.

    Predicts the deterministic stuck-off mask for ``(plan, scenario,
    key)``, computes an output-group permutation that keeps large-|w|
    (top-``top_q``-quantile) weights off stuck-off cells
    (``crossbar.fault_aware_group_perm``), and returns
    ``(remapped_plan, out_perm)``: the remapped plan carries the permuted
    conductance groups AND the ``out_perm`` inverse gather, so
    ``plan.assemble`` hands back logically-ordered outputs.  Identity when
    the scenario has no stuck-off faults.  Perturb the result with the
    SAME ``key``: the masks depend only on shapes, so the faults land on
    the same physical cells the permutation was planned against.

    ``horizon`` -- optional sequence of ages (seconds since programming,
    e.g. the maintenance-checkpoint timeline) -- switches the permutation
    to *wear-aware* selection: a second candidate assignment is grown
    greedily under the stuck-off damage anticipated over the whole drift
    trajectory (``fault_aware_group_perm``'s horizon mode), then the
    instant and wear-aware candidates are scored by REALIZING each
    through ``perturb_plan`` at every horizon age -- the same
    (scenario, key) perturbation the deployment will serve with, so
    programming noise, stuck-on faults, drift and clipping are all in
    the score -- and measuring the global-scale-invariant deviation of
    the aged differential weights from the young programmed ones.  The
    lower-deviation candidate wins, instant on ties: wear-aware
    remapping never realizes a worse end-of-horizon weight deviation
    than instant remapping, and genuinely wins when per-tile drift
    heterogeneity makes slow-decaying die positions the riskier
    long-term hosts.  ``horizon=None`` is bit-identical to the
    instantaneous assignment."""
    if not scenario.has_stuck_off:
        return plan, jnp.arange(plan.N, dtype=jnp.int32)
    _, off = realized_fault_masks(plan, scenario, key)
    g = np.asarray(plan.g_feat)
    hz = None
    if horizon is not None:
        with jax.ensure_compile_time_eval():
            hz = [np.asarray(drift_factor_at_age(scenario, t))
                  for t in horizon]
    cands = _perm_candidates(np.asarray(g, np.float64),
                             np.asarray(off, bool), plan, acfg, top_q, hz)
    gperm = cands[0]
    if len(cands) > 1:
        scores = [_realized_horizon_score(plan, acfg, scenario, key, c,
                                          horizon) for c in cands]
        if scores[1] < scores[0]:                      # instant wins ties
            gperm = cands[1]
    out_perm, gperm, ginv = finish_group_perm(gperm, plan)
    remapped = plan.with_g(jnp.take(plan.g_feat, jnp.asarray(ginv), axis=1),
                           acfg).with_perm(jnp.asarray(out_perm, jnp.int32))
    return remapped, remapped.out_perm


def _realized_horizon_score(plan: ConductancePlan, acfg: AnalogConfig,
                            scenario: Scenario, key: jax.Array,
                            gperm: np.ndarray,
                            ages: Sequence[float]) -> float:
    """Realized end-of-horizon weight deviation of a remap candidate.

    Builds the candidate's remapped plan, perturbs it with the SAME
    ``(scenario, key)`` the deployment will use at each checkpoint age
    (programming noise, stuck faults, drift, clipping -- the exact
    serving conductances), gathers the aged cells back into logical
    order, and measures ``min_a ||W_young - a * W_aged||_F^2`` over the
    real (un-padded) columns -- the global scale ``a`` standing in for
    the affine refit periodic recalibration performs.  Averaged over the
    ages; lower is better."""
    gperm = np.asarray(gperm)
    ginv = np.empty_like(gperm)
    ginv[gperm] = np.arange(gperm.shape[0], dtype=gperm.dtype)
    no = plan.no
    col = np.arange(plan.NO)[:, None] * no + np.arange(no)[None, :]
    vmask = (col < plan.N)[None, :, None, None, :].astype(np.float64)
    g = np.asarray(plan.g_feat, np.float64)
    w_young = (g[..., 0::2] - g[..., 1::2]) * vmask
    with jax.ensure_compile_time_eval():
        base = plan.with_g(jnp.take(plan.g_feat, jnp.asarray(ginv), axis=1),
                           acfg)
        total = 0.0
        for t in ages:
            aged = dataclasses.replace(scenario,
                                       drift_t=jnp.asarray(t, jnp.float32))
            eff = np.asarray(perturb_plan(base, acfg, aged, key).g_feat,
                             np.float64)[:, gperm]
            w_eff = (eff[..., 0::2] - eff[..., 1::2]) * vmask
            ee = float((w_eff * w_eff).sum())
            a = float((w_eff * w_young).sum()) / ee if ee > 0.0 else 1.0
            r = w_young - a * w_eff
            total += float((r * r).sum())
    return total / max(len(list(ages)), 1)


def scenario_circuit_params(cp: CircuitParams,
                            scenario: Scenario) -> CircuitParams:
    """Line-resistance scaling for the circuit solver.  Static: CircuitParams
    is a hashed constant of the compiled graph, so changing r_line_scale
    recompiles the circuit backend (the fast-path backends are unaffected)."""
    if scenario.r_line_scale == 1.0:
        return cp
    return dataclasses.replace(cp, r_bl=cp.r_bl * scenario.r_line_scale)
