"""Conductance-level perturbation ops.

Everything applies at the *conductance-plan* level -- raw conductances in
[g_min, g_max], any shape -- so one implementation serves all three analog
backends: the circuit solver consumes perturbed ``g`` directly (noise-aware
training data), and the emulator / analytic fast paths consume a perturbed
``ConductancePlan`` (``plan.with_g``) whose arrays enter the per-tag jitted
forward as traced buffers, leaving PR 1's compile cache intact.

Composition order (device-state, one draw per device key):
  quantize -> programming variation -> retention drift -> stuck faults -> clip
then per read cycle:
  read noise -> clip

Each step is an exact bitwise identity at its ideal parameter value: the
non-ideal candidate is computed on the side and selected with
``jnp.where(active, candidate, g)``, multiplicative factors are exactly 1.0
at zero sigma, and the final clip is a no-op for in-range values.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import AnalogConfig
from repro.core.circuit import CircuitParams
from repro.core.crossbar import ConductancePlan
from repro.nonideal.scenario import Scenario


def sample_fault_masks(key: jax.Array, shape, p_stuck_on, p_stuck_off):
    """(stuck_on, stuck_off) boolean masks from ONE uniform draw per cell.

    A single draw keeps the masks disjoint (for p_on + p_off <= 1), makes
    them deterministic under a fixed key, and makes fault populations nested
    across p sweeps (cells stuck at p=0.001 stay stuck at p=0.01), which is
    what makes fault-rate curves monotone."""
    u = jax.random.uniform(key, shape)
    return u < p_stuck_on, u > 1.0 - p_stuck_off


def drift_factor(scenario: Scenario) -> jax.Array:
    """Retention decay multiplier (t / t0)^-nu; exactly 1.0 when inactive."""
    t = jnp.asarray(scenario.drift_t, jnp.float32)
    nu = jnp.asarray(scenario.drift_nu, jnp.float32)
    active = (nu != 0.0) & (t > 0.0)
    tt = jnp.maximum(t, 1e-30) / jnp.asarray(scenario.drift_t0, jnp.float32)
    return jnp.where(active, jnp.power(tt, -nu), 1.0)


def quantize_levels(g: jax.Array, acfg: AnalogConfig, n_levels) -> jax.Array:
    """Snap to n_levels equispaced programming levels over [g_min, g_max]."""
    span = acfg.g_max - acfg.g_min
    lm1 = jnp.maximum(jnp.asarray(n_levels, jnp.float32) - 1.0, 1.0)
    gq = acfg.g_min + span * (jnp.round((g - acfg.g_min) / span * lm1) / lm1)
    return jnp.where(jnp.asarray(n_levels) >= 2, gq, g)


def perturb_conductance(g: jax.Array, acfg: AnalogConfig,
                        scenario: Scenario, key: jax.Array) -> jax.Array:
    """Device-state perturbation (programming + retention) of raw
    conductances.  One ``key`` = one fabricated device draw; the same key
    reproduces the same device.  Read noise is separate (per read cycle):
    see apply_read_noise."""
    kp, kf = jax.random.split(key)
    # conductance plans pad partial tiles/output groups with g == 0 exactly:
    # there is no physical cell at those lattice sites, so no perturbation
    # (and in particular no clip up to g_min) may touch them
    live = g > 0.0
    gp = quantize_levels(g, acfg, scenario.n_levels)
    eps = jax.random.normal(kp, g.shape, jnp.float32)
    gp = gp * jnp.exp(jnp.asarray(scenario.prog_sigma, jnp.float32) * eps)
    gp = gp * drift_factor(scenario)
    on, off = sample_fault_masks(kf, g.shape, scenario.p_stuck_on,
                                 scenario.p_stuck_off)
    gp = jnp.where(on, acfg.g_max, gp)
    gp = jnp.where(off, acfg.g_min, gp)
    return jnp.where(live, jnp.clip(gp, acfg.g_min, acfg.g_max), g)


def apply_read_noise(g: jax.Array, acfg: AnalogConfig, read_sigma,
                     key: jax.Array) -> jax.Array:
    """Cycle-to-cycle multiplicative read noise; one key per read cycle.
    Padded lattice sites (g == 0, no cell) stay exactly zero."""
    eps = jax.random.normal(key, g.shape, jnp.float32)
    gn = g * (1.0 + jnp.asarray(read_sigma, jnp.float32) * eps)
    return jnp.where(g > 0.0, jnp.clip(gn, acfg.g_min, acfg.g_max), g)


def perturb_plan(plan: ConductancePlan, acfg: AnalogConfig,
                 scenario: Scenario, key: jax.Array) -> ConductancePlan:
    """Device-state-perturbed copy of a conductance plan (static layout
    unchanged, so consumers compiled for the base plan's shapes are reused)."""
    return plan.with_g(perturb_conductance(plan.g_feat, acfg, scenario, key),
                       acfg)


def scenario_circuit_params(cp: CircuitParams,
                            scenario: Scenario) -> CircuitParams:
    """Line-resistance scaling for the circuit solver.  Static: CircuitParams
    is a hashed constant of the compiled graph, so changing r_line_scale
    recompiles the circuit backend (the fast-path backends are unaffected)."""
    if scenario.r_line_scale == 1.0:
        return cp
    return dataclasses.replace(cp, r_bl=cp.r_bl * scenario.r_line_scale)
