"""Device non-ideality scenarios: what can go wrong between the weights you
wanted and the conductances the crossbar actually reads.

A ``Scenario`` is a frozen dataclass registered as a jax pytree so its
numeric knobs enter compiled functions as *traced* leaves -- sweeping
``prog_sigma`` (or any other float field) across values reuses one
compilation.  ``name`` and ``r_line_scale`` are static aux data:
``r_line_scale`` rewrites ``CircuitParams`` (a hashed static), so changing
it recompiles the circuit backend by design.

Leaves may be scalars (one corner for the whole plan) or ``(NB, NO)``
arrays -- one value per (block-group, output-group) tile of a
``ConductancePlan`` -- describing per-tile fab heterogeneity.  Build such
tile-indexed scenario batches with ``tile_scenarios``; ``perturb_plan``
vmaps the perturbation over the tile lattice so each tile gets its own
sigma / drift / fault draw (docs/nonideal.md, "Per-tile heterogeneity").

Fields (composition order documented in docs/nonideal.md):
  n_levels     -- quantized programming levels over [g_min, g_max]
                  (0 or 1 = continuous programming)
  prog_sigma   -- lognormal programming variation: g <- g * exp(sigma * eps),
                  one draw per device (fixed by the device key)
  drift_nu     -- retention drift g <- g * (t / t0)^-nu  (clipped to range)
  drift_t      -- seconds since programming (0 = no drift)
  drift_t0     -- drift reference time
  p_stuck_on   -- fraction of cells stuck at g_max (fault mask, per device)
  p_stuck_off  -- fraction of cells stuck at g_min
  read_sigma   -- cycle-to-cycle multiplicative read noise, redrawn per call
                  on the eager per-tag path and per draw in sweeps; under an
                  ENCLOSING jit (e.g. a compiled decode step) the draw is
                  baked at trace time -- see docs/nonideal.md
  r_line_scale -- bitline/integrator input-resistance multiplier (circuit
                  solver only; the emulator sees it through noise-aware
                  retraining, see nonideal/data.py)

Every perturbation is an exact identity at its ideal value (verified
bitwise in tests), so the ideal scenario cannot change serving numerics.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_LEAF_FIELDS: Tuple[str, ...] = (
    "prog_sigma", "read_sigma", "p_stuck_on", "p_stuck_off",
    "drift_nu", "drift_t", "drift_t0", "n_levels",
)
_AUX_FIELDS: Tuple[str, ...] = ("name", "r_line_scale")


def _leaf_dtype(f: str):
    return jnp.int32 if f == "n_levels" else jnp.float32


def _leaf_max(v) -> float:
    """Concrete max of a leaf (python scalar stays pure python: ``is_ideal``
    sits on the serving hot path and must not sync the device per call)."""
    if isinstance(v, (int, float)):
        return float(v)
    return float(jnp.max(jnp.asarray(v)))


def _leaf_min(v) -> float:
    if isinstance(v, (int, float)):
        return float(v)
    return float(jnp.min(jnp.asarray(v)))


@dataclass(frozen=True)
class Scenario:
    """One device non-ideality corner (see module docstring for field
    semantics and docs/nonideal.md for the composition order).

    Numeric fields are pytree leaves and may be python scalars or
    ``(NB, NO)`` jax arrays (per-tile heterogeneity, ``tile_scenarios``);
    ``name`` and ``r_line_scale`` are static aux data.  Instances are
    frozen: derive variants with ``dataclasses.replace`` (e.g. aging a
    corner by rewriting ``drift_t``, as ``lifetime.scenario_at_age`` does).
    """
    name: str = "ideal"
    prog_sigma: float = 0.0
    read_sigma: float = 0.0
    p_stuck_on: float = 0.0
    p_stuck_off: float = 0.0
    drift_nu: float = 0.0
    drift_t: float = 0.0
    drift_t0: float = 1.0
    r_line_scale: float = 1.0
    n_levels: int = 0

    def __post_init__(self):
        # pin leaf dtypes so jit sees stable (weak f32 / i32) avals across
        # sweeps -- Scenario(prog_sigma=0) must not retrace vs prog_sigma=0.0
        for f in _LEAF_FIELDS:
            v = getattr(self, f)
            if isinstance(v, jax.Array):
                continue
            if isinstance(v, (np.ndarray, list, tuple)):
                object.__setattr__(self, f, jnp.asarray(v, _leaf_dtype(f)))
            elif isinstance(v, (bool, int, float, np.number)):
                object.__setattr__(
                    self, f, int(v) if f == "n_levels" else float(v))
            # anything else (e.g. jax transform sentinels during pytree
            # unflattening inside vmap) passes through untouched
        object.__setattr__(self, "r_line_scale", float(self.r_line_scale))

    @property
    def tile_shape(self) -> Optional[Tuple[int, ...]]:
        """``(NB, NO)`` for a tile-indexed scenario batch, None for a scalar
        (whole-plan) scenario.  All non-scalar leaves must agree in shape."""
        shapes = {tuple(getattr(self, f).shape) for f in _LEAF_FIELDS
                  if isinstance(getattr(self, f), jax.Array)
                  and getattr(self, f).ndim > 0}
        if not shapes:
            return None
        if len(shapes) > 1:
            raise ValueError(f"inconsistent per-tile leaf shapes: "
                             f"{sorted(shapes)}")
        return shapes.pop()

    @property
    def is_ideal(self) -> bool:
        """True iff every perturbation is an exact identity (for per-tile
        batches: at every tile).  Cached -- the check runs once per
        Scenario object, not once per matmul call."""
        c = self.__dict__.get("_is_ideal")
        if c is None:
            c = (_leaf_max(self.prog_sigma) == 0.0
                 and _leaf_max(self.read_sigma) == 0.0
                 and _leaf_max(self.p_stuck_on) == 0.0
                 and _leaf_max(self.p_stuck_off) == 0.0
                 and ((_leaf_max(self.drift_nu) == 0.0
                       and _leaf_min(self.drift_nu) == 0.0)
                      or _leaf_max(self.drift_t) <= 0.0)
                 and self.r_line_scale == 1.0
                 and _leaf_max(self.n_levels) < 2)
            object.__setattr__(self, "_is_ideal", c)
        return c

    @property
    def has_read_noise(self) -> bool:
        """True if any tile draws cycle-to-cycle read noise (cached)."""
        c = self.__dict__.get("_has_read_noise")
        if c is None:
            c = _leaf_max(self.read_sigma) > 0.0
            object.__setattr__(self, "_has_read_noise", c)
        return c

    @property
    def has_stuck_off(self) -> bool:
        """True if any tile has a nonzero stuck-at-G_off rate (cached) --
        the trigger for fault-aware remapping."""
        c = self.__dict__.get("_has_stuck_off")
        if c is None:
            c = _leaf_max(self.p_stuck_off) > 0.0
            object.__setattr__(self, "_has_stuck_off", c)
        return c


def _flatten(s: Scenario):
    return (tuple(getattr(s, f) for f in _LEAF_FIELDS),
            tuple(getattr(s, f) for f in _AUX_FIELDS))


def _unflatten(aux, leaves) -> Scenario:
    kw = dict(zip(_LEAF_FIELDS, leaves))
    kw.update(zip(_AUX_FIELDS, aux))
    return Scenario(**kw)


jax.tree_util.register_pytree_node(Scenario, _flatten, _unflatten)


# --------------------------------------------------------------------------- #
# Per-tile scenario batches
# --------------------------------------------------------------------------- #
def tile_scenarios(nb: int, no: int, base: Optional[Scenario] = None,
                   *, name: Optional[str] = None, **fields) -> Scenario:
    """Build a ``(nb, no)``-tile-indexed scenario batch.

    Every numeric leaf is broadcast to an ``(nb, no)`` array -- one value
    per (block-group, output-group) tile of a ``ConductancePlan`` -- so
    ``perturb_plan`` gives each tile its own sigma / drift level and its
    own device draw.  ``fields`` override ``base`` per leaf and may be
    scalars (uniform) or anything broadcastable to ``(nb, no)``:

        tile_scenarios(2, 8, prog_sigma=0.05)                  # uniform
        tile_scenarios(2, 8, prog_sigma=jnp.linspace(...))     # gradient

    ``r_line_scale`` stays a whole-plan static (it rewrites the circuit
    solver's ``CircuitParams``, which has no tile axis).
    """
    base = base if base is not None else Scenario(name="tiled")
    kw = {}
    for f in _LEAF_FIELDS:
        v = fields.pop(f, getattr(base, f))
        kw[f] = jnp.broadcast_to(jnp.asarray(v, _leaf_dtype(f)), (nb, no))
    if fields:
        raise TypeError(f"unknown Scenario fields: {sorted(fields)}")
    return Scenario(name=name or base.name,
                    r_line_scale=base.r_line_scale, **kw)


def collapse_tiles(s: Scenario) -> Scenario:
    """Mean-field scalar Scenario from a tile-indexed batch (identity for
    scalar scenarios).  For consumers that need ONE corner -- e.g. the
    noise-aware training-data generator, which perturbs per-sample block
    tensors that have no (NB, NO) lattice to index."""
    if s.tile_shape is None:
        return s
    kw = {}
    for f in _LEAF_FIELDS:
        m = float(jnp.mean(jnp.asarray(getattr(s, f), jnp.float32)))
        kw[f] = int(round(m)) if f == "n_levels" else m
    return Scenario(name=s.name, r_line_scale=s.r_line_scale, **kw)


# --------------------------------------------------------------------------- #
# Scenario feature encoding (the conditioned emulator's corner descriptor)
# --------------------------------------------------------------------------- #
# Canonical layout of the scenario-feature vector appended to the emulator's
# peripheral features (docs/emulator.md).  The ordering is part of the
# trained-params contract: a conditioned Conv4Xbar's fc0 rows are bound to
# THESE positions, so the tuple is append-only and JSON-stable (tests pin
# it).  Per-tile scenario batches are reduced to fixed-length summary stats
# (mean + max over the (NB, NO) tile lattice), so scalar and tiled corners
# share one encoding.  Every feature is exactly 0.0 at the ideal corner --
# that is what makes the ideal conditioned forward bit-identical to the
# unconditioned fast path (the zero block contributes nothing to fc0).
SCENARIO_FEATURE_NAMES: Tuple[str, ...] = (
    "prog_sigma_mean", "prog_sigma_max",
    "read_sigma_mean", "read_sigma_max",
    "p_stuck_on_mean", "p_stuck_on_max",
    "p_stuck_off_mean", "p_stuck_off_max",
    "drift_nu_mean", "drift_nu_max",
    "drift_age",          # log1p(mean(drift_t / drift_t0)) / 16
    "r_line_scale_m1",    # r_line_scale - 1
    "quant_inv",          # 2 / n_levels for n_levels >= 2, else 0
)
N_SCENARIO_FEATURES = len(SCENARIO_FEATURE_NAMES)

# drift_age normalizer: log1p(1 month / 1 s) ~= 14.8, so /16 keeps the
# feature in [0, ~1] over any plausible service life
_DRIFT_AGE_SCALE = 16.0


def scenario_features(s: Scenario) -> jax.Array:
    """Encode a scenario as the fixed-length ``(N_SCENARIO_FEATURES,)`` f32
    vector a conditioned emulator consumes (layout:
    ``SCENARIO_FEATURE_NAMES``).

    Pure jnp on the numeric leaves, so it traces: inside the executor's
    scenario forward (or a ``ScenarioSweep``) the features are functions of
    traced leaves and corner/age changes never recompile.  Per-tile
    ``(NB, NO)`` leaves reduce to (mean, max) summary stats; scalar leaves
    reduce to themselves, so a scalar corner and its uniform tile batch
    encode identically.  ``r_line_scale`` is static aux data and enters as
    a constant.  The ideal scenario encodes to the all-zero vector:

    >>> import numpy as np
    >>> from repro.nonideal import Scenario, scenario_features
    >>> bool(np.all(np.asarray(scenario_features(Scenario())) == 0.0))
    True
    """
    def mean(v):
        return jnp.mean(jnp.asarray(v, jnp.float32))

    def mx(v):
        return jnp.max(jnp.asarray(v, jnp.float32))

    age = jnp.log1p(mean(s.drift_t) / jnp.maximum(mean(s.drift_t0), 1e-30)) \
        / _DRIFT_AGE_SCALE
    nl = mx(s.n_levels)
    quant = jnp.where(nl >= 2.0, 2.0 / jnp.maximum(nl, 2.0), 0.0)
    return jnp.stack([
        mean(s.prog_sigma), mx(s.prog_sigma),
        mean(s.read_sigma), mx(s.read_sigma),
        mean(s.p_stuck_on), mx(s.p_stuck_on),
        mean(s.p_stuck_off), mx(s.p_stuck_off),
        mean(s.drift_nu), mx(s.drift_nu),
        age,
        jnp.asarray(s.r_line_scale - 1.0, jnp.float32),
        quant,
    ])


def scenario_features_tiled(s: Scenario, nb: Optional[int] = None,
                            no: Optional[int] = None) -> jax.Array:
    """Per-tile feature operand: encode a scenario as an ``(NB, NO,
    N_SCENARIO_FEATURES)`` f32 lattice, one feature vector per
    (block-group, output-group) tile.

    This is the heterogeneity-preserving sibling of
    ``scenario_features``: instead of collapsing a tiled corner to fleet
    (mean, max) summaries, every tile gets its own vector, encoded
    exactly as if that tile were a scalar corner of its own values --
    for each (mean, max) feature pair the tile's mean equals its max
    equals its value, which is precisely the distribution the
    conditioned net was trained on (its training corners are scalar
    scenarios).  A *uniform* tile batch therefore encodes each tile
    identically to ``scenario_features`` of the collapsed scalar corner,
    and the ideal corner encodes to the all-zero lattice (so the plain
    fast path stays bit-identical).

    Scalar scenarios broadcast to the lattice; pass ``nb``/``no`` for
    those (tiled scenarios carry their own ``tile_shape``).  Pure jnp on
    the numeric leaves, so it traces -- aging / corner swaps through a
    tiled feature operand never recompile.

    >>> import numpy as np
    >>> from repro.nonideal import (Scenario, scenario_features,
    ...                             scenario_features_tiled, tile_scenarios)
    >>> t = scenario_features_tiled(Scenario(), nb=2, no=3)
    >>> t.shape == (2, 3, N_SCENARIO_FEATURES) and bool(np.all(t == 0))
    True
    >>> u = tile_scenarios(2, 3, prog_sigma=0.05, drift_nu=0.02)
    >>> bool(np.allclose(scenario_features_tiled(u)[1, 2],
    ...                  scenario_features(collapse_tiles(u))))
    True
    """
    shape = s.tile_shape
    if shape is None:
        if nb is None or no is None:
            raise ValueError("scalar scenario needs explicit (nb, no)")
        shape = (int(nb), int(no))

    def bc(v):
        return jnp.broadcast_to(jnp.asarray(v, jnp.float32), shape)

    age = jnp.log1p(bc(s.drift_t) / jnp.maximum(bc(s.drift_t0), 1e-30)) \
        / _DRIFT_AGE_SCALE
    nl = bc(s.n_levels)
    quant = jnp.where(nl >= 2.0, 2.0 / jnp.maximum(nl, 2.0), 0.0)
    ps, rs = bc(s.prog_sigma), bc(s.read_sigma)
    on, off = bc(s.p_stuck_on), bc(s.p_stuck_off)
    nu = bc(s.drift_nu)
    rline = jnp.full(shape, s.r_line_scale - 1.0, jnp.float32)
    return jnp.stack([ps, ps, rs, rs, on, on, off, off, nu, nu,
                      age, rline, quant], axis=-1)


# --------------------------------------------------------------------------- #
# String-keyed registry + JSON (de)serialization
# --------------------------------------------------------------------------- #
_REGISTRY: Dict[str, Scenario] = {}


def register_scenario(s: Scenario, overwrite: bool = False) -> Scenario:
    """Add ``s`` to the process-wide registry under ``s.name``.  Refuses
    silent overwrites (pass ``overwrite=True`` to replace); returns ``s``
    for chaining."""
    if s.name in _REGISTRY and not overwrite:
        raise ValueError(f"scenario {s.name!r} already registered "
                         f"(pass overwrite=True to replace)")
    _REGISTRY[s.name] = s
    return s


def get_scenario(name: str) -> Scenario:
    """Look up a registered scenario by name (KeyError lists what exists
    -- this is what ``AnalogConfig.scenario`` / ``serve --scenario``
    resolve through)."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; registered: "
                       f"{sorted(_REGISTRY)}") from None


def list_scenarios() -> Tuple[str, ...]:
    """Sorted names of every registered scenario (built-ins + user)."""
    return tuple(sorted(_REGISTRY))


def _json_default(o):
    if isinstance(o, (jax.Array, np.ndarray)):
        return np.asarray(o).tolist()
    raise TypeError(f"not JSON serializable: {type(o)}")


def scenario_to_json(s: Scenario) -> str:
    """Canonical JSON encoding (sorted keys; per-tile array leaves become
    nested lists).  Inverse of ``scenario_from_json``."""
    return json.dumps(dataclasses.asdict(s), sort_keys=True,
                      default=_json_default)


def scenario_from_json(doc: str) -> Scenario:
    """Parse ``scenario_to_json`` output; rejects unknown fields.  List
    values round-trip back into (NB, NO) per-tile array leaves."""
    d = json.loads(doc)
    known = {f.name for f in dataclasses.fields(Scenario)}
    bad = set(d) - known
    if bad:
        raise ValueError(f"unknown Scenario fields in JSON: {sorted(bad)}")
    return Scenario(**d)


# Built-in corners. "stressed" is the serving-overhead benchmark scenario
# (bench_speed's speed_matmul_emulator_nonideal row).
BUILTIN_SCENARIOS: Tuple[Scenario, ...] = (
    Scenario(name="ideal"),
    Scenario(name="prog_mild", prog_sigma=0.03),
    Scenario(name="prog_heavy", prog_sigma=0.12),
    Scenario(name="read_noisy", read_sigma=0.05),
    Scenario(name="stuck_1pct", p_stuck_on=0.005, p_stuck_off=0.005),
    Scenario(name="quantized_16", n_levels=16),
    Scenario(name="drift_1day", drift_nu=0.05, drift_t=86_400.0),
    Scenario(name="ir_degraded", r_line_scale=4.0),
    Scenario(name="stressed", prog_sigma=0.08, read_sigma=0.03,
             p_stuck_on=0.002, p_stuck_off=0.005,
             drift_nu=0.03, drift_t=3_600.0, n_levels=32),
)
for _s in BUILTIN_SCENARIOS:
    register_scenario(_s)
del _s
