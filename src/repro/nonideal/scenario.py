"""Device non-ideality scenarios: what can go wrong between the weights you
wanted and the conductances the crossbar actually reads.

A ``Scenario`` is a frozen dataclass registered as a jax pytree so its
numeric knobs enter compiled functions as *traced* leaves -- sweeping
``prog_sigma`` (or any other float field) across values reuses one
compilation.  ``name`` and ``r_line_scale`` are static aux data:
``r_line_scale`` rewrites ``CircuitParams`` (a hashed static), so changing
it recompiles the circuit backend by design.

Fields (composition order documented in docs/nonideal.md):
  n_levels     -- quantized programming levels over [g_min, g_max]
                  (0 or 1 = continuous programming)
  prog_sigma   -- lognormal programming variation: g <- g * exp(sigma * eps),
                  one draw per device (fixed by the device key)
  drift_nu     -- retention drift g <- g * (t / t0)^-nu  (clipped to range)
  drift_t      -- seconds since programming (0 = no drift)
  drift_t0     -- drift reference time
  p_stuck_on   -- fraction of cells stuck at g_max (fault mask, per device)
  p_stuck_off  -- fraction of cells stuck at g_min
  read_sigma   -- cycle-to-cycle multiplicative read noise, redrawn per call
                  on the eager per-tag path and per draw in sweeps; under an
                  ENCLOSING jit (e.g. a compiled decode step) the draw is
                  baked at trace time -- see docs/nonideal.md
  r_line_scale -- bitline/integrator input-resistance multiplier (circuit
                  solver only; the emulator sees it through noise-aware
                  retraining, see nonideal/data.py)

Every perturbation is an exact identity at its ideal value (verified
bitwise in tests), so the ideal scenario cannot change serving numerics.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass
from typing import Dict, Tuple

import jax

_LEAF_FIELDS: Tuple[str, ...] = (
    "prog_sigma", "read_sigma", "p_stuck_on", "p_stuck_off",
    "drift_nu", "drift_t", "drift_t0", "n_levels",
)
_AUX_FIELDS: Tuple[str, ...] = ("name", "r_line_scale")


@dataclass(frozen=True)
class Scenario:
    name: str = "ideal"
    prog_sigma: float = 0.0
    read_sigma: float = 0.0
    p_stuck_on: float = 0.0
    p_stuck_off: float = 0.0
    drift_nu: float = 0.0
    drift_t: float = 0.0
    drift_t0: float = 1.0
    r_line_scale: float = 1.0
    n_levels: int = 0

    def __post_init__(self):
        # pin leaf dtypes so jit sees stable (weak f32 / i32) avals across
        # sweeps -- Scenario(prog_sigma=0) must not retrace vs prog_sigma=0.0
        for f in _LEAF_FIELDS:
            v = getattr(self, f)
            if not isinstance(v, jax.Array):
                object.__setattr__(
                    self, f, int(v) if f == "n_levels" else float(v))
        object.__setattr__(self, "r_line_scale", float(self.r_line_scale))

    @property
    def is_ideal(self) -> bool:
        """True iff every perturbation is an exact identity."""
        return (self.prog_sigma == 0.0 and self.read_sigma == 0.0
                and self.p_stuck_on == 0.0 and self.p_stuck_off == 0.0
                and (self.drift_nu == 0.0 or self.drift_t <= 0.0)
                and self.r_line_scale == 1.0 and self.n_levels < 2)


def _flatten(s: Scenario):
    return (tuple(getattr(s, f) for f in _LEAF_FIELDS),
            tuple(getattr(s, f) for f in _AUX_FIELDS))


def _unflatten(aux, leaves) -> Scenario:
    kw = dict(zip(_LEAF_FIELDS, leaves))
    kw.update(zip(_AUX_FIELDS, aux))
    return Scenario(**kw)


jax.tree_util.register_pytree_node(Scenario, _flatten, _unflatten)


# --------------------------------------------------------------------------- #
# String-keyed registry + JSON (de)serialization
# --------------------------------------------------------------------------- #
_REGISTRY: Dict[str, Scenario] = {}


def register_scenario(s: Scenario, overwrite: bool = False) -> Scenario:
    if s.name in _REGISTRY and not overwrite:
        raise ValueError(f"scenario {s.name!r} already registered "
                         f"(pass overwrite=True to replace)")
    _REGISTRY[s.name] = s
    return s


def get_scenario(name: str) -> Scenario:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; registered: "
                       f"{sorted(_REGISTRY)}") from None


def list_scenarios() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def scenario_to_json(s: Scenario) -> str:
    return json.dumps(dataclasses.asdict(s), sort_keys=True)


def scenario_from_json(doc: str) -> Scenario:
    d = json.loads(doc)
    known = {f.name for f in dataclasses.fields(Scenario)}
    bad = set(d) - known
    if bad:
        raise ValueError(f"unknown Scenario fields in JSON: {sorted(bad)}")
    return Scenario(**d)


# Built-in corners. "stressed" is the serving-overhead benchmark scenario
# (bench_speed's speed_matmul_emulator_nonideal row).
BUILTIN_SCENARIOS: Tuple[Scenario, ...] = (
    Scenario(name="ideal"),
    Scenario(name="prog_mild", prog_sigma=0.03),
    Scenario(name="prog_heavy", prog_sigma=0.12),
    Scenario(name="read_noisy", read_sigma=0.05),
    Scenario(name="stuck_1pct", p_stuck_on=0.005, p_stuck_off=0.005),
    Scenario(name="quantized_16", n_levels=16),
    Scenario(name="drift_1day", drift_nu=0.05, drift_t=86_400.0),
    Scenario(name="ir_degraded", r_line_scale=4.0),
    Scenario(name="stressed", prog_sigma=0.08, read_sigma=0.03,
             p_stuck_on=0.002, p_stuck_off=0.005,
             drift_nu=0.03, drift_t=3_600.0, n_levels=32),
)
for _s in BUILTIN_SCENARIOS:
    register_scenario(_s)
del _s
