"""Fleet lifetime management: drift-scheduled recalibration / retraining.

A deployed crossbar fleet ages: retention drift shrinks conductances as
``g * (t / t0)^-nu``, while the programming-variation draw and the stuck
fault population fixed at fabrication persist.  Serving accuracy decays
not (mostly) because the hardware forgets, but because the *calibration
and the emulator were fitted to the young device*.  This module walks a
drift timeline (t = 1h / 1d / 1mo by default) and, at each checkpoint,
applies the three mitigations the rest of the subsystem provides:

  * **remap**    -- stuck-fault-aware column remapping
                    (``perturb.remap_plan``, ``AnalogExecutor.fault_remap``)
  * **recalibrate** -- noise-aware affine refit against the aged device
                    (``AnalogExecutor.calibrate``)
  * **retrain**  -- noise-aware emulator retraining on the aged corner,
                    hot-swapped with ``AnalogExecutor.deploy(params=...)``

A fourth option supersedes the third: a *scenario-conditioned* emulator
(``nonideal.data.train_conditioned_emulator``, docs/emulator.md) reads
the aged corner off its scenario-feature input, so the scheduler limits
retraining to a ONE-TIME deployment field calibration
(``make_conditioned_field_calibrator``: the realized device across its
predicted drift trajectory, knowable at t = 0 because drift is
deterministic given the fabrication draw) and the walk needs zero
retraining between checkpoints (``prefer_conditioned``) -- the
per-checkpoint fine-tune path stays available as the fallback and the
accuracy baseline.

All three ride the executor's per-tag *unified forward*
(``core.deployment.DeploymentState``): perturbed conductances,
calibration affine, remap permutation and emulator params are leaves of
the ONE traced deployment-state argument -- so an entire lifetime walk
(ages x remaps x recalibrations x retrains) compiles exactly ONCE per
(tag, shape).  ``benchmarks/bench_lifetime.py`` productionizes this into
accuracy-vs-age curves with and without mitigation; docs/lifetime.md is
the narrative version.

Calibration transfer: after the deployment-time cold fit, every
checkpoint's affine refit warm-starts from the previous checkpoint's
affine (drift is mostly a scale shift), cutting the probe budget in half
(``AnalogExecutor.calibrate(warm_start=True)``; the per-checkpoint
``calib_n`` is recorded in the scheduler history and asserted in tests).

The fleet identity lives in the executor's ``scenario_key``: the
scheduler ages the scenario (rewrites ``drift_t``) under a FIXED key --
``deploy(scenario=aged)`` keeps the key, so every checkpoint sees the
same fabricated devices (the same sigma draw, the same stuck cells),
just older.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.nonideal.scenario import Scenario, collapse_tiles
from repro.obs import OBS

# Canonical drift checkpoints: (label, seconds since programming).
DEFAULT_TIMELINE: Tuple[Tuple[str, float], ...] = (
    ("1h", 3_600.0),
    ("1d", 86_400.0),
    ("1mo", 2_592_000.0),
)


def scenario_at_age(scenario: Scenario, t: float) -> Scenario:
    """The same device corner, ``t`` seconds after programming.

    Rewrites ``drift_t`` only (per-tile aware: for a tile-indexed batch
    the age is broadcast to the (NB, NO) lattice so leaf avals stay
    stable across checkpoints).  Everything else -- sigma, fault rates,
    the device key held by the executor -- is unchanged: a fleet ages, it
    is not refabricated."""
    ts = scenario.tile_shape
    tt = float(t) if ts is None else jnp.full(ts, float(t), jnp.float32)
    return dataclasses.replace(scenario, drift_t=tt)


def make_noise_aware_retrainer(geom, acfg, cp, key: jax.Array,
                               n: int = 4096, epochs: int = 30,
                               lr: float = 2e-4) -> Callable:
    """Retrain callback for ``LifetimeScheduler``: warm-start fine-tuning
    of the SERVING params on circuit data perturbed by the *aged* scenario
    (``nonideal.data.finetune_emulator``).

    Fine-tuning, not from-scratch retraining: an independently trained net
    differs from the serving net by far more than aging shifted the
    response surface, so scratch retraining pays full model variance at
    every checkpoint and can *lose* accuracy.  A few low-lr epochs from
    the current params track the drifting operating region and nothing
    else.  (From-scratch remains available as
    ``data.train_noise_aware_emulator`` for corners that change the
    response function wholesale, e.g. large ``r_line_scale``.)

    Tile-indexed scenario batches are collapsed to their mean-field
    corner (the data generator samples block tensors with no (NB, NO)
    lattice to index).  The key is fixed across checkpoints: common
    random numbers keep the accuracy-vs-age curve free of data-draw
    jitter."""
    from repro.nonideal.data import finetune_emulator

    def retrain(scenario: Scenario, t: float, ex, w, tag: str) -> dict:
        return finetune_emulator(key, ex.emulator_params, geom, acfg, cp,
                                 collapse_tiles(scenario), n=n,
                                 epochs=epochs, lr=lr)

    return retrain


def _probe_blocks(ex, plan, key: jax.Array, n: int, w, solve):
    """Serving-exact probe blocks for field fine-tuning: drive ``n``
    random inputs through the plan's rail/tile path exactly as
    ``raw_matmul`` does (dual rail, gate overdrive), label with the
    circuit ``solve`` fn.  Returns ``(X_normalized, periph2, Y)`` --
    every retrain/calibration callback shares this one construction so
    the train/serve drive discipline cannot drift apart."""
    from repro.core.emulator import normalize_features

    xc = jax.random.normal(key, (n, w.shape[0])) * 0.5
    x2 = xc.astype(jnp.float32)
    x_scale = jnp.maximum(jnp.max(jnp.abs(x2)), 1e-9)
    rails = jnp.concatenate([jnp.clip(x2, 0.0, None),
                             jnp.clip(-x2, 0.0, None)], axis=0)
    vb01 = plan.tile_v(ex._drive01(rails / x_scale), 1.0)
    xb = plan.build_x(vb01 * ex.acfg.v_read).astype(jnp.float32)
    periph = jnp.concatenate([jnp.ones((xb.shape[0], 1), jnp.float32),
                              jnp.zeros((xb.shape[0], 1), jnp.float32)],
                             axis=-1)
    return normalize_features(xb, ex.acfg), periph, solve(xb, periph)


def make_field_retrainer(key: jax.Array, n: int = 192, epochs: int = 40,
                         batch_size: int = 512, lr: float = 3e-4) -> Callable:
    """Serving-distribution retrain callback: fine-tune the emulator on
    the fleet's OWN aged blocks under its OWN drive statistics.

    ``make_noise_aware_retrainer`` samples the corner's conductance
    *distribution*; this one goes further and trains on the exact device
    the executor serves: the cached scenario plan (device draw, drift,
    remap included), driven by calibration-style inputs through the same
    rail/tile path ``raw_matmul`` uses, labeled by the scenario-adjusted
    circuit solver.  That closes the train/serve distribution gap -- the
    deployed-fleet analogue of collecting input traces on your own
    hardware and recalibrating against a SPICE reference.  ``n`` is the
    number of (K,)-input probes; each contributes ``2 * n_blocks`` block
    samples (both rails)."""
    from repro.core.circuit import block_response
    from repro.nonideal.data import finetune_emulator
    from repro.nonideal.perturb import scenario_circuit_params

    def retrain(scenario: Scenario, t: float, ex, w, tag: str) -> dict:
        plan = ex._scenario_plan(tag, w)          # the fleet's aged devices
        cp_s = scenario_circuit_params(ex.cp, collapse_tiles(scenario))
        solve = jax.jit(lambda b, p: block_response(b, cp_s, p))
        data = _probe_blocks(ex, plan, jax.random.fold_in(key, 0xF1E1D),
                             n, w, solve)
        return finetune_emulator(key, ex.emulator_params, ex.geom, ex.acfg,
                                 ex.cp, scenario, epochs=epochs,
                                 batch_size=batch_size, lr=lr, data=data)

    return retrain


def make_conditioned_field_calibrator(key: jax.Array,
                                      ages: Tuple[float, ...] = (
                                          0.0, 3_600.0, 86_400.0,
                                          604_800.0, 2_592_000.0),
                                      n: int = 96, epochs: int = 240,
                                      batch_size: int = 512,
                                      lr: float = 3e-4) -> Callable:
    """Deployment-only field calibration for a CONDITIONED emulator.

    ``make_field_retrainer`` closes the train/serve gap by fine-tuning on
    the fleet's own realized devices -- but it must re-run at every
    checkpoint because the unconditioned net cannot represent age.  A
    conditioned net can, so the device-specific adaptation is paid ONCE,
    at deployment: retention drift is deterministic given ``(nu, t)``
    (``g * (t/t0)^-nu`` on the fabrication draw the executor already
    holds), so the fleet's aged devices are *predictable* at t = 0.  This
    callback fine-tunes on the realized device at every age in ``ages``
    jointly -- each age's blocks carrying that age's
    ``scenario_features`` in the peripheral vector -- and returns None at
    every later checkpoint (zero retraining between checkpoints; the
    scheduler records ``retrained`` only at deploy).  The conditioned
    forward then tracks the fleet between and beyond the calibrated ages
    through its ``drift_age`` input.  The default ``epochs`` is sized to
    the per-checkpoint loop's CUMULATIVE optimization budget (4-5
    checkpoints x ~50 epochs) -- same total work, paid once, off the
    serving path; ``bench_lifetime`` shows it matching or beating the
    per-checkpoint fine-tunes at every drift checkpoint."""
    from repro.core.circuit import block_response
    from repro.nonideal.data import finetune_emulator
    from repro.nonideal.perturb import scenario_circuit_params
    from repro.nonideal.scenario import (scenario_features,
                                         scenario_features_tiled)

    def retrain(scenario: Scenario, t: float, ex, w,
                tag: str) -> Optional[dict]:
        if t > 0.0:
            return None                   # deployment-only
        cp_s = scenario_circuit_params(ex.cp, collapse_tiles(scenario))
        solve = jax.jit(lambda b, p2: block_response(b, cp_s, p2))
        xs, ps, ys = [], [], []
        for i, ta in enumerate(ages):
            aged = scenario_at_age(scenario, ta)
            # serving-exact aged plan: same fabrication key, same remap
            # discipline the executor will use at this age
            ex.deploy(scenario=aged, key=ex.scenario_key)
            plan = ex._scenario_plan(tag, w)
            X, periph2, y = _probe_blocks(ex, plan,
                                          jax.random.fold_in(key, i),
                                          n, w, solve)
            if aged.tile_shape is not None:
                # per-tile feature operands, exactly as serving feeds
                # them: one vector per tile, tiled across the probe rows
                # (build_x rows are lattice-innermost)
                sf2 = jnp.asarray(scenario_features_tiled(aged), jnp.float32)
                sf2 = sf2.reshape(-1, sf2.shape[-1])
                sfr = jnp.tile(sf2, (X.shape[0] // sf2.shape[0], 1))
            else:
                sf = jnp.asarray(scenario_features(aged), jnp.float32)
                sfr = jnp.broadcast_to(sf[None], (X.shape[0], sf.shape[0]))
            xs.append(X)
            ps.append(jnp.concatenate([periph2, sfr], axis=-1))
            ys.append(y)
        ex.deploy(scenario=scenario_at_age(scenario, 0.0),
                  key=ex.scenario_key)
        data = (jnp.concatenate(xs), jnp.concatenate(ps),
                jnp.concatenate(ys))
        return finetune_emulator(key, ex.emulator_params, ex.geom, ex.acfg,
                                 ex.cp, scenario, epochs=epochs,
                                 batch_size=batch_size, lr=lr, data=data)

    return retrain


@dataclass
class LifetimeScheduler:
    """Walk an aging fleet through drift checkpoints, mitigating as it goes.

    Attributes:
      ex:          the serving ``AnalogExecutor`` to manage (mutated).
      scenario:    the fleet's device corner at programming time (t = 0);
                   scalar or per-tile (``tile_scenarios``).
      timeline:    ``(label, seconds)`` checkpoints, ``DEFAULT_TIMELINE``
                   = 1h / 1d / 1mo.
      remap:       enable stuck-fault-aware column remapping.
      recalibrate: refit the volts->logical affine at every checkpoint.
      retrain:     optional ``(aged_scenario, t, ex, w, tag) -> params``
                   callback (``make_field_retrainer`` fine-tunes on the
                   fleet's own serving distribution;
                   ``make_noise_aware_retrainer`` on the corner's
                   distribution); returned params are hot-swapped via
                   ``deploy(params=...)``.
      prefer_conditioned: when the executor serves a *scenario-conditioned*
                   emulator (``AnalogExecutor.emulator_conditioned``), run
                   the retrain callback at DEPLOYMENT only (one-time field
                   calibration, e.g.
                   ``make_conditioned_field_calibrator``) and never
                   between checkpoints -- the net reads the aged corner
                   off its scenario-feature input (docs/emulator.md).
                   Set False to force per-checkpoint fine-tuning (the
                   accuracy baseline ``bench_lifetime`` compares
                   against).
      key:         fleet fabrication key (fixed: the same devices age
                   through every checkpoint).
      calib_n:     calibration sample count (keep small for the circuit
                   backend; every sample is a block solve).

    ``deploy`` programs the fleet at t = 0 and calibrates (cold, full
    probe budget); ``step`` ages it to one checkpoint and warm-starts the
    affine refit from the previous checkpoint's fit (half budget,
    recorded as ``calib_n`` in the history); ``run`` does the whole walk
    and returns one record per checkpoint.  None of it touches the
    executor's compiled forwards: every intervention is a leaf of the
    traced ``DeploymentState`` (asserted by tests and bench_lifetime).
    """
    ex: "object"                       # AnalogExecutor (kept untyped: no cycle)
    scenario: Scenario
    timeline: Tuple[Tuple[str, float], ...] = DEFAULT_TIMELINE
    remap: bool = True
    recalibrate: bool = True
    retrain: Optional[Callable[..., Optional[dict]]] = None
    prefer_conditioned: bool = True
    key: Optional[jax.Array] = None
    calib_n: int = 128
    history: List[dict] = field(default_factory=list)

    def __post_init__(self):
        if self.key is None:
            self.key = jax.random.PRNGKey(0)

    @property
    def conditioned(self) -> bool:
        """True when the walk rides a scenario-conditioned emulator instead
        of per-checkpoint fine-tunes (see ``prefer_conditioned``)."""
        return self.prefer_conditioned \
            and getattr(self.ex, "emulator_conditioned", False)

    def _retrain(self, scenario: Scenario, t: float, w, tag: str) -> bool:
        """Run the retrain callback under the conditioned-first policy
        (conditioned net => deployment-time calibration only, zero
        retraining between checkpoints); True iff params were
        hot-swapped."""
        if self.retrain is None or (self.conditioned and t > 0.0):
            return False
        params = self.retrain(scenario, t, self.ex, w, tag)
        if params is None:
            return False
        self.ex.deploy(params=params)
        return True

    def _calibrate(self, w, tag: str, step: int):
        """Refit the affine; checkpoints past deployment warm-start from
        the previous fit on half the probe budget (calibration
        transfer)."""
        k = jax.random.fold_in(jax.random.fold_in(self.key, 0xCA1), step)
        out = self.ex.calibrate(k, w, tag, n=self.calib_n,
                                warm_start=(step > 0))
        self._calib_used = self.ex._last_calib_n
        return out

    def _observe(self, tag: str, t: float, event: str, retrained: bool,
                 recalibrated: bool) -> None:
        """Fleet-health telemetry for one checkpoint (no-op when the
        registry is disabled): current drift age and probe budget as
        gauges, every applied mitigation as an event counter
        (docs/observability.md)."""
        OBS.gauge("lifetime_drift_age_seconds",
                  "drift age the fleet is currently deployed at",
                  tag=tag).set(t)
        OBS.gauge("lifetime_calib_probes",
                  "probe budget spent by the last calibration at this "
                  "checkpoint (0 = not recalibrated)",
                  tag=tag).set(self._calib_used)
        OBS.counter("lifetime_checkpoints_total",
                    "lifetime checkpoints walked", tag=tag).inc()
        events = [event]
        if event == "deploy" and self.remap:
            events.append("remap")
        if retrained:
            events.append("retrain")
        if recalibrated:
            events.append("recalibrate")
        for ev in events:
            OBS.counter("lifetime_events_total",
                        "mitigation events applied across the lifetime "
                        "walk (deploy/remap/retrain/recalibrate/"
                        "checkpoint)", tag=tag, event=ev).inc()

    def deploy(self, w, tag: str) -> Scenario:
        """Program the fleet (t = 0) and fit the initial calibration.

        Both the mitigated and the unmitigated lifetime start here: a
        freshly deployed fleet is always calibrated once (cold, full
        probe budget).  A configured ``retrain`` callback also runs at
        deployment -- field calibration of the emulator against the fresh
        hardware, before drift sets in -- unless a conditioned net
        supersedes it (``prefer_conditioned``)."""
        sc0 = scenario_at_age(self.scenario, 0.0)
        self.ex.deploy(scenario=sc0, key=self.key, remap=self.remap)
        retrained = self._retrain(sc0, 0.0, w, tag)
        self._calib_used = 0
        self._calibrate(w, tag, 0)
        self.history = [{"label": "t0", "t": 0.0, "retrained": retrained,
                         "conditioned": self.conditioned,
                         "calib_n": self._calib_used}]
        if OBS.enabled:
            self._observe(tag, 0.0, "deploy", retrained, True)
        return sc0

    def step(self, w, tag: str, label: str, t: float) -> Scenario:
        """Age the fleet to ``t`` seconds and apply the configured
        mitigations (retrain -> hot-swap -> recalibrate, in that order:
        the affine must be fitted against the params that will serve).
        ``deploy(scenario=aged)`` keeps the fleet key and remap policy:
        same devices, older."""
        aged = scenario_at_age(self.scenario, t)
        self.ex.deploy(scenario=aged, key=self.key)    # same fleet, older
        retrained = self._retrain(aged, t, w, tag)
        self._calib_used = 0
        if self.recalibrate:
            self._calibrate(w, tag, len(self.history))
        self.history.append({"label": label, "t": t, "retrained": retrained,
                             "conditioned": self.conditioned,
                             "calib_n": self._calib_used})
        if OBS.enabled:
            self._observe(tag, t, "checkpoint", retrained, self.recalibrate)
        return aged

    def run(self, w, tag: str, x) -> List[dict]:
        """Deploy, then walk every checkpoint; returns one record per
        checkpoint: ``{"label", "t", "retrained", "y"}`` with ``y`` the
        calibrated analog output of ``x @ w`` at that age."""
        self.deploy(w, tag)
        records = [{**self.history[-1], "y": self.ex.matmul(x, w, tag)}]
        for label, t in self.timeline:
            self.step(w, tag, label, t)
            records.append({**self.history[-1],
                            "y": self.ex.matmul(x, w, tag)})
        return records
