"""repro.nonideal -- device non-ideality & fault-injection subsystem.

Composable crossbar device corners (programming variation, read noise,
stuck cells, retention drift, line resistance, quantized levels) applied at
the conductance-plan level so one implementation serves the circuit,
analytic and emulator backends.  See docs/nonideal.md.
"""
from repro.nonideal.data import (generate_dataset_nonideal,
                                 train_noise_aware_emulator)
from repro.nonideal.perturb import (apply_read_noise, drift_factor,
                                    perturb_conductance, perturb_plan,
                                    quantize_levels, sample_fault_masks,
                                    scenario_circuit_params)
from repro.nonideal.scenario import (BUILTIN_SCENARIOS, Scenario,
                                     get_scenario, list_scenarios,
                                     register_scenario, scenario_from_json,
                                     scenario_to_json)
from repro.nonideal.sweep import ScenarioSweep

__all__ = [
    "BUILTIN_SCENARIOS", "Scenario", "ScenarioSweep", "apply_read_noise",
    "drift_factor", "generate_dataset_nonideal", "get_scenario",
    "list_scenarios", "perturb_conductance", "perturb_plan",
    "quantize_levels", "register_scenario", "sample_fault_masks",
    "scenario_circuit_params", "scenario_from_json", "scenario_to_json",
    "train_noise_aware_emulator",
]
