"""repro.nonideal -- device non-ideality & fault-injection subsystem.

Composable crossbar device corners (programming variation, read noise,
stuck cells, retention drift, line resistance, quantized levels) applied at
the conductance-plan level so one implementation serves the circuit,
analytic and emulator backends.  Scenarios may be scalar (one corner for
the whole plan) or (NB, NO)-tile-indexed batches (``tile_scenarios``:
per-tile fab heterogeneity); ``remap_plan`` adds stuck-fault-aware column
remapping and ``lifetime`` schedules recalibration / retraining across a
drift timeline.  ``scenario_features`` encodes a corner as a fixed-length
vector and ``train_conditioned_emulator`` trains ONE emulator over the
whole corner manifold (zero per-corner retraining).  See docs/nonideal.md,
docs/lifetime.md and docs/emulator.md.
"""
from repro.nonideal.data import (ScenarioSpace, generate_dataset_conditioned,
                                 generate_dataset_nonideal, sample_scenarios,
                                 train_conditioned_emulator,
                                 train_noise_aware_emulator)
from repro.nonideal.lifetime import (DEFAULT_TIMELINE, LifetimeScheduler,
                                     make_conditioned_field_calibrator,
                                     make_field_retrainer,
                                     make_noise_aware_retrainer,
                                     scenario_at_age)
from repro.nonideal.perturb import (apply_read_noise, drift_factor,
                                    drift_factor_at_age,
                                    perturb_conductance, perturb_plan,
                                    quantize_levels, realized_fault_masks,
                                    remap_plan, sample_fault_masks,
                                    scenario_circuit_params)
from repro.nonideal.scenario import (BUILTIN_SCENARIOS, N_SCENARIO_FEATURES,
                                     SCENARIO_FEATURE_NAMES, Scenario,
                                     collapse_tiles, get_scenario,
                                     list_scenarios, register_scenario,
                                     scenario_features,
                                     scenario_features_tiled,
                                     scenario_from_json, scenario_to_json,
                                     tile_scenarios)
from repro.nonideal.sweep import ScenarioSweep

__all__ = [
    "BUILTIN_SCENARIOS", "DEFAULT_TIMELINE", "LifetimeScheduler",
    "N_SCENARIO_FEATURES", "SCENARIO_FEATURE_NAMES", "Scenario",
    "ScenarioSpace", "ScenarioSweep", "apply_read_noise", "collapse_tiles",
    "drift_factor", "drift_factor_at_age", "generate_dataset_conditioned",
    "generate_dataset_nonideal", "get_scenario", "list_scenarios",
    "make_conditioned_field_calibrator", "make_field_retrainer",
    "make_noise_aware_retrainer",
    "perturb_conductance", "perturb_plan",
    "quantize_levels", "realized_fault_masks", "register_scenario",
    "remap_plan", "sample_fault_masks", "sample_scenarios",
    "scenario_at_age", "scenario_circuit_params", "scenario_features",
    "scenario_features_tiled", "scenario_from_json", "scenario_to_json",
    "tile_scenarios",
    "train_conditioned_emulator", "train_noise_aware_emulator",
]
