"""Compile-once multi-sample scenario sweeps.

``ScenarioSweep`` evaluates one analog matmul under N independent device
draws of a scenario in a single compiled call: the scenario enters as a
pytree of traced leaves and the device/read keys as a vmapped key batch, so
a whole accuracy-vs-sigma (or vs-drift-time) curve reuses ONE executable.
``trace_count`` / ``cache_size()`` expose that invariant to tests and to
bench_robustness.

Per-tile scenario batches (``tile_scenarios``, leaves shaped (NB, NO))
sweep the same way: their leaves are traced (NB, NO) arrays, so varying a
heterogeneity *pattern* across calls still reuses one executable -- only
switching between scalar and tiled leaf shapes compiles a second variant.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.deployment import DeploymentState
from repro.nonideal.perturb import perturb_plan
from repro.nonideal.scenario import (N_SCENARIO_FEATURES, Scenario,
                                     scenario_features)


class ScenarioSweep:
    """N-device-draw scenario evaluation of ``ex.matmul(x, w, tag)``.

    The executor's own scenario state is bypassed for everything that is a
    traced scenario field: the sweep perturbs the cached base conductance
    plan directly, per draw, inside one jitted vmap.  Static circuit
    parameters are the exception -- the executor's CircuitParams (including
    an active scenario's r_line_scale) are baked at first trace, which is
    why swept scenarios must keep r_line_scale == 1.0 (enforced).
    Calibration (``ex.calibration[tag]``) is applied, so outputs are in
    logical units and comparable with the digital matmul.
    """

    def __init__(self, ex, w: jax.Array, tag: str, n_draws: int = 8):
        self.ex = ex
        self.w = w.astype(jnp.float32)
        self.tag = tag
        self.n_draws = n_draws
        self.trace_count = 0
        self._fn = None

    def cache_size(self) -> int:
        """Number of compiled executables behind the sweep (tests assert
        this stays 1 across a whole curve)."""
        return self._fn._cache_size() if self._fn is not None else 0

    def _build(self):
        from repro.core.analog import _st_matmul_u
        ex, w, tag = self.ex, self.w, self.tag

        def fwd(x2, scen: Scenario, keys, a, b):
            self.trace_count += 1          # trace-time side effect, by design
            plan = ex._plan_for(w, tag)    # concrete w -> cached, baked
            # conditioned emulator: the swept corner's feature encoding is
            # a function of the traced scenario leaves, so it rides the
            # same single executable as the corner sweep itself
            sf = (scenario_features(scen)
                  if getattr(ex, "emulator_conditioned", False)
                  else jnp.zeros((N_SCENARIO_FEATURES,), jnp.float32))
            ep = (ex.emulator_params
                  if ex.acfg.backend == "emulator"
                  and ex.emulator_params is not None else {})
            rsig = jnp.broadcast_to(
                jnp.asarray(scen.read_sigma, jnp.float32),
                (plan.NB, plan.NO))
            operm = jnp.arange(plan.N, dtype=jnp.int32)

            def one(k):
                kd, kr = jax.random.split(k)
                p = perturb_plan(plan, ex.acfg, scen, kd)
                st = DeploymentState(gf=p.g_feat, read_sigma=rsig,
                                     read_key=kr, out_perm=operm,
                                     eparams=ep, sfeat=sf,
                                     cal_a=a, cal_b=b)
                return _st_matmul_u(ex, tag, x2, w, st)

            return jax.vmap(one)(keys)

        self._fn = jax.jit(fwd)

    def __call__(self, x: jax.Array, scenario: Scenario,
                 key: Optional[jax.Array] = None) -> jax.Array:
        """x: (B, K) -> (n_draws, B, N) calibrated outputs, one device draw
        per row.  Fixing ``key`` across calls gives common random numbers
        over scenario parameters (variance-reduced, monotone curves)."""
        if scenario.r_line_scale != 1.0:
            raise ValueError(
                "ScenarioSweep sweeps traced scenario fields only; "
                "r_line_scale is static (it rewrites CircuitParams, so each "
                "level would recompile and the circuit backend's closure "
                "would not see it) -- use AnalogExecutor.deploy("
                "scenario=...) for line-resistance corners")
        if self._fn is None:
            self._build()
        if key is None:
            key = jax.random.PRNGKey(0)
        keys = jax.random.split(key, self.n_draws)
        a, b = self.ex.calibration.get(self.tag, (1.0, 0.0))
        x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
        return self._fn(x2, scenario, keys,
                        jnp.asarray(a, jnp.float32), jnp.asarray(b, jnp.float32))
