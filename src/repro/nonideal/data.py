"""Noise-aware emulator training data.

The circuit solver is the ground truth for *any* device corner: perturb the
sampled per-cell conductances with a scenario (one device draw + one read
draw per training sample) and label with the scenario-adjusted circuit
(line-resistance scaling included).  An emulator trained on this data
learns the response surface of the degraded hardware, which is how
non-idealities that have no analytic hook (IR drop under faults, drifted
operating points) reach the emulator backend.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import AnalogConfig
from repro.configs.rram_ps32 import BlockGeometry, EmulatorTrainConfig
from repro.core.circuit import CircuitParams, block_response
from repro.core.emulator import (EmulatorResult, normalize_features,
                                 sample_block_inputs, train_emulator)
from repro.nonideal.perturb import (apply_read_noise, perturb_conductance,
                                    scenario_circuit_params)
from repro.nonideal.scenario import Scenario


def generate_dataset_nonideal(key, n: int, geom: BlockGeometry,
                              acfg: AnalogConfig, cp: CircuitParams,
                              scenario: Scenario, batch: int = 2048,
                              with_periph: bool = True):
    """Scenario-perturbed twin of ``emulator.generate_dataset``.

    Each sample is its own device draw + read draw, so the dataset covers
    the scenario's conductance distribution (stuck rails, quantized levels,
    drifted spans), not one frozen device."""
    cp_s = scenario_circuit_params(cp, scenario)
    solve = jax.jit(lambda x, p: block_response(x, cp_s, p))

    def _perturb(x, kd, kr):
        g = perturb_conductance(x[:, 1], acfg, scenario, kd)
        g = apply_read_noise(g, acfg, scenario.read_sigma, kr)
        return x.at[:, 1].set(g)

    perturb = jax.jit(_perturb)
    xs, ps, ys = [], [], []
    done = 0
    while done < n:
        b = min(batch, n - done)
        key, ks, kd, kr = jax.random.split(key, 4)
        # fixed-size sample + tail slice: solve/perturb compile exactly once
        x, periph = sample_block_inputs(ks, batch, geom, acfg, with_periph)
        x = perturb(x, kd, kr)
        y = solve(x, periph)
        xs.append(normalize_features(x[:b], acfg))
        ps.append(periph[:b] if periph is not None else None)
        ys.append(y[:b])
        done += b
    X = jnp.concatenate(xs)
    Pf = jnp.concatenate(ps) if with_periph else None
    Y = jnp.concatenate(ys)
    return X, Pf, Y


def train_noise_aware_emulator(key, geom: BlockGeometry, acfg: AnalogConfig,
                               cp: CircuitParams, tcfg: EmulatorTrainConfig,
                               scenario: Scenario,
                               log_every: int = 0) -> EmulatorResult:
    """Paper training protocol on scenario-perturbed circuit data."""
    kd, kt = jax.random.split(key)
    data = generate_dataset_nonideal(kd, tcfg.n_train + tcfg.n_test, geom,
                                     acfg, cp, scenario)
    return train_emulator(kt, geom, acfg, cp, tcfg, data=data,
                          log_every=log_every)
