"""Noise-aware emulator training data.

The circuit solver is the ground truth for *any* device corner: perturb the
sampled per-cell conductances with a scenario (one device draw + one read
draw per training sample) and label with the scenario-adjusted circuit
(line-resistance scaling included).  An emulator trained on this data
learns the response surface of the degraded hardware, which is how
non-idealities that have no analytic hook (IR drop under faults, drifted
operating points) reach the emulator backend.

Three training modes live here (docs/emulator.md):

  * ``train_noise_aware_emulator`` -- one net per corner (the original
    per-configuration protocol);
  * ``finetune_emulator`` -- warm-start adaptation of a trained net to a
    new corner (what the lifetime scheduler's retrain callbacks use);
  * ``train_conditioned_emulator`` -- ONE net for the whole corner
    manifold: each training sample draws its own scenario from a
    ``ScenarioSpace`` and the scenario's feature encoding
    (``scenario_features``) is appended to the peripheral features, so
    the net learns response-surface-versus-corner jointly and serves any
    corner/age with zero retraining.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AnalogConfig
from repro.configs.rram_ps32 import BlockGeometry, EmulatorTrainConfig
from repro.core.circuit import CircuitParams, block_response
from repro.core.emulator import (EmulatorResult, normalize_features,
                                 sample_block_inputs, train_emulator)
from repro.nonideal.perturb import (_broadcast_scenario, apply_read_noise,
                                    perturb_conductance,
                                    scenario_circuit_params)
from repro.nonideal.scenario import Scenario, scenario_features


def generate_dataset_nonideal(key, n: int, geom: BlockGeometry,
                              acfg: AnalogConfig, cp: CircuitParams,
                              scenario: Scenario, batch: int = 2048,
                              with_periph: bool = True):
    """Scenario-perturbed twin of ``emulator.generate_dataset``.

    Each sample is its own device draw + read draw, so the dataset covers
    the scenario's conductance distribution (stuck rails, quantized levels,
    drifted spans), not one frozen device."""
    cp_s = scenario_circuit_params(cp, scenario)
    solve = jax.jit(lambda x, p: block_response(x, cp_s, p))

    def _perturb(x, kd, kr):
        g = perturb_conductance(x[:, 1], acfg, scenario, kd)
        g = apply_read_noise(g, acfg, scenario.read_sigma, kr)
        return x.at[:, 1].set(g)

    perturb = jax.jit(_perturb)
    xs, ps, ys = [], [], []
    done = 0
    while done < n:
        b = min(batch, n - done)
        key, ks, kd, kr = jax.random.split(key, 4)
        # fixed-size sample + tail slice: solve/perturb compile exactly once
        x, periph = sample_block_inputs(ks, batch, geom, acfg, with_periph)
        x = perturb(x, kd, kr)
        y = solve(x, periph)
        xs.append(normalize_features(x[:b], acfg))
        ps.append(periph[:b] if periph is not None else None)
        ys.append(y[:b])
        done += b
    X = jnp.concatenate(xs)
    Pf = jnp.concatenate(ps) if with_periph else None
    Y = jnp.concatenate(ys)
    return X, Pf, Y


# --------------------------------------------------------------------------- #
# Scenario-conditioned training: one emulator for the whole corner manifold
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class ScenarioSpace:
    """The corner manifold a conditioned emulator trains over.

    Each field is a ``(lo, hi)`` uniform sampling range for the matching
    ``Scenario`` knob; drift ages are log-uniform over
    ``[60 s, drift_t_max]`` with a ``p_undrifted`` point mass at exactly
    t = 0 (a freshly programmed fleet is a corner the net must serve
    bit-for-bit well, not a measure-zero edge).  ``n_levels`` is a choice
    set.  ``r_line_scale`` is deliberately absent: it rewrites the circuit
    solver's static ``CircuitParams``, so it cannot vary per sample inside
    one compiled label batch -- line-resistance corners keep the
    per-corner retrain/fine-tune path (docs/emulator.md).  The defaults
    cover every built-in registry corner except ``ir_degraded``.
    """
    prog_sigma: Tuple[float, float] = (0.0, 0.15)
    read_sigma: Tuple[float, float] = (0.0, 0.06)
    p_stuck_on: Tuple[float, float] = (0.0, 0.01)
    p_stuck_off: Tuple[float, float] = (0.0, 0.06)
    drift_nu: Tuple[float, float] = (0.0, 0.08)
    drift_t_max: float = 2_592_000.0          # one month
    p_undrifted: float = 0.25
    n_levels: Tuple[int, ...] = (0, 16, 32)
    # serving-statistics mixture.  Per-checkpoint field fine-tunes train
    # on the fleet's own serving distribution; for ONE conditioned net to
    # match them with zero retraining, its training data must cover that
    # distribution too, not just uniform (V, G) blocks:
    #   * with probability ``p_serving_drive`` a sample's voltages are
    #     drawn the way the executor drives them -- per-row zero with
    #     probability ``serve_sparsity`` (a rail sees relu'd activations),
    #     nonzero rows gate-overdriven into [v_th, v_read]
    #     (``AnalogConfig.wl_overdrive``) -- instead of uniform;
    #   * with probability ``p_weightlike`` a sample's conductances are
    #     WEIGHT-derived differential pairs (one rail at g_min, the other
    #     encoding |w| of a random sub-unit-scale weight, exactly
    #     ``crossbar.weights_to_conductance``) instead of uniform over
    #     [g_min, g_max]^W -- the low-g differential manifold serving
    #     actually lives on (and drift pushes further down).
    p_serving_drive: float = 0.5
    serve_sparsity: float = 0.5
    p_weightlike: float = 0.5
    weight_scale: Tuple[float, float] = (0.05, 0.6)


def sample_scenarios(key, n: int,
                     space: Optional[ScenarioSpace] = None) -> Scenario:
    """One ``Scenario`` whose numeric leaves are ``(n,)`` arrays -- n
    independent corners drawn from ``space``, ready to vmap a per-sample
    perturbation over (the batch-axis twin of ``tile_scenarios``)."""
    space = space if space is not None else ScenarioSpace()
    ks = jax.random.split(key, 8)

    def u(k, rng):
        return jax.random.uniform(k, (n,), minval=rng[0], maxval=rng[1])

    t_raw = jnp.exp(jax.random.uniform(
        ks[5], (n,), minval=jnp.log(60.0),
        maxval=jnp.log(jnp.maximum(space.drift_t_max, 61.0))))
    drift_t = jnp.where(jax.random.uniform(ks[6], (n,)) < space.p_undrifted,
                        0.0, t_raw)
    nl = jnp.asarray(space.n_levels, jnp.int32)[
        jax.random.randint(ks[7], (n,), 0, len(space.n_levels))]
    s = Scenario(name="manifold",
                 prog_sigma=u(ks[0], space.prog_sigma),
                 read_sigma=u(ks[1], space.read_sigma),
                 p_stuck_on=u(ks[2], space.p_stuck_on),
                 p_stuck_off=u(ks[3], space.p_stuck_off),
                 drift_nu=u(ks[4], space.drift_nu),
                 drift_t=drift_t, n_levels=nl)
    # broadcast the remaining scalar leaves (drift_t0) to (n,) so every
    # leaf carries the batch axis and a plain vmap(in_axes=0) applies
    return _broadcast_scenario(s, (n,))


def generate_dataset_conditioned(key, n: int, geom: BlockGeometry,
                                 acfg: AnalogConfig, cp: CircuitParams,
                                 space: Optional[ScenarioSpace] = None,
                                 batch: int = 2048):
    """Training data for the scenario-conditioned emulator.

    Every sample draws its OWN corner from ``space`` (then its own device
    and read draw under that corner), so one dataset covers the manifold
    instead of one frozen scenario; the sample's feature encoding
    (``scenario_features``) is appended to the peripheral features --
    ``Pf`` is ``(n, 2 + N_SCENARIO_FEATURES)`` and ``train_emulator``
    sizes the net's fc0 accordingly.  A ``p_serving_drive`` fraction of
    samples swaps the uniform wordline voltages for serving-statistics
    drives (sparse rails, gate-overdriven levels), closing the
    train/serve distribution gap the per-checkpoint field fine-tunes
    otherwise exploit.  Labels come from the base circuit solver on the
    perturbed conductances (``r_line_scale`` is static and stays 1 --
    see ``ScenarioSpace``)."""
    space = space if space is not None else ScenarioSpace()
    solve = jax.jit(lambda x, p: block_response(x, cp, p))

    def _one(xi, si: Scenario, kd, kr):
        g = perturb_conductance(xi[1], acfg, si, kd)
        g = apply_read_noise(g, acfg, si.read_sigma, kr)
        return xi.at[1].set(g), scenario_features(si)

    perturb = jax.jit(jax.vmap(_one))

    def _mix_serving(x, k):
        """Swap a fraction of samples onto serving statistics: drive rows
        sparse + overdriven into [v_th, v_read] (matching ``_drive01``),
        conductances weight-derived differential pairs (matching
        ``build_conductance_plan``)."""
        ka, kb, kc, kd_, ke, kf = jax.random.split(k, 6)
        B = x.shape[0]
        vshape = (B,) + x.shape[2:4]                   # (B, D, H)
        live = jax.random.uniform(ka, vshape) >= space.serve_sparsity
        lvl = cp.v_th + jax.random.uniform(kb, vshape) * (acfg.v_read
                                                          - cp.v_th)
        v_serve = jnp.where(live, lvl, 0.0)
        pick_v = (jax.random.uniform(kc, (B, 1, 1))
                  < space.p_serving_drive)
        v = jnp.where(pick_v, v_serve, x[:, 0, :, :, 0])
        x = x.at[:, 0].set(
            jnp.broadcast_to(v[..., None], (B,) + x.shape[2:]))
        # weight-like differential conductances: wn in [-1, 1] at a random
        # per-sample scale, G+ <- w > 0, G- <- -w > 0 (other rail g_min)
        no = x.shape[4] // 2
        wshape = (B,) + x.shape[2:4] + (no,)
        lo, hi = space.weight_scale
        s = jnp.exp(jax.random.uniform(kd_, (B, 1, 1, 1),
                                       minval=jnp.log(lo),
                                       maxval=jnp.log(hi)))
        wn = jnp.clip(jax.random.normal(ke, wshape) * s, -1.0, 1.0)
        span = acfg.g_max - acfg.g_min
        gp = acfg.g_min + span * jnp.clip(wn, 0.0, 1.0)
        gn = acfg.g_min + span * jnp.clip(-wn, 0.0, 1.0)
        g_w = jnp.stack([gp, gn], axis=-1).reshape((B,) + x.shape[2:])
        pick_g = (jax.random.uniform(kf, (B, 1, 1, 1))
                  < space.p_weightlike)
        return x.at[:, 1].set(jnp.where(pick_g, g_w, x[:, 1]))

    mix = jax.jit(_mix_serving)
    xs, ps, ys = [], [], []
    done = 0
    while done < n:
        b = min(batch, n - done)
        key, ks, kc, kd, kr, kv = jax.random.split(key, 6)
        # fixed-size sample + tail slice: compiles exactly once
        x, periph = sample_block_inputs(ks, batch, geom, acfg, True)
        x = mix(x, kv)
        scen = sample_scenarios(kc, batch, space)
        x, sfeat = perturb(x, scen, jax.random.split(kd, batch),
                           jax.random.split(kr, batch))
        y = solve(x, periph)
        xs.append(normalize_features(x[:b], acfg))
        ps.append(jnp.concatenate([periph[:b], sfeat[:b]], axis=-1))
        ys.append(y[:b])
        done += b
    return jnp.concatenate(xs), jnp.concatenate(ps), jnp.concatenate(ys)


def train_conditioned_emulator(key, geom: BlockGeometry, acfg: AnalogConfig,
                               cp: CircuitParams, tcfg: EmulatorTrainConfig,
                               space: Optional[ScenarioSpace] = None,
                               log_every: int = 0) -> EmulatorResult:
    """Paper training protocol over the corner manifold: ONE age-aware,
    corner-aware Conv4Xbar (peripheral width 2 + N_SCENARIO_FEATURES)
    that replaces per-corner retraining and the lifetime scheduler's
    per-checkpoint fine-tunes (docs/emulator.md)."""
    kd, kt = jax.random.split(key)
    data = generate_dataset_conditioned(kd, tcfg.n_train + tcfg.n_test,
                                        geom, acfg, cp, space=space)
    return train_emulator(kt, geom, acfg, cp, tcfg, data=data,
                          log_every=log_every)


def train_noise_aware_emulator(key, geom: BlockGeometry, acfg: AnalogConfig,
                               cp: CircuitParams, tcfg: EmulatorTrainConfig,
                               scenario: Scenario,
                               log_every: int = 0) -> EmulatorResult:
    """Paper training protocol on scenario-perturbed circuit data."""
    kd, kt = jax.random.split(key)
    data = generate_dataset_nonideal(kd, tcfg.n_train + tcfg.n_test, geom,
                                     acfg, cp, scenario)
    return train_emulator(kt, geom, acfg, cp, tcfg, data=data,
                          log_every=log_every)


def finetune_emulator(key, params: dict, geom: BlockGeometry,
                      acfg: AnalogConfig, cp: CircuitParams,
                      scenario: Scenario, n: int = 4096, epochs: int = 30,
                      batch_size: int = 512, lr: float = 2e-4,
                      data=None) -> dict:
    """Warm-start adaptation of a trained emulator to a degraded corner.

    Drift-scheduled retraining from scratch pays full model variance at
    every checkpoint -- an independently trained net differs from the
    serving net far more than the corner shifted.  Fine-tuning instead
    takes a few low-lr Adam epochs from the CURRENT params, so the model
    moves a short distance toward the degraded response surface (e.g. the
    low-g region drift concentrates inputs into) and nowhere else.

    ``data`` is an ``(X, Pf, Y)`` triple of normalized block features,
    peripheral features and raw-volt circuit labels; when None, a
    noise-aware sample of the aged corner is generated
    (``generate_dataset_nonideal``).  ``lifetime.make_field_retrainer``
    passes serving-distribution data instead -- the fleet's own drive
    statistics against its own drawn devices -- which is what closes the
    train/serve distribution gap.  Targets are raw volts (the input
    params already predict volts; no standardization refold).  Returns
    fresh params; the input dict is not mutated."""
    import functools

    from repro.core import conv4xbar

    if data is None:
        kd = jax.random.fold_in(key, 0xF17E)
        data = generate_dataset_nonideal(kd, n, geom, acfg, cp, scenario)
    X, Pf, Y = data
    n = X.shape[0]
    bs = min(batch_size, n)
    steps = max(1, n // bs)

    def loss_fn(p, xb, pb, yb):
        return jnp.mean(jnp.square(conv4xbar.apply_fused(p, xb, pb) - yb))

    @functools.partial(jax.jit, donate_argnums=(1, 2, 3))
    def epoch_fn(perm, p, m, v, t0):
        xb = X[perm[:steps * bs]].reshape((steps, bs) + X.shape[1:])
        yb = Y[perm[:steps * bs]].reshape((steps, bs) + Y.shape[1:])
        pb = Pf[perm[:steps * bs]].reshape((steps, bs) + Pf.shape[1:])

        def step(carry, xs):
            p, m, v, t = carry
            xi, pi, yi = xs
            l, g = jax.value_and_grad(loss_fn)(p, xi, pi, yi)
            m = jax.tree.map(lambda a, b: 0.9 * a + 0.1 * b, m, g)
            v = jax.tree.map(lambda a, b: 0.999 * a + 0.001 * jnp.square(b),
                             v, g)
            t = t + 1
            bc1, bc2 = 1 - 0.9 ** t, 1 - 0.999 ** t
            p = jax.tree.map(
                lambda pp, mm, vv: pp - lr * (mm / bc1)
                / (jnp.sqrt(vv / bc2) + 1e-8), p, m, v)
            return (p, m, v, t), l

        (p, m, v, t), ls = jax.lax.scan(step, (p, m, v, t0), (xb, pb, yb))
        return p, m, v, t, ls.mean()

    p = {k: jnp.array(v) for k, v in params.items()}      # private copy
    m = jax.tree.map(jnp.zeros_like, p)
    v = jax.tree.map(jnp.zeros_like, p)
    t = jnp.zeros((), jnp.float32)
    rng = np.random.default_rng(int(jax.random.randint(
        jax.random.fold_in(key, 0x5EED), (), 0, 2**31 - 1)))
    for _ in range(epochs):
        perm = jnp.asarray(rng.permutation(n))
        p, m, v, t, _ = epoch_fn(perm, p, m, v, t)
    return p
