"""Noise-aware emulator training data.

The circuit solver is the ground truth for *any* device corner: perturb the
sampled per-cell conductances with a scenario (one device draw + one read
draw per training sample) and label with the scenario-adjusted circuit
(line-resistance scaling included).  An emulator trained on this data
learns the response surface of the degraded hardware, which is how
non-idealities that have no analytic hook (IR drop under faults, drifted
operating points) reach the emulator backend.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AnalogConfig
from repro.configs.rram_ps32 import BlockGeometry, EmulatorTrainConfig
from repro.core.circuit import CircuitParams, block_response
from repro.core.emulator import (EmulatorResult, normalize_features,
                                 sample_block_inputs, train_emulator)
from repro.nonideal.perturb import (apply_read_noise, perturb_conductance,
                                    scenario_circuit_params)
from repro.nonideal.scenario import Scenario


def generate_dataset_nonideal(key, n: int, geom: BlockGeometry,
                              acfg: AnalogConfig, cp: CircuitParams,
                              scenario: Scenario, batch: int = 2048,
                              with_periph: bool = True):
    """Scenario-perturbed twin of ``emulator.generate_dataset``.

    Each sample is its own device draw + read draw, so the dataset covers
    the scenario's conductance distribution (stuck rails, quantized levels,
    drifted spans), not one frozen device."""
    cp_s = scenario_circuit_params(cp, scenario)
    solve = jax.jit(lambda x, p: block_response(x, cp_s, p))

    def _perturb(x, kd, kr):
        g = perturb_conductance(x[:, 1], acfg, scenario, kd)
        g = apply_read_noise(g, acfg, scenario.read_sigma, kr)
        return x.at[:, 1].set(g)

    perturb = jax.jit(_perturb)
    xs, ps, ys = [], [], []
    done = 0
    while done < n:
        b = min(batch, n - done)
        key, ks, kd, kr = jax.random.split(key, 4)
        # fixed-size sample + tail slice: solve/perturb compile exactly once
        x, periph = sample_block_inputs(ks, batch, geom, acfg, with_periph)
        x = perturb(x, kd, kr)
        y = solve(x, periph)
        xs.append(normalize_features(x[:b], acfg))
        ps.append(periph[:b] if periph is not None else None)
        ys.append(y[:b])
        done += b
    X = jnp.concatenate(xs)
    Pf = jnp.concatenate(ps) if with_periph else None
    Y = jnp.concatenate(ys)
    return X, Pf, Y


def train_noise_aware_emulator(key, geom: BlockGeometry, acfg: AnalogConfig,
                               cp: CircuitParams, tcfg: EmulatorTrainConfig,
                               scenario: Scenario,
                               log_every: int = 0) -> EmulatorResult:
    """Paper training protocol on scenario-perturbed circuit data."""
    kd, kt = jax.random.split(key)
    data = generate_dataset_nonideal(kd, tcfg.n_train + tcfg.n_test, geom,
                                     acfg, cp, scenario)
    return train_emulator(kt, geom, acfg, cp, tcfg, data=data,
                          log_every=log_every)


def finetune_emulator(key, params: dict, geom: BlockGeometry,
                      acfg: AnalogConfig, cp: CircuitParams,
                      scenario: Scenario, n: int = 4096, epochs: int = 30,
                      batch_size: int = 512, lr: float = 2e-4,
                      data=None) -> dict:
    """Warm-start adaptation of a trained emulator to a degraded corner.

    Drift-scheduled retraining from scratch pays full model variance at
    every checkpoint -- an independently trained net differs from the
    serving net far more than the corner shifted.  Fine-tuning instead
    takes a few low-lr Adam epochs from the CURRENT params, so the model
    moves a short distance toward the degraded response surface (e.g. the
    low-g region drift concentrates inputs into) and nowhere else.

    ``data`` is an ``(X, Pf, Y)`` triple of normalized block features,
    peripheral features and raw-volt circuit labels; when None, a
    noise-aware sample of the aged corner is generated
    (``generate_dataset_nonideal``).  ``lifetime.make_field_retrainer``
    passes serving-distribution data instead -- the fleet's own drive
    statistics against its own drawn devices -- which is what closes the
    train/serve distribution gap.  Targets are raw volts (the input
    params already predict volts; no standardization refold).  Returns
    fresh params; the input dict is not mutated."""
    import functools

    from repro.core import conv4xbar

    if data is None:
        kd = jax.random.fold_in(key, 0xF17E)
        data = generate_dataset_nonideal(kd, n, geom, acfg, cp, scenario)
    X, Pf, Y = data
    n = X.shape[0]
    bs = min(batch_size, n)
    steps = max(1, n // bs)

    def loss_fn(p, xb, pb, yb):
        return jnp.mean(jnp.square(conv4xbar.apply_fused(p, xb, pb) - yb))

    @functools.partial(jax.jit, donate_argnums=(1, 2, 3))
    def epoch_fn(perm, p, m, v, t0):
        xb = X[perm[:steps * bs]].reshape((steps, bs) + X.shape[1:])
        yb = Y[perm[:steps * bs]].reshape((steps, bs) + Y.shape[1:])
        pb = Pf[perm[:steps * bs]].reshape((steps, bs) + Pf.shape[1:])

        def step(carry, xs):
            p, m, v, t = carry
            xi, pi, yi = xs
            l, g = jax.value_and_grad(loss_fn)(p, xi, pi, yi)
            m = jax.tree.map(lambda a, b: 0.9 * a + 0.1 * b, m, g)
            v = jax.tree.map(lambda a, b: 0.999 * a + 0.001 * jnp.square(b),
                             v, g)
            t = t + 1
            bc1, bc2 = 1 - 0.9 ** t, 1 - 0.999 ** t
            p = jax.tree.map(
                lambda pp, mm, vv: pp - lr * (mm / bc1)
                / (jnp.sqrt(vv / bc2) + 1e-8), p, m, v)
            return (p, m, v, t), l

        (p, m, v, t), ls = jax.lax.scan(step, (p, m, v, t0), (xb, pb, yb))
        return p, m, v, t, ls.mean()

    p = {k: jnp.array(v) for k, v in params.items()}      # private copy
    m = jax.tree.map(jnp.zeros_like, p)
    v = jax.tree.map(jnp.zeros_like, p)
    t = jnp.zeros((), jnp.float32)
    rng = np.random.default_rng(int(jax.random.randint(
        jax.random.fold_in(key, 0x5EED), (), 0, 2**31 - 1)))
    for _ in range(epochs):
        perm = jnp.asarray(rng.permutation(n))
        p, m, v, t, _ = epoch_fn(perm, p, m, v, t)
    return p
