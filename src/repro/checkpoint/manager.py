"""Mesh-agnostic checkpointing: every leaf is saved as its full logical
array (npz shards by pytree key), so restore can re-shard onto ANY mesh --
the basis of elastic re-scaling (lose a pod -> restart on a smaller mesh).

Durability: atomic tmp+rename directories, keep-last-k GC, optional async
save on a background thread (device->host transfer is the only sync part).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(path): leaf for path, leaf in leaves}


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ #
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def all_steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, d, "MANIFEST.json")):
                    out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ------------------------------------------------------------------ #
    def save(self, state, step: int, extra: Optional[dict] = None):
        """Device->host synchronously; serialization possibly async."""
        host = {k: np.asarray(jax.device_get(v))
                for k, v in _flatten(state).items()}
        if self.async_save:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(host, step, extra), daemon=True)
            self._thread.start()
        else:
            self._write(host, step, extra)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, host: Dict[str, np.ndarray], step: int,
               extra: Optional[dict]):
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **host)
        manifest = {
            "step": step,
            "time": time.time(),
            "keys": sorted(host.keys()),
            "shapes": {k: list(v.shape) for k, v in host.items()},
            "dtypes": {k: str(v.dtype) for k, v in host.items()},
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)          # atomic publish
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------ #
    def restore(self, abstract_state, step: Optional[int] = None):
        """Restore into the shardings of `abstract_state` (any mesh)."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self._step_dir(step)
        data = np.load(os.path.join(d, "arrays.npz"))
        flat_abs = _flatten(abstract_state)

        def put(k, ab):
            arr = data[k]
            if hasattr(ab, "sharding") and ab.sharding is not None:
                return jax.device_put(arr.astype(ab.dtype), ab.sharding)
            return jax.device_put(arr.astype(ab.dtype))

        vals = {k: put(k, ab) for k, ab in flat_abs.items()}
        leaves, treedef = jax.tree.flatten(abstract_state)
        paths = [jax.tree_util.keystr(p)
                 for p, _ in jax.tree_util.tree_flatten_with_path(abstract_state)[0]]
        return jax.tree.unflatten(treedef, [vals[p] for p in paths]), step
