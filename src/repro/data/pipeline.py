"""Deterministic, stateless-resumable synthetic LM data pipeline.

Batch t is a pure function of (seed, t): restart after a failure needs no
data-loader state (the trainer just asks for step t again). Tokens follow a
noisy affine-recurrence Markov chain so models can actually reduce loss in
integration tests; padding/masking mimics packed documents.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig


@dataclass
class SyntheticLMData:
    cfg: ArchConfig
    seq_len: int
    global_batch: int
    seed: int = 0
    markov_p: float = 0.75

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.Generator(np.random.Philox(key=self.seed + 7919 * step))
        B, S, V = self.global_batch, self.seq_len, self.cfg.vocab_size
        toks = np.empty((B, S + 1), np.int32)
        toks[:, 0] = rng.integers(0, V, B)
        noise = rng.integers(0, V, (B, S))
        use_chain = rng.random((B, S)) < self.markov_p
        for s in range(S):
            nxt = (toks[:, s] * 31 + 7) % V
            toks[:, s + 1] = np.where(use_chain[:, s], nxt, noise[:, s])
        # document boundaries -> loss mask (mask out 5% as padding)
        mask = (rng.random((B, S)) > 0.05).astype(np.float32)
        out = {
            "tokens": toks[:, :-1],
            "targets": toks[:, 1:].astype(np.int32),
            "mask": mask,
        }
        if self.cfg.frontend == "vision":
            out["image_embeds"] = rng.standard_normal(
                (B, self.cfg.frontend_tokens, self.cfg.d_model),
                dtype=np.float32).astype(np.float32)
        if self.cfg.encoder_layers:
            out["enc_frames"] = rng.standard_normal(
                (B, S, self.cfg.d_model), dtype=np.float32)
        return out
