"""System-level benchmark: tiny-LM training throughput, digital vs
analog-emulated execution (SEMULATOR's target use-case: simulating a full
analog neural system inside an ML framework)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import QUICK, get_emulator, timed
from repro.configs import get_config, reduced
from repro.configs.base import AnalogConfig, ParallelConfig, TrainConfig
from repro.configs.rram_ps32 import CASE_A
from repro.core.analog import AnalogExecutor
from repro.core.circuit import CircuitParams
from repro.data import SyntheticLMData
from repro.models.common import use_dense_hook
from repro.runtime import steps as S


def run(arch: str = "gemma3-1b", seq: int = 64, batch: int = 4):
    cfg = reduced(get_config(arch))
    pcfg = ParallelConfig(attn_block_kv=seq, xent_chunk=seq, scan_chunk=32)
    tcfg = TrainConfig(total_steps=50, warmup_steps=1)
    data = SyntheticLMData(cfg, seq, batch)
    state = S.init_train_state(jax.random.PRNGKey(0), cfg)
    batch0 = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    step = S.make_train_step(cfg, pcfg, tcfg)

    out = {}
    dt, _ = timed(jax.jit(step), state, batch0, warmup=1, iters=3)
    out["digital_us_per_step"] = dt * 1e6

    res = get_emulator(CASE_A.name, QUICK)
    ex = AnalogExecutor(
        acfg=AnalogConfig(enabled=True, backend="emulator", layers=("mlp",)),
        geom=CASE_A, cp=CircuitParams(), emulator_params=res.params)
    with use_dense_hook(ex.hook):
        jstep = jax.jit(step)
        dt, r = timed(jstep, state, batch0, warmup=1, iters=1)
    out["analog_emulated_us_per_step"] = dt * 1e6
    out["tokens_per_s_digital"] = batch * seq / (out["digital_us_per_step"] / 1e6)
    return out


def main(csv=True):
    out = run()
    if csv:
        print(f"system_train_digital,{out['digital_us_per_step']:.0f},"
              f"us_per_step;tok_s={out['tokens_per_s_digital']:.0f}")
        print(f"system_train_analog_emulated,"
              f"{out['analog_emulated_us_per_step']:.0f},us_per_step")
    return out


if __name__ == "__main__":
    main()
