"""The paper's headline claim: emulation is 'incomparably' faster than the
circuit simulator. Times one computing-block batch through:
  circuit   -- Newton-Raphson solver (SPICE stand-in)
  analytic  -- expert analytical model
  emulator  -- Conv4Xbar (paper conv path, fused path, Pallas kernels)
and a system-level figure: one AnalogMatmul (K=512, N=32) per backend.

Besides the CSV lines, every run appends a machine-readable entry to
``BENCH_speed.json`` at the repo root (see docs/performance.md for the
schema) so the perf trajectory is tracked across PRs.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp

from benchmarks.common import QUICK, get_conditioned_emulator, get_emulator, \
    timed
from repro.configs.base import AnalogConfig
from repro.configs.rram_ps32 import CASE_A, EmulatorTrainConfig
from repro.core import conv4xbar
from repro.core.analog import AnalogExecutor
from repro.core.analytic import analytic_block_response
from repro.core.circuit import CircuitParams, block_response
from repro.core.emulator import normalize_features, sample_block_inputs
from repro.obs import OBS

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_speed.json")

# tiny protocol for CI smoke runs: exercises every code path, proves nothing
# about emulator quality
SMOKE = EmulatorTrainConfig(n_train=512, n_test=128, epochs=2, lr=2e-3,
                            lr_halve_at=(), batch_size=256)


def _pallas_backend() -> str:
    """Label the Pallas rows by how the kernel actually executes."""
    return "tpu" if jax.default_backend() == "tpu" else "interp"


def run(batch: int = 2048, seed: int = 0, tcfg=QUICK, iters: int = 3,
        with_circuit: bool = True):
    # benchmark runs sweep block sizes (kernels.autotune); the resolved
    # configs land in the run row, and telemetry rides along so the
    # cache-hit counters land there too (schema 3)
    os.environ.setdefault("REPRO_AUTOTUNE", "1")
    OBS.enable()
    geom, acfg, cp = CASE_A, AnalogConfig(), CircuitParams()
    res = get_emulator(geom.name, tcfg, seed)
    key = jax.random.PRNGKey(seed)
    x, periph = sample_block_inputs(key, batch, geom, acfg)
    xn = normalize_features(x, acfg)
    pl_mode = _pallas_backend()

    fns = {
        "analytic": jax.jit(lambda a, p: analytic_block_response(a, cp, p)),
        "emulator_conv": jax.jit(
            lambda a, p: conv4xbar.apply(res.params, a, p)),
        "emulator_fused": jax.jit(
            lambda a, p: conv4xbar.apply_fused(res.params, a, p)),
    }
    if with_circuit:
        fns["circuit"] = jax.jit(lambda a, p: block_response(a, cp, p))
    rows = {}
    for name, fn in fns.items():
        arg = x if name in ("circuit", "analytic") else xn
        dt, _ = timed(fn, arg, periph, iters=iters)
        rows[name] = dt / batch * 1e6          # us per block

    from repro.kernels.emulator_block import emulator_block
    dt, _ = timed(jax.jit(lambda a, p: emulator_block(res.params, a, p, geom)),
                  xn, periph, iters=iters)
    rows[f"emulator_pallas_{pl_mode}"] = dt / batch * 1e6

    # system level: one matmul through the executor
    w = jax.random.normal(key, (512, 32)) * 0.2
    xin = jax.random.normal(jax.random.fold_in(key, 1), (16, 512)) * 0.5
    sys_rows = {}
    backends = ("circuit", "analytic", "emulator") if with_circuit else \
        ("analytic", "emulator")
    for backend in backends:
        ex = AnalogExecutor(
            acfg=dataclasses.replace(acfg, backend=backend), geom=geom,
            cp=cp, emulator_params=res.params)
        fn = jax.jit(lambda a: ex.matmul(a, w, "bench"))
        dt, _ = timed(fn, xin, iters=iters)
        sys_rows[backend] = dt * 1e6
    # scenario serving overhead: same matmul through the per-tag unified
    # forward ("stressed" corner), timed as the eager dispatch (read noise
    # redrawn per call, in-trace fast-path precompute).  Worst case: a serve
    # loop that jits an enclosing step bakes the perturbation at trace time
    # and pays ~the plain emulator row instead.
    from repro.nonideal import get_scenario
    ex_sc = AnalogExecutor(
        acfg=dataclasses.replace(acfg, backend="emulator"), geom=geom,
        cp=cp, emulator_params=res.params)
    ex_sc.deploy(scenario=get_scenario("stressed"),
                 key=jax.random.PRNGKey(seed))
    dt, _ = timed(lambda a: ex_sc.matmul(a, w, "bench"), xin, iters=iters)
    sys_rows["emulator_nonideal"] = dt * 1e6
    # unified cache at the IDEAL deployment: the eager per-tag dispatch
    # with the whole DeploymentState as ONE traced argument -- the single
    # jit-cache family that replaced the plain/calibration/scenario trio.
    # Gated below within 5% of the fast-path rows it unified.
    ex_u = AnalogExecutor(
        acfg=dataclasses.replace(acfg, backend="emulator"), geom=geom,
        cp=cp, emulator_params=res.params)
    dt, _ = timed(lambda a: ex_u.matmul(a, w, "bench"), xin, iters=iters)
    sys_rows["emulator_unified"] = dt * 1e6
    # scenario-conditioned emulator on the PLAIN fast path: the ideal
    # (all-zero) feature block folds into the cached weights, so the
    # conditioning overhead should be within noise of the emulator row
    cond = get_conditioned_emulator(geom.name, tcfg, seed)
    ex_cd = AnalogExecutor(
        acfg=dataclasses.replace(acfg, backend="emulator"), geom=geom,
        cp=cp, emulator_params=cond.params)
    fn = jax.jit(lambda a: ex_cd.matmul(a, w, "bench"))
    dt, _ = timed(fn, xin, iters=iters)
    sys_rows["emulator_conditioned"] = dt * 1e6
    # the unified serving dispatcher, jitted: ONE fused pallas_call per
    # matmul on TPU (both rails + both GEMM stages + scenario epilogue);
    # on non-TPU hosts the dispatcher's identical-math XLA schedule runs
    # instead (interpret-mode kernel timings would benchmark the
    # interpreter, not the kernel), so there the row tracks the jitted
    # fast path and the gate is a no-regression check on the dispatcher.
    ex_pl = AnalogExecutor(
        acfg=dataclasses.replace(acfg, backend="emulator"), geom=geom,
        cp=cp, emulator_params=res.params)
    fn = jax.jit(lambda a: ex_pl.matmul(a, w, "bench"))
    dt, _ = timed(fn, xin, iters=iters)
    sys_rows["emulator_pallas_unified"] = dt * 1e6
    dt, _ = timed(jax.jit(lambda a: a @ w), xin, iters=iters)
    sys_rows["digital"] = dt * 1e6
    # tensor-parallel serving row (docs/parallel.md): the same matmul
    # through a (2, 4) data x model mesh.  Only measurable when the
    # process has >= 8 devices (the CI multidevice-smoke job forces
    # XLA_FLAGS=--xla_force_host_platform_device_count=8).  NOTE: forced
    # host devices multiplex the host's physical cores -- on a
    # single-core host the row records the partitioning OVERHEAD, not a
    # speedup; a real >= 1.5x needs >= 8 real cores/devices
    # (docs/performance.md).
    if len(jax.devices()) >= 8:
        from repro.parallel.sharding import serve_mesh
        ex_sh = AnalogExecutor(
            acfg=dataclasses.replace(acfg, backend="emulator"), geom=geom,
            cp=cp, emulator_params=res.params, mesh=serve_mesh(2, 4))
        fn = jax.jit(lambda a: ex_sh.matmul(a, w, "bench"))
        dt, _ = timed(fn, xin, iters=iters)
        sys_rows["emulator_sharded"] = dt * 1e6
    return rows, sys_rows


def _obs_summary() -> dict:
    """Counter totals worth tracking per run: executor cache hit/miss
    counts and autotune resolutions by source, folded out of the full
    telemetry snapshot (docs/observability.md)."""
    met = OBS.snapshot()["metrics"]

    def by_label(name: str, label: str) -> dict:
        out: dict = {}
        for s in met.get(name, {}).get("series", []):
            k = s["labels"].get(label, "?")
            out[k] = out.get(k, 0) + int(s["value"])
        return out

    return {"plan_cache": by_label("analog_plan_cache_total", "event"),
            "state_cache": by_label("analog_state_cache_total", "event"),
            "autotune_sources": by_label("autotune_resolutions_total",
                                         "source")}


def write_json(rows, sys_rows, label: str, path: str = BENCH_JSON):
    """Append this run to the perf-trajectory file (schema v3: each run
    row also records the autotuner's resolved block sizes and cache-hit
    status under ``kernels``, plus the telemetry counter summary under
    ``obs``; see docs/performance.md)."""
    from repro.kernels import autotune
    doc = {"schema": 3, "unit_block": "us_per_block",
           "unit_matmul": "us_per_matmul_512x32_b16", "runs": []}
    if os.path.exists(path):
        try:
            with open(path) as f:
                prev = json.load(f)
            if isinstance(prev, dict) and isinstance(prev.get("runs"), list):
                doc["runs"] = prev["runs"]
        except (json.JSONDecodeError, OSError):
            pass
    doc["runs"].append({
        "label": label,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "jax_backend": jax.default_backend(),
        "cpus": os.cpu_count(),
        "pallas": _pallas_backend(),
        "block_us": {k: round(v, 3) for k, v in rows.items()},
        "matmul_us": {k: round(v, 1) for k, v in sys_rows.items()},
        "kernels": autotune.report(),
        "obs": _obs_summary(),
    })
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    return path


def main(csv=True, quick: bool = False, label: str | None = None):
    if quick:
        rows, sys_rows = run(batch=256, tcfg=SMOKE, iters=2,
                             with_circuit=False)
    else:
        rows, sys_rows = run()
    # unified-cache gate: the ONE per-tag forward (DeploymentState as a
    # single traced arg) must stay within 5% of the fast-path rows it
    # unified -- the jit-baked plain row and the traced scenario row
    ref = max(sys_rows["emulator"], sys_rows["emulator_nonideal"])
    unified_ok = sys_rows["emulator_unified"] <= 1.05 * ref
    # fused-kernel gate: the jitted unified dispatcher must never regress
    # past the eager unified forward it accelerates
    pallas_ok = (sys_rows["emulator_pallas_unified"]
                 <= 1.0 * sys_rows["emulator_unified"])
    if csv:
        for k, v in rows.items():
            print(f"speed_block_{k},{v:.2f},us_per_block")
        for k, v in sys_rows.items():
            print(f"speed_matmul_{k},{v:.1f},us_per_matmul_512x32_b16")
        print(f"speed_unified_within_5pct,{int(unified_ok)},bool")
        print(f"speed_pallas_unified_no_regress,{int(pallas_ok)},bool")
        if "circuit" in rows:
            speedup = rows["circuit"] / rows["emulator_fused"]
            print(f"speed_emulator_speedup,{speedup:.1f},circuit/emulator_fused"
                  f" (CPU; paper's claim is orders-of-magnitude vs SPICE)")
    path = write_json(rows, sys_rows,
                      label or ("quick" if quick else "full"))
    print(f"bench_json,{os.path.abspath(path)},appended")
    if not unified_ok:
        raise SystemExit(
            f"unified-cache overhead gate violated: emulator_unified "
            f"{sys_rows['emulator_unified']:.1f} us > 1.05 x "
            f"max(emulator, emulator_nonideal) = {1.05 * ref:.1f} us")
    if not pallas_ok:
        raise SystemExit(
            f"fused-kernel gate violated: emulator_pallas_unified "
            f"{sys_rows['emulator_pallas_unified']:.1f} us > 1.0 x "
            f"emulator_unified = {sys_rows['emulator_unified']:.1f} us")
    return rows, sys_rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: tiny emulator, no circuit rows")
    ap.add_argument("--label", default=None,
                    help="label recorded in BENCH_speed.json")
    args = ap.parse_args()
    main(quick=args.quick, label=args.label)
