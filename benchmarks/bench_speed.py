"""The paper's headline claim: emulation is 'incomparably' faster than the
circuit simulator. Times one computing-block batch through:
  circuit   -- Newton-Raphson solver (SPICE stand-in)
  analytic  -- expert analytical model
  emulator  -- Conv4Xbar (paper conv path, fused path, Pallas kernel)
and a system-level figure: one AnalogMatmul (K=512, N=32) per backend.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import QUICK, get_emulator, timed
from repro.configs.base import AnalogConfig
from repro.configs.rram_ps32 import CASE_A
from repro.core import conv4xbar
from repro.core.analog import AnalogExecutor
from repro.core.analytic import analytic_block_response
from repro.core.circuit import CircuitParams, block_response
from repro.core.emulator import normalize_features, sample_block_inputs


def run(batch: int = 2048, seed: int = 0, tcfg=QUICK):
    geom, acfg, cp = CASE_A, AnalogConfig(), CircuitParams()
    res = get_emulator(geom.name, tcfg, seed)
    key = jax.random.PRNGKey(seed)
    x, periph = sample_block_inputs(key, batch, geom, acfg)
    xn = normalize_features(x, acfg)

    fns = {
        "circuit": jax.jit(lambda a, p: block_response(a, cp, p)),
        "analytic": jax.jit(lambda a, p: analytic_block_response(a, cp, p)),
        "emulator_conv": jax.jit(
            lambda a, p: conv4xbar.apply(res.params, a, p)),
        "emulator_fused": jax.jit(
            lambda a, p: conv4xbar.apply_fused(res.params, a, p)),
    }
    rows = {}
    for name, fn in fns.items():
        arg = x if name in ("circuit", "analytic") else xn
        dt, _ = timed(fn, arg, periph, iters=3)
        rows[name] = dt / batch * 1e6          # us per block

    from repro.kernels.emulator_block import emulator_block
    dt, _ = timed(jax.jit(lambda a, p: emulator_block(res.params, a, p, geom)),
                  xn, periph, iters=3)
    rows["emulator_pallas_interp"] = dt / batch * 1e6

    # system level: one matmul through the executor
    w = jax.random.normal(key, (512, 32)) * 0.2
    xin = jax.random.normal(jax.random.fold_in(key, 1), (16, 512)) * 0.5
    sys_rows = {}
    for backend in ("circuit", "analytic", "emulator"):
        ex = AnalogExecutor(
            acfg=dataclasses.replace(acfg, backend=backend), geom=geom,
            cp=cp, emulator_params=res.params)
        fn = jax.jit(lambda a: ex.matmul(a, w, "bench"))
        dt, _ = timed(fn, xin, iters=3)
        sys_rows[backend] = dt * 1e6
    dt, _ = timed(jax.jit(lambda a: a @ w), xin, iters=3)
    sys_rows["digital"] = dt * 1e6
    return rows, sys_rows


def main(csv=True):
    rows, sys_rows = run()
    speedup = rows["circuit"] / rows["emulator_fused"]
    if csv:
        for k, v in rows.items():
            print(f"speed_block_{k},{v:.2f},us_per_block")
        for k, v in sys_rows.items():
            print(f"speed_matmul_{k},{v:.1f},us_per_matmul_512x32_b16")
        print(f"speed_emulator_speedup,{speedup:.1f},circuit/emulator_fused"
              f" (CPU; paper's claim is orders-of-magnitude vs SPICE)")
    return rows, sys_rows


if __name__ == "__main__":
    main()
