"""Fleet digital twin: a maintenance campaign over 10^5 simulated devices.

One ``repro.fleet.Fleet`` -- population drawn around an aging corner
(programming noise, read noise, stuck-off faults, drift, all with
per-device lognormal fab spread) -- is walked through the drift timeline
under three maintenance policies:

  * **never**       -- calibrate at deployment, then serve untouched;
  * **always**      -- recalibrate every device at every checkpoint;
  * **plan**        -- ``MaintenancePlanner``: per-device DP schedules on
                      ``SurrogateRanker`` forecasts (a pinball-loss
                      quantile surface fitted on a probed subsample --
                      the million-device-cheap path).

Every policy replay, the surrogate's probe grid and the SLO probe ride
the fleet's ONE compiled chunk executable: device ids, ages and
calibration ages are traced operands of a fixed-shape vmapped chunk, so
the whole campaign compiles exactly once and memory is bounded by the
chunk size, never the population (``RecompileSentinel``-gated).

The serving model is the trained scenario-conditioned emulator
(``benchmarks.common.get_conditioned_emulator``): each device's aged
per-tile corner rides the feature operands, so forecasting and replay
never retrain (docs/fleet.md).

Asserted (exit 1 on violation):
  * the planner's cost-adjusted accuracy matches or beats BOTH baselines
    at every checkpoint (cost model: action costs + SLO-violation
    penalty, ``mean(1/(1+err)) - acc_per_cost * cum_cost / n``);
  * ONE chunk executable across the entire campaign;
  * under the conditioned emulator ``field_retrain`` is dominated and
    never scheduled (``retrain_gain = 1.0`` -- docs/emulator.md).

CSV lines to stdout + results/fleet_<label>.json.

  PYTHONPATH=src python -m benchmarks.bench_fleet [--quick]
      [--devices N] [--telemetry [PATH]]
"""
from __future__ import annotations

import argparse
import json
import os
import resource
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_lifetime import LIFETIME_QUICK
from benchmarks.common import QUICK, get_conditioned_emulator
from repro.configs.base import AnalogConfig
from repro.configs.rram_ps32 import CASE_A
from repro.core.analog import AnalogExecutor
from repro.fleet import (ActionCosts, Fleet, FleetSpec, MaintenancePlanner,
                         always_recalibrate_policy, never_policy,
                         simulate_policy)
from repro.nonideal import Scenario
from repro.nonideal.lifetime import DEFAULT_TIMELINE
from repro.obs import OBS, RecompileSentinel

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")

N_DEVICES_FULL = 100_000
N_DEVICES_QUICK = 10_000

# the population's nominal corner: enough drift + staleness signal that
# a stale affine fails the SLO by end of timeline, while a freshly
# recalibrated device passes -- the regime where scheduling matters
BASE = Scenario(name="fleet-base", prog_sigma=0.05, read_sigma=0.01,
                p_stuck_off=0.02, drift_nu=0.04, drift_t=0.0)

# SLO: fixed multiple of the fleet's median FRESHLY-MAINTAINED error at
# the first checkpoint -- self-calibrating against the emulator's model
# floor, so the gate measures scheduling, not absolute net quality.  2x
# leaves maintained devices (p90 ~ 1.5x median) comfortably inside the
# SLO while the never-maintained drift trajectory crosses it, so the
# planner's tau=0.8 surrogate sees most devices as repairable instead
# of conservatively retiring the whole fleet
SLO_OVER_FLOOR = 2.0


def _policies(n: int, timeline, planner_actions):
    return (("never", never_policy(n, timeline)),
            ("always", always_recalibrate_policy(n, timeline)),
            ("plan", planner_actions))


def run(quick: bool = False, seed: int = 0, n_devices: int | None = None):
    geom = CASE_A
    tcfg = LIFETIME_QUICK if quick else QUICK
    cond = get_conditioned_emulator(geom.name, tcfg, seed)
    key = jax.random.PRNGKey(seed)
    K, N, B = (64, 8, 4) if quick else (128, 16, 8)
    n = int(n_devices or (N_DEVICES_QUICK if quick else N_DEVICES_FULL))
    w = jax.random.normal(key, (K, N)) * 0.2
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, K)) * 0.5
    ages = [t for _, t in DEFAULT_TIMELINE]

    ex = AnalogExecutor(acfg=AnalogConfig(backend="emulator"), geom=geom,
                        emulator_params=cond.params, use_pallas=False)
    assert ex.emulator_conditioned, "bench_fleet needs the conditioned net"
    spec = FleetSpec(n_devices=n, base=BASE, chunk=256)
    fleet = Fleet(ex, w, "fleet", spec, key=jax.random.fold_in(key, 2))
    fleet._build()                       # executable exists before the gate

    rss0 = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    t0 = time.time()
    with RecompileSentinel(fns=(fleet._fn,), max_traces=1, strict=False,
                           label="fleet:chunk") as sent:
        # SLO from the realized model floor: fresh-calibration error at
        # the first checkpoint, probed on an evenly-strided subsample
        probe_ids = np.arange(0, n, max(1, n // 512), dtype=np.int32)
        floor = fleet.evaluate(x, ages[0], ids=probe_ids, cal_age=ages[0])
        slo = SLO_OVER_FLOOR * float(np.median(floor))

        planner = MaintenancePlanner(fleet, ages, costs=ActionCosts(),
                                     slo=slo,
                                     n_probe=128 if quick else 256)
        plan = planner.plan(x)
        replays = {
            name: simulate_policy(fleet, x, ages, acts, planner.costs,
                                  slo, policy=name)
            for name, acts in _policies(n, ages, plan.actions)
        }
    wall_s = time.time() - t0
    rss_mb = (resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
              - rss0) / 1024.0

    dominates = [
        all(replays["plan"][i]["cost_adjusted_acc"]
            >= replays[b][i]["cost_adjusted_acc"] for b in ("never",
                                                            "always"))
        for i in range(len(ages))
    ]
    action_counts = {name: int((plan.actions == a).sum())
                     for a, name in enumerate(
                         ("none", "recalibrate", "field_retrain", "retire"))}
    return {
        "n_devices": n,
        "chunk": spec.chunk,
        "slo": slo,
        "timeline": [{"label": l, "t": t} for l, t in DEFAULT_TIMELINE],
        "plan": {"expected_cost": plan.expected_cost,
                 "remap_horizon": (list(plan.remap_horizon)
                                   if plan.remap_horizon else None),
                 "actions": action_counts},
        "surrogate_train_pinball": (planner.ranker.train_pinball
                                    if planner.ranker else None),
        "replays": replays,
        "campaign_wall_s": wall_s,
        "campaign_rss_delta_mb": rss_mb,
        "gates": {
            "plan_dominates_at_every_checkpoint": all(dominates),
            "chunk_compiled_once": (sent.ok and fleet.cache_size() == 1),
            "retrain_dominated": action_counts["field_retrain"] == 0,
        },
    }


def write_json(row, label: str, quick: bool, seed: int) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"fleet_{label}.json")
    doc = {"schema": 1,
           "label": label,
           "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
           "jax_backend": jax.default_backend(),
           "quick": quick,
           "seed": seed,
           "metric": "cost_adjusted_acc = mean(1/(1+rel_err)) - "
                     "acc_per_cost * cum_cost / n; cost = action costs + "
                     "slo_penalty per violating device-checkpoint",
           **row}
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    return path


def main(quick: bool = False, seed: int = 0, label: str | None = None,
         n_devices: int | None = None, telemetry: str | None = None):
    if telemetry is not None:
        OBS.enable()
    row = run(quick=quick, seed=seed, n_devices=n_devices)
    print(f"fleet_devices,{row['n_devices']},chunk={row['chunk']}")
    print(f"fleet_slo,{row['slo']:.4f},rel_err")
    for i, (label_i, _) in enumerate(DEFAULT_TIMELINE):
        cols = ",".join(
            f"{row['replays'][p][i]['cost_adjusted_acc']:.4f}"
            for p in ("never", "always", "plan"))
        print(f"fleet_cost_adjusted_acc,{label_i},{cols}")
        cols = ",".join(str(row['replays'][p][i]['violations'])
                        for p in ("never", "always", "plan"))
        print(f"fleet_slo_violations,{label_i},{cols}")
    for name, cnt in row["plan"]["actions"].items():
        print(f"fleet_plan_actions,{name},{cnt}")
    print(f"fleet_campaign_wall_s,{row['campaign_wall_s']:.1f},s")
    print(f"fleet_campaign_rss_delta_mb,{row['campaign_rss_delta_mb']:.0f},"
          "mb")
    for k, v in row["gates"].items():
        print(f"fleet_{k},{int(v)},bool")
    path = write_json(row, label or ("quick" if quick else "full"),
                      quick, seed)
    print(f"fleet_json,{os.path.abspath(path)},written")
    if telemetry is not None:
        from repro.obs import snapshot, write_snapshot
        if telemetry == "-":
            print(json.dumps(snapshot(), indent=2, sort_keys=True))
        else:
            write_snapshot(telemetry)
            print(f"telemetry snapshot -> {telemetry}")
    bad = [k for k, v in row["gates"].items() if not v]
    if bad:
        raise SystemExit(f"fleet gates violated: {bad}")
    return row


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: 10^4 devices, reduced emulator protocol")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--label", default=None)
    ap.add_argument("--devices", type=int, default=None,
                    help="override the campaign's population size")
    ap.add_argument("--telemetry", nargs="?", const="-", default=None,
                    metavar="PATH",
                    help="enable the metrics registry and dump the JSON "
                         "snapshot (PATH, or stdout when bare)")
    args = ap.parse_args()
    main(quick=args.quick, seed=args.seed, label=args.label,
         n_devices=args.devices, telemetry=args.telemetry)
