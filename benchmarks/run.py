# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness entry point.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only table1,fig4,...]

Each bench maps to one paper artifact (see DESIGN.md §6):
  table1  -- emulator MAE vs circuit for both RRAM+PS32 geometries
  fig4    -- train/test loss trajectory (lr-halving schedule)
  fig5    -- DO(V, G) response heatmap structure + emulator agreement
  fig6    -- loss vs number of training samples
  speed   -- circuit vs analytic vs emulator timing (headline claim)
  system  -- tiny-LM train throughput, digital vs analog-emulated
Emits name,value,derived CSV lines.
"""
import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale protocols (hours on CPU)")
    ap.add_argument("--only", default="")
    args, _ = ap.parse_known_args()

    from benchmarks import (bench_table1, bench_fig4, bench_fig5, bench_fig6,
                            bench_speed, bench_system)
    benches = {
        "table1": bench_table1.main,
        "fig4": bench_fig4.main,
        "fig5": bench_fig5.main,
        "fig6": bench_fig6.main,
        "speed": bench_speed.main,
        "system": bench_system.main,
    }
    only = [s for s in args.only.split(",") if s]
    failures = 0
    for name, fn in benches.items():
        if only and name not in only:
            continue
        t0 = time.time()
        print(f"# --- {name} ---", flush=True)
        try:
            fn()
            print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            failures += 1
            print(f"# {name} FAILED:", flush=True)
            traceback.print_exc()
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
