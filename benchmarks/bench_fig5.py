"""Paper Fig. 5: the emulated DO(V, G) response heatmap.

Sweep one cell's (normalized V, normalized G) with the other parameters
randomized, for a positive-weight and a negative-weight column; check the
emulator reproduces the circuit's threshold/power-law structure:
  DO ~ const        if V < V_const
  DO ~ k(V-V_c)^a   otherwise, monotone in G
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import QUICK, get_emulator
from repro.configs.base import AnalogConfig
from repro.configs.rram_ps32 import CASE_A
from repro.core import conv4xbar
from repro.core.circuit import CircuitParams, block_response
from repro.core.emulator import normalize_features, sample_block_inputs


def sweep(n_grid: int = 12, seed: int = 0, tcfg=QUICK):
    geom, acfg, cp = CASE_A, AnalogConfig(), CircuitParams()
    res = get_emulator(geom.name, tcfg, seed)
    key = jax.random.PRNGKey(seed)
    base_x, periph = sample_block_inputs(key, 1, geom, acfg)
    vs = jnp.linspace(0.0, 1.0, n_grid)
    gs = jnp.linspace(0.0, 1.0, n_grid)

    grids = {}
    for which, col in (("pos", 0), ("neg", 1)):
        xs = []
        for v in vs:
            for g in gs:
                x = base_x
                x = x.at[0, 0, 0, 0, :].set(v * acfg.v_read)   # cell voltage
                x = x.at[0, 1, 0, 0, col].set(
                    acfg.g_min + g * (acfg.g_max - acfg.g_min))
                xs.append(x[0])
        X = jnp.stack(xs)
        P = jnp.tile(periph, (X.shape[0], 1))
        y_circ = block_response(X, cp, P).reshape(n_grid, n_grid)
        y_emu = conv4xbar.apply_fused(res.params,
                                      normalize_features(X, acfg),
                                      P).reshape(n_grid, n_grid)
        grids[which] = (np.asarray(y_circ), np.asarray(y_emu))
    return grids


def structure_checks(grids):
    """Threshold + monotonicity structure on the circuit; emulator tracks."""
    yc, ye = grids["pos"]
    n = yc.shape[0]
    # V below threshold (first rows: v < v_th/v_read ~ 0.4) ~ flat in V
    low = yc[: max(2, int(0.3 * n))]
    flat_low = float(np.std(low)) < 0.25 * float(np.std(yc) + 1e-12)
    # above threshold: monotone increasing in V for high G
    hi_g = yc[:, -1]
    mono_v = bool(np.all(np.diff(hi_g[int(0.45 * n):]) > -1e-4))
    rms = float(np.sqrt(np.mean((yc - ye) ** 2)))
    corr = float(np.corrcoef(yc.ravel(), ye.ravel())[0, 1])
    return {"flat_below_threshold": flat_low, "monotone_above": mono_v,
            "emulator_rms_v": rms, "emulator_corr": corr}


def main(csv=True):
    grids = sweep()
    chk = structure_checks(grids)
    if csv:
        print(f"fig5_heatmap,{chk['emulator_rms_v']*1e3:.2f},"
              f"corr={chk['emulator_corr']:.4f};"
              f"flat_below_thr={chk['flat_below_threshold']};"
              f"monotone_above={chk['monotone_above']}")
    return chk


if __name__ == "__main__":
    main()
