"""Roofline analysis over the dry-run records (results/dryrun/*.json).

Hardware model (TPU v5e-like, per chip):
  PEAK_FLOPS = 197e12 bf16 FLOP/s
  HBM_BW     = 819e9  B/s
  ICI_BW     = 50e9   B/s effective collective bandwidth per chip (one
               link-pair busy; a conservative single-link model -- v5e has
               multiple ICI links but collectives on a 2D mesh typically
               bottleneck on one axis at a time)

Terms (seconds, per step, per chip -- all inputs are per-device values from
the SPMD-partitioned program, with while-loop bodies multiplied by trip
count by benchmarks.hlo_analysis):
  compute    = flops / PEAK_FLOPS
  memory     = hbm_bytes / HBM_BW
  collective = collective_bytes / ICI_BW

MODEL_FLOPS (the useful-work floor): 6*N*tokens for training (2*N forward
+ 4*N backward), 2*N_active*tokens for prefill, 2*N_active*batch per decode
step. ratio = MODEL_FLOPS / (chips * HLO_flops_per_chip) shows how much of
compiled compute is useful (catches remat/causal-masking/replication waste).
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def model_flops(rec: Dict) -> float:
    """Global useful FLOPs per step."""
    n_active = rec["active_params"]
    B, S = rec["global_batch"], rec["seq_len"]
    mode = rec["mode"]
    if mode == "train":
        return 6.0 * n_active * B * S
    if mode == "prefill":
        return 2.0 * n_active * B * S
    return 2.0 * n_active * B          # decode: one token


def analyze_record(rec: Dict) -> Dict:
    ana = rec["hlo_analysis"]
    chips = rec["n_chips"]
    t_comp = ana["flops"] / PEAK_FLOPS
    t_mem = ana["hbm_bytes"] / HBM_BW
    t_coll = ana.get("collective_bytes", 0.0) / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec)
    useful_ratio = mf / max(ana["flops"] * chips, 1.0)
    # roofline fraction: useful work per step / (bound step time * peak)
    step_time = max(terms.values())
    frac = (mf / chips / PEAK_FLOPS) / max(step_time, 1e-12)
    suggestions = {
        "compute": "reduce non-useful FLOPs (remat policy, causal-block "
                   "skipping, replicated attention)",
        "memory": "shrink fp32 temporaries / fuse elementwise chains / "
                  "quantize the KV cache",
        "collective": "cheaper weight gathers (bf16 once per step), larger "
                      "microbatches, int8 gradient compression, resharding",
    }
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "mode": rec["mode"], "chips": chips,
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "useful_ratio": useful_ratio,
        "roofline_fraction": frac,
        "next_lever": suggestions[dominant],
        "temp_gb": rec["memory"]["temp_bytes"] / 1e9,
        "compile_s": rec.get("compile_s", 0.0),
    }


def load_records(results_dir: str = RESULTS_DIR, mesh: Optional[str] = None,
                 tag: str = "") -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("status") != "ok":
            recs.append(r)
            continue
        if mesh and r["mesh"] != mesh:
            continue
        if (r.get("tag") or "") != tag:
            continue
        recs.append(r)
    return recs


def markdown_table(results_dir: str = RESULTS_DIR, mesh: str = "single",
                   tag: str = "") -> str:
    rows = []
    skips = []
    for r in load_records(results_dir, tag=tag):
        if r.get("status") == "skipped":
            if r["mesh" if "mesh" in r else "shape"]:
                skips.append(r)
            continue
        if r["mesh"] != mesh:
            continue
        rows.append(analyze_record(r))
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    out = ["| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | dominant "
           "| useful/HLO | roofline-frac | next lever |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3g} | "
            f"{r['t_memory_s']:.3g} | {r['t_collective_s']:.3g} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} | {r['next_lever']} |")
    seen = set()
    for s in skips:
        key = (s["arch"], s["shape"])
        if key in seen:
            continue
        seen.add(key)
        out.append(f"| {s['arch']} | {s['shape']} | -- | -- | -- | skipped | "
                   f"-- | -- | {s.get('reason','')[:60]} |")
    return "\n".join(out)


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    print(markdown_table(mesh=args.mesh, tag=args.tag))


if __name__ == "__main__":
    main()
