"""Paper Fig. 4: train/test loss trajectory with the lr-halving schedule;
checks (a) no overfit gap, (b) monotone descent through lr drops."""
from __future__ import annotations

import jax

from repro.configs.base import AnalogConfig
from repro.configs.rram_ps32 import CASE_A, EmulatorTrainConfig
from repro.core.circuit import CircuitParams
from repro.core.emulator import train_emulator


def run(epochs: int = 80, n_train: int = 6000):
    tcfg = EmulatorTrainConfig(
        n_train=n_train, n_test=600, epochs=epochs, lr=2e-3,
        lr_halve_at=(epochs // 2, int(epochs * 0.75), int(epochs * 0.9)),
        batch_size=512)
    res = train_emulator(jax.random.PRNGKey(0), CASE_A, AnalogConfig(),
                         CircuitParams(), tcfg,
                         log_every=max(1, epochs // 12))
    h = res.history
    gap = [abs(te - tr) / max(te, 1e-12)
           for tr, te in zip(h["train"], h["test"])]
    return {"history": h, "final_gap_rel": gap[-1] if gap else float("nan"),
            "monotone_test": all(b <= a * 1.15 for a, b in
                                 zip(h["test"], h["test"][1:]))}


def main(csv=True):
    out = run()
    h = out["history"]
    if csv:
        print(f"fig4_loss_curve,{h['test'][-1]*1e6:.2f},"
              f"final_test_mse={h['test'][-1]:.3e};"
              f"gap={out['final_gap_rel']:.3f};"
              f"monotone={out['monotone_test']}")
    for e, tr, te in zip(h["epoch"], h["train"], h["test"]):
        print(f"fig4_point,{e},train={tr:.3e};test={te:.3e}")
    return out


if __name__ == "__main__":
    main()
