"""Task-level robustness: accuracy vs sigma / vs age on ACTUAL token
prediction, through a ``ServeSession`` (repro.launch.serve).

``bench_robustness`` and ``bench_lifetime`` measure matmul fidelity; this
bench closes the ROADMAP's "task-level robustness" loop: one reduced
model serves its MLP projections on analog hardware, the fleet's device
corner is swept (programming sigma; retention age), and each point
reports how the MODEL's predictions degrade against the digital serve:

  * ``token_agreement`` -- fraction of greedy-decoded tokens matching
    the digital reference (the headline task metric);
  * ``acc_logits``      -- 1 / (1 + NRMSE) of the decode-step logit
    trajectory vs digital (continuous, CRN-monotone companion).

Both backends run (emulator on every MLP projection; circuit on the
down-projections -- each probe is a Newton block solve, so its analog
surface is kept CI-sized), with per-site noise-aware calibration at each
sweep point.  The sweep exercises the DeploymentState redesign
end-to-end: every point re-materializes the per-site device states and
threads them through the SAME compiled prefill/decode executables --

Asserted (exit 1 on violation):
  * compile-once: a ``repro.obs.RecompileSentinel`` watches the session's
    prefill/decode trace counters and every call site's unified forward
    across the whole sigma x age sweep -- one trace each, never a
    recompile;
  * on the sigma axis, the ideal corner scores at least as well as the
    heaviest swept corner on ``acc_logits`` (common-random-numbers fleet
    key; the age axis is reported ungated -- see the note in ``run``);
  * every metric is finite.

CSV lines to stdout + results/task_<label>.json.

  PYTHONPATH=src python -m benchmarks.bench_task [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from benchmarks.bench_speed import SMOKE
from benchmarks.common import QUICK, get_emulator
from repro.configs.base import AnalogConfig
from repro.configs.rram_ps32 import CASE_A
from repro.core.analog import AnalogExecutor
from repro.launch.serve import ServeSession
from repro.nonideal import Scenario
from repro.obs import RecompileSentinel

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")

ARCH = "gemma3-1b"
LAYERS = 2                       # < len(pattern): unrolled, state-threaded
SIGMAS = (0.0, 0.05, 0.15)
SIGMAS_QUICK = (0.0, 0.1)
AGES = (0.0, 86_400.0, 2_592_000.0)     # deploy / 1d / 1mo
AGES_QUICK = (0.0, 2_592_000.0)
AGE_SIGMA = 0.03                 # fab corner the aging fleet starts from
DRIFT_NU = 0.05


def _metrics(out: dict, ref: dict) -> dict:
    tok = out["tokens"] == ref["tokens"]
    lo, lr = out["logits"], ref["logits"]
    nrmse = float(np.linalg.norm(lo - lr) / max(np.linalg.norm(lr), 1e-12))
    return {"token_agreement": float(np.mean(tok)),
            "acc_logits": 1.0 / (1.0 + nrmse)}


def _backend_executor(backend: str, eparams):
    # circuit: every probe is a Newton block solve -- serve only the
    # down-projections to keep the CI budget; emulator serves all of MLP
    layers = ("mlp",) if backend == "emulator" else ("mlp.down",)
    return AnalogExecutor(
        acfg=AnalogConfig(backend=backend, layers=layers), geom=CASE_A,
        emulator_params=eparams if backend == "emulator" else None,
        use_pallas=False)


def run(quick: bool = False, seed: int = 0):
    res = get_emulator(CASE_A.name, SMOKE if quick else QUICK, seed)
    B, P, G = (2, 8, 6) if quick else (4, 16, 12)
    calib_n = 8 if quick else 16
    sigmas = SIGMAS_QUICK if quick else SIGMAS
    ages = AGES_QUICK if quick else AGES
    fleet_key = jax.random.fold_in(jax.random.PRNGKey(seed), 7)  # CRN

    ref = ServeSession(ARCH, reduced=True, reduced_layers=LAYERS, batch=B,
                       prompt_len=P, gen=G, seed=seed,
                       executor=None).generate()

    curves = []
    for backend in ("emulator", "circuit"):
        ex = _backend_executor(backend, res.params)
        sess = ServeSession(ARCH, reduced=True, reduced_layers=LAYERS,
                            batch=B, prompt_len=P, gen=G, seed=seed,
                            executor=ex)

        def point(scenario):
            ex.deploy(scenario=scenario, key=fleet_key)
            sess.calibrate(n=calib_n)
            return _metrics(sess.generate(), ref)

        # compile-once across the WHOLE sweep: the per-site device states
        # are traced arguments of the serving steps, and each site's
        # unified forward compiles exactly one calibration batch shape
        with RecompileSentinel(session=sess, executor=ex, strict=False,
                               label=f"task:{backend}") as sent:
            sigma_pts = [point(Scenario(name="task", prog_sigma=s))
                         for s in sigmas]
            age_pts = [point(Scenario(name="task", prog_sigma=AGE_SIGMA,
                                      drift_nu=DRIFT_NU, drift_t=t))
                       for t in ages]
        compiled_once = sent.ok
        curves.append({
            "backend": backend,
            "analog_layers": list(ex.acfg.layers),
            "n_sites": len(sess.sites()),
            "compiled_once": compiled_once,
            "sigma": {"levels": list(sigmas), "points": sigma_pts},
            "age": {"levels": list(ages), "sigma": AGE_SIGMA,
                    "nu": DRIFT_NU, "points": age_pts},
            # weak endpoint check, SIGMA axis only: the calibrated ideal
            # corner may not strictly beat a mild corner on a tiny greedy
            # decode (probe budgets are CI-sized), so allow token-noise
            # tolerance.  The age axis is reported ungated: recalibrated
            # drift can RAISE circuit fidelity vs digital -- shrunken
            # conductances load the bitlines less, so the solve runs in a
            # more linear regime (a real effect, not a bench artifact).
            "ideal_no_worse": (
                sigma_pts[0]["acc_logits"] >= sigma_pts[-1]["acc_logits"]
                - 0.02),
            "finite": all(np.isfinite(list(p.values())).all()
                          for p in sigma_pts + age_pts),
        })
    return curves


def write_json(curves, label: str, quick: bool, seed: int) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"task_{label}.json")
    doc = {"schema": 1,
           "label": label,
           "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
           "jax_backend": jax.default_backend(),
           "quick": quick,
           "seed": seed,
           "arch": f"{ARCH}-reduced-{LAYERS}l",
           "metric": "token_agreement = greedy-token match vs digital "
                     "serve; acc_logits = 1/(1+NRMSE) of the decode logit "
                     "trajectory; per-site noise-aware calibration at "
                     "every sweep point; states threaded through ONE "
                     "compiled serve per backend (ServeSession)",
           "curves": curves}
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    return path


def main(quick: bool = False, seed: int = 0, label: str | None = None):
    curves = run(quick=quick, seed=seed)
    for c in curves:
        for axis in ("sigma", "age"):
            for lvl, p in zip(c[axis]["levels"], c[axis]["points"]):
                print(f"task_{c['backend']}_{axis},{lvl:g},"
                      f"{p['token_agreement']:.4f},{p['acc_logits']:.4f}")
        for k in ("compiled_once", "ideal_no_worse", "finite"):
            print(f"task_{c['backend']}_{k},{int(c[k])},bool")
    path = write_json(curves, label or ("quick" if quick else "full"),
                      quick, seed)
    print(f"task_json,{os.path.abspath(path)},written")
    bad = [f"{c['backend']}:{k}" for c in curves
           for k in ("compiled_once", "ideal_no_worse", "finite")
           if not c[k]]
    if bad:
        raise SystemExit(f"task-level invariants violated: {bad}")
    return curves


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: tiny emulator, 2-level sweeps")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--label", default=None)
    args = ap.parse_args()
    main(quick=args.quick, seed=args.seed, label=args.label)
