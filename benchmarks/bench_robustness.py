"""Robustness sweeps: how fast does each analog backend degrade as the
device corner worsens?

Two axes per backend (emulator + analytic):
  * accuracy vs programming-variation sigma (lognormal conductance noise)
  * accuracy vs retention drift time (g * (t/t0)^-nu)

Each point is the mean over N device draws, evaluated in ONE compiled call
per backend (repro.nonideal.ScenarioSweep: scenario parameters are traced,
so the whole curve reuses one executable -- asserted here).  All points of
a curve share the device key (common random numbers), which is what makes
the curves monotone instead of sampling-jittered.

accuracy = 1 / (1 + NRMSE(y_scenario, y_ideal_backend)) in (0, 1]; 1 means
the corner is indistinguishable from the ideal device.  `corr_digital`
(Pearson r against the exact digital matmul) is reported alongside for
absolute quality context.

CSV lines to stdout + a machine-readable artifact in
results/robustness_<label>.json.

  PYTHONPATH=src python -m benchmarks.bench_robustness [--quick]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_speed import SMOKE
from benchmarks.common import QUICK, get_emulator
from repro.configs.base import AnalogConfig
from repro.configs.rram_ps32 import CASE_A
from repro.core.analog import AnalogExecutor
from repro.nonideal import Scenario, ScenarioSweep
from repro.obs import RecompileSentinel

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")

SIGMAS = (0.0, 0.02, 0.05, 0.1, 0.2)
DRIFT_TS = (0.0, 1e2, 1e4, 1e6)          # seconds since programming
DRIFT_NU = 0.05
SIGMAS_QUICK = (0.0, 0.1)
DRIFT_TS_QUICK = (0.0, 1e4)


def _nrmse(y: np.ndarray, ref: np.ndarray) -> float:
    return float(np.linalg.norm(y - ref) / max(np.linalg.norm(ref), 1e-12))


def _accuracy(y: np.ndarray, ref: np.ndarray) -> float:
    return 1.0 / (1.0 + _nrmse(y, ref))


def _monotone_decreasing(vals, tol=1e-9) -> bool:
    return all(vals[i + 1] <= vals[i] + tol for i in range(len(vals) - 1))


def _sweep_axis(sweep: ScenarioSweep, x, scenarios, key, y_ideal, y_digital):
    pts = []
    for s in scenarios:
        ym = np.asarray(sweep(x, s, key)).mean(axis=0)
        corr = float(np.corrcoef(ym.ravel(), y_digital.ravel())[0, 1])
        pts.append({"accuracy": _accuracy(ym, y_ideal),
                    "corr_digital": corr})
    return pts


def run(quick: bool = False, seed: int = 0):
    geom, acfg = CASE_A, AnalogConfig()
    res = get_emulator(geom.name, SMOKE if quick else QUICK, seed)
    key = jax.random.PRNGKey(seed)
    K, N, B = (128, 8, 8) if quick else (512, 32, 16)
    n_draws = 2 if quick else 8
    w = jax.random.normal(key, (K, N)) * 0.2
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, K)) * 0.5
    y_digital = np.asarray(x @ w)
    key_dev = jax.random.fold_in(key, 2)   # shared across levels: CRN
    sigmas = SIGMAS_QUICK if quick else SIGMAS
    drift_ts = DRIFT_TS_QUICK if quick else DRIFT_TS

    curves = []
    for backend in ("emulator", "analytic"):
        ex = AnalogExecutor(
            acfg=dataclasses.replace(acfg, backend=backend), geom=geom,
            emulator_params=res.params)
        ex.calibrate(jax.random.fold_in(key, 3), w, "rob")
        y_ideal = np.asarray(ex.matmul(x, w, "rob"))
        sweep = ScenarioSweep(ex, w, "rob", n_draws=n_draws)
        # NOTE one name for every swept scenario: `name` is pytree aux data
        # (static), so it must not vary within a compile-once sweep.
        # strict sentinel: a retrace means scenario params stopped being
        # traced arguments -- fail loudly right here
        with RecompileSentinel(sweep=sweep,
                               label=f"robustness:{backend}") as sent:
            sig_pts = _sweep_axis(
                sweep, x,
                [Scenario(name="sweep", prog_sigma=s) for s in sigmas],
                key_dev, y_ideal, y_digital)
            drift_pts = _sweep_axis(
                sweep, x,
                [Scenario(name="sweep", drift_nu=DRIFT_NU, drift_t=t)
                 for t in drift_ts],
                key_dev, y_ideal, y_digital)
        curves.append({
            "backend": backend,
            "n_draws": n_draws,
            "compiled_once": sent.ok,
            "sigma": {"levels": list(sigmas),
                      "points": sig_pts,
                      "monotone": _monotone_decreasing(
                          [p["accuracy"] for p in sig_pts])},
            "drift": {"levels": list(drift_ts), "nu": DRIFT_NU,
                      "points": drift_pts,
                      "monotone": _monotone_decreasing(
                          [p["accuracy"] for p in drift_pts])},
        })
    return curves


def write_json(curves, label: str, quick: bool, seed: int) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"robustness_{label}.json")
    doc = {"schema": 1,
           "label": label,
           "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
           "jax_backend": jax.default_backend(),
           "quick": quick,
           "seed": seed,
           "matmul": "accuracy = 1/(1+NRMSE) vs the backend's own ideal "
                     "device; corr_digital vs the exact digital matmul",
           "curves": curves}
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    return path


def main(quick: bool = False, seed: int = 0, label: str | None = None):
    curves = run(quick=quick, seed=seed)
    for c in curves:
        for axis in ("sigma", "drift"):
            ax = c[axis]
            for lvl, p in zip(ax["levels"], ax["points"]):
                print(f"robustness_{c['backend']}_{axis},{lvl:g},"
                      f"{p['accuracy']:.4f},{p['corr_digital']:.4f}")
            print(f"robustness_{c['backend']}_{axis}_monotone,"
                  f"{int(ax['monotone'])},bool")
    path = write_json(curves, label or ("quick" if quick else "full"),
                      quick, seed)
    print(f"robustness_json,{os.path.abspath(path)},written")
    bad = [f"{c['backend']}/{ax}" for c in curves for ax in ("sigma", "drift")
           if not c[ax]["monotone"]]
    if bad:
        raise SystemExit(f"non-monotone robustness curves: {bad}")
    return curves


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: tiny emulator, 2-scenario sweep")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--label", default=None)
    args = ap.parse_args()
    main(quick=args.quick, seed=args.seed, label=args.label)
