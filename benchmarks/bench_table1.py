"""Paper Table 1: emulator MAE vs the circuit simulator for the two
RRAM+PS32 computing-block geometries.

Paper (SPICE ground truth, 50k samples, 2000 epochs on GPU):
  (2,4,64,2) -> 1 voltage : MAE 0.981 mV
  (2,2,64,8) -> 4 voltage : MAE 0.955 mV
Ours (NR-solver ground truth; CPU-budget 'quick' protocol by default; pass
tcfg=FULL for the paper protocol).
"""
from __future__ import annotations

from benchmarks.common import QUICK, get_emulator
from repro.core import theory


def run(tcfg=QUICK, seed: int = 0):
    rows = []
    for geom, paper_mae_mv in (("rram_ps32_a", 0.981), ("rram_ps32_b", 0.955)):
        res = get_emulator(geom, tcfg, seed)
        p_pred = theory.predicted_probability(res.test_mse, 2)
        rows.append({
            "block": geom,
            "test_mse": res.test_mse,
            "mae_mv": res.test_mae * 1e3,
            "paper_mae_mv": paper_mae_mv,
            "thm41_bound_s3": res.bound,
            "sig_prob_s3": res.sig_prob,
            "pred_prob_s2": p_pred,
            "accepted_s3": res.accepted,
        })
    return rows


def main(csv=True):
    rows = run()
    for r in rows:
        if csv:
            print(f"table1_{r['block']},{r['mae_mv']*1e3:.1f},"
                  f"mae_mv={r['mae_mv']:.3f};paper={r['paper_mae_mv']};"
                  f"mse={r['test_mse']:.3e};sig_p_s3={r['sig_prob_s3']:.3f}")
        else:
            print(r)
    return rows


if __name__ == "__main__":
    main()
