"""Regenerate the data-driven sections of EXPERIMENTS.md from
results/dryrun/*.json. The §Perf narrative is maintained by hand in
PERF_LOG below (hypothesis -> change -> before -> after -> verdict)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.roofline import (ICI_BW, HBM_BW, PEAK_FLOPS, analyze_record,
                                 load_records, model_flops)

ROOT = os.path.join(os.path.dirname(__file__), "..")


def fmt_cell_table(mesh: str, tag: str) -> str:
    rows, skips = [], []
    for r in load_records(mesh=mesh, tag=tag):
        if r.get("status") == "skipped":
            skips.append(r)
            continue
        if r["mesh"] != mesh:
            continue
        rows.append(analyze_record(r))
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    out = ["| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | dominant | "
           "useful/HLO | roofline-frac | HBM fit (temp GB) |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3g} | "
            f"{r['t_memory_s']:.3g} | {r['t_collective_s']:.3g} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} | {r['temp_gb']:.1f} |")
    seen = set()
    for s in skips:
        key = (s["arch"], s["shape"], mesh)
        if key in seen or s.get("mesh") != mesh:
            continue
        seen.add(key)
        out.append(f"| {s['arch']} | {s['shape']} | — | — | — | *skipped* | "
                   f"— | — | — |")
    return "\n".join(out)


def dryrun_summary(tag: str = "") -> str:
    ok = {"single": 0, "multi": 0}
    sk = {"single": 0, "multi": 0}
    comp = []
    for r in load_records(tag=tag):
        if r.get("status") == "skipped":
            sk[r["mesh"]] = sk.get(r["mesh"], 0) + 1
            continue
        ok[r["mesh"]] += 1
        comp.append(r.get("compile_s", 0))
    return (f"single-pod OK: {ok['single']}, multi-pod OK: {ok['multi']}, "
            f"documented skips: {sk['single'] + sk['multi']} "
            f"(compile time: median "
            f"{sorted(comp)[len(comp)//2] if comp else 0:.0f}s, max "
            f"{max(comp) if comp else 0:.0f}s)")


def perf_compare_table(cells) -> str:
    out = ["| cell | metric | baseline | optimized | Δ |", "|---|---|---|---|---|"]
    for arch, shape in cells:
        base = opt = None
        for r in load_records(tag=""):
            if r.get("status") == "ok" and r["arch"] == arch \
                    and r["shape"] == shape and r["mesh"] == "single":
                base = analyze_record(r)
        for r in load_records(tag="sp"):
            if r.get("status") == "ok" and r["arch"] == arch \
                    and r["shape"] == shape and r["mesh"] == "single":
                opt = analyze_record(r)
        if not (base and opt):
            continue
        bstep = max(base["t_compute_s"], base["t_memory_s"], base["t_collective_s"])
        ostep = max(opt["t_compute_s"], opt["t_memory_s"], opt["t_collective_s"])
        out.append(f"| {arch} {shape} | bound step time (s) | {bstep:.3g} "
                   f"({base['dominant']}) | {ostep:.3g} ({opt['dominant']}) | "
                   f"{(1 - ostep / bstep) * 100:+.0f}% |")
        out.append(f"| | roofline fraction | {base['roofline_fraction']:.3f} | "
                   f"{opt['roofline_fraction']:.3f} | "
                   f"×{opt['roofline_fraction'] / max(base['roofline_fraction'], 1e-9):.2f} |")
        out.append(f"| | temp HBM (GB) | {base['temp_gb']:.1f} | "
                   f"{opt['temp_gb']:.1f} | "
                   f"{(1 - opt['temp_gb'] / base['temp_gb']) * 100:+.0f}% |")
    return "\n".join(out)


def main():
    header = open(os.path.join(ROOT, "EXPERIMENTS.header.md")).read()
    parts = [header]
    parts.append("\n## §Dry-run\n")
    parts.append(f"All (arch × shape × mesh) cells lower + compile via "
                 f"`repro.launch.dryrun` with ShapeDtypeStruct stand-ins "
                 f"(zero allocation). **{dryrun_summary()}** — and the same "
                 f"40 cells also pass on the 2×16×16 multi-pod mesh "
                 f"(proves the `pod` axis shards). Raw records: "
                 f"`results/dryrun/*.json` (memory_analysis, cost_analysis, "
                 f"per-collective bytes, compile times).\n")
    parts.append("\n## §Roofline — baseline (paper-faithful config), "
                 "single-pod 16×16\n")
    parts.append("Hardware model: 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s "
                 "ICI per chip. Terms are seconds per step per chip from "
                 "the while-trip-aware HLO cost model "
                 "(`benchmarks/hlo_analysis.py`); `useful/HLO` = "
                 "MODEL_FLOPS / compiled FLOPs (6·N·D train, 2·N_active·D "
                 "prefill, 2·N_active·B decode).\n")
    parts.append(fmt_cell_table("single", ""))
    parts.append("\n\n### Multi-pod (2×16×16) baseline\n")
    parts.append(fmt_cell_table("multi", ""))
    parts.append("\n\n## §Roofline — optimized (sequence-parallel residual "
                 "+ MoE dispatch fixes), single-pod\n")
    parts.append("Train/prefill cells only — decode/long cells are "
                 "unchanged by the train-path levers (see §Perf). Known "
                 "outlier: llama4 prefill on the multi-pod mesh spikes "
                 "transient memory (MoE eval-capacity buffers at 1M "
                 "tokens); the fix is sequence-chunked prefill, noted as "
                 "future work.\n")
    parts.append(fmt_cell_table("single", "sp"))
    parts.append("\n\n### Baseline → optimized on the three hillclimb "
                 "cells\n")
    parts.append(perf_compare_table([
        ("phi3.5-moe-42b-a6.6b", "train_4k"),
        ("deepseek-coder-33b", "train_4k"),
        ("gemma3-1b", "train_4k"),
        ("qwen1.5-110b", "train_4k"),
    ]))
    perf = open(os.path.join(ROOT, "EXPERIMENTS.perf.md")).read()
    parts.append("\n\n" + perf)
    with open(os.path.join(ROOT, "EXPERIMENTS.md"), "w") as f:
        f.write("\n".join(parts))
    print("EXPERIMENTS.md written")


if __name__ == "__main__":
    main()
