"""Fleet lifetime curves: how fast does serving accuracy decay as the
crossbar fleet ages, and how much of it do the mitigations buy back?

One fleet (fixed device key: same sigma draw, same stuck cells at every
age) is walked through the drift timeline t = 1h / 1d / 1mo twice per
backend:

  * **unmitigated** -- calibrated once at deployment, then left alone;
  * **mitigated**   -- stuck-fault-aware column remapping + noise-aware
    recalibration at every checkpoint, plus (emulator backend)
    serving-distribution retraining on the aged fleet
    (``make_field_retrainer``), hot-swapped into the executor.

and, on the emulator backend, a third time:

  * **conditioned** -- ONE scenario-conditioned emulator
    (``train_conditioned_emulator``) with remap + recalibration, a
    ONE-TIME field calibration at deployment
    (``make_conditioned_field_calibrator``: the realized device across
    its predicted drift trajectory) and ZERO retraining between
    checkpoints: the net reads the fleet's age and corner off its
    scenario-feature input (docs/emulator.md).  The gate is that this
    single net tracks (within ``COND_TRACK_TOL``) or beats the
    per-checkpoint fine-tuned baseline at every drift checkpoint.

The fleet's corner is a per-tile scenario batch (``tile_scenarios``): a
programming-sigma gradient across output groups plus uniform stuck-off
rate and drift, so the bench exercises heterogeneity, remapping and the
scheduler together.  accuracy = 1 / (1 + NRMSE) against the **young
ideal circuit output** (calibrated): the ground-truth computation the
fleet performed on day zero is the thing lifetime management tries to
preserve, for both backends.

Asserted (exit 1 on violation):
  * mitigation strictly dominates at every drift checkpoint, both backends;
  * the conditioned net matches or beats the fine-tuned baseline at every
    drift checkpoint with zero retrains recorded;
  * each lifetime walk reuses ONE compiled unified forward per input
    shape (ages, remaps, recalibrations, hot-swapped retrained params
    AND scenario features are all leaves of the one traced
    ``DeploymentState``: only the matmul batch and the two calibration
    probe batches -- cold and warm-start -- add executables);
  * ``DeploymentState.ideal()`` (identity permutation, zero read sigma
    and, conditioned, the all-zero feature block) is bit-identical to
    the plain serving fast path.

CSV lines to stdout + results/lifetime_<label>.json.

  PYTHONPATH=src python -m benchmarks.bench_lifetime [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import QUICK, get_conditioned_emulator, get_emulator
from repro.configs.base import AnalogConfig
from repro.configs.rram_ps32 import CASE_A, EmulatorTrainConfig
from repro.core.analog import AnalogExecutor
from repro.core.deployment import DeploymentState
from repro.nonideal import (LifetimeScheduler,
                            make_conditioned_field_calibrator,
                            make_field_retrainer, tile_scenarios)
from repro.nonideal.lifetime import DEFAULT_TIMELINE
from repro.obs import RecompileSentinel

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")

P_STUCK_OFF = 0.04
DRIFT_NU = 0.05
SIGMA_LO, SIGMA_HI = 0.02, 0.08        # per-tile fab gradient

# wear-aware remapping gate: a stressed corner -- heavy stuck-off rate +
# a retention-decay gradient across die positions -- where the physical
# host a group lands on decides how hard it drifts by end of horizon
WEAR_P_STUCK_OFF = 0.18
WEAR_NU_HI = 0.04
WEAR_KEYS = 6                          # fault draws sampled by the gate

# "matching" margin for the conditioned-vs-finetuned gate: the conditioned
# net must come within this accuracy of the per-checkpoint fine-tuned
# baseline at every drift checkpoint (it usually beats it -- the margin
# absorbs model-variance noise between two independently trained nets)
COND_TRACK_TOL = 0.01

# CI-budget emulator: enough training that the model floor sits well below
# the aging signal (the 2-epoch bench_speed SMOKE net is too coarse here)
LIFETIME_QUICK = EmulatorTrainConfig(n_train=4_000, n_test=500, epochs=80,
                                     lr=2e-3, lr_halve_at=(40, 60, 72),
                                     batch_size=512)


def _accuracy(y: np.ndarray, ref: np.ndarray) -> float:
    nrmse = float(np.linalg.norm(np.asarray(y) - ref)
                  / max(np.linalg.norm(ref), 1e-12))
    return 1.0 / (1.0 + nrmse)


def _fleet_scenario(nb: int, no: int):
    """Per-tile aging corner: sigma gradient across output groups, uniform
    stuck-off rate and drift exponent."""
    sig = np.broadcast_to(np.linspace(SIGMA_LO, SIGMA_HI, no), (nb, no))
    return tile_scenarios(nb, no, name="fleet", prog_sigma=sig,
                          p_stuck_off=P_STUCK_OFF, drift_nu=DRIFT_NU)


def _make_executor(backend: str, eparams) -> AnalogExecutor:
    return AnalogExecutor(
        acfg=AnalogConfig(backend=backend), geom=CASE_A,
        emulator_params=eparams if backend == "emulator" else None,
        use_pallas=False)


def _ideal_bit_identity(backend: str, eparams, x, w, tag: str) -> bool:
    """Unified forward fed ``DeploymentState.ideal()`` (unperturbed
    conductances, zero read sigma, identity permutation, all-zero
    scenario features, unit affine) vs the serving path's own output.
    For a conditioned net the zero feature block is exactly the ideal
    corner's encoding, so the identity must hold there too."""
    ex = _make_executor(backend, eparams)
    y_plain = np.asarray(ex.matmul(x, w, tag))
    plan = ex._plan_for(w, tag)
    x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    ep = ex.emulator_params if backend == "emulator" else {}
    y_sc = ex._unified_for(tag, w)(x2, DeploymentState.ideal(plan,
                                                             eparams=ep))
    return bool(np.array_equal(np.asarray(y_sc), y_plain))


def wear_remap_gate(seed: int = 0):
    """Wear-aware vs instantaneous fault remapping at end of horizon.

    A stressed corner (heavy stuck-off rate, per-die-position drift
    gradient) is deployed twice per fault draw with the analytic
    backend: ``remap=True`` (instantaneous assignment) and
    ``remap=<timeline ages>`` (wear-aware: candidates realized through
    the serving perturbation at every checkpoint age and selected by
    end-of-horizon weight deviation).  Both walks cold-calibrate at
    deploy, age to the end of ``DEFAULT_TIMELINE`` and warm-recalibrate
    -- then serving accuracy vs the digital product is compared on a
    large probe batch.  Gates: wear-aware >= instant for EVERY fault
    draw (the realized-score selection falls back to the instant
    assignment whenever anticipation doesn't pay), and
    ``remap_plan(horizon=None)`` stays bit-identical to a call without
    the argument."""
    from repro.nonideal import remap_plan

    key = jax.random.PRNGKey(seed)
    K, N, B = 64, 8, 256
    w = jax.random.normal(key, (K, N)) * 0.2
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, K)) * 0.5
    ref = np.asarray(x @ w)
    ages = tuple(t for _, t in DEFAULT_TIMELINE)

    probe = _make_executor("analytic", None)._plan_for(w, "probe")
    nu = np.broadcast_to(np.linspace(0.0, WEAR_NU_HI, probe.NO),
                         (probe.NB, probe.NO))
    corner = tile_scenarios(probe.NB, probe.NO, name="wear",
                            p_stuck_off=WEAR_P_STUCK_OFF, drift_nu=nu)

    # horizon=None must be bit-identical to a call without the argument
    acfg = AnalogConfig(backend="analytic")
    kb = jax.random.fold_in(key, 3)
    p_a, o_a = remap_plan(probe, acfg, corner, kb)
    p_b, o_b = remap_plan(probe, acfg, corner, kb, horizon=None)
    bit_identical = (np.array_equal(np.asarray(o_a), np.asarray(o_b))
                     and np.array_equal(np.asarray(p_a.g_feat),
                                        np.asarray(p_b.g_feat)))

    kf = jax.random.fold_in(key, 2)
    draws = []
    for i in range(WEAR_KEYS):
        kk = jax.random.fold_in(kf, i)
        out = {}
        for mode, remap in (("instant", True), ("wear", ages)):
            ex = _make_executor("analytic", None)
            ex.deploy(scenario=corner, key=kk, remap=remap)
            ex.calibrate(jax.random.fold_in(key, 11), w, "wear", n=64)
            ex.deploy(age=ages[-1])
            ex.calibrate(jax.random.fold_in(key, 12), w, "wear", n=64,
                         warm_start=True)
            out[mode] = _accuracy(ex.matmul(x, w, "wear"), ref)
        draws.append(out)
    return {
        "p_stuck_off": WEAR_P_STUCK_OFF,
        "drift_nu_hi": WEAR_NU_HI,
        "horizon": list(ages),
        "draws": draws,
        "wear_strict_wins": sum(d["wear"] > d["instant"] for d in draws),
        "wear_ge_instant_all": all(d["wear"] >= d["instant"]
                                   for d in draws),
        "horizon_none_bit_identical": bit_identical,
    }


def run(quick: bool = False, seed: int = 0):
    geom = CASE_A
    tcfg = LIFETIME_QUICK if quick else QUICK
    res = get_emulator(geom.name, tcfg, seed)
    cond = get_conditioned_emulator(geom.name, tcfg, seed)
    key = jax.random.PRNGKey(seed)
    K, N, B = (64, 8, 4) if quick else (128, 16, 8)
    calib_n = 32 if quick else 64
    w = jax.random.normal(key, (K, N)) * 0.2
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, K)) * 0.5
    k_fleet = jax.random.fold_in(key, 2)   # ONE fleet for every run

    # tile lattice of the (K, N) plan under this geometry
    probe = _make_executor("analytic", None)._plan_for(w, "probe")
    fleet = _fleet_scenario(probe.NB, probe.NO)

    # ground-truth reference: the young ideal fleet through the circuit
    # solver, calibrated -- what the hardware computed on day zero
    exc = _make_executor("circuit", None)
    exc.calibrate(jax.random.fold_in(key, 9), w, "ref", n=calib_n)
    ref = np.asarray(exc.matmul(x, w, "ref"))

    curves = []
    for backend in ("emulator", "circuit"):
        retrain = None
        if backend == "emulator":
            retrain = make_field_retrainer(jax.random.fold_in(key, 4))

        modes = [
            ("unmitigated", res.params, dict(remap=False, recalibrate=False,
                                             retrain=None)),
            ("mitigated", res.params, dict(remap=True, recalibrate=True,
                                           retrain=retrain)),
        ]
        if backend == "emulator":
            # ONE conditioned net: one-time field calibration at deploy
            # (the realized device across its predicted drift trajectory),
            # then zero retraining between checkpoints -- age and corner
            # ride the scenario-feature input
            modes.append(("conditioned", cond.params,
                          dict(remap=True, recalibrate=True,
                               retrain=make_conditioned_field_calibrator(
                                   jax.random.fold_in(key, 5)))))

        runs = {}
        for mode, eparams, kwargs in modes:
            ex = _make_executor(backend, eparams)
            sched = LifetimeScheduler(ex, fleet, timeline=DEFAULT_TIMELINE,
                                      key=k_fleet, calib_n=calib_n, **kwargs)
            # ONE unified forward; executables count only distinct input
            # SHAPES: the matmul batch, plus (when recalibrating) the
            # cold-calibration probe batch and its warm half-budget batch.
            # Ages, remaps, read draws, retrained params and affines are
            # all DeploymentState leaves and never add executables.
            expected = 2 if mode == "unmitigated" else 3
            with RecompileSentinel(executor=ex, max_traces=expected,
                                   strict=False,
                                   label=f"lifetime:{backend}:{mode}") as sent:
                recs = sched.run(w, "life", x)
            runs[mode] = [{"label": r["label"], "t": r["t"],
                           "retrained": r["retrained"],
                           "accuracy": _accuracy(r["y"], ref)}
                          for r in recs]
            runs[mode + "_compiled_once"] = (
                sent.ok
                and sent.new_counts.get("executor.unified[life]") == expected)

        dominates = [m["accuracy"] > u["accuracy"]
                     for u, m in zip(runs["unmitigated"][1:],
                                     runs["mitigated"][1:])]
        curve = {
            "backend": backend,
            "timeline": [{"label": l, "t": t} for l, t in DEFAULT_TIMELINE],
            "unmitigated": runs["unmitigated"],
            "mitigated": runs["mitigated"],
            "dominates_at_every_checkpoint": all(dominates),
            "compiled_once": (runs["unmitigated_compiled_once"]
                              and runs["mitigated_compiled_once"]),
            "ideal_bit_identical": _ideal_bit_identity(
                backend, res.params, x, w, "ident"),
        }
        if backend == "emulator":
            tracks = [c["accuracy"] >= m["accuracy"] - COND_TRACK_TOL
                      for m, c in zip(runs["mitigated"][1:],
                                      runs["conditioned"][1:])]
            curve.update({
                "conditioned": runs["conditioned"],
                "conditioned_tracks_finetune": all(tracks),
                # "zero retraining BETWEEN checkpoints": the deploy-time
                # field calibration (records[0]) is the one allowed
                "conditioned_zero_retrains": not any(
                    r["retrained"] for r in runs["conditioned"][1:]),
                "conditioned_compiled_once":
                    runs["conditioned_compiled_once"],
                "conditioned_ideal_bit_identical": _ideal_bit_identity(
                    backend, cond.params, x, w, "ident_cond"),
                "cond_track_tol": COND_TRACK_TOL,
            })
        curves.append(curve)
    return curves


def write_json(curves, wear, label: str, quick: bool, seed: int) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"lifetime_{label}.json")
    doc = {"schema": 1,
           "label": label,
           "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
           "jax_backend": jax.default_backend(),
           "quick": quick,
           "seed": seed,
           "fleet": {"p_stuck_off": P_STUCK_OFF, "drift_nu": DRIFT_NU,
                     "prog_sigma": [SIGMA_LO, SIGMA_HI],
                     "per_tile": True},
           "metric": "accuracy = 1/(1+NRMSE) vs the calibrated young-ideal "
                     "circuit output; mitigated = remap + recalibrate (+ "
                     "field retraining on the emulator backend); "
                     "conditioned = ONE scenario-conditioned emulator, "
                     "remap + recalibrate, zero retraining",
           "curves": curves,
           "wear_remap": wear}
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    return path


def main(quick: bool = False, seed: int = 0, label: str | None = None):
    curves = run(quick=quick, seed=seed)
    wear = wear_remap_gate(seed=seed)
    for c in curves:
        conditioned = c.get("conditioned")
        for i, (u, m) in enumerate(zip(c["unmitigated"], c["mitigated"])):
            cond_col = (f",{conditioned[i]['accuracy']:.4f}"
                        if conditioned else "")
            print(f"lifetime_{c['backend']},{u['label']},"
                  f"{u['accuracy']:.4f},{m['accuracy']:.4f}{cond_col},"
                  f"{int(m['retrained'])}")
        print(f"lifetime_{c['backend']}_dominates,"
              f"{int(c['dominates_at_every_checkpoint'])},bool")
        print(f"lifetime_{c['backend']}_compiled_once,"
              f"{int(c['compiled_once'])},bool")
        print(f"lifetime_{c['backend']}_ideal_bit_identical,"
              f"{int(c['ideal_bit_identical'])},bool")
        if conditioned:
            for k in ("conditioned_tracks_finetune",
                      "conditioned_zero_retrains",
                      "conditioned_compiled_once",
                      "conditioned_ideal_bit_identical"):
                print(f"lifetime_{c['backend']}_{k},{int(c[k])},bool")
    for i, d in enumerate(wear["draws"]):
        print(f"lifetime_wear_remap,draw{i},{d['instant']:.4f},"
              f"{d['wear']:.4f}")
    print(f"lifetime_wear_ge_instant,{int(wear['wear_ge_instant_all'])},"
          f"bool,strict_wins={wear['wear_strict_wins']}")
    print("lifetime_wear_horizon_none_bit_identical,"
          f"{int(wear['horizon_none_bit_identical'])},bool")
    path = write_json(curves, wear, label or ("quick" if quick else "full"),
                      quick, seed)
    print(f"lifetime_json,{os.path.abspath(path)},written")
    gates = ("dominates_at_every_checkpoint", "compiled_once",
             "ideal_bit_identical", "conditioned_tracks_finetune",
             "conditioned_zero_retrains", "conditioned_compiled_once",
             "conditioned_ideal_bit_identical")
    bad = [f"{c['backend']}:{k}" for c in curves
           for k in gates if not c.get(k, True)]
    bad += [f"wear_remap:{k}" for k in ("wear_ge_instant_all",
                                        "horizon_none_bit_identical")
            if not wear[k]]
    if bad:
        raise SystemExit(f"lifetime invariants violated: {bad}")
    return curves


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: reduced emulator protocol, small matmul")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--label", default=None)
    args = ap.parse_args()
    main(quick=args.quick, seed=args.seed, label=args.label)
