"""Paper Fig. 6: train loss vs number of training samples (tens of
thousands of samples are needed to avoid underfitting)."""
from __future__ import annotations

import jax

from repro.configs.base import AnalogConfig
from repro.configs.rram_ps32 import CASE_A, EmulatorTrainConfig
from repro.core.circuit import CircuitParams
from repro.core.emulator import generate_dataset, train_emulator


def run(sizes=(500, 2000, 8000), epochs: int = 50):
    acfg, cp = AnalogConfig(), CircuitParams()
    n_test = 500
    data = generate_dataset(jax.random.PRNGKey(0), max(sizes) + n_test,
                            CASE_A, acfg, cp)
    out = []
    for n in sizes:
        X, Pf, Y = data
        sub = (jax.numpy.concatenate([X[:n], X[-n_test:]]),
               jax.numpy.concatenate([Pf[:n], Pf[-n_test:]]),
               jax.numpy.concatenate([Y[:n], Y[-n_test:]]))
        tcfg = EmulatorTrainConfig(
            n_train=n, n_test=n_test, epochs=epochs, lr=2e-3,
            lr_halve_at=(epochs // 2, int(0.75 * epochs)), batch_size=256)
        res = train_emulator(jax.random.PRNGKey(1), CASE_A, acfg, cp, tcfg,
                             data=sub)
        out.append({"n": n, "train_mse": res.train_mse,
                    "test_mse": res.test_mse})
    return out


def main(csv=True):
    rows = run()
    dec = all(b["test_mse"] <= a["test_mse"] * 1.3
              for a, b in zip(rows, rows[1:]))
    if csv:
        for r in rows:
            print(f"fig6_point,{r['n']},train={r['train_mse']:.3e};"
                  f"test={r['test_mse']:.3e}")
        print(f"fig6_loss_vs_data,{rows[-1]['test_mse']*1e6:.2f},"
              f"decreasing={dec}")
    return rows


if __name__ == "__main__":
    main()
