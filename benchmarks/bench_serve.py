"""Continuous-batching serving bench: tokens/s and request latency under
Poisson load, through ``repro.launch.batching`` (docs/serving.md).

Two phases over one model (reduced scanned gemma3-1b -- the arch whose
per-period ``DeploymentState``s ride the layer scan as stacked xs):

  * throughput -- N requests served by the batched engine (B slots, one
    compiled decode call per tick) vs the SAME engine class pinned to
    ``max_slots=1`` (sequential single-request serving).  Headline:
    ``speedup = tok/s(batched) / tok/s(sequential)``.
  * latency    -- Poisson arrivals at ~1.5x the measured service
    capacity (queueing visible by construction); reports p50/p99 of
    submit -> last-token per request, plus time-to-first-token.

Asserted (exit 1 on violation):
  * speedup >= 4x with B >= 8 slots (the ISSUE-8 acceptance gate);
  * compile-once: a ``RecompileSentinel`` watches BOTH engines' prefill/
    decode trace counters (and the executor's unified forwards when an
    analog backend serves the MLPs) across warmup + both phases -- one
    trace each, zero decode recompiles across the whole run;
  * all reported numbers finite.

CSV lines to stdout + results/serve_<label>.json.

  PYTHONPATH=src python -m benchmarks.bench_serve [--quick] \
      [--analog-backend digital|analytic] [--telemetry PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")

ARCH = "gemma3-1b"               # full reduced pattern: scanned periods


def _percentile(xs, q):
    return float(np.percentile(np.asarray(xs, np.float64), q))


def _mk_executor(backend: str):
    if backend == "digital":
        return None
    from repro.configs.base import AnalogConfig
    from repro.configs.rram_ps32 import CASE_A
    from repro.core.analog import AnalogExecutor
    return AnalogExecutor(
        acfg=AnalogConfig(backend=backend, layers=("mlp",)), geom=CASE_A)


def _prompts(n, length, vocab, seed):
    import jax
    key = jax.random.PRNGKey(seed)
    return [np.asarray(
        jax.random.randint(jax.random.fold_in(key, i), (length,), 0, vocab),
        np.int32) for i in range(n)]


def run(quick: bool = False, seed: int = 0, backend: str = "digital",
        slots: int = 16):
    import jax
    from repro.launch.batching import ContinuousBatchEngine
    from repro.launch.serve import ServeSession
    from repro.obs import RecompileSentinel

    B = slots
    # decode-heavy on purpose: the batching win is on the decode ticks
    # (bulk prefill is per-request in both modes), so G >> P makes the
    # headline reflect steady-state continuous batching
    P, G, N = (8, 32, 2 * B) if quick else (32, 96, 4 * B)
    ex = _mk_executor(backend)
    sess = ServeSession(ARCH, reduced=True, batch=1, prompt_len=P, gen=G,
                        seed=seed, executor=ex)
    prompts = _prompts(N, P, sess.cfg.vocab_size, seed + 1)

    eng_b = ContinuousBatchEngine(sess, max_slots=B, max_len=P + G)
    eng_1 = ContinuousBatchEngine(sess, max_slots=1, max_len=P + G)

    with RecompileSentinel(session=eng_b, executor=ex, strict=False,
                           label="serve:batched") as sent_b, \
         RecompileSentinel(session=eng_1, strict=False,
                           label="serve:sequential") as sent_1:
        # warmup: pay the one allowed compile per engine outside the clock
        eng_b.run(prompts[:1], max_new=2)
        eng_1.run(prompts[:1], max_new=2)

        t0 = time.monotonic()
        out_b = eng_b.run(prompts, max_new=G)
        t_b = time.monotonic() - t0
        t0 = time.monotonic()
        out_1 = eng_1.run(prompts, max_new=G)
        t_1 = time.monotonic() - t0

        # Reported, not gated: per-row arithmetic is identical by
        # construction, but XLA CPU lowers the (B,.) and (1,.) GEMMs to
        # different microkernels whose k-accumulation rounds differently
        # in the last bit, and over a long greedy decode that drift can
        # flip a near-tie argmax.  tests/test_serve_loop.py asserts
        # bit-identity at the short horizon where it is exact.
        identical = all(np.array_equal(a, b) for a, b in zip(out_b, out_1))

        # Poisson load at ~1.5x measured capacity
        cap = N / t_b                                  # requests/s, batched
        rate = 1.5 * cap
        rng = np.random.default_rng(seed)
        arrivals = np.cumsum(rng.exponential(1.0 / rate, size=N))
        t_start = time.monotonic()
        rids, i = [], 0
        while i < len(arrivals) or eng_b.busy:
            now = time.monotonic() - t_start
            while i < len(arrivals) and arrivals[i] <= now:
                rids.append(eng_b.submit(prompts[i], G))
                i += 1
            if eng_b.busy:
                eng_b.step()
            elif i < len(arrivals):
                time.sleep(min(0.001, arrivals[i] - now))
        lat = [eng_b.requests[r].t_done - eng_b.requests[r].t_submit
               for r in rids]
        ttft = [eng_b.requests[r].t_first - eng_b.requests[r].t_submit
                for r in rids]
        t_poisson = time.monotonic() - t_start

    eng_b.pool.check()
    eng_1.pool.check()
    tok_b, tok_1 = N * G / t_b, N * G / t_1
    row = {
        "arch": f"{ARCH}-reduced", "backend": backend,
        "slots": B, "prompt_len": P, "gen": G, "requests": N,
        "throughput": {
            "batched_tok_s": tok_b, "sequential_tok_s": tok_1,
            "speedup": tok_b / tok_1,
            "batched_wall_s": t_b, "sequential_wall_s": t_1,
            "tokens_identical": identical,
        },
        "poisson": {
            "offered_rate_req_s": float(rate),
            "wall_s": t_poisson,
            "tok_s": N * G / t_poisson,
            "latency_p50_s": _percentile(lat, 50),
            "latency_p99_s": _percentile(lat, 99),
            "ttft_p50_s": _percentile(ttft, 50),
            "ttft_p99_s": _percentile(ttft, 99),
        },
        "sentinel": {"batched_ok": sent_b.ok, "sequential_ok": sent_1.ok,
                     "batched_new": sent_b.new_counts,
                     "sequential_new": sent_1.new_counts},
        "gates": {
            "speedup_4x": tok_b / tok_1 >= 4.0 and B >= 8,
            "compile_once": bool(sent_b.ok and sent_1.ok),
            "finite": bool(np.isfinite(
                [tok_b, tok_1, t_poisson] + lat + ttft).all()),
        },
    }
    return row


def write_json(row, label: str, quick: bool, seed: int) -> str:
    import jax
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"serve_{label}.json")
    doc = {"schema": 1,
           "label": label,
           "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
           "jax_backend": jax.default_backend(),
           "quick": quick,
           "seed": seed,
           "metric": "batched vs sequential tokens/s through the "
                     "continuous-batching engine (same arch/backend; "
                     "sequential = max_slots=1), plus p50/p99 request "
                     "latency under Poisson arrivals at 1.5x capacity; "
                     "compile-once sentinel across the whole run",
           "row": row}
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    return path


def main(quick: bool = False, seed: int = 0, label: str | None = None,
         backend: str = "digital", slots: int = 16,
         telemetry: str | None = None):
    from repro.obs import OBS
    if telemetry is not None:
        OBS.enable()
    row = run(quick=quick, seed=seed, backend=backend, slots=slots)
    th, po = row["throughput"], row["poisson"]
    print(f"serve_tok_s,batched,{th['batched_tok_s']:.1f}")
    print(f"serve_tok_s,sequential,{th['sequential_tok_s']:.1f}")
    print(f"serve_speedup,{row['slots']}slots,{th['speedup']:.2f}")
    print(f"serve_latency_s,p50,{po['latency_p50_s']:.4f}")
    print(f"serve_latency_s,p99,{po['latency_p99_s']:.4f}")
    print(f"serve_ttft_s,p50,{po['ttft_p50_s']:.4f}")
    for k, v in row["gates"].items():
        print(f"serve_{k},{int(v)},bool")
    path = write_json(row, label or ("quick" if quick else "full"),
                      quick, seed)
    print(f"serve_json,{os.path.abspath(path)},written")
    if telemetry is not None:
        from repro.obs import snapshot, write_snapshot
        if telemetry == "-":
            print(json.dumps(snapshot(), indent=2, sort_keys=True))
        else:
            write_snapshot(telemetry)
            print(f"telemetry snapshot -> {telemetry}")
    bad = [k for k, v in row["gates"].items() if not v]
    if bad:
        raise SystemExit(f"serving gates violated: {bad}")
    return row


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: shorter prompts/decodes, 2B requests")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--label", default=None)
    ap.add_argument("--slots", type=int, default=16,
                    help="batch slots B (the 4x gate applies at B >= 8)")
    ap.add_argument("--analog-backend", default="digital",
                    choices=["digital", "analytic"],
                    help="serve MLP projections on the analog fast path "
                         "(states threaded through the batched calls)")
    ap.add_argument("--telemetry", nargs="?", const="-", default=None,
                    metavar="PATH",
                    help="enable the metrics registry and dump the JSON "
                         "snapshot (PATH, or stdout when bare)")
    args = ap.parse_args()
    main(quick=args.quick, seed=args.seed, label=args.label,
         backend=args.analog_backend, slots=args.slots,
         telemetry=args.telemetry)
