"""Static cost analysis of post-SPMD per-device HLO text.

Why not ``compiled.cost_analysis()``? Verified empirically on this JAX/XLA
build: it reports per-device numbers but visits each ``while`` body ONCE --
a scanned 80-layer transformer would be under-counted 80x. This parser
propagates costs through the call graph (fusion / call / while /
conditional) and multiplies while-loop bodies by their trip count, which is
recovered from the loop-condition's comparison constant.

Per instruction we accumulate:
  flops            -- dot (2*M*N*K from output shape x contraction size),
                      convolution (2 * out_elems * kernel_elems * Cin / groups)
  hbm_bytes        -- fusion-boundary traffic: operand bytes + result bytes
                      for top-level ops (inside-fusion ops are VMEM-local)
  collective_bytes -- bytes moved per device for all-gather / all-reduce /
                      reduce-scatter / all-to-all / collective-permute
                      (max of operand/result size per op; standard ring
                      factors are applied in roofline.py, not here)
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")


def _parse_shape_str(s: str) -> List[Tuple[str, Tuple[int, ...]]]:
    """'(s32[], f32[16,64]{1,0})' or 'f32[8,128]{1,0}' -> [(dtype, dims)...]"""
    out = []
    for m in _SHAPE_RE.finditer(s):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        shape = tuple(int(x) for x in dims.split(",") if x) if dims else ()
        out.append((dt, shape))
    return out


def _nbytes(shapes: List[Tuple[str, Tuple[int, ...]]]) -> int:
    total = 0
    for dt, dims in shapes:
        n = DTYPE_BYTES.get(dt, 4)
        for d in dims:
            n *= d
        total += n
    return total


def _nelems(shape: Tuple[int, ...]) -> int:
    n = 1
    for d in shape:
        n *= d
    return n


class Instruction:
    __slots__ = ("name", "result_shapes", "opcode", "operands", "attrs", "raw")

    def __init__(self, name, result_shapes, opcode, operands, attrs, raw):
        self.name = name
        self.result_shapes = result_shapes
        self.opcode = opcode
        self.operands = operands
        self.attrs = attrs
        self.raw = raw


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    """Split the module into computations. Header params may contain nested
    parens (tuple types), so match on 'name (' ... ') -> ... {' loosely."""
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo.splitlines():
        stripped = line.strip()
        m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{$", stripped)
        if m and not stripped.startswith("//") and "=" not in stripped.split("(")[0]:
            cur = m.group(1)
            comps[cur] = []
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is not None and stripped:
            comps[cur].append(stripped)
    return comps


_OPCODE_RE = re.compile(r"^([\w\-]+)\(")


def _parse_instruction(line: str) -> Optional[Instruction]:
    m = _INSTR_RE.match(line)
    if not m:
        return None
    name, rhs = m.group(1), m.group(2)
    # rhs = "f32[16,64]{1,0} dot(%a, %b), attrs..." or "(tuple...) while(...)"
    # find the opcode: first identifier followed by '(' after the shape part
    shape_end = 0
    depth = 0
    i = 0
    # result shape may be a tuple: scan until we pass the leading shape token(s)
    if rhs.startswith("("):
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    shape_end = i + 1
                    break
    else:
        sp = rhs.find(" ")
        shape_end = sp if sp > 0 else len(rhs)
    result_str = rhs[:shape_end]
    rest = rhs[shape_end:].strip()
    om = _OPCODE_RE.match(rest)
    if not om:
        return None
    opcode = om.group(1)
    # operand segment: between the first '(' and its matching ')'
    start = rest.find("(")
    depth = 0
    end = start
    for j in range(start, len(rest)):
        if rest[j] == "(":
            depth += 1
        elif rest[j] == ")":
            depth -= 1
            if depth == 0:
                end = j
                break
    operand_str = rest[start + 1:end]
    attrs = rest[end + 1:]
    operands = [o.strip() for o in _split_top_level(operand_str)]
    return Instruction(name, _parse_shape_str(result_str), opcode, operands,
                       attrs, line)


def _split_top_level(s: str) -> List[str]:
    parts, depth, cur = [], 0, []
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur))
    return [p for p in (p.strip() for p in parts) if p]


_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations={([^}]*)}")
_DIMS_RE = re.compile(r"lhs_contracting_dims={([\d,]*)}")
_CONST_RE = re.compile(r"constant\((\d+)\)")


class HloCostModel:
    def __init__(self, hlo: str):
        self.comps_raw = _split_computations(hlo)
        self.comps: Dict[str, List[Instruction]] = {}
        self.symtab: Dict[str, Dict[str, List[Tuple[str, Tuple[int, ...]]]]] = {}
        for cname, lines in self.comps_raw.items():
            instrs = []
            syms: Dict[str, List] = {}
            for ln in lines:
                ins = _parse_instruction(ln)
                if ins is None:
                    continue
                instrs.append(ins)
                syms[ins.name] = ins.result_shapes
            self.comps[cname] = instrs
            self.symtab[cname] = syms
        self._cost_cache: Dict[str, Dict[str, float]] = {}
        self.entry = self._find_entry(hlo)

    def _find_entry(self, hlo: str) -> str:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo, re.M)
        if m:
            return m.group(1)
        # fall back: computation named like the module
        return next(iter(self.comps))

    # ------------------------------------------------------------------ #
    def _operand_shapes(self, comp: str, operand: str):
        """Operand text is either '%name' or 'dtype[shape] %name' or a literal."""
        shapes = _parse_shape_str(operand)
        if shapes:
            return shapes
        name = operand.lstrip("%")
        return self.symtab.get(comp, {}).get(name, [])

    def _trip_count(self, cond_comp: str) -> int:
        """Loop bound for canonical counted loops: the integer constant in
        the condition computation (compared against the induction var).
        Constants directly in the cond computation take priority; callees
        (wrapped-compare fusions) are only searched as a fallback."""
        direct = [int(m.group(1))
                  for ln in self.comps_raw.get(cond_comp, ())
                  for m in _CONST_RE.finditer(ln)]
        if direct:
            return max(max(direct), 1)
        best = 1
        seen = {cond_comp}
        stack = []
        for ln in self.comps_raw.get(cond_comp, ()):
            cm = _CALLS_RE.search(ln)
            if cm:
                stack.append(cm.group(1))
        while stack:
            c = stack.pop()
            if c in seen or c not in self.comps_raw:
                continue
            seen.add(c)
            for ln in self.comps_raw[c]:
                for m in _CONST_RE.finditer(ln):
                    best = max(best, int(m.group(1)))
                cm = _CALLS_RE.search(ln)
                if cm:
                    stack.append(cm.group(1))
        return best

    def instruction_cost(self, comp: str, ins: Instruction) -> Dict[str, float]:
        c = defaultdict(float)
        op = ins.opcode
        out_bytes = _nbytes(ins.result_shapes)
        in_shapes = [self._operand_shapes(comp, o) for o in ins.operands]
        in_bytes = sum(_nbytes(s) for s in in_shapes)

        if op == "dot":
            out_elems = sum(_nelems(sh) for _, sh in ins.result_shapes)
            k = 1
            dm = _DIMS_RE.search(ins.attrs)
            if dm and in_shapes and in_shapes[0]:
                lhs_shape = in_shapes[0][0][1]
                for d in dm.group(1).split(","):
                    if d:
                        k *= lhs_shape[int(d)]
            c["flops"] += 2.0 * out_elems * k
            c["hbm_bytes"] += in_bytes + out_bytes
            c["mxu_bytes"] += in_bytes + out_bytes
        elif op == "convolution":
            out_elems = sum(_nelems(sh) for _, sh in ins.result_shapes)
            # kernel = operand 1
            kern = in_shapes[1][0][1] if len(in_shapes) > 1 and in_shapes[1] else ()
            kern_elems = _nelems(kern)
            # per output element: kernel_elems MACs (already includes Cin*kw*kh)
            # kernel shape includes Cout; divide it out
            fg = 1
            fgm = re.search(r"feature_group_count=(\d+)", ins.attrs)
            if fgm:
                fg = int(fgm.group(1))
            cout = 0
            for _, sh in ins.result_shapes:
                pass
            # heuristic: MACs = out_elems * kern_elems / Cout(kernel dim 0 or
            # output feature dim); use output feature size from kernel shape
            # via attrs dim_labels if present; fall back to kern_elems.
            dl = re.search(r"dim_labels=\S*?->\w*f", ins.attrs)
            macs = out_elems * max(kern_elems, 1)
            # kernel contains output-feature dim; remove it: find from
            # dim_labels like b01f_01io->b01f : kernel 'o' dim
            dlm = re.search(r"_(\w+)->", ins.attrs)
            if dlm and kern:
                klabels = dlm.group(1)
                if "o" in klabels and len(klabels) == len(kern):
                    macs = out_elems * (kern_elems // max(kern[klabels.index("o")], 1))
            c["flops"] += 2.0 * macs
            c["hbm_bytes"] += in_bytes + out_bytes
        elif op in COLLECTIVES:
            moved = max(in_bytes, out_bytes)
            c["collective_bytes"] += moved
            c[f"coll_{op.replace('-', '_')}"] += moved
            c["hbm_bytes"] += in_bytes + out_bytes
        elif op == "fusion":
            fm = _CALLS_RE.search(ins.attrs)
            if fm:
                callee = fm.group(1)
                inner = self.computation_cost(callee)
                # flops/collectives inside count; hbm traffic is the fusion
                # boundary (operands + result), not inner temporaries.
                c["flops"] += inner["flops"]
                c["collective_bytes"] += inner["collective_bytes"]
                for k2, v2 in inner.items():
                    if k2.startswith("coll_"):
                        c[k2] += v2
                c["hbm_bytes"] += self._fusion_traffic(callee, in_shapes,
                                                       out_bytes)
            else:
                c["hbm_bytes"] += in_bytes + out_bytes
        elif op in ("call", "custom-call", "async-start"):
            fm = _CALLS_RE.search(ins.attrs) or _TO_APPLY_RE.search(ins.attrs)
            if fm and fm.group(1) in self.comps:
                inner = self.computation_cost(fm.group(1))
                for k2, v2 in inner.items():
                    c[k2] += v2
            else:
                c["hbm_bytes"] += in_bytes + out_bytes
        elif op == "while":
            bm = _BODY_RE.search(ins.attrs)
            cm = _COND_RE.search(ins.attrs)
            trip = self._trip_count(cm.group(1)) if cm else 1
            if bm:
                inner = self.computation_cost(bm.group(1))
                for k2, v2 in inner.items():
                    c[k2] += v2 * trip
            c["while_trips"] += trip
        elif op == "conditional":
            brm = _BRANCHES_RE.search(ins.attrs)
            if brm:
                branches = [b.strip().lstrip("%") for b in
                            brm.group(1).split(",")]
                costs = [self.computation_cost(b) for b in branches
                         if b in self.comps]
                if costs:
                    # expected cost: average over branches
                    keys = set().union(*[set(x) for x in costs])
                    for k2 in keys:
                        c[k2] += sum(x.get(k2, 0.0) for x in costs) / len(costs)
        elif op in ("parameter", "constant", "tuple", "get-tuple-element",
                    "bitcast", "after-all", "partition-id", "replica-id",
                    "iota"):
            pass
        elif op == "dynamic-update-slice":
            # in-place: traffic = the updated slice (read update + write)
            upd = _nbytes(in_shapes[1]) if len(in_shapes) > 1 else out_bytes
            c["hbm_bytes"] += 2 * upd
        elif op in ("dynamic-slice", "gather"):
            # read the extracted slice + write it (not the whole operand)
            c["hbm_bytes"] += 2 * out_bytes
        elif op == "scatter":
            upd = _nbytes(in_shapes[2]) if len(in_shapes) > 2 else out_bytes
            c["hbm_bytes"] += 3 * upd    # read base slice + update + write
        elif op in ("copy", "copy-start", "transpose", "reshape", "broadcast",
                    "slice", "concatenate", "reduce", "reduce-window",
                    "select", "pad", "reverse", "sort", "convert", "compare",
                    "rng", "rng-bit-generator"):
            c["hbm_bytes"] += in_bytes + out_bytes
            if op == "reduce":
                c["flops"] += sum(_nelems(s) for sh in in_shapes for _, s in sh)
        else:
            # elementwise and everything else: traffic + 1 flop/elem
            c["hbm_bytes"] += in_bytes + out_bytes
            c["flops"] += sum(_nelems(sh) for _, sh in ins.result_shapes)
        return c

    def _fusion_traffic(self, comp: str, in_shapes, out_bytes: int) -> float:
        """HBM traffic of a fusion, correcting the two in-place idioms:
          * operands consumed ONLY by dynamic-slice -> count slice bytes
          * a dynamic-update-slice feeding the root with an operand the same
            size as the result -> aliased in-place update (slice r+w)
        """
        instrs = self.comps.get(comp, [])
        param_idx: Dict[str, int] = {}
        for ins in instrs:
            if ins.opcode == "parameter":
                m = re.search(r"parameter\((\d+)\)", ins.raw)
                if m:
                    param_idx[ins.name] = int(m.group(1))
        # value -> consuming opcodes (following no-op chains)
        NOOP = ("bitcast", "convert", "copy", "reshape", "transpose")
        consumers: Dict[str, List] = {}
        produced_by: Dict[str, Instruction] = {}
        dus_update = None
        out_elems = 0
        for ins in instrs:
            produced_by[ins.name] = ins
            if ins.opcode == "dynamic-update-slice" and len(ins.operands) > 1:
                base = sum(_nelems(s) for _, s in
                           self._operand_shapes(comp, ins.operands[0]))
                res_elems = sum(_nelems(s) for _, s in ins.result_shapes)
                if base == res_elems:
                    dus_update = _nbytes(
                        self._operand_shapes(comp, ins.operands[1]))
            for o in ins.operands:
                nm = o.split()[-1].lstrip("%")
                consumers.setdefault(nm, []).append(ins)

        def slice_only(name, depth=0) -> Optional[int]:
            """If all (transitive through no-ops) consumers of `name` are
            dynamic-slice, total bytes of those slices; else None."""
            if depth > 4:
                return None
            total = 0
            cons = consumers.get(name, [])
            if not cons:
                return None
            for ins in cons:
                if ins.opcode == "dynamic-slice":
                    total += _nbytes(ins.result_shapes)
                elif ins.opcode in NOOP:
                    sub = slice_only(ins.name, depth + 1)
                    if sub is None:
                        return None
                    total += sub
                else:
                    return None
            return total

        # fusion result element count (for dtype-agnostic alias matching)
        root_elems = None
        for ins in instrs:
            if "ROOT" in ins.raw:
                root_elems = sum(_nelems(s) for _, s in ins.result_shapes)
        total = 0.0
        aliased_done = False
        by_idx = {v: k for k, v in param_idx.items()}
        for i, shapes in enumerate(in_shapes):
            nb = _nbytes(shapes)
            pname = by_idx.get(i)
            elems = sum(_nelems(s) for _, s in shapes)
            so = slice_only(pname) if pname else None
            if so is not None:
                total += so                           # sliced reads only
            elif dus_update is not None and root_elems is not None and \
                    elems == root_elems and not aliased_done:
                aliased_done = True                   # in-place buffer (alias)
            else:
                total += nb
        if dus_update is not None and aliased_done:
            total += 2 * dus_update                   # slice read + write
        else:
            total += out_bytes
        return total

    def computation_cost(self, comp: str) -> Dict[str, float]:
        if comp in self._cost_cache:
            return self._cost_cache[comp]
        total: Dict[str, float] = defaultdict(float)
        self._cost_cache[comp] = total          # break recursion cycles
        for ins in self.comps.get(comp, []):
            for k, v in self.instruction_cost(comp, ins).items():
                total[k] += v
        return total

    def entry_cost(self) -> Dict[str, float]:
        c = dict(self.computation_cost(self.entry))
        for k in ("flops", "hbm_bytes", "collective_bytes"):
            c.setdefault(k, 0.0)
        return c


def analyze_hlo(hlo: str) -> Dict[str, float]:
    model = HloCostModel(hlo)
    c = model.entry_cost()
    out = {k: float(v) for k, v in c.items()}
    out["n_computations"] = len(model.comps)
    return out
