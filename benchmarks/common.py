"""Shared benchmark plumbing: trained-emulator cache + timing helper."""
from __future__ import annotations

import os
import statistics
import time

import jax
import numpy as np

from repro.configs.base import AnalogConfig
from repro.configs.rram_ps32 import BLOCKS, BlockGeometry, EmulatorTrainConfig
from repro.core.circuit import CircuitParams
from repro.core.emulator import EmulatorResult, train_emulator

CACHE_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                         "emulator_cache")

# "quick" protocol for the CPU-only CI budget; --full uses the paper's
QUICK = EmulatorTrainConfig(n_train=10_000, n_test=1_000, epochs=200,
                            lr=2e-3, lr_halve_at=(100, 140, 170),
                            batch_size=512)
FULL = EmulatorTrainConfig()          # 50k samples, 2000 epochs (paper)


def timed(fn, *args, warmup: int = 1, iters: int = 5):
    """Median-of-iters wall time (robust to one-off scheduler noise)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    out = None
    for _ in range(iters):
        t0 = time.time()
        out = jax.block_until_ready(fn(*args))
        ts.append(time.time() - t0)
    return statistics.median(ts), out


def _load_cached(path: str) -> EmulatorResult:
    data = np.load(path, allow_pickle=True)
    params = {k: jax.numpy.asarray(v) for k, v in data.items()
              if not k.startswith("__")}
    meta = data["__meta"].item() if "__meta" in data else {}
    return EmulatorResult(params=params, history={},
                          train_mse=meta.get("train_mse", float("nan")),
                          test_mse=meta.get("test_mse", float("nan")),
                          test_mae=meta.get("test_mae", float("nan")),
                          bound=meta.get("bound", float("nan")),
                          accepted=bool(meta.get("accepted", False)),
                          sig_prob=meta.get("sig_prob", float("nan")))


def save_emulator_npz(res: EmulatorResult, path: str) -> str:
    """Benchmarks-cache npz format (also what serve --emulator-params
    loads)."""
    np.savez(path,
             __meta=np.array({"train_mse": res.train_mse,
                              "test_mse": res.test_mse,
                              "test_mae": res.test_mae, "bound": res.bound,
                              "accepted": res.accepted,
                              "sig_prob": res.sig_prob}, dtype=object),
             **{k: np.asarray(v) for k, v in res.params.items()})
    return path


def get_emulator(geom_name: str, tcfg: EmulatorTrainConfig = QUICK,
                 seed: int = 0, refresh: bool = False) -> EmulatorResult:
    """Train (or load from cache) one emulator per block geometry."""
    os.makedirs(CACHE_DIR, exist_ok=True)
    tag = f"{geom_name}_n{tcfg.n_train}_e{tcfg.epochs}_s{seed}"
    path = os.path.join(CACHE_DIR, tag + ".npz")
    geom = BLOCKS[geom_name]
    acfg = AnalogConfig()
    cp = CircuitParams()
    if os.path.exists(path) and not refresh:
        return _load_cached(path)
    res = train_emulator(jax.random.PRNGKey(seed), geom, acfg, cp, tcfg,
                         log_every=max(1, tcfg.epochs // 8))
    save_emulator_npz(res, path)
    return res


def get_conditioned_emulator(geom_name: str,
                             tcfg: EmulatorTrainConfig = QUICK,
                             seed: int = 0,
                             refresh: bool = False) -> EmulatorResult:
    """Train (or load from cache) ONE scenario-conditioned emulator per
    block geometry: every sample draws its own device corner and the
    corner's feature encoding rides the peripheral vector, so the same
    params serve the whole manifold (docs/emulator.md)."""
    from repro.nonideal.data import train_conditioned_emulator
    os.makedirs(CACHE_DIR, exist_ok=True)
    tag = f"{geom_name}_cond_n{tcfg.n_train}_e{tcfg.epochs}_s{seed}"
    path = os.path.join(CACHE_DIR, tag + ".npz")
    geom = BLOCKS[geom_name]
    if os.path.exists(path) and not refresh:
        return _load_cached(path)
    res = train_conditioned_emulator(jax.random.PRNGKey(seed), geom,
                                     AnalogConfig(), CircuitParams(), tcfg,
                                     log_every=max(1, tcfg.epochs // 8))
    save_emulator_npz(res, path)
    return res
