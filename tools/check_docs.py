#!/usr/bin/env python
"""Docs health checker: internal-link validation + doctest runner.

Walks README.md, ROADMAP.md and docs/*.md, and

  1. resolves every markdown link ``[text](target)``: relative targets
     must exist on disk, and ``#fragment`` anchors must match a heading
     (GitHub slug rules) in the target file;
  2. runs ``python -m doctest`` semantics over each file's ``>>>``
     examples (``doctest.testfile``), so the code blocks in the docs are
     executable truth, not decoration.

Exit 1 with a per-file report on any broken link or failing example.

  PYTHONPATH=src python tools/check_docs.py [--no-doctest]
"""
from __future__ import annotations

import argparse
import doctest
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
LINK_RE = re.compile(r"\[([^\]]*)\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
EXTERNAL = ("http://", "https://", "mailto:")


def doc_files():
    files = [p for p in ("README.md", "ROADMAP.md") if
             os.path.exists(os.path.join(REPO, p))]
    docs = os.path.join(REPO, "docs")
    if os.path.isdir(docs):
        files += sorted(os.path.join("docs", f) for f in os.listdir(docs)
                        if f.endswith(".md"))
    return files


def slugify(heading: str) -> str:
    """GitHub-style anchor slug: lowercase, drop punctuation, dashes."""
    h = re.sub(r"[`*_]", "", heading.strip().lower())
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def anchors_of(path: str) -> set:
    with open(path, encoding="utf-8") as f:
        return {slugify(m.group(1)) for m in HEADING_RE.finditer(f.read())}


def check_links(rel_path: str) -> list:
    path = os.path.join(REPO, rel_path)
    with open(path, encoding="utf-8") as f:
        text = f.read()
    errors = []
    for m in LINK_RE.finditer(text):
        target = m.group(2)
        if target.startswith(EXTERNAL):
            continue
        base, _, frag = target.partition("#")
        if base:
            resolved = os.path.normpath(
                os.path.join(os.path.dirname(path), base))
            if not os.path.exists(resolved):
                errors.append(f"{rel_path}: broken link -> {target}")
                continue
        else:
            resolved = path
        if frag and resolved.endswith(".md"):
            if slugify(frag) not in anchors_of(resolved):
                errors.append(f"{rel_path}: missing anchor -> {target}")
    return errors


def run_doctests(rel_path: str) -> list:
    res = doctest.testfile(
        os.path.join(REPO, rel_path), module_relative=False,
        optionflags=doctest.ELLIPSIS | doctest.NORMALIZE_WHITESPACE)
    if res.failed:
        return [f"{rel_path}: {res.failed}/{res.attempted} doctests failed"]
    return []


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--no-doctest", action="store_true",
                    help="links only (doctests need jax importable)")
    args = ap.parse_args(argv)
    src = os.path.join(REPO, "src")
    if src not in sys.path:
        sys.path.insert(0, src)
    errors = []
    for rel in doc_files():
        errors += check_links(rel)
        if not args.no_doctest:
            errors += run_doctests(rel)
    if errors:
        print("\n".join(errors))
        print(f"FAILED: {len(errors)} docs problem(s)")
        return 1
    kind = "links" if args.no_doctest else "links + doctests"
    print(f"docs OK ({kind}) across {len(doc_files())} files")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
