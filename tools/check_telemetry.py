#!/usr/bin/env python
"""Validate a telemetry snapshot against the checked-in schema.

CI's telemetry-smoke step runs a short serve with ``--telemetry=PATH``
and feeds the exported snapshot through this checker
(tools/telemetry_schema.json):

  * every ``require`` entry must exist with the declared kind, at least
    ``min_series`` label series, and the declared label keys on every
    series -- a serve that stopped exporting its latency histograms or
    cache counters fails here;
  * no ``forbid_nonzero`` series may be positive -- this is how a
    ``RecompileSentinel`` violation recorded during the run
    (``obs_sentinel_checks_total{outcome="violation"}``) fails CI
    straight from the artifact.

Two profiles select which require list applies (``forbid_nonzero``
applies to both):

  * ``session`` (default) -- a ``ServeSession`` serve (the ``require``
    schema key; CI telemetry-smoke);
  * ``serve``   -- the continuous-batching engine (``require_serve``;
    fed by ``benchmarks/bench_serve.py --telemetry`` in the CI
    serve-smoke job);
  * ``fleet``   -- a fleet maintenance campaign (``require_fleet``;
    fed by ``benchmarks/bench_fleet.py --telemetry`` in the CI
    fleet-smoke job).

Exit 1 with a per-rule report on any violation.

  PYTHONPATH=src python tools/check_telemetry.py SNAP.json [--schema JSON]
      [--profile session|serve]
"""
from __future__ import annotations

import argparse
import json
import os

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_SCHEMA = os.path.join(REPO, "tools", "telemetry_schema.json")


PROFILES = {"session": "require", "serve": "require_serve",
            "fleet": "require_fleet"}


def check(snap: dict, schema: dict, profile: str = "session") -> list:
    """All violations of ``schema`` in ``snap`` (empty = healthy)."""
    errs = []
    metrics = snap.get("metrics")
    if not isinstance(metrics, dict):
        return [f"snapshot has no 'metrics' mapping "
                f"(schema={snap.get('schema')!r})"]
    for rule in schema.get(PROFILES[profile], []):
        name = rule["metric"]
        m = metrics.get(name)
        if m is None:
            errs.append(f"missing required metric {name}")
            continue
        if m.get("kind") != rule.get("kind", m.get("kind")):
            errs.append(f"{name}: kind {m.get('kind')!r}, schema wants "
                        f"{rule['kind']!r}")
        series = m.get("series", [])
        if len(series) < rule.get("min_series", 1):
            errs.append(f"{name}: {len(series)} series, schema wants "
                        f">= {rule.get('min_series', 1)}")
        for want in rule.get("labels", []):
            bad = [s for s in series if want not in s.get("labels", {})]
            if bad:
                errs.append(f"{name}: {len(bad)} series missing label "
                            f"{want!r}")
    for rule in schema.get("forbid_nonzero", []):
        m = metrics.get(rule["metric"])
        if m is None:
            continue
        sub = rule.get("labels", {})
        for s in m.get("series", []):
            labels = s.get("labels", {})
            if all(labels.get(k) == v for k, v in sub.items()) \
                    and s.get("value", 0) > 0:
                errs.append(f"{rule['metric']}{sub}: forbidden series is "
                            f"nonzero ({s.get('value')}) -- labels "
                            f"{labels}")
    return errs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("snapshot", help="telemetry snapshot JSON to validate")
    ap.add_argument("--schema", default=DEFAULT_SCHEMA,
                    help="schema file (default: tools/telemetry_schema.json)")
    ap.add_argument("--profile", default="session", choices=sorted(PROFILES),
                    help="which require list applies: 'session' (a "
                         "ServeSession serve), 'serve' (the "
                         "continuous-batching engine) or 'fleet' (a "
                         "fleet maintenance campaign)")
    args = ap.parse_args(argv)
    with open(args.snapshot) as f:
        snap = json.load(f)
    with open(args.schema) as f:
        schema = json.load(f)
    errs = check(snap, schema, profile=args.profile)
    if errs:
        print(f"telemetry snapshot FAILED {len(errs)} schema check(s):")
        for e in errs:
            print(f"  - {e}")
        return 1
    n = len(snap.get("metrics", {}))
    print(f"telemetry snapshot ok: {n} metrics, schema "
          f"v{schema.get('version')}, profile {args.profile}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
