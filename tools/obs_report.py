#!/usr/bin/env python
"""Telemetry snapshot reporter: one snapshot as a table, or the delta
between two.

Consumes the JSON snapshots the serving stack exports
(``serve --telemetry=PATH``, ``repro.obs.write_snapshot``;
docs/observability.md) and renders them human-first:

  * one snapshot  -- every metric as a table row per label series
    (histograms show count / mean / min / max);
  * two snapshots -- ``diff_snapshots(base, snap)``: counters and
    histograms subtract per series (what happened BETWEEN the two
    exports), gauges show the later value;
  * ``--prometheus`` -- emit the Prometheus text exposition instead of
    the table (pipe into a pushgateway or a scrape file).

  PYTHONPATH=src python tools/obs_report.py SNAP.json [--base BASE.json]
                                            [--prometheus] [--grep RE]
"""
from __future__ import annotations

import argparse
import json
import re
import sys

from repro.obs import diff_snapshots, to_prometheus


def _load(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc, dict) or "metrics" not in doc:
        raise SystemExit(f"{path}: not a telemetry snapshot "
                         "(no 'metrics' key)")
    return doc


def _fmt_val(v: float) -> str:
    if isinstance(v, float) and v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return f"{v:.6g}"


def _labels(labels: dict) -> str:
    return ",".join(f"{k}={v}" for k, v in sorted(labels.items())) or "-"


def rows(snap: dict, grep: str = "") -> list:
    """Flatten a snapshot into ``(metric, kind, labels, value)`` table
    rows; histograms render as ``count / mean / min / max``."""
    pat = re.compile(grep) if grep else None
    out = []
    for name, m in sorted(snap["metrics"].items()):
        if pat and not pat.search(name):
            continue
        for s in m["series"]:
            if m["kind"] == "histogram":
                n = s["count"]
                mean = s["sum"] / n if n else 0.0
                val = (f"n={n} mean={mean:.6g} "
                       f"min={_fmt_val(s['min']) if n else '-'} "
                       f"max={_fmt_val(s['max']) if n else '-'}")
            else:
                val = _fmt_val(s["value"])
            out.append((name, m["kind"], _labels(s["labels"]), val))
    return out


def render(table: list) -> str:
    if not table:
        return "(no metrics matched)"
    heads = ("metric", "kind", "labels", "value")
    widths = [max(len(heads[i]), *(len(r[i]) for r in table))
              for i in range(4)]
    lines = ["  ".join(h.ljust(w) for h, w in zip(heads, widths)),
             "  ".join("-" * w for w in widths)]
    lines += ["  ".join(c.ljust(w) for c, w in zip(r, widths))
              for r in table]
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("snapshot", help="telemetry snapshot JSON")
    ap.add_argument("--base", default=None, metavar="JSON",
                    help="earlier snapshot: report the counter/histogram "
                         "delta between the two instead of the absolutes")
    ap.add_argument("--prometheus", action="store_true",
                    help="emit Prometheus text exposition, not a table")
    ap.add_argument("--grep", default="",
                    help="only metrics whose name matches this regex")
    args = ap.parse_args(argv)

    snap = _load(args.snapshot)
    if args.base:
        snap = diff_snapshots(_load(args.base), snap)
    if args.prometheus:
        sys.stdout.write(to_prometheus(snap))
    else:
        if snap.get("diff"):
            print(f"# delta: {args.base} -> {args.snapshot}")
        print(render(rows(snap, args.grep)))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
