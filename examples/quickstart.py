"""Quickstart: the SEMULATOR pipeline end to end, in miniature.

1. Solve an analog computing block with the circuit simulator (NR solver)
2. Train a Conv4Xbar emulator on circuit data; check Theorem 4.1
3. Swap the emulator in as the execution backend for a real matmul

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs.base import AnalogConfig
from repro.configs.rram_ps32 import CASE_A, EmulatorTrainConfig
from repro.core import theory
from repro.core.analog import AnalogExecutor
from repro.core.circuit import CircuitParams, block_response
from repro.core.emulator import sample_block_inputs, train_emulator


def main():
    key = jax.random.PRNGKey(0)
    acfg, cp = AnalogConfig(), CircuitParams()

    # -- 1. the accurate (slow) circuit simulator -------------------------- #
    x, periph = sample_block_inputs(key, 4, CASE_A, acfg)
    y = block_response(x, cp, periph)
    print(f"circuit block outputs (V): {y.ravel()}")

    # -- 2. train the emulator against it ---------------------------------- #
    tcfg = EmulatorTrainConfig(n_train=4000, n_test=500, epochs=40,
                               lr=2e-3, lr_halve_at=(25, 35), batch_size=256)
    res = train_emulator(key, CASE_A, acfg, cp, tcfg, log_every=10)
    print(f"emulator: test MSE {res.test_mse:.3e} "
          f"(MAE {res.test_mae*1e3:.2f} mV)")
    print(f"Thm 4.1: bound(s=3, p=0.3) = {res.bound:.2e}; "
          f"P(|err|<0.5mV) = {res.sig_prob:.3f}; accepted = {res.accepted}")
    print(f"  (paper protocol: 50k samples / 2000 epochs; this demo: "
          f"{tcfg.n_train} / {tcfg.epochs})")

    # -- 3. run a matmul on the emulated analog hardware ------------------- #
    ex = AnalogExecutor(acfg=AnalogConfig(backend="emulator"), geom=CASE_A,
                        cp=cp, emulator_params=res.params)
    w = jax.random.normal(key, (128, 8)) * 0.2
    xin = jax.random.normal(jax.random.fold_in(key, 1), (4, 128)) * 0.5
    ex.calibrate(jax.random.fold_in(key, 2), w, "demo")
    y_analog = ex.matmul(xin, w, "demo")
    y_digital = xin @ w
    corr = jnp.corrcoef(y_analog.ravel(), y_digital.ravel())[0, 1]
    print(f"analog-emulated matmul vs digital: corr = {corr:.3f}")


if __name__ == "__main__":
    main()
