"""End-to-end driver: hardware-aware training of a small LM whose MLP
matmuls execute on SEMULATOR-emulated analog crossbars (forward analog,
backward straight-through digital), for a few hundred steps, with
fault-tolerant checkpointing.

Run:  PYTHONPATH=src python examples/train_analog_aware.py [--steps 200]
"""
import argparse
import dataclasses

import jax

from repro.configs import get_config, reduced
from repro.configs.base import AnalogConfig, ParallelConfig, TrainConfig
from repro.configs.rram_ps32 import CASE_A, EmulatorTrainConfig
from repro.core.analog import AnalogExecutor
from repro.core.circuit import CircuitParams
from repro.core.emulator import train_emulator
from repro.data import SyntheticLMData
from repro.models.common import use_dense_hook
from repro.runtime.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--backend", default="emulator",
                    choices=["digital", "analytic", "emulator"])
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch), layers=2)
    pcfg = ParallelConfig(attn_block_kv=32, xent_chunk=32, scan_chunk=16)
    tcfg = TrainConfig(lr=1e-3, warmup_steps=10, total_steps=args.steps,
                       checkpoint_every=50)
    data = SyntheticLMData(cfg, seq_len=32, global_batch=4)

    hook = None
    if args.backend != "digital":
        ex = AnalogExecutor(
            acfg=AnalogConfig(backend=args.backend, layers=("mlp",)),
            geom=CASE_A, cp=CircuitParams())
        if args.backend == "emulator":
            print("training the block emulator first ...")
            res = train_emulator(
                jax.random.PRNGKey(0), CASE_A, AnalogConfig(),
                CircuitParams(),
                EmulatorTrainConfig(n_train=3000, n_test=400, epochs=30,
                                    lr=2e-3, lr_halve_at=(20,),
                                    batch_size=256))
            ex.emulator_params = res.params
            print(f"  emulator MAE {res.test_mae*1e3:.2f} mV")
        hook = ex.hook

    trainer = Trainer(cfg=cfg, pcfg=pcfg, tcfg=tcfg, mesh=None, data=data,
                      ckpt_dir="/tmp/repro_analog_ckpt")
    import contextlib
    ctx = use_dense_hook(hook) if hook else contextlib.nullcontext()
    with ctx:
        summary = trainer.run(args.steps)
    losses = [m["loss"] for m in trainer.metrics_log]
    n = max(len(losses) // 10, 1)
    print(f"{args.backend}: loss {sum(losses[:n])/n:.4f} -> "
          f"{sum(losses[-n:])/n:.4f} over {summary['final_step']} steps "
          f"({summary['restarts']} restarts)")


if __name__ == "__main__":
    main()
