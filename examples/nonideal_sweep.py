"""Device non-ideality walkthrough: program a weight matrix onto an
emulated crossbar, degrade the device corner step by step, and sweep N
fabricated devices per corner in one compiled call.

Run:  PYTHONPATH=src python examples/nonideal_sweep.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AnalogConfig
from repro.configs.rram_ps32 import CASE_A
from repro.core.analog import AnalogExecutor
from repro.nonideal import (Scenario, ScenarioSweep, get_scenario,
                            list_scenarios, register_scenario,
                            scenario_to_json)


def main():
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (128, 8)) * 0.2
    x = jax.random.normal(jax.random.fold_in(key, 1), (16, 128)) * 0.5
    y_digital = np.asarray(x @ w)

    ex = AnalogExecutor(acfg=AnalogConfig(backend="analytic"), geom=CASE_A)
    ex.calibrate(jax.random.fold_in(key, 2), w, "demo")

    print("registered scenarios:", ", ".join(list_scenarios()))
    print("\ncorner-by-corner (one fixed device draw each):")
    for name in ("ideal", "prog_mild", "prog_heavy", "stuck_1pct",
                 "quantized_16", "drift_1day", "stressed"):
        ex.deploy(scenario=get_scenario(name), key=jax.random.PRNGKey(42))
        y = np.asarray(ex.matmul(x, w, "demo"))
        corr = np.corrcoef(y.ravel(), y_digital.ravel())[0, 1]
        print(f"  {name:14s} corr vs digital = {corr:+.4f}")
    ex.deploy(scenario=None)

    # custom corner: JSON round-trippable, registry-addressable
    mine = register_scenario(Scenario(name="my_fab", prog_sigma=0.06,
                                      p_stuck_off=0.01, n_levels=32),
                             overwrite=True)
    print(f"\ncustom scenario JSON: {scenario_to_json(mine)}")

    # device-to-device variation: 8 fabricated devices per sigma, ONE
    # compiled call for the whole curve (scenario params are traced)
    sweep = ScenarioSweep(ex, w, "demo", n_draws=8)
    print("\ndevice-to-device spread vs programming sigma (8 devices):")
    for s in (0.0, 0.05, 0.1, 0.2):
        ys = np.asarray(sweep(x, dataclasses.replace(mine, prog_sigma=s),
                              jax.random.PRNGKey(7)))
        spread = ys.std(axis=0).mean()
        print(f"  sigma={s:4.2f}  mean output spread = {spread:.5f}")
    print(f"sweep executables compiled: {sweep.trace_count} (the whole "
          f"curve reuses one)")


if __name__ == "__main__":
    main()
