"""Serve a small model with batched requests: prefill + KV-cache decode
(ring buffers for local/chunked layers, state caches for SSM layers).

Run:  PYTHONPATH=src python examples/serve_batched.py --arch recurrentgemma-2b
"""
import sys

from repro.launch import serve


def main():
    if "--arch" not in sys.argv:
        sys.argv += ["--arch", "recurrentgemma-2b"]
    if "--reduced" not in sys.argv:
        sys.argv += ["--reduced"]
    serve.main()


if __name__ == "__main__":
    main()
