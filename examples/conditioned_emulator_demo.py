"""Scenario-conditioned emulator demo: train ONE Conv4Xbar over the whole
device-corner manifold, then serve an aging, heterogeneous crossbar fleet
through it with ZERO retraining between checkpoints -- the net reads the
fleet's age and corner off its scenario-feature input.

Phases (mirroring examples/crossbar_lifetime_demo.py):
  1. train   -- sample corners jointly with inputs, one training run
  2. deploy  -- same fleet twice: a plain net left alone vs the
                conditioned net (remap + recalibrate, no retrain)
  3. compare -- accuracy vs age against the young-ideal computation
  4. verify  -- zero retrains recorded, whole walk compiled once

Writes the trained conditioned params to
``results/conditioned_emulator_demo.npz`` (benchmarks-cache npz format),
ready for ``launch/serve.py --conditioned-emulator``.  See
docs/emulator.md.

Run:  PYTHONPATH=src python examples/conditioned_emulator_demo.py [--quick]
"""
import argparse
import os

import jax
import numpy as np

from repro.configs.base import AnalogConfig
from repro.configs.rram_ps32 import CASE_A, EmulatorTrainConfig
from repro.core.analog import AnalogExecutor
from repro.core.circuit import CircuitParams
from repro.core.emulator import train_emulator
from repro.nonideal import (LifetimeScheduler, tile_scenarios,
                            train_conditioned_emulator)

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")

# small protocols: enough to show the conditioning effect, not paper-grade
DEMO = EmulatorTrainConfig(n_train=4_000, n_test=500, epochs=60, lr=2e-3,
                           lr_halve_at=(30, 45), batch_size=512)
SMOKE = EmulatorTrainConfig(n_train=1_024, n_test=256, epochs=12, lr=2e-3,
                            lr_halve_at=(8,), batch_size=256)


def accuracy(y, ref):
    nrmse = np.linalg.norm(np.asarray(y) - ref) / np.linalg.norm(ref)
    return 1.0 / (1.0 + nrmse)


def main(quick: bool = False):
    tcfg = SMOKE if quick else DEMO
    acfg, cp, geom = AnalogConfig(), CircuitParams(), CASE_A
    key = jax.random.PRNGKey(0)

    print("phase 1: train one plain and one scenario-conditioned emulator")
    plain = train_emulator(key, geom, acfg, cp, tcfg)
    cond = train_conditioned_emulator(key, geom, acfg, cp, tcfg)
    print(f"  plain       test MSE {plain.test_mse:.3e}")
    print(f"  conditioned test MSE {cond.test_mse:.3e} "
          f"(over the corner manifold)")

    print("phase 2: deploy one aging fleet twice")
    w = jax.random.normal(key, (64, 8)) * 0.2
    x = jax.random.normal(jax.random.fold_in(key, 1), (4, 64)) * 0.5
    fleet_key = jax.random.fold_in(key, 2)

    def make_ex(params):
        return AnalogExecutor(acfg=AnalogConfig(backend="emulator"),
                              geom=geom, emulator_params=params,
                              use_pallas=False)

    probe = make_ex(plain.params)._plan_for(w, "probe")
    sigma = np.broadcast_to(np.linspace(0.02, 0.08, probe.NO),
                            (probe.NB, probe.NO))
    fleet = tile_scenarios(probe.NB, probe.NO, name="fleet",
                           prog_sigma=sigma, p_stuck_off=0.04, drift_nu=0.05)

    exc = AnalogExecutor(acfg=AnalogConfig(backend="circuit"), geom=geom)
    exc.calibrate(jax.random.fold_in(key, 9), w, "ref", n=32)
    ref = np.asarray(exc.matmul(x, w, "ref"))   # young-ideal ground truth

    neglected = LifetimeScheduler(make_ex(plain.params), fleet, remap=False,
                                  recalibrate=False, key=fleet_key,
                                  calib_n=32)
    recs_n = neglected.run(w, "mlp", x)
    managed = LifetimeScheduler(make_ex(cond.params), fleet, remap=True,
                                recalibrate=True, key=fleet_key, calib_n=32)
    recs_c = managed.run(w, "mlp", x)

    print("phase 3: accuracy vs age (vs the young ideal computation)")
    print(f"  {'age':>4}  {'neglected':>9}  {'conditioned':>11}")
    for n, c in zip(recs_n, recs_c):
        an, ac = accuracy(n["y"], ref), accuracy(c["y"], ref)
        print(f"  {n['label']:>4}  {an:9.4f}  {ac:11.4f}"
              f"   {'<- one net, zero retraining' if ac > an else ''}")

    print("phase 4: verify")
    assert managed.conditioned, "scheduler should ride the conditioned net"
    assert not any(r["retrained"] for r in recs_c), \
        "conditioned walk must record zero retrains"
    # matmul batch + cold/warm calibration batches on the ONE unified
    # forward; corners and ages never add executables
    assert managed.ex._fns["mlp"][2]._cache_size() == 3, \
        "whole walk (corners + ages) must reuse one compiled forward "\
        "per input shape"
    print("  zero retrains + compile-once verified")

    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, "conditioned_emulator_demo.npz")
    # benchmarks-cache npz format (what serve --emulator-params loads)
    np.savez(path, **{k: np.asarray(v) for k, v in cond.params.items()})
    print(f"  conditioned params -> {os.path.abspath(path)} "
          f"(serve with --conditioned-emulator)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: tiny training protocol")
    args = ap.parse_args()
    main(quick=args.quick)
