"""Crossbar fleet lifetime demo: fabricate a heterogeneous fleet with
stuck cells, watch the unmanaged copy decay as retention drift sets in,
then re-run the same fleet under lifetime management (stuck-fault-aware
remapping + drift-scheduled recalibration) and compare accuracy-vs-age.

Mirrors the inject -> observe -> mitigate -> verify phases of
examples/fault_tolerance_demo.py, for device lifetime instead of
trainer-node failures.  See docs/lifetime.md.

Run:  PYTHONPATH=src python examples/crossbar_lifetime_demo.py
"""
import jax
import numpy as np

from repro.configs.base import AnalogConfig
from repro.configs.rram_ps32 import CASE_A
from repro.core.analog import AnalogExecutor
from repro.nonideal import LifetimeScheduler, tile_scenarios


def accuracy(y, ref):
    nrmse = np.linalg.norm(np.asarray(y) - ref) / np.linalg.norm(ref)
    return 1.0 / (1.0 + nrmse)


def main():
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (128, 16)) * 0.2
    x = jax.random.normal(jax.random.fold_in(key, 1), (8, 128)) * 0.5

    def make_ex():
        return AnalogExecutor(acfg=AnalogConfig(backend="analytic"),
                              geom=CASE_A)

    print("phase 1: fabricate a heterogeneous fleet "
          "(sigma gradient + 4% stuck-off cells + drift)")
    plan = make_ex()._plan_for(w, "probe")
    sigma = np.broadcast_to(np.linspace(0.02, 0.08, plan.NO),
                            (plan.NB, plan.NO))
    fleet = tile_scenarios(plan.NB, plan.NO, name="fleet", prog_sigma=sigma,
                           p_stuck_off=0.04, drift_nu=0.05)
    fleet_key = jax.random.fold_in(key, 2)      # the fleet's identity

    # young ideal reference: what this layer computed on perfect hardware
    exi = make_ex()
    exi.calibrate(jax.random.fold_in(key, 9), w, "mlp", n=64)
    ref = np.asarray(exi.matmul(x, w, "mlp"))

    print("phase 2: deploy unmanaged (calibrate once, then neglect)")
    unmanaged = LifetimeScheduler(make_ex(), fleet, remap=False,
                                  recalibrate=False, key=fleet_key,
                                  calib_n=64)
    recs_u = unmanaged.run(w, "mlp", x)

    print("phase 3: same fleet, managed "
          "(fault-aware remap + recalibration at each checkpoint)")
    managed = LifetimeScheduler(make_ex(), fleet, remap=True,
                                recalibrate=True, key=fleet_key, calib_n=64)
    recs_m = managed.run(w, "mlp", x)

    print("phase 4: accuracy vs age (vs the young ideal computation)")
    print(f"  {'age':>4}  {'unmanaged':>9}  {'managed':>9}")
    for u, m in zip(recs_u, recs_m):
        au, am = accuracy(u["y"], ref), accuracy(m["y"], ref)
        print(f"  {u['label']:>4}  {au:9.4f}  {am:9.4f}"
              f"   {'<- mitigation wins' if am > au else ''}")
    # ONE unified forward; 3 executables = 3 input shapes (the matmul
    # batch, the cold calibration batch, the warm half-budget batch) --
    # ages, remaps and recalibrations are all DeploymentState leaves
    assert managed.ex._fns["mlp"][2]._cache_size() == 3, \
        "lifetime walk must reuse one compiled forward per input shape"
    print("compile-once verified: the whole managed walk reused one "
          "executable per input shape")


if __name__ == "__main__":
    main()
