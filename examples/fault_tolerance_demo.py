"""Fault-tolerance demo: inject node failures mid-training and watch the
supervisor restore from the latest checkpoint and carry on; then do an
elastic 'lost half the fleet' remesh restart (multi-device simulation).

Run:  PYTHONPATH=src python examples/fault_tolerance_demo.py
"""
import os
import shutil

# simulate an 8-device pod (must precede jax import)
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import jax  # noqa: E402

from repro.configs import get_config, reduced  # noqa: E402
from repro.configs.base import ParallelConfig, TrainConfig  # noqa: E402
from repro.data import SyntheticLMData  # noqa: E402
from repro.runtime.trainer import SimulatedFailure, Trainer  # noqa: E402


def main():
    ckpt = "/tmp/repro_ft_demo"
    shutil.rmtree(ckpt, ignore_errors=True)
    cfg = reduced(get_config("qwen1.5-110b"))
    pcfg = ParallelConfig(attn_block_kv=32, xent_chunk=32, scan_chunk=16)
    tcfg = TrainConfig(lr=1e-3, warmup_steps=2, total_steps=60,
                       checkpoint_every=10)
    data = SyntheticLMData(cfg, seq_len=32, global_batch=8)

    fail_at = {25: True, 41: True}

    def chaos(step):
        if fail_at.pop(step, False):
            print(f"  !! injecting node failure at step {step}")
            raise SimulatedFailure(f"node lost at step {step}")

    mesh = jax.make_mesh((4, 2), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    tr = Trainer(cfg=cfg, pcfg=pcfg, tcfg=tcfg, mesh=mesh, data=data,
                 ckpt_dir=ckpt, fault_hook=chaos)
    print("phase 1: training on a 4x2 mesh with injected failures")
    s = tr.run(40)
    print(f"  -> step {s['final_step']}, {s['restarts']} restarts, "
          f"{s['straggler_events']} straggler events")

    print("phase 2: 'lost half the fleet' -> elastic restart on 2x2")
    mesh2 = jax.make_mesh((2, 2), ("data", "model"),
                          axis_types=(jax.sharding.AxisType.Auto,) * 2)
    tr2 = tr.remesh(mesh2)
    s2 = tr2.run(60)
    print(f"  -> resumed at step {tr2.metrics_log[0]['step']}, "
          f"finished at {s2['final_step']}; "
          f"loss {tr2.metrics_log[0]['loss']:.3f} -> "
          f"{tr2.metrics_log[-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
